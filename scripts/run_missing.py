"""Batch-retry dry-run cells that have no results yet.

Reads ``arch|shape`` lines from ``/tmp/missing.txt`` (one cell per line, as
emitted by a prior ``repro.launch.dryrun`` sweep's gap report) and re-runs
each through ``python -m repro.launch.dryrun`` on the given mesh, printing a
per-cell return code.  Operator utility — not part of the library or CI.

    PYTHONPATH=src python scripts/run_missing.py [single|multi]
"""
import pathlib
import subprocess
import sys

mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
cells = [tuple(l.split("|")) for l in pathlib.Path("/tmp/missing.txt").read_text().splitlines() if l]
for arch, shape in cells:
    try:
        r = subprocess.run([sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                            "--shape", shape, "--mesh", mesh],
                           env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}, timeout=3000)
        rc = r.returncode
    except Exception as e:
        rc = repr(e)
    print(f"=== {arch} x {shape}: rc={rc}", flush=True)
print("DONE", flush=True)
