"""Mixture-of-Experts with explicit expert parallelism (shard_map).

Two compute layouts, both ZeRO-sharded for storage and combined with a
single psum over the ``model`` axis:

* ``ep``        — experts sharded over ("model","data"); inside the shard,
                  weights are all-gathered over "data" so each model-shard
                  owns a contiguous block of E/|model| experts.  Tokens are
                  masked to local experts, packed into an (E_loc, C, d)
                  capacity buffer, computed, and psum-combined over "model".
                  Used when E % (|model|·|data|) == 0 (deepseek-v3: 256).
* ``ffslice``   — experts sharded over "data" (storage) with d_ff sharded
                  over "model".  After the "data" all-gather every device
                  holds ALL experts with a 1/|model| slice of d_ff, so
                  dispatch is local and the ff-partial outputs are
                  psum-reduced over "model".  Used when E doesn't divide the
                  full mesh (llama4-maverick: 128 experts, top-1).

Dispatch uses capacity-based packing (GShard-style dropping) built from a
cumsum position-in-expert — the (N, E, C) one-hot dispatch tensor of the
original GShard einsum is never materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.common import compat
from repro.nn import layers


def init_moe(key, n_experts, d_model, d_ff, *, gated=True, n_shared=0, shared_d_ff=None,
             dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    fan = d_model
    def w(k, shape, mode="fan_in"):
        return layers.variance_scaling(k, shape, mode=mode, dtype=dtype)

    p = {
        "router": w(ks[0], (d_model, n_experts)),
        "wo": w(ks[3], (n_experts, d_ff, d_model), mode="fan_out"),
    }
    if gated:
        p["wi_0"] = w(ks[1], (n_experts, d_model, d_ff))
        p["wi_1"] = w(ks[2], (n_experts, d_model, d_ff))
    else:
        p["wi"] = w(ks[1], (n_experts, d_model, d_ff))
    if n_shared:
        p["shared"] = layers.init_ffn(ks[4], d_model, (shared_d_ff or d_ff) * n_shared,
                                      gated=gated, dtype=dtype)
    return p


def moe_param_specs(layout: str, *, stacked: bool = False):
    """PartitionSpecs for the expert weights (prepend None if scan-stacked)."""
    if layout == "ep":
        e3 = P(("model", "data"), None, None)
        router = P(None, None)
    else:  # ffslice
        e3 = P("data", None, "model")
        router = P(None, None)
    wo = P(("model", "data"), None, None) if layout == "ep" else P("data", "model", None)
    specs = {"router": router, "wi_0": e3, "wi_1": e3, "wi": e3, "wo": wo}
    if stacked:
        specs = {k: P(None, *v) for k, v in specs.items()}
    return specs


def _capacity(n_tokens: int, top_k: int, n_experts: int, factor: float, floor: int = 8):
    ideal = (n_tokens * top_k + n_experts - 1) // n_experts
    return int(min(max(floor, int(ideal * factor)), max(1, n_tokens * top_k)))


def _pack_dispatch(x, eid, gate, n_local: int, capacity: int):
    """Pack selected (token, expert) pairs into an (E_loc, C, d) buffer.

    x: (N, d); eid: (N, k) LOCAL expert ids (may be out of [0, n_local) =>
    dropped); gate: (N, k).  Returns (buffer, eid_flat, pos_flat, keep).
    """
    N, k = eid.shape
    e_flat = eid.reshape(-1)
    valid = (e_flat >= 0) & (e_flat < n_local)
    e_safe = jnp.where(valid, e_flat, n_local)  # park invalid in a trash row
    onehot = jax.nn.one_hot(e_safe, n_local + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos_flat = jnp.take_along_axis(pos, e_safe[:, None], axis=1)[:, 0]
    keep = valid & (pos_flat < capacity)
    tok = jnp.repeat(jnp.arange(N), k)
    buf = jnp.zeros((n_local, capacity, x.shape[-1]), x.dtype)
    buf = buf.at[
        jnp.where(keep, e_flat, n_local - 1),
        jnp.where(keep, pos_flat, capacity - 1),
    ].add(jnp.where(keep[:, None], x[tok], 0))
    return buf, e_flat, pos_flat, keep, tok


def _expert_ffn(buf, wi_0, wi_1, wi, wo, activation):
    act = layers.ACTIVATIONS[activation]
    if wi_0 is not None:
        h = act(jnp.einsum("ecd,edf->ecf", buf, wi_0)) * jnp.einsum("ecd,edf->ecf", buf, wi_1)
    else:
        h = act(jnp.einsum("ecd,edf->ecf", buf, wi))
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _moe_shard_body(x, router_w, wi_0, wi_1, wi, wo, *, layout, n_experts, top_k,
                    capacity_factor, activation, model_size, router_noise_eps=0.0):
    """Runs per-shard inside shard_map.  x: (Nloc, d) local tokens."""
    axis = "model"
    j = jax.lax.axis_index(axis)
    # ZeRO weight gather over the fsdp ("data") axis
    gather = lambda a: None if a is None else jax.lax.all_gather(a, "data", axis=0, tiled=True)
    wi_0, wi_1, wi, wo = gather(wi_0), gather(wi_1), gather(wi), gather(wo)

    N, d = x.shape
    logits = (x @ router_w.astype(x.dtype)).astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, top_k)  # (N, k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # load-balance aux loss (computed identically on all shards)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eid, n_experts, dtype=jnp.float32), axis=1), axis=0
    )
    aux = jnp.sum(me * ce) * n_experts

    if layout == "ep":
        n_local = n_experts // model_size
        lo = j * n_local
        local_eid = jnp.where((eid >= lo) & (eid < lo + n_local), eid - lo, -1)
    else:  # all experts local (ff sliced)
        n_local = n_experts
        local_eid = eid

    # capacity per expert derives from the GLOBAL expert count (expected
    # tokens/expert = N*k/E); sizing by the local count inflates the buffer
    # |model|x (found via the MODEL/HLO roofline ratio, EXPERIMENTS Perf-4)
    C = _capacity(N, top_k, n_experts, capacity_factor)
    buf, e_flat, pos_flat, keep, tok = _pack_dispatch(x, local_eid, gate, n_local, C)
    out_buf = _expert_ffn(buf, wi_0, wi_1, wi, wo, activation)  # (E_loc, C, d)

    # un-pack: gather each kept (token, slot) row back and weight by its gate
    rows = out_buf[
        jnp.where(keep, e_flat, 0), jnp.where(keep, pos_flat, 0)
    ]  # (N*k, d)
    g = (gate.reshape(-1) * keep).astype(rows.dtype)
    y = jnp.zeros_like(x).at[tok].add(rows * g[:, None])
    y = jax.lax.psum(y, axis)
    aux = jax.lax.pmean(aux, axis)
    return y, aux


def _moe_tokengather_body(x, router_w, wi_0, wi_1, wi, wo, *, layout, n_experts,
                          top_k, capacity_factor, activation, model_size,
                          data_size, batch_axes, n_local_tokens):
    """Decode-path MoE: gather TOKENS (KBs), never weights (GBs).

    Inverse of the ZeRO-gather body: each device keeps only its stored
    expert shard, all-gathers the (tiny) token set over the batch axes,
    computes its local experts, and one psum over ("model","data") combines
    the full expert sum — collective volume per layer is O(tokens·d) instead
    of O(E_local·d·ff) for the weight gather (4–5 orders of magnitude at
    decode shapes; EXPERIMENTS.md §Perf iteration 2)."""
    for ax in reversed(batch_axes):  # innermost first -> major-axis-ordered
        x = jax.lax.all_gather(x, ax, axis=0, tiled=True)
    N, d = x.shape
    logits = (x @ router_w.astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, top_k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(eid, n_experts, dtype=jnp.float32), axis=1), axis=0)
    aux = jnp.sum(me * ce) * n_experts

    j = jax.lax.axis_index("model")
    i = jax.lax.axis_index("data")
    if layout == "ep":  # storage P(("model","data")) on E: shard s = j*data + i
        n_local = max(1, n_experts // (model_size * data_size))
        lo = (j * data_size + i) * n_local
    else:  # ffslice: storage P("data", None, "model"): data shard i owns E/data
        n_local = max(1, n_experts // data_size)
        lo = i * n_local
    local_eid = jnp.where((eid >= lo) & (eid < lo + n_local), eid - lo, -1)
    C = _capacity(N, top_k, n_experts, capacity_factor)
    buf, e_flat, pos_flat, keep, tok = _pack_dispatch(x, local_eid, gate, n_local, C)
    out_buf = _expert_ffn(buf, wi_0, wi_1, wi, wo, activation)
    rows = out_buf[jnp.where(keep, e_flat, 0), jnp.where(keep, pos_flat, 0)]
    g = (gate.reshape(-1) * keep).astype(rows.dtype)
    y = jnp.zeros_like(x).at[tok].add(rows * g[:, None])
    y = jax.lax.psum(y, ("model", "data"))
    idx = 0
    for ax in batch_axes:
        idx = idx * compat.axis_size(ax) + jax.lax.axis_index(ax)
    y = jax.lax.dynamic_slice_in_dim(y, idx * n_local_tokens, n_local_tokens, axis=0)
    return y, jax.lax.pmean(aux, "model")


def moe_apply(params, x, *, layout: str, n_experts: int, top_k: int, mesh,
              capacity_factor: float = 1.25, activation: str = "silu",
              token_spec=None, token_gather_threshold: int = 4096):
    """x: (B, T, d) -> (y, aux_loss).  Must run under `mesh`.

    ``token_spec`` shards the flattened token axis; expert weights follow
    ``moe_param_specs(layout)``.  When the global token count is at most
    ``token_gather_threshold`` (decode shapes), the token-gather body is used
    instead of the ZeRO weight-gather body.
    """
    import numpy as np
    from repro.common.compat import shard_map

    B, T, d = x.shape
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_tok_shards = int(np.prod([mesh.shape[a] for a in batch_axes]))
    if (B * T) % max(n_tok_shards, 1) != 0:
        batch_axes = ()  # tiny decode batches: replicate tokens
        n_tok_shards = 1
    if token_spec is None:
        token_spec = P(batch_axes, None)
    xf = x.reshape(B * T, d)
    specs = moe_param_specs(layout)
    model_size = mesh.shape["model"]
    data_size = mesh.shape.get("data", 1)

    wi_0 = params.get("wi_0")
    wi_1 = params.get("wi_1")
    wi = params.get("wi")
    wo = params["wo"]

    in_specs = (
        token_spec,
        specs["router"],
        specs["wi_0"] if wi_0 is not None else P(),
        specs["wi_1"] if wi_1 is not None else P(),
        specs["wi"] if wi is not None else P(),
        specs["wo"],
    )
    if B * T <= token_gather_threshold:
        body = functools.partial(
            _moe_tokengather_body,
            layout=layout, n_experts=n_experts, top_k=top_k,
            capacity_factor=capacity_factor, activation=activation,
            model_size=model_size, data_size=data_size, batch_axes=batch_axes,
            n_local_tokens=(B * T) // n_tok_shards,
        )
    else:
        body = functools.partial(
            _moe_shard_body,
            layout=layout,
            n_experts=n_experts,
            top_k=top_k,
            capacity_factor=capacity_factor,
            activation=activation,
            model_size=model_size,
        )
    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(token_spec, P()),
        check_vma=False,
    )(xf, params["router"], wi_0, wi_1, wi, wo)

    y = y.reshape(B, T, d)
    if "shared" in params:
        y = y + layers.ffn(params["shared"], x, activation)
    return y, aux


def moe_apply_dense(params, x, *, n_experts: int, top_k: int,
                    activation: str = "silu"):
    """Reference single-device MoE (no dropping): computes ALL experts for all
    tokens and mixes with the gate.  Used for smoke tests / oracles only."""
    B, T, d = x.shape
    xf = x.reshape(-1, d)
    logits = (xf @ params["router"].astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, top_k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    act = layers.ACTIVATIONS[activation]
    if "wi_0" in params:
        h = act(jnp.einsum("nd,edf->nef", xf, params["wi_0"].astype(xf.dtype)))
        h = h * jnp.einsum("nd,edf->nef", xf, params["wi_1"].astype(xf.dtype))
    else:
        h = act(jnp.einsum("nd,edf->nef", xf, params["wi"].astype(xf.dtype)))
    y_all = jnp.einsum("nef,efd->ned", h, params["wo"].astype(xf.dtype))
    mix = jnp.sum(
        jax.nn.one_hot(eid, n_experts, dtype=xf.dtype) * gate[..., None].astype(xf.dtype),
        axis=1,
    )  # (N, E)
    y = jnp.einsum("ne,ned->nd", mix, y_all).reshape(B, T, d)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(eid, n_experts, dtype=jnp.float32), axis=1), axis=0)
    aux = jnp.sum(me * ce) * n_experts
    if "shared" in params:
        y = y + layers.ffn(params["shared"], x, activation)
    return y, aux
