"""Functional NN layers (init/apply pairs over plain dict pytrees).

No flax offline — this is the framework's module substrate.  Conventions:

* ``init_*(key, ...) -> params`` returns a dict pytree of fp32 arrays.
* ``apply`` functions are pure; compute dtype follows the input dtype
  (cast params at the call site via the dtype policy in the model).
* weight layout is always ``(d_in, d_out)`` so that logical sharding rules
  can be written as (fsdp-axis, tensor-axis) uniformly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def variance_scaling(key, shape, scale: float = 1.0, mode: str = "fan_in", dtype=jnp.float32):
    fan_in, fan_out = _fans(shape)
    denom = {"fan_in": fan_in, "fan_out": fan_out, "fan_avg": (fan_in + fan_out) / 2}[mode]
    std = np.sqrt(scale / max(denom, 1.0))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def _fans(shape):
    if len(shape) < 1:
        return 1.0, 1.0
    if len(shape) == 1:
        return float(shape[0]), float(shape[0])
    rf = float(np.prod(shape[:-2])) if len(shape) > 2 else 1.0
    return float(shape[-2]) * rf, float(shape[-1]) * rf


def init_dense(key, d_in: int, d_out: int, use_bias: bool = False, dtype=jnp.float32):
    p = {"kernel": variance_scaling(key, (d_in, d_out), dtype=dtype)}
    if use_bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params, x):
    y = x @ params["kernel"].astype(x.dtype)
    if "bias" in params:
        y = y + params["bias"].astype(x.dtype)
    return y


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    return {"embedding": jax.random.normal(key, (vocab, d), jnp.float32).astype(dtype) * (d**-0.5)}


def embed(params, ids):
    return jnp.take(params["embedding"], ids, axis=0)


def embed_logits(params, x):
    """Tied-embedding readout: (..., d) @ (d, vocab)."""
    return x @ params["embedding"].astype(x.dtype).T


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations / gated FFN
# ---------------------------------------------------------------------------

def gelu(x):
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS = {
    "gelu": gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}


def init_ffn(key, d_model: int, d_ff: int, gated: bool, use_bias: bool = False, dtype=jnp.float32):
    """Dense FFN.  ``gated=True`` gives GeGLU/SwiGLU layout (wi_0 gate, wi_1 up)."""
    k0, k1, k2 = jax.random.split(key, 3)
    p = {"wo": init_dense(k2, d_ff, d_model, use_bias, dtype)}
    if gated:
        p["wi_0"] = init_dense(k0, d_model, d_ff, use_bias, dtype)
        p["wi_1"] = init_dense(k1, d_model, d_ff, use_bias, dtype)
    else:
        p["wi"] = init_dense(k0, d_model, d_ff, use_bias, dtype)
    return p


def ffn(params, x, activation: str = "gelu"):
    act = ACTIVATIONS[activation]
    if "wi_0" in params:
        h = act(dense(params["wi_0"], x)) * dense(params["wi_1"], x)
    else:
        h = act(dense(params["wi"], x))
    return dense(params["wo"], h)


# ---------------------------------------------------------------------------
# MLP (generic, used by recsys towers / gnn / lemur)
# ---------------------------------------------------------------------------

def init_mlp(key, dims: tuple[int, ...], use_bias: bool = True, dtype=jnp.float32):
    """dims = (d_in, h1, ..., d_out)."""
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"layer_{i}": init_dense(keys[i], dims[i], dims[i + 1], use_bias, dtype)
        for i in range(len(dims) - 1)
    }


def mlp(params, x, activation: str = "relu", final_activation: bool = False):
    act = ACTIVATIONS[activation]
    n = len(params)
    for i in range(n):
        x = dense(params[f"layer_{i}"], x)
        if i < n - 1 or final_activation:
            x = act(x)
    return x
