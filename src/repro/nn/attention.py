"""Attention layers: RoPE, GQA/MQA/MHA, MLA (DeepSeek), KV caches.

Two execution paths:

* ``flash_attention`` — blockwise online-softmax attention in pure JAX
  (double ``lax.scan`` over query/KV blocks).  Never materializes the full
  (T, S) score matrix, so 32k prefill fits per-device HBM; GSPMD shards it
  like any einsum.  This is the path used inside the jitted system graphs
  (a Pallas flash kernel would not lower on the CPU-only container; the
  Pallas MaxSim/MIPS kernels in ``repro.kernels`` cover the paper's own
  hot spots and are validated in interpret mode).
* ``decode_attention`` — single-token query against a padded KV cache
  (scores are (B, H, 1, S): linear in S, safe to materialize).

Layouts: activations (B, T, D); q/k/v projections (D, H, head_dim);
caches (B, S_max, n_kv, head_dim) — batch on the data axis, heads or
sequence on the model axis (see repro.dist.sharding).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.nn import layers

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, base: float = 10000.0):
    return 1.0 / (base ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, base: float = 10000.0):
    """x: (B, T, H, D); positions: (B, T) int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, base)  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * inv  # (B, T, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# masking rule (never materialize (T, S) globally — evaluated per block)
# ---------------------------------------------------------------------------

def _allowed(q_pos, kv_pos, *, causal: bool, chunk: int | None = None, kv_len=None):
    """q_pos: (..., Tq), kv_pos: (Sb,) -> bool (..., Tq, Sb)."""
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        ok &= kp <= qp
    if chunk is not None:
        ok &= (kp // chunk) == (qp // chunk)
    if kv_len is not None:
        ok &= kp < kv_len
    return ok


# ---------------------------------------------------------------------------
# blockwise flash attention (pure JAX)
# ---------------------------------------------------------------------------

class _Carry(NamedTuple):
    o: jax.Array  # (B, Tq, K, G, D) fp32 — unnormalized output accumulator
    m: jax.Array  # (B, Tq, K, G) running max
    l: jax.Array  # (B, Tq, K, G) running sum


def _flash_q_block(q, k, v, q_pos, kv_pos, *, scale, causal, chunk, kv_block):
    """q: (B, Tq, K, G, D); k/v: (B, S, K, D). Returns (B, Tq, K, G, D)."""
    B, Tq, K, G, D = q.shape
    S = k.shape[1]
    nkv = S // kv_block

    kb = k.reshape(B, nkv, kv_block, K, -1)
    vb = v.reshape(B, nkv, kv_block, K, v.shape[-1])
    pb = kv_pos.reshape(nkv, kv_block)

    init = _Carry(
        o=jnp.zeros((B, Tq, K, G, v.shape[-1]), jnp.float32),
        m=jnp.full((B, Tq, K, G), NEG_INF, jnp.float32),
        l=jnp.zeros((B, Tq, K, G), jnp.float32),
    )

    def step(carry: _Carry, xs):
        kc, vc, pc = xs  # (B, Sb, K, Dk), (B, Sb, K, Dv), (Sb,)
        # scores: (B, Tq, K, G, Sb)
        s = jnp.einsum("btkgd,bskd->btkgs", q, kc, preferred_element_type=jnp.float32)
        s = s * scale
        ok = _allowed(q_pos, pc, causal=causal, chunk=chunk)  # (B?, Tq, Sb)
        ok = ok[:, :, None, None, :] if ok.ndim == 3 else ok[None, :, None, None, :]
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(carry.m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new == NEG_INF)
        m_safe = jnp.maximum(m_new, -0.5 * NEG_INF * 0 + NEG_INF * 0.99)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(ok, p, 0.0)
        alpha = jnp.exp(carry.m - m_new)
        alpha = jnp.where(carry.m <= NEG_INF * 0.5, 0.0, alpha)
        l_new = carry.l * alpha + jnp.sum(p, axis=-1)
        o_new = carry.o * alpha[..., None] + jnp.einsum(
            "btkgs,bskd->btkgd", p, vc.astype(jnp.float32), preferred_element_type=jnp.float32
        )
        return _Carry(o_new, m_new, l_new), None

    carry, _ = jax.lax.scan(
        step,
        init,
        (
            jnp.moveaxis(kb, 1, 0),
            jnp.moveaxis(vb, 1, 0),
            pb,
        ),
    )
    denom = jnp.maximum(carry.l, 1e-30)[..., None]
    return carry.o / denom


def flash_attention(
    q,
    k,
    v,
    q_positions,
    kv_positions,
    *,
    causal: bool = True,
    chunk: int | None = None,
    q_block: int = 1024,
    kv_block: int = 1024,
    scale: float | None = None,
):
    """q: (B, T, Hq, D), k/v: (B, S, Kv, D[v]).  Hq % Kv == 0 (GQA groups).

    Returns (B, T, Hq, Dv) in q.dtype.  Positions are absolute token indices
    (ints); masking (causal / chunked-local / cache-validity) is computed
    per block from positions, so no global mask tensor exists.
    """
    B, T, H, D = q.shape
    Kv = k.shape[2]
    G = H // Kv
    scale = scale if scale is not None else D**-0.5

    Tp = -(-T // q_block) * q_block
    Sp = -(-k.shape[1] // kv_block) * kv_block
    qp = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sp - k.shape[1]), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sp - v.shape[1]), (0, 0), (0, 0)))
    qpos = jnp.pad(q_positions, ((0, 0), (0, Tp - T)), constant_values=-1)
    kvpos = jnp.pad(kv_positions, (0, Sp - kv_positions.shape[0]), constant_values=2**30)

    qg = qp.reshape(B, Tp // q_block, q_block, Kv, G, D)

    def per_qblock(qb, qposb):
        # qb: (B, q_block, Kv, G, D), qposb: (B, q_block)
        return _flash_q_block(
            qb, kp, vp, qposb, kvpos, scale=scale, causal=causal, chunk=chunk, kv_block=kv_block
        )

    # scan over query blocks (keeps peak memory at one (q_block, kv_block) tile)
    qg_t = jnp.moveaxis(qg, 1, 0)  # (nq, B, q_block, Kv, G, D)
    qpos_t = jnp.moveaxis(qpos.reshape(B, Tp // q_block, q_block), 1, 0)
    out_blocks = jax.lax.map(lambda xs: per_qblock(*xs), (qg_t, qpos_t))
    out = jnp.moveaxis(out_blocks, 0, 1).reshape(B, Tp, H, v.shape[-1])
    return out[:, :T].astype(q.dtype)


def flash_attention_cp(q, k, v, q_positions, mesh, *, causal=True, chunk=None,
                       q_block: int = 1024, kv_block: int = 1024, scale=None):
    """Context-parallel flash attention (shard_map over the "model" axis).

    q/k/v enter seq-sharded (the residual stream's sequence-parallel layout);
    each shard all-gathers K/V ONCE and runs the blockwise flash core on its
    local T/|model| query rows.  Per layer this costs exactly one (B, S, Kv, D)
    gather — versus GSPMD re-gathering K/V inside every (q-block × kv-block)
    loop iteration when the nested-scan version is left to the partitioner
    (measured 440x collective inflation on the 32k prefill cells; see
    EXPERIMENTS.md §Perf iteration 1).  Causal load imbalance across shards
    is accepted (ring/striped attention is the documented next step).
    """
    from repro.common.compat import shard_map
    from jax.sharding import PartitionSpec as P

    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    S = k.shape[1]
    kv_pos = jnp.arange(S)

    def body(q_l, k_l, v_l, pos_l, kv_pos_f):
        k_f = jax.lax.all_gather(k_l, "model", axis=1, tiled=True)
        v_f = jax.lax.all_gather(v_l, "model", axis=1, tiled=True)
        return flash_attention(q_l, k_f, v_f, pos_l, kv_pos_f, causal=causal,
                               chunk=chunk, q_block=min(q_block, q_l.shape[1]),
                               kv_block=kv_block, scale=scale)

    seq4 = P(ba, "model", None, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(seq4, seq4, seq4, P(ba, "model"), P()),
        out_specs=seq4,
        check_vma=False,
    )(q, k, v, q_positions, kv_pos)


def _use_cp(mesh, T: int) -> bool:
    return (
        mesh is not None
        and "model" in getattr(mesh, "axis_names", ())
        and T % mesh.shape["model"] == 0
        and T // mesh.shape["model"] >= 128
    )


def decode_attention(q, k_cache, v_cache, kv_len, *, chunk: int | None = None, scale=None):
    """One-step decode.  q: (B, 1, Hq, D); caches: (B, S, Kv, D); kv_len: ()/(B,)."""
    B, _, H, D = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    G = H // Kv
    scale = scale if scale is not None else D**-0.5
    qg = q.reshape(B, 1, Kv, G, D)
    s = jnp.einsum("btkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32)
    s = s * scale
    kv_pos = jnp.arange(S)
    q_pos = (jnp.broadcast_to(jnp.asarray(kv_len), (B,)) - 1)[:, None]
    ok = _allowed(q_pos, kv_pos, causal=True, chunk=chunk, kv_len=jnp.asarray(kv_len))
    # ok: (B, 1, S) -> (B, 1, 1, S) broadcast over (Kv, G)
    s = jnp.where(ok[:, None, :, :] if ok.ndim == 3 else ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (init / train / prefill / decode)
# ---------------------------------------------------------------------------

def init_gqa(key, d_model, n_heads, n_kv, head_dim, qkv_bias=False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.variance_scaling(ks[0], (d_model, n_heads, head_dim), dtype=dtype),
        "wk": layers.variance_scaling(ks[1], (d_model, n_kv, head_dim), dtype=dtype),
        "wv": layers.variance_scaling(ks[2], (d_model, n_kv, head_dim), dtype=dtype),
        "wo": layers.variance_scaling(ks[3], (n_heads, head_dim, d_model), mode="fan_out", dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), dtype)
        p["bk"] = jnp.zeros((n_kv, head_dim), dtype)
        p["bv"] = jnp.zeros((n_kv, head_dim), dtype)
    return p


def _qkv(params, x):
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    return q, k, v


def gqa_train(params, x, positions, *, rope_base=10000.0, chunk=None, q_block=1024,
              kv_block=1024, mesh=None):
    """Full causal self-attention over x: (B, T, D)."""
    q, k, v = _qkv(params, x)
    q = apply_rope(q, positions, rope_base)
    k = apply_rope(k, positions, rope_base)
    if _use_cp(mesh, x.shape[1]):
        o = flash_attention_cp(q, k, v, positions, mesh, causal=True, chunk=chunk,
                               q_block=q_block, kv_block=kv_block)
    else:
        o = flash_attention(
            q, k, v, positions, positions[0], causal=True, chunk=chunk,
            q_block=q_block, kv_block=kv_block
        )
    return jnp.einsum("bthk,hkd->btd", o, params["wo"].astype(x.dtype))


def gqa_prefill(params, x, positions, cache_len, *, rope_base=10000.0, chunk=None,
                q_block=1024, kv_block=1024, mesh=None):
    """Prefill: returns (out, (k_cache, v_cache)) with caches padded to cache_len."""
    q, k, v = _qkv(params, x)
    q = apply_rope(q, positions, rope_base)
    k = apply_rope(k, positions, rope_base)
    if _use_cp(mesh, x.shape[1]):
        o = flash_attention_cp(q, k, v, positions, mesh, causal=True, chunk=chunk,
                               q_block=q_block, kv_block=kv_block)
    else:
        o = flash_attention(
            q, k, v, positions, positions[0], causal=True, chunk=chunk,
            q_block=q_block, kv_block=kv_block
        )
    out = jnp.einsum("bthk,hkd->btd", o, params["wo"].astype(x.dtype))
    pad = cache_len - k.shape[1]
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out, (kc, vc)


def _masked_cache_write(cache, new, idx):
    """Write ``new`` (B, 1, ...) at seq position ``idx`` via a predicated
    select instead of dynamic-update-slice: elementwise select partitions
    under ANY cache sharding (seq-sharded included), whereas a dynamic-start
    DUS on the sharded axis makes GSPMD all-gather the cache."""
    S = cache.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, S) + (1,) * (cache.ndim - 2), 1)
    return jnp.where(iota == idx, new.astype(cache.dtype), cache)


def gqa_decode(params, x, cache, kv_len, *, rope_base=10000.0, chunk=None):
    """Decode one token.  x: (B, 1, D); cache: (k, v) each (B, S, Kv, hd).

    Returns (out, new_cache).  The new token is written at position kv_len-1...
    convention: ``kv_len`` INCLUDES the new token; its position is kv_len-1.
    """
    kc, vc = cache
    B = x.shape[0]
    pos = (jnp.broadcast_to(jnp.asarray(kv_len), (B,)) - 1)[:, None]  # (B, 1)
    q, k, v = _qkv(params, x)
    q = apply_rope(q, pos, rope_base)
    k = apply_rope(k, pos, rope_base)
    idx = jnp.asarray(kv_len) - 1
    kc = _masked_cache_write(kc, k, idx)
    vc = _masked_cache_write(vc, v, idx)
    o = decode_attention(q, kc, vc, kv_len, chunk=chunk)
    out = jnp.einsum("bthk,hkd->btd", o, params["wo"].astype(x.dtype))
    return out, (kc, vc)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2/V3 multi-head latent attention), absorbed formulation
# ---------------------------------------------------------------------------

def init_mla(key, d_model, n_heads, q_lora, kv_lora, qk_nope, qk_rope, v_head, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    return {
        "wq_a": layers.variance_scaling(ks[0], (d_model, q_lora), dtype=dtype),
        "q_norm": layers.init_rmsnorm(q_lora, dtype),
        "wq_b": layers.variance_scaling(ks[1], (q_lora, n_heads, qk_nope + qk_rope), dtype=dtype),
        "wkv_a": layers.variance_scaling(ks[2], (d_model, kv_lora + qk_rope), dtype=dtype),
        "kv_norm": layers.init_rmsnorm(kv_lora, dtype),
        "wk_b": layers.variance_scaling(ks[3], (kv_lora, n_heads, qk_nope), dtype=dtype),
        "wv_b": layers.variance_scaling(ks[4], (kv_lora, n_heads, v_head), dtype=dtype),
        "wo": layers.variance_scaling(ks[5], (n_heads, v_head, d_model), mode="fan_out", dtype=dtype),
    }


def _mla_query(params, x, positions, qk_nope, rope_base):
    ql = layers.rmsnorm(params["q_norm"], x @ params["wq_a"].astype(x.dtype))
    q = jnp.einsum("btl,lhk->bthk", ql, params["wq_b"].astype(x.dtype))
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = apply_rope(q_rope, positions, rope_base)
    # absorb k_up: q_nope (B,T,H,nope) x (kv_lora,H,nope) -> (B,T,H,kv_lora)
    q_lat = jnp.einsum("bthk,lhk->bthl", q_nope, params["wk_b"].astype(x.dtype))
    return q_lat, q_rope


def _mla_kv(params, x, positions, kv_lora, rope_base):
    kv = x @ params["wkv_a"].astype(x.dtype)  # (B, T, kv_lora + qk_rope)
    c_kv = layers.rmsnorm(params["kv_norm"], kv[..., :kv_lora])
    k_rope = kv[..., kv_lora:][:, :, None, :]  # (B, T, 1, rope)
    k_rope = apply_rope(k_rope, positions, rope_base)[:, :, 0, :]
    return c_kv, k_rope


def _mla_attend(params, q_lat, q_rope, c_kv, k_rope, q_pos, kv_pos, *, scale, kv_len=None):
    """Absorbed MLA attention.  q_lat: (B,T,H,L); c_kv: (B,S,L); k_rope: (B,S,R)."""
    s = jnp.einsum("bthl,bsl->bhts", q_lat, c_kv, preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bthr,bsr->bhts", q_rope, k_rope, preferred_element_type=jnp.float32)
    s = s * scale
    ok = _allowed(q_pos, kv_pos, causal=True, kv_len=kv_len)  # (B, T, S) or (T, S)
    ok = ok[:, None] if ok.ndim == 3 else ok[None, None]
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhts,bsl->bthl", p, c_kv.astype(jnp.float32))  # (B,T,H,L)
    o = jnp.einsum("bthl,lhv->bthv", o_lat.astype(q_lat.dtype), params["wv_b"].astype(q_lat.dtype))
    return o


def mla_train(params, x, positions, *, qk_nope, qk_rope, kv_lora, rope_base=10000.0,
              kv_block: int = 2048, q_block: int = 1024, mesh=None):
    """MLA causal self-attention via the flash core.

    The absorbed formulation IS MQA over the latent cache: the query is
    concat(q_lat, q_rope) with per-head dim kv_lora+qk_rope, the (single,
    shared) key is concat(c_kv, k_rope), and the value is c_kv — so the
    generic blockwise/context-parallel flash attention applies unchanged
    (Kv=1), with the true 1/sqrt(qk_nope+qk_rope) scale passed explicitly."""
    scale = (qk_nope + qk_rope) ** -0.5
    q_lat, q_rope = _mla_query(params, x, positions, qk_nope, rope_base)
    c_kv, k_rope = _mla_kv(params, x, positions, kv_lora, rope_base)
    q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)          # (B, T, H, L+R)
    k_cat = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None, :]  # (B, S, 1, L+R)
    v = c_kv[:, :, None, :]                                    # (B, S, 1, L)
    if _use_cp(mesh, x.shape[1]):
        o = flash_attention_cp(q_cat, k_cat, v, positions, mesh, causal=True,
                               q_block=q_block, kv_block=kv_block, scale=scale)
    else:
        o = flash_attention(q_cat, k_cat, v, positions, positions[0], causal=True,
                            q_block=q_block, kv_block=kv_block, scale=scale)
    o = jnp.einsum("bthl,lhv->bthv", o, params["wv_b"].astype(x.dtype))
    return jnp.einsum("bthv,hvd->btd", o, params["wo"].astype(x.dtype))


def mla_prefill(params, x, positions, cache_len, *, qk_nope, qk_rope, kv_lora,
                rope_base=10000.0, kv_block: int = 2048, q_block: int = 1024,
                mesh=None):
    out = mla_train(params, x, positions, qk_nope=qk_nope, qk_rope=qk_rope,
                    kv_lora=kv_lora, rope_base=rope_base, kv_block=kv_block,
                    q_block=q_block, mesh=mesh)
    c_kv, k_rope = _mla_kv(params, x, positions, kv_lora, rope_base)
    pad = cache_len - c_kv.shape[1]
    c_kv = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0)))
    k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    return out, (c_kv, k_rope)


def mla_decode(params, x, cache, kv_len, *, qk_nope, qk_rope, kv_lora, rope_base=10000.0):
    """Decode one token with the compressed latent cache (B, S, kv_lora)+(B, S, rope)."""
    c_cache, r_cache = cache
    scale = (qk_nope + qk_rope) ** -0.5
    B = x.shape[0]
    pos = (jnp.broadcast_to(jnp.asarray(kv_len), (B,)) - 1)[:, None]
    q_lat, q_rope = _mla_query(params, x, pos, qk_nope, rope_base)
    c_new, r_new = _mla_kv(params, x, pos, kv_lora, rope_base)
    idx = jnp.asarray(kv_len) - 1
    c_cache = _masked_cache_write(c_cache, c_new, idx)
    r_cache = _masked_cache_write(r_cache, r_new, idx)
    kv_pos = jnp.arange(c_cache.shape[1])
    o = _mla_attend(params, q_lat, q_rope, c_cache, r_cache, pos, kv_pos,
                    scale=scale, kv_len=jnp.asarray(kv_len))
    out = jnp.einsum("bthv,hvd->btd", o, params["wo"].astype(x.dtype))
    return out, (c_cache, r_cache)
