"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step):

    <dir>/step_000120/
        manifest.json      # leaf names, shapes, dtypes, shard map, config
        shard_00000.npz    # this process's leaves (np arrays)
        _COMMITTED         # written LAST: restore ignores dirs without it

Fault-tolerance properties:
  * atomic: the _COMMITTED marker is created only after every shard file is
    fsync'd, so a crash mid-save never corrupts the latest checkpoint;
    restore picks the newest committed step.
  * async: ``CheckpointManager.save_async`` snapshots device arrays to host
    (blocking only for the device->host copy) and writes on a worker thread,
    overlapping training.
  * elastic: arrays are saved UNSHARDED per-leaf (host gathers); restore
    re-shards onto whatever mesh/rules the new job provides — a restart can
    use a different device count (node failures / resizes).
  * retention: keep_last N steps are retained; older ones pruned after a
    successful commit.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

from repro.common.pytree import named_leaves


def _leaf_dict(tree: Any) -> dict[str, np.ndarray]:
    out = {}
    for name, leaf in named_leaves(tree):
        x = np.asarray(jax.device_get(leaf))
        if x.dtype.kind == "V" or str(x.dtype) == "bfloat16":
            # npz can't round-trip ml_dtypes (bf16 et al): store fp32; the
            # restore path casts back to the target leaf dtype.
            x = x.astype(np.float32)
        out[name] = x
    return out


def _fsync_file(path: pathlib.Path) -> None:
    with open(path, "rb") as f:
        os.fsync(f.fileno())


def _write_step(directory: str | os.PathLike, step: int,
                leaves: dict[str, np.ndarray], *,
                extra: dict | None = None) -> pathlib.Path:
    """The one crash-safe write path (sync and async saves both use it).

    Ordering is the whole contract: shard npz AND manifest are written and
    fsync'd BEFORE the ``_COMMITTED`` marker (itself fsync'd), all inside a
    ``.tmp`` staging dir that is renamed into place LAST.  A crash at any
    point leaves either the previous committed step intact (restore ignores
    dirs without the marker; ``.tmp`` names never match the step regex) or
    the new step fully durable — never a torn checkpoint."""
    d = pathlib.Path(directory) / f"step_{step:08d}"
    tmp = d.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in leaves.items()},
        "extra": extra or {},
    }
    np.savez(tmp / "shard_00000.npz",
             **{k.replace("/", "__"): v for k, v in leaves.items()})
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    _fsync_file(tmp / "shard_00000.npz")
    _fsync_file(tmp / "manifest.json")
    (tmp / "_COMMITTED").write_text("ok")
    _fsync_file(tmp / "_COMMITTED")
    if d.exists():
        shutil.rmtree(d)
    tmp.rename(d)
    return d


def save(directory: str | os.PathLike, step: int, tree: Any, *,
         extra: dict | None = None) -> pathlib.Path:
    """Synchronous checkpoint save.  Returns the committed step directory."""
    return _write_step(directory, step, _leaf_dict(tree), extra=extra)


def latest_step(directory: str | os.PathLike) -> int | None:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    best = None
    for sub in d.iterdir():
        m = re.fullmatch(r"step_(\d+)", sub.name)
        if m and (sub / "_COMMITTED").exists():
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


def restore(directory: str | os.PathLike, target_tree: Any, *,
            step: int | None = None, shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``target_tree`` (shapes validated).

    ``shardings``: optional pytree of NamedSharding — leaves are device_put
    with them (elastic re-shard onto the current mesh)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory}")
    d = pathlib.Path(directory) / f"step_{step:08d}"
    data = np.load(d / "shard_00000.npz")
    stored = {k.replace("__", "/"): data[k] for k in data.files}

    names = [n for n, _ in named_leaves(target_tree)]
    missing = [n for n in names if n not in stored]
    if missing:
        raise KeyError(f"checkpoint {d} missing leaves: {missing[:5]}...")

    flat_shardings = None
    if shardings is not None:
        flat_shardings = dict(named_leaves(shardings))

    def fill(name_leaf):
        name, leaf = name_leaf
        arr = stored[name]
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != target {want}")
        out = jax.numpy.asarray(arr).astype(leaf.dtype)
        if flat_shardings is not None and name in flat_shardings:
            return jax.device_put(out, flat_shardings[name])
        return out

    leaves = [fill(nl) for nl in named_leaves(target_tree)]
    treedef = jax.tree_util.tree_structure(target_tree)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class CheckpointManager:
    """Async save + retention + restore-latest."""

    def __init__(self, directory: str | os.PathLike, keep_last: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save_async(self, step: int, tree: Any, *, extra: dict | None = None):
        self.wait()
        # snapshot to host synchronously (cheap vs step time), write async
        host = _leaf_dict(tree)

        def work():
            try:
                # same crash-safe ordering as the sync path — the async
                # worker used to skip every fsync, so a host crash after
                # "commit" could still lose or tear the step
                _write_step(self.directory, step, host, extra=extra)
                self._prune()
            except Exception as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def restore_latest(self, target_tree: Any, shardings: Any = None):
        return restore(self.directory, target_tree, shardings=shardings)

    def latest_step(self):
        return latest_step(self.directory)

    def _prune(self):
        steps = sorted(
            int(m.group(1))
            for sub in self.directory.iterdir()
            if (m := re.fullmatch(r"step_(\d+)", sub.name)) and (sub / "_COMMITTED").exists()
        )
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)
