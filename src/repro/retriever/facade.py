"""LemurRetriever: the stable Retriever API v1 facade.

One object owns the full lifecycle of a LEMUR index (Fig. 1):

    r = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0))
    scores, ids = r.search(q_tokens, q_mask, SearchParams(k=10))
    r.add(new_doc_tokens, new_doc_mask)          # incremental growth (§4.3)
    r.delete(r.last_added_ids)                   # tombstone + page free
    r.update([3, 7], new_tokens, new_mask)       # delete+add, ONE version
    r2 = r.with_backend("muvera")                # same reduction, new stage
    sr = r.shard(mesh)                           # multi-device serving
    r.save("my_index/"); r = LemurRetriever.load("my_index/")

Design points:

* **Paged corpus, surviving compile caches.**  The corpus lives in a
  :class:`repro.core.pages.PagedStore` (fixed-size token pages + per-doc
  page table + tombstones; stable slot ids).  Compiled query fns take the
  WHOLE mutable state (ψ, stats, store, backend state) as jit ARGUMENTS —
  never baked in as closure constants — so a mutation that fits the
  pre-grown pool changes no shapes and issues ZERO new traces; only a
  power-of-two capacity-bucket growth retraces.  ``_compiled`` is never
  cleared on mutation.

* **Build-time vs query-time split.**  ``LemurConfig`` (with its per-backend
  namespaces) is fixed at ``build()``; every query-time knob travels in a
  frozen :class:`SearchParams`.  ``search()`` resolves the params against
  the config once, then caches exactly one ``jax.jit``-compiled query fn
  per (backend, resolved params) — jit itself specializes per batch shape,
  so compilation count is one per (backend, params, batch-shape), observable
  via :meth:`trace_count`.

* **Deterministic growth.**  ``build()`` retains the OLS solver state
  (Gram factor + the n' training tokens), so ``add()`` fits new W rows with
  the exact build-time solver.  When the solver is gone (e.g. a legacy
  index wrapped directly), the corpus-sampling fallback takes an explicit
  ``seed`` instead of the v0 hidden ``default_rng(0)``.

* **Persistence.**  ``save()``/``load()`` use ``checkpoint/manager.py``'s
  atomic manifest+shards format: cfg, ψ, W, doc tokens, the backend name
  and its opaque packed state (plus the OLS tokens, so ``add()`` stays
  deterministic after a reload).  Round-trip reproduces search ids
  bit-identically.

The v0 free functions (``core.index.build_index`` / ``attach_backend`` /
``add_docs`` / ``query`` / ``candidates``) are thin shims over this module.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.anns import ivf as _ivf
from repro.anns import registry
from repro.anns.base import CorpusView, QueryBatch, pad_topk
from repro.anns.bruteforce import mips_topk
from repro.checkpoint import manager as ckpt
from repro.core import indexer, maxsim, pages
from repro.core.config import LemurConfig
from repro.kernels import ops
from repro.core.index import LemurIndex
from repro.core.model import TargetStats, pool_queries, train_phi
from repro.retriever.params import SearchParams

FORMAT = "lemur-retriever-v1"


class CorruptIndexError(ValueError):
    """A rebuilt index failed install-time validation in
    :meth:`LemurRetriever.install_refresh` — the last-good snapshot is left
    fully installed.  Serving layers treat this as ``SwapAborted``, never as
    a torn state.  ``preserves_replica_state`` tells the fleet write barrier
    this is a typed rejection with the replica intact, not a replica
    failure — no quarantine."""

    preserves_replica_state = True


# --------------------------------------------------------------------------
# pure query pipeline (jit-able; params must be fully resolved)
# --------------------------------------------------------------------------

def first_stage(index: LemurIndex, q_tokens, q_mask, params: SearchParams):
    """Pool queries and run the selected backend (or the exact latent scan).

    One-launch routing happens HERE, not in the backend protocol: the fused
    first stage consumes the raw query tokens plus ψ (the projection runs
    inside the kernel), while ``be.search`` only ever sees the pooled
    latent.  The candidate ids are bit-identical either way (fp32).

    Every path ends in :func:`pages.mask_dead`: first-stage backends are
    never rebuilt on ``delete()``, so their candidate lists can contain
    tombstoned slots — the mask turns those into ``-1`` pads, the single
    choke point that guarantees a deleted doc never surfaces."""
    store = index.store
    if (params.use_ann and index.backend == "ivf"
            and getattr(params.backend, "use_one_launch", False)):
        bp = params.backend
        nprobe = min(int(bp.nprobe or min(32, index.ann.nlist)),
                     index.ann.nlist)
        _, cand = _ivf.search_ivf_one_launch(
            index.ann, index.psi, q_tokens, q_mask, nprobe, params.k_prime)
        return pages.mask_dead(store, cand)
    psi_q = pool_queries(index.psi, q_tokens, q_mask)  # (B, d')
    if not params.use_ann:
        # exact latent scan over the store's full slot CAPACITY — dead and
        # unallocated slots are masked by the (traced) alive bits, so the
        # scan shape is jit-static across mutations
        kk = min(params.k_prime, store.W.shape[0])
        if params.use_one_launch:
            # fused dense scan + in-kernel top-k' — never materializes the
            # (B, C) score matrix; ids match the blocked mips_topk bit for bit
            top, cand = ops.mips_topk_fused(psi_q, store.W, None, kk,
                                            valid=store.alive)
        else:
            top, cand = mips_topk(psi_q, store.W, kk, valid=store.alive)
        cand = pad_topk(top, cand, params.k_prime)[1]
        return pages.mask_dead(store, cand)
    be = registry.get_backend(index.backend)
    _, cand = be.search(index.ann, QueryBatch(psi_q, q_tokens, q_mask),
                        params.k_prime, params.backend)
    return pages.mask_dead(store, cand)


def search_pipeline(index: LemurIndex, q_tokens, q_mask, params: SearchParams):
    """pool -> first-stage candidates -> exact MaxSim rerank -> top-k.

    ``-1``-padded first-stage rows (including tombstoned docs masked by
    ``first_stage``) score NEG inside the rerank — pads can never surface
    as results.  ``params.use_fused_gather`` (the resolved default) sends
    the rerank through the page-fed kernel path
    (``kernels.ops.fused_rerank_paged``: each candidate's token pages are
    DMA'd straight into VMEM on TPU, page ids from SMEM, instead of
    materializing the ``(B, k', Tm, d)`` gather in HBM); ``False`` keeps
    the legacy materialize-from-pages + ``maxsim.rerank_gathered`` path
    benchmarkable — both return bit-identical ids on fp32."""
    cand = first_stage(index, q_tokens, q_mask, params)
    store = index.store
    if store.residual and params.use_residual and params.use_fused_gather:
        # compressed tier, fused path: candidate pages are DMA'd as centroid
        # ids + packed residual codes and dequantized INSIDE the rerank
        # kernel — fp32 token pages never exist
        return ops.fused_rerank_paged_res(
            q_tokens, q_mask, cand, store.cent_pages, store.code_pages,
            store.page_table, store.n_tokens, store.codec.centroids,
            store.codec.values, params.k)
    if params.use_fused_gather and not store.residual:
        return ops.fused_rerank_paged(q_tokens, q_mask, cand,
                                      store.tok_pages, store.page_table,
                                      store.n_tokens, params.k)
    # legacy HBM gather; on the compressed tier gather_docs residual-decodes
    # on the fly, so this is also the use_residual=False decoded-view path
    toks, tmask = pages.gather_docs(store, cand)
    return maxsim.rerank_gathered(q_tokens, q_mask, cand, toks, tmask,
                                  params.k)


def launch_plan(resolved: SearchParams) -> dict[str, int]:
    """Static per-search kernel-launch breakdown for a RESOLVED params.

    The legacy first stage is 3 corpus-scale launches before the rerank
    (ψ projection → scan → top-k'); the one-launch path collapses them into
    a single fused kernel.  This is the accounting BENCH rows and
    ``examples/serve_batched.py`` print, and what :meth:`LemurRetriever.
    launches` asserts: the one-launch plan has exactly 1 pre-rerank launch.
    """
    one = bool(getattr(resolved.backend, "use_one_launch", False)
               if resolved.use_ann else resolved.use_one_launch)
    if one:
        plan = {"one_launch": 1, "rerank": 1}
    else:
        plan = {"projection": 1, "scan": 1, "topk": 1, "rerank": 1}
    pre = sum(v for name, v in plan.items() if name != "rerank")
    assert not one or pre == 1, plan   # the one-launch contract
    return plan


# --------------------------------------------------------------------------
# the facade
# --------------------------------------------------------------------------

class LemurRetriever:
    """Stable facade over a :class:`LemurIndex` (see module docstring).

    Construct via :meth:`build` / :meth:`load`, or wrap an existing
    ``LemurIndex`` directly (``LemurRetriever(index)``)."""

    def __init__(self, index: LemurIndex, *, solver_state: dict | None = None,
                 x_ols: jax.Array | None = None):
        self._index = index
        self._solver = solver_state
        self._x_ols = x_ols if x_ols is not None else (
            solver_state["x_ols"] if solver_state else None)
        self._compiled: dict[tuple, Any] = {}
        self._trace_counts: dict[tuple, int] = {}
        self._trace_shapes: dict[tuple, int] = {}
        self._resolve_memo: dict[SearchParams | None, SearchParams] = {}
        self._version = 0
        # page allocator: lazily derived from the store (deterministic —
        # snapshots/checkpoints never persist it), then threaded through
        # mutations.  Byte counters feed the add-amortization bench.
        self._free_pages: list[int] | None = None
        self._last_added_ids = np.empty((0,), np.int32)
        self._last_mutation_bytes = 0
        self._bytes_moved = 0

    # -- introspection ------------------------------------------------------

    @property
    def index(self) -> LemurIndex:
        return self._index

    @property
    def cfg(self) -> LemurConfig:
        return self._index.cfg

    @property
    def backend(self) -> str:
        return self._index.backend

    @property
    def m(self) -> int:
        return self._index.m

    @property
    def n_alive(self) -> int:
        """Live (non-tombstoned) docs; ``m`` stays the slot high-water mark
        because external ids are stable slot indices."""
        return self._index.n_alive

    @property
    def version(self) -> int:
        """Snapshot version: bumped by every :meth:`add` / :meth:`delete` /
        :meth:`update` (update bumps ONCE).  Serving layers
        (``repro.serving``) use it to tell which corpus snapshot answered a
        request."""
        return self._version

    @property
    def last_added_ids(self) -> np.ndarray:
        """Slot ids allocated by the most recent :meth:`add`/:meth:`update`."""
        return self._last_added_ids

    @property
    def last_mutation_bytes(self) -> int:
        """Logical bytes the most recent mutation wrote (pages + touched
        table/W rows + any bucket-growth copy) — O(doc) when the pool has
        capacity; the add-amortization bench gates on this."""
        return self._last_mutation_bytes

    @property
    def bytes_moved(self) -> int:
        """Cumulative logical mutation bytes since construction."""
        return self._bytes_moved

    def snapshot(self) -> LemurIndex:
        """The current immutable index snapshot.  ``add()`` swaps the whole
        ``LemurIndex`` atomically (it is a NamedTuple — existing references
        keep serving the old corpus), which is what makes add-while-serving
        safe for readers holding a snapshot."""
        return self._index

    def __repr__(self) -> str:
        return (f"LemurRetriever(m={self.m}, d_prime={self.cfg.d_prime}, "
                f"backend={self.backend!r})")

    # -- lifecycle ----------------------------------------------------------

    @classmethod
    def build(cls, corpus, cfg: LemurConfig | None = None, *, key=None,
              x_train: np.ndarray | None = None,
              verbose: bool = False) -> "LemurRetriever":
        """Full offline build: training-token selection (§4.2) -> ψ
        pre-training against m' sampled docs (§4.3) -> OLS output layer over
        the full corpus (eq. 7) -> first-stage index via the backend
        registry.  ``corpus`` is any object with doc_tokens/doc_mask arrays
        (e.g. ``data.synthetic.MultiVectorCorpus``)."""
        cfg = cfg or LemurConfig()
        if key is None:
            key = jax.random.PRNGKey(0)
        t0 = time.time()
        keys = jax.random.split(key, 4)
        doc_tokens = jnp.asarray(corpus.doc_tokens)
        doc_mask = jnp.asarray(corpus.doc_mask)
        m = doc_tokens.shape[0]

        # 1. training tokens (§4.2)
        if x_train is None:
            x_train = indexer.make_training_tokens(corpus, cfg, seed=0)
        x_train = jnp.asarray(x_train)

        # 2. ψ pre-training against m' sampled documents (§4.3)
        m_pre = min(cfg.m_pretrain, m)
        pre_idx = jax.random.choice(keys[0], m, (m_pre,), replace=False)
        g_pre = maxsim.token_maxsim(x_train, doc_tokens[pre_idx], doc_mask[pre_idx])
        phi, stats, losses = train_phi(keys[1], x_train, g_pre, cfg)
        if verbose:
            print(f"[build] psi pretrain done ({time.time()-t0:.1f}s, "
                  f"loss {losses[-1]:.4f})")

        # 3. OLS output layer over the full corpus (eq. 7); the solver state
        # (Gram factor + tokens) is retained so add() reuses it verbatim
        n_ols = min(cfg.n_ols, x_train.shape[0])
        x_ols = x_train[jax.random.choice(keys[2], x_train.shape[0], (n_ols,),
                                          replace=False)]
        solver = indexer.ols_solver_state(phi["psi"], x_ols, cfg)
        W = indexer.fit_output_layer_ols(phi["psi"], x_ols, doc_tokens,
                                         doc_mask, cfg, stats,
                                         solver_state=solver)
        if verbose:
            print(f"[build] OLS W ({m} docs) done ({time.time()-t0:.1f}s)")

        # 4. first-stage index via the backend registry
        backend = registry.canonical(cfg.anns)
        be = registry.get_backend(backend)
        ann = be.build(keys[3], CorpusView(W, doc_tokens, doc_mask),
                       cfg.backend_config(backend))
        if verbose:
            print(f"[build] {backend} index complete ({time.time()-t0:.1f}s)")

        # 5. corpus store — optionally pooled to a constant per-doc token
        # budget and/or residual-encoded (cfg.residual).  ψ/OLS/backend above
        # always train on the RAW tokens; pooling/compression only change
        # what the store keeps for the exact-MaxSim rerank.
        st_tokens, st_mask, codec = doc_tokens, doc_mask, None
        rcfg = cfg.residual
        if int(rcfg.token_budget) > 0:
            st_tokens, st_mask = pages.pool_tokens(doc_tokens, doc_mask,
                                                   int(rcfg.token_budget))
            st_tokens = jnp.asarray(st_tokens)
            st_mask = jnp.asarray(st_mask)
        if rcfg.enabled:
            from repro.anns import quantization as _q

            flat = np.asarray(st_tokens)[np.asarray(st_mask)]
            # fold_in (not a wider split) keeps keys[0..3] — and thus ψ/W —
            # bit-identical to a build without the compressed tier
            codec = _q.train_residual_codec(
                jax.random.fold_in(keys[3], 1), jnp.asarray(flat),
                bits=int(rcfg.bits), ncent=int(rcfg.ncent),
                iters=int(rcfg.kmeans_iters), sample=int(rcfg.train_sample))
            if verbose:
                print(f"[build] residual codec trained "
                      f"({time.time()-t0:.1f}s)")
        index = LemurIndex.from_dense(cfg, phi["psi"], stats, W, st_tokens,
                                      st_mask, backend, ann, codec=codec)
        return cls(index, solver_state=solver)

    def with_backend(self, backend: str, *, key=None,
                     cfg: LemurConfig | None = None) -> "LemurRetriever":
        """A new retriever over the SAME trained reduction (ψ/W/doc tokens
        shared, never re-trained) with a different first-stage backend —
        what benchmarks use to sweep backends over one build."""
        idx = self._index
        cfg = cfg or idx.cfg
        backend = registry.canonical(backend)
        be = registry.get_backend(backend)
        if key is None:
            key = jax.random.PRNGKey(0)
        view = CorpusView(idx.W, idx.doc_tokens, idx.doc_mask)
        ann = be.build(key, view, cfg.backend_config(backend))
        index = idx._replace(cfg=cfg.replace(anns=backend), backend=backend,
                             ann=ann)
        return LemurRetriever(index, solver_state=self._solver,
                              x_ols=self._x_ols)

    def add(self, doc_tokens, doc_mask, *, seed: int = 0) -> "LemurRetriever":
        """Incremental growth: fit new W rows with the frozen-ψ OLS solver,
        push them into the first-stage backend via its ``add`` hook — ψ and
        existing rows are never touched (§4.3) — and allocate token PAGES
        for the new docs (slots ``[m, m+n)``: stable ids).  Reuses the
        build-time solver state when available (also after ``load()``); the
        corpus-sampling fallback is seeded by the explicit ``seed``.

        Compiled query fns are NOT invalidated: they take the store/backend
        state as jit arguments, so an add that fits the pre-grown pool
        issues zero new traces (only a power-of-two capacity-bucket growth
        retraces).  Mutates this retriever and returns it; the new slot ids
        are in :attr:`last_added_ids`."""
        self._mutate_add(doc_tokens, doc_mask, seed)
        self._version += 1
        return self

    def delete(self, doc_ids) -> "LemurRetriever":
        """Tombstone docs and return their pages to the free list.  Ids of
        surviving docs are unchanged (slots are never reused); the
        first-stage backends are NOT rebuilt — their stale candidates are
        masked out after every first stage (``pages.mask_dead``), so a
        deleted doc can never surface.  Raises ``ValueError`` on unknown or
        already-deleted ids.  Mutates this retriever and returns it."""
        self._mutate_delete(doc_ids)
        self._version += 1
        return self

    def update(self, doc_ids, doc_tokens, doc_mask, *,
               seed: int = 0) -> np.ndarray:
        """Replace docs: delete ``doc_ids`` + add the new contents under ONE
        snapshot version bump.  The replacement docs get NEW slot ids
        (returned; also in :attr:`last_added_ids`) — an updated doc is a
        new document as far as stable external ids are concerned."""
        self._mutate_delete(doc_ids)
        ids = self._mutate_add(doc_tokens, doc_mask, seed)
        self._version += 1
        return ids

    def _free(self) -> list[int]:
        if self._free_pages is None:
            self._free_pages = pages.free_list(self._index.store)
        return self._free_pages

    def _mutate_add(self, doc_tokens, doc_mask, seed: int) -> np.ndarray:
        idx = self._index
        doc_tokens = jnp.asarray(doc_tokens)
        doc_mask = jnp.asarray(doc_mask)
        solver = self._ensure_solver(seed)
        w_new = indexer.fit_docs(solver, doc_tokens, doc_mask, idx.stats)
        be = registry.get_backend(idx.backend)
        ann = be.add(idx.ann, CorpusView(w_new, doc_tokens, doc_mask))
        # mirror build(): W/backend see raw tokens, the store keeps the
        # pooled view (add_docs residual-encodes via store.codec itself)
        budget = int(idx.cfg.residual.token_budget)
        if budget > 0:
            doc_tokens, doc_mask = pages.pool_tokens(doc_tokens, doc_mask,
                                                     budget)
        store, free, ids, moved = pages.add_docs(
            idx.store, self._free(), w_new, doc_tokens, doc_mask)
        self._free_pages = free
        self._index = idx._replace(store=store, ann=ann)
        self._last_added_ids = ids
        self._last_mutation_bytes = moved
        self._bytes_moved += moved
        return ids

    def _mutate_delete(self, doc_ids) -> None:
        idx = self._index
        store, free, moved = pages.delete_docs(idx.store, self._free(),
                                               doc_ids)
        self._free_pages = free
        self._index = idx._replace(store=store)
        self._last_mutation_bytes = moved
        self._bytes_moved += moved

    def clone(self) -> "LemurRetriever":
        """An independent replica over the SAME built state — zero re-train,
        zero re-build.  The immutable ``LemurIndex`` and the OLS solver state
        are shared (both are read-only under search; ``add()`` swaps the
        index atomically per-replica), compile caches are private, and
        ``version`` is carried over so a fleet can stamp every replica to a
        common snapshot numbering.  Because ``fit_docs`` is deterministic
        given the shared solver, fanning the same ``add()`` out to every
        clone produces bit-identical W rows — the invariant the fleet write
        barrier checks."""
        r = LemurRetriever(self._index, solver_state=self._solver,
                           x_ols=self._x_ols)
        r._version = self._version
        return r

    def install_refresh(self, refresh) -> "LemurRetriever":
        """Warm-swap a background rebuild (``lifecycle.build_refresh``) in.

        Three stages, atomic from any reader's point of view:

        1. **validate** — backend match, W shape/finiteness, solver keys,
           and a probe search through the rebuilt first stage (latent
           backends) checking candidate ids stay in ``[0, m0)``.  Any
           failure raises :class:`CorruptIndexError` BEFORE anything is
           touched: the last-good snapshot keeps serving.
        2. **catch up** — docs added since the rebuild snapshotted
           (slots ``[m0, m_now)``) get W rows fit with the NEW solver and
           are appended to the rebuilt backend in slot order (dead slots as
           zero rows, preserving the slot-numbering invariant); rows the
           rebuild covered but that were deleted meanwhile are re-zeroed.
        3. **swap** — one atomic ``LemurIndex`` replace + ONE version bump.
           Readers holding the old snapshot keep it; compiled query fns
           survive (state is a jit argument — only a shape change retraces).

        Deterministic given the same ``RefreshResult`` and mutation history,
        so fanning one result out to every fleet replica lands the same
        post-swap snapshot version with bit-identical search results — the
        invariant the fleet write barrier checks.  Mutates this retriever
        and returns it; meant to run inside a server mutation barrier
        (``RetrieverServer.apply`` / ``Router.apply``)."""
        idx = self._index

        # -- 1. validate (raise BEFORE touching anything) ------------------
        def bad(msg: str) -> CorruptIndexError:
            return CorruptIndexError(f"install_refresh rejected: {msg}")

        if getattr(refresh, "backend", None) != idx.backend:
            raise bad(f"backend {getattr(refresh, 'backend', None)!r} != "
                      f"{idx.backend!r}")
        m_now = self.m
        m0 = int(refresh.m0)
        if not 0 < m0 <= m_now:
            raise bad(f"m0={m0} outside (0, {m_now}]")
        W_new = jnp.asarray(refresh.W)
        if W_new.shape != (m0, idx.cfg.d_prime):
            raise bad(f"W shape {W_new.shape} != {(m0, idx.cfg.d_prime)}")
        if not bool(jnp.isfinite(W_new).all()):
            raise bad("non-finite values in refit W")
        solver = refresh.solver
        if not (isinstance(solver, dict)
                and {"chol", "feats", "x_ols"} <= set(solver)):
            raise bad("solver state missing chol/feats/x_ols")
        # chol is a cho_factor (factor, lower) pair — validate the factor
        if not bool(jnp.isfinite(jnp.asarray(solver["chol"][0])).all()):
            raise bad("non-finite OLS Gram factor")
        be = registry.get_backend(idx.backend)
        if be.representation == "latent":
            try:
                _, cand = be.search(
                    refresh.ann, QueryBatch(W_new[:1], None, None),
                    min(8, m0),
                    be.default_params(idx.cfg.backend_config(idx.backend)))
                cand = np.asarray(cand)
            except Exception as e:
                raise bad(f"probe search through rebuilt backend failed: "
                          f"{e}") from e
            if cand.size == 0 or (cand >= m0).any() or (cand < -1).any():
                raise bad("rebuilt backend emits out-of-range candidate ids")

        # -- 2. catch up slots [m0, m_now) with the NEW solver -------------
        alive_now = np.asarray(idx.store.alive)
        W2 = idx.store.W.at[:m0].set(
            jnp.where(jnp.asarray(alive_now[:m0])[:, None], W_new, 0.0))
        ann = refresh.ann
        caught = 0
        if m_now > m0:
            catch = jnp.arange(m0, m_now, dtype=jnp.int32)
            toks_c, mask_c = pages.gather_docs(idx.store, catch)
            alive_c = np.flatnonzero(alive_now[m0:m_now])
            w_c = jnp.zeros((m_now - m0, idx.cfg.d_prime),
                            idx.store.W.dtype)
            if alive_c.size:
                sub = jnp.asarray(alive_c.astype(np.int32))
                w_fit = indexer.fit_docs(solver, toks_c[sub], mask_c[sub],
                                         idx.stats)
                w_c = w_c.at[sub].set(w_fit)
                caught = int(alive_c.size)
            # append ALL slots in order (dead as zero rows): backend
            # numbering must equal slot numbering, mask_dead does the rest
            ann = be.add(ann, CorpusView(w_c, toks_c, mask_c))
            W2 = W2.at[m0:m_now].set(w_c)

        # -- 3. atomic swap + ONE version bump -----------------------------
        self._index = idx._replace(store=idx.store._replace(W=W2), ann=ann)
        self._solver = solver
        self._x_ols = solver["x_ols"]
        self._version += 1
        self._last_refresh_caught_up = caught
        return self

    def shard(self, mesh, *, sq8: bool | None = None,
              k_prime_local: int | None = None):
        """Multi-device serving: a :class:`~repro.retriever.sharded.
        ShardedLemurRetriever` over this built retriever, with the corpus
        block-sharded across every axis of ``mesh`` (Fig. 1 at pod scale —
        each shard runs latent scan → local top-k' → local exact rerank,
        only (k, score) pairs cross the wire).

        ``sq8`` selects the SQ8 code path for the resident corpus (default:
        the build config's ``cfg.ivf.sq8``); ``k_prime_local`` overrides the
        per-shard candidate budget (default: a 4x oversample of k'/n_shards,
        see ``repro.dist.serve.default_k_prime_local``)."""
        from repro.retriever.sharded import ShardedLemurRetriever

        return ShardedLemurRetriever(self, mesh, sq8=sq8,
                                     k_prime_local=k_prime_local)

    def _ensure_solver(self, seed: int) -> dict:
        if self._solver is not None:
            return self._solver
        idx = self._index
        if self._x_ols is not None:
            # persisted/handed-down OLS tokens: rebuild the Gram factor
            # deterministically (bit-exact W scales across save/load)
            self._solver = indexer.ols_solver_state(idx.psi, self._x_ols, idx.cfg)
            return self._solver
        # legacy fallback: resample OLS tokens from the stored corpus
        # ("corpus" strategy) — seeded explicitly, not a hidden rng(0)
        flat = np.asarray(idx.doc_tokens)[np.asarray(idx.doc_mask)]
        pick = np.random.default_rng(seed).integers(
            0, flat.shape[0], size=min(idx.cfg.n_ols, flat.shape[0]))
        self._solver = indexer.ols_solver_state(
            idx.psi, jnp.asarray(flat[pick]), idx.cfg)
        return self._solver

    # -- query --------------------------------------------------------------

    def resolve(self, params: SearchParams | None = None) -> SearchParams:
        """Fill a (possibly partial) SearchParams from the build config.
        Memoized — cfg and backend are fixed for this retriever's lifetime,
        so repeated serving calls skip the per-call resolution work."""
        resolved = self._resolve_memo.get(params)
        if resolved is None:
            resolved = (params or SearchParams()).resolve(self.cfg, self.backend)
            self._resolve_memo[params] = resolved
        return resolved

    def search(self, q_tokens, q_mask=None, params: SearchParams | None = None):
        """q_tokens: (B, Tq, d) -> (scores (B, k), doc_ids (B, k)).

        Runs the compiled pool -> candidates -> exact-rerank pipeline for
        the resolved params (one XLA graph; compiled once per params and
        batch shape)."""
        q_tokens = jnp.asarray(q_tokens)
        if q_mask is None:
            q_mask = jnp.ones(q_tokens.shape[:2], bool)
        return self._compiled_fn(self.resolve(params))(q_tokens, q_mask)

    def candidates(self, q_tokens, q_mask=None,
                   params: SearchParams | None = None):
        """First-stage candidate ids only (recall@k' ablations, Fig. 2)."""
        q_tokens = jnp.asarray(q_tokens)
        if q_mask is None:
            q_mask = jnp.ones(q_tokens.shape[:2], bool)
        return first_stage(self._index, q_tokens, q_mask, self.resolve(params))

    def _compiled_fn(self, resolved: SearchParams):
        key = (self.backend, resolved)
        run = self._compiled.get(key)
        if run is None:
            counts = self._trace_counts
            shapes = self._trace_shapes
            cfg, backend = self.cfg, self.backend

            def pipeline(psi, stats, store, ann, q, qm):
                # trace-time only: bucket-aware compile accounting — each
                # (backend, params, q-shape) cache entry is observable, so
                # serving layers can assert their shape-ladder compile bound
                counts[key] = counts.get(key, 0) + 1
                skey = key + (tuple(q.shape),)
                shapes[skey] = shapes.get(skey, 0) + 1
                idx = LemurIndex(cfg, psi, stats, store, backend, ann)
                return search_pipeline(idx, q, qm, resolved)

            jitted = jax.jit(pipeline)
            use_ann = bool(resolved.use_ann)

            # the WHOLE mutable state rides in as jit arguments — mutations
            # that keep shapes (pool has capacity) hit the compiled program
            # with zero retraces; only a pow2 bucket growth traces again.
            # Exact-scan params drop the (unused) backend state from the
            # arguments so a backend whose state grows per add (e.g.
            # bruteforce's concatenated W view) cannot retrace them.
            def run(q, qm):
                i = self._index
                return jitted(i.psi, i.stats, i.store,
                              i.ann if use_ann else None, q, qm)

            self._compiled[key] = run
        return run

    def trace_count(self, params: SearchParams | None = None) -> int:
        """jit traces so far: for one resolved SearchParams, or in total.
        The API contract is one trace per (backend, params, batch-shape)."""
        if params is None:
            return sum(self._trace_counts.values())
        return self._trace_counts.get((self.backend, self.resolve(params)), 0)

    def launches(self, params: SearchParams | None = None) -> dict[str, int]:
        """Per-search launch breakdown for ``params`` (resolved first) —
        see :func:`launch_plan`.  Pairs with :meth:`trace_count`: traces say
        how many XLA programs exist, this says how many corpus-scale kernel
        launches each search issues."""
        return launch_plan(self.resolve(params))

    def trace_shapes(self) -> dict[tuple, int]:
        """Per-shape compile accounting: ``{(batch, Tq[, d]): n_traces}``
        aggregated over params.  The online server's shape-bucket ladder
        bounds ``len(trace_shapes())`` per resolved params no matter how
        request shapes churn — asserted in tests/test_serving_runtime.py."""
        out: dict[tuple, int] = {}
        for (*_, shape), n in self._trace_shapes.items():
            out[shape] = out.get(shape, 0) + n
        return out

    # -- persistence --------------------------------------------------------

    def save(self, directory) -> pathlib.Path:
        """Persist everything needed to serve (and grow) this retriever:
        cfg, ψ, target stats, the PAGED store (token pages, page table,
        token counts, W, alive tombstones, doc count), the backend name +
        its opaque packed state, and the OLS training tokens when available.
        The ``alive`` mask is load-bearing: tombstoned slots keep zeroed W
        rows, and without it they would resurface as zero-score docs after
        a reload.  The page free list is NOT persisted — it is derived
        deterministically from the page table on first mutation.
        Uses the checkpoint manager's atomic manifest+shards layout."""
        idx = self._index
        st = idx.store
        be = registry.get_backend(idx.backend)
        ann_arrays, ann_meta = be.pack_state(idx.ann)
        tree = {
            "psi": idx.psi,
            "stats": {"mean": idx.stats.mean, "std": idx.stats.std},
            "pages": {
                "tok_pages": st.tok_pages,
                "page_table": st.page_table,
                "n_tokens": st.n_tokens,
                "W": st.W,
                "alive": st.alive,
                "n_docs": st.n_docs,
            },
            "ann": dict(ann_arrays),
        }
        if st.codec is not None:
            # compressed tier: id/code pools + the trained codec tables
            # (cuts included so add() keeps encoding after a reload)
            tree["pages"]["cent_pages"] = st.cent_pages
            tree["pages"]["code_pages"] = st.code_pages
            tree["codec"] = {"centroids": st.codec.centroids,
                             "cuts": st.codec.cuts,
                             "values": st.codec.values}
        if self._x_ols is not None:
            tree["solver"] = {"x_ols": self._x_ols}
        extra = {"format": FORMAT, "cfg": idx.cfg.to_dict(),
                 "backend": idx.backend, "ann_meta": ann_meta}
        return ckpt.save(directory, 0, tree, extra=extra)

    @classmethod
    def load(cls, directory, *, step: int | None = None) -> "LemurRetriever":
        """Inverse of :meth:`save`; search ids reproduce bit-identically."""
        directory = pathlib.Path(directory)
        if step is None:
            step = ckpt.latest_step(directory)
            if step is None:
                raise FileNotFoundError(
                    f"no committed retriever checkpoint under {directory}")
        manifest = json.loads(
            (directory / f"step_{step:08d}" / "manifest.json").read_text())
        extra = manifest.get("extra", {})
        if extra.get("format") != FORMAT:
            raise ValueError(
                f"{directory} is not a {FORMAT} checkpoint "
                f"(format={extra.get('format')!r})")
        target = _tree_from_manifest(manifest["leaves"])
        tree, _ = ckpt.restore(directory, target, step=step)
        cfg = LemurConfig.from_dict(extra["cfg"])
        backend = extra["backend"]
        be = registry.get_backend(backend)
        ann = be.unpack_state(tree["ann"], extra.get("ann_meta", {}))
        stats = TargetStats(tree["stats"]["mean"], tree["stats"]["std"])
        if "pages" in tree:
            p = tree["pages"]
            codec = None
            if "codec" in tree:
                from repro.anns.quantization import ResidualCodec

                c = tree["codec"]
                codec = ResidualCodec(centroids=c["centroids"],
                                      cuts=c["cuts"], values=c["values"])
            store = pages.PagedStore(
                p["tok_pages"], p["page_table"], p["n_tokens"], p["W"],
                jnp.asarray(p["alive"], bool),
                jnp.asarray(p["n_docs"], jnp.int32),
                cent_pages=p.get("cent_pages"),
                code_pages=p.get("code_pages"), codec=codec)
            index = LemurIndex(cfg, tree["psi"], stats, store, backend, ann)
        else:
            # legacy dense checkpoint (pre-paged format): migrate on load
            index = LemurIndex.from_dense(cfg, tree["psi"], stats, tree["W"],
                                          tree["doc_tokens"],
                                          tree["doc_mask"], backend, ann)
        x_ols = tree.get("solver", {}).get("x_ols")
        return cls(index, x_ols=x_ols)


def _tree_from_manifest(leaves: dict[str, dict]) -> dict:
    """Rebuild the (pure nested-dict) save tree's structure from manifest
    leaf names, with ShapeDtypeStruct leaves (no allocation) for restore."""
    root: dict = {}
    for name, spec in leaves.items():
        parts = name.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jax.ShapeDtypeStruct(
            tuple(spec["shape"]), _np_dtype(spec["dtype"]))
    return root


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:  # ml_dtypes names (bfloat16 et al.)
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))
