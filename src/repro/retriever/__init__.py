"""Retriever API v1 — the stable serving surface of the reproduction.

:class:`LemurRetriever` owns the index lifecycle (build / search / add /
with_backend / save / load); :class:`SearchParams` is the typed, hashable,
jit-static query-time knob object.  Per-backend build knobs live in the
``LemurConfig`` namespaces (``cfg.ivf``, ``cfg.muvera``, …) defined in
:mod:`repro.anns.params` and registered next to each backend in
:mod:`repro.anns.registry`.
"""
from repro.anns.params import (
    BruteforceBackendConfig,
    DessertBackendConfig,
    IVFBackendConfig,
    IVFSearchParams,
    MuveraBackendConfig,
    NoSearchParams,
    TokenPruningBackendConfig,
    TokenPruningSearchParams,
)
from repro.retriever.facade import CorruptIndexError, LemurRetriever
from repro.retriever.params import SearchParams
from repro.retriever.sharded import ShardedLemurRetriever

__all__ = [
    "CorruptIndexError",
    "LemurRetriever",
    "ShardedLemurRetriever",
    "SearchParams",
    "IVFSearchParams",
    "NoSearchParams",
    "TokenPruningSearchParams",
    "BruteforceBackendConfig",
    "IVFBackendConfig",
    "MuveraBackendConfig",
    "DessertBackendConfig",
    "TokenPruningBackendConfig",
]
