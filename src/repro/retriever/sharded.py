"""ShardedLemurRetriever: the facade's multi-device serving surface.

Obtained via :meth:`repro.retriever.LemurRetriever.shard`::

    r = LemurRetriever.build(corpus, cfg)
    sr = r.shard(mesh)                       # corpus block-sharded over mesh
    scores, ids = sr.search(q, qm, SearchParams(k=10))
    sr.add(new_tokens, new_mask)             # shard-balanced growth
    sr.delete(sr.base.last_added_ids)        # in-place slot eviction
    sr.update([3, 7], new_tokens[:2], new_mask[:2])
    sr.save("idx/"); sr = ShardedLemurRetriever.load("idx/", mesh)

It mirrors the single-device facade's surface (``search`` / ``add`` /
``save`` / ``load`` / ``trace_count``) on top of the Fig.-1-at-pod-scale
serve step in :mod:`repro.dist.serve`: the latent corpus W and the doc
token store are block-sharded over the *flattened* mesh, each shard runs
latent scan → local top-k' → local exact rerank, and only (k, score) pairs
cross the wire in the hierarchical merge.

Design points:

* **State build.**  ``ShardedRetrievalState`` is materialized from any
  built retriever as a SLOT POOL: every shard owns a power-of-two bucket of
  ``rows_per_shard`` physical rows, ``row_ids``/``row_valid`` map rows to
  the base facade's stable external slot ids (free rows are ``-1`` and
  masked out of the latent scan), and rows are either kept fp
  (bit-identical to the local facade's exact-scan search when k' covers
  the corpus) or scalar-quantized to SQ8 codes + per-row/per-token scales
  (``sq8=True``; 2-4x less resident HBM per shard, scores exact w.r.t. the
  quantized representation — per-row quantization means in-place row
  writes requantize ONLY the touched rows, exactly).  The default follows
  the build config's ``cfg.ivf.sq8`` knob.

* **Compilation contract.**  Like the single-device facade: exactly one
  compiled serve step per (mesh, resolved ``SearchParams``, batch shape),
  observable via :meth:`trace_count`.  The sharded state rides into the
  compiled step as a jit ARGUMENT, so in-capacity mutations (add into free
  rows, delete, update) keep every leaf shape and issue ZERO new traces —
  only a bucket-growing rebuild re-specializes.  The first-stage backend
  and ``use_ann`` are ignored here — the sharded first stage IS the
  per-shard exact latent scan (the paper's k' budget becomes the per-shard
  ``k_prime_local`` oversample, see ``dist.serve.default_k_prime_local``).

* **Shard-balanced mutation.**  ``add()`` fits new W rows with the base
  retriever's frozen-ψ OLS solver, then writes them into free rows of the
  LEAST-occupied shards (in-place ``.at[rows].set`` — no resharding, no
  O(corpus) copy while the pool has capacity).  ``delete()`` evicts rows
  in place (scan-masked + token-masked, so a deleted doc can never
  surface) and returns them to the per-shard free lists; ``update()`` is
  delete+add under the base facade's single version bump.  External ids
  keep the base facade's stable numbering throughout.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import dist
from repro.anns.quantization import sq8_quant
from repro.core import maxsim, pages
from repro.core.config import LemurConfig
from repro.retriever.facade import LemurRetriever
from repro.retriever.params import SearchParams


class ShardedLemurRetriever:
    """Multi-device serving facade over a built :class:`LemurRetriever`
    (see module docstring).  Construct via ``LemurRetriever.shard(mesh)``."""

    def __init__(self, base: LemurRetriever, mesh, *, sq8: bool | None = None,
                 k_prime_local: int | None = None):
        self._base = base
        self._mesh = mesh
        self._sq8 = bool(base.cfg.ivf.sq8) if sq8 is None else bool(sq8)
        self._k_prime_local = k_prime_local
        self._compiled: dict[tuple, Any] = {}
        self._trace_counts: dict[tuple, int] = {}
        self._trace_shapes: dict[tuple, int] = {}
        self._state: dist.ShardedRetrievalState | None = None
        # slot-pool allocator mirrors (host side): external id -> physical
        # row, and per-shard LIFO free-row lists for balanced placement
        self._row_of: dict[int, int] = {}
        self._free_rows: list[list[int]] = []
        self._rows_per_shard = 0
        self._rebuild_state()

    # -- introspection ------------------------------------------------------

    @property
    def base(self) -> LemurRetriever:
        return self._base

    @property
    def mesh(self):
        return self._mesh

    @property
    def cfg(self) -> LemurConfig:
        return self._base.cfg

    @property
    def m(self) -> int:
        """Slot high-water mark of the base facade (stable external ids)."""
        return self._base.m

    @property
    def n_alive(self) -> int:
        return self._base.n_alive

    @property
    def rows_per_shard(self) -> int:
        """Physical slot-pool rows each shard owns (pow2 bucket)."""
        return self._rows_per_shard

    @property
    def last_added_ids(self) -> np.ndarray:
        """External ids allocated by the most recent add/update (base's)."""
        return self._base.last_added_ids

    @property
    def sq8(self) -> bool:
        return self._sq8

    @property
    def version(self) -> int:
        """Snapshot version of the underlying facade (bumped per
        add/delete/update; update bumps ONCE)."""
        return self._base.version

    @property
    def state(self) -> dist.ShardedRetrievalState:
        return self._state

    def __repr__(self) -> str:
        shape = "x".join(str(self._mesh.shape[a]) for a in self._mesh.axis_names)
        return (f"ShardedLemurRetriever(m={self.m}, mesh={shape}, "
                f"sq8={self._sq8})")

    # -- state build --------------------------------------------------------

    def _rebuild_state(self) -> None:
        """Materialize the sharded slot pool from the base index: every shard
        owns a pow2 bucket of ``rows_per_shard`` rows (block-balanced
        placement; slot i lands on row i, so a fresh pool reproduces the
        legacy block layout), dead/unused rows are free (``row_ids=-1``,
        scan-masked), then quantize (SQ8) or keep fp and place per
        ``dist.state_shardings``.  Only runs at construction and when a
        mutation outgrows the pool (rows or token width)."""
        idx = self._base.index
        st = idx.store
        n = dist.n_corpus_shards(self._mesh)
        m = idx.m
        rps = max(1, pages.next_pow2(-(-m // n) if m else 1))
        total = n * rps
        docs, mask = pages.gather_docs(st, jnp.arange(m, dtype=jnp.int32))
        W = jnp.asarray(st.W[:m], jnp.float32)
        alive = np.asarray(st.alive[:m])
        pad = total - m
        if pad:
            W = jnp.pad(W, ((0, pad), (0, 0)))
            docs = jnp.pad(docs, ((0, pad), (0, 0), (0, 0)))
            mask = jnp.pad(mask, ((0, pad), (0, 0)))
        row_ids = np.full(total, -1, np.int32)
        row_ids[:m][alive] = np.arange(m, dtype=np.int32)[alive]
        row_valid = row_ids >= 0
        self._rows_per_shard = rps
        self._row_of = {int(i): int(i) for i in np.flatnonzero(alive)}
        free = np.flatnonzero(~row_valid)
        self._free_rows = [
            sorted(free[(free >= s * rps) & (free < (s + 1) * rps)].tolist(),
                   reverse=True)
            for s in range(n)]
        extra = {"row_ids": jnp.asarray(row_ids),
                 "row_valid": jnp.asarray(row_valid)}
        if self._sq8:
            W, w_scales = sq8_quant(W)
            docs, doc_scales = sq8_quant(docs)
            state = dist.ShardedRetrievalState(
                psi=idx.psi, W=W, doc_tokens=docs, doc_mask=mask,
                W_scales=w_scales, doc_scales=doc_scales, **extra)
        else:
            state = dist.ShardedRetrievalState(
                psi=idx.psi, W=W, doc_tokens=docs, doc_mask=mask, **extra)
        self._state = jax.device_put(
            state, dist.state_shardings(self._mesh, state))

    # -- query --------------------------------------------------------------

    def resolve(self, params: SearchParams | None = None) -> SearchParams:
        """Resolution is delegated to the base facade (same cfg defaults)."""
        return self._base.resolve(params)

    def search(self, q_tokens, q_mask=None, params: SearchParams | None = None):
        """q_tokens: (B, Tq, d) -> (scores (B, k), doc_ids (B, k)).

        One compiled serve step per (mesh, resolved params, batch shape);
        padded corpus rows are filtered to ``(NEG, -1)`` — the same pad
        convention as the single-device pipeline."""
        q_tokens = jnp.asarray(q_tokens)
        if q_mask is None:
            q_mask = jnp.ones(q_tokens.shape[:2], bool)
        resolved = self.resolve(params)
        return self._compiled_fn(resolved)(self._state, q_tokens, q_mask)

    def _compiled_fn(self, resolved: SearchParams):
        key = (resolved.k, resolved.k_prime, resolved.use_fused_gather,
               resolved.use_one_launch, resolved.use_residual)
        fn = self._compiled.get(key)
        if fn is None:
            serve = dist.make_serve_step(
                self._mesh,
                self.cfg.replace(k=resolved.k, k_prime=resolved.k_prime),
                k_prime_local=self._k_prime_local,
                use_fused_gather=resolved.use_fused_gather,
                use_one_launch=resolved.use_one_launch,
                use_residual=resolved.use_residual)
            counts = self._trace_counts
            shapes = self._trace_shapes

            def run(state, q, qm):
                counts[key] = counts.get(key, 0) + 1  # trace-time only
                skey = key + (tuple(q.shape),)
                shapes[skey] = shapes.get(skey, 0) + 1
                scores, ids = serve(state, q, qm)
                # free/tombstoned rows arrive id -1 (the row_ids map), score
                # NEG-ish — pin their scores so they sort last deterministically
                valid = ids >= 0
                scores = jnp.where(valid, scores, maxsim.NEG)
                if scores.shape[1] < resolved.k:
                    # k exceeds the (padded) corpus: keep the facade's (B, k)
                    # pad-to-k contract instead of the merge's narrower width
                    extra = resolved.k - scores.shape[1]
                    scores = jnp.pad(scores, ((0, 0), (0, extra)),
                                     constant_values=maxsim.NEG)
                    ids = jnp.pad(ids, ((0, 0), (0, extra)),
                                  constant_values=-1)
                return scores, ids

            fn = self._compiled[key] = jax.jit(run)
        return fn

    def trace_count(self, params: SearchParams | None = None) -> int:
        """jit traces so far: for one resolved SearchParams, or in total.
        The contract is one trace per (mesh, params, batch shape)."""
        if params is None:
            return sum(self._trace_counts.values())
        resolved = self.resolve(params)
        return self._trace_counts.get(
            (resolved.k, resolved.k_prime, resolved.use_fused_gather,
             resolved.use_one_launch, resolved.use_residual), 0)

    def trace_shapes(self) -> dict[tuple, int]:
        """Per-shape compile accounting (same contract as the single-device
        facade): ``{q.shape: n_traces}`` aggregated over params."""
        out: dict[tuple, int] = {}
        for (*_, shape), n in self._trace_shapes.items():
            out[shape] = out.get(shape, 0) + n
        return out

    def clone(self) -> "ShardedLemurRetriever":
        """An independent replica over a clone of the base facade (shared
        immutable index + OLS solver, private compile caches and sharded
        state) on the SAME mesh — the fleet router's replica factory for
        multi-device serving."""
        return ShardedLemurRetriever(self._base.clone(), self._mesh,
                                     sq8=self._sq8,
                                     k_prime_local=self._k_prime_local)

    # -- mutation -----------------------------------------------------------

    def add(self, doc_tokens, doc_mask, *, seed: int = 0) -> "ShardedLemurRetriever":
        """Incremental growth (§4.3) with shard-balanced placement: new W
        rows come from the base facade's frozen-ψ OLS solver, then the new
        docs are written IN PLACE into free rows of the least-occupied
        shards.  While the pool has rows (and the token width fits), no
        leaf changes shape — compiled serve steps survive with zero new
        traces; an outgrown pool triggers one bucket-doubling rebuild."""
        self._base.add(doc_tokens, doc_mask, seed=seed)
        self._place(self._base.last_added_ids)
        return self

    def delete(self, doc_ids) -> "ShardedLemurRetriever":
        """Tombstone docs: evict their rows in place (scan mask off, tokens
        masked — a deleted doc can never surface) and return the rows to
        the per-shard free lists.  Surviving ids are unchanged."""
        self._base.delete(doc_ids)
        self._evict(doc_ids)
        return self

    def update(self, doc_ids, doc_tokens, doc_mask, *,
               seed: int = 0) -> np.ndarray:
        """Replace docs under ONE version bump (the base facade's
        delete+add); returns the NEW external ids."""
        ids = self._base.update(doc_ids, doc_tokens, doc_mask, seed=seed)
        self._evict(doc_ids)
        self._place(ids)
        return ids

    def install_refresh(self, refresh) -> "ShardedLemurRetriever":
        """Warm-swap a background rebuild: delegate validation + catch-up +
        atomic swap to the base facade (raises ``CorruptIndexError`` with
        this sharded state untouched), then rebuild the sharded slot pool
        from the new index — the refit W rows must reach the devices, so the
        one-bucket re-place is unavoidable and billed to the swap, never to
        serving."""
        self._base.install_refresh(refresh)
        self._rebuild_state()
        return self

    def _evict(self, doc_ids) -> None:
        rows = np.asarray([self._row_of.pop(int(i))
                           for i in np.asarray(doc_ids).reshape(-1)],
                          np.int64)
        st = self._state
        state = st._replace(
            W=st.W.at[rows].set(jnp.zeros((), st.W.dtype)),
            doc_mask=st.doc_mask.at[rows].set(False),
            row_ids=st.row_ids.at[rows].set(-1),
            row_valid=st.row_valid.at[rows].set(False),
        )
        self._state = jax.device_put(
            state, dist.state_shardings(self._mesh, state))
        for r in rows.tolist():
            self._free_rows[r // self._rows_per_shard].append(r)

    def _place(self, new_ids) -> None:
        ids = np.asarray(new_ids, np.int32).reshape(-1)
        if not ids.size:
            return
        st = self._state
        store = self._base.index.store
        if (store.td_max > st.doc_tokens.shape[1]
                or ids.size > sum(len(f) for f in self._free_rows)):
            self._rebuild_state()
            return
        rows = []
        for _ in ids:
            s = max(range(len(self._free_rows)),
                    key=lambda i: len(self._free_rows[i]))
            rows.append(self._free_rows[s].pop())
        rows_np = np.asarray(rows, np.int64)
        jids = jnp.asarray(ids)
        toks, tmask = pages.gather_docs(store, jids)
        w = jnp.take(store.W, jids, axis=0).astype(jnp.float32)
        wide = st.doc_tokens.shape[1] - toks.shape[1]
        if wide:
            toks = jnp.pad(toks, ((0, 0), (0, wide), (0, 0)))
            tmask = jnp.pad(tmask, ((0, 0), (0, wide)))
        upd = {"doc_mask": st.doc_mask.at[rows_np].set(tmask),
               "row_ids": st.row_ids.at[rows_np].set(jids),
               "row_valid": st.row_valid.at[rows_np].set(True)}
        if self._sq8:
            # per-row/per-token quantization: requantizing ONLY the new rows
            # is exactly what quantizing the whole array would produce
            w, ws = sq8_quant(w)
            toks, ts = sq8_quant(toks)
            upd.update(W_scales=st.W_scales.at[rows_np].set(ws),
                       doc_scales=st.doc_scales.at[rows_np].set(ts))
        state = st._replace(
            W=st.W.at[rows_np].set(w.astype(st.W.dtype)),
            doc_tokens=st.doc_tokens.at[rows_np].set(
                toks.astype(st.doc_tokens.dtype)),
            **upd)
        self._state = jax.device_put(
            state, dist.state_shardings(self._mesh, state))
        for i, r in zip(ids.tolist(), rows):
            self._row_of[int(i)] = r

    # -- persistence --------------------------------------------------------

    def save(self, directory):
        """Persist the UNDERLYING retriever (mesh/device placement is a
        runtime concern, not an index property): any saved index reloads
        onto any mesh via :meth:`load`."""
        return self._base.save(directory)

    @classmethod
    def load(cls, directory, mesh, *, step: int | None = None,
             sq8: bool | None = None,
             k_prime_local: int | None = None) -> "ShardedLemurRetriever":
        """``LemurRetriever.load(...)`` then shard onto ``mesh``."""
        base = LemurRetriever.load(directory, step=step)
        return cls(base, mesh, sq8=sq8, k_prime_local=k_prime_local)
