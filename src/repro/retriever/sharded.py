"""ShardedLemurRetriever: the facade's multi-device serving surface.

Obtained via :meth:`repro.retriever.LemurRetriever.shard`::

    r = LemurRetriever.build(corpus, cfg)
    sr = r.shard(mesh)                       # corpus block-sharded over mesh
    scores, ids = sr.search(q, qm, SearchParams(k=10))
    sr.add(new_tokens, new_mask)             # shard-balanced growth
    sr.save("idx/"); sr = ShardedLemurRetriever.load("idx/", mesh)

It mirrors the single-device facade's surface (``search`` / ``add`` /
``save`` / ``load`` / ``trace_count``) on top of the Fig.-1-at-pod-scale
serve step in :mod:`repro.dist.serve`: the latent corpus W and the doc
token store are block-sharded over the *flattened* mesh, each shard runs
latent scan → local top-k' → local exact rerank, and only (k, score) pairs
cross the wire in the hierarchical merge.

Design points:

* **State build.**  ``ShardedRetrievalState`` is materialized from any
  built retriever: the corpus is padded up to a device-count multiple
  (padded rows are masked out of the latent scan by ``m_real`` and can
  never surface), then either kept fp (bit-identical to the local facade's
  exact-scan search when k' covers the corpus) or scalar-quantized to SQ8
  codes + per-row/per-token scales (``sq8=True``; 2-4x less resident HBM
  per shard, scores exact w.r.t. the quantized representation).  The
  default follows the build config's ``cfg.ivf.sq8`` knob.

* **Compilation contract.**  Like the single-device facade: exactly one
  compiled serve step per (mesh, resolved ``SearchParams``, batch shape),
  observable via :meth:`trace_count`.  The first-stage backend and
  ``use_ann`` are ignored here — the sharded first stage IS the per-shard
  exact latent scan (the paper's k' budget becomes the per-shard
  ``k_prime_local`` oversample, see ``dist.serve.default_k_prime_local``).

* **Shard-balanced growth.**  ``add()`` fits new W rows with the base
  retriever's frozen-ψ OLS solver, then re-pads and re-distributes the
  grown corpus so every shard again owns exactly ``ceil(m/n)`` rows — ids
  keep the original numbering, so results stay comparable across growth.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import dist
from repro.anns.quantization import sq8_quant
from repro.core import maxsim
from repro.core.config import LemurConfig
from repro.retriever.facade import LemurRetriever
from repro.retriever.params import SearchParams


class ShardedLemurRetriever:
    """Multi-device serving facade over a built :class:`LemurRetriever`
    (see module docstring).  Construct via ``LemurRetriever.shard(mesh)``."""

    def __init__(self, base: LemurRetriever, mesh, *, sq8: bool | None = None,
                 k_prime_local: int | None = None):
        self._base = base
        self._mesh = mesh
        self._sq8 = bool(base.cfg.ivf.sq8) if sq8 is None else bool(sq8)
        self._k_prime_local = k_prime_local
        self._compiled: dict[tuple, Any] = {}
        self._trace_counts: dict[tuple, int] = {}
        self._trace_shapes: dict[tuple, int] = {}
        self._state: dist.ShardedRetrievalState | None = None
        self._m_real = 0
        self._rebuild_state()

    # -- introspection ------------------------------------------------------

    @property
    def base(self) -> LemurRetriever:
        return self._base

    @property
    def mesh(self):
        return self._mesh

    @property
    def cfg(self) -> LemurConfig:
        return self._base.cfg

    @property
    def m(self) -> int:
        return self._m_real

    @property
    def sq8(self) -> bool:
        return self._sq8

    @property
    def version(self) -> int:
        """Snapshot version of the underlying facade (bumped per add)."""
        return self._base.version

    @property
    def state(self) -> dist.ShardedRetrievalState:
        return self._state

    def __repr__(self) -> str:
        shape = "x".join(str(self._mesh.shape[a]) for a in self._mesh.axis_names)
        return (f"ShardedLemurRetriever(m={self.m}, mesh={shape}, "
                f"sq8={self._sq8})")

    # -- state build --------------------------------------------------------

    def _rebuild_state(self) -> None:
        """Materialize the sharded serving state from the base index: pad the
        corpus to a device-count multiple (block-balanced placement), then
        quantize (SQ8) or keep fp, and place per ``dist.state_shardings``."""
        idx = self._base.index
        n = dist.n_corpus_shards(self._mesh)
        self._m_real = idx.m
        pad = (-idx.m) % n
        W = jnp.asarray(idx.W, jnp.float32)
        docs = jnp.asarray(idx.doc_tokens)
        mask = jnp.asarray(idx.doc_mask)
        if pad:
            W = jnp.pad(W, ((0, pad), (0, 0)))
            docs = jnp.pad(docs, ((0, pad), (0, 0), (0, 0)))
            mask = jnp.pad(mask, ((0, pad), (0, 0)))
        if self._sq8:
            W, w_scales = sq8_quant(W)
            docs, doc_scales = sq8_quant(docs)
            state = dist.ShardedRetrievalState(
                psi=idx.psi, W=W, doc_tokens=docs, doc_mask=mask,
                W_scales=w_scales, doc_scales=doc_scales)
        else:
            state = dist.ShardedRetrievalState(
                psi=idx.psi, W=W, doc_tokens=docs, doc_mask=mask)
        self._state = jax.device_put(
            state, dist.state_shardings(self._mesh, state))

    # -- query --------------------------------------------------------------

    def resolve(self, params: SearchParams | None = None) -> SearchParams:
        """Resolution is delegated to the base facade (same cfg defaults)."""
        return self._base.resolve(params)

    def search(self, q_tokens, q_mask=None, params: SearchParams | None = None):
        """q_tokens: (B, Tq, d) -> (scores (B, k), doc_ids (B, k)).

        One compiled serve step per (mesh, resolved params, batch shape);
        padded corpus rows are filtered to ``(NEG, -1)`` — the same pad
        convention as the single-device pipeline."""
        q_tokens = jnp.asarray(q_tokens)
        if q_mask is None:
            q_mask = jnp.ones(q_tokens.shape[:2], bool)
        resolved = self.resolve(params)
        return self._compiled_fn(resolved)(self._state, q_tokens, q_mask)

    def _compiled_fn(self, resolved: SearchParams):
        key = (resolved.k, resolved.k_prime, resolved.use_fused_gather,
               resolved.use_one_launch)
        fn = self._compiled.get(key)
        if fn is None:
            serve = dist.make_serve_step(
                self._mesh,
                self.cfg.replace(k=resolved.k, k_prime=resolved.k_prime),
                k_prime_local=self._k_prime_local,
                m_real=self._m_real,
                use_fused_gather=resolved.use_fused_gather,
                use_one_launch=resolved.use_one_launch)
            m_real = self._m_real
            counts = self._trace_counts
            shapes = self._trace_shapes

            def run(state, q, qm):
                counts[key] = counts.get(key, 0) + 1  # trace-time only
                skey = key + (tuple(q.shape),)
                shapes[skey] = shapes.get(skey, 0) + 1
                scores, ids = serve(state, q, qm)
                valid = ids < m_real  # pads arrive id >= m_real, score NEG-ish
                scores = jnp.where(valid, scores, maxsim.NEG)
                ids = jnp.where(valid, ids, -1)
                if scores.shape[1] < resolved.k:
                    # k exceeds the (padded) corpus: keep the facade's (B, k)
                    # pad-to-k contract instead of the merge's narrower width
                    extra = resolved.k - scores.shape[1]
                    scores = jnp.pad(scores, ((0, 0), (0, extra)),
                                     constant_values=maxsim.NEG)
                    ids = jnp.pad(ids, ((0, 0), (0, extra)),
                                  constant_values=-1)
                return scores, ids

            fn = self._compiled[key] = jax.jit(run)
        return fn

    def trace_count(self, params: SearchParams | None = None) -> int:
        """jit traces so far: for one resolved SearchParams, or in total.
        The contract is one trace per (mesh, params, batch shape)."""
        if params is None:
            return sum(self._trace_counts.values())
        resolved = self.resolve(params)
        return self._trace_counts.get(
            (resolved.k, resolved.k_prime, resolved.use_fused_gather,
             resolved.use_one_launch), 0)

    def trace_shapes(self) -> dict[tuple, int]:
        """Per-shape compile accounting (same contract as the single-device
        facade): ``{q.shape: n_traces}`` aggregated over params."""
        out: dict[tuple, int] = {}
        for (*_, shape), n in self._trace_shapes.items():
            out[shape] = out.get(shape, 0) + n
        return out

    def clone(self) -> "ShardedLemurRetriever":
        """An independent replica over a clone of the base facade (shared
        immutable index + OLS solver, private compile caches and sharded
        state) on the SAME mesh — the fleet router's replica factory for
        multi-device serving."""
        return ShardedLemurRetriever(self._base.clone(), self._mesh,
                                     sq8=self._sq8,
                                     k_prime_local=self._k_prime_local)

    # -- growth -------------------------------------------------------------

    def add(self, doc_tokens, doc_mask, *, seed: int = 0) -> "ShardedLemurRetriever":
        """Incremental growth (§4.3) with shard-balanced placement: new W
        rows come from the base facade's frozen-ψ OLS solver, then the grown
        corpus is re-padded and re-block-sharded so every device again owns
        ``ceil(m/n)`` rows.  Compiled serve steps are invalidated (the
        corpus shape and the ``m_real`` pad mask changed)."""
        self._base.add(doc_tokens, doc_mask, seed=seed)
        self._rebuild_state()
        self._compiled.clear()
        self._trace_counts.clear()
        self._trace_shapes.clear()
        return self

    # -- persistence --------------------------------------------------------

    def save(self, directory):
        """Persist the UNDERLYING retriever (mesh/device placement is a
        runtime concern, not an index property): any saved index reloads
        onto any mesh via :meth:`load`."""
        return self._base.save(directory)

    @classmethod
    def load(cls, directory, mesh, *, step: int | None = None,
             sq8: bool | None = None,
             k_prime_local: int | None = None) -> "ShardedLemurRetriever":
        """``LemurRetriever.load(...)`` then shard onto ``mesh``."""
        base = LemurRetriever.load(directory, step=step)
        return cls(base, mesh, sq8=sq8, k_prime_local=k_prime_local)
