"""Typed, hashable search parameters for Retriever API v1.

:class:`SearchParams` is the single query-time knob object: it replaces the
v0 loose ``k/k_prime/nprobe`` kwargs of ``core.index.query`` and the untyped
``**overrides`` of ``anns/base.py``.  It is a frozen dataclass, so it is
hashable and usable as a jit-static argument — ``LemurRetriever`` keys its
compiled-query cache on (backend, resolved params) and lets ``jax.jit``
specialize per batch shape, i.e. exactly one trace per
(backend, params, batch-shape).

``backend`` carries the active backend's typed knobs (an instance of its
registered ``params_cls``, e.g. :class:`~repro.anns.params.IVFSearchParams`);
``None`` means "that backend's configured defaults".  ``k``/``k_prime``
default to the build config's values when left ``None``.
"""
from __future__ import annotations

import dataclasses

from repro.anns.params import (
    BackendSearchParams,
    IVFSearchParams,
    NoSearchParams,
    TokenPruningSearchParams,
)


@dataclasses.dataclass(frozen=True)
class SearchParams:
    k: int | None = None                       # final top-k (None => cfg.k)
    k_prime: int | None = None                 # rerank budget (None => cfg.k_prime)
    use_ann: bool = True                       # False => exact latent scan (Fig. 3)
    backend: BackendSearchParams | None = None  # typed per-backend knobs
    use_fused_gather: bool | None = None       # candidate-gather rerank via the
                                               # gather-at-source kernel path
                                               # (None => cfg.use_fused_gather);
                                               # False keeps the legacy HBM
                                               # gather benchmarkable.  The IVF
                                               # probe-scan twin rides in
                                               # IVFSearchParams.use_fused_gather.
    use_one_launch: bool | None = None         # fuse the pre-rerank first stage
                                               # (ψ-pool + scan + top-k') into
                                               # ONE kernel launch (None =>
                                               # cfg.use_one_launch).  Governs
                                               # the exact scan (use_ann=False)
                                               # and the sharded dense scan; the
                                               # IVF twin rides in
                                               # IVFSearchParams.use_one_launch.
    use_residual: bool | None = None           # rerank off the compressed
                                               # (residual-codec) token tier via
                                               # the in-kernel dequant path
                                               # (None => cfg.residual.enabled).
                                               # Only meaningful on a store
                                               # BUILT with the codec; False on
                                               # such a store reads the decoded
                                               # fp32 view (legacy gather).

    def resolve(self, cfg, backend_name: str) -> "SearchParams":
        """Fill every ``None`` from the build config: ``k``/``k_prime`` from
        the core config, ``backend`` from the named backend's namespace.
        Resolution happens before jit, so equivalent param spellings share
        one compiled query fn.  Raises ``TypeError`` if ``backend`` is typed
        for a different backend than the active one."""
        from repro.anns import registry

        be = registry.get_backend(backend_name)
        if not self.use_ann:
            bp = None  # exact scan has no backend knobs; collapse the key
        elif self.backend is None:
            bp = be.default_params(cfg.backend_config(backend_name))
        elif not isinstance(self.backend, be.params_cls):
            raise TypeError(
                f"SearchParams.backend is {type(self.backend).__name__}, but "
                f"backend {be.name!r} takes {be.params_cls.__name__}")
        else:
            # fill the instance's None fields from the config namespace, so
            # e.g. IVFSearchParams() === "cfg.ivf defaults" (and equivalent
            # spellings collapse to one compiled-fn cache entry)
            defaults = be.default_params(cfg.backend_config(backend_name))
            fill = {f.name: getattr(defaults, f.name)
                    for f in dataclasses.fields(self.backend)
                    if getattr(self.backend, f.name) is None}
            bp = dataclasses.replace(self.backend, **fill) if fill else self.backend
        return dataclasses.replace(
            self,
            k=int(self.k if self.k is not None else cfg.k),
            k_prime=int(self.k_prime if self.k_prime is not None else cfg.k_prime),
            backend=bp,
            use_fused_gather=bool(
                cfg.use_fused_gather if self.use_fused_gather is None
                else self.use_fused_gather),
            use_one_launch=bool(
                cfg.use_one_launch if self.use_one_launch is None
                else self.use_one_launch),
            use_residual=bool(
                cfg.residual.enabled if self.use_residual is None
                else self.use_residual),
        )


__all__ = [
    "SearchParams",
    "BackendSearchParams",
    "IVFSearchParams",
    "NoSearchParams",
    "TokenPruningSearchParams",
]
