"""LifecycleManager: the drift -> refresh -> warm-swap control loop.

One daemon thread owns the whole closed loop so serving never pays for it:

* poll the :class:`~repro.lifecycle.drift.DriftMonitor` (cheap; only
  measures once enough recent mutations accumulated);
* on a triggered report, run :func:`~repro.lifecycle.refresh.build_refresh`
  on THIS thread against an immutable snapshot — the server worker keeps
  batching searches and applying mutations the whole time;
* install the result through the target's ``apply()`` FIFO barrier
  (``RetrieverServer.apply`` locally, ``Router.apply`` fleet-wide): earlier
  searches resolve against the old snapshot, later ones see the refit index,
  zero requests dropped — the same guarantee add/delete already have.

Every transition lands in a bounded :class:`~repro.lifecycle.events.EventLog`
as a typed event; failures degrade, never propagate:

====================  =====================================================
event                 meaning / operator action
====================  =====================================================
``DriftDetected``     staleness signal crossed threshold; refresh imminent
``RefreshStarted``    background rebuild running; serving unaffected
``RefreshFailed``     rebuild crashed (phase recorded); last-good serving —
                      retried after ``cooldown_s``
``RefreshCompleted``  rebuilt index ready; swap being installed
``SwapCompleted``     fleet serving the refit index at the new version
``SwapAborted``       install validation rejected the rebuild
                      (``CorruptIndexError``) or the barrier could not
                      complete; last-good serving everywhere
====================  =====================================================
"""
from __future__ import annotations

import threading
import time

from .drift import DriftMonitor
from .events import (DriftDetected, EventLog, RefreshCompleted, RefreshFailed,
                     RefreshStarted, SwapAborted, SwapCompleted)
from .refresh import RefreshResult, build_refresh


def _target_retriever(target):
    """The retriever to monitor/snapshot: a server's, or the first healthy
    replica's for a fleet router (all replicas are bit-identical between
    barriers, so any healthy one represents the fleet snapshot)."""
    first = getattr(target, "_first_healthy_server", None)
    if first is not None:
        return first().retriever
    return target.retriever


class LifecycleManager:
    """Drives drift detection, background refresh, and warm swap against a
    ``RetrieverServer`` or fleet ``Router`` (anything with ``apply(fn)``).

    ``start()`` launches the polling thread (``auto=True``); with
    ``auto=False`` nothing runs until :meth:`refresh_now` — the manual mode
    benchmarks and chaos tests drive.  Use as a context manager.
    """

    def __init__(self, target, *, monitor: DriftMonitor | None = None,
                 seed: int = 0, chaos=None,
                 poll_interval_s: float = 0.05,
                 cooldown_s: float = 1.0,
                 min_reservoir: int = 16,
                 swap_timeout_s: float = 300.0,
                 event_log_size: int = 1024,
                 on_event=None):
        self._target = target
        self._monitor = monitor or DriftMonitor(_target_retriever(target),
                                                seed=seed)
        self._seed = seed
        self._chaos = chaos
        self._poll_s = float(poll_interval_s)
        self._cooldown_s = float(cooldown_s)
        self._min_reservoir = int(min_reservoir)
        self._swap_timeout_s = float(swap_timeout_s)
        self._log = EventLog(event_log_size)
        self._on_event = on_event
        self._stop_evt = threading.Event()
        self._thread: threading.Thread | None = None
        self._refresh_lock = threading.Lock()   # one refresh at a time
        self._last_attempt_t = -float("inf")
        self.last_refresh_result: RefreshResult | None = None
        self.n_refreshes = 0
        self.n_swaps = 0

    # -- lifecycle ----------------------------------------------------------

    @property
    def monitor(self) -> DriftMonitor:
        return self._monitor

    def start(self, *, auto: bool = True) -> "LifecycleManager":
        self._monitor.attach()
        if auto:
            self._stop_evt.clear()
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="lemur-lifecycle")
            self._thread.start()
        return self

    def stop(self, timeout: float | None = 30.0) -> None:
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        self._monitor.detach()

    def __enter__(self) -> "LifecycleManager":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def events(self, kind: type | None = None):
        return self._log.events(kind)

    def _emit(self, ev) -> None:
        self._log.append(ev)
        if self._on_event is not None:
            try:
                self._on_event(ev)
            except Exception:
                pass

    # -- control loop -------------------------------------------------------

    def _loop(self) -> None:
        while not self._stop_evt.wait(self._poll_s):
            try:
                self.poll_once()
            except Exception:
                # the loop must never die silently mid-deployment; failures
                # are already recorded as typed events by refresh_now
                pass

    def poll_once(self) -> bool:
        """One drift check; kicks a refresh when triggered (respecting the
        cooldown).  Returns True only when a triggered refresh completed
        its swap — a crashed rebuild or aborted install returns False (with
        the typed event recorded) so callers can observe the failure."""
        now = time.perf_counter()
        if now - self._last_attempt_t < self._cooldown_s:
            return False
        report = self._monitor.maybe_report(self._min_reservoir)
        if report is None or not report.triggered:
            return False
        self._emit(DriftDetected(t=now, coverage=report.coverage,
                                 baseline_coverage=report.baseline_coverage,
                                 fidelity=report.fidelity,
                                 baseline_fidelity=report.baseline_fidelity,
                                 skew=report.skew,
                                 n_reservoir=report.n_reservoir,
                                 reason=report.reason))
        return self.refresh_now(reason=report.reason)

    def refresh_now(self, reason: str = "manual") -> bool:
        """Run the full rebuild + warm swap once.  Returns True on a
        completed swap; every failure path leaves a typed event and the
        last-good snapshot serving."""
        with self._refresh_lock:
            self._last_attempt_t = time.perf_counter()
            retriever = _target_retriever(self._target)
            self._emit(RefreshStarted(t=time.perf_counter(),
                                      m0=retriever.m,
                                      version=retriever.version,
                                      reason=reason))
            try:
                result = build_refresh(retriever, seed=self._seed,
                                       chaos=self._chaos)
            except Exception as e:
                self._emit(RefreshFailed(
                    t=time.perf_counter(),
                    phase=getattr(e, "lifecycle_phase", "unknown"),
                    error=repr(e)))
                return False
            self.last_refresh_result = result
            self.n_refreshes += 1
            self._emit(RefreshCompleted(t=time.perf_counter(), m0=result.m0,
                                        wall_s=result.wall_s))
            return self._install(result)

    def _install(self, result: RefreshResult) -> bool:
        try:
            fut = self._target.apply(lambda r: r.install_refresh(result))
            fut.result(timeout=self._swap_timeout_s)
        except Exception as e:
            # CorruptIndexError (validation), barrier failure, timeout —
            # in every case install validation ran before any mutation, so
            # each replica still serves its last-good snapshot
            self._emit(SwapAborted(t=time.perf_counter(), error=repr(e)))
            return False
        retriever = _target_retriever(self._target)
        self._emit(SwapCompleted(
            t=time.perf_counter(),
            version=getattr(fut, "snapshot_version", retriever.version),
            m=retriever.m,
            caught_up=getattr(retriever, "_last_refresh_caught_up", 0)))
        self.n_swaps += 1
        # recalibrate against the NEW fit: the next drift report measures
        # post-swap staleness, not the drift the swap just repaired
        self._monitor.reset()
        return True


__all__ = ["LifecycleManager"]
