"""Online drift detection for the learned index.

LEMUR's first stage is *trained*: the OLS map ``W`` and the IVF coarse
quantizer are fit to a corpus snapshot.  As the mutable corpus drifts
(adds from a shifted distribution, deletes of the docs the fit saw), recall
decays with no error raised.  The monitor turns that silent decay into a
cheap online signal measured on a reservoir of recent mutations:

* **first-stage coverage** — the primary signal, a direct proxy for the
  recall of record: the fraction of reservoir docs that appear in their OWN
  first-stage candidate list when their tokens are replayed as a query at
  the configured operating point (``candidates()``: IVF probe + k′).  Docs
  the frozen quantizer no longer covers fall out of their own candidate
  lists long before anyone inspects end-to-end recall.  Reported as a ratio
  against a baseline calibrated on docs the fit was trained for; the
  trigger is ``coverage < coverage_ratio_threshold * baseline``.
* **score fidelity** — the Fig.-2 d′ proxy made incremental: Pearson
  correlation between the latent scores ``psi(x) @ W_j`` the index serves
  and the true standardized MaxSim targets ``g_j(x)``, pooled over probe
  tokens ``x`` drawn from recently-added docs.  Probing with *recent*
  tokens is the point — they expose exactly the region the stale OLS fit
  extrapolates into.  Catches map/stats staleness that coverage (a set
  membership test) is blind to, e.g. score-scale drift.
* **assignment skew** — EXCESS total-variation distance between where
  recent docs' latent rows land on the frozen IVF centroids and the current
  cluster mass, beyond the finite-sample multinomial null (a reservoir of n
  docs over ``nlist`` clusters has TV ≈ Θ(sqrt(nlist/n)) against ANY mass
  purely from sampling — raw TV would false-trigger on small reservoirs,
  see tests).  The null mean is estimated with seeded multinomial draws.

All three are O(reservoir), not O(corpus), and read live index state at
report time — after a warm swap the same reservoir immediately measures the
new fit (coverage against the re-clustered quantizer in particular), which
is how refresh efficacy is verified.

The reservoir is fed by the ``core.pages`` mutation tap.  In a fleet every
replica applies the same logical mutation, so the tap fires once per
replica; slot ids are globally monotone, which makes dedupe trivial —
record only ids beyond the high-water mark.
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

import jax.numpy as jnp

from ..core import maxsim, pages
from ..core.model import psi_apply


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """One staleness measurement.  ``triggered`` applies the monitor's
    thresholds; ``reason`` says which signal fired."""
    coverage: float          # reservoir self-retrieval rate (primary signal)
    baseline_coverage: float
    fidelity: float
    baseline_fidelity: float
    fidelity_drop: float
    skew: float              # excess TV over the finite-sample null
    n_reservoir: int
    triggered: bool
    reason: str

    @property
    def coverage_ratio(self) -> float:
        return self.coverage / max(self.baseline_coverage, 1e-9)


def _facade(retriever):
    """Accept both ``LemurRetriever`` and ``ShardedLemurRetriever`` — the
    sharded wrapper's learned state lives on its base facade."""
    return getattr(retriever, "_base", retriever)


def _pearson(a: np.ndarray, b: np.ndarray) -> float:
    a = a.ravel().astype(np.float64)
    b = b.ravel().astype(np.float64)
    a = a - a.mean()
    b = b - b.mean()
    denom = float(np.sqrt((a * a).sum() * (b * b).sum()))
    if denom <= 0.0 or not np.isfinite(denom):
        return 0.0
    return float((a * b).sum() / denom)


class DriftMonitor:
    """Tracks staleness of a ``LemurRetriever``'s learned first stage.

    ``attach()`` registers a mutation tap and calibrates the fidelity
    baseline on the CURRENT corpus (a sample of alive docs — by
    construction the fit is fresh for them).  ``report()`` measures the
    reservoir against the live index.  Thread-safe: taps fire on server
    worker threads while reports run on a lifecycle thread.
    """

    def __init__(self, retriever, *, reservoir: int = 256, probes: int = 128,
                 probe_docs: int = 64,
                 coverage_ratio_threshold: float = 0.25,
                 fidelity_drop_threshold: float = 0.10,
                 skew_threshold: float = 0.25, seed: int = 0):
        self._retriever = retriever
        self._cap = int(reservoir)
        self._probes = int(probes)
        self._probe_docs = int(probe_docs)
        self._cov_thr = float(coverage_ratio_threshold)
        self._drop_thr = float(fidelity_drop_threshold)
        self._skew_thr = float(skew_threshold)
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        # slot id -> (tokens (t, d), mask (t,)) of recently-added docs
        self._res: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        self._max_seen = -1           # monotone-id dedupe across replicas
        # delete dedupe: dict-as-ordered-set, FIFO-bounded so a long-running
        # monitor never leaks (worst case after eviction: an over-counted
        # n_mutations, never a wrong report)
        self._deleted: dict[int, None] = {}
        self._baseline: tuple[float, float] | None = None  # (fidelity, coverage)
        self._attached = False
        self.n_mutations = 0          # logical mutations observed (deduped)

    # -- reservoir feed ----------------------------------------------------

    def _tap(self, kind: str, ids, **payload) -> None:
        ids = np.asarray(ids).ravel()
        with self._lock:
            if kind == "add":
                fresh = ids > self._max_seen
                if not fresh.any():
                    return          # a sibling replica already reported these
                toks = payload["doc_tokens"]
                mask = payload["doc_mask"]
                for k in np.flatnonzero(fresh):
                    i = int(ids[k])
                    self._res[i] = (toks[k], mask[k])
                    self._max_seen = max(self._max_seen, i)
                while len(self._res) > self._cap:
                    self._res.pop(next(iter(self._res)))
                self.n_mutations += 1
            elif kind == "delete":
                new = [int(i) for i in ids if int(i) not in self._deleted]
                if not new:
                    return
                for i in new:
                    self._deleted[i] = None
                    self._res.pop(i, None)
                while len(self._deleted) > 4 * self._cap:
                    self._deleted.pop(next(iter(self._deleted)))
                self.n_mutations += 1

    def attach(self) -> None:
        if self._attached:
            return
        self._baseline = self._measure_baseline()
        pages.register_mutation_tap(self._tap)
        self._attached = True

    def detach(self) -> None:
        if self._attached:
            pages.unregister_mutation_tap(self._tap)
            self._attached = False

    def __enter__(self):
        self.attach()
        return self

    def __exit__(self, *exc):
        self.detach()
        return False

    def reset(self) -> None:
        """Drop the reservoir and recalibrate the baseline — called after a
        warm swap so the next report measures drift against the NEW fit."""
        with self._lock:
            self._res.clear()
            self.n_mutations = 0
        self._baseline = self._measure_baseline()

    @property
    def n_reservoir(self) -> int:
        with self._lock:
            return len(self._res)

    # -- measurement -------------------------------------------------------

    def _fidelity(self, doc_ids: np.ndarray, toks: np.ndarray,
                  mask: np.ndarray) -> float:
        """Pearson corr of served latent scores vs true standardized MaxSim
        over (probe token, doc) pairs; probes drawn from ``toks``."""
        idx = _facade(self._retriever)._index
        flat = toks.reshape(-1, toks.shape[-1])
        ok = np.flatnonzero(mask.reshape(-1))
        if ok.size == 0:
            return 1.0
        pick = self._rng.choice(ok, size=min(self._probes, ok.size),
                                replace=False)
        x = jnp.asarray(flat[pick])
        w = idx.store.W[jnp.asarray(doc_ids, jnp.int32)]
        pred = psi_apply(idx.psi, x) @ w.T
        g = maxsim.token_maxsim(x, jnp.asarray(toks), jnp.asarray(mask))
        g = (g - idx.stats.mean) / idx.stats.std
        return _pearson(np.asarray(pred), np.asarray(g))

    def _coverage(self, doc_ids: np.ndarray, toks: np.ndarray,
                  mask: np.ndarray) -> float:
        """Self-retrieval rate: the fraction of ``doc_ids`` that appear in
        their own first-stage candidate list when their tokens are replayed
        as a query at the configured operating point.  Samples at most
        ``probe_docs`` docs; the batch is padded to a FIXED (probe_docs,
        pow2-Tq) shape so the background monitor compiles one candidates fn
        per token bucket, not one per reservoir size."""
        r = _facade(self._retriever)
        n = min(self._probe_docs, len(doc_ids))
        if n == 0:
            return 1.0
        pick = self._rng.choice(len(doc_ids), size=n, replace=False)
        tmax = 1 << (int(toks.shape[1]) - 1).bit_length()
        tp = np.zeros((self._probe_docs, tmax, toks.shape[-1]), np.float32)
        mp = np.zeros((self._probe_docs, tmax), bool)
        tp[:n, :toks.shape[1]] = toks[pick]
        mp[:n, :toks.shape[1]] = mask[pick]
        cand = np.asarray(r.candidates(tp, mp))[:n]
        ids = np.asarray(doc_ids)[pick]
        return float(np.mean([int(i) in set(cand[j].tolist())
                              for j, i in enumerate(ids)]))

    def _measure_baseline(self, sample: int = 64) -> tuple[float, float]:
        """(fidelity, coverage) on a sample of docs the CURRENT fit covers —
        by construction fresh for them, so it calibrates both signals."""
        idx = _facade(self._retriever)._index
        alive = np.flatnonzero(np.asarray(idx.store.alive)[:idx.m])
        if alive.size == 0:
            return 1.0, 1.0
        pick = self._rng.choice(alive, size=min(sample, alive.size),
                                replace=False).astype(np.int32)
        toks, mask = pages.gather_docs(idx.store, jnp.asarray(pick))
        toks, mask = np.asarray(toks), np.asarray(mask)
        return (self._fidelity(pick, toks, mask),
                self._coverage(pick, toks, mask))

    def _skew(self, doc_ids: np.ndarray) -> float:
        """EXCESS TV distance between reservoir centroid assignments and the
        current cluster mass, beyond the finite-sample multinomial null
        (mean TV of same-size draws FROM that mass — raw TV at reservoir
        sizes is dominated by sampling noise and would false-trigger).
        0.0 when the backend has no coarse quantizer."""
        idx = _facade(self._retriever)._index
        ann = idx.ann
        if ann is None or not hasattr(ann, "centroids"):
            return 0.0
        w = idx.store.W[jnp.asarray(doc_ids, jnp.int32)]
        if getattr(ann, "mean", None) is not None:
            w = w - ann.mean[None, :]
        from ..anns.ivf import assign_clusters
        assign = np.asarray(assign_clusters(w, ann.centroids))
        nlist = ann.centroids.shape[0]
        n = len(doc_ids)
        p = np.bincount(assign, minlength=nlist).astype(np.float64)
        p /= max(p.sum(), 1.0)
        q = np.asarray(ann.counts, np.float64)
        q /= max(q.sum(), 1.0)
        tv = float(0.5 * np.abs(p - q).sum())
        draws = self._rng.multinomial(n, q, size=32) / max(n, 1)
        null = float(0.5 * np.abs(draws - q[None, :]).sum(axis=1).mean())
        return max(0.0, tv - null)

    def report(self) -> DriftReport:
        with self._lock:
            items = [(i, t, mk) for i, (t, mk) in self._res.items()
                     if i not in self._deleted]
        alive = np.asarray(_facade(self._retriever)._index.store.alive)
        items = [(i, t, mk) for i, t, mk in items
                 if i < alive.shape[0] and alive[i]]
        base_fid, base_cov = self._baseline if self._baseline else (1.0, 1.0)
        if not items:
            return DriftReport(1.0, base_cov, 1.0, base_fid, 0.0, 0.0, 0,
                               False, "empty reservoir")
        ids = np.asarray([i for i, _, _ in items], np.int32)
        tmax = max(t.shape[0] for _, t, _ in items)
        d = items[0][1].shape[-1]
        toks = np.zeros((len(items), tmax, d), np.float32)
        mask = np.zeros((len(items), tmax), bool)
        for k, (_, t, mk) in enumerate(items):
            toks[k, :t.shape[0]] = t
            mask[k, :mk.shape[0]] = mk
        coverage = self._coverage(ids, toks, mask)
        fidelity = self._fidelity(ids, toks, mask)
        drop = max(0.0, base_fid - fidelity)
        skew = self._skew(ids)
        reasons = []
        if coverage < self._cov_thr * base_cov:
            reasons.append(f"first-stage coverage {coverage:.3f} < "
                           f"{self._cov_thr} * baseline {base_cov:.3f}")
        if drop > self._drop_thr:
            reasons.append(f"fidelity drop {drop:.3f} > {self._drop_thr}")
        if skew > self._skew_thr:
            reasons.append(f"assignment skew {skew:.3f} > {self._skew_thr}")
        return DriftReport(coverage, base_cov, fidelity, base_fid, drop, skew,
                           len(items), bool(reasons),
                           "; ".join(reasons) or "healthy")

    def maybe_report(self, min_reservoir: int = 16) -> DriftReport | None:
        """Cheap gate for polling loops: only measure once enough recent
        mutations accumulated to make the signal meaningful."""
        if self.n_reservoir < min_reservoir:
            return None
        return self.report()


__all__ = ["DriftMonitor", "DriftReport"]
