"""Learned-index lifecycle: drift detection, background refresh, warm swap.

LEMUR's first stage is a *trained* reduction — a mutable corpus silently
degrades it.  This package closes the loop:

    from repro.lifecycle import DriftMonitor, LifecycleManager

    with RetrieverServer(r, ladder=ladder) as srv:
        with LifecycleManager(srv, seed=0) as mgr:   # monitors, refreshes,
            ...                                      # and warm-swaps alone

See :mod:`repro.lifecycle.manager` for the event taxonomy and
``tests/test_lifecycle_chaos.py`` for the fault-injection proof.
"""
from repro.lifecycle.chaos import ChaosError, ChaosInjector
from repro.lifecycle.drift import DriftMonitor, DriftReport
from repro.lifecycle.events import (DriftDetected, EventLog, LifecycleEvent,
                                    RefreshCompleted, RefreshFailed,
                                    RefreshStarted, SwapAborted,
                                    SwapCompleted)
from repro.lifecycle.manager import LifecycleManager
from repro.lifecycle.refresh import RefreshResult, Refresher, build_refresh

__all__ = [
    "ChaosError",
    "ChaosInjector",
    "DriftDetected",
    "DriftMonitor",
    "DriftReport",
    "EventLog",
    "LifecycleEvent",
    "LifecycleManager",
    "RefreshCompleted",
    "RefreshFailed",
    "RefreshResult",
    "RefreshStarted",
    "Refresher",
    "SwapAborted",
    "SwapCompleted",
    "build_refresh",
]
