"""Background index refresh: re-fit the learned reduction on the live corpus.

``build_refresh`` is a *pure function of one index snapshot* — it reads the
immutable ``LemurIndex`` NamedTuple (safe from any thread while serving
continues to mutate the retriever) and produces everything a warm swap
installs:

1. **re-sampled OLS probes** — ``x_ols`` drawn from the tokens of the docs
   that are alive NOW, not the build-time training tokens, so the Gram
   matrix reflects the drifted distribution;
2. **re-fit latent map** — ``W`` rows for every alive slot in ``[0, m0)``
   via the blocked OLS solve with frozen ψ and frozen target stats.  Dead
   slots get zero rows (never fed through the solver: a tombstone's NEG
   mask values would poison the fp32 normal equations) — which is exactly
   what the slot-numbering invariant needs anyway;
3. **re-clustered first stage** — a from-scratch ``be.build`` over the
   re-fit latent rows, so IVF centroids move to where the corpus actually
   is instead of extending the frozen build-time quantizer forever.

ψ itself stays frozen: per §4.3 the MLP is pre-trained on a sample and the
OLS output layer does the corpus-specific work, so refit+recluster recovers
almost all drift-lost recall at a tiny fraction of a full rebuild.

Determinism: given the same snapshot and ``seed``, the result is
bit-identical — which is why a fleet can install one ``RefreshResult`` on
every replica and still pass the barrier's same-snapshot-version check.

Failure injection: ``chaos.check()`` runs at each phase boundary; any
exception escapes with ``e.lifecycle_phase`` set so the manager can emit a
typed ``RefreshFailed(phase=...)``.  An exception leaves the retriever and
its served snapshot completely untouched.
"""
from __future__ import annotations

import threading
import time
from typing import Any, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from ..anns import registry
from ..anns.base import CorpusView
from ..core import indexer, pages


class RefreshResult(NamedTuple):
    """Everything ``LemurRetriever.install_refresh`` needs.  ``m0`` is the
    slot high-water mark the rebuild covered; docs added after the snapshot
    are caught up at install time with the new solver."""
    backend: str
    version: int           # snapshot version the rebuild started from
    m0: int
    W: Any                 # (m0, d_prime) re-fit latent rows, dead slots zero
    ann: Any               # freshly built first-stage state over those rows
    solver: dict           # new OLS solver state {"chol", "feats", "x_ols"}
    seed: int
    wall_s: float


def build_refresh(retriever, *, seed: int = 0, chaos=None) -> RefreshResult:
    """Rebuild the learned first stage from ``retriever``'s current snapshot.

    Runs anywhere (worker thread included): only reads the immutable
    snapshot.  Raises ``ValueError`` if the snapshot has no alive docs."""
    t0 = time.perf_counter()
    base = getattr(retriever, "_base", retriever)   # sharded -> facade
    idx = base.snapshot()
    version = int(base.version)
    cfg, psi, stats = idx.cfg, idx.psi, idx.stats
    m0 = idx.m
    phase = "snapshot"
    try:
        alive = np.flatnonzero(np.asarray(idx.store.alive)[:m0])
        if alive.size == 0:
            raise ValueError("refresh: snapshot has no alive docs")
        alive = jnp.asarray(alive.astype(np.int32))
        # one dense materialization of [0, m0), reused by every phase below
        toks, mask = pages.gather_docs(idx.store, jnp.arange(m0))

        phase = "solver"
        if chaos is not None:
            chaos.check("refresh:solver")
        a_toks, a_mask = toks[alive], mask[alive]
        flat = np.asarray(a_toks).reshape(-1, idx.store.d)
        ok = np.flatnonzero(np.asarray(a_mask).reshape(-1))
        rng = np.random.default_rng(seed)
        pick = rng.choice(ok, size=min(cfg.n_ols, ok.size), replace=False)
        x_ols = jnp.asarray(flat[pick])
        solver = indexer.ols_solver_state(psi, x_ols, cfg)

        phase = "refit"
        if chaos is not None:
            chaos.check("refresh:refit")
        w_alive = indexer.fit_output_layer_ols(psi, x_ols, a_toks, a_mask,
                                               cfg, stats,
                                               solver_state=solver)
        W = jnp.zeros((m0, cfg.d_prime), idx.store.W.dtype).at[alive].set(
            w_alive)

        phase = "recluster"
        if chaos is not None:
            chaos.check("refresh:recluster")
        be = registry.get_backend(idx.backend)
        ann = be.build(jax.random.PRNGKey(seed), CorpusView(W, toks, mask),
                       cfg.backend_config(idx.backend))
    except Exception as e:
        e.lifecycle_phase = phase
        raise
    result = RefreshResult(idx.backend, version, m0, W, ann, solver,
                           seed, time.perf_counter() - t0)
    if chaos is not None:
        result = chaos.maybe_corrupt(result)
    return result


class Refresher:
    """Run one ``build_refresh`` on a daemon worker thread.

    Serving never blocks: the thread only reads an immutable snapshot.
    ``result(timeout)`` joins and returns the :class:`RefreshResult`,
    re-raising whatever the rebuild raised (with ``lifecycle_phase`` set).
    """

    def __init__(self, retriever, *, seed: int = 0, chaos=None):
        self._retriever = retriever
        self._seed = seed
        self._chaos = chaos
        self._result: RefreshResult | None = None
        self._error: BaseException | None = None
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="lemur-refresher")

    def _run(self) -> None:
        try:
            self._result = build_refresh(self._retriever, seed=self._seed,
                                         chaos=self._chaos)
        except BaseException as e:
            self._error = e

    def start(self) -> "Refresher":
        self._thread.start()
        return self

    def running(self) -> bool:
        return self._thread.is_alive()

    def result(self, timeout: float | None = None) -> RefreshResult:
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError("refresh still running")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result


__all__ = ["RefreshResult", "Refresher", "build_refresh"]
