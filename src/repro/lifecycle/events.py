"""Typed lifecycle health events — the failure taxonomy the runbook keys on.

Every state transition of the learned-index lifecycle (drift detection,
background refresh, warm swap) is recorded as one frozen dataclass below,
never a log line alone: chaos tests and the lifecycle bench assert on the
TYPES (a crashed refresh must leave a ``RefreshFailed``, a rejected rebuilt
index a ``SwapAborted``) so "degraded gracefully to the last-good snapshot"
is machine-checkable, not an operator's impression.

Events carry plain JSON-able payloads (no live index state) so an event log
can be shipped off-box verbatim.  ``EventLog`` is the bounded ring buffer
every lifecycle component appends to — same no-unbounded-growth contract as
``ServerStats``' latency windows, with a dropped counter so truncation is
observable.
"""
from __future__ import annotations

import collections
import dataclasses
import threading


@dataclasses.dataclass(frozen=True)
class LifecycleEvent:
    """Base: ``t`` is a perf_counter-domain timestamp (monotonic, comparable
    with server/router event times)."""
    t: float

    @property
    def kind(self) -> str:
        return type(self).__name__


@dataclasses.dataclass(frozen=True)
class DriftDetected(LifecycleEvent):
    """The monitor's staleness signal crossed its trigger threshold."""
    coverage: float          # reservoir first-stage self-retrieval rate
    baseline_coverage: float
    fidelity: float          # latent score fidelity on recent mutations
    baseline_fidelity: float
    skew: float              # excess centroid-assignment TV vs sampling null
    n_reservoir: int
    reason: str


@dataclasses.dataclass(frozen=True)
class RefreshStarted(LifecycleEvent):
    m0: int                  # slot high-water mark the rebuild snapshotted
    version: int             # snapshot version the rebuild started from
    reason: str


@dataclasses.dataclass(frozen=True)
class RefreshFailed(LifecycleEvent):
    """The background rebuild died (crash, injected fault, ...).  Serving
    was never touched — the last-good snapshot keeps answering."""
    phase: str               # which rebuild phase raised ("solver"/"refit"/...)
    error: str


@dataclasses.dataclass(frozen=True)
class RefreshCompleted(LifecycleEvent):
    m0: int
    wall_s: float


@dataclasses.dataclass(frozen=True)
class SwapCompleted(LifecycleEvent):
    """The rebuilt index is installed fleet-wide behind the FIFO barrier."""
    version: int             # snapshot version AFTER the swap
    m: int
    caught_up: int           # docs added during the rebuild, re-fit at install


@dataclasses.dataclass(frozen=True)
class SwapAborted(LifecycleEvent):
    """Install-time validation rejected the rebuilt index (corrupt W, bad
    candidate ids, ...) — the last-good snapshot stays installed on every
    replica; nothing is torn."""
    error: str


class EventLog:
    """Thread-safe bounded event ring (newest kept; drops counted)."""

    def __init__(self, maxlen: int = 1024):
        self._lock = threading.Lock()
        self._events: collections.deque[LifecycleEvent] = collections.deque(
            maxlen=maxlen)
        self._dropped = 0

    def append(self, ev: LifecycleEvent) -> None:
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self._dropped += 1
            self._events.append(ev)

    def events(self, kind: type | None = None) -> list[LifecycleEvent]:
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if isinstance(e, kind)]
        return evs

    @property
    def dropped(self) -> int:
        with self._lock:
            return self._dropped

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)


__all__ = [
    "DriftDetected",
    "EventLog",
    "LifecycleEvent",
    "RefreshCompleted",
    "RefreshFailed",
    "RefreshStarted",
    "SwapAborted",
    "SwapCompleted",
]
