"""Fault injection for the index lifecycle.

The chaos harness is deliberately dumb: a registry of named checkpoints
(``refresh:solver``, ``refresh:refit``, ``refresh:recluster``, ...) that the
real code calls ``check()`` at, plus an optional hook that corrupts a
finished :class:`~repro.lifecycle.refresh.RefreshResult` before install.
Tests arm specific failures; production code runs with ``chaos=None`` and
pays one ``is None`` branch per checkpoint.

Scenarios this enables (see ``tests/test_lifecycle_chaos.py``):

* kill the refresh mid-train          -> ``RefreshFailed``, serving untouched
* hand install a corrupted index      -> ``SwapAborted``, last-good kept
* crash a replica mid-swap            -> barrier excuses it, swap completes
"""
from __future__ import annotations

import threading


class ChaosError(RuntimeError):
    """Raised by an armed chaos checkpoint — a stand-in for OOM, preemption,
    or a worker segfault at that point in the lifecycle."""


class ChaosInjector:
    """Arm named failure points; ``check(point)`` raises once per arming.

    ``fail_at(point, times=n)`` makes the next ``n`` ``check(point)`` calls
    raise :class:`ChaosError`.  ``corrupt_results(fn)`` installs a transform
    applied to refresh results via :meth:`maybe_corrupt` (used to hand the
    swap path a poisoned index).  Thread-safe: refreshes run on worker
    threads.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._armed: dict[str, int] = {}
        self._fired: dict[str, int] = {}
        self._corrupt = None

    def fail_at(self, point: str, times: int = 1) -> None:
        with self._lock:
            self._armed[point] = self._armed.get(point, 0) + int(times)

    def check(self, point: str) -> None:
        with self._lock:
            left = self._armed.get(point, 0)
            if left <= 0:
                return
            self._armed[point] = left - 1
            self._fired[point] = self._fired.get(point, 0) + 1
        err = ChaosError(f"chaos: injected failure at {point!r}")
        err.point = point
        raise err

    def corrupt_results(self, fn) -> None:
        """``fn(result) -> result`` applied to every refresh result."""
        with self._lock:
            self._corrupt = fn

    def maybe_corrupt(self, result):
        with self._lock:
            fn = self._corrupt
        if fn is None:
            return result
        with self._lock:
            self._fired["corrupt"] = self._fired.get("corrupt", 0) + 1
        return fn(result)

    def fired(self, point: str) -> int:
        with self._lock:
            return self._fired.get(point, 0)


__all__ = ["ChaosError", "ChaosInjector"]
