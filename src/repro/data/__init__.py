from repro.data import loader, synthetic

__all__ = ["loader", "synthetic"]
