"""Sharded host->device data loading with double-buffered prefetch.

``ShardedLoader`` places each host batch on the mesh with the step function's
input shardings (so jit never sees a layout change), and prefetches the next
batch on a background thread while the current step runs — the host->HBM copy
overlaps compute, which is the standard input-pipeline optimization at pod
scale.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterable, Iterator

import jax
import numpy as np


class ShardedLoader:
    def __init__(
        self,
        batches: Iterable[Any],
        shardings: Any | None = None,
        prefetch: int = 2,
    ):
        self._batches = iter(batches)
        self._shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._done = object()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self._shardings is None:
            return jax.tree_util.tree_map(jax.numpy.asarray, batch)
        return jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), batch, self._shardings
        )

    def _producer(self):
        try:
            for b in self._batches:
                self._q.put(self._place(b))
        finally:
            self._q.put(self._done)

    def __iter__(self) -> Iterator[Any]:
        while True:
            item = self._q.get()
            if item is self._done:
                return
            yield item


def local_batch_slicer(global_batch: np.ndarray, process_index: int, n_processes: int):
    """Slice a global host batch to this process's shard (multi-host launch)."""
    n = global_batch.shape[0]
    per = n // n_processes
    return global_batch[process_index * per : (process_index + 1) * per]
