"""Synthetic dataset generators (no BEIR/ViDoRe/Criteo/OGB offline).

The multi-vector corpus generator is statistically matched to the paper's
setting (Table 1): unit-norm token embeddings, variable tokens/doc, topical
cluster structure so that MaxSim has learnable signal, and three query
distributions mirroring §4.2 / App. D:

* ``queries_from_corpus_query``  — documents re-encoded "as queries"
  (token subset + query-encoder noise + fixed query length): the paper's
  default *corpus-query* strategy.
* ``queries_from_corpus``        — raw document token samples (*corpus*).
* ``queries_held_out``           — fresh queries from the topic model
  (*query* strategy; mimics actual training queries).

All generators return numpy (host) arrays; the loader shards them onto the
mesh.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class MultiVectorCorpus:
    doc_tokens: np.ndarray  # (m, T_max, d) fp32, unit-norm rows (zeros padded)
    doc_mask: np.ndarray    # (m, T_max) bool
    topics: np.ndarray      # (m, n_topics_per_doc) int32 (generator metadata)
    centers: np.ndarray     # (K, d)

    @property
    def m(self) -> int:
        return self.doc_tokens.shape[0]

    @property
    def d(self) -> int:
        return self.doc_tokens.shape[-1]


def _unit(x: np.ndarray, axis: int = -1) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=axis, keepdims=True), 1e-9)


def make_corpus(
    m: int = 20000,
    d: int = 64,
    avg_tokens: int = 24,
    max_tokens: int = 32,
    n_centers: int = 256,
    topics_per_doc: int = 2,
    topic_strength: float = 1.2,
    seed: int = 0,
) -> MultiVectorCorpus:
    rng = np.random.default_rng(seed)
    centers = _unit(rng.standard_normal((n_centers, d), dtype=np.float32))
    topics = rng.integers(0, n_centers, size=(m, topics_per_doc), dtype=np.int32)
    counts = np.clip(rng.poisson(avg_tokens, size=m), 4, max_tokens).astype(np.int32)

    tok = rng.standard_normal((m, max_tokens, d), dtype=np.float32)
    which = rng.integers(0, topics_per_doc, size=(m, max_tokens))
    c = centers[np.take_along_axis(topics, which, axis=1)]  # (m, T, d)
    tok = _unit(tok + topic_strength * c)
    mask = np.arange(max_tokens)[None, :] < counts[:, None]
    tok = tok * mask[..., None]
    return MultiVectorCorpus(tok.astype(np.float32), mask, topics, centers)


def queries_from_corpus_query(
    corpus: MultiVectorCorpus,
    n_queries: int,
    q_tokens: int = 8,
    encoder_noise: float = 0.25,
    seed: int = 1,
) -> np.ndarray:
    """Paper-default *corpus-query* strategy: re-encode sampled docs as
    queries (subset of doc tokens + query-encoder perturbation, fixed
    length).  Returns (n_queries, q_tokens, d) unit-norm."""
    rng = np.random.default_rng(seed)
    docs = rng.integers(0, corpus.m, size=n_queries)
    counts = corpus.doc_mask.sum(1)[docs]
    pick = (rng.random((n_queries, q_tokens)) * counts[:, None]).astype(np.int64)
    toks = corpus.doc_tokens[docs[:, None], pick]  # (n, q, d)
    toks = toks + encoder_noise * rng.standard_normal(toks.shape).astype(np.float32)
    return _unit(toks)


def queries_from_corpus(
    corpus: MultiVectorCorpus, n_queries: int, q_tokens: int = 8, seed: int = 1
) -> np.ndarray:
    """*corpus* strategy (App. D.1): raw document-encoder token samples."""
    rng = np.random.default_rng(seed)
    docs = rng.integers(0, corpus.m, size=n_queries)
    counts = corpus.doc_mask.sum(1)[docs]
    pick = (rng.random((n_queries, q_tokens)) * counts[:, None]).astype(np.int64)
    return corpus.doc_tokens[docs[:, None], pick].astype(np.float32)


def queries_held_out(
    corpus: MultiVectorCorpus, n_queries: int, q_tokens: int = 8,
    topic_strength: float = 1.2, seed: int = 2
) -> np.ndarray:
    """*query* strategy (App. D.2): fresh queries from the same topic model."""
    rng = np.random.default_rng(seed)
    d = corpus.d
    t = rng.integers(0, corpus.centers.shape[0], size=n_queries)
    tok = rng.standard_normal((n_queries, q_tokens, d), dtype=np.float32)
    return _unit(tok + topic_strength * corpus.centers[t][:, None, :])


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------

def lm_token_batches(vocab: int, batch: int, seq: int, n_batches: int, seed: int = 0):
    """Zipf-ish synthetic token stream; yields (tokens, labels) int32 pairs."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / ranks
    p /= p.sum()
    for _ in range(n_batches):
        toks = rng.choice(vocab, size=(batch, seq + 1), p=p).astype(np.int32)
        yield toks[:, :-1], toks[:, 1:]


# ---------------------------------------------------------------------------
# graphs (MeshGraphNet-style simulation meshes + big CSR graphs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Graph:
    senders: np.ndarray     # (E,) int32
    receivers: np.ndarray   # (E,) int32
    node_feat: np.ndarray   # (N, d) fp32
    edge_feat: np.ndarray   # (E, de) fp32
    labels: np.ndarray      # (N, dy) fp32 regression targets
    row_ptr: np.ndarray     # (N+1,) CSR over incoming edges (for sampling)
    col_idx: np.ndarray     # (E,)


def make_mesh_graph(n_nodes: int, avg_degree: int = 6, d_feat: int = 16,
                    d_edge: int = 4, d_out: int = 2, seed: int = 0) -> Graph:
    """Random geometric graph ~= a 2-D simulation mesh (MeshGraphNet regime)."""
    rng = np.random.default_rng(seed)
    pos = rng.random((n_nodes, 2), dtype=np.float32)
    # k-nearest by grid hashing (cheap O(N k) approximation, fine for synthesis)
    k = max(2, avg_degree // 2)
    idx = np.argsort(pos[:, 0], kind="stable")
    senders, receivers = [], []
    for j in range(1, k + 1):
        senders.append(idx[:-j])
        receivers.append(idx[j:])
    s = np.concatenate(senders + receivers)
    r = np.concatenate(receivers + senders)
    rel = pos[s] - pos[r]
    dist = np.linalg.norm(rel, axis=1, keepdims=True)
    edge_feat = np.concatenate(
        [rel, dist, np.ones_like(dist)], axis=1
    )[:, :d_edge].astype(np.float32)
    node_feat = np.concatenate(
        [pos, rng.standard_normal((n_nodes, max(0, d_feat - 2)), dtype=np.float32)], axis=1
    )[:, :d_feat].astype(np.float32)
    labels = np.stack(
        [np.sin(4 * np.pi * pos[:, 0]), np.cos(4 * np.pi * pos[:, 1])], axis=1
    )[:, :d_out].astype(np.float32)

    order = np.argsort(r, kind="stable")
    s, r = s[order].astype(np.int32), r[order].astype(np.int32)
    edge_feat = edge_feat[order]
    row_ptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(row_ptr, r + 1, 1)
    row_ptr = np.cumsum(row_ptr).astype(np.int64)
    return Graph(s, r, node_feat, edge_feat, labels, row_ptr, s.copy())


# ---------------------------------------------------------------------------
# recsys click logs
# ---------------------------------------------------------------------------

def make_clicks(batch: int, n_fields: int, vocab_sizes: np.ndarray, seed: int = 0,
                hist_len: int = 0, n_items: int = 0):
    """Power-law categorical ids + planted-logistic labels.  Returns dict."""
    rng = np.random.default_rng(seed)
    ids = np.stack(
        [
            np.minimum(
                rng.zipf(1.2, size=batch) - 1, vocab_sizes[f] - 1
            ).astype(np.int32)
            for f in range(n_fields)
        ],
        axis=1,
    )  # (batch, n_fields)
    w = rng.standard_normal(n_fields).astype(np.float32) * 0.3
    logit = (np.sin(ids[:, : n_fields]) * w[None, :]).sum(1)
    labels = (rng.random(batch) < 1 / (1 + np.exp(-logit))).astype(np.float32)
    out = {"ids": ids, "labels": labels}
    if hist_len:
        out["history"] = np.minimum(
            rng.zipf(1.2, size=(batch, hist_len)) - 1, n_items - 1
        ).astype(np.int32)
        out["target_item"] = np.minimum(
            rng.zipf(1.2, size=batch) - 1, n_items - 1
        ).astype(np.int32)
    return out
