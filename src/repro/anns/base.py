"""First-stage retrieval protocol — LEMUR's index-agnostic reduction (§3.2).

LEMUR's second reduction turns multi-vector inference into single-vector
MIPS, "enabling the use of existing single-vector search indexes".  This
module is that seam: every first-stage backend (exact scan, IVF, MUVERA
FDEs, DESSERT LSH sketches, PLAID-style token pruning) implements one
``Retriever`` interface, and ``core.index`` serves any of them through the
same jit-able pool → candidates → rerank pipeline.

The contract
------------
``build(key, corpus, cfg) -> state``
    One-shot offline construction.  ``corpus`` is a :class:`CorpusView`
    carrying both the latent doc vectors (LEMUR's OLS ``W`` rows, when
    available) and the raw token matrices; each backend reads the
    representation it indexes.  The returned ``state`` is an opaque jax
    pytree — ``core.index.LemurIndex`` stores it without knowing its type.

``search(state, query, k, params=None) -> (scores, ids)``
    Pure, jit-able candidate generation.  ``query`` is a
    :class:`QueryBatch` (pooled ψ latent + raw tokens); returns ``(B, k)``
    approximate scores and int32 doc ids, ``-1``-padded when a row yields
    fewer than ``k`` valid candidates.  Downstream ``maxsim.rerank`` masks
    ``-1`` ids to ``NEG`` so pads can never surface as results.
    ``params`` is an instance of the backend's declared ``params_cls``
    (:mod:`repro.anns.params`) — the typed replacement for the v0
    ``**overrides`` kwargs; ``None`` selects every default.  ``k`` and
    ``params`` are jit-static.

``add(state, corpus) -> state``
    Incremental growth: append documents without rebuilding from scratch
    (mirrors ``indexer.ols_solver_state``'s per-shard ``fit_docs`` hook —
    new W rows never touch ψ or existing rows, and the first-stage index
    must keep up).  Ids of added docs continue the existing numbering.

``pack_state(state) / unpack_state(arrays, meta)``
    Persistence seam for ``LemurRetriever.save()/load()``: the backend
    flattens its opaque state to a flat ``{name: array}`` dict plus a
    JSON-able meta dict, and reconstructs it bit-identically.  The facade
    never learns the state's type.

Backends register themselves by name in :mod:`repro.anns.registry`,
together with their build-time config namespace (``config_cls``) and
query-time params type (``params_cls``); the string key is what
``LemurConfig.anns`` / ``--backend`` select.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Protocol, runtime_checkable

import jax

from repro.anns.params import BackendConfig, BackendSearchParams


class CorpusView(NamedTuple):
    """Everything a backend may index.

    latent:     (m, d') LEMUR latent doc vectors (OLS W rows), or None when
                the caller has no learned reduction (token-level backends
                never need it).
    doc_tokens: (m, Td, d) raw token embeddings.
    doc_mask:   (m, Td) validity mask.
    """

    latent: jax.Array | None
    doc_tokens: jax.Array
    doc_mask: jax.Array

    @property
    def m(self) -> int:
        return self.doc_tokens.shape[0]


class QueryBatch(NamedTuple):
    """Both query representations, so any backend can serve the same call.

    latent: (B, d') pooled Ψ(X) queries (None when the index has no ψ —
            contract tests exercise token-level backends without one).
    tokens: (B, Tq, d) raw query tokens.
    mask:   (B, Tq) validity mask.
    """

    latent: jax.Array | None
    tokens: jax.Array
    mask: jax.Array


@runtime_checkable
class Retriever(Protocol):
    """Pluggable first-stage candidate generator (see module docstring)."""

    name: str
    #: which CorpusView/QueryBatch field drives this backend
    representation: str  # "latent" | "tokens"
    #: build-time config namespace (a field of LemurConfig) and query-time
    #: params type — registered alongside the backend in anns/registry.py
    config_cls: type[BackendConfig]
    params_cls: type[BackendSearchParams]

    def build(self, key, corpus: CorpusView, cfg) -> Any:
        """Offline construction -> opaque pytree state.  ``cfg`` is an
        instance of ``config_cls`` (or None for every default)."""
        ...

    def search(self, state, query: QueryBatch, k: int,
               params: BackendSearchParams | None = None):
        """(scores (B, k), ids (B, k) int32, -1 padded).  Must be jit-able
        with ``k`` and ``params`` static."""
        ...

    def add(self, state, corpus: CorpusView) -> Any:
        """Append documents; returned state serves ids [0, m_old + m_new)."""
        ...

    def default_params(self, cfg) -> BackendSearchParams:
        """Fully-resolved query params for a ``config_cls`` instance."""
        ...

    def pack_state(self, state) -> tuple[dict[str, Any], dict]:
        """state -> (flat {name: array} dict, JSON-able meta)."""
        ...

    def unpack_state(self, arrays: dict[str, Any], meta: dict) -> Any:
        """Inverse of :meth:`pack_state` (bit-identical)."""
        ...


def pad_topk(scores: jax.Array, ids: jax.Array, k: int):
    """Pad a (B, kk<=k) top-k result out to k columns with (-inf, -1)."""
    import jax.numpy as jnp

    kk = scores.shape[1]
    if kk >= k:
        return scores[:, :k], ids[:, :k]
    return (
        jnp.pad(scores, ((0, 0), (0, k - kk)), constant_values=-jnp.inf),
        jnp.pad(ids, ((0, 0), (0, k - kk)), constant_values=-1),
    )
