"""DESSERT-style baseline (Engels et al., NeurIPS 2023): vector-set search
with LSH sketches.

DESSERT estimates MaxSim(X, C_j) by replacing the exact per-token max with
an LSH collision estimate: each document token is hashed by L independent
SimHash functions into tables of 2^C buckets; a query token's estimated max
similarity to document j is a function of how many of its L hashes collide
with any of j's tokens.  We implement the TPU-friendly dense form:

  * build: per document, per table, a 2^C-bit occupancy BITMAP over buckets
    (documents × L × 2^C bools — dense, gather-free scoring).
  * score: hash the query tokens, gather the (L,) occupancy bits per
    document, average collisions over tables, map the collision rate back
    through the SimHash angle estimate, sum over query tokens.
  * rerank top-k' with exact MaxSim (same second stage as everything else).

Hyperparameters mirror the paper's grid: L ∈ {32, 64} tables, C ∈ {5, 7}
bits.  This is the third baseline family of Table 2 (token-pruning = PLAID,
FDE = MUVERA, LSH set-sketch = DESSERT).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ConfigBase


@dataclasses.dataclass(frozen=True)
class DessertConfig(ConfigBase):
    n_tables: int = 32       # L
    n_bits: int = 5          # C -> 2^C buckets per table
    seed: int = 11


class DessertIndex(NamedTuple):
    occupancy: jax.Array     # (m, L, 2^C) bool — bucket occupied by any doc token
    hyper: jax.Array         # (L, C, d) SimHash hyperplanes


def _hash(tokens, hyper):
    """tokens: (..., T, d) -> bucket ids (..., L, T) int32."""
    bits = jnp.einsum("...td,lcd->...ltc", tokens, hyper) > 0
    w = 2 ** jnp.arange(hyper.shape[1])
    return jnp.sum(bits * w, axis=-1).astype(jnp.int32)


def _occupancy(doc_tokens, doc_mask, hyper):
    """(m, T, d) docs -> (m, L, 2^C) bool bucket-occupancy bitmaps."""
    ids = _hash(doc_tokens, hyper)                       # (m, L, T)
    nb = 2 ** hyper.shape[1]
    onehot = jax.nn.one_hot(ids, nb, dtype=jnp.bool_)    # (m, L, T, nb)
    onehot = jnp.logical_and(onehot, doc_mask[:, None, :, None])
    return jnp.any(onehot, axis=2)                       # (m, L, nb)


def build_dessert(doc_tokens, doc_mask, cfg: DessertConfig) -> DessertIndex:
    m, T, d = doc_tokens.shape
    key = jax.random.PRNGKey(cfg.seed)
    hyper = jax.random.normal(key, (cfg.n_tables, cfg.n_bits, d))
    return DessertIndex(_occupancy(doc_tokens, doc_mask, hyper), hyper)


def extend_dessert(index: DessertIndex, doc_tokens, doc_mask) -> DessertIndex:
    """Incremental add: hash the new docs with the FROZEN hyperplanes and
    append their occupancy rows (ids continue the existing numbering)."""
    occ_new = _occupancy(doc_tokens, doc_mask, index.hyper)
    return DessertIndex(jnp.concatenate([index.occupancy, occ_new], axis=0),
                        index.hyper)


@functools.partial(jax.jit, static_argnames=("k_prime",))
def search_dessert(index: DessertIndex, q_tokens, q_mask, *, k_prime: int):
    """q_tokens: (B, Tq, d) -> (approx scores (B, k'), candidate ids (B, k')).

    Collision rate over L tables estimates P[collision] = (1 - θ/π)^C for the
    best-matching doc token; we invert to cos θ as the similarity estimate.
    """
    B, Tq, d = q_tokens.shape
    qh = _hash(q_tokens, index.hyper)                    # (B, L, Tq)
    # occupancy lookup: (m, L, nb) gathered at (B, L, Tq) bucket ids
    occ = index.occupancy                                # (m, L, nb)
    hits = jnp.take_along_axis(
        occ[None, :, :, :],                              # (1, m, L, nb)
        qh[:, None, :, :].astype(jnp.int32),             # (B, 1, L, Tq)
        axis=3,
    )                                                    # (B, m, L, Tq) bool
    rate = jnp.mean(hits.astype(jnp.float32), axis=2)    # (B, m, Tq)
    # invert SimHash: p = (1 - θ/π)^C  =>  θ = π(1 - p^{1/C}); sim ~ cos θ
    nbit = index.hyper.shape[1]
    theta = jnp.pi * (1.0 - jnp.power(jnp.clip(rate, 1e-6, 1.0), 1.0 / nbit))
    sim = jnp.cos(theta)                                 # (B, m, Tq)
    sim = jnp.where(q_mask[:, None, :], sim, 0.0)
    scores = jnp.sum(sim, axis=-1)                       # (B, m): Σ_q est-max
    kk = min(k_prime, scores.shape[1])
    return jax.lax.top_k(scores, kk)
