"""PLAID-style token-level pruning baseline (§5.1 family).

Pipeline (Santhanam et al. 2022a, simplified to its retrieval core):
  1. cluster ALL corpus token embeddings (nlist = 16·sqrt(n) pow2-floored,
     the paper's §6.3 rule);
  2. per query token, score the centroids and probe the top-`nprobe`
     clusters;
  3. approximate per-document score = Σ_q max over that query token's probed
     centroids containing the doc (centroid-interaction), accumulated by
     scatter-max over the clusters' (token -> doc) lists;
  4. exact MaxSim rerank of the top-k' docs.

This is the representative of the token-pruning family the paper argues
against: token-level proximity is a weak proxy for document MaxSim, so k'
must be large for recall — which is exactly what the benchmarks show.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.anns.ivf import assign_clusters
from repro.anns.kmeans import kmeans


class TokenPruningIndex(NamedTuple):
    centroids: jax.Array   # (nlist, d)
    doc_lists: jax.Array   # (nlist, cap) int32 doc id per member token, -1 pad
    counts: jax.Array      # (nlist,)


def plaid_nlist(n_tokens: int) -> int:
    raw = 16 * int(np.sqrt(max(n_tokens, 1)))
    return max(16, 1 << (raw.bit_length() - 1))


def build_token_pruning(key, doc_tokens, doc_mask, *, nlist: int = 0,
                        kmeans_iters: int = 8, train_sample: int = 262144,
                        cap_quantile: float = 1.0) -> TokenPruningIndex:
    m, T, d = doc_tokens.shape
    flat = np.asarray(doc_tokens[doc_mask])          # (n_tokens, d)
    tok_doc = np.broadcast_to(np.arange(m)[:, None], (m, T))[np.asarray(doc_mask)]
    n = flat.shape[0]
    # tiny corpora: never ask kmeans for more centroids than tokens
    nlist = min(nlist or plaid_nlist(n), n)

    sample = flat
    if n > train_sample:
        ridx = np.random.default_rng(0).choice(n, train_sample, replace=False)
        sample = flat[ridx]
    centroids, _ = kmeans(key, jnp.asarray(sample), nlist, iters=kmeans_iters)
    assign = np.asarray(assign_clusters(jnp.asarray(flat), centroids))

    counts = np.bincount(assign, minlength=nlist)
    cap = int(max(1, np.quantile(counts, cap_quantile) if cap_quantile < 1.0 else counts.max()))
    doc_lists = np.full((nlist, cap), -1, np.int32)
    pos = np.zeros(nlist, np.int64)
    order = np.argsort(assign, kind="stable")
    for i in order:
        c = assign[i]
        if pos[c] < cap:
            doc_lists[c, pos[c]] = tok_doc[i]
            pos[c] += 1
    return TokenPruningIndex(centroids, jnp.asarray(doc_lists), jnp.asarray(counts, jnp.int32))


def extend_token_pruning(index: TokenPruningIndex, doc_tokens, doc_mask,
                         m_old: int) -> TokenPruningIndex:
    """Incremental add: assign the new docs' tokens to the FROZEN centroids
    and append (cluster -> doc id) entries, growing list capacity as needed.
    New docs are numbered from ``m_old``."""
    m_new, T, d = doc_tokens.shape
    flat = np.asarray(doc_tokens[doc_mask])
    tok_doc = m_old + np.broadcast_to(np.arange(m_new)[:, None], (m_new, T))[
        np.asarray(doc_mask)]
    assign = np.asarray(assign_clusters(jnp.asarray(flat), index.centroids))

    nlist = index.centroids.shape[0]
    old = np.asarray(index.doc_lists)
    fill = (old >= 0).sum(axis=1)  # stored entries (counts may be cap-trimmed)
    new_counts = np.bincount(assign, minlength=nlist)
    cap = int(max(old.shape[1], (fill + new_counts).max()))
    out = np.full((nlist, cap), -1, np.int32)
    out[:, : old.shape[1]] = old
    pos = fill.astype(np.int64)
    for i in np.argsort(assign, kind="stable"):
        c = assign[i]
        out[c, pos[c]] = tok_doc[i]
        pos[c] += 1
    counts = np.asarray(index.counts) + new_counts
    return TokenPruningIndex(index.centroids, jnp.asarray(out),
                             jnp.asarray(counts, jnp.int32))


@functools.partial(jax.jit, static_argnames=("nprobe", "k_prime", "m"))
def search_token_pruning(index: TokenPruningIndex, q, q_mask, *, nprobe: int,
                         k_prime: int, m: int):
    """q: (B, Tq, d) -> (approx_scores (B, k'), cand_ids (B, k'))."""
    B, Tq, d = q.shape
    nprobe = min(nprobe, index.centroids.shape[0])  # tiny-index clamp
    cs = jnp.einsum("bqd,cd->bqc", q, index.centroids)      # (B, Tq, nlist)
    probe_s, probe = jax.lax.top_k(cs, nprobe)              # (B, Tq, nprobe)

    def per_query(args):
        probe_q, score_q, mask_q = args  # (Tq, nprobe), (Tq, nprobe), (Tq,)

        def per_token(acc, xs):
            pr, sc, mk = xs  # (nprobe,), (nprobe,), ()
            docs = jnp.take(index.doc_lists, pr, axis=0)    # (nprobe, cap)
            val = jnp.broadcast_to(sc[:, None], docs.shape)
            val = jnp.where((docs >= 0) & mk, val, -jnp.inf)
            # per-token best centroid-proxy score for each doc
            tok_acc = jnp.full((m,), -jnp.inf).at[jnp.maximum(docs, 0).reshape(-1)].max(
                val.reshape(-1)
            )
            return acc + jnp.maximum(tok_acc, 0.0), None

        acc, _ = jax.lax.scan(
            per_token, jnp.zeros((m,)), (probe_q, score_q, mask_q)
        )
        return acc

    approx = jax.lax.map(per_query, (probe, probe_s, q_mask))   # (B, m)
    return jax.lax.top_k(approx, k_prime)
