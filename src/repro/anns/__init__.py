"""First-stage ANNS layer.

Functional modules (``bruteforce``, ``ivf``, ``muvera``, ``dessert``,
``token_pruning``) hold the algorithms; :mod:`repro.anns.base` defines the
``Retriever`` protocol they are adapted to in :mod:`repro.anns.backends`;
:mod:`repro.anns.registry` maps backend names to instances for
``LemurConfig.anns`` / ``--backend`` selection.
"""
from repro.anns.base import CorpusView, QueryBatch, Retriever
from repro.anns.bruteforce import mips_topk
from repro.anns.ivf import IVFIndex, build_ivf, extend_ivf, search_ivf
from repro.anns.kmeans import kmeans
from repro.anns.quantization import sq8_dequant, sq8_quant
from repro.anns.dessert import DessertConfig, build_dessert, extend_dessert, search_dessert
from repro.anns.muvera import MuveraConfig, doc_fde, query_fde
from repro.anns.token_pruning import (
    TokenPruningIndex,
    build_token_pruning,
    extend_token_pruning,
    search_token_pruning,
)
from repro.anns.registry import get_backend, list_backends

__all__ = [
    "Retriever",
    "CorpusView",
    "QueryBatch",
    "get_backend",
    "list_backends",
    "mips_topk",
    "IVFIndex",
    "build_ivf",
    "extend_ivf",
    "search_ivf",
    "kmeans",
    "sq8_quant",
    "sq8_dequant",
    "DessertConfig",
    "build_dessert",
    "extend_dessert",
    "search_dessert",
    "MuveraConfig",
    "doc_fde",
    "query_fde",
    "TokenPruningIndex",
    "build_token_pruning",
    "extend_token_pruning",
    "search_token_pruning",
]
