from repro.anns.bruteforce import mips_topk
from repro.anns.ivf import IVFIndex, build_ivf, search_ivf
from repro.anns.kmeans import kmeans
from repro.anns.quantization import sq8_dequant, sq8_quant
from repro.anns.dessert import DessertConfig, build_dessert, search_dessert
from repro.anns.muvera import MuveraConfig, doc_fde, query_fde
from repro.anns.token_pruning import TokenPruningIndex, build_token_pruning, search_token_pruning

__all__ = [
    "mips_topk",
    "IVFIndex",
    "build_ivf",
    "search_ivf",
    "kmeans",
    "sq8_quant",
    "sq8_dequant",
    "DessertConfig",
    "build_dessert",
    "search_dessert",
    "MuveraConfig",
    "doc_fde",
    "query_fde",
    "TokenPruningIndex",
    "build_token_pruning",
    "search_token_pruning",
]
