"""Exact (brute-force) MIPS with blocked streaming top-k.

The corpus is scanned in blocks; a running top-k is merged per block so peak
memory is O(B·(k + block)) — this is the "exact inference" arm of Fig. 3 and
the building block of the sharded retrieval step (one block per device,
all-gather of per-shard top-k, global merge)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k", "block"))
def mips_topk(q: jax.Array, corpus: jax.Array, k: int, block: int = 8192,
              *, valid: jax.Array | None = None):
    """q: (B, d); corpus: (m, d) -> (scores (B, k), ids (B, k)).

    ``valid`` (m,) bool (traced, optional) masks rows to ``-inf`` — how the
    paged store scans its full slot capacity while dead/unallocated slots
    can never win (their POSITION ids are kept, like the pad rows')."""
    B = q.shape[0]
    m, d = corpus.shape
    nb = -(-m // block)
    pad = nb * block - m
    cp = jnp.pad(corpus, ((0, pad), (0, 0))).reshape(nb, block, d)
    if valid is None:
        valid = jnp.ones((m,), bool)
    vp = jnp.pad(valid, (0, pad)).reshape(nb, block)

    init = (
        jnp.full((B, k), -jnp.inf, jnp.float32),
        jnp.full((B, k), -1, jnp.int32),
    )

    def step(carry, xs):
        top_s, top_i = carry
        cb, vb, off = xs
        s = (q @ cb.T).astype(jnp.float32)  # (B, block)
        ids = off + jnp.arange(block, dtype=jnp.int32)
        s = jnp.where(vb[None, :], s, -jnp.inf)
        bs, bi = jax.lax.top_k(s, min(k, block))
        cand_s = jnp.concatenate([top_s, bs], axis=1)
        cand_i = jnp.concatenate([top_i, jnp.take(ids, bi)], axis=1)
        ms, mi = jax.lax.top_k(cand_s, k)
        return (ms, jnp.take_along_axis(cand_i, mi, axis=1)), None

    offsets = (jnp.arange(nb) * block).astype(jnp.int32)
    (top_s, top_i), _ = jax.lax.scan(step, init, (cp, vp, offsets))
    return top_s, top_i
