"""Per-row symmetric int8 scalar quantization (Glass-style SQ)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sq8_quant(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (..., d) -> (int8 codes, fp32 per-row scales (...,))."""
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def sq8_dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def sq8_dot(q_query: jax.Array, codes: jax.Array, scale: jax.Array) -> jax.Array:
    """fp query (B, d) x int8 corpus (m, d) with per-row scales -> (B, m)."""
    s = q_query @ codes.astype(q_query.dtype).T
    return s * scale[None, :]
