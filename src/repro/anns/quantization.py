"""Corpus compression codecs.

Two tiers live here:

* **SQ8** (Glass-style per-row symmetric int8): 4x over fp32, exact scores
  w.r.t. the quantized representation.  The per-row scale is CLAMPED
  (``max(|x|, 1e-12)``) so an all-zero row — e.g. the latent row of a
  fully-masked pad doc — quantizes to all-zero codes with a tiny positive
  scale instead of dividing by zero and poisoning every downstream score
  with NaN.

* **Residual codec** (ColBERTv2-style, §PAPERS.md): each vector is stored
  as a k-means centroid id plus a 2-bit or 4-bit per-dimension quantized
  residual.  Bucket boundaries (``cuts``) and reconstruction values
  (``values``) are trained per dimension from residual quantiles, so the
  code allocation adapts to the residual distribution instead of assuming
  it uniform.  At 4 bits/dim + a 1-byte centroid id this is ~7-8x smaller
  than fp32 per token; combined with index-time token pooling
  (:func:`repro.core.pages.pool_tokens`) the corpus tier shrinks 10-30x.

Everything is pure jax: a trained :class:`ResidualCodec` is a pytree of
arrays, so a compressed store rides into jitted query functions as an
ARGUMENT (like ``PagedStore``) — retraining or swapping the codec never
retraces the serving graph.

Packed layout (the contract the in-kernel decoders in
``repro.kernels.gather_scan`` / ``query_fused`` unpack bit-exactly):
``per = 8 // bits`` codes per byte, dimension ``k = i*per + j`` lives in
byte ``i`` at bit offset ``bits*j`` (little-endian within the byte).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def sq8_quant(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (..., d) -> (int8 codes, fp32 per-row scales (...,)).

    The scale clamp makes all-zero rows (fully-masked pad docs) safe:
    codes 0, scale ~1e-14, dequant exactly 0 — never NaN."""
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def sq8_dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def sq8_dot(q_query: jax.Array, codes: jax.Array, scale: jax.Array) -> jax.Array:
    """fp query (B, d) x int8 corpus (m, d) with per-row scales -> (B, m)."""
    s = q_query @ codes.astype(q_query.dtype).T
    return s * scale[None, :]


# --------------------------------------------------------------------------
# residual codec (centroid id + quantized per-dim residual)
# --------------------------------------------------------------------------


class ResidualCodec(NamedTuple):
    """Trained residual-codec tables (a pytree of arrays — jit argument).

    centroids: (ncent, d) fp32 k-means centroids (the coarse code book)
    cuts:      (d, L-1) fp32 per-dim bucket boundaries, L = 2**bits levels
    values:    (d, L)   fp32 per-dim reconstruction value per bucket
    """
    centroids: jax.Array
    cuts: jax.Array
    values: jax.Array

    @property
    def ncent(self) -> int:
        return self.centroids.shape[0]

    @property
    def d(self) -> int:
        return self.centroids.shape[1]

    @property
    def nlevels(self) -> int:
        return self.values.shape[1]

    @property
    def bits(self) -> int:
        return int(self.values.shape[1]).bit_length() - 1

    @property
    def packed_width(self) -> int:
        """Bytes per packed vector: d * bits / 8."""
        return self.d * self.bits // 8


def codes_per_byte(bits: int) -> int:
    if bits not in (2, 4):
        raise ValueError(f"residual codec supports 2 or 4 bits, got {bits}")
    return 8 // bits


def pack_codes(idx: jax.Array, bits: int) -> jax.Array:
    """Bucket indices (..., d) int -> packed (..., d*bits//8) uint8.

    Little-endian within the byte: dim ``i*per + j`` sits at bit ``bits*j``
    of byte ``i`` (``per = 8 // bits``)."""
    per = codes_per_byte(bits)
    d = idx.shape[-1]
    if d % per:
        raise ValueError(f"d={d} not divisible by {per} codes/byte ({bits}-bit)")
    grp = idx.astype(jnp.uint8).reshape(*idx.shape[:-1], d // per, per)
    out = jnp.zeros(grp.shape[:-1], jnp.uint8)
    for j in range(per):
        out = out | (grp[..., j] << (bits * j))
    return out


def unpack_codes(packed: jax.Array, bits: int) -> jax.Array:
    """Packed (..., db) uint8 -> bucket indices (..., db * 8//bits) int32."""
    per = codes_per_byte(bits)
    mask = (1 << bits) - 1
    b = packed.astype(jnp.int32)
    parts = [(b >> (bits * j)) & mask for j in range(per)]
    return jnp.stack(parts, axis=-1).reshape(*packed.shape[:-1],
                                             packed.shape[-1] * per)


def train_residual_codec(key, x: jax.Array, *, bits: int = 4, ncent: int = 0,
                         iters: int = 8, sample: int = 65536) -> ResidualCodec:
    """Fit the codec on (a sample of) token vectors x: (n, d).

    k-means gives the coarse centroids; per-dimension residual quantiles
    give the bucket boundaries (at (l+1)/L) and reconstruction values (at
    the bucket midpoints (l+0.5)/L), so buckets equalize residual mass per
    dim (ColBERTv2 §2.2)."""
    from repro.anns.kmeans import kmeans

    L = 1 << bits
    codes_per_byte(bits)  # validate bits
    x = jnp.asarray(x, jnp.float32)
    n, d = x.shape
    if d % codes_per_byte(bits):
        raise ValueError(f"d={d} not packable at {bits} bits")
    if n > sample:
        pick = jax.random.choice(key, n, (sample,), replace=False)
        xs = x[pick]
    else:
        xs = x
    if ncent <= 0:
        # 1-byte centroid ids keep the compressed tier honest about bytes;
        # 256 coarse cells is plenty at bench scale (ColBERTv2 uses more
        # only because its corpora are ~1e9 tokens)
        ncent = 256
    ncent = int(min(ncent, xs.shape[0]))
    centroids, assign = kmeans(key, xs, ncent, iters=iters)
    r = xs - centroids[assign]
    qs_cut = jnp.arange(1, L, dtype=jnp.float32) / L
    qs_val = (jnp.arange(L, dtype=jnp.float32) + 0.5) / L
    cuts = jnp.quantile(r, qs_cut, axis=0).T       # (d, L-1)
    values = jnp.quantile(r, qs_val, axis=0).T     # (d, L)
    return ResidualCodec(centroids=centroids, cuts=cuts, values=values)


def residual_assign(codec: ResidualCodec, x: jax.Array) -> jax.Array:
    """Nearest centroid per vector: x (..., d) -> int32 (...,)."""
    half = 0.5 * jnp.sum(jnp.square(codec.centroids), axis=1)
    s = x @ codec.centroids.T - half
    return jnp.argmax(s, axis=-1).astype(jnp.int32)


def residual_encode(codec: ResidualCodec, x: jax.Array,
                    cent_ids: jax.Array | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """x (..., d) -> (cent_ids (...,) int32, packed (..., d*bits//8) uint8).

    Pass ``cent_ids`` to code residuals against EXTERNALLY assigned
    centroids (the IVF storage mode codes each vector against its own
    cluster centroid, making the id implicit in the list)."""
    x = jnp.asarray(x, jnp.float32)
    if cent_ids is None:
        cent_ids = residual_assign(codec, x)
    r = x - jnp.take(codec.centroids, cent_ids, axis=0)
    # bucket l <- cuts[l-1] < r <= cuts[l]; sum of (r > cut) over L-1 cuts
    idx = jnp.sum(r[..., None] > codec.cuts, axis=-1).astype(jnp.int32)
    return cent_ids, pack_codes(idx, codec.bits)


def residual_decode(codec: ResidualCodec, cent_ids: jax.Array,
                    packed: jax.Array) -> jax.Array:
    """Inverse of :func:`residual_encode`: -> fp32 (..., d).

    Pure jnp (take_along_axis) — jit-safe, and bit-identical to the
    in-kernel one-hot decoders (each sums exactly one fp32 term)."""
    idx = unpack_codes(packed, codec.bits)                 # (..., d)
    # values.T is (L, d); gather along the level axis per dimension
    flat = idx.reshape(-1, idx.shape[-1])
    res = jnp.take_along_axis(codec.values.T, flat, axis=0)
    res = res.reshape(idx.shape)
    return jnp.take(codec.centroids, cent_ids, axis=0) + res
