"""MUVERA baseline (Jayaram et al., 2024): fixed-dimensional encodings.

Data-oblivious single-vector reduction: R independent SimHash partitions of
R^d into 2^k_sim buckets; a document's FDE block is the per-bucket *centroid*
of its tokens (empty buckets backfilled with the doc centroid), a query's is
the per-bucket *sum*; blocks are concatenated and randomly projected to
``final_dim``.  E[<q_fde, d_fde>] approximates MaxSim (their Thm 2.1).

Paper-recommended config (§6.3): R=40, k_sim=6, d_proj=d, final 10240 dims.
This is the comparison target for claims C1/C2 — LEMUR's *learned* 1024-d
embeddings beat these 10240-d FDEs.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.common.config import ConfigBase


@dataclasses.dataclass(frozen=True)
class MuveraConfig(ConfigBase):
    r_reps: int = 40
    k_sim: int = 6
    d_proj: int = 0          # 0 => identity (d_proj = d), per paper
    final_dim: int = 10240
    seed: int = 7


def _partition_params(cfg: MuveraConfig, d: int):
    key = jax.random.PRNGKey(cfg.seed)
    kh, kp, kf = jax.random.split(key, 3)
    hyper = jax.random.normal(kh, (cfg.r_reps, cfg.k_sim, d))
    d_proj = cfg.d_proj or d
    if cfg.d_proj:
        proj = jax.random.choice(kp, jnp.asarray([-1.0, 1.0]), (cfg.r_reps, d, d_proj))
        proj = proj / jnp.sqrt(d_proj)
    else:
        proj = None
    inner = cfg.r_reps * (2**cfg.k_sim) * d_proj
    final = jax.random.choice(kf, jnp.asarray([-1.0, 1.0]), (inner, cfg.final_dim))
    final = final / jnp.sqrt(cfg.final_dim)
    return hyper, proj, final


def _bucket_ids(tokens, hyper):
    """tokens: (..., T, d); hyper: (R, k, d) -> (..., R, T) int32 in [0, 2^k)."""
    bits = jnp.einsum("...td,rkd->...rtk", tokens, hyper) > 0
    weights = 2 ** jnp.arange(hyper.shape[1])
    return jnp.sum(bits * weights, axis=-1).astype(jnp.int32)


def _fde(tokens, mask, cfg: MuveraConfig, *, is_query: bool):
    """tokens: (B, T, d); mask: (B, T) -> (B, final_dim)."""
    d = tokens.shape[-1]
    hyper, proj, final = _partition_params(cfg, d)
    nb = 2**cfg.k_sim
    b = _bucket_ids(tokens, hyper)  # (B, R, T)
    onehot = jax.nn.one_hot(b, nb, dtype=tokens.dtype)  # (B, R, T, nb)
    onehot = onehot * mask[:, None, :, None]
    t = tokens
    if proj is not None:
        t = jnp.einsum("btd,rde->brte", tokens, proj)  # (B, R, T, d_proj)
    else:
        t = jnp.broadcast_to(tokens[:, None], (tokens.shape[0], cfg.r_reps, *tokens.shape[1:]))
    sums = jnp.einsum("brtn,brte->brne", onehot, t)     # (B, R, nb, dp)
    if is_query:
        block = sums
    else:
        cnt = jnp.sum(onehot, axis=2)                   # (B, R, nb)
        centroid = sums / jnp.maximum(cnt[..., None], 1.0)
        # empty-bucket backfill: document centroid (approximation of MUVERA's
        # nearest-token fill; noted in DESIGN.md §3)
        doc_cent = jnp.sum(t * mask[:, None, :, None], axis=2) / jnp.maximum(
            jnp.sum(mask, axis=1)[:, None, None], 1.0
        )
        block = jnp.where(cnt[..., None] > 0, centroid, doc_cent[:, :, None, :])
    flat = block.reshape(block.shape[0], -1)
    return flat @ final


def doc_fde(tokens, mask, cfg: MuveraConfig, *, block: int = 512):
    outs = []
    for lo in range(0, tokens.shape[0], block):
        outs.append(_fde(tokens[lo : lo + block], mask[lo : lo + block], cfg, is_query=False))
    return jnp.concatenate(outs, axis=0)


def query_fde(tokens, mask, cfg: MuveraConfig):
    return _fde(tokens, mask, cfg, is_query=True)
