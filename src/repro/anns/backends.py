"""Built-in Retriever implementations — the five first-stage backends.

Each class adapts one functional ANNS module (`bruteforce`, `ivf`,
`dessert`, `muvera`, `token_pruning`) to the :class:`repro.anns.base.Retriever`
protocol and registers itself by name.  The functional modules stay usable
directly (tests/benchmarks call them); these wrappers are what
``core.index.LemurIndex`` dispatches through.

Representation per backend:

====================  ==========  =============================================
name                  indexes     query side
====================  ==========  =============================================
``bruteforce``        latent W    pooled Ψ(X) — exact latent MIPS (Fig. 3)
``ivf``               latent W    pooled Ψ(X) — TPU-native IVF (+SQ8 kernel)
``muvera``            tokens      FDE of the query tokens (Jayaram et al.)
``dessert``           tokens      LSH sketches of the query tokens (Engels)
``token_pruning``     tokens      PLAID-style centroid interaction
====================  ==========  =============================================

``cfg`` is duck-typed: any object exposing the knobs below works (and
``None`` selects every default), so backends never import the core layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.anns import dessert as _dessert
from repro.anns import ivf as _ivf
from repro.anns import muvera as _muvera
from repro.anns import token_pruning as _tp
from repro.anns.base import CorpusView, QueryBatch, pad_topk
from repro.anns.bruteforce import mips_topk
from repro.anns.registry import register


def _cfg(cfg, name, default):
    v = getattr(cfg, name, default) if cfg is not None else default
    return default if v is None else v


@register
class BruteforceRetriever:
    """Exact latent MIPS — the recall ceiling of the first stage."""

    name = "bruteforce"
    representation = "latent"

    def build(self, key, corpus: CorpusView, cfg=None):
        if corpus.latent is None:
            raise ValueError("bruteforce backend needs latent vectors "
                             "(CorpusView.latent is None)")
        return {"W": jnp.asarray(corpus.latent)}

    def search(self, state, query: QueryBatch, k: int, **_):
        return mips_topk(query.latent, state["W"], k)

    def add(self, state, corpus: CorpusView):
        return {"W": jnp.concatenate([state["W"], jnp.asarray(corpus.latent)], 0)}

    def defaults(self, cfg) -> dict:
        return {}


@register
class IVFRetriever:
    """IVF over the latent corpus (SQ8 scan via ``kernels.ops.mips_sq8``)."""

    name = "ivf"
    representation = "latent"

    def build(self, key, corpus: CorpusView, cfg=None):
        if corpus.latent is None:
            raise ValueError("ivf backend needs latent vectors")
        return _ivf.build_ivf(key, jnp.asarray(corpus.latent),
                              int(_cfg(cfg, "ivf_nlist", 0)),
                              sq8=bool(_cfg(cfg, "sq8", False)))

    def search(self, state, query: QueryBatch, k: int, *, nprobe=None, **_):
        nprobe = min(int(nprobe or min(32, state.nlist)), state.nlist)
        return _ivf.search_ivf(state, query.latent, nprobe, k)

    def add(self, state, corpus: CorpusView):
        return _ivf.extend_ivf(state, jnp.asarray(corpus.latent))

    def defaults(self, cfg) -> dict:
        return {"nprobe": _cfg(cfg, "ivf_nprobe", None)}


@register
class MuveraRetriever:
    """Fixed-dimensional encodings + exact MIPS over the FDEs."""

    name = "muvera"
    representation = "tokens"

    def build(self, key, corpus: CorpusView, cfg=None):
        mcfg = _muvera.MuveraConfig(
            r_reps=int(_cfg(cfg, "muvera_r_reps", 20)),
            k_sim=int(_cfg(cfg, "muvera_k_sim", 5)),
            final_dim=int(_cfg(cfg, "muvera_final_dim", 1280)),
        )
        dfde = _muvera.doc_fde(corpus.doc_tokens, corpus.doc_mask, mcfg)
        return MuveraState(dfde, mcfg)

    def search(self, state, query: QueryBatch, k: int, **_):
        qfde = _muvera.query_fde(query.tokens, query.mask, state.mcfg)
        return mips_topk(qfde, state.dfde, k)

    def add(self, state, corpus: CorpusView):
        new = _muvera.doc_fde(corpus.doc_tokens, corpus.doc_mask, state.mcfg)
        return MuveraState(jnp.concatenate([state.dfde, new], 0), state.mcfg)

    def defaults(self, cfg) -> dict:
        return {}


@register
class DessertRetriever:
    """LSH set-sketch scoring (DESSERT) straight off the token matrices."""

    name = "dessert"
    representation = "tokens"

    def build(self, key, corpus: CorpusView, cfg=None):
        dcfg = _dessert.DessertConfig(
            n_tables=int(_cfg(cfg, "dessert_tables", 32)),
            n_bits=int(_cfg(cfg, "dessert_bits", 5)),
        )
        return _dessert.build_dessert(corpus.doc_tokens, corpus.doc_mask, dcfg)

    def search(self, state, query: QueryBatch, k: int, **_):
        m = state.occupancy.shape[0]
        s, ids = _dessert.search_dessert(state, query.tokens, query.mask,
                                         k_prime=min(k, m))
        return pad_topk(s, ids, k)

    def add(self, state, corpus: CorpusView):
        return _dessert.extend_dessert(state, corpus.doc_tokens, corpus.doc_mask)

    def defaults(self, cfg) -> dict:
        return {}


@register
class TokenPruningRetriever:
    """PLAID-style centroid-interaction pruning over corpus tokens."""

    name = "token_pruning"
    representation = "tokens"

    def build(self, key, corpus: CorpusView, cfg=None):
        if key is None:
            key = jax.random.PRNGKey(0)
        idx = _tp.build_token_pruning(key, corpus.doc_tokens, corpus.doc_mask,
                                      nlist=int(_cfg(cfg, "tp_nlist", 0)))
        return TokenPruningState(idx, corpus.m)

    def search(self, state, query: QueryBatch, k: int, *, nprobe=None, **_):
        nlist = state.index.centroids.shape[0]
        nprobe = min(int(nprobe or 8), nlist)
        s, ids = _tp.search_token_pruning(state.index, query.tokens, query.mask,
                                          nprobe=nprobe,
                                          k_prime=min(k, state.m), m=state.m)
        return pad_topk(s, ids, k)

    def add(self, state, corpus: CorpusView):
        idx = _tp.extend_token_pruning(state.index, corpus.doc_tokens,
                                       corpus.doc_mask, m_old=state.m)
        return TokenPruningState(idx, state.m + corpus.m)

    def defaults(self, cfg) -> dict:
        return {"nprobe": _cfg(cfg, "tp_nprobe", None)}


# --------------------------------------------------------------------------
# Opaque state pytrees whose static parts (config, corpus size) must ride
# as aux data so the state can cross jit boundaries without retracing.
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class MuveraState:
    """(m, final_dim) doc FDEs + the (static) MuveraConfig that made them."""

    def __init__(self, dfde, mcfg):
        self.dfde = dfde
        self.mcfg = mcfg

    def tree_flatten(self):
        return (self.dfde,), self.mcfg

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


@jax.tree_util.register_pytree_node_class
class TokenPruningState:
    """TokenPruningIndex + the (static) corpus size the scatter targets."""

    def __init__(self, index, m: int):
        self.index = index
        self.m = int(m)

    def tree_flatten(self):
        return (self.index,), self.m

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)
