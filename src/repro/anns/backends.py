"""Built-in Retriever implementations — the five first-stage backends.

Each class adapts one functional ANNS module (`bruteforce`, `ivf`,
`dessert`, `muvera`, `token_pruning`) to the :class:`repro.anns.base.Retriever`
protocol and registers itself by name.  The functional modules stay usable
directly (tests/benchmarks call them); these wrappers are what
``repro.retriever.LemurRetriever`` dispatches through.

Representation per backend:

====================  ==========  =============================================
name                  indexes     query side
====================  ==========  =============================================
``bruteforce``        latent W    pooled Ψ(X) — exact latent MIPS (Fig. 3)
``ivf``               latent W    pooled Ψ(X) — TPU-native IVF (+SQ8 kernel)
``muvera``            tokens      FDE of the query tokens (Jayaram et al.)
``dessert``           tokens      LSH sketches of the query tokens (Engels)
``token_pruning``     tokens      PLAID-style centroid interaction
====================  ==========  =============================================

``build`` takes the backend's own config namespace (its ``config_cls``, a
field of ``LemurConfig``: ``cfg.ivf``, ``cfg.muvera``, …); ``None`` selects
every default.  ``search`` takes the backend's ``params_cls`` — the typed
replacement for the v0 ``**overrides`` — and ``pack_state``/``unpack_state``
give ``LemurRetriever.save()/load()`` a bit-exact persistence seam without
the facade learning any state type.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.anns import dessert as _dessert
from repro.anns import ivf as _ivf
from repro.anns import muvera as _muvera
from repro.anns import token_pruning as _tp
from repro.anns.base import CorpusView, QueryBatch, pad_topk
from repro.anns.bruteforce import mips_topk
from repro.anns.params import (
    BruteforceBackendConfig,
    DessertBackendConfig,
    IVFBackendConfig,
    IVFSearchParams,
    MuveraBackendConfig,
    NoSearchParams,
    TokenPruningBackendConfig,
    TokenPruningSearchParams,
)
from repro.anns.registry import register


@register
class BruteforceRetriever:
    """Exact latent MIPS — the recall ceiling of the first stage."""

    name = "bruteforce"
    representation = "latent"
    config_cls = BruteforceBackendConfig
    params_cls = NoSearchParams

    def build(self, key, corpus: CorpusView, cfg=None):
        if corpus.latent is None:
            raise ValueError("bruteforce backend needs latent vectors "
                             "(CorpusView.latent is None)")
        return {"W": jnp.asarray(corpus.latent)}

    def search(self, state, query: QueryBatch, k: int, params=None):
        return mips_topk(query.latent, state["W"], k)

    def add(self, state, corpus: CorpusView):
        return {"W": jnp.concatenate([state["W"], jnp.asarray(corpus.latent)], 0)}

    def default_params(self, cfg) -> NoSearchParams:
        return NoSearchParams()

    def pack_state(self, state):
        return {"W": state["W"]}, {}

    def unpack_state(self, arrays, meta):
        return {"W": arrays["W"]}


@register
class IVFRetriever:
    """IVF over the latent corpus (SQ8 scan via ``kernels.ops.mips_sq8``)."""

    name = "ivf"
    representation = "latent"
    config_cls = IVFBackendConfig
    params_cls = IVFSearchParams

    def build(self, key, corpus: CorpusView, cfg: IVFBackendConfig | None = None):
        if corpus.latent is None:
            raise ValueError("ivf backend needs latent vectors")
        cfg = cfg or IVFBackendConfig()
        return _ivf.build_ivf(key, jnp.asarray(corpus.latent),
                              int(cfg.nlist), sq8=bool(cfg.sq8),
                              residual_bits=int(getattr(cfg, "residual_bits",
                                                        0) or 0))

    def search(self, state, query: QueryBatch, k: int,
               params: IVFSearchParams | None = None):
        nprobe = params.nprobe if params is not None else None
        nprobe = min(int(nprobe or min(32, state.nlist)), state.nlist)
        # unresolved params default to the fused path (like nprobe's 32
        # fallback above, cfg routing happens in SearchParams.resolve —
        # default_params carries cfg.ivf.use_fused_gather through it)
        fused = params.use_fused_gather if params is not None else None
        fused = True if fused is None else bool(fused)
        return _ivf.search_ivf(state, query.latent, nprobe, k,
                               use_fused_gather=fused)

    def add(self, state, corpus: CorpusView):
        return _ivf.extend_ivf(state, jnp.asarray(corpus.latent))

    def default_params(self, cfg) -> IVFSearchParams:
        if cfg is None:
            return IVFSearchParams()
        return IVFSearchParams(nprobe=cfg.nprobe,
                               use_fused_gather=cfg.use_fused_gather,
                               use_one_launch=cfg.use_one_launch)

    def pack_state(self, state: _ivf.IVFIndex):
        arrays = {"centroids": state.centroids, "ids": state.ids,
                  "vecs": state.vecs, "counts": state.counts}
        if state.scales is not None:
            arrays["scales"] = state.scales
        if state.mean is not None:
            arrays["mean"] = state.mean
        if state.rq_cuts is not None:
            arrays["rq_cuts"] = state.rq_cuts
        if state.rq_values is not None:
            arrays["rq_values"] = state.rq_values
        return arrays, {}

    def unpack_state(self, arrays, meta):
        return _ivf.IVFIndex(centroids=arrays["centroids"], ids=arrays["ids"],
                             vecs=arrays["vecs"], scales=arrays.get("scales"),
                             counts=arrays["counts"], mean=arrays.get("mean"),
                             rq_cuts=arrays.get("rq_cuts"),
                             rq_values=arrays.get("rq_values"))


@register
class MuveraRetriever:
    """Fixed-dimensional encodings + exact MIPS over the FDEs."""

    name = "muvera"
    representation = "tokens"
    config_cls = MuveraBackendConfig
    params_cls = NoSearchParams

    def build(self, key, corpus: CorpusView, cfg: MuveraBackendConfig | None = None):
        cfg = cfg or MuveraBackendConfig()
        mcfg = _muvera.MuveraConfig(r_reps=int(cfg.r_reps), k_sim=int(cfg.k_sim),
                                    final_dim=int(cfg.final_dim))
        dfde = _muvera.doc_fde(corpus.doc_tokens, corpus.doc_mask, mcfg)
        return MuveraState(dfde, mcfg)

    def search(self, state, query: QueryBatch, k: int, params=None):
        qfde = _muvera.query_fde(query.tokens, query.mask, state.mcfg)
        return mips_topk(qfde, state.dfde, k)

    def add(self, state, corpus: CorpusView):
        new = _muvera.doc_fde(corpus.doc_tokens, corpus.doc_mask, state.mcfg)
        return MuveraState(jnp.concatenate([state.dfde, new], 0), state.mcfg)

    def default_params(self, cfg) -> NoSearchParams:
        return NoSearchParams()

    def pack_state(self, state: "MuveraState"):
        return {"dfde": state.dfde}, {"mcfg": state.mcfg.to_dict()}

    def unpack_state(self, arrays, meta):
        return MuveraState(arrays["dfde"],
                           _muvera.MuveraConfig.from_dict(meta["mcfg"]))


@register
class DessertRetriever:
    """LSH set-sketch scoring (DESSERT) straight off the token matrices."""

    name = "dessert"
    representation = "tokens"
    config_cls = DessertBackendConfig
    params_cls = NoSearchParams

    def build(self, key, corpus: CorpusView, cfg: DessertBackendConfig | None = None):
        cfg = cfg or DessertBackendConfig()
        dcfg = _dessert.DessertConfig(n_tables=int(cfg.tables),
                                      n_bits=int(cfg.bits))
        return _dessert.build_dessert(corpus.doc_tokens, corpus.doc_mask, dcfg)

    def search(self, state, query: QueryBatch, k: int, params=None):
        m = state.occupancy.shape[0]
        s, ids = _dessert.search_dessert(state, query.tokens, query.mask,
                                         k_prime=min(k, m))
        return pad_topk(s, ids, k)

    def add(self, state, corpus: CorpusView):
        return _dessert.extend_dessert(state, corpus.doc_tokens, corpus.doc_mask)

    def default_params(self, cfg) -> NoSearchParams:
        return NoSearchParams()

    def pack_state(self, state: _dessert.DessertIndex):
        return {"occupancy": state.occupancy, "hyper": state.hyper}, {}

    def unpack_state(self, arrays, meta):
        return _dessert.DessertIndex(occupancy=arrays["occupancy"],
                                     hyper=arrays["hyper"])


@register
class TokenPruningRetriever:
    """PLAID-style centroid-interaction pruning over corpus tokens."""

    name = "token_pruning"
    representation = "tokens"
    config_cls = TokenPruningBackendConfig
    params_cls = TokenPruningSearchParams

    def build(self, key, corpus: CorpusView,
              cfg: TokenPruningBackendConfig | None = None):
        if key is None:
            key = jax.random.PRNGKey(0)
        cfg = cfg or TokenPruningBackendConfig()
        idx = _tp.build_token_pruning(key, corpus.doc_tokens, corpus.doc_mask,
                                      nlist=int(cfg.nlist))
        return TokenPruningState(idx, corpus.m)

    def search(self, state, query: QueryBatch, k: int,
               params: TokenPruningSearchParams | None = None):
        nprobe = params.nprobe if params is not None else None
        nlist = state.index.centroids.shape[0]
        nprobe = min(int(nprobe or 8), nlist)
        s, ids = _tp.search_token_pruning(state.index, query.tokens, query.mask,
                                          nprobe=nprobe,
                                          k_prime=min(k, state.m), m=state.m)
        return pad_topk(s, ids, k)

    def add(self, state, corpus: CorpusView):
        idx = _tp.extend_token_pruning(state.index, corpus.doc_tokens,
                                       corpus.doc_mask, m_old=state.m)
        return TokenPruningState(idx, state.m + corpus.m)

    def default_params(self, cfg) -> TokenPruningSearchParams:
        return TokenPruningSearchParams(
            nprobe=cfg.nprobe if cfg is not None else None)

    def pack_state(self, state: "TokenPruningState"):
        arrays = {"centroids": state.index.centroids,
                  "doc_lists": state.index.doc_lists,
                  "counts": state.index.counts}
        return arrays, {"m": int(state.m)}

    def unpack_state(self, arrays, meta):
        idx = _tp.TokenPruningIndex(centroids=arrays["centroids"],
                                    doc_lists=arrays["doc_lists"],
                                    counts=arrays["counts"])
        return TokenPruningState(idx, int(meta["m"]))


# --------------------------------------------------------------------------
# Opaque state pytrees whose static parts (config, corpus size) must ride
# as aux data so the state can cross jit boundaries without retracing.
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class MuveraState:
    """(m, final_dim) doc FDEs + the (static) MuveraConfig that made them."""

    def __init__(self, dfde, mcfg):
        self.dfde = dfde
        self.mcfg = mcfg

    def tree_flatten(self):
        return (self.dfde,), self.mcfg

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


@jax.tree_util.register_pytree_node_class
class TokenPruningState:
    """TokenPruningIndex + the (static) corpus size the scatter targets."""

    def __init__(self, index, m: int):
        self.index = index
        self.m = int(m)

    def tree_flatten(self):
        return (self.index,), self.m

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)
