"""Mini-batch-free Lloyd's k-means in JAX (TPU-friendly: pure matmuls)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k", "iters", "block"))
def kmeans(key, x: jax.Array, k: int, iters: int = 10, block: int = 65536):
    """x: (n, d) -> (centroids (k, d), assignment (n,)).

    Assignment by max inner product of mean-centered... no — standard
    Euclidean: argmin ||x - c||² = argmax (x·c - ||c||²/2), computed as one
    matmul per iteration (blocked over n).
    """
    n, d = x.shape
    init_idx = jax.random.choice(key, n, (k,), replace=False)
    cent = x[init_idx]

    def assign(cent):
        half = 0.5 * jnp.sum(jnp.square(cent), axis=1)

        def blk(xb):
            s = xb @ cent.T - half[None, :]
            return jnp.argmax(s, axis=1)

        nb = -(-n // block)
        pad = nb * block - n
        xp = jnp.pad(x, ((0, pad), (0, 0))).reshape(nb, block, d)
        a = jax.lax.map(blk, xp).reshape(-1)[:n]
        return a

    def step(cent, _):
        a = assign(cent)
        sums = jnp.zeros((k, d), x.dtype).at[a].add(x)
        counts = jnp.zeros((k,), x.dtype).at[a].add(1.0)
        new = sums / jnp.maximum(counts[:, None], 1.0)
        # keep empty clusters where they were
        new = jnp.where(counts[:, None] > 0, new, cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    return cent, assign(cent)
