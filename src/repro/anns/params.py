"""Per-backend configuration namespaces and typed search parameters.

Retriever API v1 splits every backend's knobs into two frozen dataclasses:

* a **build-time config** (``*BackendConfig``) — what the index looks like
  (nlist, sq8, sketch sizes …).  ``LemurConfig`` holds one instance per
  backend as a nested namespace (``cfg.ivf.nprobe`` instead of the old flat
  ``cfg.ivf_nprobe``), and the registry maps backend name -> config class so
  ``cfg.backend_config("ivf")`` and ``--set ivf.nprobe=64`` resolve
  generically.

* **query-time params** (``*SearchParams``) — per-call knobs that used to
  travel as untyped ``**overrides`` through ``anns/base.py``.  They ride
  inside :class:`repro.retriever.SearchParams` as its typed ``backend``
  field and are passed jit-static, so one compiled query fn exists per
  (backend, params, batch-shape).

This module stays import-light (dataclasses only, no jax) because
``core.config`` imports it at module scope.
"""
from __future__ import annotations

import dataclasses

from repro.common.config import ConfigBase


@dataclasses.dataclass(frozen=True)
class BackendConfig(ConfigBase):
    """Marker base for per-backend build-time config namespaces."""


@dataclasses.dataclass(frozen=True)
class BackendSearchParams(ConfigBase):
    """Marker base for per-backend query-time knobs (jit-static)."""


# --------------------------------------------------------------------------
# build-time namespaces (defaults == the old flat LemurConfig knobs)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BruteforceBackendConfig(BackendConfig):
    """Exact latent MIPS has no build-time knobs."""


@dataclasses.dataclass(frozen=True)
class IVFBackendConfig(BackendConfig):
    nlist: int = 0           # 0 => 4*sqrt(m) rounded down to pow2 (paper's rule)
    nprobe: int = 32         # default query-time probe count
    sq8: bool = True         # scalar-quantize the latent corpus (Glass-style)
    residual_bits: int = 0   # 2/4 => residual-codec list storage (packed
                             # codes vs the own-cluster centroid; supersedes
                             # sq8); 0 => off
    use_fused_gather: bool = True  # gather-at-source probe scan (kernels.
                                   # gather_scan); False = legacy HBM gather
    use_one_launch: bool = False   # fuse ψ-pool + probe scan + top-k' into
                                   # ONE launch (kernels.query_fused); the
                                   # legacy 3-launch path stays the default


@dataclasses.dataclass(frozen=True)
class ResidualConfig(ConfigBase):
    """The compressed TOKEN-corpus tier (``cfg.residual``) — a third storage
    tier next to fp32 and SQ8: ColBERTv2-style centroid id + packed 2/4-bit
    per-dim residual per token, plus optional index-time constant-space
    token pooling.  Build-time: changing any field rebuilds the store."""

    enabled: bool = False    # store doc tokens in the residual codec tier
    bits: int = 4            # residual bits/dim (2 or 4)
    ncent: int = 256         # coarse token centroids (1-byte ids at <=256)
    token_budget: int = 0    # constant-space pooling: max tokens/doc
                             # (hierarchical cluster-pooling at index/add
                             # time; 0 = keep all tokens)
    kmeans_iters: int = 8    # codec k-means iterations
    train_sample: int = 65536  # token sample for codec training


@dataclasses.dataclass(frozen=True)
class MuveraBackendConfig(BackendConfig):
    r_reps: int = 20         # MUVERA R
    k_sim: int = 5           # MUVERA k_sim
    final_dim: int = 1280


@dataclasses.dataclass(frozen=True)
class DessertBackendConfig(BackendConfig):
    tables: int = 32         # DESSERT L
    bits: int = 5            # DESSERT C -> 2^C buckets


@dataclasses.dataclass(frozen=True)
class TokenPruningBackendConfig(BackendConfig):
    nlist: int = 0           # 0 => PLAID 16*sqrt(n) rule
    nprobe: int = 8


# --------------------------------------------------------------------------
# query-time knobs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NoSearchParams(BackendSearchParams):
    """Backends whose only query-time knob is the shared k' budget."""


@dataclasses.dataclass(frozen=True)
class IVFSearchParams(BackendSearchParams):
    nprobe: int | None = None    # None => cfg.ivf.nprobe
    use_fused_gather: bool | None = None  # None => cfg.ivf.use_fused_gather
    use_one_launch: bool | None = None    # None => cfg.ivf.use_one_launch


@dataclasses.dataclass(frozen=True)
class TokenPruningSearchParams(BackendSearchParams):
    nprobe: int | None = None    # None => cfg.token_pruning.nprobe


__all__ = [
    "BackendConfig",
    "BackendSearchParams",
    "ResidualConfig",
    "BruteforceBackendConfig",
    "IVFBackendConfig",
    "MuveraBackendConfig",
    "DessertBackendConfig",
    "TokenPruningBackendConfig",
    "NoSearchParams",
    "IVFSearchParams",
    "TokenPruningSearchParams",
]
