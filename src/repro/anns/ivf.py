"""IVF index — the TPU-native replacement for Glass/HNSW (DESIGN.md §3).

Build: k-means coarse quantizer over the latent corpus; vectors are packed
into fixed-capacity padded cluster lists (capacity = max cluster size) with
optional SQ8 storage.  Search: one (B, nlist) centroid matmul, top-`nprobe`
clusters, then either the gather-at-source probe scan (default —
``kernels.gather_scan`` DMAs each probed cluster tile straight into VMEM on
TPU) or the legacy gathered block scan, and a masked top-k'.  Everything is
dense matmul + gather — no pointer chasing — so it maps onto MXU tiles and
shards (each device holds a slice of the cluster lists).

The recall/latency knob is ``nprobe`` (HNSW's ef_search analogue, §6.2).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.anns.base import pad_topk
from repro.anns.kmeans import kmeans
from repro.anns.quantization import sq8_dequant, sq8_quant
from repro.kernels import ops


class IVFIndex(NamedTuple):
    centroids: jax.Array   # (nlist, d)
    ids: jax.Array         # (nlist, cap) int32, -1 padded
    vecs: jax.Array        # (nlist, cap, d) fp32  OR int8 codes when sq8
    scales: jax.Array | None  # (nlist, cap) fp32 when sq8 else None
    counts: jax.Array      # (nlist,) int32
    mean: jax.Array | None = None  # (d,) corpus mean (centered MIPS: ranking
                                   # by q.(w-mean) == ranking by q.w)

    @property
    def nlist(self) -> int:
        return self.centroids.shape[0]

    @property
    def capacity(self) -> int:
        return self.ids.shape[1]


def default_nlist(m: int) -> int:
    """Paper's clustering rule (§6.3): 16·sqrt(n) rounded down to pow2 is for
    token-level indexes; for the (much smaller) latent corpus we use
    4·sqrt(m) rounded to pow2, floor 16."""
    raw = 4 * int(np.sqrt(max(m, 1)))
    return max(16, 1 << (raw.bit_length() - 1))


def build_ivf(key, vectors: jax.Array, nlist: int = 0, *, sq8: bool = False,
              kmeans_iters: int = 10, train_sample: int = 131072,
              center: bool = True) -> IVFIndex:
    """``center=True`` subtracts the corpus mean before clustering/scan:
    learned LEMUR W rows carry a large shared component (globally
    standardized OLS targets) that otherwise dominates the coarse quantizer;
    MIPS ranking is invariant to it (q·mean is constant per query)."""
    m, d = vectors.shape
    mean = None
    if center:
        mean = jnp.mean(vectors, axis=0)
        vectors = vectors - mean[None, :]
    nlist = nlist or default_nlist(m)
    ktrain, kassign = jax.random.split(jax.random.PRNGKey(0) if key is None else key)
    sample = vectors
    if m > train_sample:
        idx = jax.random.choice(ktrain, m, (train_sample,), replace=False)
        sample = vectors[idx]
    centroids, _ = kmeans(ktrain, sample, nlist, iters=kmeans_iters)
    assign = assign_clusters(vectors, centroids)  # full corpus
    ids, vecs, scales, counts = _pack_lists(vectors, np.asarray(assign), nlist,
                                            sq8=sq8)
    return IVFIndex(centroids, ids, vecs, scales, counts, mean)


def assign_clusters(vectors: jax.Array, centroids: jax.Array) -> jax.Array:
    """Nearest-centroid assignment (MIPS form with the -||c||²/2 correction)."""
    half = 0.5 * jnp.sum(jnp.square(centroids), axis=1)
    return jnp.argmax(vectors @ centroids.T - half[None, :], axis=1)


def _pack_lists(vectors, assign: np.ndarray, nlist: int, *, sq8: bool,
                cap_floor: int = 1):
    """Pack vectors into fixed-capacity padded cluster lists (host-side).

    ``cap`` is bucketed to a power of two (and never below ``cap_floor`` —
    :func:`extend_ivf` passes the old capacity so adds can only keep or
    double it): the list shapes are jit-static, so shape-stable adds leave
    compiled query fns alive instead of retracing per add."""
    from repro.core.pages import next_pow2

    counts = np.bincount(assign, minlength=nlist)
    cap = max(next_pow2(int(max(1, counts.max()))), int(cap_floor))
    ids = np.full((nlist, cap), -1, np.int32)
    order = np.argsort(assign, kind="stable")
    pos = np.zeros(nlist, np.int64)
    for i in order:
        c = assign[i]
        ids[c, pos[c]] = i
        pos[c] += 1
    ids = jnp.asarray(ids)
    safe = jnp.maximum(ids, 0)
    vecs = jnp.take(jnp.asarray(vectors), safe, axis=0)  # (nlist, cap, d)
    vecs = vecs * (ids >= 0)[..., None]
    scales = None
    if sq8:
        vecs, scales = sq8_quant(vecs)
    return ids, vecs, scales, jnp.asarray(counts, jnp.int32)


def extend_ivf(index: IVFIndex, new_vectors: jax.Array) -> IVFIndex:
    """Incremental add: assign new vectors to the FROZEN coarse quantizer and
    re-pack the padded lists (host-side, like build).  New docs get ids
    continuing the existing numbering; centroids/mean are not re-fit, so
    recall degrades only as far as the data drifts from the original
    clustering."""
    nlist, d = index.centroids.shape
    newv = jnp.asarray(new_vectors)
    if index.mean is not None:
        newv = newv - index.mean[None, :]
    assign_new = np.asarray(assign_clusters(newv, index.centroids))

    ids = np.asarray(index.ids)
    valid = ids >= 0
    m_old = int(valid.sum())
    m_new = newv.shape[0]
    sq8 = index.scales is not None
    # reconstruct the (centered) stored vectors; SQ8 requant is exact because
    # each row's max code is 127, so the recomputed scale equals the old one
    full = sq8_dequant(index.vecs, index.scales) if sq8 else index.vecs
    full = np.asarray(full)
    all_vecs = np.zeros((m_old + m_new, d), np.float32)
    all_assign = np.zeros(m_old + m_new, np.int64)
    cluster_of = np.broadcast_to(np.arange(nlist)[:, None], ids.shape)
    all_vecs[ids[valid]] = full[valid]
    all_assign[ids[valid]] = cluster_of[valid]
    all_vecs[m_old:] = np.asarray(newv)
    all_assign[m_old:] = assign_new
    ids2, vecs2, scales2, counts2 = _pack_lists(all_vecs, all_assign, nlist,
                                                sq8=sq8,
                                                cap_floor=index.capacity)
    return IVFIndex(index.centroids, ids2, vecs2, scales2, counts2, index.mean)


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "use_fused_gather"))
def search_ivf(index: IVFIndex, q: jax.Array, nprobe: int, k: int,
               use_fused_gather: bool = False):
    """q: (B, d) -> (scores (B, k), ids (B, k)).

    ``use_fused_gather=True`` scores the probed cluster lists through the
    gather-at-source kernel path (``ops.fused_ivf_scan``: the scalar-prefetch
    Pallas scan on TPU, its gather-then-score oracle elsewhere) — only the
    ``(B, nprobe, cap)`` id strip is ever gathered in HBM.  ``False`` keeps
    the legacy materialize-then-score path benchmarkable.
    """
    B, d = q.shape
    cs = q @ index.centroids.T                     # (B, nlist)
    _, probe = jax.lax.top_k(cs, nprobe)           # (B, nprobe)
    ids = jnp.take(index.ids, probe, axis=0)       # (B, nprobe, cap)
    if use_fused_gather:
        # masked -inf inside the scan (same pad convention as below)
        s = ops.fused_ivf_scan(q, probe, index.ids, index.vecs, index.scales)
    else:
        vecs = jnp.take(index.vecs, probe, axis=0)  # (B, nprobe, cap, d)
        cap = vecs.shape[2]
        if index.scales is not None:
            # batched SQ8 scan: all B queries' gathered lists in ONE call
            # (the old path vmapped B one-row mips_sq8 launches — 1/128 MXU
            # tile utilization at block_q=128)
            sc = jnp.take(index.scales, probe, axis=0)         # (B, P, cap)
            s = ops.mips_sq8_batched(q, vecs.reshape(B, -1, d),
                                     sc.reshape(B, -1))        # (B, P*cap)
            s = s.reshape(B, nprobe, cap)
        else:
            s = jnp.einsum("bd,bpcd->bpc", q, vecs.astype(q.dtype),
                           preferred_element_type=jnp.float32)
        s = jnp.where(ids >= 0, s, -jnp.inf)
    flat_s = s.reshape(B, -1)
    flat_i = ids.reshape(B, -1)
    kk = min(k, flat_s.shape[1])
    top, pos = jax.lax.top_k(flat_s, kk)
    out_ids = jnp.take_along_axis(flat_i, pos, axis=1)
    return pad_topk(top, out_ids, k)


@functools.partial(jax.jit, static_argnames=("nprobe", "k"))
def search_ivf_one_launch(index: IVFIndex, psi_params, q_tokens, q_mask,
                          nprobe: int, k: int):
    """One-launch first stage: raw query TOKENS in, top-k' candidates out.

    Unlike :func:`search_ivf` this takes the query tokens, not the pooled
    latent — the ψ projection, pooling, probe scan and top-k' all happen in
    ONE Pallas launch on TPU (``ops.fused_query``; its legacy-composition
    oracle elsewhere), so the ``(B, Tq, d')`` features and the
    ``(B, nprobe, cap)`` score strip never round-trip HBM.  Same math as
    ``pool_queries`` + :func:`search_ivf` — fp32 ids are bit-identical.
    q_tokens: (B, Tq, d) -> (scores (B, k), ids (B, k))."""
    kp = min(k, nprobe * index.capacity)
    top, out_ids = ops.fused_query(
        q_tokens, q_mask, psi_params, index.centroids, index.ids, index.vecs,
        index.scales, nprobe=nprobe, kp=kp)
    return pad_topk(top, out_ids, k)
