"""IVF index — the TPU-native replacement for Glass/HNSW (DESIGN.md §3).

Build: k-means coarse quantizer over the latent corpus; vectors are packed
into fixed-capacity padded cluster lists (capacity = max cluster size) with
optional SQ8 storage.  Search: one (B, nlist) centroid matmul, top-`nprobe`
clusters, then either the gather-at-source probe scan (default —
``kernels.gather_scan`` DMAs each probed cluster tile straight into VMEM on
TPU) or the legacy gathered block scan, and a masked top-k'.  Everything is
dense matmul + gather — no pointer chasing — so it maps onto MXU tiles and
shards (each device holds a slice of the cluster lists).

The recall/latency knob is ``nprobe`` (HNSW's ef_search analogue, §6.2).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.anns.base import pad_topk
from repro.anns.kmeans import kmeans
from repro.anns.quantization import (
    ResidualCodec,
    residual_decode,
    residual_encode,
    sq8_dequant,
    sq8_quant,
)
from repro.kernels import ops


class IVFIndex(NamedTuple):
    centroids: jax.Array   # (nlist, d)
    ids: jax.Array         # (nlist, cap) int32, -1 padded
    vecs: jax.Array        # (nlist, cap, d) fp32  OR int8 codes when sq8
                           # OR (nlist, cap, d*bits//8) uint8 packed residual
                           # codes when rq (coded against the OWN cluster
                           # centroid — the id is implicit in the list row)
    scales: jax.Array | None  # (nlist, cap) fp32 when sq8 else None
    counts: jax.Array      # (nlist,) int32
    mean: jax.Array | None = None  # (d,) corpus mean (centered MIPS: ranking
                                   # by q.(w-mean) == ranking by q.w)
    # residual-codec storage tier (None unless built with residual_bits)
    rq_cuts: jax.Array | None = None    # (d, L-1) per-dim bucket boundaries
    rq_values: jax.Array | None = None  # (d, L)   per-dim reconstruction vals

    @property
    def nlist(self) -> int:
        return self.centroids.shape[0]

    @property
    def capacity(self) -> int:
        return self.ids.shape[1]

    @property
    def residual(self) -> bool:
        return self.rq_values is not None


def default_nlist(m: int) -> int:
    """Paper's clustering rule (§6.3): 16·sqrt(n) rounded down to pow2 is for
    token-level indexes; for the (much smaller) latent corpus we use
    4·sqrt(m) rounded to pow2, floor 16."""
    raw = 4 * int(np.sqrt(max(m, 1)))
    return max(16, 1 << (raw.bit_length() - 1))


def build_ivf(key, vectors: jax.Array, nlist: int = 0, *, sq8: bool = False,
              residual_bits: int = 0, kmeans_iters: int = 10,
              train_sample: int = 131072, center: bool = True) -> IVFIndex:
    """``center=True`` subtracts the corpus mean before clustering/scan:
    learned LEMUR W rows carry a large shared component (globally
    standardized OLS targets) that otherwise dominates the coarse quantizer;
    MIPS ranking is invariant to it (q·mean is constant per query).

    ``residual_bits`` (2 or 4) switches the list storage to the residual
    codec: each vector is kept as a packed 2/4-bit per-dim residual against
    its OWN cluster centroid (the centroid id is the list row — free), with
    per-dim bucket boundaries/values trained from the corpus residual
    quantiles.  Supersedes ``sq8`` (d/2 or d/4 bytes/vector vs d+4)."""
    m, d = vectors.shape
    mean = None
    if center:
        mean = jnp.mean(vectors, axis=0)
        vectors = vectors - mean[None, :]
    nlist = nlist or default_nlist(m)
    ktrain, kassign = jax.random.split(jax.random.PRNGKey(0) if key is None else key)
    sample = vectors
    if m > train_sample:
        idx = jax.random.choice(ktrain, m, (train_sample,), replace=False)
        sample = vectors[idx]
    centroids, _ = kmeans(ktrain, sample, nlist, iters=kmeans_iters)
    assign = assign_clusters(vectors, centroids)  # full corpus
    ids, vecs, scales, counts = _pack_lists(vectors, np.asarray(assign), nlist,
                                            sq8=sq8 and not residual_bits)
    if residual_bits:
        cuts, values = _train_rq(vecs, ids, centroids, int(residual_bits))
        vecs = _residual_pack(centroids, cuts, values, ids, vecs)
        return IVFIndex(centroids, ids, vecs, None, counts, mean,
                        rq_cuts=cuts, rq_values=values)
    return IVFIndex(centroids, ids, vecs, scales, counts, mean)


def assign_clusters(vectors: jax.Array, centroids: jax.Array) -> jax.Array:
    """Nearest-centroid assignment (MIPS form with the -||c||²/2 correction)."""
    half = 0.5 * jnp.sum(jnp.square(centroids), axis=1)
    return jnp.argmax(vectors @ centroids.T - half[None, :], axis=1)


def _pack_lists(vectors, assign: np.ndarray, nlist: int, *, sq8: bool,
                cap_floor: int = 1):
    """Pack vectors into fixed-capacity padded cluster lists (host-side).

    ``cap`` is bucketed to a power of two (and never below ``cap_floor`` —
    :func:`extend_ivf` passes the old capacity so adds can only keep or
    double it): the list shapes are jit-static, so shape-stable adds leave
    compiled query fns alive instead of retracing per add."""
    from repro.core.pages import next_pow2

    counts = np.bincount(assign, minlength=nlist)
    cap = max(next_pow2(int(max(1, counts.max()))), int(cap_floor))
    ids = np.full((nlist, cap), -1, np.int32)
    order = np.argsort(assign, kind="stable")
    pos = np.zeros(nlist, np.int64)
    for i in order:
        c = assign[i]
        ids[c, pos[c]] = i
        pos[c] += 1
    ids = jnp.asarray(ids)
    safe = jnp.maximum(ids, 0)
    vecs = jnp.take(jnp.asarray(vectors), safe, axis=0)  # (nlist, cap, d)
    vecs = vecs * (ids >= 0)[..., None]
    scales = None
    if sq8:
        vecs, scales = sq8_quant(vecs)
    return ids, vecs, scales, jnp.asarray(counts, jnp.int32)


def _train_rq(vecs_fp, ids, centroids, bits: int):
    """Per-dim residual quantile tables over the packed lists' VALID rows:
    cuts at (l+1)/L, reconstruction values at bucket midpoints (l+0.5)/L
    (same rule as ``quantization.train_residual_codec``, but the residuals
    are against each vector's own cluster centroid)."""
    L = 1 << int(bits)
    r = np.asarray(vecs_fp - centroids[:, None, :])[np.asarray(ids) >= 0]
    rv = jnp.asarray(r, jnp.float32)                    # (n_valid, d)
    qs_cut = jnp.arange(1, L, dtype=jnp.float32) / L
    qs_val = (jnp.arange(L, dtype=jnp.float32) + 0.5) / L
    cuts = jnp.quantile(rv, qs_cut, axis=0).T           # (d, L-1)
    values = jnp.quantile(rv, qs_val, axis=0).T         # (d, L)
    return cuts, values


def _residual_pack(centroids, cuts, values, ids, vecs_fp):
    """fp32 padded lists (nlist, cap, d) -> packed residual codes
    (nlist, cap, d*bits//8) uint8 coded against the own-cluster centroid."""
    codec = ResidualCodec(centroids=centroids, cuts=cuts, values=values)
    nlist, cap = ids.shape
    cent = jnp.broadcast_to(
        jnp.arange(nlist, dtype=jnp.int32)[:, None], (nlist, cap))
    _, packed = residual_encode(codec, vecs_fp, cent)
    return jnp.where((ids >= 0)[..., None], packed, jnp.uint8(0))


def _residual_unpack(index: IVFIndex) -> jax.Array:
    """Decode the packed lists back to (nlist, cap, d) fp32 (centered)."""
    codec = ResidualCodec(centroids=index.centroids, cuts=index.rq_cuts,
                          values=index.rq_values)
    nlist, cap = index.ids.shape
    cent = jnp.broadcast_to(
        jnp.arange(nlist, dtype=jnp.int32)[:, None], (nlist, cap))
    full = residual_decode(codec, cent, index.vecs)
    return full * (index.ids >= 0)[..., None]


def extend_ivf(index: IVFIndex, new_vectors: jax.Array) -> IVFIndex:
    """Incremental add: assign new vectors to the FROZEN coarse quantizer and
    re-pack the padded lists (host-side, like build).  New docs get ids
    continuing the existing numbering; centroids/mean are not re-fit, so
    recall degrades only as far as the data drifts from the original
    clustering."""
    nlist, d = index.centroids.shape
    newv = jnp.asarray(new_vectors)
    if index.mean is not None:
        newv = newv - index.mean[None, :]
    assign_new = np.asarray(assign_clusters(newv, index.centroids))

    ids = np.asarray(index.ids)
    valid = ids >= 0
    m_old = int(valid.sum())
    m_new = newv.shape[0]
    sq8 = index.scales is not None
    rq = index.residual
    # reconstruct the (centered) stored vectors; SQ8 requant is exact because
    # each row's max code is 127, so the recomputed scale equals the old one;
    # residual re-encode is code-stable because decode reconstructs bucket
    # MIDPOINTS, which fall strictly inside their own bucket and so re-bucket
    # to the same code — repeated adds never drift the retained rows
    if rq:
        full = _residual_unpack(index)
    elif sq8:
        full = sq8_dequant(index.vecs, index.scales)
    else:
        full = index.vecs
    full = np.asarray(full)
    all_vecs = np.zeros((m_old + m_new, d), np.float32)
    all_assign = np.zeros(m_old + m_new, np.int64)
    cluster_of = np.broadcast_to(np.arange(nlist)[:, None], ids.shape)
    all_vecs[ids[valid]] = full[valid]
    all_assign[ids[valid]] = cluster_of[valid]
    all_vecs[m_old:] = np.asarray(newv)
    all_assign[m_old:] = assign_new
    ids2, vecs2, scales2, counts2 = _pack_lists(all_vecs, all_assign, nlist,
                                                sq8=sq8,
                                                cap_floor=index.capacity)
    if rq:
        # the trained tables are FROZEN like the coarse quantizer — new
        # vectors are coded with the existing cuts/values
        vecs2 = _residual_pack(index.centroids, index.rq_cuts,
                               index.rq_values, ids2, vecs2)
    return IVFIndex(index.centroids, ids2, vecs2, scales2, counts2,
                    index.mean, rq_cuts=index.rq_cuts,
                    rq_values=index.rq_values)


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "use_fused_gather"))
def search_ivf(index: IVFIndex, q: jax.Array, nprobe: int, k: int,
               use_fused_gather: bool = False):
    """q: (B, d) -> (scores (B, k), ids (B, k)).

    ``use_fused_gather=True`` scores the probed cluster lists through the
    gather-at-source kernel path (``ops.fused_ivf_scan``: the scalar-prefetch
    Pallas scan on TPU, its gather-then-score oracle elsewhere) — only the
    ``(B, nprobe, cap)`` id strip is ever gathered in HBM.  ``False`` keeps
    the legacy materialize-then-score path benchmarkable.
    """
    B, d = q.shape
    cs = q @ index.centroids.T                     # (B, nlist)
    _, probe = jax.lax.top_k(cs, nprobe)           # (B, nprobe)
    ids = jnp.take(index.ids, probe, axis=0)       # (B, nprobe, cap)
    if index.residual:
        # decode-at-source scan (in-kernel on TPU); the "legacy" path for
        # this tier IS the decode-then-score oracle, so use_fused_gather
        # only decides whether the Pallas kernel may be used
        s = ops.fused_ivf_scan_res(q, probe, index.ids, index.vecs,
                                   index.centroids, index.rq_values,
                                   use_kernel=None if use_fused_gather
                                   else False)
    elif use_fused_gather:
        # masked -inf inside the scan (same pad convention as below)
        s = ops.fused_ivf_scan(q, probe, index.ids, index.vecs, index.scales)
    else:
        vecs = jnp.take(index.vecs, probe, axis=0)  # (B, nprobe, cap, d)
        cap = vecs.shape[2]
        if index.scales is not None:
            # batched SQ8 scan: all B queries' gathered lists in ONE call
            # (the old path vmapped B one-row mips_sq8 launches — 1/128 MXU
            # tile utilization at block_q=128)
            sc = jnp.take(index.scales, probe, axis=0)         # (B, P, cap)
            s = ops.mips_sq8_batched(q, vecs.reshape(B, -1, d),
                                     sc.reshape(B, -1))        # (B, P*cap)
            s = s.reshape(B, nprobe, cap)
        else:
            s = jnp.einsum("bd,bpcd->bpc", q, vecs.astype(q.dtype),
                           preferred_element_type=jnp.float32)
        s = jnp.where(ids >= 0, s, -jnp.inf)
    flat_s = s.reshape(B, -1)
    flat_i = ids.reshape(B, -1)
    kk = min(k, flat_s.shape[1])
    top, pos = jax.lax.top_k(flat_s, kk)
    out_ids = jnp.take_along_axis(flat_i, pos, axis=1)
    return pad_topk(top, out_ids, k)


@functools.partial(jax.jit, static_argnames=("nprobe", "k"))
def search_ivf_one_launch(index: IVFIndex, psi_params, q_tokens, q_mask,
                          nprobe: int, k: int):
    """One-launch first stage: raw query TOKENS in, top-k' candidates out.

    Unlike :func:`search_ivf` this takes the query tokens, not the pooled
    latent — the ψ projection, pooling, probe scan and top-k' all happen in
    ONE Pallas launch on TPU (``ops.fused_query``; its legacy-composition
    oracle elsewhere), so the ``(B, Tq, d')`` features and the
    ``(B, nprobe, cap)`` score strip never round-trip HBM.  Same math as
    ``pool_queries`` + :func:`search_ivf` — fp32 ids are bit-identical.
    q_tokens: (B, Tq, d) -> (scores (B, k), ids (B, k))."""
    kp = min(k, nprobe * index.capacity)
    if index.residual:
        top, out_ids = ops.fused_query_res(
            q_tokens, q_mask, psi_params, index.centroids, index.ids,
            index.vecs, index.rq_values, nprobe=nprobe, kp=kp)
    else:
        top, out_ids = ops.fused_query(
            q_tokens, q_mask, psi_params, index.centroids, index.ids,
            index.vecs, index.scales, nprobe=nprobe, kp=kp)
    return pad_topk(top, out_ids, k)
