"""IVF index — the TPU-native replacement for Glass/HNSW (DESIGN.md §3).

Build: k-means coarse quantizer over the latent corpus; vectors are packed
into fixed-capacity padded cluster lists (capacity = max cluster size) with
optional SQ8 storage.  Search: one (B, nlist) centroid matmul, top-`nprobe`
clusters, a gathered block scan, masked top-k'.  Everything is dense matmul
+ gather — no pointer chasing — so it maps onto MXU tiles and shards (each
device holds a slice of the cluster lists).

The recall/latency knob is ``nprobe`` (HNSW's ef_search analogue, §6.2).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.anns.kmeans import kmeans
from repro.anns.quantization import sq8_quant


class IVFIndex(NamedTuple):
    centroids: jax.Array   # (nlist, d)
    ids: jax.Array         # (nlist, cap) int32, -1 padded
    vecs: jax.Array        # (nlist, cap, d) fp32  OR int8 codes when sq8
    scales: jax.Array | None  # (nlist, cap) fp32 when sq8 else None
    counts: jax.Array      # (nlist,) int32
    mean: jax.Array | None = None  # (d,) corpus mean (centered MIPS: ranking
                                   # by q.(w-mean) == ranking by q.w)

    @property
    def nlist(self) -> int:
        return self.centroids.shape[0]

    @property
    def capacity(self) -> int:
        return self.ids.shape[1]


def default_nlist(m: int) -> int:
    """Paper's clustering rule (§6.3): 16·sqrt(n) rounded down to pow2 is for
    token-level indexes; for the (much smaller) latent corpus we use
    4·sqrt(m) rounded to pow2, floor 16."""
    raw = 4 * int(np.sqrt(max(m, 1)))
    return max(16, 1 << (raw.bit_length() - 1))


def build_ivf(key, vectors: jax.Array, nlist: int = 0, *, sq8: bool = False,
              kmeans_iters: int = 10, train_sample: int = 131072,
              center: bool = True) -> IVFIndex:
    """``center=True`` subtracts the corpus mean before clustering/scan:
    learned LEMUR W rows carry a large shared component (globally
    standardized OLS targets) that otherwise dominates the coarse quantizer;
    MIPS ranking is invariant to it (q·mean is constant per query)."""
    m, d = vectors.shape
    mean = None
    if center:
        mean = jnp.mean(vectors, axis=0)
        vectors = vectors - mean[None, :]
    nlist = nlist or default_nlist(m)
    ktrain, kassign = jax.random.split(jax.random.PRNGKey(0) if key is None else key)
    sample = vectors
    if m > train_sample:
        idx = jax.random.choice(ktrain, m, (train_sample,), replace=False)
        sample = vectors[idx]
    centroids, _ = kmeans(ktrain, sample, nlist, iters=kmeans_iters)
    # assign the full corpus
    half = 0.5 * jnp.sum(jnp.square(centroids), axis=1)
    assign = jnp.argmax(vectors @ centroids.T - half[None, :], axis=1)

    a = np.asarray(assign)
    counts = np.bincount(a, minlength=nlist)
    cap = int(max(1, counts.max()))
    ids = np.full((nlist, cap), -1, np.int32)
    order = np.argsort(a, kind="stable")
    pos = np.zeros(nlist, np.int64)
    for i in order:
        c = a[i]
        ids[c, pos[c]] = i
        pos[c] += 1
    ids = jnp.asarray(ids)
    safe = jnp.maximum(ids, 0)
    vecs = jnp.take(vectors, safe, axis=0)  # (nlist, cap, d)
    vecs = vecs * (ids >= 0)[..., None]
    scales = None
    if sq8:
        vecs, scales = sq8_quant(vecs)
    return IVFIndex(centroids, ids, vecs, scales, jnp.asarray(counts, jnp.int32),
                    mean)


@functools.partial(jax.jit, static_argnames=("nprobe", "k"))
def search_ivf(index: IVFIndex, q: jax.Array, nprobe: int, k: int):
    """q: (B, d) -> (scores (B, k), ids (B, k))."""
    B, d = q.shape
    cs = q @ index.centroids.T                     # (B, nlist)
    _, probe = jax.lax.top_k(cs, nprobe)           # (B, nprobe)
    ids = jnp.take(index.ids, probe, axis=0)       # (B, nprobe, cap)
    vecs = jnp.take(index.vecs, probe, axis=0)     # (B, nprobe, cap, d)
    s = jnp.einsum("bd,bpcd->bpc", q, vecs.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    if index.scales is not None:
        sc = jnp.take(index.scales, probe, axis=0)
        s = s * sc
    s = jnp.where(ids >= 0, s, -jnp.inf)
    flat_s = s.reshape(B, -1)
    flat_i = ids.reshape(B, -1)
    kk = min(k, flat_s.shape[1])
    top, pos = jax.lax.top_k(flat_s, kk)
    out_ids = jnp.take_along_axis(flat_i, pos, axis=1)
    if kk < k:
        top = jnp.pad(top, ((0, 0), (0, k - kk)), constant_values=-jnp.inf)
        out_ids = jnp.pad(out_ids, ((0, 0), (0, k - kk)), constant_values=-1)
    return top, out_ids
