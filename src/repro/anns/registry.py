"""String-keyed backend registry (mirrors ``configs.registry`` for archs).

``LemurConfig.anns`` / ``--backend`` select a first-stage retriever by name;
``core.index`` resolves it here and never imports a concrete backend.

    from repro.anns import registry
    be = registry.get_backend("ivf")
    state = be.build(key, corpus_view, cfg)
    scores, ids = be.search(state, query_batch, k)

Backends self-register at import time via the :func:`register` decorator;
importing this module imports all built-in backend modules so the registry
is always fully populated.  ``"exact"`` is kept as an alias for
``"bruteforce"`` (the seed config spelling).
"""
from __future__ import annotations

from repro.anns.base import Retriever

_REGISTRY: dict[str, Retriever] = {}
_ALIASES = {"exact": "bruteforce"}


def register(backend: Retriever) -> Retriever:
    """Class decorator: instantiate and register under ``cls.name``."""
    inst = backend() if isinstance(backend, type) else backend
    name = inst.name
    if name in _REGISTRY:
        raise ValueError(f"backend {name!r} already registered")
    _REGISTRY[name] = inst
    return backend


def _ensure_builtin() -> None:
    # late import: backend modules import base/registry-free helpers only,
    # so this cannot cycle; it populates _REGISTRY as a side effect.
    from repro.anns import backends as _  # noqa: F401


def canonical(name: str) -> str:
    return _ALIASES.get(name, name)


def get_backend(name: str) -> Retriever:
    _ensure_builtin()
    name = canonical(name)
    if name not in _REGISTRY:
        raise KeyError(f"unknown anns backend {name!r}; known: {list_backends()}")
    return _REGISTRY[name]


def list_backends() -> list[str]:
    _ensure_builtin()
    return sorted(_REGISTRY)
