"""String-keyed backend registry (mirrors ``configs.registry`` for archs).

``LemurConfig.anns`` / ``--backend`` select a first-stage retriever by name;
``core.index`` / ``repro.retriever`` resolve it here and never import a
concrete backend.

    from repro.anns import registry
    be = registry.get_backend("ivf")
    state = be.build(key, corpus_view, registry.get_config_cls("ivf")())
    scores, ids = be.search(state, query_batch, k, be.default_params(None))

Each backend registers three things under its name: the Retriever instance,
its build-time config namespace (``config_cls`` — the type of the matching
``LemurConfig`` field, e.g. ``cfg.ivf``), and its query-time params type
(``params_cls`` — what rides in ``SearchParams.backend``).  Backends
self-register at import time via the :func:`register` decorator; importing
this module imports all built-in backend modules so the registry is always
fully populated.  ``"exact"`` is kept as an alias for ``"bruteforce"`` (the
seed config spelling).
"""
from __future__ import annotations

from repro.anns.base import Retriever
from repro.anns.params import BackendConfig, BackendSearchParams, NoSearchParams

_REGISTRY: dict[str, Retriever] = {}
_CONFIGS: dict[str, type[BackendConfig]] = {}
_PARAMS: dict[str, type[BackendSearchParams]] = {}
_ALIASES = {"exact": "bruteforce"}


def register(backend: Retriever) -> Retriever:
    """Class decorator: instantiate and register under ``cls.name``,
    together with the backend's config namespace and search-params types."""
    inst = backend() if isinstance(backend, type) else backend
    name = inst.name
    if name in _REGISTRY:
        raise ValueError(f"backend {name!r} already registered")
    _REGISTRY[name] = inst
    _CONFIGS[name] = getattr(inst, "config_cls", BackendConfig)
    _PARAMS[name] = getattr(inst, "params_cls", NoSearchParams)
    return backend


def _ensure_builtin() -> None:
    # late import: backend modules import base/registry-free helpers only,
    # so this cannot cycle; it populates _REGISTRY as a side effect.
    from repro.anns import backends as _  # noqa: F401


def canonical(name: str) -> str:
    return _ALIASES.get(name, name)


def get_backend(name: str) -> Retriever:
    _ensure_builtin()
    name = canonical(name)
    if name not in _REGISTRY:
        raise KeyError(f"unknown anns backend {name!r}; known: {list_backends()}")
    return _REGISTRY[name]


def get_config_cls(name: str) -> type[BackendConfig]:
    """Build-time config namespace class for a backend name."""
    get_backend(name)  # populate + validate
    return _CONFIGS[canonical(name)]


def get_params_cls(name: str) -> type[BackendSearchParams]:
    """Query-time params type for a backend name."""
    get_backend(name)
    return _PARAMS[canonical(name)]


def list_backends() -> list[str]:
    _ensure_builtin()
    return sorted(_REGISTRY)
