"""Regex rule tables mapping parameter names to PartitionSpecs.

One :class:`ShardingRules` table per model family; ``launch/cells.py``
resolves every parameter leaf of every architecture through these tables
when building dry-run cells, and ``tests/test_sharding_rules.py`` statically
validates that each resolved spec divides the production meshes (the cheap
canary for config/rule drift).

Lookup contract (first-match-wins):

    rules = ShardingRules(rules=((r"attn/w.*$", P("model")), (r".*", P())))
    rules.spec("attn/wq", 3)   # -> P("model")  (trailing dims replicated)
    rules.spec("ln1/scale", 1) # -> P()         (catch-all)

A spec may be *shorter* than the leaf's rank — missing trailing entries mean
replicated — but never longer: a rule whose spec has more entries than the
leaf has dims raises ``ValueError`` (rule drift, not a silent truncation).

Scan-stacked leaves (names under ``stack_*/pos_*/``) are resolved by
``launch.cells._resolve_spec``, which strips the stack prefix, matches the
per-layer name at ``ndim - 1``, and prepends ``None`` for the scan dim — the
tables below are therefore written against PER-LAYER names and ranks.

Axis conventions (launch/mesh.py): ``pod`` is pure cross-pod data
parallelism, so parameters never use it (they are replicated across pods
and their gradients cross the DCN through optim/compress.py); ``data``
carries FSDP/ZeRO shards; ``model`` carries tensor/expert/vocab shards.
"""
from __future__ import annotations

import dataclasses
import re

from jax.sharding import PartitionSpec as P

__all__ = [
    "ShardingRules",
    "GNN_RULES",
    "LM_RULES",
    "LM_RULES_FFSLICE",
    "RECSYS_RULES",
]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """First-match-wins (regex, PartitionSpec) table (see module docstring)."""

    rules: tuple[tuple[str, P], ...]

    def spec(self, name: str, ndim: int) -> P:
        for pattern, spec in self.rules:
            if re.search(pattern, name):
                if len(spec) > ndim:
                    raise ValueError(
                        f"rule {pattern!r} spec {spec} has {len(spec)} entries "
                        f"but leaf {name!r} has rank {ndim}")
                return spec
        raise KeyError(f"no sharding rule matches {name!r}")


# ---------------------------------------------------------------------------
# LM family.  Per-layer names/ranks (the scan-stack dim is handled by the
# caller).  Dense layers: megatron TP on the ffn/vocab axes + FSDP over
# "data" on d_model where every production arch divides (5120/6144/3072/7168
# and all ffn widths are multiples of 16).  Biases, norms, and routers are
# tiny -> replicated.
# ---------------------------------------------------------------------------

_LM_COMMON_HEAD = (
    (r"(^|/)(scale|bias)$", P()),          # norms + all dense biases
    (r"attn/b[qkv]$", P()),                # per-head attn biases (ragged heads)
    (r"embed/embedding$", P("model", None)),   # vocab-sharded
    (r"head/kernel$", P(None, "model")),       # (d_model, vocab)
    (r"attn/wo$", P(None, None, "model")),     # (heads, head_dim, d_model)
    (r"attn/w", P("model")),               # every other attn proj: (d_model, ...)
)

_LM_COMMON_TAIL = (
    (r"moe/router$", P()),
    (r"wi(_\d)?/kernel$", P("data", "model")),  # (d_model, ffn) incl. moe/shared
    (r"wo/kernel$", P("model", "data")),        # (ffn, d_model)
    (r".*", P()),
)

#: expert-parallel layout: expert dim sharded over "model", d_model FSDP
#: over "data".  moe/wi_*: (E, d_model, ffn_e); moe/wo: (E, ffn_e, d_model).
LM_RULES = ShardingRules(rules=_LM_COMMON_HEAD + (
    (r"moe/wi_\d$", P("model", "data", None)),
    (r"moe/wo$", P("model", "data", None)),
) + _LM_COMMON_TAIL)

#: ffslice layout: experts replicated, each expert's ffn dim sliced over
#: "model" (nn/moe.py's all-experts-resident layout for few-large-expert
#: models such as llama4-maverick).
LM_RULES_FFSLICE = ShardingRules(rules=_LM_COMMON_HEAD + (
    (r"moe/wi_\d$", P(None, "data", "model")),
    (r"moe/wo$", P(None, "model", "data")),
) + _LM_COMMON_TAIL)


# ---------------------------------------------------------------------------
# RecSys family.  The embedding tables are the only parameters that matter
# at scale (16M x 10 .. 10M x 256 rows) -> row-sharded over "model" (the
# sharded_embedding_lookup substrate); the BST positional table (21 rows)
# and all MLP/CIN/attention weights are sub-megabyte -> replicated, except
# the two-tower MLPs whose widths are uniform multiples of 16.
# ---------------------------------------------------------------------------

RECSYS_RULES = ShardingRules(rules=(
    (r"(^|/)(scale|bias)$", P()),
    (r"pos_table/embedding$", P()),
    (r"/embedding$", P("model", None)),
    (r"_tower/layer_\d+/kernel$", P(None, "model")),
    (r".*", P()),
))


# ---------------------------------------------------------------------------
# GNN family.  Message-passing MLPs are small and the graph (nodes/edges)
# carries all the parallelism (see models/gnn.py's edge-sharded shard_map);
# parameters are replicated wholesale.
# ---------------------------------------------------------------------------

GNN_RULES = ShardingRules(rules=((r".*", P()),))
