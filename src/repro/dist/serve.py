"""Distributed LEMUR: sharded indexing + sharded serving on the mesh.

Serving (Fig. 1 at pod scale): the latent corpus W, the IVF lists, and the
doc-token store are sharded over the *flattened* mesh (every chip owns
m/n_devices docs).  A query batch is replicated across the corpus axis;
each shard runs (latent scan -> local top-k' -> local exact rerank) entirely
locally, and only the (k, score) pairs cross the wire in a final all-gather
merge — per-query traffic is k·n_devices·8 bytes, independent of m.

Indexing (§4.3): the Gram factor is tiny ((d')² fp32) and replicated; each
shard fits OLS rows for its own documents with zero communication.

The facade entry point is :meth:`repro.retriever.LemurRetriever.shard`;
``repro.core.distributed`` re-exports this module for v0 call sites.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.common.compat import shard_map
from repro.core import maxsim
from repro.core.config import LemurConfig
from repro.core.model import pool_queries
from repro.kernels import ops


def corpus_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)  # shard the corpus over every axis


def n_corpus_shards(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in corpus_axes(mesh)]))


class ShardedRetrievalState(NamedTuple):
    """Device arrays for the serving step (pytree).

    With scales present, W / doc_tokens are int8 SQ codes (Glass-style SQ8 —
    the layout repro.kernels.mips_sq8 scans on TPU): 2-4x less resident HBM
    and per-step traffic than bf16/fp32 (EXPERIMENTS.md §Perf iteration 3).

    ``row_ids`` / ``row_valid`` (optional — the paged sharded facade sets
    them) decouple physical rows from external doc ids: rows become SLOTS
    that mutations rewrite in place (add/delete/update without resharding),
    ``row_valid=False`` rows are masked out of the latent scan, and the
    merge maps surviving local rows to external ids through ``row_ids``
    (``-1`` for free rows).  When absent, row position IS the doc id (the
    legacy contract; ``m_real`` masks the tail padding)."""
    psi: dict
    W: jax.Array                    # (m, d') latent corpus (fp or int8 codes)
    doc_tokens: jax.Array           # (m, Td, d) token store (fp or int8 codes)
    doc_mask: jax.Array             # (m, Td)
    W_scales: jax.Array | None = None      # (m,) per-row scales (int8 mode)
    doc_scales: jax.Array | None = None    # (m, Td) per-token scales
    row_ids: jax.Array | None = None       # (m,) int32 external ids, -1 free
    row_valid: jax.Array | None = None     # (m,) bool occupied-and-alive


def state_shardings(mesh: Mesh, state: ShardedRetrievalState | None = None):
    """NamedShardings for a ShardedRetrievalState: ψ replicated, every
    corpus-sized leaf block-sharded over the flattened mesh.  With ``state``
    given, its ψ tree structure (and scale/row-map presence) is mirrored
    exactly."""
    corpus = NamedSharding(mesh, P(corpus_axes(mesh)))
    repl = NamedSharding(mesh, P())
    psi_tree = state.psi if state is not None else {
        "dense": {"kernel": 0, "bias": 0}, "ln": {"scale": 0, "bias": 0}}
    has_scales = state is not None and state.W_scales is not None
    has_rows = state is not None and state.row_ids is not None
    return ShardedRetrievalState(
        psi=jax.tree_util.tree_map(lambda _: repl, psi_tree),
        W=corpus,
        doc_tokens=corpus,
        doc_mask=corpus,
        W_scales=corpus if has_scales else None,
        doc_scales=corpus if has_scales else None,
        row_ids=corpus if has_rows else None,
        row_valid=corpus if has_rows else None,
    )


def _local_retrieve(psi_q, W, W_scales, doc_tokens, doc_scales, doc_mask,
                    row_ids, row_valid, q_tokens, q_mask, *, k: int,
                    k_prime: int, axes: tuple[str, ...],
                    axis_sizes: tuple[int, ...],
                    m_real: int | None = None, use_fused_gather: bool = True,
                    use_one_launch: bool = False):
    """Per-shard body (inside shard_map): local MIPS + local rerank + merge.

    * latent scan: int8 codes x fp query with per-row scales (the
      kernels.mips_sq8 contraction) when scales are present;
    * rerank: ``use_fused_gather=True`` routes the per-shard candidate
      rerank through ``kernels.ops.fused_rerank`` — the SAME gather-at-
      source kernel the single-device facade serves with (candidate token
      slabs DMA'd straight into VMEM on TPU; per-token SQ8 scales folded
      into the score rows in-kernel).  ``False`` keeps the legacy
      gather-then-contract path benchmarkable.  Either way only the k'
      CANDIDATE docs are touched and scores stay exact w.r.t. the stored
      (quantized) representation, matching Glass+SQ in the paper;
    * merge: hierarchical per-axis top-k (tree reduction) — gather volume
      k*|axis| per stage instead of k*n_devices at once.

    ``m_real``: true corpus size when the leading dim carries padding rows
    (the facade pads m up to the device count) — padded columns are masked
    out of the latent scan so they can never displace a real candidate.
    ``row_ids``/``row_valid`` (the paged slot contract, see
    :class:`ShardedRetrievalState`): the scan mask comes from the TRACED
    ``row_valid`` bits and the merge maps local rows to external ids
    through ``row_ids`` — free/tombstoned rows score NEG and resolve to
    ``-1``, so in-place slot mutation never changes shapes."""
    # psi_q: (B, d') pooled queries, already encoded batch-sharded OUTSIDE the
    # corpus shard_map (encoding inside would replicate the psi MLP's (B,Tq,d')
    # intermediates on every corpus shard — §Perf iteration 3)
    m_loc = W.shape[0]
    kp = min(k_prime, m_loc)
    # globalize ids: offset by this shard's first row (sizes are static —
    # old jax has no lax.axis_size)
    idx = 0
    for ax, size in zip(axes, axis_sizes):
        idx = idx * size + jax.lax.axis_index(ax)
    valid = row_valid
    if valid is None and m_real is not None:
        valid = (idx * m_loc + jnp.arange(m_loc)) < m_real
    if use_one_launch:
        # fused latent scan + in-kernel top-k': the (B, m_loc) score matrix
        # never exists in HBM.  The pad mask depends on TRACED state (shard
        # index / row_valid bits), so it rides into the kernel as an array
        # input (masked rows keep their position ids at NEG — identical to
        # the legacy branch).
        _, cand = ops.mips_topk_fused(psi_q, W, W_scales, kp, valid)
    else:
        s = psi_q @ W.T.astype(psi_q.dtype)                     # (B, m_loc)
        if W_scales is not None:
            s = s * W_scales[None, :].astype(s.dtype)
        if valid is not None:
            s = jnp.where(valid[None, :], s, maxsim.NEG)
        _, cand = jax.lax.top_k(s, kp)                          # local candidates
    if use_fused_gather:
        scores, local_ids = ops.fused_rerank(
            q_tokens, q_mask, cand, doc_tokens, doc_mask, min(k, kp),
            doc_scales=doc_scales)
    elif doc_scales is not None:
        cd = jnp.take(doc_tokens, cand, axis=0).astype(q_tokens.dtype)
        cs = jnp.take(doc_scales, cand, axis=0)
        cm = jnp.take(doc_mask, cand, axis=0)
        # fold the per-token scale into the SCORE tensor: score(q, s*c) =
        # s*(q.c) — avoids materializing a dequantized (B,k',Td,d) fp copy
        # (the fused kernel path does the same dequant in-VMEM on TPU)
        sc = jnp.einsum("bqd,bmtd->bmqt", q_tokens, cd,
                        preferred_element_type=jnp.float32)
        sc = sc * cs.astype(jnp.float32)[:, :, None, :]
        sc = jnp.where(cm[:, :, None, :], sc, -1e30)
        best = jnp.where(q_mask[:, None, :], jnp.max(sc, axis=-1), 0.0)
        scores = jnp.sum(best, axis=-1)
        scores, pos = jax.lax.top_k(scores, min(k, kp))
        local_ids = jnp.take_along_axis(cand, pos, axis=1)
    else:
        scores, local_ids = maxsim.rerank(q_tokens, q_mask, cand, doc_tokens,
                                          doc_mask, min(k, kp))
    if row_ids is not None:
        # slot contract: map surviving local rows to external ids; -1 rerank
        # pads and free rows (row_ids -1) stay -1
        safe = jnp.maximum(local_ids, 0)
        gids = jnp.where(local_ids >= 0, jnp.take(row_ids, safe), -1)
    else:
        gids = local_ids + idx * m_loc
    # hierarchical merge: reduce back to top-k after every axis gather
    all_s, all_i = scores, gids
    for ax in axes:
        all_s = jax.lax.all_gather(all_s, ax, axis=1, tiled=True)
        all_i = jax.lax.all_gather(all_i, ax, axis=1, tiled=True)
        all_s, pos = jax.lax.top_k(all_s, min(k, all_s.shape[1]))
        all_i = jnp.take_along_axis(all_i, pos, axis=1)
    return all_s, all_i


def default_k_prime_local(cfg_k: int, cfg_k_prime: int, n_shards: int) -> int:
    """Per-shard candidate budget: the paper's k' is a global budget; with N
    corpus shards the expected per-shard share is k'/N, so a 4x oversample
    keeps merge recall while bounding the per-shard rerank at
    O(B · k'_loc · Tq · Td)."""
    return max(cfg_k, (4 * cfg_k_prime + n_shards - 1) // n_shards)


def make_serve_step(mesh: Mesh, cfg: LemurConfig, *,
                    k_prime_local: int | None = None,
                    m_real: int | None = None,
                    use_fused_gather: bool | None = None,
                    use_one_launch: bool | None = None,
                    use_residual: bool | None = None):
    """Returns a jit-able serve_step(state, q_tokens, q_mask) -> (scores, ids).

    Queries are replicated over the corpus shards (the corpus uses every mesh
    axis, so there is no spare axis for query-batch parallelism; batchwise
    throughput comes from the batch dimension itself).

    ``k_prime_local``: per-shard candidate budget; defaults to
    :func:`default_k_prime_local`'s 4x oversample of the global k'.
    ``m_real``: true corpus size when state rows carry padding (see
    :func:`_local_retrieve`).
    ``use_fused_gather``: per-shard rerank through the gather-at-source
    kernel path (default: ``cfg.use_fused_gather``).
    ``use_one_launch``: per-shard latent scan + top-k' as ONE fused kernel
    launch (default: ``cfg.use_one_launch``); ids match the legacy
    scan-then-top-k branch bit for bit on fp32.
    ``use_residual``: the compressed-token-tier compile key (default:
    ``cfg.residual.enabled``, i.e. OFF unless the index was built with the
    residual codec).  The sharded slot pool stores DECODED rows — a
    residual base store is dequantized once at state build (then optionally
    SQ8-requantized per row), never on the serve path — so the knob only
    pins the compiled-step identity to match the single-device facade's
    (backend, resolved-params) cache contract."""
    axes = corpus_axes(mesh)
    axis_sizes = tuple(mesh.shape[a] for a in axes)
    n_shards = int(np.prod(axis_sizes))
    if k_prime_local is None:
        k_prime_local = default_k_prime_local(cfg.k, cfg.k_prime, n_shards)
    if use_fused_gather is None:
        use_fused_gather = bool(cfg.use_fused_gather)
    if use_one_launch is None:
        use_one_launch = bool(getattr(cfg, "use_one_launch", False))
    if use_residual is None:
        use_residual = bool(getattr(cfg, "residual", None) is not None
                            and cfg.residual.enabled)
    corpus_spec = P(axes)
    body = functools.partial(
        _local_retrieve, k=cfg.k, k_prime=k_prime_local, axes=axes,
        axis_sizes=axis_sizes, m_real=m_real,
        use_fused_gather=bool(use_fused_gather),
        use_one_launch=bool(use_one_launch),
    )
    del use_residual  # resolved + part of the caller's compile key; the
    #                   per-shard body always scans the decoded slot pool

    def serve_step(state: ShardedRetrievalState, q_tokens, q_mask):
        sq8 = state.W_scales is not None
        rows = state.row_ids is not None
        # encode + pool queries batch-sharded (GSPMD), replicate only the
        # pooled (B, d') vectors into the corpus shard_map
        ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        nb = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
        if q_tokens.shape[0] % max(nb, 1) == 0 and ba:
            qt = jax.lax.with_sharding_constraint(
                q_tokens, NamedSharding(mesh, P(ba, None, None)))
        else:
            qt = q_tokens
        psi_q = pool_queries(state.psi, qt.astype(jnp.float32), q_mask)
        psi_q = jax.lax.with_sharding_constraint(
            psi_q, NamedSharding(mesh, P())).astype(q_tokens.dtype)
        in_specs = (P(), corpus_spec, corpus_spec if sq8 else P(),
                    corpus_spec, corpus_spec if sq8 else P(), corpus_spec,
                    corpus_spec if rows else P(),
                    corpus_spec if rows else P(), P(), P())
        return shard_map(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(P(), P()),
            check_vma=False,
        )(psi_q, state.W, state.W_scales, state.doc_tokens,
          state.doc_scales, state.doc_mask, state.row_ids, state.row_valid,
          q_tokens, q_mask)

    return serve_step


def make_index_step(mesh: Mesh, cfg: LemurConfig, *, doc_block: int = 128):
    """Distributed OLS indexing step: every shard fits W rows for its local
    doc block against the replicated Gram factor.  jit-able; zero comms."""
    axes = corpus_axes(mesh)
    corpus_spec = P(axes)

    def body(chol_c, feats, x_ols, doc_tokens, doc_mask, mean, std):
        g = maxsim.token_maxsim(x_ols, doc_tokens, doc_mask, block=doc_block)
        g = (g - mean) / std
        rhs = feats.T @ g
        return jax.scipy.linalg.cho_solve((chol_c, False), rhs).T

    def index_step(chol_c, feats, x_ols, doc_tokens, doc_mask, mean, std):
        return shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), P(), P(), corpus_spec, corpus_spec, P(), P()),
            out_specs=corpus_spec,
            check_vma=False,
        )(chol_c, feats, x_ols, doc_tokens, doc_mask, mean, std)

    return index_step
