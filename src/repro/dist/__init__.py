"""Multi-device layer: sharding rule tables + the LEMUR corpus-sharded
serving/indexing steps (both built on ``repro.common.compat``, so they run
on every supported jax).

* :mod:`repro.dist.sharding` — regex rule tables mapping parameter names to
  PartitionSpecs (``LM_RULES`` / ``LM_RULES_FFSLICE`` / ``RECSYS_RULES`` /
  ``GNN_RULES``), consumed by ``launch/cells.py``.
* :mod:`repro.dist.serve` — ``ShardedRetrievalState`` + the per-shard
  latent-scan/rerank/merge serve step and the zero-comms OLS index step;
  the user-facing wrapper is :meth:`repro.retriever.LemurRetriever.shard`.
"""
from repro.dist.serve import (
    ShardedRetrievalState,
    corpus_axes,
    default_k_prime_local,
    make_index_step,
    make_serve_step,
    n_corpus_shards,
    state_shardings,
)
from repro.dist.sharding import (
    GNN_RULES,
    LM_RULES,
    LM_RULES_FFSLICE,
    RECSYS_RULES,
    ShardingRules,
)

__all__ = [
    "GNN_RULES",
    "LM_RULES",
    "LM_RULES_FFSLICE",
    "RECSYS_RULES",
    "ShardedRetrievalState",
    "ShardingRules",
    "corpus_axes",
    "default_k_prime_local",
    "make_index_step",
    "make_serve_step",
    "n_corpus_shards",
    "state_shardings",
]
