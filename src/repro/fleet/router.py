"""Fleet router: N replicated retriever servers behind one serving surface.

Topology (see README "Fleet serving")::

    client -> Router.submit --+--> RetrieverServer[0] -> retriever.clone()
              (admission,     +--> RetrieverServer[1] -> retriever.clone()
               deadlines,     +--> ...
               least-outstanding dispatch, SLO rung selection)

Semantics the router guarantees (each asserted in ``tests/test_fleet.py``):

* **Least-outstanding dispatch.**  Every search goes to the healthy
  replica with the fewest outstanding requests — queue depth stays
  balanced without any shared queue.
* **Exactly-once resolution.**  Every accepted request resolves exactly
  once — a result, a typed :class:`DeadlineExceeded`, or a typed
  :class:`Overloaded` — never a silent drop, never a duplicate, even
  across replica failure and re-dispatch.  ``future.request_id`` is the
  fleet-level id; ``future.replica`` says which replica answered.
* **Snapshot-consistent add.**  ``add()`` fans out to every healthy
  replica under the dispatch lock (so it lands at a consistent queue
  position fleet-wide) and returns a write-barrier future that resolves
  only when EVERY replica has applied the growth and landed on the same
  ``snapshot_version`` — after the barrier resolves, no search can observe
  the old corpus on any replica.  Quarantined replicas are excused; a
  replica whose add fails is quarantined (it diverged).
* **Admission control.**  When total outstanding requests reach
  ``max_queue_depth`` the submitted future resolves with
  :class:`Overloaded` — rejected requests are never dispatched, so they
  can never consume a micro-batch slot on any replica.
* **Health / quarantine.**  A replica with outstanding work whose server
  stops making progress for ``stall_timeout_s`` is quarantined: it stops
  receiving traffic, its in-flight requests are re-dispatched to healthy
  replicas (stale attempts are fenced by future identity, so a wedged
  replica that later revives cannot double-resolve), and pending write
  barriers excuse it.  ``kill_replica`` is quarantine + server teardown —
  the chaos hook the mid-replay-kill tests drive.
* **SLO adaptation.**  With an :class:`~repro.fleet.slo.SLOController`
  attached, submits that don't pin ``params`` are dispatched at the
  controller's active rung; the controller walks the pre-compiled
  nprobe/k' ladder down on windowed-p99 breach and back up hysteretically.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.serving.buckets import BucketLadder
from repro.serving.server import DeadlineExceeded, Overloaded, RetrieverServer

log = logging.getLogger("repro.fleet.router")


# --------------------------------------------------------------------------
# stats
# --------------------------------------------------------------------------

class FleetStats:
    """Fleet-level request accounting (thread-safe), mirroring
    :class:`~repro.serving.server.ServerStats`'s summary contract so the
    shared replay loop works unchanged over a Router."""

    def __init__(self, window: int = 100_000):
        self._lock = threading.Lock()
        self._lat: collections.deque[float] = collections.deque(maxlen=window)
        self._submit_lat: collections.deque[float] = collections.deque(
            maxlen=window)
        self._n_completed = 0
        self._n_rejected = 0
        self._n_expired = 0
        self._n_redispatched = 0
        self._n_failed = 0
        self._t_first: float | None = None
        self._t_last: float | None = None

    def record_completed(self, arrival_lat_s: float, submit_lat_s: float,
                         t_done: float) -> None:
        with self._lock:
            self._lat.append(arrival_lat_s)
            self._submit_lat.append(submit_lat_s)
            self._n_completed += 1
            if self._t_first is None:
                self._t_first = t_done
            self._t_last = t_done

    def record_rejected(self, n: int = 1) -> None:
        with self._lock:
            self._n_rejected += n

    def record_expired(self, n: int = 1) -> None:
        with self._lock:
            self._n_expired += n

    def record_redispatched(self, n: int = 1) -> None:
        with self._lock:
            self._n_redispatched += n

    def record_failed(self, n: int = 1) -> None:
        with self._lock:
            self._n_failed += n

    @property
    def n_completed(self) -> int:
        with self._lock:
            return self._n_completed

    @property
    def n_rejected(self) -> int:
        with self._lock:
            return self._n_rejected

    @property
    def n_expired(self) -> int:
        with self._lock:
            return self._n_expired

    @property
    def n_redispatched(self) -> int:
        with self._lock:
            return self._n_redispatched

    def summary(self) -> dict:
        with self._lock:
            lat = np.fromiter(self._lat, np.float64)
            sub = np.fromiter(self._submit_lat, np.float64)
            n = self._n_completed
            span = ((self._t_last - self._t_first)
                    if (self._t_first is not None and n > 1) else 0.0)
            counters = {
                "n_rejected": self._n_rejected,
                "n_expired": self._n_expired,
                "n_redispatched": self._n_redispatched,
                "n_failed": self._n_failed,
            }
        pct = ({f"p{q}_ms": float(np.percentile(lat, q) * 1e3)
                for q in (50, 95, 99)} if lat.size else
               {f"p{q}_ms": float("nan") for q in (50, 95, 99)})
        sub_pct = ({f"submit_p{q}_ms": float(np.percentile(sub, q) * 1e3)
                    for q in (50, 95, 99)} if sub.size else
                   {f"submit_p{q}_ms": float("nan") for q in (50, 95, 99)})
        return {
            "n_requests": n,
            "mean_ms": float(np.mean(lat) * 1e3) if lat.size else float("nan"),
            **pct,
            **sub_pct,
            "qps": n / span if span > 0 else float("nan"),
            **counters,
        }


# --------------------------------------------------------------------------
# request + write barrier
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _FleetRequest:
    rid: int
    q: np.ndarray
    qm: np.ndarray | None
    params: object            # resolved SearchParams this request runs at
    deadline: float | None    # absolute — preserved across re-dispatch
    t_arrival: float
    t_submit: float
    future: Future
    attempts: int = 0
    resolved: bool = False    # set under the router lock, exactly once
    current: Future | None = None  # the live replica attempt; fences stale
                                   # callbacks after re-dispatch


class _AddBarrier:
    """Write barrier over one mutation fan-out (``add``/``delete``/
    ``update``): resolves the aggregate future only when every armed
    replica has applied the mutation and landed on the same
    ``snapshot_version``.  ``excuse(i)`` drops a quarantined
    replica from the wait set; a replica whose add fails triggers
    ``on_fail`` (the router quarantines it).  All future resolution and
    the ``on_fail`` hook run OUTSIDE the barrier lock — the router may
    call ``excuse`` while holding its own lock, so the barrier must never
    call back into the router while holding its lock."""

    def __init__(self, agg: Future, on_fail):
        self._lock = threading.Lock()
        self._agg = agg
        self._on_fail = on_fail
        self._waiting: dict[int, Future] = {}
        self._versions: dict[int, int | None] = {}
        # typed rejections (exc.preserves_replica_state): the replica
        # REFUSED the mutation and provably kept its last-good snapshot —
        # e.g. ``CorruptIndexError`` from warm-swap validation.  Not a
        # replica failure: no quarantine, the aggregate carries the
        # rejection instead.
        self._rejections: dict[int, BaseException] = {}
        self._m: int | None = None
        self._sealed = False
        self.done = False

    def arm(self, i: int, rep_fut: Future) -> None:
        with self._lock:
            self._waiting[i] = rep_fut
        rep_fut.add_done_callback(lambda f, i=i: self._one_done(i, f))

    def seal(self) -> None:
        """Call after every arm(): enables completion (handles the
        all-replicas-already-done race)."""
        with self._lock:
            self._sealed = True
            fire = self._ready_locked()
        if fire is not None:
            self._finish(*fire)

    def excuse(self, i: int) -> None:
        with self._lock:
            if self.done:
                return
            self._waiting.pop(i, None)
            self._versions.pop(i, None)
            self._rejections.pop(i, None)
            fire = self._ready_locked()
        if fire is not None:
            self._finish(*fire)

    def _one_done(self, i: int, f: Future) -> None:
        fail = None
        fire = None
        with self._lock:
            if self.done or i not in self._waiting:
                return
            del self._waiting[i]
            if f.cancelled():
                fail = (i, RuntimeError("replica mutation cancelled"))
            elif f.exception() is not None:
                exc = f.exception()
                if getattr(exc, "preserves_replica_state", False):
                    self._rejections[i] = exc
                    fire = self._ready_locked()
                else:
                    fail = (i, exc)
            else:
                self._versions[i] = getattr(f, "snapshot_version", None)
                self._m = f.result()
                fire = self._ready_locked()
        if fail is not None:
            # the replica diverged from the fleet snapshot — quarantine it,
            # which excuses it from this (and every other) barrier
            self._on_fail(fail[0], fail[1])
            with self._lock:
                fire = self._ready_locked()
        if fire is not None:
            self._finish(*fire)

    def _ready_locked(self):
        if self._sealed and not self._waiting and not self.done:
            self.done = True
            return dict(self._versions), self._m, dict(self._rejections)
        return None

    def _finish(self, versions: dict, m, rejections: dict) -> None:
        if rejections and not versions:
            # every surviving replica typed-rejected with state intact —
            # deterministic transforms land here (e.g. SwapAborted); the
            # fleet is still fully healthy on the last-good snapshot
            self._agg.set_exception(next(iter(rejections.values())))
            return
        if rejections:
            # some replicas applied, some rejected: genuine divergence
            self._agg.set_exception(RuntimeError(
                f"mutation divergence: replicas {sorted(rejections)} "
                f"rejected while {sorted(versions)} applied"))
            return
        if not versions:
            self._agg.set_exception(RuntimeError(
                "mutation failed: no replica completed the barrier"))
            return
        vs = set(versions.values())
        if len(vs) != 1:
            self._agg.set_exception(RuntimeError(
                f"snapshot divergence across replicas: {versions}"))
            return
        self._agg.snapshot_version = vs.pop()
        self._agg.set_result(m)


# --------------------------------------------------------------------------
# the router
# --------------------------------------------------------------------------

class Router:
    """Replicated serving fleet (see module docstring).

    ``replicas`` is a list of independent retriever replicas (from
    :func:`repro.fleet.replica.clone_replicas`); the router owns one
    :class:`RetrieverServer` per replica.  Use as a context manager::

        reps = clone_replicas(retriever, 3)
        with Router(reps, ladder=ladder, max_queue_depth=256) as router:
            fut = router.submit(q_tokens, deadline_s=0.5)
            scores, ids = fut.result(timeout=30)
            router.add(new_tokens, new_mask).result(timeout=60)
    """

    def __init__(self, replicas, *, ladder: BucketLadder | None = None,
                 max_wait_us: int = 2000,
                 max_queue_depth: int | None = 128,
                 default_deadline_s: float | None = None,
                 default_params=None, slo=None,
                 stall_timeout_s: float = 1.0,
                 health_interval_s: float = 0.05,
                 event_log_size: int = 4096):
        if not replicas:
            raise ValueError("need at least one replica")
        self._ladder = ladder or BucketLadder()
        self._servers = [RetrieverServer(rep, ladder=self._ladder,
                                         max_wait_us=max_wait_us,
                                         default_params=default_params)
                         for rep in replicas]
        self._default_params = default_params
        self._max_queue_depth = max_queue_depth
        self._default_deadline_s = default_deadline_s
        self._slo = slo
        self._stall_timeout = float(stall_timeout_s)
        self._health_interval = float(health_interval_s)
        # RLock: barrier/quarantine paths re-enter from callbacks that can
        # run synchronously on the dispatching thread
        self._lock = threading.RLock()
        self._healthy = [True] * len(replicas)
        self._outstanding = [0] * len(replicas)
        self._inflight: list[dict[int, _FleetRequest]] = [
            {} for _ in replicas]
        self._barriers: list[_AddBarrier] = []
        # bounded audit ring: a long-running fleet must not grow without
        # limit; truncation is observable via ``events_dropped``
        self._events: collections.deque[dict] = collections.deque(
            maxlen=int(event_log_size))
        self._events_dropped = 0
        self._stats = FleetStats()
        self._rid = 0
        self._stopping = False
        self._stop_evt = threading.Event()
        self._monitor: threading.Thread | None = None

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "Router":
        for srv in self._servers:
            srv.start()
        self._stop_evt.clear()
        self._monitor = threading.Thread(target=self._monitor_loop,
                                         name="lemur-fleet-monitor",
                                         daemon=True)
        self._monitor.start()
        return self

    def stop(self, *, drain: bool = True, timeout: float | None = None) -> bool:
        with self._lock:
            self._stopping = True
        self._stop_evt.set()
        if self._monitor is not None:
            self._monitor.join(timeout=5.0)
            self._monitor = None
        ok = True
        for i, srv in enumerate(self._servers):
            if self._healthy[i]:
                ok &= srv.stop(drain=drain, timeout=timeout)
            else:
                # quarantined replicas may be wedged — never drain them
                srv.stop(drain=False, timeout=1.0)
        return ok

    def __enter__(self) -> "Router":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=exc == (None, None, None))

    # -- introspection ------------------------------------------------------

    @property
    def servers(self) -> list[RetrieverServer]:
        return list(self._servers)

    @property
    def ladder(self) -> BucketLadder:
        return self._ladder

    @property
    def stats(self) -> FleetStats:
        return self._stats

    @property
    def slo(self):
        return self._slo

    def reset_stats(self) -> FleetStats:
        old, self._stats = self._stats, FleetStats()
        return old

    @property
    def n_replicas(self) -> int:
        return len(self._servers)

    @property
    def n_healthy(self) -> int:
        with self._lock:
            return sum(self._healthy)

    def quarantined(self) -> list[int]:
        with self._lock:
            return [i for i, h in enumerate(self._healthy) if not h]

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    @property
    def events_dropped(self) -> int:
        """Audit-ring truncations: events evicted from the bounded
        ``events()`` buffer since construction."""
        with self._lock:
            return self._events_dropped

    def _record_event(self, **ev) -> None:
        # callers hold self._lock (RLock makes double-entry safe anyway)
        if len(self._events) == self._events.maxlen:
            self._events_dropped += 1
        self._events.append(ev)

    def pending(self) -> int:
        with self._lock:
            return sum(self._outstanding[i]
                       for i in range(len(self._servers)) if self._healthy[i])

    @property
    def m(self) -> int:
        return self._first_healthy_server().retriever.m

    @property
    def version(self) -> int:
        return self._first_healthy_server().retriever.version

    def trace_count(self, params=None) -> int:
        return sum(srv.trace_count(params) for srv in self._servers)

    def trace_shapes(self):
        out: dict[tuple, int] = {}
        for srv in self._servers:
            for shape, n in srv.trace_shapes().items():
                out[shape] = out.get(shape, 0) + n
        return out

    def compile_bound(self, n_param_sets: int = 1) -> int:
        """Fleet-wide compile bound: every replica compiles its own bucketed
        shapes (``trace_count`` sums over replicas the same way)."""
        return len(self._servers) * self._ladder.compile_bound(n_param_sets)

    def _first_healthy_server(self) -> RetrieverServer:
        with self._lock:
            for i, srv in enumerate(self._servers):
                if self._healthy[i]:
                    return srv
        raise RuntimeError("no healthy replicas")

    # -- client surface -----------------------------------------------------

    def submit(self, q_tokens, q_mask=None, params=None, *,
               deadline_s: float | None = None,
               deadline_at: float | None = None,
               t_arrival: float | None = None) -> Future:
        """Admit + dispatch one ragged query.  Always returns a future:
        on admission reject it resolves with :class:`Overloaded` (typed,
        async — unlike the single server's synchronous raise, so open-loop
        replays over a fleet never branch on submit).  ``params=None``
        dispatches at the SLO controller's active rung (when attached);
        the future carries ``params`` (which rung answered),
        ``request_id``, and — once resolved — ``replica`` and
        ``snapshot_version``."""
        now = time.perf_counter()
        arrival = now if t_arrival is None else float(t_arrival)
        dls = deadline_s if deadline_s is not None else self._default_deadline_s
        deadline = (float(deadline_at) if deadline_at is not None
                    else arrival + dls if dls is not None else None)
        fut: Future = Future()
        reject = None
        with self._lock:
            if self._stopping:
                raise RuntimeError("router is stopped")
            self._rid += 1
            fut.request_id = self._rid
            if params is None and self._slo is not None:
                resolved = self._slo.params()
            else:
                resolved = self._servers[0].retriever.resolve(
                    params if params is not None else self._default_params)
            fut.params = resolved
            total = sum(self._outstanding[i]
                        for i in range(len(self._servers)) if self._healthy[i])
            if (self._max_queue_depth is not None
                    and total >= self._max_queue_depth):
                self._stats.record_rejected()
                reject = Overloaded(
                    f"fleet outstanding {total} at bound "
                    f"{self._max_queue_depth}")
            else:
                req = _FleetRequest(self._rid, q_tokens, q_mask, resolved,
                                    deadline, arrival, now, fut)
                if not self._dispatch_locked(req):
                    req.resolved = True
                    reject = RuntimeError("no healthy replicas")
        if reject is not None:
            fut.set_exception(reject)
        return fut

    def search(self, q_tokens, q_mask=None, params=None,
               timeout: float | None = 60.0, **submit_kw):
        """Blocking convenience wrapper: ``submit(...).result(timeout)``."""
        return self.submit(q_tokens, q_mask, params,
                           **submit_kw).result(timeout)

    def add(self, doc_tokens, doc_mask, *, seed: int = 0) -> Future:
        """Snapshot-consistent growth fan-out (see module docstring).  The
        returned future resolves to the grown corpus size once EVERY
        healthy replica has landed on the same ``snapshot_version`` (also
        stamped on the future); until then no search observes the new docs
        on any replica, and per-replica FIFO barriers mean no search can
        ever observe them on one replica but not another in submit order."""
        return self._mutate(lambda srv: srv.add(doc_tokens, doc_mask,
                                                seed=seed))

    def delete(self, doc_ids) -> Future:
        """Snapshot-consistent tombstone fan-out: every healthy replica
        deletes the same stable external ids under its FIFO barrier and
        must land on the same ``snapshot_version``.  Resolves to the
        surviving live-doc count ``n_alive``."""
        return self._mutate(lambda srv: srv.delete(doc_ids))

    def update(self, doc_ids, doc_tokens, doc_mask, *, seed: int = 0) -> Future:
        """Snapshot-consistent replace fan-out (delete+add, ONE version
        bump per replica).  Resolves to the NEW external ids — identical on
        every replica because the shared OLS solver makes ``fit_docs``
        deterministic and slot allocation is deterministic."""
        return self._mutate(lambda srv: srv.update(doc_ids, doc_tokens,
                                                   doc_mask, seed=seed))

    def apply(self, fn) -> Future:
        """Snapshot-consistent generic transform fan-out — the warm-swap
        path.  ``fn(retriever)`` runs inside every healthy replica's FIFO
        mutation barrier (``RetrieverServer.apply``); the fleet barrier then
        requires all replicas to land on the same ``snapshot_version``,
        which a deterministic transform (e.g. ``install_refresh`` of one
        shared ``RefreshResult``) guarantees.  A replica that fails its arm
        is quarantined and excused; if validation rejects the transform on
        every replica identically (e.g. ``CorruptIndexError``), the
        aggregate future carries that exception and every replica keeps its
        last-good snapshot."""
        return self._mutate(lambda srv: srv.apply(fn))

    def _mutate(self, enqueue) -> Future:
        """Fan one mutation out to every healthy replica under an
        :class:`_AddBarrier` (a failed/cancelled replica arm quarantines
        that replica and is excused — the barrier resolves typed either
        way, never hangs)."""
        agg: Future = Future()
        barrier = _AddBarrier(agg, self._on_add_fail)
        arms: list[tuple[int, Future]] = []
        with self._lock:
            if self._stopping:
                raise RuntimeError("router is stopped")
            self._barriers = [b for b in self._barriers if not b.done]
            self._barriers.append(barrier)
            for i, srv in enumerate(self._servers):
                if not self._healthy[i]:
                    continue
                try:
                    arms.append((i, enqueue(srv)))
                except RuntimeError:
                    continue  # raced teardown — health sweep will quarantine
            if not arms:
                raise RuntimeError("no healthy replicas")
            for i, f in arms:
                barrier.arm(i, f)
        barrier.seal()
        return agg

    # -- dispatch + completion ----------------------------------------------

    def _dispatch_locked(self, req: _FleetRequest) -> bool:
        """Least-outstanding dispatch; bookkeeping is recorded BEFORE the
        replica submit so a synchronously-firing completion callback finds
        it consistent.  Returns False when no healthy replica accepts."""
        while True:
            cands = [i for i in range(len(self._servers)) if self._healthy[i]]
            if not cands:
                return False
            i = min(cands, key=lambda j: self._outstanding[j])
            self._outstanding[i] += 1
            self._inflight[i][req.rid] = req
            req.attempts += 1
            try:
                rep_fut = self._servers[i].submit(
                    req.q, req.qm, req.params,
                    deadline_at=req.deadline, t_arrival=req.t_arrival)
            except Exception:  # noqa: BLE001 — replica refused: not healthy
                self._inflight[i].pop(req.rid, None)
                self._outstanding[i] -= 1
                self._healthy[i] = False
                self._record_event(t=time.perf_counter(),
                                   event="quarantine", replica=i,
                                   reason="submit refused")
                continue
            req.current = rep_fut
            rep_fut.add_done_callback(
                lambda f, i=i, req=req: self._on_replica_done(i, req, f))
            return True

    def _on_replica_done(self, i: int, req: _FleetRequest, f: Future) -> None:
        outcome = None   # ("result", v) | ("exc", e) | ("cancel", None)
        lat = None
        with self._lock:
            if f is not req.current:
                return  # stale attempt — the request was re-dispatched
            if self._inflight[i].pop(req.rid, None) is not None:
                self._outstanding[i] -= 1
            if req.resolved:
                return
            t_done = time.perf_counter()
            if f.cancelled():
                # the replica was torn down mid-service without quarantine
                # having re-homed this request (e.g. direct server stop)
                if not self._stopping:
                    req.current = None
                    self._stats.record_redispatched()
                    if self._dispatch_locked(req):
                        return
                req.resolved = True
                outcome = ("cancel", None)
            else:
                exc = f.exception()
                req.resolved = True
                if exc is None:
                    req.future.snapshot_version = getattr(
                        f, "snapshot_version", None)
                    req.future.replica = i
                    lat = t_done - req.t_arrival
                    self._stats.record_completed(lat, t_done - req.t_submit,
                                                 t_done)
                    outcome = ("result", f.result())
                elif isinstance(exc, DeadlineExceeded):
                    lat = t_done - req.t_arrival
                    self._stats.record_expired()
                    outcome = ("exc", DeadlineExceeded(req.rid, lat))
                else:
                    self._stats.record_failed()
                    outcome = ("exc", exc)
        # resolve + SLO feedback outside the lock (client callbacks on the
        # fleet future must not run under the dispatch lock)
        kind, val = outcome
        if kind == "result":
            req.future.set_result(val)
        elif kind == "exc":
            req.future.set_exception(val)
        else:
            req.future.cancel()
        if lat is not None and self._slo is not None:
            # expiries feed the controller too — under total overload every
            # request can expire, and the SLO must still see the breach
            self._slo.observe(lat, t_done)

    # -- health -------------------------------------------------------------

    def quarantine(self, i: int, reason: str = "") -> int:
        """Take replica ``i`` out of rotation: stop dispatching to it,
        re-dispatch its in-flight requests to healthy replicas (stale
        attempts are fenced via ``req.current``), and excuse it from every
        pending write barrier.  Idempotent; returns how many requests were
        re-homed."""
        orphans: list[_FleetRequest] = []
        with self._lock:
            if not self._healthy[i]:
                return 0
            self._healthy[i] = False
            self._record_event(t=time.perf_counter(),
                               event="quarantine", replica=i,
                               reason=reason)
            log.warning("quarantining replica %d: %s", i, reason)
            reqs = [r for r in self._inflight[i].values() if not r.resolved]
            self._inflight[i].clear()
            self._outstanding[i] = 0
            for req in reqs:
                req.current = None  # fence: the old attempt can no longer win
                self._stats.record_redispatched()
                if not self._dispatch_locked(req):
                    req.resolved = True
                    orphans.append(req)
            barriers = [b for b in self._barriers if not b.done]
        for b in barriers:
            b.excuse(i)
        for req in orphans:
            req.future.set_exception(RuntimeError(
                f"no healthy replicas (request {req.rid})"))
        return len(reqs)

    def kill_replica(self, i: int, *, timeout: float = 5.0) -> int:
        """Chaos hook: quarantine + tear the replica's server down
        (cancelling whatever it still holds).  Every request it was serving
        is re-dispatched first, so nothing is dropped."""
        n = self.quarantine(i, reason="killed")
        self._servers[i].stop(drain=False, timeout=timeout)
        return n

    def _on_add_fail(self, i: int, exc: BaseException | None) -> None:
        self.quarantine(i, reason=f"mutation failed: {exc!r}")

    def _monitor_loop(self) -> None:
        while not self._stop_evt.wait(self._health_interval):
            now = time.perf_counter()
            with self._lock:
                stalled = [
                    i for i in range(len(self._servers))
                    if self._healthy[i] and self._outstanding[i] > 0
                    and now - self._servers[i].progress_time
                    > self._stall_timeout]
            for i in stalled:
                self.quarantine(
                    i, reason=f"no progress for > {self._stall_timeout:.2f}s "
                              f"with outstanding work")


__all__ = ["FleetStats", "Router"]
