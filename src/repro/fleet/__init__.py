"""Fleet serving: a replicated router over the online serving runtime.

The production operating point above :mod:`repro.serving`'s single-worker
``RetrieverServer``:

* :mod:`repro.fleet.replica` — replica factory (``retriever.clone()`` per
  replica: shared immutable index + OLS solver, private compile caches)
  and ladder×rung warmup.
* :mod:`repro.fleet.router` — :class:`Router`: least-outstanding dispatch
  over N replicas, fleet admission control (typed :class:`Overloaded`),
  per-request deadlines (typed :class:`DeadlineExceeded`), health
  monitoring with quarantine + exactly-once re-dispatch, and the
  snapshot-consistent ``add()`` write barrier.
* :mod:`repro.fleet.slo` — :class:`SLOController`: windowed-p99 breach →
  walk ``SearchParams`` down the pre-compiled nprobe/k' rung ladder,
  hysteretic recovery; :func:`build_rungs` builds the ladder.
"""
from repro.fleet.replica import clone_replicas, warm_replicas
from repro.fleet.router import FleetStats, Router
from repro.fleet.slo import RungTransition, SLOController, build_rungs
from repro.serving.server import DeadlineExceeded, Overloaded

__all__ = [
    "DeadlineExceeded",
    "FleetStats",
    "Overloaded",
    "Router",
    "RungTransition",
    "SLOController",
    "build_rungs",
    "clone_replicas",
    "warm_replicas",
]
