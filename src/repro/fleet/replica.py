"""Replica factory + warmup for the fleet router.

A fleet replica is a :class:`~repro.serving.RetrieverServer` over an
independent ``retriever.clone()`` — the immutable index and the OLS solver
state are shared (one build, N serving replicas; no re-train, no extra
corpus copies), compile caches are private per replica, and ``version``
numbering is common across the fleet so the router's write barrier can
stamp every replica to the same snapshot.

``warm_replicas`` pre-compiles every (rung, Tq bucket, batch bucket) shape
on every replica before traffic arrives, so neither dispatch skew nor an
SLO downshift ever pays an XLA compile in the latency path.
"""
from __future__ import annotations

from repro.serving.buckets import BucketLadder
from repro.serving.replay import warm_buckets


def clone_replicas(retriever, n: int) -> list:
    """``n`` independent replicas of a built retriever (clone semantics —
    see ``LemurRetriever.clone``).  Replica 0 is a clone too, so the
    caller's retriever is never mutated by fleet traffic."""
    if n < 1:
        raise ValueError(f"need at least one replica, got {n}")
    return [retriever.clone() for _ in range(n)]


def warm_replicas(replicas, ladder: BucketLadder, d: int,
                  params_list=(None,)) -> int:
    """Pre-compile the bucketed serving shapes for every params set (e.g.
    every SLO rung) on every replica.  Returns total shapes warmed — equals
    ``n_replicas * ladder.compile_bound(len(params_list))`` when the params
    sets are distinct."""
    n = 0
    for rep in replicas:
        for params in params_list:
            n += warm_buckets(rep, ladder, d, params)
    return n


__all__ = ["clone_replicas", "warm_replicas"]
