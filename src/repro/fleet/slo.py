"""SLO-adaptive search: walk ``SearchParams`` down a pre-compiled ladder.

Under overload, a fixed operating point has only one failure mode —
unbounded latency (or rejects).  LEMUR's first stage exposes two graceful
quality/latency knobs that do NOT change the compiled shape ladder:
``nprobe`` (IVF/token-pruning probe count) and ``k_prime`` (rerank
candidate budget).  :func:`build_rungs` pre-resolves a small ladder of
``SearchParams`` — rung 0 is the configured operating point, each further
rung halves ``nprobe`` (when the backend has one) and ``k_prime`` — and
:class:`SLOController` walks down one rung when the windowed p99 breaches
the target, recovering hysteretically (windowed p99 must clear
``recover_frac * target`` for ``hold`` consecutive evaluations) so the
controller never flaps at the boundary.

Every rung is a distinct resolved ``SearchParams``, so a fleet serving the
whole ladder pays ``BucketLadder.compile_bound(n_rungs)`` compiles — the
rungs must be warmed up-front (``fleet.replica.warm_replicas``) so a
downshift never triggers an XLA compile in the latency path.

Transitions are recorded as :class:`RungTransition` rows (and logged), so
benchmarks and CI can assert the controller engaged exactly when the SLO
was breached.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import threading

import numpy as np

from repro.retriever.params import SearchParams

log = logging.getLogger("repro.fleet.slo")


@dataclasses.dataclass(frozen=True)
class RungTransition:
    """One controller step, recorded at the moment it happened."""
    t: float                 # perf_counter-domain timestamp of the decision
    from_rung: int
    to_rung: int
    p99_ms: float            # the windowed p99 that triggered the step
    target_ms: float

    @property
    def direction(self) -> str:
        return "down" if self.to_rung > self.from_rung else "up"


def build_rungs(retriever, params: SearchParams | None = None,
                n_rungs: int = 3, *, nprobe_floor: int = 1,
                k_prime_floor: int | None = None) -> list[SearchParams]:
    """Pre-resolve the degradation ladder for ``retriever``.

    Rung 0 is ``params`` resolved against the build config; rung ``i+1``
    halves the backend ``nprobe`` (when the backend params carry one) and
    ``k_prime``, floored at ``nprobe_floor`` / ``k_prime_floor`` (default:
    ``max(k, 8)`` so the rerank can always fill the top-k).  Rungs that
    stop changing are dropped, so the list can be shorter than
    ``n_rungs`` — every entry is a distinct compiled operating point."""
    base = retriever.resolve(params)
    if k_prime_floor is None:
        k_prime_floor = max(int(base.k), 8)
    rungs = [base]
    cur = base
    for _ in range(n_rungs - 1):
        k_prime = max(int(cur.k_prime) // 2, k_prime_floor, int(base.k))
        bp = cur.backend
        if bp is not None and getattr(bp, "nprobe", None) is not None:
            bp = dataclasses.replace(
                bp, nprobe=max(int(bp.nprobe) // 2, nprobe_floor))
        nxt = retriever.resolve(dataclasses.replace(
            cur, k_prime=k_prime, backend=bp))
        if nxt == cur:
            break  # both knobs hit their floors — ladder is exhausted
        rungs.append(nxt)
        cur = nxt
    return rungs


class SLOController:
    """Hysteretic p99 controller over a pre-compiled rung ladder.

    ``observe(latency_s, t)`` feeds one completed request; every
    ``eval_every`` observations the controller evaluates the windowed p99:

    * **breach** (``p99 > target``): step DOWN one rung (cheaper params).
    * **clear** (``p99 < recover_frac * target`` for ``hold`` consecutive
      evaluations): step UP one rung (back toward full quality).

    The window is cleared on every transition so the next decision is based
    purely on the new rung's latencies — without this, pre-transition
    samples would keep the controller oscillating.  Thread-safe: the router
    calls ``observe`` from replica-completion callbacks and ``params()``
    from the submit path concurrently."""

    def __init__(self, rungs, target_p99_ms: float, *, window: int = 128,
                 min_window: int = 20, eval_every: int = 16,
                 recover_frac: float = 0.7, hold: int = 3):
        if not rungs:
            raise ValueError("need at least one rung")
        self._rungs = list(rungs)
        self.target_p99_ms = float(target_p99_ms)
        self._window: collections.deque[float] = collections.deque(
            maxlen=int(window))
        self._min_window = int(min_window)
        self._eval_every = int(eval_every)
        self._recover_frac = float(recover_frac)
        self._hold = int(hold)
        self._lock = threading.Lock()
        self._rung = 0
        self._since_eval = 0
        self._clear_streak = 0
        self._transitions: list[RungTransition] = []
        self._n_floor_breaches = 0

    # -- read side -----------------------------------------------------------

    @property
    def rungs(self) -> list[SearchParams]:
        return list(self._rungs)

    @property
    def rung(self) -> int:
        with self._lock:
            return self._rung

    def params(self) -> SearchParams:
        """The active rung's resolved SearchParams (what submit dispatches)."""
        with self._lock:
            return self._rungs[self._rung]

    @property
    def transitions(self) -> list[RungTransition]:
        with self._lock:
            return list(self._transitions)

    def windowed_p99_ms(self) -> float:
        with self._lock:
            lat = np.fromiter(self._window, np.float64)
        return float(np.percentile(lat, 99) * 1e3) if lat.size else float("nan")

    @property
    def n_floor_breaches(self) -> int:
        """Evaluations that breached the target while already at the floor
        rung — nothing left to shed; the fleet needs more replicas, not a
        cheaper operating point.  These must NOT clear the window or record
        a transition: the window keeps accumulating so the moment load
        drops, recovery hysteresis starts from real samples instead of an
        empty window."""
        with self._lock:
            return self._n_floor_breaches

    # -- write side ----------------------------------------------------------

    def observe(self, latency_s: float, t: float = 0.0) -> int:
        """Feed one completed (or expired) request latency; returns the
        active rung after any transition this observation triggered."""
        with self._lock:
            self._window.append(float(latency_s))
            self._since_eval += 1
            if (self._since_eval < self._eval_every
                    or len(self._window) < self._min_window):
                return self._rung
            self._since_eval = 0
            p99 = float(np.percentile(
                np.fromiter(self._window, np.float64), 99) * 1e3)
            if p99 > self.target_p99_ms:
                if self._rung < len(self._rungs) - 1:
                    self._step(self._rung + 1, p99, t)
                else:
                    # breach at the floor: no rung left to shed.  Do NOT
                    # clear the window and do NOT record a transition —
                    # recovery hysteresis must judge real samples the
                    # moment load drops (see n_floor_breaches)
                    self._n_floor_breaches += 1
                    self._clear_streak = 0
            elif p99 < self._recover_frac * self.target_p99_ms and self._rung > 0:
                self._clear_streak += 1
                if self._clear_streak >= self._hold:
                    self._step(self._rung - 1, p99, t)
            else:
                self._clear_streak = 0
            return self._rung

    def _step(self, to_rung: int, p99_ms: float, t: float) -> None:
        # lock held by observe()
        if to_rung == self._rung:
            return  # guard: a same-rung "step" would spuriously clear state
        tr = RungTransition(t, self._rung, to_rung, p99_ms, self.target_p99_ms)
        self._transitions.append(tr)
        log.info("SLO %s: rung %d -> %d (windowed p99 %.1fms, target %.1fms)",
                 tr.direction, tr.from_rung, tr.to_rung, p99_ms,
                 self.target_p99_ms)
        self._rung = to_rung
        self._clear_streak = 0
        self._window.clear()  # judge the new rung on its own samples only
        self._since_eval = 0


__all__ = ["RungTransition", "build_rungs", "SLOController"]
