"""Pallas TPU kernel: int8 scalar-quantized MIPS scan (Glass-style SQ on MXU).

Scores a block of fp32 queries against an int8-quantized latent corpus with
per-row scales, dequantizing INSIDE the kernel — HBM traffic for the corpus
is 4x lower than fp32, which matters because the latent scan is memory-bound
(arithmetic intensity 2·B flops/byte at int8).

    s = q (Bq, d') @ codes^T (d', Bm) * scales (Bm)

int8 codes are widened to bf16 for the MXU dot (int8×int8→int32 MXU paths
are not exposed via Pallas dot_general on all generations; bf16 exactly
represents ints up to 256).  The fp32 query is split into hi+lo bf16 parts
(two MXU passes) so the fp32-accumulated result matches the fp32 oracle to
~2^-16 relative — 2 bf16 matmuls still beat one fp32 matmul on the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mips_sq8_kernel(q_ref, codes_ref, scales_ref, out_ref):
    q = q_ref[...]                       # (Bq, d) fp32
    c = codes_ref[...].astype(jnp.bfloat16)  # (Bm, d) int8 -> bf16 (exact)
    q_hi = q.astype(jnp.bfloat16)
    q_lo = (q - q_hi.astype(jnp.float32)).astype(jnp.bfloat16)
    dot = lambda a: jax.lax.dot_general(
        a, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = dot(q_hi) + dot(q_lo)            # (Bq, Bm) fp32, hi/lo split
    out_ref[...] = s * scales_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("block_q", "block_m", "interpret"))
def mips_sq8(q, codes, scales, *, block_q: int = 128, block_m: int = 1024,
             interpret: bool = False):
    """q: (B, d) fp32; codes: (m, d) int8; scales: (m,) -> (B, m) fp32."""
    B, d = q.shape
    m = codes.shape[0]
    dp = -(-d // 128) * 128
    bp = -(-B // block_q) * block_q
    mp = -(-m // block_m) * block_m
    q_p = jnp.pad(q, ((0, bp - B), (0, dp - d)))
    c_p = jnp.pad(codes, ((0, mp - m), (0, dp - d)))
    s_p = jnp.pad(scales, (0, mp - m))

    out = pl.pallas_call(
        _mips_sq8_kernel,
        grid=(bp // block_q, mp // block_m),
        in_specs=[
            pl.BlockSpec((block_q, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, dp), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_q, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, mp), jnp.float32),
        interpret=interpret,
    )(q_p, c_p, s_p)
    return out[:B, :m]
