"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` takes the same logical arguments as the corresponding
``ops.*`` wrapper and is used by tests/benchmarks as ground truth."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def token_maxsim_ref(x, doc_tokens, doc_mask):
    """g(x)_l = max_{c in C_l} <c, x>.   x: (n, d); docs: (m, T, d) -> (n, m)."""
    s = jnp.einsum("nd,mtd->nmt", x, doc_tokens, preferred_element_type=jnp.float32)
    s = jnp.where(doc_mask[None], s, NEG)
    return jnp.max(s, axis=-1)


def maxsim_scores_ref(q, q_mask, doc_tokens, doc_mask):
    """MaxSim(X, C_j).  q: (B, Tq, d) -> (B, m)."""
    s = jnp.einsum("bqd,mtd->bmqt", q, doc_tokens, preferred_element_type=jnp.float32)
    s = jnp.where(doc_mask[None, :, None, :], s, NEG)
    best = jnp.max(s, axis=-1)
    best = jnp.where(q_mask[:, None, :], best, 0.0)
    return jnp.sum(best, axis=-1)


def fused_psi_ref(x, kernel, bias, ln_scale, ln_bias, eps: float = 1e-5):
    """LN(GELU(x @ kernel + bias)).  x: (n, d) -> (n, d')."""
    h = x @ kernel + bias
    h = jax.nn.gelu(h, approximate=True)
    hf = h.astype(jnp.float32)
    mu = jnp.mean(hf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(hf - mu), axis=-1, keepdims=True)
    y = (hf - mu) * jax.lax.rsqrt(var + eps) * ln_scale + ln_bias
    return y.astype(x.dtype)


def mips_sq8_ref(q, codes, scales):
    """fp32 queries x int8 corpus with per-row scales.
    q: (B, d); codes: (m, d) int8; scales: (m,) -> (B, m) fp32."""
    return (q @ codes.astype(jnp.float32).T) * scales[None, :]


def mips_sq8_batched_ref(q, codes, scales):
    """Per-query SQ8 MIPS: every query scores its OWN code list, all B rows
    in ONE contraction (the batched non-Pallas fallback for the IVF scan —
    no per-row vmap, no B one-row kernel launches).
    q: (B, d); codes: (B, n, d) int8; scales: (B, n) -> (B, n) fp32."""
    s = jnp.einsum("bd,bnd->bn", q, codes.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    return s * scales.astype(jnp.float32)


def ivf_scan_ref(q, probe, ids, vecs, scales=None):
    """Oracle for :func:`repro.kernels.gather_scan.ivf_probe_scan` — the
    gather-then-score path (what the legacy ``search_ivf`` computes).
    q: (B, d); probe: (B, nprobe); ids: (nlist, cap); vecs: (nlist, cap, d)
    fp32 or int8 (with scales (nlist, cap)) -> (B, nprobe, cap) fp32,
    pad slots at ``-inf``."""
    gids = jnp.take(ids, probe, axis=0)                 # (B, P, cap)
    gv = jnp.take(vecs, probe, axis=0)                  # (B, P, cap, d)
    if scales is not None:
        # same flattened contraction as mips_sq8_batched_ref (the legacy
        # SQ8 fallback), so fused-ref == legacy bit for bit on CPU
        B, P, cap, d = gv.shape
        s = jnp.einsum("bd,bnd->bn", q,
                       gv.reshape(B, P * cap, d).astype(jnp.float32),
                       preferred_element_type=jnp.float32).reshape(B, P, cap)
        s = s * jnp.take(scales, probe, axis=0).astype(jnp.float32)
    else:
        s = jnp.einsum("bd,bpcd->bpc", q, gv.astype(q.dtype),
                       preferred_element_type=jnp.float32)
    return jnp.where(gids >= 0, s, -jnp.inf)


def _residual_codec(centroids, values):
    # cuts are only used at ENCODE time; decode needs centroids + values
    from repro.anns.quantization import ResidualCodec
    return ResidualCodec(centroids=centroids, cuts=None, values=values)


def ivf_scan_res_ref(q, probe, ids, codes, centroids, values):
    """Oracle for :func:`repro.kernels.gather_scan.ivf_probe_res_scan` —
    gather the probed packed lists, decode host-side
    (``quantization.residual_decode`` with each vector's centroid id = its
    own cluster row), then the fp32 contraction.
    q: (B, d); probe: (B, nprobe); ids: (nlist, cap); codes: (nlist, cap,
    db) uint8; centroids: (nlist, d); values: (d, L) -> (B, nprobe, cap)
    fp32, pad slots ``-inf``."""
    from repro.anns.quantization import residual_decode
    codec = _residual_codec(centroids, values)
    gids = jnp.take(ids, probe, axis=0)                 # (B, P, cap)
    gc = jnp.take(codes, probe, axis=0)                 # (B, P, cap, db)
    cent = jnp.broadcast_to(probe[..., None], gids.shape)
    v = residual_decode(codec, cent, gc)                # (B, P, cap, d)
    s = jnp.einsum("bd,bpcd->bpc", q.astype(jnp.float32), v,
                   preferred_element_type=jnp.float32)
    return jnp.where(gids >= 0, s, -jnp.inf)


def rerank_scores_ref(q, q_mask, cand_ids, doc_tokens, doc_mask,
                      doc_scales=None):
    """Oracle for :func:`repro.kernels.gather_scan.rerank_gather_scores` —
    gathers the ``(B, k', Td, d)`` candidate slab and contracts it (what
    ``core.maxsim.rerank`` computes before its top-k).  ``-1`` candidates
    score doc 0 here; the caller masks them.
    q: (B, Tq, d); cand_ids: (B, k') -> (B, k') fp32 raw pair scores."""
    safe = jnp.maximum(cand_ids, 0)
    cd = jnp.take(doc_tokens, safe, axis=0)             # (B, k', Td, d)
    cm = jnp.take(doc_mask, safe, axis=0)               # (B, k', Td)
    s = jnp.einsum("bqd,bmtd->bmqt", q, cd.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    if doc_scales is not None:
        cs = jnp.take(doc_scales, safe, axis=0)
        s = s * cs.astype(jnp.float32)[:, :, None, :]
    s = jnp.where(cm[:, :, None, :], s, NEG)
    best = jnp.max(s, axis=-1)                          # (B, k', Tq)
    best = jnp.where(q_mask[:, None, :], best, 0.0)
    return jnp.sum(best, axis=-1)                       # (B, k')


def rerank_scores_paged_ref(q, q_mask, cand_ids, tok_pages, page_table,
                            n_tokens):
    """Oracle for :func:`repro.kernels.gather_scan.rerank_paged_scores` —
    materializes each candidate's tokens FROM PAGES (same gather as
    ``core.pages.gather_docs``) and contracts the slab.  ``-1``/dead
    candidates score all-NEG positions here; the caller masks them.
    q: (B, Tq, d); cand_ids: (B, k'); tok_pages: (P, page, d); page_table:
    (C, pmax); n_tokens: (C,) -> (B, k') fp32 raw pair scores."""
    safe = jnp.maximum(cand_ids, 0)
    table = jnp.take(page_table, safe, axis=0)          # (B, k', pmax)
    nt = jnp.where(cand_ids >= 0, jnp.take(n_tokens, safe, axis=0), 0)
    toks = jnp.take(tok_pages, jnp.maximum(table, 0), axis=0)
    B, kp, pmax, page, d = toks.shape
    toks = toks.reshape(B, kp, pmax * page, d)
    cm = jnp.arange(pmax * page, dtype=jnp.int32) < nt[..., None]
    s = jnp.einsum("bqd,bmtd->bmqt", q, toks.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    s = jnp.where(cm[:, :, None, :], s, NEG)
    best = jnp.max(s, axis=-1)                          # (B, k', Tq)
    best = jnp.where(q_mask[:, None, :], best, 0.0)
    return jnp.sum(best, axis=-1)                       # (B, k')


def rerank_scores_paged_res_ref(q, q_mask, cand_ids, cent_pages, code_pages,
                                page_table, n_tokens, centroids, values):
    """Oracle for :func:`repro.kernels.gather_scan.rerank_paged_res_scores`
    — decode the WHOLE compressed page pool host-side, then run the fp32
    paged oracle on the reconstructed pages (same math, and the decode is
    bit-identical to the in-kernel one-hot path).
    cent_pages: (P, page) int32; code_pages: (P, page, db) uint8."""
    from repro.anns.quantization import residual_decode
    codec = _residual_codec(centroids, values)
    tok_pages = residual_decode(codec, cent_pages, code_pages)  # (P, page, d)
    return rerank_scores_paged_ref(q, q_mask, cand_ids, tok_pages,
                                   page_table, n_tokens)


def query_fused_res_ref(q_tokens, q_mask, kernel, bias, ln_scale, ln_bias,
                        probe, ids, codes, centroids, values, *, kp: int):
    """Oracle for :func:`repro.kernels.query_fused.query_fused_res` — the
    legacy composition over a residual-compressed index: ψ-pool, decode-
    then-score probe scan, flat top-k' (same stable tie contract as
    :func:`query_fused_ref`)."""
    psi_q = psi_pool_ref(q_tokens, q_mask, kernel, bias, ln_scale, ln_bias)
    s = ivf_scan_res_ref(psi_q, probe, ids, codes, centroids, values)
    gids = jnp.take(ids, probe, axis=0)                 # (B, P, cap)
    B = s.shape[0]
    flat_s = s.reshape(B, -1)
    flat_i = gids.reshape(B, -1)
    kk = min(kp, flat_s.shape[1])
    top, pos = jax.lax.top_k(flat_s, kk)
    out_i = jnp.take_along_axis(flat_i, pos, axis=1)
    if kk < kp:
        top = jnp.pad(top, ((0, 0), (0, kp - kk)), constant_values=-jnp.inf)
        out_i = jnp.pad(out_i, ((0, 0), (0, kp - kk)), constant_values=-1)
    return top, out_i


def psi_pool_ref(q_tokens, q_mask, kernel, bias, ln_scale, ln_bias,
                 eps: float = 1e-5):
    """Pooled query latent: sum_t mask_t * psi(x_t)  (eq. 5).

    Op-for-op the same graph as ``core.model.pool_queries`` (dense → GELU →
    LayerNorm → mask → sum), spelled on the raw weight arrays so the
    one-launch oracle does not import the model layer.  For fp32 inputs the
    two jit to identical XLA programs — bit-identical pooled latents.
    q_tokens: (B, Tq, d) -> (B, d')."""
    h = q_tokens @ kernel.astype(q_tokens.dtype) + bias.astype(q_tokens.dtype)
    h = jax.nn.gelu(h, approximate=True)
    hf = h.astype(jnp.float32)
    mu = jnp.mean(hf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(hf - mu), axis=-1, keepdims=True)
    y = (hf - mu) * jax.lax.rsqrt(var + eps)
    y = y * ln_scale.astype(jnp.float32) + ln_bias.astype(jnp.float32)
    y = y.astype(q_tokens.dtype)
    if q_mask is not None:
        y = y * q_mask[..., None]
    return jnp.sum(y, axis=-2)


def query_fused_ref(q_tokens, q_mask, kernel, bias, ln_scale, ln_bias,
                    probe, ids, vecs, scales=None, *, kp: int):
    """Oracle for :func:`repro.kernels.query_fused.query_fused` — the
    legacy 3-launch composition: ψ-pool, gather-then-score probe scan, flat
    top-k' over the (B, nprobe*cap) strip (stable: earlier flat positions
    win ties, the contract the kernel's carried merge reproduces).
    Returns (scores (B, kp), ids (B, kp)) padded with (-inf, -1)."""
    psi_q = psi_pool_ref(q_tokens, q_mask, kernel, bias, ln_scale, ln_bias)
    s = ivf_scan_ref(psi_q, probe, ids, vecs, scales)   # (B, P, cap)
    gids = jnp.take(ids, probe, axis=0)                 # (B, P, cap)
    B = s.shape[0]
    flat_s = s.reshape(B, -1)
    flat_i = gids.reshape(B, -1)
    kk = min(kp, flat_s.shape[1])
    top, pos = jax.lax.top_k(flat_s, kk)
    out_i = jnp.take_along_axis(flat_i, pos, axis=1)
    if kk < kp:
        top = jnp.pad(top, ((0, 0), (0, kp - kk)), constant_values=-jnp.inf)
        out_i = jnp.pad(out_i, ((0, 0), (0, kp - kk)), constant_values=-1)
    return top, out_i


def mips_topk_ref(q, W, W_scales=None, valid=None, *, kp: int):
    """Oracle for :func:`repro.kernels.query_fused.mips_topk` — exactly the
    sharded serve step's legacy math: full (B, m) latent score matrix,
    optional per-row scales, invalid rows pinned to ``NEG`` (position ids
    kept), then ``jax.lax.top_k``.
    q: (B, d'); W: (m, d') fp32 or int8 -> (scores, position ids) (B, kp)."""
    s = q @ W.T.astype(q.dtype)
    if W_scales is not None:
        s = s * W_scales[None, :].astype(s.dtype)
    if valid is not None:
        s = jnp.where(valid[None, :], s, NEG)
    return jax.lax.top_k(s, kp)
