"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` takes the same logical arguments as the corresponding
``ops.*`` wrapper and is used by tests/benchmarks as ground truth."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def token_maxsim_ref(x, doc_tokens, doc_mask):
    """g(x)_l = max_{c in C_l} <c, x>.   x: (n, d); docs: (m, T, d) -> (n, m)."""
    s = jnp.einsum("nd,mtd->nmt", x, doc_tokens, preferred_element_type=jnp.float32)
    s = jnp.where(doc_mask[None], s, NEG)
    return jnp.max(s, axis=-1)


def maxsim_scores_ref(q, q_mask, doc_tokens, doc_mask):
    """MaxSim(X, C_j).  q: (B, Tq, d) -> (B, m)."""
    s = jnp.einsum("bqd,mtd->bmqt", q, doc_tokens, preferred_element_type=jnp.float32)
    s = jnp.where(doc_mask[None, :, None, :], s, NEG)
    best = jnp.max(s, axis=-1)
    best = jnp.where(q_mask[:, None, :], best, 0.0)
    return jnp.sum(best, axis=-1)


def fused_psi_ref(x, kernel, bias, ln_scale, ln_bias, eps: float = 1e-5):
    """LN(GELU(x @ kernel + bias)).  x: (n, d) -> (n, d')."""
    h = x @ kernel + bias
    h = jax.nn.gelu(h, approximate=True)
    hf = h.astype(jnp.float32)
    mu = jnp.mean(hf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(hf - mu), axis=-1, keepdims=True)
    y = (hf - mu) * jax.lax.rsqrt(var + eps) * ln_scale + ln_bias
    return y.astype(x.dtype)


def mips_sq8_ref(q, codes, scales):
    """fp32 queries x int8 corpus with per-row scales.
    q: (B, d); codes: (m, d) int8; scales: (m,) -> (B, m) fp32."""
    return (q @ codes.astype(jnp.float32).T) * scales[None, :]
