"""Pallas TPU kernels for the paper's compute hot spots.

maxsim    — token-level MaxSim (rerank + OLS target matrix; the paper's C++ loop)
fused_psi — ψ(x) = LN(GELU(xW'+b)) fused single-pass encoder
mips_sq8  — int8 scalar-quantized latent MIPS scan (Glass-style SQ)

``ops`` holds the jit'd wrappers with CPU-interpret dispatch; ``ref`` the
pure-jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
