"""Pallas TPU kernels for the paper's compute hot spots.

maxsim      — token-level MaxSim (rerank + OLS target matrix; the paper's C++ loop)
fused_psi   — ψ(x) = LN(GELU(xW'+b)) fused single-pass encoder
mips_sq8    — int8 scalar-quantized latent MIPS scan (Glass-style SQ)
gather_scan — gather-at-source serving kernels: scalar-prefetch IVF probe
              scan + fused candidate-gather MaxSim rerank (DMA the probed
              cluster / candidate tiles straight into VMEM instead of
              materializing the gathers in HBM)

``ops`` holds the jit'd wrappers with CPU-interpret dispatch; ``ref`` the
pure-jnp oracles.
"""
from repro.kernels import gather_scan, ops, ref

__all__ = ["gather_scan", "ops", "ref"]
