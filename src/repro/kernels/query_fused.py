"""Pallas TPU one-launch query kernels: ψ-projection → scan → in-kernel top-k'.

LEMUR's speed claim is that MaxSim retrieval collapses into a single latent
MIPS pass — yet the serving path still ran it as 3+ XLA launches with full
HBM round-trips between them (ψ latent projection → IVF probe scan → top-k'):
the ``(B, Tq, d')`` ψ features and the ``(B, nprobe, cap)`` score strip each
made an HBM write+read purely to cross a launch boundary.  These kernels
keep the whole pre-rerank pipeline inside ONE grid:

``query_fused`` — grid ``(B, nprobe)``, probe ids scalar-prefetched to SMEM
(``pltpu.PrefetchScalarGridSpec``, same scheme as ``gather_scan``):

* step ``(b, 0)`` computes ψ for query ``b``'s tokens in-kernel (the
  ``fused_psi`` matmul+GELU+LayerNorm body), masks and pools them
  (eq. 5) into a ``(1, d')`` VMEM scratch — the pooled query never touches
  HBM, and is carried across the ``nprobe`` minor grid steps (the TPU grid
  iterates the last dimension innermost, so scratch persists per ``b``);
* every step ``(b, p)`` DMAs exactly cluster ``probe[b, p]``'s ``(cap, d')``
  tile HBM→VMEM (BlockSpec index_map reads the prefetched id; consecutive
  steps double-buffer automatically — cluster ``p+1`` streams in while
  ``p``'s MXU contraction runs), scores it against the pooled query (fp32,
  or int8 codes dequantized in-kernel via the hi/lo-bf16 split), masks
  ``-1`` pad slots to ``-inf``;
* the per-step ``(1, cap)`` score strip is merged into a carried ``(1, k')``
  best-scores/best-ids strip (local ``jax.lax.top_k`` over
  ``concat([carried, strip])`` — carried first, so earlier flat positions
  win score ties exactly like the legacy flat top-k), and only the final
  ``(B, k')`` ids+scores are written to HBM.

Per query the HBM traffic is the probed source bytes streamed once plus
``k'`` result slots — the ``(B, Tq, d')`` feature tensor and the
``(B, nprobe, cap)`` strip never exist.

VMEM per step (Tq=32, d=128, d'=2048, cap=512, k'=1024, fp32): W' tile
1 MiB + token slab 16 KiB + pooled query 8 KiB + cluster tile 4 MiB (×2 for
the pipeline's double buffer) + heap strip 8 KiB ≈ 9.1 MiB — inside ~16 MiB
v5e VMEM.  cap=4096 at d'=2048 would need 32 MiB/tile in fp32: the SQ8 path
(8 MiB/tile) is the only one-launch option there.

``mips_topk`` — the dense-scan twin for the sharded serving step: grid
``(B, m/bm)`` over corpus tiles of the local latent shard, per-step MXU
contraction + validity mask (corpus pad rows → ``NEG``) + the same carried
top-k' merge.  Replaces ``psi_q @ W.T`` → mask → ``top_k`` (a full
``(B, m_loc)`` HBM score matrix) with one launch returning ``(B, k')``.

The in-kernel ``jax.lax.top_k`` merge is validated in interpret mode (the
tests' parity grid); on real TPUs it relies on Mosaic's sort lowering —
gate with ``use_one_launch=False`` if a toolchain rejects it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _merge_topk(best_s, best_i, s, ids):
    """Fold one (1, n) score/id strip into the carried (1, k') strip.

    The carried strip goes FIRST in the concat: its entries came from
    earlier flat positions, so a stable ``jax.lax.top_k`` (lowest index
    first on ties) reproduces the legacy flat top-k's tie-breaking, step by
    step, by induction."""
    kp = best_s.shape[1]
    cs = jnp.concatenate([best_s[...], s], axis=1)
    ci = jnp.concatenate([best_i[...], ids.astype(jnp.int32)], axis=1)
    top, pos = jax.lax.top_k(cs, kp)
    best_s[...] = top
    best_i[...] = jnp.take_along_axis(ci, pos, axis=1)


def _pool_psi(qt_ref, qm_ref, w_ref, b_ref, g_ref, beta_ref, eps):
    """The ``fused_psi`` kernel body + mask + pool: (1, Tq, d) -> (1, d')."""
    _, Tq, d = qt_ref.shape
    x = qt_ref[...].reshape(Tq, d)
    h = jax.lax.dot_general(
        x, w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    h = h + b_ref[...][None, :]
    h = jax.nn.gelu(h, approximate=True)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
    y = (h - mu) * jax.lax.rsqrt(var + eps)
    y = y * g_ref[...][None, :] + beta_ref[...][None, :]
    y = y * (qm_ref[...].reshape(Tq, 1) > 0)
    return jnp.sum(y, axis=0, keepdims=True)


def _query_fused_fp_kernel(probe_ref, qt_ref, qm_ref, w_ref, b_ref, g_ref,
                           beta_ref, ids_ref, vecs_ref, out_s_ref, out_i_ref,
                           q_acc, best_s, best_i, *, eps, nprobe):
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        q_acc[...] = _pool_psi(qt_ref, qm_ref, w_ref, b_ref, g_ref, beta_ref,
                               eps)
        best_s[...] = jnp.full(best_s.shape, -jnp.inf, jnp.float32)
        best_i[...] = jnp.full(best_i.shape, -1, jnp.int32)

    _, cap, dp = vecs_ref.shape
    s = jax.lax.dot_general(
        q_acc[...], vecs_ref[...].reshape(cap, dp), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (1, cap)
    s = jnp.where(ids_ref[...] >= 0, s, -jnp.inf)
    _merge_topk(best_s, best_i, s, ids_ref[...])

    @pl.when(p == nprobe - 1)
    def _flush():
        out_s_ref[...] = best_s[...]
        out_i_ref[...] = best_i[...]


def _query_fused_sq8_kernel(probe_ref, qt_ref, qm_ref, w_ref, b_ref, g_ref,
                            beta_ref, ids_ref, codes_ref, scales_ref,
                            out_s_ref, out_i_ref, q_acc, best_s, best_i, *,
                            eps, nprobe):
    # int8 cluster codes dequantized IN-KERNEL: hi/lo bf16 split of the fp32
    # pooled query (two MXU passes), per-slot scales folded into the strip —
    # same identity as gather_scan._ivf_scan_sq8_kernel (~2^-16 relative)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        q_acc[...] = _pool_psi(qt_ref, qm_ref, w_ref, b_ref, g_ref, beta_ref,
                               eps)
        best_s[...] = jnp.full(best_s.shape, -jnp.inf, jnp.float32)
        best_i[...] = jnp.full(best_i.shape, -1, jnp.int32)

    q = q_acc[...]
    _, cap, dp = codes_ref.shape
    c = codes_ref[...].reshape(cap, dp).astype(jnp.bfloat16)
    q_hi = q.astype(jnp.bfloat16)
    q_lo = (q - q_hi.astype(jnp.float32)).astype(jnp.bfloat16)
    dot = lambda a: jax.lax.dot_general(
        a, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = (dot(q_hi) + dot(q_lo)) * scales_ref[...]
    s = jnp.where(ids_ref[...] >= 0, s, -jnp.inf)
    _merge_topk(best_s, best_i, s, ids_ref[...])

    @pl.when(p == nprobe - 1)
    def _flush():
        out_s_ref[...] = best_s[...]
        out_i_ref[...] = best_i[...]


def _query_fused_res_kernel(probe_ref, qt_ref, qm_ref, w_ref, b_ref, g_ref,
                            beta_ref, ids_ref, codes_ref, cent_ref, val_ref,
                            out_s_ref, out_i_ref, q_acc, best_s, best_i, *,
                            eps, nprobe, bits):
    # residual-tier cluster lists decoded IN-KERNEL: packed 2/4-bit codes
    # unpack via shifts/ANDs, per-dim values via a select-sum over the L
    # static levels, and the cluster's OWN centroid row (IVF residual
    # storage) arrives as a (1, d') tile DMA'd by the same prefetched probe
    # id — the fp32 cluster list never exists in HBM (gather_scan.
    # _ivf_scan_res_kernel, fused behind the pooled-ψ carry)
    from repro.kernels.gather_scan import _residual_values, _unpack_codes_i32

    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        q_acc[...] = _pool_psi(qt_ref, qm_ref, w_ref, b_ref, g_ref, beta_ref,
                               eps)
        best_s[...] = jnp.full(best_s.shape, -jnp.inf, jnp.float32)
        best_i[...] = jnp.full(best_i.shape, -1, jnp.int32)

    _, cap, db = codes_ref.shape
    idx = _unpack_codes_i32(codes_ref[...].reshape(cap, db), bits=bits)
    v = _residual_values(idx, val_ref[...]) + cent_ref[...]   # (cap, d')
    s = jax.lax.dot_general(
        q_acc[...], v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (1, cap)
    s = jnp.where(ids_ref[...] >= 0, s, -jnp.inf)
    _merge_topk(best_s, best_i, s, ids_ref[...])

    @pl.when(p == nprobe - 1)
    def _flush():
        out_s_ref[...] = best_s[...]
        out_i_ref[...] = best_i[...]


@functools.partial(jax.jit, static_argnames=("kp", "interpret"))
def query_fused(q_tokens, q_mask, kernel, bias, ln_scale, ln_bias, probe,
                ids, vecs, scales=None, *, kp: int, interpret: bool = False,
                eps: float = 1e-5):
    """One-launch fused query: pooled ψ(X) + probed IVF scan + top-k'.

    q_tokens: (B, Tq, d); kernel/bias/ln_*: the ψ weights (d, d') / (d',);
    probe: (B, nprobe) int32 cluster ids (the query-scale probe-select
    prelude runs in XLA — see ``ops.fused_query``); ids: (nlist, cap) int32
    (-1 padded); vecs: (nlist, cap, d') fp32 — or int8 codes with scales:
    (nlist, cap) — returns (scores (B, kp) fp32, ids (B, kp) int32), rows
    padded with ``(-inf, -1)`` when fewer than ``kp`` real candidates were
    probed.  Only these two (B, kp) strips ever reach HBM.
    """
    B, Tq, d = q_tokens.shape
    nprobe = probe.shape[1]
    nlist, cap = ids.shape
    dp = kernel.shape[1]
    qm = q_mask.astype(jnp.int8)
    in_specs = [
        pl.BlockSpec((1, Tq, d), lambda b, p, pr: (b, 0, 0)),
        pl.BlockSpec((1, Tq), lambda b, p, pr: (b, 0)),
        pl.BlockSpec((d, dp), lambda b, p, pr: (0, 0)),
        pl.BlockSpec((dp,), lambda b, p, pr: (0,)),
        pl.BlockSpec((dp,), lambda b, p, pr: (0,)),
        pl.BlockSpec((dp,), lambda b, p, pr: (0,)),
        pl.BlockSpec((1, cap), lambda b, p, pr: (pr[b, p], 0)),
        pl.BlockSpec((1, cap, dp), lambda b, p, pr: (pr[b, p], 0, 0)),
    ]
    args = [q_tokens, qm, kernel, bias, ln_scale, ln_bias, ids, vecs]
    kfn = functools.partial(_query_fused_fp_kernel, eps=eps, nprobe=nprobe)
    if scales is not None:
        in_specs.append(pl.BlockSpec((1, cap), lambda b, p, pr: (pr[b, p], 0)))
        args.append(scales)
        kfn = functools.partial(_query_fused_sq8_kernel, eps=eps,
                                nprobe=nprobe)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nprobe),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((1, kp), lambda b, p, pr: (b, 0)),
                   pl.BlockSpec((1, kp), lambda b, p, pr: (b, 0))],
        scratch_shapes=[pltpu.VMEM((1, dp), jnp.float32),
                        pltpu.VMEM((1, kp), jnp.float32),
                        pltpu.VMEM((1, kp), jnp.int32)],
    )
    return pl.pallas_call(
        kfn,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, kp), jnp.float32),
                   jax.ShapeDtypeStruct((B, kp), jnp.int32)],
        interpret=interpret,
    )(probe.astype(jnp.int32), *args)


@functools.partial(jax.jit, static_argnames=("kp", "interpret"))
def query_fused_res(q_tokens, q_mask, kernel, bias, ln_scale, ln_bias, probe,
                    ids, codes, centroids, rq_values, *, kp: int,
                    interpret: bool = False, eps: float = 1e-5):
    """One-launch fused query over a RESIDUAL-compressed IVF index.

    Same contract as :func:`query_fused`, with the cluster lists stored as
    packed residual codes: codes (nlist, cap, db) uint8 coded against each
    cluster's own centroid row; centroids (nlist, d') fp32 (the SAME table
    the probe-select prelude scores); rq_values (d', L) fp32.  Returns
    (scores (B, kp) fp32, ids (B, kp) int32) padded with ``(-inf, -1)``.
    """
    B, Tq, d = q_tokens.shape
    nprobe = probe.shape[1]
    nlist, cap = ids.shape
    db = codes.shape[2]
    dp = kernel.shape[1]
    L = rq_values.shape[1]
    bits = int(L).bit_length() - 1
    qm = q_mask.astype(jnp.int8)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nprobe),
        in_specs=[
            pl.BlockSpec((1, Tq, d), lambda b, p, pr: (b, 0, 0)),
            pl.BlockSpec((1, Tq), lambda b, p, pr: (b, 0)),
            pl.BlockSpec((d, dp), lambda b, p, pr: (0, 0)),
            pl.BlockSpec((dp,), lambda b, p, pr: (0,)),
            pl.BlockSpec((dp,), lambda b, p, pr: (0,)),
            pl.BlockSpec((dp,), lambda b, p, pr: (0,)),
            pl.BlockSpec((1, cap), lambda b, p, pr: (pr[b, p], 0)),
            pl.BlockSpec((1, cap, db), lambda b, p, pr: (pr[b, p], 0, 0)),
            pl.BlockSpec((1, dp), lambda b, p, pr: (pr[b, p], 0)),
            pl.BlockSpec((dp, L), lambda b, p, pr: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((1, kp), lambda b, p, pr: (b, 0)),
                   pl.BlockSpec((1, kp), lambda b, p, pr: (b, 0))],
        scratch_shapes=[pltpu.VMEM((1, dp), jnp.float32),
                        pltpu.VMEM((1, kp), jnp.float32),
                        pltpu.VMEM((1, kp), jnp.int32)],
    )
    return pl.pallas_call(
        functools.partial(_query_fused_res_kernel, eps=eps, nprobe=nprobe,
                          bits=bits),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, kp), jnp.float32),
                   jax.ShapeDtypeStruct((B, kp), jnp.int32)],
        interpret=interpret,
    )(probe.astype(jnp.int32), q_tokens, qm, kernel, bias, ln_scale, ln_bias,
      ids, codes, centroids, rq_values)


# --------------------------------------------------------------------------
# dense-scan twin: fused latent MIPS + in-kernel top-k' (the sharded path)
# --------------------------------------------------------------------------

def _mips_topk_fp_kernel(q_ref, w_ref, valid_ref, out_s_ref, out_i_ref,
                         best_s, best_i, *, nt, bm):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        best_s[...] = jnp.full(best_s.shape, -jnp.inf, jnp.float32)
        best_i[...] = jnp.full(best_i.shape, -1, jnp.int32)

    s = jax.lax.dot_general(
        q_ref[...], w_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (1, bm)
    ids = t * bm + jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1)
    s = jnp.where(valid_ref[...] > 0, s, NEG)
    _merge_topk(best_s, best_i, s, ids)

    @pl.when(t == nt - 1)
    def _flush():
        out_s_ref[...] = best_s[...]
        out_i_ref[...] = best_i[...]


def _mips_topk_sq8_kernel(q_ref, codes_ref, ws_ref, valid_ref, out_s_ref,
                          out_i_ref, best_s, best_i, *, nt, bm):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        best_s[...] = jnp.full(best_s.shape, -jnp.inf, jnp.float32)
        best_i[...] = jnp.full(best_i.shape, -1, jnp.int32)

    q = q_ref[...]
    c = codes_ref[...].astype(jnp.bfloat16)
    q_hi = q.astype(jnp.bfloat16)
    q_lo = (q - q_hi.astype(jnp.float32)).astype(jnp.bfloat16)
    dot = lambda a: jax.lax.dot_general(
        a, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = (dot(q_hi) + dot(q_lo)) * ws_ref[...]
    ids = t * bm + jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1)
    s = jnp.where(valid_ref[...] > 0, s, NEG)
    _merge_topk(best_s, best_i, s, ids)

    @pl.when(t == nt - 1)
    def _flush():
        out_s_ref[...] = best_s[...]
        out_i_ref[...] = best_i[...]


@functools.partial(jax.jit, static_argnames=("kp", "block_m", "interpret"))
def mips_topk(q, W, W_scales=None, valid=None, *, kp: int,
              block_m: int = 512, interpret: bool = False):
    """Fused latent scan + top-k': q (B, d') x W (m, d') -> top-k' of each
    row without materializing the (B, m) score matrix in HBM.

    ``W`` is fp32 — or int8 codes with per-row ``W_scales`` (m,).  ``valid``
    (m,) bool masks rows to ``NEG`` (score only — their POSITION ids are
    kept, matching the sharded serve step's pad-row convention); the rows
    this wrapper pads up to the tile multiple are masked the same way and,
    sitting at the highest positions, can never displace a real row.
    Returns (scores (B, kp) fp32, ids (B, kp) int32 positions).
    """
    B, dp = q.shape
    m = W.shape[0]
    bm = min(block_m, m)
    mp = -(-m // bm) * bm
    if valid is None:
        valid = jnp.ones((m,), bool)
    valid = jnp.pad(valid, (0, mp - m)).reshape(1, mp).astype(jnp.int8)
    Wp = jnp.pad(W, ((0, mp - m), (0, 0)))
    nt = mp // bm
    in_specs = [
        pl.BlockSpec((1, dp), lambda b, t: (b, 0)),
        pl.BlockSpec((bm, dp), lambda b, t: (t, 0)),
    ]
    args = [q, Wp]
    if W_scales is not None:
        in_specs.append(pl.BlockSpec((1, bm), lambda b, t: (0, t)))
        args.append(jnp.pad(W_scales, (0, mp - m)).reshape(1, mp))
        kfn = functools.partial(_mips_topk_sq8_kernel, nt=nt, bm=bm)
    else:
        kfn = functools.partial(_mips_topk_fp_kernel, nt=nt, bm=bm)
    in_specs.append(pl.BlockSpec((1, bm), lambda b, t: (0, t)))
    args.append(valid)
    return pl.pallas_call(
        kfn,
        grid=(B, nt),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((1, kp), lambda b, t: (b, 0)),
                   pl.BlockSpec((1, kp), lambda b, t: (b, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, kp), jnp.float32),
                   jax.ShapeDtypeStruct((B, kp), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, kp), jnp.float32),
                        pltpu.VMEM((1, kp), jnp.int32)],
        interpret=interpret,
    )(*args)
