"""jit'd public wrappers over the Pallas kernels with platform dispatch.

On TPU the Pallas path compiles natively; on this CPU container the kernels
run in ``interpret=True`` mode (Python-interpreted kernel body — exact
semantics, slow), so system-level code defaults to the pure-jnp reference
unless ``use_kernel=True`` is forced (tests do force it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fused_psi as _fp
from repro.kernels import gather_scan as _gs
from repro.kernels import maxsim as _mx
from repro.kernels import mips_sq8 as _mq
from repro.kernels import query_fused as _qf
from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def token_maxsim(x, doc_tokens, doc_mask, *, use_kernel: bool | None = None,
                 block_n: int = 256, block_m: int = 64):
    """(n, d) x (m, T, d) -> (n, m) fp32 per-token MaxSim contributions."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return ref.token_maxsim_ref(x, doc_tokens, doc_mask)
    return _mx.token_maxsim(
        x, doc_tokens, doc_mask, block_n=block_n, block_m=block_m,
        interpret=not _on_tpu(),
    )


def maxsim_scores(q, q_mask, doc_tokens, doc_mask, *, use_kernel: bool | None = None):
    """(B, Tq, d) -> (B, m): full MaxSim via the token kernel + masked sum."""
    B, Tq, d = q.shape
    g = token_maxsim(q.reshape(B * Tq, d), doc_tokens, doc_mask, use_kernel=use_kernel)
    g = g.reshape(B, Tq, -1)
    return jnp.sum(jnp.where(q_mask[:, :, None], g, 0.0), axis=1)


def fused_psi(x, psi_params, *, use_kernel: bool | None = None, block_n: int = 256):
    """Fused ψ(x) (see repro.core.model.psi_apply for the unfused version)."""
    kernel = psi_params["dense"]["kernel"]
    bias = psi_params["dense"]["bias"]
    g = psi_params["ln"]["scale"]
    b = psi_params["ln"]["bias"]
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return ref.fused_psi_ref(x, kernel, bias, g, b)
    return _fp.fused_psi(x, kernel, bias, g, b, block_n=block_n,
                         interpret=not _on_tpu())


def mips_sq8(q, codes, scales, *, use_kernel: bool | None = None,
             block_q: int = 128, block_m: int = 1024):
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return ref.mips_sq8_ref(q, codes, scales)
    return _mq.mips_sq8(q, codes, scales, block_q=block_q, block_m=block_m,
                        interpret=not _on_tpu())


def mips_sq8_batched(q, codes, scales, *, use_kernel: bool | None = None,
                     block_q: int = 128, block_m: int = 1024):
    """Per-query SQ8 scan: q (B, d) x codes (B, n, d) / scales (B, n) ->
    (B, n), every query scoring its OWN gathered list.

    The fallback is ONE batched contraction (``ref.mips_sq8_batched_ref``)
    instead of B one-row ``mips_sq8`` calls (1/128 MXU tile utilization at
    ``block_q=128``).  The kernel path flattens the per-query lists into a
    single ``mips_sq8`` launch — the B query rows fill a whole MXU tile,
    whose off-diagonal strips were dead weight in the one-row calls anyway
    — and slices each query's own strip back out.  Prefer
    :func:`fused_ivf_scan` on TPU: it skips the HBM gather entirely.
    """
    B, n, d = codes.shape
    # the flattened launch materializes a (B, B*n) score matrix before the
    # strip slice; past ~256 MB that HBM spike costs more than the tile-
    # utilization win, so large shapes take the single-contraction fallback
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel or B * B * n * 4 > 256 * 2**20:
        return ref.mips_sq8_batched_ref(q, codes, scales)
    full = _mq.mips_sq8(q, codes.reshape(B * n, d), scales.reshape(B * n),
                        block_q=block_q, block_m=block_m,
                        interpret=not _on_tpu())            # (B, B*n)
    strip = jnp.arange(B)[:, None] * n + jnp.arange(n)[None, :]
    return jnp.take_along_axis(full, strip, axis=1)         # (B, n)


def fused_ivf_scan(q, probe, ids, vecs, scales=None, *,
                   use_kernel: bool | None = None):
    """Gather-at-source IVF probe scan: score the probed cluster lists
    without materializing the ``(B, nprobe, cap, d)`` gather in HBM.

    q: (B, d); probe: (B, nprobe) int32; ids/vecs/scales are the IVF
    index's padded cluster lists -> (B, nprobe, cap) fp32 scores, pad slots
    ``-inf``.  TPU: the scalar-prefetch Pallas kernel
    (:func:`repro.kernels.gather_scan.ivf_probe_scan`); otherwise the
    gather-then-score oracle (identical math to the legacy path).
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return ref.ivf_scan_ref(q, probe, ids, vecs, scales)
    return _gs.ivf_probe_scan(q, probe, ids, vecs, scales,
                              interpret=not _on_tpu())


def fused_ivf_scan_res(q, probe, ids, codes, centroids, values, *,
                       use_kernel: bool | None = None):
    """Residual-tier IVF probe scan: the packed 2/4-bit cluster lists are
    decoded at the source (in-kernel on TPU) — the fp32 lists never exist.

    q: (B, d); probe: (B, nprobe) int32; ids (nlist, cap) / codes (nlist,
    cap, db) uint8 coded against each cluster's own centroid; centroids
    (nlist, d); values (d, L) -> (B, nprobe, cap) fp32, pad slots ``-inf``.
    Decode is bit-identical between the kernel (one-hot/select-sum) and the
    host oracle (``quantization.residual_decode``), so both paths agree.
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return ref.ivf_scan_res_ref(q, probe, ids, codes, centroids, values)
    return _gs.ivf_probe_res_scan(q, probe, ids, codes, centroids, values,
                                  interpret=not _on_tpu())


def fused_rerank(q, q_mask, cand_ids, doc_tokens, doc_mask, k: int, *,
                 doc_scales=None, use_kernel: bool | None = None):
    """Fused candidate-gather exact MaxSim rerank -> (scores, ids), (B, k).

    Drop-in for ``core.maxsim.rerank`` (same ``-1``-pad contract: pads
    score ``NEG`` and can only surface, id ``-1``, when a row has fewer
    than ``k`` real candidates; rows are padded out to ``k`` when
    ``k > k'``).  ``doc_scales`` selects the SQ8 token store (per-token
    scales folded into the score rows).  TPU: the scalar-prefetch Pallas
    kernel; otherwise the gather-then-contract oracle.
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        s = ref.rerank_scores_ref(q, q_mask, cand_ids, doc_tokens, doc_mask,
                                  doc_scales)
    else:
        s = _gs.rerank_gather_scores(q, q_mask, cand_ids, doc_tokens,
                                     doc_mask, doc_scales,
                                     interpret=not _on_tpu())
    s = jnp.where(cand_ids >= 0, s, ref.NEG)
    kk = min(k, s.shape[1])
    top, idx = jax.lax.top_k(s, kk)
    out_ids = jnp.take_along_axis(cand_ids, idx, axis=1)
    if kk < k:
        top = jnp.pad(top, ((0, 0), (0, k - kk)), constant_values=ref.NEG)
        out_ids = jnp.pad(out_ids, ((0, 0), (0, k - kk)), constant_values=-1)
    return top, out_ids


def fused_rerank_paged(q, q_mask, cand_ids, tok_pages, page_table, n_tokens,
                       k: int, *, use_kernel: bool | None = None):
    """Paged-corpus exact MaxSim rerank -> (scores, ids), (B, k).

    The corpus arrives as its paged-store pieces (``core.pages.PagedStore``:
    token pages + per-doc page table + token counts) instead of dense
    ``(m, Td, d)`` slabs; candidates' page ids are fed to the kernel through
    SMEM scalar prefetch.  Same ``-1``-pad contract as :func:`fused_rerank`,
    and — because per-token dots are unchanged and the token max is
    order-independent — bit-identical scores to the dense paths on the same
    docs.  TPU: the scalar-prefetch Pallas kernel
    (:func:`repro.kernels.gather_scan.rerank_paged_scores`); otherwise the
    gather-from-pages oracle.  fp32 only (the SQ8 token tier stays on the
    dense sharded path).
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        s = ref.rerank_scores_paged_ref(q, q_mask, cand_ids, tok_pages,
                                        page_table, n_tokens)
    else:
        s = _gs.rerank_paged_scores(q, q_mask, cand_ids, tok_pages,
                                    page_table, n_tokens,
                                    interpret=not _on_tpu())
    s = jnp.where(cand_ids >= 0, s, ref.NEG)
    kk = min(k, s.shape[1])
    top, idx = jax.lax.top_k(s, kk)
    out_ids = jnp.take_along_axis(cand_ids, idx, axis=1)
    if kk < k:
        top = jnp.pad(top, ((0, 0), (0, k - kk)), constant_values=ref.NEG)
        out_ids = jnp.pad(out_ids, ((0, 0), (0, k - kk)), constant_values=-1)
    return top, out_ids


def fused_rerank_paged_res(q, q_mask, cand_ids, cent_pages, code_pages,
                           page_table, n_tokens, centroids, values, k: int,
                           *, use_kernel: bool | None = None):
    """Residual-tier paged MaxSim rerank -> (scores, ids), (B, k).

    The compressed twin of :func:`fused_rerank_paged`: candidates' token
    pages arrive as centroid-id pages (P, page) int32 + packed residual
    pages (P, page, db) uint8 plus the codec tables, decoded in VMEM on the
    TPU path (host-side by the oracle — bit-identical).  Same ``-1``-pad
    contract as :func:`fused_rerank`.
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        s = ref.rerank_scores_paged_res_ref(q, q_mask, cand_ids, cent_pages,
                                            code_pages, page_table, n_tokens,
                                            centroids, values)
    else:
        s = _gs.rerank_paged_res_scores(q, q_mask, cand_ids, cent_pages,
                                        code_pages, page_table, n_tokens,
                                        centroids, values,
                                        interpret=not _on_tpu())
    s = jnp.where(cand_ids >= 0, s, ref.NEG)
    kk = min(k, s.shape[1])
    top, idx = jax.lax.top_k(s, kk)
    out_ids = jnp.take_along_axis(cand_ids, idx, axis=1)
    if kk < k:
        top = jnp.pad(top, ((0, 0), (0, k - kk)), constant_values=ref.NEG)
        out_ids = jnp.pad(out_ids, ((0, 0), (0, k - kk)), constant_values=-1)
    return top, out_ids


def fused_query(q_tokens, q_mask, psi_params, centroids, ids, vecs,
                scales=None, *, nprobe: int, kp: int,
                use_kernel: bool | None = None):
    """One-launch first stage: ψ-pool + IVF probe scan + in-kernel top-k'.

    The probe SELECTION (pooled query vs the tiny (nlist, d') centroid
    table + ``top_k(nprobe)``) runs as a query-scale XLA prelude in both
    paths — it feeds the kernel's SMEM scalar prefetch, so it cannot live
    inside the grid it steers.  Everything corpus-scale — the per-cluster
    gather, MXU scoring, and the top-k' reduction — is one Pallas launch on
    TPU (ψ is recomputed in-kernel at grid step 0: cheaper than an HBM
    round-trip of the (B, d') latent).  Returns (scores, ids), (B, kp),
    short rows padded with ``(-inf, -1)`` exactly like the legacy flat
    top-k over the gathered strip.
    """
    kernel = psi_params["dense"]["kernel"]
    bias = psi_params["dense"]["bias"]
    g = psi_params["ln"]["scale"]
    b = psi_params["ln"]["bias"]
    psi_q = ref.psi_pool_ref(q_tokens, q_mask, kernel, bias, g, b)
    cs = psi_q @ centroids.T
    _, probe = jax.lax.top_k(cs, nprobe)
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return ref.query_fused_ref(q_tokens, q_mask, kernel, bias, g, b,
                                   probe, ids, vecs, scales, kp=kp)
    return _qf.query_fused(q_tokens, q_mask, kernel, bias, g, b, probe, ids,
                           vecs, scales, kp=kp, interpret=not _on_tpu())


def fused_query_res(q_tokens, q_mask, psi_params, centroids, ids, codes,
                    rq_values, *, nprobe: int, kp: int,
                    use_kernel: bool | None = None):
    """One-launch first stage over a RESIDUAL-compressed IVF index.

    Same contract as :func:`fused_query`; the cluster lists are packed
    2/4-bit residual codes (nlist, cap, db) coded against each cluster's
    own centroid row (the same (nlist, d') table the probe-select prelude
    scores), with rq_values (d', L) the per-dim reconstruction tables.
    """
    kernel = psi_params["dense"]["kernel"]
    bias = psi_params["dense"]["bias"]
    g = psi_params["ln"]["scale"]
    b = psi_params["ln"]["bias"]
    psi_q = ref.psi_pool_ref(q_tokens, q_mask, kernel, bias, g, b)
    cs = psi_q @ centroids.T
    _, probe = jax.lax.top_k(cs, nprobe)
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return ref.query_fused_res_ref(q_tokens, q_mask, kernel, bias, g, b,
                                       probe, ids, codes, centroids,
                                       rq_values, kp=kp)
    return _qf.query_fused_res(q_tokens, q_mask, kernel, bias, g, b, probe,
                               ids, codes, centroids, rq_values, kp=kp,
                               interpret=not _on_tpu())


def mips_topk_fused(q, W, W_scales, kp: int, valid=None, *,
                    use_kernel: bool | None = None, block_m: int = 512):
    """Fused dense latent scan + in-kernel top-k' (the sharded serve step's
    one-launch first stage): never materializes the (B, m) score matrix.

    Contract matches the legacy ``psi_q @ W.T`` → mask → ``top_k``: ids are
    corpus POSITIONS (``valid=False`` rows keep their position but score
    ``NEG``, so with ``kp`` ≤ #valid rows they never surface).  ``valid``
    may be a traced array — the sharded path's pad mask depends on
    ``jax.lax.axis_index``.  Returns (scores, ids), (B, kp).
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return ref.mips_topk_ref(q, W, W_scales, valid, kp=kp)
    return _qf.mips_topk(q, W, W_scales, valid, kp=kp, block_m=block_m,
                         interpret=not _on_tpu())
