"""jit'd public wrappers over the Pallas kernels with platform dispatch.

On TPU the Pallas path compiles natively; on this CPU container the kernels
run in ``interpret=True`` mode (Python-interpreted kernel body — exact
semantics, slow), so system-level code defaults to the pure-jnp reference
unless ``use_kernel=True`` is forced (tests do force it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fused_psi as _fp
from repro.kernels import maxsim as _mx
from repro.kernels import mips_sq8 as _mq
from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def token_maxsim(x, doc_tokens, doc_mask, *, use_kernel: bool | None = None,
                 block_n: int = 256, block_m: int = 64):
    """(n, d) x (m, T, d) -> (n, m) fp32 per-token MaxSim contributions."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return ref.token_maxsim_ref(x, doc_tokens, doc_mask)
    return _mx.token_maxsim(
        x, doc_tokens, doc_mask, block_n=block_n, block_m=block_m,
        interpret=not _on_tpu(),
    )


def maxsim_scores(q, q_mask, doc_tokens, doc_mask, *, use_kernel: bool | None = None):
    """(B, Tq, d) -> (B, m): full MaxSim via the token kernel + masked sum."""
    B, Tq, d = q.shape
    g = token_maxsim(q.reshape(B * Tq, d), doc_tokens, doc_mask, use_kernel=use_kernel)
    g = g.reshape(B, Tq, -1)
    return jnp.sum(jnp.where(q_mask[:, :, None], g, 0.0), axis=1)


def fused_psi(x, psi_params, *, use_kernel: bool | None = None, block_n: int = 256):
    """Fused ψ(x) (see repro.core.model.psi_apply for the unfused version)."""
    kernel = psi_params["dense"]["kernel"]
    bias = psi_params["dense"]["bias"]
    g = psi_params["ln"]["scale"]
    b = psi_params["ln"]["bias"]
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return ref.fused_psi_ref(x, kernel, bias, g, b)
    return _fp.fused_psi(x, kernel, bias, g, b, block_n=block_n,
                         interpret=not _on_tpu())


def mips_sq8(q, codes, scales, *, use_kernel: bool | None = None,
             block_q: int = 128, block_m: int = 1024):
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return ref.mips_sq8_ref(q, codes, scales)
    return _mq.mips_sq8(q, codes, scales, block_q=block_q, block_m=block_m,
                        interpret=not _on_tpu())
