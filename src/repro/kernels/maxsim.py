"""Pallas TPU kernel for token-level MaxSim (the paper's rerank/target hot loop).

Computes g(x)_l = max_{c∈C_l}⟨c,x⟩ for a block of query tokens against a
block of documents.  The (m, T, d) document store is viewed as an
(m·T, d) matrix so the inner contraction is ONE MXU matmul per tile:

    scores = x_tile (Bn, d) @ docs_tile^T (d, Bm·T)   ->  (Bn, Bm·T)
    masked max over T                                  ->  (Bn, Bm)

VMEM budget per tile (defaults Bn=256, Bm=64, T=32, d=128, fp32):
  x 256·128·4 = 128 KiB, docs 64·32·128·4 = 1 MiB, scores 256·2048·4 = 2 MiB
  — comfortably inside the ~16 MiB v5e VMEM, MXU-aligned (128 lanes).

The same kernel serves both uses in the paper: the OLS/MLP *target matrix*
(§3.1/§4.3) and exact *reranking* (ops.maxsim_scores sums the per-token
maxima over the query's tokens).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _token_maxsim_kernel(x_ref, docs_ref, mask_ref, out_ref):
    # x: (Bn, d); docs: (Bm, T, d); mask: (Bm, T) float (1/0); out: (Bn, Bm)
    x = x_ref[...]
    docs = docs_ref[...]
    mask = mask_ref[...]
    Bm, T, d = docs.shape
    flat = docs.reshape(Bm * T, d)
    s = jax.lax.dot_general(
        x, flat, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Bn, Bm*T)
    s = s.reshape(x.shape[0], Bm, T)
    s = jnp.where(mask[None] > 0, s, NEG)
    out_ref[...] = jnp.max(s, axis=-1)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret")
)
def token_maxsim(
    x,
    doc_tokens,
    doc_mask,
    *,
    block_n: int = 256,
    block_m: int = 64,
    interpret: bool = False,
):
    """x: (n, d); doc_tokens: (m, T, d); doc_mask: (m, T) -> (n, m) fp32.

    n, m are padded to block multiples internally; d should be 128-aligned
    for MXU efficiency (the wrapper pads if not).
    """
    n, d = x.shape
    m, T, _ = doc_tokens.shape

    dp = -(-d // 128) * 128
    np_ = -(-n // block_n) * block_n
    mp = -(-m // block_m) * block_m
    x_p = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
    docs_p = jnp.pad(doc_tokens, ((0, mp - m), (0, 0), (0, dp - d)))
    mask_p = jnp.pad(doc_mask.astype(jnp.float32), ((0, mp - m), (0, 0)))

    grid = (np_ // block_n, mp // block_m)
    out = pl.pallas_call(
        _token_maxsim_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, T, dp), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((block_m, T), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, mp), jnp.float32),
        interpret=interpret,
    )(x_p, docs_p, mask_p)
    return out[:n, :m]
