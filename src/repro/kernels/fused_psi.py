"""Pallas TPU kernel: fused LEMUR feature encoder ψ(x) = LN(GELU(xW' + b)).

One HBM round-trip instead of three (matmul / GELU / LayerNorm as separate
XLA ops): each row tile keeps the FULL d' (=2048) activation in VMEM so the
LayerNorm reduction is local to the tile.

VMEM per tile (Bn=256, d=128, d'=2048, fp32):
  x 128 KiB + W' 1 MiB + h 2 MiB  ≈ 3.2 MiB.
Grid is 1-D over row blocks; d' must fit in one tile (true for the paper's
1024–4096 ablation range).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_psi_kernel(x_ref, w_ref, b_ref, g_ref, beta_ref, out_ref, *, eps):
    x = x_ref[...]
    w = w_ref[...]
    h = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    h = h + b_ref[...][None, :]
    h = jax.nn.gelu(h, approximate=True)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
    y = (h - mu) * jax.lax.rsqrt(var + eps)
    y = y * g_ref[...][None, :] + beta_ref[...][None, :]
    out_ref[...] = y.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fused_psi(
    x, kernel, bias, ln_scale, ln_bias, *, block_n: int = 256, interpret: bool = False,
    eps: float = 1e-5,
):
    """x: (n, d) -> ψ(x): (n, d') fp32."""
    n, d = x.shape
    d_prime = kernel.shape[1]
    dp = -(-d // 128) * 128
    np_ = -(-n // block_n) * block_n
    x_p = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
    w_p = jnp.pad(kernel, ((0, dp - d), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_fused_psi_kernel, eps=eps),
        grid=(np_ // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, dp), lambda i: (i, 0)),
            pl.BlockSpec((dp, d_prime), lambda i: (0, 0)),
            pl.BlockSpec((d_prime,), lambda i: (0,)),
            pl.BlockSpec((d_prime,), lambda i: (0,)),
            pl.BlockSpec((d_prime,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, d_prime), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, d_prime), jnp.float32),
        interpret=interpret,
    )(x_p, w_p, bias, ln_scale, ln_bias)
    return out[:n]
