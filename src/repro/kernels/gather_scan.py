"""Pallas TPU gather-at-source serving kernels (scalar-prefetch DMA).

LEMUR inference is two memory-bound gathers: the IVF probe scan pulls
``nprobe`` cluster lists per query, the exact rerank pulls ``k'`` candidate
documents per query.  The pure-XLA path materializes both gathers in HBM
(``jnp.take`` copies a ``(B, nprobe, cap, d)`` / ``(B, k', Td, d)`` tensor)
before any math runs — every gathered byte makes three HBM trips (read at
the source, write to the copy, read by the scoring op) and the copies are
duplicated per query row.

These kernels move the gather INTO the grid instead: the probe / candidate
ids are scalar-prefetched to SMEM (``pltpu.PrefetchScalarGridSpec``), and
each grid step's BlockSpec ``index_map`` reads the prefetched id to DMA
exactly one cluster (or candidate) tile HBM→VMEM, where the MXU contraction
runs immediately.  Per query the HBM read volume is O(nprobe·cap·d) /
O(k'·Td·d) source bytes streamed exactly once; nothing is materialized.
Consecutive grid steps double-buffer their DMAs automatically (the Pallas
grid pipeline), so the scan runs at HBM bandwidth.

``ivf_probe_scan`` — grid ``(B, nprobe)``; step ``(b, p)`` DMAs cluster
``probe[b, p]``'s ``(cap, d)`` list (fp32, or int8 codes dequantized
in-kernel via the same hi/lo-bf16 split as ``mips_sq8``), scores it against
query row ``b`` in one MXU matmul, masks ``-1`` pad slots to ``-inf`` and
writes a compact ``(B, nprobe, cap)`` score strip (the top-k' runs on the
strip outside, like the legacy path — bit-identical ids on fp32).

VMEM per step (cap=4096, d=128): fp32 cluster tile 2 MiB (int8: 512 KiB +
16 KiB scales), query row 512 B, score strip 16 KiB — ×2 for the pipeline's
double buffer, comfortably inside ~16 MiB v5e VMEM.

``rerank_gather_scores`` — grid ``(B, k')``; step ``(b, c)`` DMAs candidate
``cand[b, c]``'s ``(Td, d)`` token slab (fp or int8 + per-token scales),
computes the masked ``(Tq × Td)`` MXU contraction, token-max and
query-masked sum entirely in VMEM, and writes the single MaxSim score.
``-1`` candidates are clamped to doc 0 for the DMA and masked by the
caller (``ops.fused_rerank``), matching ``core.maxsim.rerank``.

VMEM per step (Tq=32, Td=32, d=128): query slab 16 KiB, doc slab 16 KiB
(int8: 4 KiB + 128 B scales), score tile 4 KiB — the whole working set of
one candidate fits in registers-adjacent VMEM; the ``(B, k', Td, d)`` HBM
tensor of the legacy path never exists.

``rerank_paged_scores`` — the paged-corpus twin of the rerank: the corpus
lives as fixed-size token PAGES behind a per-doc page table
(``core.pages.PagedStore``), so a candidate's tokens are not one contiguous
``(Td, d)`` slab.  Grid ``(B, k', pmax)``: the per-candidate page ids are
scalar-prefetched to SMEM (exactly the paged-KV page-table-in-SMEM idiom),
step ``(b, c, j)`` DMAs page ``table[cand[b, c], j]``'s ``(page, d)`` tile,
scores it against the query slab, masks token positions ``>= n_tokens`` to
``NEG``, and folds a per-query-token running max carried in VMEM scratch
across the ``pmax`` minor steps (the TPU grid iterates the last dimension
innermost, so the scratch persists per candidate); the final step applies
the query mask and writes the single MaxSim score.  Because per-token dots
are unchanged and max is order-independent, scores are bit-identical to the
dense-slab kernel's on the same docs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


# --------------------------------------------------------------------------
# scalar-prefetch IVF probe scan
# --------------------------------------------------------------------------

def _ivf_scan_fp_kernel(probe_ref, q_ref, ids_ref, vecs_ref, out_ref):
    # q: (1, d); ids: (1, cap); vecs: (1, cap, d) — ONE cluster, DMA'd by the
    # index_map from the prefetched probe id; out: (1, 1, cap) score strip
    q = q_ref[...]
    _, cap, d = vecs_ref.shape
    s = jax.lax.dot_general(
        q, vecs_ref[...].reshape(cap, d), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (1, cap)
    out_ref[...] = jnp.where(ids_ref[...] >= 0, s, -jnp.inf).reshape(1, 1, cap)


def _ivf_scan_sq8_kernel(probe_ref, q_ref, ids_ref, codes_ref, scales_ref,
                         out_ref):
    # int8 cluster codes dequantized IN-KERNEL: hi/lo bf16 split of the fp32
    # query (two MXU passes) x bf16-widened codes, per-slot scales folded
    # into the score strip — matches kernels.mips_sq8 to ~2^-16 relative
    q = q_ref[...]                                   # (1, d) fp32
    _, cap, d = codes_ref.shape
    c = codes_ref[...].reshape(cap, d).astype(jnp.bfloat16)
    q_hi = q.astype(jnp.bfloat16)
    q_lo = (q - q_hi.astype(jnp.float32)).astype(jnp.bfloat16)
    dot = lambda a: jax.lax.dot_general(
        a, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = (dot(q_hi) + dot(q_lo)) * scales_ref[...]    # (1, cap)
    out_ref[...] = jnp.where(ids_ref[...] >= 0, s, -jnp.inf).reshape(1, 1, cap)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ivf_probe_scan(q, probe, ids, vecs, scales=None, *, interpret: bool = False):
    """Scan the probed IVF cluster lists without gathering them to HBM.

    q: (B, d) fp32; probe: (B, nprobe) int32 cluster ids; ids: (nlist, cap)
    int32 (-1 padded); vecs: (nlist, cap, d) fp32 — or int8 codes with
    scales: (nlist, cap) — returns (B, nprobe, cap) fp32 scores with pad
    slots at ``-inf``.  Each grid step DMAs only cluster ``probe[b, p]``.
    """
    B, d = q.shape
    nprobe = probe.shape[1]
    nlist, cap = ids.shape
    grid = (B, nprobe)
    in_specs = [
        pl.BlockSpec((1, d), lambda b, p, pr: (b, 0)),
        pl.BlockSpec((1, cap), lambda b, p, pr: (pr[b, p], 0)),
        pl.BlockSpec((1, cap, d), lambda b, p, pr: (pr[b, p], 0, 0)),
    ]
    args = [q, ids, vecs]
    kernel = _ivf_scan_fp_kernel
    if scales is not None:
        in_specs.append(pl.BlockSpec((1, cap), lambda b, p, pr: (pr[b, p], 0)))
        args.append(scales)
        kernel = _ivf_scan_sq8_kernel
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, cap), lambda b, p, pr: (b, p, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nprobe, cap), jnp.float32),
        interpret=interpret,
    )(probe.astype(jnp.int32), *args)


# --------------------------------------------------------------------------
# fused candidate-gather MaxSim rerank
# --------------------------------------------------------------------------

def _rerank_fp_kernel(cand_ref, q_ref, qm_ref, docs_ref, dm_ref, out_ref):
    # q: (1, Tq, d); docs: (1, Td, d) — ONE candidate's token slab, DMA'd by
    # the index_map from the prefetched (clamped) candidate id; the masks
    # arrive pre-gathered per (b, c) (they are Td bytes against the slab's
    # Td·d·4 — see rerank_gather_scores); out: (1, 1)
    _, Tq, d = q_ref.shape
    _, Td, _ = docs_ref.shape
    s = jax.lax.dot_general(
        q_ref[...].reshape(Tq, d), docs_ref[...].reshape(Td, d),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )  # (Tq, Td)
    s = jnp.where(dm_ref[...].reshape(1, Td) > 0, s, NEG)
    best = jnp.max(s, axis=-1)                       # (Tq,)
    best = jnp.where(qm_ref[...].reshape(Tq) > 0, best, 0.0)
    out_ref[...] = jnp.sum(best).reshape(1, 1)


def _rerank_sq8_kernel(cand_ref, q_ref, qm_ref, codes_ref, dm_ref, ds_ref,
                       out_ref):
    # per-token scales fold into the SCORE rows — score(q, s·c) = s·(q·c) —
    # so the dequantized fp slab never materializes (same identity the
    # sharded serve step used in jnp, now in VMEM)
    _, Tq, d = q_ref.shape
    _, Td, _ = codes_ref.shape
    q = q_ref[...].reshape(Tq, d)
    c = codes_ref[...].reshape(Td, d).astype(jnp.bfloat16)
    q_hi = q.astype(jnp.bfloat16)
    q_lo = (q - q_hi.astype(jnp.float32)).astype(jnp.bfloat16)
    dot = lambda a: jax.lax.dot_general(
        a, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = (dot(q_hi) + dot(q_lo)) * ds_ref[...].reshape(1, Td)
    s = jnp.where(dm_ref[...].reshape(1, Td) > 0, s, NEG)
    best = jnp.max(s, axis=-1)
    best = jnp.where(qm_ref[...].reshape(Tq) > 0, best, 0.0)
    out_ref[...] = jnp.sum(best).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rerank_gather_scores(q, q_mask, cand_ids, doc_tokens, doc_mask,
                         doc_scales=None, *, interpret: bool = False):
    """Exact MaxSim of each query against ITS OWN candidate docs, gathering
    each candidate's token slab at the source.

    q: (B, Tq, d); cand_ids: (B, k') int32 (-1 padded — pads are clamped to
    doc 0 here and must be masked by the caller); doc_tokens: (m, Td, d) fp
    — or int8 codes with doc_scales: (m, Td) — returns (B, k') fp32 raw
    pair scores.
    """
    B, Tq, d = q.shape
    kp = cand_ids.shape[1]
    m, Td, _ = doc_tokens.shape
    safe = jnp.maximum(cand_ids, 0).astype(jnp.int32)
    qm = q_mask.astype(jnp.int8)
    # masks (and SQ8 scales) are gathered per candidate in XLA — B·k'·Td
    # slots, tiny next to the (Td, d) token slabs the kernel streams, and it
    # avoids converting/copying the corpus-sized (m, Td) mask every call
    dm = jnp.take(doc_mask, safe, axis=0).astype(jnp.int8)   # (B, k', Td)
    in_specs = [
        pl.BlockSpec((1, Tq, d), lambda b, c, cr: (b, 0, 0)),
        pl.BlockSpec((1, Tq), lambda b, c, cr: (b, 0)),
        pl.BlockSpec((1, Td, d), lambda b, c, cr: (cr[b, c], 0, 0)),
        pl.BlockSpec((1, 1, Td), lambda b, c, cr: (b, c, 0)),
    ]
    args = [q, qm, doc_tokens, dm]
    kernel = _rerank_fp_kernel
    if doc_scales is not None:
        in_specs.append(pl.BlockSpec((1, 1, Td), lambda b, c, cr: (b, c, 0)))
        args.append(jnp.take(doc_scales, safe, axis=0))
        kernel = _rerank_sq8_kernel
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, kp),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1), lambda b, c, cr: (b, c)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, kp), jnp.float32),
        interpret=interpret,
    )(safe, *args)


# --------------------------------------------------------------------------
# paged-corpus MaxSim rerank (page table fed through SMEM)
# --------------------------------------------------------------------------

def _rerank_paged_fp_kernel(pt_ref, nt_ref, q_ref, qm_ref, page_ref, out_ref,
                            acc_ref, *, pmax):
    # q: (1, Tq, d); page: (1, page, d) — ONE token page, DMA'd by the
    # index_map from the prefetched page id pt[b, c, j]; acc: (Tq, 1) VMEM
    # running per-query-token max, carried across the pmax minor grid steps
    b, c, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.full(acc_ref.shape, NEG, jnp.float32)

    _, Tq, d = q_ref.shape
    _, page, _ = page_ref.shape
    s = jax.lax.dot_general(
        q_ref[...].reshape(Tq, d), page_ref[...].reshape(page, d),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )  # (Tq, page)
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (Tq, page), 1)
    s = jnp.where(pos < nt_ref[b, c], s, NEG)
    acc_ref[...] = jnp.maximum(acc_ref[...],
                               jnp.max(s, axis=-1, keepdims=True))

    @pl.when(j == pmax - 1)
    def _flush():
        best = jnp.where(qm_ref[...].reshape(Tq, 1) > 0, acc_ref[...], 0.0)
        out_ref[...] = jnp.sum(best).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rerank_paged_scores(q, q_mask, cand_ids, tok_pages, page_table, n_tokens,
                        *, interpret: bool = False):
    """Exact MaxSim of each query against ITS OWN candidates, streaming each
    candidate's token PAGES at the source.

    q: (B, Tq, d); cand_ids: (B, k') int32 (-1 padded — pads/dead slots are
    clamped for the DMA, score all-NEG here, and must be masked by the
    caller); tok_pages: (P, page, d) fp32; page_table: (C, pmax) int32 (-1
    padded); n_tokens: (C,) int32 — returns (B, k') fp32 raw pair scores.
    The per-candidate page-id strip (B·k'·pmax int32, tiny next to the token
    pages) is gathered in XLA and scalar-prefetched to SMEM.
    """
    B, Tq, d = q.shape
    kp = cand_ids.shape[1]
    _, page, _ = tok_pages.shape
    pmax = page_table.shape[1]
    safe = jnp.maximum(cand_ids, 0).astype(jnp.int32)
    pt = jnp.maximum(jnp.take(page_table, safe, axis=0), 0).astype(jnp.int32)
    nt = jnp.take(n_tokens, safe, axis=0).astype(jnp.int32)
    nt = jnp.where(cand_ids >= 0, nt, 0)         # (B, k')
    qm = q_mask.astype(jnp.int8)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, kp, pmax),
        in_specs=[
            pl.BlockSpec((1, Tq, d), lambda b, c, j, pt, nt: (b, 0, 0)),
            pl.BlockSpec((1, Tq), lambda b, c, j, pt, nt: (b, 0)),
            pl.BlockSpec((1, page, d),
                         lambda b, c, j, pt, nt: (pt[b, c, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, c, j, pt, nt: (b, c)),
        scratch_shapes=[pltpu.VMEM((Tq, 1), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_rerank_paged_fp_kernel, pmax=pmax),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, kp), jnp.float32),
        interpret=interpret,
    )(pt, nt, q, qm, tok_pages)


# --------------------------------------------------------------------------
# residual-codec tier: in-kernel centroid lookup + residual unpack
# --------------------------------------------------------------------------
#
# The compressed corpus stores each token as a centroid id (int32) plus a
# packed 2/4-bit per-dim residual code (``repro.anns.quantization``).  The
# kernels below decode INSIDE the grid — the fp32 token slab never exists in
# HBM — generalizing the SQ8 hi/lo-bf16 trick from "scale a cheap int8 dot"
# to "reconstruct, then dot".  Mosaic has no dynamic-gather primitive, so
# the decode avoids gathers entirely:
#
# * packed codes unpack with int32 shifts/ANDs (vector ALU);
# * per-dim reconstruction values resolve by a select-sum over the L static
#   levels (``sum_l values[:, l] * (idx == l)``);
# * centroid rows resolve by a one-hot MXU matmul
#   (``onehot(cent, ncent) @ centroids``).
#
# Every output element is the sum of exactly one fp32 term plus zeros, so
# the in-kernel decode is BIT-IDENTICAL to the host-side
# ``quantization.residual_decode`` (``jnp.take``/``take_along_axis``) — the
# property ``tests/test_residual_codec.py`` pins down.


def _unpack_codes_i32(codes, *, bits):
    """Packed (n, db) uint8 -> (n, db * 8//bits) int32 bucket indices.

    Same little-endian-within-byte layout as ``quantization.pack_codes``:
    dim ``i*per + j`` sits at bit ``bits*j`` of byte ``i``."""
    per = 8 // bits
    mask = (1 << bits) - 1
    b = codes.astype(jnp.int32)
    parts = [(b >> (bits * j)) & mask for j in range(per)]
    idx = jnp.stack(parts, axis=-1)                    # (n, db, per)
    return idx.reshape(idx.shape[0], idx.shape[1] * per)


def _residual_values(idx, values):
    """Bucket indices (n, d) + per-dim tables (d, L) -> (n, d) fp32 via a
    select-sum over the L static levels (exactly one nonzero term/element)."""
    L = values.shape[1]
    res = jnp.zeros(idx.shape, jnp.float32)
    for l in range(L):
        res = res + jnp.where(idx == l, values[:, l][None, :], 0.0)
    return res


def residual_decode_onehot(cent, codes, centroids, values, *, bits):
    """Gather-free residual decode (kernel-safe, also called by tests).

    cent: (n,) int32 centroid ids; codes: (n, db) uint8 packed residuals;
    centroids: (ncent, d) fp32; values: (d, L) fp32 -> (n, d) fp32,
    bit-identical to ``quantization.residual_decode`` on the same inputs."""
    n = cent.shape[0]
    ncent = centroids.shape[0]
    idx = _unpack_codes_i32(codes, bits=bits)          # (n, d)
    res = _residual_values(idx, values)                # (n, d)
    onehot = (cent[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, (n, ncent), 1)
              ).astype(jnp.float32)
    cvec = jax.lax.dot_general(
        onehot, centroids, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # (n, d)
    return cvec + res


def _ivf_scan_res_kernel(probe_ref, q_ref, ids_ref, codes_ref, cent_ref,
                         val_ref, out_ref, *, bits):
    # codes: (1, cap, db) packed residuals of ONE cluster; cent: (1, d) the
    # SAME cluster's centroid row (IVF storage codes each vector against its
    # own cluster, so the id is implicit in the list and both tiles are
    # DMA'd by the one prefetched probe id) — no one-hot lookup needed here
    q = q_ref[...]                                     # (1, d) fp32
    _, cap, db = codes_ref.shape
    idx = _unpack_codes_i32(codes_ref[...].reshape(cap, db), bits=bits)
    v = _residual_values(idx, val_ref[...]) + cent_ref[...]   # (cap, d)
    s = jax.lax.dot_general(
        q, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )  # (1, cap)
    out_ref[...] = jnp.where(ids_ref[...] >= 0, s, -jnp.inf).reshape(
        1, 1, out_ref.shape[-1])


@functools.partial(jax.jit, static_argnames=("interpret",))
def ivf_probe_res_scan(q, probe, ids, codes, centroids, values, *,
                       interpret: bool = False):
    """Residual-tier IVF probe scan: decode-at-source, never materializing
    the fp32 cluster lists.

    q: (B, d) fp32; probe: (B, nprobe) int32; ids: (nlist, cap) int32 (-1
    padded); codes: (nlist, cap, db) uint8 packed residuals coded against
    each vector's OWN cluster centroid; centroids: (nlist, d) fp32; values:
    (d, L) fp32 -> (B, nprobe, cap) fp32 scores, pad slots ``-inf``.
    """
    B, d = q.shape
    nprobe = probe.shape[1]
    nlist, cap = ids.shape
    db = codes.shape[2]
    L = values.shape[1]
    bits = int(L).bit_length() - 1
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nprobe),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, p, pr: (b, 0)),
            pl.BlockSpec((1, cap), lambda b, p, pr: (pr[b, p], 0)),
            pl.BlockSpec((1, cap, db), lambda b, p, pr: (pr[b, p], 0, 0)),
            pl.BlockSpec((1, d), lambda b, p, pr: (pr[b, p], 0)),
            pl.BlockSpec((d, L), lambda b, p, pr: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, cap), lambda b, p, pr: (b, p, 0)),
    )
    return pl.pallas_call(
        functools.partial(_ivf_scan_res_kernel, bits=bits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nprobe, cap), jnp.float32),
        interpret=interpret,
    )(probe.astype(jnp.int32), q, ids, codes, centroids, values)


def _rerank_paged_res_kernel(pt_ref, nt_ref, q_ref, qm_ref, cent_ref,
                             code_ref, cb_ref, val_ref, out_ref, acc_ref, *,
                             pmax, bits):
    # the paged fp rerank with the page DMA swapped for cent ids (1, page)
    # int32 + packed codes (1, page, db) uint8 and an in-VMEM decode; the
    # codec tables (cb: (ncent, d), val: (d, L)) ride along as full blocks
    b, c, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.full(acc_ref.shape, NEG, jnp.float32)

    _, Tq, d = q_ref.shape
    _, page = cent_ref.shape
    toks = residual_decode_onehot(
        cent_ref[...].reshape(page), code_ref[...].reshape(page, -1),
        cb_ref[...], val_ref[...], bits=bits,
    )                                                  # (page, d)
    s = jax.lax.dot_general(
        q_ref[...].reshape(Tq, d), toks, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Tq, page)
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (Tq, page), 1)
    s = jnp.where(pos < nt_ref[b, c], s, NEG)
    acc_ref[...] = jnp.maximum(acc_ref[...],
                               jnp.max(s, axis=-1, keepdims=True))

    @pl.when(j == pmax - 1)
    def _flush():
        best = jnp.where(qm_ref[...].reshape(Tq, 1) > 0, acc_ref[...], 0.0)
        out_ref[...] = jnp.sum(best).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rerank_paged_res_scores(q, q_mask, cand_ids, cent_pages, code_pages,
                            page_table, n_tokens, centroids, values, *,
                            interpret: bool = False):
    """Residual-tier paged MaxSim rerank: stream each candidate's COMPRESSED
    token pages and decode in VMEM — the fp32 slab never exists in HBM.

    q: (B, Tq, d); cand_ids: (B, k') int32 (-1 padded, caller masks);
    cent_pages: (P, page) int32; code_pages: (P, page, db) uint8;
    page_table: (C, pmax) int32 (-1 padded); n_tokens: (C,) int32;
    centroids: (ncent, d) / values: (d, L) the codec tables -> (B, k') fp32
    raw pair scores, bit-identical to decoding the pages host-side and
    running :func:`rerank_paged_scores`.
    """
    B, Tq, d = q.shape
    kp = cand_ids.shape[1]
    _, page = cent_pages.shape
    db = code_pages.shape[2]
    ncent = centroids.shape[0]
    L = values.shape[1]
    bits = int(L).bit_length() - 1
    pmax = page_table.shape[1]
    safe = jnp.maximum(cand_ids, 0).astype(jnp.int32)
    pt = jnp.maximum(jnp.take(page_table, safe, axis=0), 0).astype(jnp.int32)
    nt = jnp.take(n_tokens, safe, axis=0).astype(jnp.int32)
    nt = jnp.where(cand_ids >= 0, nt, 0)         # (B, k')
    qm = q_mask.astype(jnp.int8)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, kp, pmax),
        in_specs=[
            pl.BlockSpec((1, Tq, d), lambda b, c, j, pt, nt: (b, 0, 0)),
            pl.BlockSpec((1, Tq), lambda b, c, j, pt, nt: (b, 0)),
            pl.BlockSpec((1, page),
                         lambda b, c, j, pt, nt: (pt[b, c, j], 0)),
            pl.BlockSpec((1, page, db),
                         lambda b, c, j, pt, nt: (pt[b, c, j], 0, 0)),
            pl.BlockSpec((ncent, d), lambda b, c, j, pt, nt: (0, 0)),
            pl.BlockSpec((d, L), lambda b, c, j, pt, nt: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, c, j, pt, nt: (b, c)),
        scratch_shapes=[pltpu.VMEM((Tq, 1), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_rerank_paged_res_kernel, pmax=pmax, bits=bits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, kp), jnp.float32),
        interpret=interpret,
    )(pt, nt, q, qm, cent_pages, code_pages, centroids, values)
