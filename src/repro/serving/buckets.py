"""Shape-bucketing policy for the online serving runtime.

Online traffic is ragged: single queries with arbitrary token counts
arriving asynchronously.  jit-compiled serving fns specialize per input
shape, so serving raw ragged shapes would compile an unbounded set of
XLA graphs.  :class:`BucketLadder` bounds the shape space instead:

* **Tq ladder** — every query's token axis is padded up to a small fixed
  ladder of lengths (default ``32/64/128/256``, the ColBERT-style query
  length regime).  Padded token rows carry zero vectors and ``False``
  mask bits, which the pool/rerank pipeline treats as exact no-ops.
* **Batch sizes** — micro-batches are padded up to power-of-two sizes
  (``1, 2, 4, …, max_batch``).  Padded batch rows replicate a real row
  (never a degenerate all-``False`` mask) and their results are dropped.

With both axes bucketed, the compiled-fn cache is bounded by
``compile_bound()`` = ``len(tq_ladder) × len(batch_sizes)`` per resolved
``SearchParams`` — asserted against ``trace_count()`` in the serving
runtime tests, no matter how shapes churn.
"""
from __future__ import annotations

import dataclasses

import numpy as np

DEFAULT_TQ_LADDER = (32, 64, 128, 256)


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


@dataclasses.dataclass(frozen=True)
class BucketLadder:
    """The serving shape policy (see module docstring).

    ``tq_ladder`` must be strictly increasing.  Queries longer than the top
    rung overflow to the next power of two — legal, but each distinct
    overflow length compiles outside the ladder bound, so size the ladder
    to the traffic."""

    tq_ladder: tuple[int, ...] = DEFAULT_TQ_LADDER
    max_batch: int = 16

    def __post_init__(self):
        ladder = tuple(int(t) for t in self.tq_ladder)
        if not ladder or any(t <= 0 for t in ladder):
            raise ValueError(f"tq_ladder must be positive: {ladder}")
        if list(ladder) != sorted(set(ladder)):
            raise ValueError(f"tq_ladder must be strictly increasing: {ladder}")
        object.__setattr__(self, "tq_ladder", ladder)
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1: {self.max_batch}")
        object.__setattr__(self, "max_batch", _next_pow2(self.max_batch))

    # -- bucket selection ---------------------------------------------------

    def tq_bucket(self, tq: int) -> int:
        """Smallest ladder rung >= tq (next power of two on overflow)."""
        for rung in self.tq_ladder:
            if tq <= rung:
                return rung
        return _next_pow2(tq)

    def batch_bucket(self, n: int) -> int:
        """Smallest power-of-two batch size >= n, capped at ``max_batch``."""
        return min(_next_pow2(n), self.max_batch)

    def batch_sizes(self) -> tuple[int, ...]:
        sizes, b = [], 1
        while b <= self.max_batch:
            sizes.append(b)
            b *= 2
        return tuple(sizes)

    def compile_bound(self, n_param_sets: int = 1) -> int:
        """Upper bound on jit traces for in-ladder traffic: one per
        (Tq rung, batch size, resolved SearchParams)."""
        return len(self.tq_ladder) * len(self.batch_sizes()) * n_param_sets

    # -- batch assembly -----------------------------------------------------

    def pad_batch(self, queries, masks):
        """Assemble ragged single queries into one bucketed slab.

        ``queries``: list of (Tq_i, d) fp32 arrays; ``masks``: matching list
        of (Tq_i,) bool arrays.  Returns ``(q, qm, n_real)`` with
        ``q: (Bb, Tqb, d)``, ``qm: (Bb, Tqb)`` where ``Tqb`` buckets the
        longest request and ``Bb`` buckets ``len(queries)``.  Padded token
        rows are zero vectors with ``False`` mask (exact no-ops in the
        pool/rerank pipeline); padded batch rows replicate row 0 and are
        sliced away by the caller."""
        if not queries:
            raise ValueError("pad_batch needs at least one query")
        n_real = len(queries)
        tqb = self.tq_bucket(max(q.shape[0] for q in queries))
        bb = self.batch_bucket(n_real)
        d = queries[0].shape[-1]
        q = np.zeros((bb, tqb, d), np.float32)
        qm = np.zeros((bb, tqb), bool)
        for i, (qi, mi) in enumerate(zip(queries, masks)):
            t = qi.shape[0]
            q[i, :t] = qi
            qm[i, :t] = mi
        if bb > n_real:  # replicate a real row into the batch pad
            q[n_real:] = q[0]
            qm[n_real:] = qm[0]
        return q, qm, n_real


def pad_single(query, mask, tq: int):
    """Pad one (Tq, d) query + (Tq,) mask up to ``tq`` token rows (zero
    vectors, ``False`` mask) — the per-request half of :meth:`pad_batch`,
    exposed for conformance tests."""
    t, d = query.shape
    q = np.zeros((tq, d), np.float32)
    m = np.zeros((tq,), bool)
    q[:t] = query
    m[:t] = mask
    return q, m


__all__ = ["BucketLadder", "DEFAULT_TQ_LADDER", "pad_single"]
