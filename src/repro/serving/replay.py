"""Poisson arrival-trace replay against a :class:`RetrieverServer`.

The online operating point depends on the arrival process, not just the
kernel: latency percentiles trade against micro-batch occupancy as load
rises.  This module owns the replay loop shared by ``launch/serve.py
--online``, ``benchmarks/serving_online.py``, and the example demo:
generate a seeded Poisson trace, pace ragged submissions against the wall
clock, then fold the server's stats into one JSON-able report.

Latency is measured from each request's *scheduled* arrival time, not from
the (possibly delayed) ``submit()`` call.  When the replay thread itself
falls behind — a submit stalls, the queue backs up — the un-submitted
requests are already waiting in line; measuring from the late submit call
hides that wait (coordinated omission) and reports an optimistic p99.
``replay`` therefore passes ``t_arrival=t0 + at`` through to the server,
whose stats keep the submit-relative twins alongside (``submit_p*_ms``) so
tests can assert the two diverge under an induced stall.
"""
from __future__ import annotations

import time

import numpy as np

from repro.serving.server import DeadlineExceeded, Overloaded


def poisson_trace(rate_qps: float, duration_s: float, seed: int = 0):
    """Arrival offsets (seconds from t0) of a Poisson process at
    ``rate_qps`` over ``duration_s`` — the standard open-loop serving
    workload (exponential inter-arrivals, seeded for reproducibility)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate_qps, 1e-9),
                           size=max(int(rate_qps * duration_s * 2), 16))
    at = np.cumsum(gaps)
    return at[at < duration_s]


def ragged_queries(n: int, d: int, tq_range=(2, 24), seed: int = 0):
    """``n`` unit-norm ragged queries with Tq uniform over ``tq_range``."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        tq = int(rng.integers(tq_range[0], tq_range[1] + 1))
        q = rng.standard_normal((tq, d)).astype(np.float32)
        out.append(q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True),
                                  1e-9))
    return out


def warm_buckets(retriever, ladder, d: int, params=None,
                 batch_sizes=None) -> int:
    """Pre-compile the bucketed serving shapes so the replay measures
    steady-state latency, not XLA compiles.  Returns the number of shapes
    warmed (== the compile bound actually paid)."""
    resolved = retriever.resolve(params)
    n = 0
    for tq in ladder.tq_ladder:
        for b in (batch_sizes or ladder.batch_sizes()):
            q = np.zeros((b, tq, d), np.float32)
            qm = np.zeros((b, tq), bool)
            qm[:, 0] = True
            retriever.search(q, qm, resolved)
            n += 1
    return n


def replay(server, queries, arrivals, params=None, *, timeout: float = 300.0,
           deadline_s: float | None = None):
    """Open-loop replay: submit ``queries[i]`` at wall-clock offset
    ``arrivals[i]`` (cycling the query list if the trace is longer), wait
    for every future, and return ``(results, report)`` where ``report`` is
    ``server.stats.summary()`` extended with the offered load.  The stats
    window is reset at replay start, so the report covers exactly this
    trace (earlier phases don't bleed into the percentiles).

    Each submit carries ``t_arrival = t0 + at`` so the reported ``p*_ms``
    are free of coordinated omission (see module docstring).  Typed
    serving outcomes — :class:`Overloaded` rejects (from admission
    control) and :class:`DeadlineExceeded` expiries — are returned
    in-place in ``results`` as the exception instance, counted in the
    report (``n_rejected``/``n_expired``/``reject_rate``), and
    ``n_lost`` counts requests that vanished without any outcome (always
    0 for a correct server)."""
    server.reset_stats()
    t0 = time.perf_counter()
    futs: list = []
    for i, at in enumerate(arrivals):
        delay = at - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        try:
            futs.append(server.submit(queries[i % len(queries)],
                                      params=params,
                                      t_arrival=t0 + float(at),
                                      deadline_s=deadline_s))
        except Overloaded as e:
            futs.append(e)  # synchronous typed reject — an outcome, not a loss
    results: list = []
    n_lost = 0
    for f in futs:
        if isinstance(f, Overloaded):
            results.append(f)
            continue
        try:
            results.append(f.result(timeout=timeout))
        except (Overloaded, DeadlineExceeded) as e:
            results.append(e)
        except Exception:  # noqa: BLE001 — cancelled/timed out == lost
            results.append(None)
            n_lost += 1
    report = server.stats.summary()
    report["offered_qps"] = (len(arrivals) / float(arrivals[-1])
                             if len(arrivals) and arrivals[-1] > 0
                             else float("nan"))
    report["trace_count"] = server.trace_count()
    report["n_lost"] = n_lost
    report["reject_rate"] = (report.get("n_rejected", 0) / len(arrivals)
                             if len(arrivals) else 0.0)
    return results, report


__all__ = ["poisson_trace", "ragged_queries", "replay", "warm_buckets"]
