"""RetrieverServer: the online serving runtime in front of the facade.

Offline serving (``examples/serve_batched.py``, ``benchmarks/table2_qps``)
feeds fixed-shape query slabs to ``LemurRetriever.search``.  Real traffic
is ragged single queries arriving asynchronously — this module turns the
facade (or its sharded twin) into an online service:

* **Dynamic micro-batching.**  ``submit()`` enqueues a request and returns
  a future; a single worker thread coalesces in-flight requests that share
  a (Tq bucket, resolved ``SearchParams``) group into one micro-batch, up
  to ``max_batch`` requests or ``max_wait_us`` of head-of-line waiting,
  whichever comes first.
* **Shape bucketing.**  Requests are padded per :class:`~repro.serving.
  buckets.BucketLadder` so the compiled-fn cache stays bounded by
  ``ladder.compile_bound()`` regardless of traffic shape churn (padded
  token rows are exact no-ops; padded batch rows are sliced away).
  Returned top-k ids are bit-identical to a direct ``retriever.search()``
  of the raw ragged query; scores match to float-reduction tolerance.
* **Streaming mutation.**  ``add()`` / ``delete()`` / ``update()`` enqueue
  corpus mutations that act as queue barriers: searches submitted before
  one complete against the old snapshot, the worker then applies the
  retriever mutation atomically between micro-batches (the worker is the
  only thread touching the retriever), and every later search sees the
  mutated corpus.  Every barrier future resolves — drained, failed typed,
  or cancelled on a non-drain stop — never leaked.
* **Deadlines.**  ``submit(..., deadline_s=...)`` bounds how long a request
  may wait for service: a request whose deadline has passed when the worker
  would admit it to a micro-batch resolves with a typed
  :class:`DeadlineExceeded` (a ``TimeoutError`` subclass carrying the
  request id) instead of being served late — expired requests never occupy
  a micro-batch slot and are never silently dropped.  Deadlines gate batch
  ADMISSION: a request that expires while its batch is already executing
  still resolves with its (late) result — XLA calls are not preempted.
* **Admission control.**  ``max_queue_depth`` bounds the queue: when full,
  ``submit()`` raises a typed :class:`Overloaded` instead of accepting
  unbounded latency.  Rejected requests are never enqueued, so they can
  never consume a micro-batch slot.  ``add()`` is exempt — growth ops must
  land on every replica for fleet snapshot consistency.
* **Observability.**  :class:`ServerStats` tracks per-request latency
  percentiles (p50/p95/p99) measured from each request's *scheduled arrival*
  (``t_arrival``, free of coordinated omission under open-loop replay) with
  the submit-call-relative twins alongside (``submit_p*_ms``), QPS over the
  serving window, micro-batch occupancy and bucket histograms, and
  rejected/expired counters; ``trace_count()``/``trace_shapes()`` pass
  through to the underlying retriever.

The server works over any object with the facade serving surface
(``search``/``add``/``resolve``/``trace_count``) — both ``LemurRetriever``
and ``ShardedLemurRetriever``.  ``pause()``/``resume()`` wedge the worker
without losing queue state — the chaos hook the fleet router's health
monitor and the drain-ordering tests are built on.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any

import numpy as np

from repro.serving.buckets import BucketLadder


# --------------------------------------------------------------------------
# typed serving outcomes
# --------------------------------------------------------------------------

class DeadlineExceeded(TimeoutError):
    """A request's deadline expired before it was admitted to a micro-batch.

    Set as the future's exception (never a silent drop), so callers always
    observe a typed timeout.  ``request_id`` identifies the request."""

    def __init__(self, request_id: int | None = None, waited_s: float = 0.0):
        self.request_id = request_id
        self.waited_s = waited_s
        super().__init__(
            f"request {request_id} deadline exceeded after {waited_s*1e3:.1f}ms")


class Overloaded(RuntimeError):
    """Admission control rejected a request: the queue (or the fleet) is at
    its depth bound.  Raised synchronously by ``RetrieverServer.submit`` and
    set as the future's exception by the fleet ``Router`` — either way the
    request never consumes a micro-batch slot."""


# --------------------------------------------------------------------------
# stats
# --------------------------------------------------------------------------

class ServerStats:
    """Per-request latency + micro-batch shape accounting (thread-safe).

    Latencies are kept in a bounded sliding window (``window`` most recent
    requests) so a long-lived server never grows without bound; counters
    (requests, batches, occupancy/bucket histograms) are exact totals."""

    def __init__(self, window: int = 100_000):
        self._lock = threading.Lock()
        # primary latencies: from each request's scheduled ARRIVAL time
        # (t_arrival; == the submit call unless the submitter passes the
        # scheduled offset) — the coordinated-omission-free measurement
        self._latencies: collections.deque[float] = collections.deque(
            maxlen=window)
        # submit-call-relative twins: the pre-fix optimistic measurement,
        # kept so replays can assert the two diverge under submit-side stall
        self._submit_lat: collections.deque[float] = collections.deque(
            maxlen=window)
        self._occupancy = collections.Counter()   # n_real per micro-batch
        self._buckets = collections.Counter()     # (batch_bucket, tq_bucket)
        self._n_requests = 0
        self._n_batches = 0
        self._n_rejected = 0
        self._n_expired = 0
        self._t_first: float | None = None
        self._t_last: float | None = None

    def record_batch(self, latencies_s, submit_latencies_s, n_real: int,
                     batch_bucket: int, tq_bucket: int, t_done: float) -> None:
        with self._lock:
            self._latencies.extend(latencies_s)
            self._submit_lat.extend(submit_latencies_s)
            self._n_requests += len(latencies_s)
            self._occupancy[n_real] += 1
            self._buckets[(batch_bucket, tq_bucket)] += 1
            self._n_batches += 1
            if self._t_first is None:
                self._t_first = t_done
            self._t_last = t_done

    def record_rejected(self, n: int = 1) -> None:
        with self._lock:
            self._n_rejected += n

    def record_expired(self, n: int = 1) -> None:
        with self._lock:
            self._n_expired += n

    @property
    def n_rejected(self) -> int:
        with self._lock:
            return self._n_rejected

    @property
    def n_expired(self) -> int:
        with self._lock:
            return self._n_expired

    @property
    def n_requests(self) -> int:
        with self._lock:
            return self._n_requests

    @property
    def n_batches(self) -> int:
        with self._lock:
            return self._n_batches

    def percentiles(self, qs=(50, 95, 99)) -> dict[str, float]:
        """Latency percentiles in milliseconds, ``{"p50": …, …}``."""
        with self._lock:
            lat = np.fromiter(self._latencies, np.float64)
        if lat.size == 0:
            return {f"p{q}": float("nan") for q in qs}
        return {f"p{q}": float(np.percentile(lat, q) * 1e3) for q in qs}

    def summary(self) -> dict:
        """One JSON-able dict: percentiles, QPS over the serving window,
        occupancy/bucket histograms, reject/expiry counters.  ``p*_ms`` are
        measured from scheduled arrival; ``submit_p*_ms`` from the (possibly
        delayed) submit call — under open-loop backlog only the former is
        honest (coordinated omission)."""
        pct = self.percentiles()
        with self._lock:
            n = self._n_requests
            span = ((self._t_last - self._t_first)
                    if (self._t_first is not None and self._n_batches > 1)
                    else 0.0)
            occ = dict(sorted(self._occupancy.items()))
            buckets = {f"{b}x{t}": c
                       for (b, t), c in sorted(self._buckets.items())}
            n_batches = self._n_batches
            mean_ms = (float(np.mean(np.fromiter(self._latencies,
                                                 np.float64)) * 1e3)
                       if self._latencies else float("nan"))
            sub = np.fromiter(self._submit_lat, np.float64)
            sub_pct = ({f"submit_p{q}_ms": float(np.percentile(sub, q) * 1e3)
                        for q in (50, 95, 99)} if sub.size else
                       {f"submit_p{q}_ms": float("nan") for q in (50, 95, 99)})
            n_rejected, n_expired = self._n_rejected, self._n_expired
        return {
            "n_requests": n,
            "n_batches": n_batches,
            "n_rejected": n_rejected,
            "n_expired": n_expired,
            "mean_ms": mean_ms,
            **{f"{k}_ms": v for k, v in pct.items()},
            **sub_pct,
            "qps": n / span if span > 0 else float("nan"),
            "mean_occupancy": n / max(n_batches, 1),
            "occupancy_hist": occ,
            "bucket_hist": buckets,
        }


# --------------------------------------------------------------------------
# queue ops
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _Search:
    rid: int
    q: np.ndarray            # (Tq, d) fp32
    qm: np.ndarray           # (Tq,) bool
    params: object           # resolved SearchParams (hashable group key)
    future: Future
    t_submit: float          # when submit() was called
    t_arrival: float         # scheduled arrival (== t_submit unless passed)
    deadline: float | None   # absolute perf_counter bound, or None


@dataclasses.dataclass
class _Mutation:
    """A FIFO-barrier corpus mutation: ``add``, ``delete``, ``update``, or a
    generic ``apply``.  All share the same queue semantics — searches
    submitted earlier run against the old snapshot, the worker applies the
    mutation atomically between micro-batches, later searches see the new
    corpus."""
    kind: str                            # "add" | "delete" | "update" | "apply"
    future: Future
    doc_tokens: np.ndarray | None = None
    doc_mask: np.ndarray | None = None
    doc_ids: np.ndarray | None = None
    seed: int = 0
    fn: Any = None                       # "apply": fn(retriever) -> result


# --------------------------------------------------------------------------
# the server
# --------------------------------------------------------------------------

class RetrieverServer:
    """Online micro-batching server over a retriever (see module docstring).

    Use as a context manager, or ``start()``/``stop()`` explicitly::

        with RetrieverServer(r, ladder=BucketLadder((32, 64), 8)) as srv:
            fut = srv.submit(q_tokens)            # (Tq, d) ragged
            scores, ids = fut.result(timeout=30)
            srv.add(new_tokens, new_mask).result(timeout=60)
    """

    def __init__(self, retriever, *, ladder: BucketLadder | None = None,
                 max_wait_us: int = 2000, default_params=None,
                 max_queue_depth: int | None = None):
        self._retriever = retriever
        self._ladder = ladder or BucketLadder()
        self._max_wait_s = max_wait_us / 1e6
        self._default_params = default_params
        self._max_queue_depth = max_queue_depth
        self._queue: collections.deque = collections.deque()
        self._cond = threading.Condition()
        self._stats = ServerStats()
        self._rid = 0
        self._stopping = False
        self._drain = True
        self._paused = False
        self._progress_t = time.perf_counter()
        self._worker: threading.Thread | None = None

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "RetrieverServer":
        if self._worker is not None:
            raise RuntimeError("server already started")
        self._stopping = False
        self._worker = threading.Thread(target=self._serve_loop,
                                        name="lemur-retriever-server",
                                        daemon=True)
        self._worker.start()
        return self

    def stop(self, *, drain: bool = True, timeout: float | None = None) -> bool:
        """Stop the worker.  ``drain=True`` (default) serves every queued
        request first; ``drain=False`` cancels pending requests.  Returns
        ``True`` once the worker has exited; ``False`` if ``timeout``
        expired with the worker still draining — the server stays stopped
        (submits raise) and ``start()`` keeps refusing until a later
        ``stop()`` observes the exit, so a second worker can never race
        the first on the queue."""
        with self._cond:
            self._stopping = True
            self._drain = drain
            self._cond.notify_all()
        if self._worker is not None:
            self._worker.join(timeout)
            if self._worker.is_alive():
                return False
            self._worker = None
        return True

    def __enter__(self) -> "RetrieverServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=exc == (None, None, None))

    # -- introspection ------------------------------------------------------

    @property
    def retriever(self):
        return self._retriever

    @property
    def ladder(self) -> BucketLadder:
        return self._ladder

    @property
    def stats(self) -> ServerStats:
        return self._stats

    def reset_stats(self) -> ServerStats:
        """Swap in a fresh :class:`ServerStats` window (e.g. between replay
        phases) and return the old one.  Trace counts are NOT reset — they
        belong to the retriever's compile cache, not the serving window."""
        old, self._stats = self._stats, ServerStats()
        return old

    def pending(self) -> int:
        with self._cond:
            return len(self._queue)

    @property
    def progress_time(self) -> float:
        """perf_counter of the worker's last sign of life: a batch or add
        completing, or the queue observed empty.  Enqueues also stamp it, so
        a stall window always starts at the oldest unserved work — the fleet
        router's health monitor quarantines a replica whose queue is
        non-empty but whose ``progress_time`` is stale."""
        return self._progress_t

    def pause(self) -> None:
        """Wedge the worker at its loop top WITHOUT losing queue state — a
        chaos/test hook simulating a replica that stops draining.  Queued
        requests stay queued; ``submit()`` keeps accepting."""
        with self._cond:
            self._paused = True
            self._cond.notify_all()

    def resume(self) -> None:
        with self._cond:
            self._paused = False
            self._cond.notify_all()

    def trace_count(self, params=None) -> int:
        return self._retriever.trace_count(params)

    def trace_shapes(self):
        return self._retriever.trace_shapes()

    def compile_bound(self, n_param_sets: int = 1) -> int:
        return self._ladder.compile_bound(n_param_sets)

    # -- client surface -----------------------------------------------------

    def submit(self, q_tokens, q_mask=None, params=None, *,
               deadline_s: float | None = None,
               deadline_at: float | None = None,
               t_arrival: float | None = None) -> Future:
        """Enqueue one ragged query — ``q_tokens: (Tq, d)`` (a leading
        singleton batch axis is accepted and squeezed).  Returns a future
        resolving to ``(scores (k,), ids (k,))`` with ``future.request_id``
        set; FIFO submission order is preserved relative to ``add()``.

        ``t_arrival`` is the request's scheduled arrival (perf_counter
        offset) — open-loop replays pass it so latency is measured from the
        schedule, not the (possibly delayed) submit call.  ``deadline_s`` is
        relative to the arrival; ``deadline_at`` (absolute) takes precedence
        and lets the fleet router preserve a deadline across re-dispatch.
        Raises :class:`Overloaded` when ``max_queue_depth`` is hit — the
        rejected request never consumes a micro-batch slot."""
        q = np.asarray(q_tokens, np.float32)
        if q.ndim == 3 and q.shape[0] == 1:
            q = q[0]
            if q_mask is not None:
                q_mask = np.asarray(q_mask)[0]
        if q.ndim != 2:
            raise ValueError(f"submit takes one (Tq, d) query, got {q.shape}")
        qm = (np.ones(q.shape[0], bool) if q_mask is None
              else np.asarray(q_mask, bool))
        if qm.shape != (q.shape[0],):
            raise ValueError(f"mask {qm.shape} does not match query {q.shape}")
        resolved = self._retriever.resolve(
            params if params is not None else self._default_params)
        now = time.perf_counter()
        arrival = now if t_arrival is None else float(t_arrival)
        deadline = (float(deadline_at) if deadline_at is not None
                    else arrival + deadline_s if deadline_s is not None
                    else None)
        fut: Future = Future()
        with self._cond:
            if self._stopping:
                raise RuntimeError("server is stopped")
            if (self._max_queue_depth is not None
                    and len(self._queue) >= self._max_queue_depth):
                self._stats.record_rejected()
                raise Overloaded(
                    f"queue depth {len(self._queue)} at bound "
                    f"{self._max_queue_depth}")
            self._rid += 1
            fut.request_id = self._rid
            self._queue.append(_Search(self._rid, q, qm, resolved, fut,
                                       now, arrival, deadline))
            self._progress_t = max(self._progress_t, now)
            self._cond.notify_all()
        return fut

    def search(self, q_tokens, q_mask=None, params=None,
               timeout: float | None = 60.0, **submit_kw):
        """Blocking convenience wrapper: ``submit(...).result(timeout)``."""
        return self.submit(q_tokens, q_mask, params,
                           **submit_kw).result(timeout)

    def add(self, doc_tokens, doc_mask, *, seed: int = 0) -> Future:
        """Enqueue streaming growth.  Acts as a FIFO barrier: earlier
        searches run against the old snapshot, the swap happens atomically
        between micro-batches, later searches see the new docs.  The future
        resolves to the grown corpus size ``m`` (and carries
        ``added_ids`` + ``snapshot_version``)."""
        return self._enqueue_mutation(_Mutation(
            "add", Future(), doc_tokens=np.asarray(doc_tokens),
            doc_mask=np.asarray(doc_mask), seed=seed))

    def delete(self, doc_ids) -> Future:
        """Enqueue a tombstone delete (same FIFO-barrier semantics as
        :meth:`add`).  The future resolves to the surviving live-doc count
        ``n_alive``; unknown/already-deleted ids resolve it with the
        retriever's ``ValueError``."""
        return self._enqueue_mutation(_Mutation(
            "delete", Future(), doc_ids=np.asarray(doc_ids, np.int32)))

    def update(self, doc_ids, doc_tokens, doc_mask, *, seed: int = 0) -> Future:
        """Enqueue a replace (delete+add under ONE snapshot version — the
        facade's ``update``).  The future resolves to the NEW external ids
        of the replacement docs."""
        return self._enqueue_mutation(_Mutation(
            "update", Future(), doc_tokens=np.asarray(doc_tokens),
            doc_mask=np.asarray(doc_mask),
            doc_ids=np.asarray(doc_ids, np.int32), seed=seed))

    def apply(self, fn) -> Future:
        """Enqueue a generic retriever transform behind the same FIFO
        barrier as :meth:`add`: ``fn(retriever)`` runs atomically between
        micro-batches on the worker thread — earlier searches resolve
        against the old snapshot, later ones see whatever ``fn`` installed.
        This is the warm-swap entry point (``lifecycle`` passes
        ``lambda r: r.install_refresh(result)``); if ``fn`` raises (e.g.
        ``CorruptIndexError`` from install validation) the retriever is
        whatever ``fn`` left behind — install validation guarantees that is
        the untouched last-good snapshot — and the future carries the
        exception."""
        return self._enqueue_mutation(_Mutation("apply", Future(), fn=fn))

    def _enqueue_mutation(self, op: _Mutation) -> Future:
        with self._cond:
            if self._stopping:
                raise RuntimeError("server is stopped")
            self._queue.append(op)
            self._cond.notify_all()
        return op.future

    # -- worker -------------------------------------------------------------

    def _serve_loop(self) -> None:
        # the finally clause is the no-leak guarantee: HOWEVER the worker
        # exits (drain, cancel, or an unexpected crash), every future still
        # in the queue resolves — cancelled on a non-drain stop, failed with
        # the worker's exception on a crash — so a caller blocked on
        # ``.result(timeout=...)`` always observes a typed outcome, never a
        # hang until timeout
        try:
            self._serve_loop_inner()
        except BaseException as e:  # noqa: BLE001 — resolve then re-raise
            with self._cond:
                pending = list(self._queue)
                self._queue.clear()
            for op in pending:
                if not op.future.done():
                    op.future.set_exception(
                        RuntimeError(f"server worker died: {e!r}"))
            raise

    def _serve_loop_inner(self) -> None:
        while True:
            batch: list[_Search] = []
            mut_op: _Mutation | None = None
            expired: list[_Search] = []
            with self._cond:
                # wedge while paused (unless a non-drain stop must cancel),
                # or while idle; an idle queue is a sign of life
                while ((self._paused
                        and not (self._stopping and not self._drain))
                       or (not self._queue and not self._stopping)):
                    if not self._queue and not self._paused:
                        self._progress_t = time.perf_counter()
                    self._cond.wait(timeout=0.05 if self._paused else None)
                if not self._queue and self._stopping:
                    return
                if self._stopping and not self._drain:
                    # cancel-don't-leak: every queued future (searches AND
                    # mutation barriers) resolves with CancelledError to its
                    # waiters — Future.cancel() on a pending future always
                    # succeeds here because the worker (sole executor) is
                    # the one abandoning it
                    for op in self._queue:
                        op.future.cancel()
                    self._queue.clear()
                    return
                # deadline sweep: pull expired searches out of the queue now,
                # resolve them typed once the lock is dropped
                now = time.perf_counter()
                expired = [op for op in self._queue
                           if isinstance(op, _Search)
                           and op.deadline is not None and now > op.deadline]
                if expired:
                    gone = set(map(id, expired))
                    kept = [op for op in self._queue if id(op) not in gone]
                    self._queue.clear()
                    self._queue.extend(kept)
                if self._queue:
                    if self._stopping and self._drain:
                        # drain ordering guarantee: pending mutation barriers
                        # are flushed BEFORE the remaining searches are
                        # served, so drained results reflect the final
                        # snapshot version
                        muts = [op for op in self._queue
                                if isinstance(op, _Mutation)]
                        if muts and not isinstance(self._queue[0], _Mutation):
                            rest = [op for op in self._queue
                                    if not isinstance(op, _Mutation)]
                            self._queue.clear()
                            self._queue.extend(muts + rest)
                    head = self._queue[0]
                    if isinstance(head, _Mutation):
                        mut_op = self._queue.popleft()
                    else:
                        batch = self._collect_batch(head)
            if expired:
                self._resolve_expired(expired)
            if mut_op is not None:
                self._apply_mutation(mut_op)
            elif batch:
                self._run_batch(batch)

    def _resolve_expired(self, expired: list[_Search]) -> None:
        """Resolve swept requests with a typed :class:`DeadlineExceeded` —
        never a silent drop.  Called without the lock held."""
        now = time.perf_counter()
        self._stats.record_expired(len(expired))
        for op in expired:
            if not op.future.cancelled():
                op.future.set_exception(
                    DeadlineExceeded(op.rid, now - op.t_arrival))

    def _collect_batch(self, head: _Search) -> list[_Search]:
        """Coalesce queue entries sharing head's (Tq bucket, params) group,
        up to ``max_batch`` / ``max_wait_us``.  Called with the lock held;
        removes the collected entries from the queue."""
        key = (self._ladder.tq_bucket(head.q.shape[0]), head.params)
        deadline = head.t_submit + self._max_wait_s

        def matching() -> list[_Search]:
            out = []
            now = time.perf_counter()
            for op in self._queue:
                if isinstance(op, _Mutation):
                    break  # mutations are barriers: never batch across one
                if op.deadline is not None and now > op.deadline:
                    continue  # expired: swept at loop top, never takes a slot
                if (self._ladder.tq_bucket(op.q.shape[0]), op.params) == key:
                    out.append(op)
                    if len(out) == self._ladder.max_batch:
                        break
            return out

        batch = matching()
        while (len(batch) < self._ladder.max_batch and not self._stopping
               and not self._paused):
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            self._cond.wait(timeout=remaining)
            batch = matching()
        got = set(id(op) for op in batch)
        kept = [op for op in self._queue if id(op) not in got]
        self._queue.clear()
        self._queue.extend(kept)
        return batch

    def _run_batch(self, batch: list[_Search]) -> None:
        # last-chance expiry filter: a request whose deadline passed during
        # collection resolves typed and never occupies a micro-batch slot
        now = time.perf_counter()
        stale = [op for op in batch
                 if op.deadline is not None and now > op.deadline]
        if stale:
            self._resolve_expired(stale)
            gone = set(map(id, stale))
            batch = [op for op in batch if id(op) not in gone]
            if not batch:
                return
        # a batch entering execution is progress too: without this stamp a
        # long (e.g. freshly-invalidated-compile) batch looks like a stall
        self._progress_t = time.perf_counter()
        try:
            q, qm, n_real = self._ladder.pad_batch(
                [op.q for op in batch], [op.qm for op in batch])
            scores, ids = self._retriever.search(q, qm, batch[0].params)
            scores = np.asarray(scores)   # blocks until ready
            ids = np.asarray(ids)
        except Exception as e:  # noqa: BLE001 — the request owns the error
            for op in batch:
                op.future.set_exception(e)
            return
        t_done = time.perf_counter()
        self._progress_t = t_done
        # record stats BEFORE resolving any future: a client unblocked by the
        # last result may immediately read/reset the stats window, and this
        # batch must already be in it
        self._stats.record_batch([t_done - op.t_arrival for op in batch],
                                 [t_done - op.t_submit for op in batch],
                                 n_real, q.shape[0], q.shape[1], t_done)
        version = getattr(self._retriever, "version", None)
        for i, op in enumerate(batch):
            # which corpus snapshot answered (facade.version, bumped per add)
            op.future.snapshot_version = version
            op.future.set_result((scores[i], ids[i]))

    def _apply_mutation(self, op: _Mutation) -> None:
        self._progress_t = time.perf_counter()
        r = self._retriever
        try:
            if op.kind == "add":
                r.add(op.doc_tokens, op.doc_mask, seed=op.seed)
                result = r.m
                op.future.added_ids = np.asarray(
                    getattr(r, "last_added_ids", np.empty(0, np.int32)))
            elif op.kind == "delete":
                r.delete(op.doc_ids)
                result = r.n_alive
            elif op.kind == "apply":
                result = op.fn(r)
            else:  # update
                result = np.asarray(r.update(op.doc_ids, op.doc_tokens,
                                             op.doc_mask, seed=op.seed))
        except Exception as e:  # noqa: BLE001
            op.future.set_exception(e)
            return
        self._progress_t = time.perf_counter()
        # which snapshot this barrier produced — the fleet write barrier
        # asserts every replica lands on the same version — and what the
        # mutation logically wrote (the add-amortization bench reads it off
        # the future so churn needn't serialize on the worker)
        op.future.snapshot_version = getattr(r, "version", None)
        op.future.mutation_bytes = getattr(r, "last_mutation_bytes", 0)
        op.future.set_result(result)


__all__ = ["RetrieverServer", "ServerStats", "DeadlineExceeded", "Overloaded"]
