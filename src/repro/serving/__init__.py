"""Online serving runtime: shape-bucketed dynamic micro-batching + streaming
add over the Retriever API (single-device and sharded facades).

* :mod:`repro.serving.buckets` — :class:`BucketLadder`: the Tq-ladder /
  power-of-two-batch shape policy that keeps the compiled-fn cache bounded.
* :mod:`repro.serving.server` — :class:`RetrieverServer`: thread-safe
  request queue, micro-batcher (``max_batch`` / ``max_wait_us``), streaming
  ``add()`` with atomic snapshot swap between micro-batches, and
  :class:`ServerStats` (latency percentiles, QPS, occupancy histograms).
* :mod:`repro.serving.replay` — seeded Poisson arrival traces + the
  open-loop replay/warmup loop shared by the launcher, the online
  benchmark, and the example demo.
"""
from repro.serving.buckets import DEFAULT_TQ_LADDER, BucketLadder, pad_single
from repro.serving.replay import (
    poisson_trace,
    ragged_queries,
    replay,
    warm_buckets,
)
from repro.serving.server import (
    DeadlineExceeded,
    Overloaded,
    RetrieverServer,
    ServerStats,
)

__all__ = [
    "BucketLadder",
    "DEFAULT_TQ_LADDER",
    "DeadlineExceeded",
    "Overloaded",
    "RetrieverServer",
    "ServerStats",
    "pad_single",
    "poisson_trace",
    "ragged_queries",
    "replay",
    "warm_buckets",
]
