"""Fault-tolerant training loop.

Production behaviors implemented (and unit-tested on CPU):

* checkpoint/restart — CheckpointManager (atomic, async, elastic); the loop
  always starts by restoring the newest committed step, so a crashed or
  pre-empted job resumes exactly where it left off.
* step retry — transient step failures (device OOM spikes, interconnect
  hiccups surface as XlaRuntimeError) are retried up to ``max_retries`` from
  the last good in-memory state; a second failure re-restores from disk.
  A fault-injection hook exists for tests.
* straggler mitigation — per-step wall-clock is tracked with an EMA; a step
  exceeding ``straggler_factor``× the EMA is logged and counted.  On real
  multi-host topologies the remediation is re-scheduling the slow host from
  the launcher; in-process we surface the signal (see DESIGN.md §4).
* NaN guard — non-finite loss skips the update (params/opt are only swapped
  in after the step is validated), with a counter.
* gradient compression — pass ``wrap_grads`` to apply the int8
  error-feedback cross-pod reduction inside the step (optim.compress).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Iterable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.common.config import ConfigBase


@dataclasses.dataclass(frozen=True)
class TrainerConfig(ConfigBase):
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    max_retries: int = 2
    straggler_factor: float = 3.0
    log_every: int = 10


class TrainLoop:
    def __init__(
        self,
        cfg: TrainerConfig,
        step_fn: Callable,          # (params, opt_state, batch) -> (params, opt, metrics)
        params: Any,
        opt_state: Any,
        *,
        fault_hook: Callable[[int], None] | None = None,
        logger: Callable[[str], None] = print,
    ):
        self.cfg = cfg
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.fault_hook = fault_hook
        self.log = logger
        self.ckpt = CheckpointManager(cfg.checkpoint_dir, keep_last=cfg.keep_last)
        self.step = 0
        self.stats = {"retries": 0, "nan_skips": 0, "stragglers": 0, "restores": 0}
        self._ema_step_time: float | None = None

    # -- fault tolerance ----------------------------------------------------

    def try_restore(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        (self.params, self.opt_state), step = self.ckpt.restore_latest(
            (self.params, self.opt_state)
        )
        self.step = step
        self.stats["restores"] += 1
        self.log(f"[trainer] restored checkpoint @ step {step}")
        return True

    def _run_one(self, batch):
        if self.fault_hook is not None:
            self.fault_hook(self.step)  # may raise (test injection)
        new_params, new_opt, metrics = self.step_fn(self.params, self.opt_state, batch)
        loss = float(metrics.get("loss", 0.0))
        if not math.isfinite(loss):
            self.stats["nan_skips"] += 1
            self.log(f"[trainer] step {self.step}: non-finite loss {loss}, skipping update")
            return metrics
        self.params, self.opt_state = new_params, new_opt
        return metrics

    def run(self, batches: Iterable[Any]) -> dict:
        cfg = self.cfg
        history = []
        it = iter(batches)
        while self.step < cfg.total_steps:
            try:
                batch = next(it)
            except StopIteration:
                break
            t0 = time.time()
            metrics = None
            for attempt in range(cfg.max_retries + 1):
                try:
                    metrics = self._run_one(batch)
                    break
                except Exception as e:  # noqa: BLE001 (transient runtime faults)
                    self.stats["retries"] += 1
                    self.log(f"[trainer] step {self.step} attempt {attempt} failed: {e!r}")
                    if attempt == cfg.max_retries:
                        # final fallback: restore from disk and surface
                        if self.ckpt.latest_step() is not None:
                            self.try_restore()
                        else:
                            raise
            dt = time.time() - t0
            if self._ema_step_time is not None and dt > cfg.straggler_factor * self._ema_step_time:
                self.stats["stragglers"] += 1
                self.log(f"[trainer] step {self.step}: straggler ({dt:.2f}s vs "
                         f"EMA {self._ema_step_time:.2f}s)")
            self._ema_step_time = dt if self._ema_step_time is None else (
                0.9 * self._ema_step_time + 0.1 * dt
            )
            self.step += 1
            if metrics is not None:
                history.append({k: float(v) for k, v in metrics.items()})
            if cfg.log_every and self.step % cfg.log_every == 0 and metrics is not None:
                self.log(f"[trainer] step {self.step}: "
                         + " ".join(f"{k}={float(v):.5f}" for k, v in metrics.items()))
            if cfg.checkpoint_every and self.step % cfg.checkpoint_every == 0:
                self.ckpt.save_async(self.step, (self.params, self.opt_state))
        self.ckpt.wait()
        self.ckpt.save_async(self.step, (self.params, self.opt_state))
        self.ckpt.wait()
        return {"history": history, **self.stats, "final_step": self.step}
