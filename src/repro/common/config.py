"""Config base: frozen dataclasses with dict round-trip and CLI overrides.

Every architecture / trainer / index config in the framework derives from
``ConfigBase``.  Keeping configs as plain frozen dataclasses (instead of a
dynamic dict) gives static typo-checking, hashability (usable as jit static
args), and trivially serializable checkpoint manifests.
"""
from __future__ import annotations

import dataclasses
import json
import typing
from dataclasses import field
from typing import Any, Type, TypeVar

T = TypeVar("T", bound="ConfigBase")


@dataclasses.dataclass(frozen=True)
class ConfigBase:
    """Frozen dataclass with dict/json round-trip and `replace`."""

    def replace(self: T, **kwargs: Any) -> T:
        return dataclasses.replace(self, **kwargs)

    def to_dict(self) -> dict:
        def conv(v):
            if isinstance(v, ConfigBase):
                return v.to_dict()
            if isinstance(v, tuple):
                return [conv(x) for x in v]
            return v

        return {f.name: conv(getattr(self, f.name)) for f in dataclasses.fields(self)}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_dict(cls: Type[T], d: dict) -> T:
        kwargs = {}
        hints = None
        for f in dataclasses.fields(cls):
            if f.name not in d:
                continue
            v = d[f.name]
            ft = f.type
            if not isinstance(ft, type):
                # `from __future__ import annotations` stringifies f.type;
                # resolve lazily so nested sub-configs still round-trip
                if hints is None:
                    try:
                        hints = typing.get_type_hints(cls)
                    except Exception:  # unresolvable forward refs: best effort
                        hints = {}
                ft = hints.get(f.name)
            if isinstance(ft, type) and issubclass(ft, ConfigBase) and isinstance(v, dict):
                v = ft.from_dict(v)
            if isinstance(v, list):
                v = tuple(v)
            kwargs[f.name] = v
        return cls(**kwargs)

    def override(self: T, overrides: dict[str, Any]) -> T:
        """Apply dotted-path CLI overrides, e.g. {"optimizer.lr": 1e-3}."""
        out = self
        for key, value in overrides.items():
            parts = key.split(".")
            out = _apply_override(out, parts, value)
        return out


def _apply_override(cfg: ConfigBase, parts: list[str], value: Any) -> ConfigBase:
    name = parts[0]
    if not hasattr(cfg, name):
        raise KeyError(f"config {type(cfg).__name__} has no field {name!r}")
    if len(parts) == 1:
        cur = getattr(cfg, name)
        if cur is not None and not isinstance(cur, type(value)) and not isinstance(cur, ConfigBase):
            # cast strings coming from CLI to the field's runtime type
            value = type(cur)(value)
        return cfg.replace(**{name: value})
    sub = getattr(cfg, name)
    if not isinstance(sub, ConfigBase):
        raise KeyError(f"field {name!r} is not a sub-config")
    return cfg.replace(**{name: _apply_override(sub, parts[1:], value)})


def parse_cli_overrides(args: list[str]) -> dict[str, Any]:
    """Parse ``key=value`` strings, with json-ish literal coercion."""
    out: dict[str, Any] = {}
    for a in args:
        if "=" not in a:
            raise ValueError(f"override {a!r} must be key=value")
        k, v = a.split("=", 1)
        try:
            out[k] = json.loads(v)
        except json.JSONDecodeError:
            out[k] = v
    return out


__all__ = ["ConfigBase", "field", "parse_cli_overrides"]
