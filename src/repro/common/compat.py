"""jax version-compatibility layer for the multi-device code.

The distributed layer was written against newer jax surface APIs
(``jax.shard_map``, ``jax.sharding.AxisType``, ``jax.make_mesh(...,
axis_types=...)``, ``jax.set_mesh``); this container runs jax 0.4.37 where
those live under ``jax.experimental`` or do not exist.  Every multi-device
module imports the four names below from here instead of from jax, so the
whole layer runs unchanged on either side of the API split:

    from repro.common.compat import AxisType, make_mesh, set_mesh, shard_map

Semantics per name:

``shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=False)``
    Forwards to ``jax.shard_map`` when present; otherwise to
    ``jax.experimental.shard_map.shard_map`` with ``check_vma`` renamed to
    its old spelling ``check_rep``.

``make_mesh(shape, axis_names, axis_types=None, devices=None)``
    Forwards to ``jax.make_mesh``; the ``axis_types`` kwarg is dropped on
    versions whose ``make_mesh`` does not accept it (pre-explicit-sharding
    jax has only what ``AxisType.Auto`` means today).

``AxisType``
    ``jax.sharding.AxisType`` when present, else an inert stand-in enum with
    the same member names (only ever passed back into :func:`make_mesh`,
    which drops it on old jax).

``set_mesh(mesh)``
    Context manager.  ``jax.set_mesh`` / ``jax.sharding.use_mesh`` when
    available; on old jax the ``Mesh`` object itself is the context manager
    that installs the global mesh, which is all pre-explicit-sharding code
    can use.

``axis_size(name)``
    ``jax.lax.axis_size`` when present; otherwise ``psum(1, name)`` inside a
    mapped context (prefer static ``mesh.shape`` lookups where the mesh is
    in scope — this is only for code that has just the axis name).
"""
from __future__ import annotations

import contextlib
import enum
import functools
import inspect

import jax

__all__ = ["AxisType", "axis_size", "make_mesh", "set_mesh", "shard_map"]


if hasattr(jax.sharding, "AxisType"):
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_old

    @functools.wraps(_shard_map_old)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
        if check_vma is not None:
            kwargs["check_rep"] = check_vma
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kwargs)


_MAKE_MESH_HAS_AXIS_TYPES = "axis_types" in inspect.signature(jax.make_mesh).parameters


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and _MAKE_MESH_HAS_AXIS_TYPES:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


@contextlib.contextmanager
def set_mesh(mesh):
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    elif hasattr(jax.sharding, "use_mesh"):
        with jax.sharding.use_mesh(mesh):
            yield mesh
    else:
        with mesh:  # Mesh is its own (global-mesh) context manager on old jax
            yield mesh


def axis_size(name):
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)
