"""Deterministic PRNG key sequencing."""
from __future__ import annotations

import jax


class PRNGSeq:
    """An iterator of fresh PRNG keys split from one seed key.

    Keeps model init code linear:  ``keys = PRNGSeq(0); w = init(next(keys))``.
    """

    def __init__(self, seed_or_key):
        if isinstance(seed_or_key, int):
            self._key = jax.random.PRNGKey(seed_or_key)
        else:
            self._key = seed_or_key

    def __next__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def __iter__(self):
        return self

    def take(self, n: int):
        keys = jax.random.split(self._key, n + 1)
        self._key = keys[0]
        return list(keys[1:])
