"""Small pytree helpers used across the framework (no flax/optax offline)."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

tree_map = jax.tree_util.tree_map


def tree_zeros_like(tree: Any, dtype=None) -> Any:
    return tree_map(lambda x: jnp.zeros_like(x, dtype=dtype or x.dtype), tree)


def tree_cast(tree: Any, dtype) -> Any:
    return tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


def tree_size(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Any) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_add(a: Any, b: Any) -> Any:
    return tree_map(jnp.add, a, b)


def tree_scale(tree: Any, s) -> Any:
    return tree_map(lambda x: x * s, tree)


def tree_where(pred, a: Any, b: Any) -> Any:
    return tree_map(lambda x, y: jnp.where(pred, x, y), a, b)


def named_leaves(tree: Any, prefix: str = "") -> list[tuple[str, Any]]:
    """Flatten to (dotted_name, leaf) pairs — used by checkpointing."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path)
        out.append((prefix + name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def tree_map_with_name(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Map with access to the dotted leaf name (for sharding-rule matching)."""

    def wrap(path, leaf):
        name = "/".join(_key_str(k) for k in path)
        return fn(name, leaf)

    return jax.tree_util.tree_map_with_path(wrap, tree)
