from repro.common.config import ConfigBase, field
from repro.common.pytree import (
    tree_cast,
    tree_global_norm,
    tree_map,
    tree_size,
    tree_zeros_like,
)
from repro.common.prng import PRNGSeq

__all__ = [
    "ConfigBase",
    "field",
    "PRNGSeq",
    "tree_cast",
    "tree_global_norm",
    "tree_map",
    "tree_size",
    "tree_zeros_like",
]
