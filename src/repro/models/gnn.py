"""MeshGraphNet (Pfaff et al., arXiv:2010.03409) — encode-process-decode GNN.

Message passing is built on ``jax.ops.segment_sum`` over an edge index (the
JAX sparse-op substrate — no SpMM primitive needed).  Distribution: edges are
sharded over the whole mesh inside a single shard_map (nodes replicated;
per-layer partial node aggregates are psum-reduced), so the 61M/114M-edge
cells scan locally and communicate one (N, d_hidden) reduction per layer.

Shape regimes:
  full-graph      — forward over all edges (full_graph_sm / ogb_products)
  sampled         — in-graph uniform neighbor sampler (fanout 15-10) +
                    two-hop aggregation (minibatch_lg)
  batched-small   — many small graphs flattened with graph-id segment ids
                    (molecule), graph-level readout.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.common import compat
from repro.common.config import ConfigBase
from repro.common.prng import PRNGSeq
from repro.nn import layers


@dataclasses.dataclass(frozen=True)
class GNNConfig(ConfigBase):
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2          # hidden layers per MLP (paper: 2)
    aggregator: str = "sum"
    d_node_in: int = 16
    d_edge_in: int = 4
    d_out: int = 2
    task: str = "regression"     # regression | classification
    graph_readout: bool = False  # molecule: graph-level output
    fanout: tuple[int, ...] = (15, 10)
    layernorm: bool = True


def _mlp_dims(cfg: GNNConfig, d_in: int, d_out: int) -> tuple[int, ...]:
    return (d_in, *([cfg.d_hidden] * cfg.mlp_layers), d_out)


def _init_block(key, cfg: GNNConfig, d_in: int, d_out: int):
    k1, _ = jax.random.split(key)
    p = {"mlp": layers.init_mlp(k1, _mlp_dims(cfg, d_in, d_out))}
    if cfg.layernorm:
        p["ln"] = layers.init_layernorm(d_out)
    return p


def _block(p, x, activation="relu"):
    h = layers.mlp(p["mlp"], x, activation)
    if "ln" in p:
        h = layers.layernorm(p["ln"], h)
    return h


def init_gnn(key, cfg: GNNConfig):
    ks = PRNGSeq(key)
    dh = cfg.d_hidden
    params: dict[str, Any] = {
        "node_enc": _init_block(next(ks), cfg, cfg.d_node_in, dh),
        "edge_enc": _init_block(next(ks), cfg, cfg.d_edge_in, dh),
    }
    proc_keys = jnp.stack(ks.take(cfg.n_layers))

    def init_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "edge": _init_block(k1, cfg, 3 * dh, dh),
            "node": _init_block(k2, cfg, 2 * dh, dh),
        }

    params["proc"] = jax.vmap(init_layer)(proc_keys)
    dec_in = dh
    params["decoder"] = {"mlp": layers.init_mlp(next(ks), _mlp_dims(cfg, dec_in, cfg.d_out))}
    return params


# ---------------------------------------------------------------------------
# full-graph forward (edge-sharded message passing)
# ---------------------------------------------------------------------------

def _aggregate(cfg: GNNConfig, msgs, receivers, n_nodes):
    if cfg.aggregator == "sum":
        return jax.ops.segment_sum(msgs, receivers, num_segments=n_nodes)
    if cfg.aggregator == "max":
        return jax.ops.segment_max(msgs, receivers, num_segments=n_nodes)
    if cfg.aggregator == "mean":
        s = jax.ops.segment_sum(msgs, receivers, num_segments=n_nodes)
        c = jax.ops.segment_sum(jnp.ones_like(receivers, jnp.float32), receivers,
                                num_segments=n_nodes)
        return s / jnp.maximum(c[:, None], 1.0)
    raise ValueError(cfg.aggregator)


def _forward_body(params, node_feat, edge_feat, senders, receivers, cfg: GNNConfig,
                  edge_axes: tuple[str, ...] = (), node_axes: tuple[str, ...] = ()):
    """shard_map body (or unsharded when axes are empty).

    Layout: node tensors sharded over ``node_axes`` (pod, data); edge tensors
    sharded over ALL mesh axes.  Each layer all-gathers the node states
    (transient), computes local edge messages, segment-sums into a full-N
    partial aggregate, psums it over the edge axes, and keeps only the local
    node slice — so the *persistent* per-layer state is O(N/|node_axes| +
    E/|mesh|) while the O(N) buffers are transient.  Layers are remat'd."""
    h_loc = _block(params["node_enc"], node_feat)
    e = _block(params["edge_enc"], edge_feat)
    n_loc = h_loc.shape[0]
    n_total = n_loc
    node_idx = 0
    for ax in node_axes:
        n_total *= compat.axis_size(ax)
        node_idx = node_idx * compat.axis_size(ax) + jax.lax.axis_index(ax)

    def gather_full(h_l):
        h = h_l
        for ax in reversed(node_axes):
            h = jax.lax.all_gather(h, ax, axis=0, tiled=True)
        return h

    def layer(carry, lp):
        h_l, e = carry
        h = gather_full(h_l)
        hs = jnp.take(h, senders, axis=0)
        hr = jnp.take(h, receivers, axis=0)
        e_new = e + _block(lp["edge"], jnp.concatenate([e, hs, hr], axis=-1))
        agg = _aggregate(cfg, e_new, receivers, h.shape[0])
        for ax in edge_axes:
            agg = jax.lax.psum(agg, ax)
        agg_l = jax.lax.dynamic_slice_in_dim(agg, node_idx * n_loc, n_loc, axis=0)
        h_new = h_l + _block(lp["node"], jnp.concatenate([h_l, agg_l], axis=-1))
        return (h_new, e_new), None

    (h_loc, e), _ = jax.lax.scan(jax.checkpoint(layer), (h_loc, e), params["proc"])
    return layers.mlp(params["decoder"]["mlp"], h_loc)


def _loss_from_out(out, batch, cfg: GNNConfig, node_axes: tuple[str, ...] = ()):
    def allsum(x):
        for ax in node_axes:
            x = jax.lax.psum(x, ax)
        return x

    if cfg.graph_readout:
        g = jax.ops.segment_sum(out, batch["graph_ids"],
                                num_segments=batch["graph_labels"].shape[0])
        g = allsum(g)  # graphs may straddle node shards
        return jnp.mean(jnp.square(g - batch["graph_labels"]))
    if cfg.task == "classification":
        logits = out.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
        mask = batch.get("label_mask", jnp.ones_like(lse))
        return allsum(jnp.sum((lse - gold) * mask)) / jnp.maximum(
            allsum(jnp.sum(mask)), 1.0
        )
    mask = batch.get("label_mask", jnp.ones(out.shape[0], out.dtype))
    se = jnp.sum(jnp.square(out - batch["labels"]) * mask[:, None])
    n = jnp.maximum(allsum(jnp.sum(mask)) * out.shape[-1], 1.0)
    return allsum(se) / n


def forward(params, node_feat, edge_feat, senders, receivers, cfg: GNNConfig,
            mesh=None):
    """Full-graph forward -> (N_local, d_out) per node shard (global (N, d_out)
    array sharded over the batch axes when a mesh is given)."""
    if mesh is None:
        return _forward_body(params, node_feat, edge_feat, senders, receivers, cfg)
    from repro.common.compat import shard_map

    axes = tuple(mesh.axis_names)
    node_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    espec, nspec = P(axes), P(node_axes)
    body = functools.partial(_forward_body, cfg=cfg, edge_axes=axes, node_axes=node_axes)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), nspec, espec, espec, espec),
        out_specs=nspec,
        check_vma=False,
    )(params, node_feat, edge_feat, senders, receivers)


def loss_fn(params, batch, cfg: GNNConfig, mesh=None):
    if mesh is None:
        out = _forward_body(params, batch["node_feat"], batch["edge_feat"],
                            batch["senders"], batch["receivers"], cfg)
        return _loss_from_out(out, batch, cfg)
    from repro.common.compat import shard_map

    axes = tuple(mesh.axis_names)
    node_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    espec, nspec = P(axes), P(node_axes)

    node_keys = [k for k in ("labels", "label_mask", "graph_ids") if k in batch]
    repl_keys = [k for k in ("graph_labels",) if k in batch]

    def body(params, node_feat, edge_feat, senders, receivers, *rest):
        out = _forward_body(params, node_feat, edge_feat, senders, receivers, cfg,
                            edge_axes=axes, node_axes=node_axes)
        b = dict(zip(node_keys + repl_keys, rest))
        return _loss_from_out(out, b, cfg, node_axes)

    in_specs = (
        (P(), nspec, espec, espec, espec)
        + tuple(nspec for _ in node_keys)
        + tuple(P() for _ in repl_keys)
    )
    loss = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_vma=False)(
        params, batch["node_feat"], batch["edge_feat"], batch["senders"],
        batch["receivers"], *[batch[k] for k in node_keys + repl_keys]
    )
    return loss


def make_train_step(cfg: GNNConfig, mesh=None, lr: float = 1e-3):
    from repro.optim import adam_update

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, batch, cfg, mesh))(params)
        params, opt_state, om = adam_update(grads, opt_state, params, lr=lr, grad_clip=1.0)
        return params, opt_state, {"loss": loss, **om}

    return step


# ---------------------------------------------------------------------------
# neighbor sampling (minibatch_lg): uniform fanout over CSR, in-graph
# ---------------------------------------------------------------------------

def sample_neighbors(key, row_ptr, col_idx, nodes, fanout: int):
    """Uniform-with-replacement fanout sample.  nodes: (...,) -> (..., fanout).

    Zero-degree nodes self-loop."""
    deg = row_ptr[nodes + 1] - row_ptr[nodes]
    u = jax.random.uniform(key, (*nodes.shape, fanout))
    off = jnp.floor(u * jnp.maximum(deg, 1)[..., None]).astype(row_ptr.dtype)
    idx = row_ptr[nodes][..., None] + off
    nbr = col_idx[jnp.minimum(idx, col_idx.shape[0] - 1)]
    return jnp.where((deg > 0)[..., None], nbr, nodes[..., None])


def sampled_forward(params, key, batch, cfg: GNNConfig):
    """GraphSAGE-regime two-hop forward for seed nodes.

    batch: {row_ptr, col_idx, node_feat (N, d), seeds (B,)} -> (B, d_out).
    Uses the encoder + first two processor-layer node MLPs as the two
    aggregation levels (weight-shared with the full-graph model)."""
    k1, k2 = jax.random.split(key)
    seeds = batch["seeds"]
    f1, f2 = cfg.fanout[0], cfg.fanout[1]
    n1 = sample_neighbors(k1, batch["row_ptr"], batch["col_idx"], seeds, f1)       # (B, f1)
    n2 = sample_neighbors(k2, batch["row_ptr"], batch["col_idx"], n1, f2)          # (B, f1, f2)

    enc = lambda x: _block(params["node_enc"], x)
    h_seed = enc(batch["node_feat"][seeds])
    h1 = enc(batch["node_feat"][n1])
    h2 = enc(batch["node_feat"][n2])

    lp0 = jax.tree_util.tree_map(lambda x: x[0], params["proc"])
    lp1 = jax.tree_util.tree_map(lambda x: x[1], params["proc"])
    agg2 = jnp.sum(h2, axis=2)  # (B, f1, d)
    h1 = h1 + _block(lp0["node"], jnp.concatenate([h1, agg2], axis=-1))
    agg1 = jnp.sum(h1, axis=1)  # (B, d)
    h_seed = h_seed + _block(lp1["node"], jnp.concatenate([h_seed, agg1], axis=-1))
    return layers.mlp(params["decoder"]["mlp"], h_seed)


def make_sampled_train_step(cfg: GNNConfig, lr: float = 1e-3):
    from repro.optim import adam_update

    def step(params, opt_state, key, batch):
        def lf(p):
            out = sampled_forward(p, key, batch, cfg).astype(jnp.float32)
            if cfg.task == "classification":
                lse = jax.nn.logsumexp(out, axis=-1)
                gold = jnp.take_along_axis(out, batch["labels"][:, None], axis=-1)[:, 0]
                return jnp.mean(lse - gold)
            return jnp.mean(jnp.square(out - batch["labels"]))

        loss, grads = jax.value_and_grad(lf)(params)
        params, opt_state, om = adam_update(grads, opt_state, params, lr=lr, grad_clip=1.0)
        return params, opt_state, {"loss": loss, **om}

    return step
