"""RecSys family: DeepFM, xDeepFM (CIN), BST, two-tower retrieval.

The substrate JAX lacks natively is built here:

* ``sharded_embedding_lookup`` — the distributed EmbeddingBag: tables are
  row-sharded over the ``model`` axis; each shard resolves the ids that land
  in its row range (gather + mask) and the partial rows are psum-combined.
  One combined table holds all fields (ids are field-offset, FBGEMM-style).
* ``embedding_bag`` — multi-hot gather + segment-sum/mean (BST histories).

``retrieval_cand`` (two-tower) reuses the corpus-sharded MIPS pattern from
the LEMUR serving path: candidates sharded over the whole mesh, local top-k,
all-gather merge.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common import compat
from repro.common.config import ConfigBase
from repro.common.prng import PRNGSeq
from repro.nn import layers


@dataclasses.dataclass(frozen=True)
class RecsysConfig(ConfigBase):
    name: str = "deepfm"
    model: str = "deepfm"            # deepfm | xdeepfm | bst | two_tower
    vocab_sizes: tuple[int, ...] = (1000,) * 39
    embed_dim: int = 10
    mlp_dims: tuple[int, ...] = (400, 400, 400)
    # xDeepFM
    cin_dims: tuple[int, ...] = (200, 200, 200)
    # BST
    seq_len: int = 20
    n_heads: int = 8
    n_blocks: int = 1
    n_items: int = 2_000_000
    # two-tower
    tower_dims: tuple[int, ...] = (1024, 512, 256)
    out_dim: int = 256
    temperature: float = 0.05

    @property
    def n_fields(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_vocab(self) -> int:
        return int(sum(self.vocab_sizes))

    @property
    def field_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.vocab_sizes)[:-1]]).astype(np.int64)


# ---------------------------------------------------------------------------
# distributed embedding substrate
# ---------------------------------------------------------------------------

def _lookup_body(table, ids, *, n_rows_global: int):
    """shard_map body: table (rows_loc, d) on 'model'; ids (B_loc, ...)."""
    j = jax.lax.axis_index("model")
    rows_loc = table.shape[0]
    local = ids - j * rows_loc
    ok = (local >= 0) & (local < rows_loc)
    rows = jnp.take(table, jnp.clip(local, 0, rows_loc - 1), axis=0)
    rows = rows * ok[..., None].astype(table.dtype)
    return jax.lax.psum(rows, "model")


def sharded_embedding_lookup(table, ids, mesh, *, batch_axes=("pod", "data")):
    """table: (V, d) P('model', None); ids: (B, ...) batch-sharded -> (B, ..., d).

    Batches that don't divide the batch axes (e.g. the single-query retrieval
    cell) fall back to replicated ids."""
    import numpy as np
    from repro.common.compat import shard_map

    axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    n_batch = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if ids.shape[0] % max(n_batch, 1) != 0:
        axes = ()
    body = functools.partial(_lookup_body, n_rows_global=table.shape[0])
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P("model", None), P(axes)),
        out_specs=P(axes),
        check_vma=False,
    )(table, ids)


def embedding_lookup(table, ids, mesh=None):
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return jnp.take(table, ids, axis=0)
    return sharded_embedding_lookup(table, ids, mesh)


def embedding_bag(table, ids, mesh=None, *, combiner: str = "mean", pad_id: int = 0):
    """Multi-hot bag: ids (B, L) -> (B, d) with mean/sum over valid (id != pad)."""
    e = embedding_lookup(table, ids, mesh)                  # (B, L, d)
    mask = (ids != pad_id)[..., None].astype(e.dtype)
    s = jnp.sum(e * mask, axis=-2)
    if combiner == "sum":
        return s
    return s / jnp.maximum(jnp.sum(mask, axis=-2), 1.0)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_recsys(key, cfg: RecsysConfig):
    ks = PRNGSeq(key)
    d = cfg.embed_dim
    params: dict[str, Any] = {}
    if cfg.model in ("deepfm", "xdeepfm"):
        params["table"] = layers.init_embedding(next(ks), cfg.total_vocab, d)
        params["first_order"] = layers.init_embedding(next(ks), cfg.total_vocab, 1)
        params["bias"] = jnp.zeros(())
        deep_in = cfg.n_fields * d
        params["deep"] = layers.init_mlp(next(ks), (deep_in, *cfg.mlp_dims, 1))
        if cfg.model == "xdeepfm":
            dims = (cfg.n_fields, *cfg.cin_dims)
            params["cin"] = {
                f"layer_{i}": layers.variance_scaling(
                    next(ks), (dims[i + 1], dims[i], cfg.n_fields)
                )
                for i in range(len(cfg.cin_dims))
            }
            params["cin_out"] = layers.init_dense(next(ks), sum(cfg.cin_dims), 1, True)
    elif cfg.model == "bst":
        params["item_table"] = layers.init_embedding(next(ks), cfg.n_items, d)
        params["pos_table"] = layers.init_embedding(next(ks), cfg.seq_len + 1, d)
        from repro.nn import attention

        params["blocks"] = {}
        for b in range(cfg.n_blocks):
            params["blocks"][f"block_{b}"] = {
                "attn": attention.init_gqa(next(ks), d, cfg.n_heads, cfg.n_heads,
                                           max(1, d // cfg.n_heads)),
                "ln1": layers.init_layernorm(d),
                "ln2": layers.init_layernorm(d),
                "ffn": layers.init_ffn(next(ks), d, 4 * d, gated=False, use_bias=True),
            }
        mlp_in = (cfg.seq_len + 1) * d
        params["mlp"] = layers.init_mlp(next(ks), (mlp_in, *cfg.mlp_dims, 1))
    elif cfg.model == "two_tower":
        params["user_table"] = layers.init_embedding(next(ks), cfg.total_vocab, d)
        params["item_table"] = layers.init_embedding(next(ks), cfg.n_items, d)
        user_in = cfg.n_fields * d
        params["user_tower"] = layers.init_mlp(next(ks), (user_in, *cfg.tower_dims, cfg.out_dim))
        params["item_tower"] = layers.init_mlp(next(ks), (d, *cfg.tower_dims, cfg.out_dim))
    else:
        raise ValueError(cfg.model)
    return params


# ---------------------------------------------------------------------------
# forwards
# ---------------------------------------------------------------------------

def _offset_ids(cfg: RecsysConfig, ids):
    return ids + jnp.asarray(cfg.field_offsets, ids.dtype)[None, :]


def deepfm_forward(params, ids, cfg: RecsysConfig, mesh=None):
    """ids: (B, F) per-field ids (unoffset) -> logits (B,)."""
    gids = _offset_ids(cfg, ids)
    emb = embedding_lookup(params["table"]["embedding"], gids, mesh)   # (B, F, d)
    first = embedding_lookup(params["first_order"]["embedding"], gids, mesh)[..., 0]
    sum_v = jnp.sum(emb, axis=1)
    fm = 0.5 * jnp.sum(jnp.square(sum_v) - jnp.sum(jnp.square(emb), axis=1), axis=-1)
    deep = layers.mlp(params["deep"], emb.reshape(emb.shape[0], -1))[:, 0]
    return params["bias"] + jnp.sum(first, axis=1) + fm + deep


def xdeepfm_forward(params, ids, cfg: RecsysConfig, mesh=None):
    gids = _offset_ids(cfg, ids)
    emb = embedding_lookup(params["table"]["embedding"], gids, mesh)   # (B, F, d)
    first = embedding_lookup(params["first_order"]["embedding"], gids, mesh)[..., 0]
    # CIN (arXiv:1803.05170 eq. 6): x^{k+1}_h = sum_ij W^k_{h,i,j} (x^k_i ∘ x^0_j)
    x0, xk = emb, emb
    pools = []
    for i in range(len(cfg.cin_dims)):
        w = params["cin"][f"layer_{i}"]                                # (H, Hk, F)
        xk = jnp.einsum("bid,bjd,hij->bhd", xk, x0, w)
        pools.append(jnp.sum(xk, axis=-1))                             # (B, H)
    cin = layers.dense(params["cin_out"], jnp.concatenate(pools, axis=-1))[:, 0]
    deep = layers.mlp(params["deep"], emb.reshape(emb.shape[0], -1))[:, 0]
    return params["bias"] + jnp.sum(first, axis=1) + cin + deep


def bst_forward(params, history, target_item, cfg: RecsysConfig, mesh=None):
    """history: (B, L); target_item: (B,) -> logits (B,)."""
    B, L = history.shape
    seq = jnp.concatenate([history, target_item[:, None]], axis=1)     # (B, L+1)
    e = embedding_lookup(params["item_table"]["embedding"], seq, mesh)
    e = e + params["pos_table"]["embedding"][None, : L + 1]
    for b in range(cfg.n_blocks):
        blk = params["blocks"][f"block_{b}"]
        h = layers.layernorm(blk["ln1"], e)
        q = jnp.einsum("btd,dhk->bthk", h, blk["attn"]["wq"])
        k = jnp.einsum("btd,dhk->bthk", h, blk["attn"]["wk"])
        v = jnp.einsum("btd,dhk->bthk", h, blk["attn"]["wv"])
        s = jnp.einsum("bthk,bshk->bhts", q, k) / jnp.sqrt(q.shape[-1] * 1.0)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhts,bshk->bthk", a, v)
        e = e + jnp.einsum("bthk,hkd->btd", o, blk["attn"]["wo"])
        h = layers.layernorm(blk["ln2"], e)
        e = e + layers.ffn(blk["ffn"], h, "gelu")
    return layers.mlp(params["mlp"], e.reshape(B, -1), activation="relu")[:, 0]


def two_tower_user(params, ids, cfg: RecsysConfig, mesh=None):
    gids = _offset_ids(cfg, ids)
    emb = embedding_lookup(params["user_table"]["embedding"], gids, mesh)
    u = layers.mlp(params["user_tower"], emb.reshape(emb.shape[0], -1))
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)


def two_tower_item(params, item_ids, cfg: RecsysConfig, mesh=None):
    e = embedding_lookup(params["item_table"]["embedding"], item_ids, mesh)
    v = layers.mlp(params["item_tower"], e)
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


FORWARDS = {
    "deepfm": deepfm_forward,
    "xdeepfm": xdeepfm_forward,
}


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------

def bce_loss(logits, labels):
    logits = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def ctr_loss(params, batch, cfg: RecsysConfig, mesh=None):
    if cfg.model == "bst":
        logits = bst_forward(params, batch["history"], batch["target_item"], cfg, mesh)
    else:
        logits = FORWARDS[cfg.model](params, batch["ids"], cfg, mesh)
    return bce_loss(logits, batch["labels"])


def two_tower_loss(params, batch, cfg: RecsysConfig, mesh=None):
    """In-batch sampled softmax with logQ correction (Yi et al. RecSys'19)."""
    u = two_tower_user(params, batch["ids"], cfg, mesh)         # (B, D)
    v = two_tower_item(params, batch["item"], cfg, mesh)        # (B, D)
    logits = (u @ v.T) / cfg.temperature                        # (B, B)
    logq = batch.get("logq")
    if logq is not None:
        logits = logits - logq[None, :]
    labels = jnp.arange(u.shape[0])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def make_train_step(cfg: RecsysConfig, mesh=None, lr: float = 1e-3):
    from repro.optim import adam_update

    lf = two_tower_loss if cfg.model == "two_tower" else ctr_loss

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: lf(p, batch, cfg, mesh))(params)
        params, opt_state, om = adam_update(grads, opt_state, params, lr=lr, grad_clip=1.0)
        return params, opt_state, {"loss": loss, **om}

    return step


def make_serve_step(cfg: RecsysConfig, mesh=None, *, chunk: int = 0):
    """Pointwise scoring step.  ``chunk`` > 0 streams the batch through
    fixed-size tiles with lax.map (bounds the CIN/MLP activation footprint for
    the 262k/1M bulk-scoring cells — offline scoring is throughput-bound, not
    latency-bound, so tiling is free)."""

    def score(params, batch):
        if cfg.model == "bst":
            return bst_forward(params, batch["history"], batch["target_item"], cfg, mesh)
        if cfg.model == "two_tower":
            u = two_tower_user(params, batch["ids"], cfg, mesh)
            v = two_tower_item(params, batch["item"], cfg, mesh)
            return jnp.sum(u * v, axis=-1)
        return FORWARDS[cfg.model](params, batch["ids"], cfg, mesh)

    def step(params, batch):
        n = jax.tree_util.tree_leaves(batch)[0].shape[0]
        if not chunk or n <= chunk or n % chunk != 0:
            return score(params, batch)
        nc = n // chunk
        tiled = jax.tree_util.tree_map(
            lambda x: x.reshape(nc, chunk, *x.shape[1:]), batch
        )
        out = jax.lax.map(lambda b: score(params, b), tiled)
        return out.reshape(n)

    return step


def _retrieval_body(u, cand, *, k: int, axes: tuple[str, ...]):
    """shard_map body: u (B, D) replicated; cand (m_loc, D) corpus-sharded."""
    s = u @ cand.T                               # (B, m_loc)
    m_loc = cand.shape[0]
    kk = min(k, m_loc)
    top, ids = jax.lax.top_k(s, kk)
    idx = 0
    for ax in axes:
        idx = idx * compat.axis_size(ax) + jax.lax.axis_index(ax)
    gids = ids + idx * m_loc
    for ax in axes:
        top = jax.lax.all_gather(top, ax, axis=1, tiled=True)
        gids = jax.lax.all_gather(gids, ax, axis=1, tiled=True)
    out_s, pos = jax.lax.top_k(top, k)
    return out_s, jnp.take_along_axis(gids, pos, axis=1)


def make_retrieval_step(cfg: RecsysConfig, mesh, k: int = 100):
    """Score one query batch against the full candidate matrix (sharded over
    the whole mesh) and return global top-k — the `retrieval_cand` cell."""
    from repro.common.compat import shard_map

    axes = tuple(mesh.axis_names)

    def step(params, batch, candidates):
        u = two_tower_user(params, batch["ids"], cfg, mesh)
        return shard_map(
            functools.partial(_retrieval_body, k=k, axes=axes),
            mesh=mesh,
            in_specs=(P(), P(axes)),
            out_specs=(P(), P()),
            check_vma=False,
        )(u, candidates)

    return step
