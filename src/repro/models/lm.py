"""Decoder-only LM family covering all five assigned transformer archs.

One config-driven implementation: GQA/MQA (qwen/granite/gemma/llama4) and
MLA (deepseek-v3) attention; dense GeGLU/SwiGLU/GELU or MoE FFN (ep /
ffslice expert-parallel layouts); interleaved layer patterns (llama4 dense↔
MoE alternation + chunked-attention with full attention every 4th layer;
deepseek's 3 dense prefix layers).

Layers are grouped into repeating *blocks* and scanned (``lax.scan``) so the
HLO is O(1) in depth — essential for compiling 61-layer models on the
512-device dry-run mesh.  Caches, params and per-layer specs are stacked on
the scan axis.

Entry points (all jit-able, mesh-aware):
  init_lm / forward_train / lm_loss / make_train_step
  prefill / decode / make_prefill_step / make_decode_step
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.config import ConfigBase
from repro.common.prng import PRNGSeq
from repro.nn import attention, layers, moe


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LMConfig(ConfigBase):
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab: int = 32000
    activation: str = "silu"
    gated: bool = True
    mlp_bias: bool = False
    qkv_bias: bool = False
    norm: str = "rms"            # rms | ln
    rope_base: float = 10000.0
    tie_embeddings: bool = False
    embed_scale: bool = False    # gemma: multiply embeddings by sqrt(d)
    # attention type
    attn: str = "gqa"            # gqa | mla
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_head: int = 0
    # MoE
    moe_n_experts: int = 0
    moe_top_k: int = 1
    moe_d_ff: int = 0
    moe_shared: int = 0          # shared experts (deepseek: 1)
    moe_layout: str = "ep"       # ep | ffslice (see nn.moe)
    moe_period: int = 0          # 0 = dense model; 1 = every layer; 2 = alternate
    prefix_dense_layers: int = 0 # deepseek: first 3 layers dense
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    # llama4 chunked attention
    chunk_attn: int = 0          # 0 = full; else local chunk size
    full_attn_every: int = 0     # every Nth layer uses full attention
    # execution
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    q_block: int = 512
    kv_block: int = 512
    loss_chunk: int = 512
    remat: str = "full"          # none | full
    scan_layers: bool = True
    seq_shard: bool = True       # sequence-parallel activation sharding between
                                 # layers (residual stream sharded T -> "model";
                                 # keeps scan-boundary residuals O(T/|model|))

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    is_moe: bool
    chunk: int  # 0 = full attention


def layer_stacks(cfg: LMConfig) -> list[tuple[int, tuple[LayerSpec, ...]]]:
    """Derive (n_blocks, block_pattern) stacks from the config."""
    specs = []
    for i in range(cfg.n_layers):
        if cfg.moe_n_experts > 0 and cfg.moe_period > 0 and i >= cfg.prefix_dense_layers:
            is_moe = ((i - cfg.prefix_dense_layers) % cfg.moe_period) == cfg.moe_period - 1
        else:
            is_moe = False
        chunk = cfg.chunk_attn
        if chunk and cfg.full_attn_every and (i + 1) % cfg.full_attn_every == 0:
            chunk = 0
        specs.append(LayerSpec(is_moe, chunk))

    stacks: list[tuple[int, tuple[LayerSpec, ...]]] = []
    i = 0
    if cfg.prefix_dense_layers:
        stacks.append((cfg.prefix_dense_layers, (specs[0],)))
        i = cfg.prefix_dense_layers
    rest = specs[i:]
    if not rest:
        return stacks
    # find the shortest repeating pattern in the remaining layers
    for plen in range(1, len(rest) + 1):
        if len(rest) % plen:
            continue
        pat = rest[:plen]
        if all(rest[j] == pat[j % plen] for j in range(len(rest))):
            stacks.append((len(rest) // plen, tuple(pat)))
            return stacks
    stacks.append((1, tuple(rest)))
    return stacks


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_norm(cfg: LMConfig):
    return (layers.init_rmsnorm(cfg.d_model, cfg.pdtype) if cfg.norm == "rms"
            else layers.init_layernorm(cfg.d_model, cfg.pdtype))


def _norm(cfg: LMConfig, p, x):
    return layers.rmsnorm(p, x) if cfg.norm == "rms" else layers.layernorm(p, x)


def _init_layer(key, cfg: LMConfig, spec: LayerSpec):
    ks = PRNGSeq(key)
    p: dict[str, Any] = {"ln1": _init_norm(cfg), "ln2": _init_norm(cfg)}
    if cfg.attn == "mla":
        p["attn"] = attention.init_mla(
            next(ks), cfg.d_model, cfg.n_heads, cfg.q_lora, cfg.kv_lora,
            cfg.qk_nope, cfg.qk_rope, cfg.v_head, cfg.pdtype,
        )
    else:
        p["attn"] = attention.init_gqa(
            next(ks), cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            cfg.qkv_bias, cfg.pdtype,
        )
    if spec.is_moe:
        p["moe"] = moe.init_moe(
            next(ks), cfg.moe_n_experts, cfg.d_model, cfg.moe_d_ff or cfg.d_ff,
            gated=cfg.gated, n_shared=cfg.moe_shared, shared_d_ff=cfg.moe_d_ff or cfg.d_ff,
            dtype=cfg.pdtype,
        )
    else:
        p["mlp"] = layers.init_ffn(next(ks), cfg.d_model, cfg.d_ff, cfg.gated,
                                   cfg.mlp_bias, cfg.pdtype)
    return p


def init_lm(key, cfg: LMConfig):
    ks = PRNGSeq(key)
    params: dict[str, Any] = {
        "embed": layers.init_embedding(next(ks), cfg.vocab, cfg.d_model, cfg.pdtype),
        "final_norm": _init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["head"] = layers.init_dense(next(ks), cfg.d_model, cfg.vocab, False, cfg.pdtype)
    for si, (n_blocks, block) in enumerate(layer_stacks(cfg)):
        keys = jnp.stack(ks.take(n_blocks))

        def init_block(k):
            sub = PRNGSeq(k)
            return {
                f"pos_{pi}": _init_layer(next(sub), cfg, spec)
                for pi, spec in enumerate(block)
            }

        params[f"stack_{si}"] = jax.vmap(init_block)(keys)
    return params


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _attn_train(cfg: LMConfig, p, x, positions, chunk, mesh=None):
    if cfg.attn == "mla":
        return attention.mla_train(
            p, x, positions, qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope,
            kv_lora=cfg.kv_lora, rope_base=cfg.rope_base, kv_block=cfg.kv_block,
            q_block=cfg.q_block, mesh=mesh,
        )
    return attention.gqa_train(
        p, x, positions, rope_base=cfg.rope_base, chunk=chunk or None,
        q_block=cfg.q_block, kv_block=cfg.kv_block, mesh=mesh,
    )


def _layer_train(cfg: LMConfig, spec: LayerSpec, p, x, positions, mesh):
    h = _norm(cfg, p["ln1"], x)
    x = x + _attn_train(cfg, p["attn"], h, positions, spec.chunk, mesh)
    h = _norm(cfg, p["ln2"], x)
    if spec.is_moe:
        if mesh is not None:
            y, aux = moe.moe_apply(
                p["moe"], h, layout=cfg.moe_layout, n_experts=cfg.moe_n_experts,
                top_k=cfg.moe_top_k, mesh=mesh, capacity_factor=cfg.capacity_factor,
                activation=cfg.activation,
            )
        else:
            y, aux = moe.moe_apply_dense(
                p["moe"], h, n_experts=cfg.moe_n_experts, top_k=cfg.moe_top_k,
                activation=cfg.activation,
            )
    else:
        y, aux = layers.ffn(p["mlp"], h, cfg.activation), 0.0
    return x + y, aux


def _seq_constraint(cfg: LMConfig, x, mesh):
    """Sequence-parallel residual-stream constraint (Korthikanti et al.)."""
    if mesh is None or not cfg.seq_shard or "model" not in mesh.axis_names:
        return x
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(batch_axes, "model", None))
    )


def forward_train(params, tokens, cfg: LMConfig, mesh=None):
    """tokens: (B, T) -> (hidden (B, T, d), aux_loss)."""
    B, T = tokens.shape
    x = layers.embed(params["embed"], tokens).astype(cfg.cdtype)
    if cfg.embed_scale:
        x = x * jnp.sqrt(float(cfg.d_model)).astype(cfg.cdtype)
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    aux_total = jnp.zeros((), jnp.float32)
    for si, (n_blocks, block) in enumerate(layer_stacks(cfg)):
        stack = params[f"stack_{si}"]

        def block_fn(x, bp):
            aux_b = jnp.zeros((), jnp.float32)
            for pi, spec in enumerate(block):
                x, aux = _layer_train(cfg, spec, bp[f"pos_{pi}"], x, positions, mesh)
                aux_b = aux_b + aux
            x = _seq_constraint(cfg, x, mesh)
            return x, aux_b

        if cfg.remat == "full":
            block_fn = jax.checkpoint(block_fn)
        x, auxs = jax.lax.scan(lambda c, bp: block_fn(c, bp), x, stack)
        aux_total = aux_total + jnp.sum(auxs)
    x = _norm(cfg, params["final_norm"], x)
    return x, aux_total


def lm_loss(params, hidden, labels, cfg: LMConfig):
    """Chunked softmax cross-entropy (never materializes (B, T, V))."""
    B, T, d = hidden.shape
    chunk = min(cfg.loss_chunk, T)
    nb = T // chunk if T % chunk == 0 else 1
    chunk = T // nb

    def readout(h):
        if cfg.tie_embeddings:
            return layers.embed_logits(params["embed"], h)
        return layers.dense(params["head"], h)

    def chunk_loss(carry, xs):
        h, y = xs  # (B, chunk, d), (B, chunk)
        logits = readout(h).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    hs = jnp.moveaxis(hidden.reshape(B, nb, chunk, d), 1, 0)
    ys = jnp.moveaxis(labels.reshape(B, nb, chunk), 1, 0)
    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (hs, ys))
    return total / (B * T)


def make_train_step(cfg: LMConfig, mesh=None, *, optimizer=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    from repro.optim import adam_update

    def loss_fn(params, tokens, labels):
        hidden, aux = forward_train(params, tokens, cfg, mesh)
        loss = lm_loss(params, hidden, labels, cfg)
        return loss + cfg.aux_loss_coef * aux, (loss, aux)

    def train_step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        (tot, (loss, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, tokens, labels
        )
        params, opt_state, om = adam_update(
            grads, opt_state, params, lr=1e-3, grad_clip=1.0
        )
        metrics = {"loss": loss, "aux_loss": aux, **om}
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# serving: prefill + decode with stacked caches
# ---------------------------------------------------------------------------

def _attn_prefill(cfg, p, x, positions, cache_len, chunk, mesh=None):
    if cfg.attn == "mla":
        return attention.mla_prefill(
            p, x, positions, cache_len, qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope,
            kv_lora=cfg.kv_lora, rope_base=cfg.rope_base, kv_block=cfg.kv_block,
            q_block=cfg.q_block, mesh=mesh,
        )
    return attention.gqa_prefill(
        p, x, positions, cache_len, rope_base=cfg.rope_base, chunk=chunk or None,
        q_block=cfg.q_block, kv_block=cfg.kv_block, mesh=mesh,
    )


def _attn_decode(cfg, p, x, cache, kv_len, chunk):
    if cfg.attn == "mla":
        return attention.mla_decode(
            p, x, cache, kv_len, qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope,
            kv_lora=cfg.kv_lora, rope_base=cfg.rope_base,
        )
    return attention.gqa_decode(p, x, cache, kv_len, rope_base=cfg.rope_base,
                                chunk=chunk or None)


def _layer_serve(cfg, spec, p, x, mesh, attn_fn):
    h = _norm(cfg, p["ln1"], x)
    a, cache = attn_fn(p["attn"], h)
    x = x + a
    h = _norm(cfg, p["ln2"], x)
    if spec.is_moe:
        if mesh is not None:
            y, _ = moe.moe_apply(
                p["moe"], h, layout=cfg.moe_layout, n_experts=cfg.moe_n_experts,
                top_k=cfg.moe_top_k, mesh=mesh, capacity_factor=cfg.capacity_factor,
                activation=cfg.activation,
            )
        else:
            y, _ = moe.moe_apply_dense(
                p["moe"], h, n_experts=cfg.moe_n_experts, top_k=cfg.moe_top_k,
                activation=cfg.activation,
            )
    else:
        y = layers.ffn(p["mlp"], h, cfg.activation)
    return x + y, cache


def prefill(params, tokens, cfg: LMConfig, cache_len: int, mesh=None):
    """Returns (last_token_logits, caches).  caches: list per stack of stacked
    per-layer caches (leading dim n_blocks)."""
    B, T = tokens.shape
    x = layers.embed(params["embed"], tokens).astype(cfg.cdtype)
    if cfg.embed_scale:
        x = x * jnp.sqrt(float(cfg.d_model)).astype(cfg.cdtype)
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    caches = []
    for si, (n_blocks, block) in enumerate(layer_stacks(cfg)):
        stack = params[f"stack_{si}"]

        def block_fn(x, bp):
            cs = {}
            for pi, spec in enumerate(block):
                attn_fn = lambda p, h, _spec=spec: _attn_prefill(
                    cfg, p, h, positions, cache_len, _spec.chunk, mesh
                )
                x, c = _layer_serve(cfg, spec, bp[f"pos_{pi}"], x, mesh, attn_fn)
                cs[f"pos_{pi}"] = c
            return x, cs

        x, stack_caches = jax.lax.scan(block_fn, x, stack)
        caches.append(stack_caches)
    x = _norm(cfg, params["final_norm"], x)
    last = x[:, -1:]
    logits = (layers.embed_logits(params["embed"], last) if cfg.tie_embeddings
              else layers.dense(params["head"], last))
    return logits[:, 0], caches


def decode(params, token, caches, kv_len, cfg: LMConfig, mesh=None):
    """One decode step.  token: (B, 1) int32; kv_len includes the new token.
    Returns (logits (B, vocab), new_caches)."""
    x = layers.embed(params["embed"], token).astype(cfg.cdtype)
    if cfg.embed_scale:
        x = x * jnp.sqrt(float(cfg.d_model)).astype(cfg.cdtype)

    new_caches = []
    for si, (n_blocks, block) in enumerate(layer_stacks(cfg)):
        stack = params[f"stack_{si}"]

        def block_fn(x, xs):
            bp, bc = xs
            ncs = {}
            for pi, spec in enumerate(block):
                attn_fn = lambda p, h, _spec=spec, _c=bc[f"pos_{pi}"]: _attn_decode(
                    cfg, p, h, _c, kv_len, _spec.chunk
                )
                x, c = _layer_serve(cfg, spec, bp[f"pos_{pi}"], x, mesh, attn_fn)
                ncs[f"pos_{pi}"] = c
            return x, ncs

        x, ncache = jax.lax.scan(block_fn, x, (stack, caches[si]))
        new_caches.append(ncache)
    x = _norm(cfg, params["final_norm"], x)
    logits = (layers.embed_logits(params["embed"], x) if cfg.tie_embeddings
              else layers.dense(params["head"], x))
    return logits[:, 0], new_caches


def init_cache(cfg: LMConfig, batch: int, cache_len: int):
    """Zero KV caches matching prefill()'s output structure (for decode-only
    dry-run cells and serving restarts).  dtype follows compute_dtype."""
    caches = []
    for n_blocks, block in layer_stacks(cfg):
        stack_cache = {}
        for pi, spec in enumerate(block):
            if cfg.attn == "mla":
                c = (
                    jnp.zeros((n_blocks, batch, cache_len, cfg.kv_lora), cfg.cdtype),
                    jnp.zeros((n_blocks, batch, cache_len, cfg.qk_rope), cfg.cdtype),
                )
            else:
                c = (
                    jnp.zeros((n_blocks, batch, cache_len, cfg.n_kv_heads, cfg.head_dim), cfg.cdtype),
                    jnp.zeros((n_blocks, batch, cache_len, cfg.n_kv_heads, cfg.head_dim), cfg.cdtype),
                )
            stack_cache[f"pos_{pi}"] = c
        caches.append(stack_cache)
    return caches


def make_prefill_step(cfg: LMConfig, cache_len: int, mesh=None):
    def step(params, tokens):
        return prefill(params, tokens, cfg, cache_len, mesh)

    return step


def make_decode_step(cfg: LMConfig, mesh=None):
    def step(params, token, caches, kv_len):
        logits, new_caches = decode(params, token, caches, kv_len, cfg, mesh)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, new_caches

    return step


# ---------------------------------------------------------------------------
# parameter / FLOP accounting (roofline §g)
# ---------------------------------------------------------------------------

def param_count(cfg: LMConfig) -> int:
    import numpy as np

    n = cfg.vocab * cfg.d_model  # embed
    if not cfg.tie_embeddings:
        n += cfg.vocab * cfg.d_model
    for nb, block in layer_stacks(cfg):
        per_block = 0
        for spec in block:
            if cfg.attn == "mla":
                per_block += cfg.d_model * cfg.q_lora
                per_block += cfg.q_lora * cfg.n_heads * (cfg.qk_nope + cfg.qk_rope)
                per_block += cfg.d_model * (cfg.kv_lora + cfg.qk_rope)
                per_block += cfg.kv_lora * cfg.n_heads * (cfg.qk_nope + cfg.v_head)
                per_block += cfg.n_heads * cfg.v_head * cfg.d_model
            else:
                per_block += cfg.d_model * cfg.head_dim * (cfg.n_heads + 2 * cfg.n_kv_heads)
                per_block += cfg.n_heads * cfg.head_dim * cfg.d_model
            if spec.is_moe:
                dff = cfg.moe_d_ff or cfg.d_ff
                mats = 3 if cfg.gated else 2
                per_block += cfg.moe_n_experts * mats * cfg.d_model * dff
                per_block += cfg.d_model * cfg.moe_n_experts
                if cfg.moe_shared:
                    per_block += mats * cfg.d_model * dff * cfg.moe_shared
            else:
                mats = 3 if cfg.gated else 2
                per_block += mats * cfg.d_model * cfg.d_ff
        n += nb * per_block
    return int(n)


def active_param_count(cfg: LMConfig) -> int:
    """Active params per token (MoE: only routed top-k + shared)."""
    if not cfg.moe_n_experts:
        return param_count(cfg)
    full = param_count(cfg)
    dff = cfg.moe_d_ff or cfg.d_ff
    mats = 3 if cfg.gated else 2
    n_moe_layers = sum(
        nb * sum(1 for s in block if s.is_moe) for nb, block in layer_stacks(cfg)
    )
    inactive = n_moe_layers * (cfg.moe_n_experts - cfg.moe_top_k) * mats * cfg.d_model * dff
    return int(full - inactive)
