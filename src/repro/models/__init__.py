# Subpackages imported lazily (gnn/recsys may not exist during scaffolding).
