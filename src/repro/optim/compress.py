"""Gradient compression for the cross-pod data-parallel all-reduce.

int8 error-feedback compression (1-bit-Adam-family, Seide et al. 2014 EF
trick): gradients are quantized to int8 with a per-tensor scale before the
*pod-axis* reduction; the quantization residual is carried to the next step
so the compression is unbiased over time.  In-pod reductions stay full
precision (ICI is fast; DCN between pods is the scarce link — 4x fewer bytes
cross-pod).

Used by wrapping the grad pytree inside the train step *before* the psum
over the "pod" axis (see repro.train.trainer).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_map


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_int8_allreduce(grads: Any, error: Any, axis_name: str):
    """Error-feedback int8 all-reduce over ``axis_name`` (inside shard_map/pmap
    context or any code where ``axis_name`` is bound).

    Returns (reduced_grads_f32_mean, new_error).

    int8 values are summed in int32 (no overflow below 2**23 summands), and
    each participant contributes its own scale; scales are all-gathered so the
    sum is exact w.r.t. the quantized values.
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        new_e = g32 - dequantize_int8(q, scale)
        # sum_i q_i * scale_i: scale differs per participant -> psum of dequantized
        # int8 payload; the wire format is int8+f32 scalar (4x compression), the
        # arithmetic below is what the reduction computes.
        total = jax.lax.psum(q.astype(jnp.float32) * scale, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return total / n, new_e

    flat = tree_map(one, grads, error)
    reduced = tree_map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_err = tree_map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    return reduced, new_err
