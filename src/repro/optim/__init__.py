from repro.optim.adam import OptState, adam_init, adam_update, adamw
from repro.optim.schedule import cosine_schedule, linear_warmup_cosine
from repro.optim.compress import ef_int8_allreduce, quantize_int8, dequantize_int8

__all__ = [
    "OptState",
    "adam_init",
    "adam_update",
    "adamw",
    "cosine_schedule",
    "linear_warmup_cosine",
    "ef_int8_allreduce",
    "quantize_int8",
    "dequantize_int8",
]
