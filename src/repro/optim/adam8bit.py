"""Row-wise 8-bit Adam (Dettmers et al., arXiv:2110.02861 regime).

Both moments are stored as int8 with per-ROW (last-axis) fp32 scales —
~5 bytes/param with bf16 weights vs 10 for fp32 moments.  This is what
makes the 671B deepseek-v3 train cell fit v5e HBM at 256/512 chips
(EXPERIMENTS.md §Dry-run reports the per-device bytes).

Quantization granularity is one scale per last-axis row instead of the
paper's 2048-element flat blocks: a flat reshape is NOT GSPMD-sharding-
preserving (it forces a full re-replication of sharded expert weights —
observed as a 240 GiB/device buffer on the llama4 train cell), whereas a
last-axis reduce keeps every leading-dim sharding intact.  Noted in
DESIGN.md as a TPU-adaptation of the algorithm.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_global_norm, tree_map
from repro.optim.adam import clip_by_global_norm


class Q8(NamedTuple):
    q: jax.Array       # int8, original shape
    scale: jax.Array   # fp32, shape[:-1] (per last-axis row)


def _quantize(x: jax.Array) -> Q8:
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return Q8(q, scale.astype(jnp.float32))


def _dequantize(s: Q8) -> jax.Array:
    return s.q.astype(jnp.float32) * s.scale[..., None]


class Opt8State(NamedTuple):
    step: jax.Array
    mu: Any    # pytree of Q8
    nu: Any


def adam8_init(params: Any) -> Opt8State:
    z = lambda p: Q8(
        jnp.zeros(p.shape, jnp.int8), jnp.full(p.shape[:-1], 1e-12, jnp.float32)
    )
    return Opt8State(
        step=jnp.zeros((), jnp.int32),
        mu=tree_map(z, params),
        nu=tree_map(z, params),
    )


def adam8_update(grads, state: Opt8State, params, *, lr=1e-3, b1=0.9, b2=0.999,
                 eps=1e-8, weight_decay=0.0, grad_clip: float | None = 1.0):
    if grad_clip is not None:
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
    else:
        gnorm = tree_global_norm(grads)
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    is_q8 = lambda x: isinstance(x, Q8)

    def upd(p, g, m8, v8):
        g32 = g.astype(jnp.float32)
        m = b1 * _dequantize(m8) + (1 - b1) * g32
        v = b2 * _dequantize(v8) + (1 - b2) * jnp.square(g32)
        delta = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)
        return new_p, _quantize(m), _quantize(v)

    out = tree_map(upd, params, grads, state.mu, state.nu, is_leaf=is_q8)
    pick = lambda i: tree_map(
        lambda t: t[i], out,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3 and not is_q8(x),
    )
    return pick(0), Opt8State(step, pick(1), pick(2)), {"grad_norm": gnorm, "lr": lr_t}
