"""Adam / AdamW on pytrees (no optax offline).

Matches the paper's App. A trainer: Adam, lr 3e-3, grad-clip 0.5.  Optimizer
state mirrors the parameter pytree so it inherits the parameters' logical
sharding (ZeRO: the m/v moments are sharded exactly like the weights).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.common.pytree import tree_global_norm, tree_map


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adam_init(params: Any, moment_dtype=jnp.float32) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=tree_map(zeros, params),
        nu=tree_map(zeros, params),
    )


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def adam_update(
    grads: Any,
    state: OptState,
    params: Any,
    *,
    lr: float | Callable[[jax.Array], jax.Array] = 3e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: float | None = 0.5,
):
    """Returns (new_params, new_state, metrics)."""
    if grad_clip is not None:
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
    else:
        gnorm = tree_global_norm(grads)

    step = state.step + 1
    lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

    flat = tree_map(upd, params, grads, state.mu, state.nu)
    new_params = tree_map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = tree_map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = tree_map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr_t}
    return new_params, OptState(step, new_mu, new_nu), metrics


def adamw(**kwargs):
    """Convenience: partial of adam_update with weight decay defaulting to 0.1."""
    kwargs.setdefault("weight_decay", 0.1)

    def update(grads, state, params):
        return adam_update(grads, state, params, **kwargs)

    return update
