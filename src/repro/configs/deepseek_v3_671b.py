"""deepseek-v3-671b [arXiv:2412.19437]: MLA (q_lora 1536, kv_lora 512+64 rope),
MoE 1 shared + 256 routed top-8 (d_ff 2048), 3 dense prefix layers (d_ff
18432).  MTP head omitted (training objective, not serving topology — DESIGN
§6).  Expert-parallel "ep" layout; 8-bit Adam for the train cell."""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="deepseek-v3-671b",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,
    vocab=129280,
    activation="silu",
    gated=True,
    norm="rms",
    rope_base=10000.0,
    attn="mla",
    q_lora=1536,
    kv_lora=512,
    qk_nope=128,
    qk_rope=64,
    v_head=128,
    moe_n_experts=256,
    moe_top_k=8,
    moe_d_ff=2048,
    moe_shared=1,
    moe_period=1,
    prefix_dense_layers=3,
    moe_layout="ep",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    q_block=2048,
    kv_block=2048,
    loss_chunk=512,
    remat="full",
)

FAMILY = "lm"
USE_ADAM8 = True
SHAPES = {
    "train_4k": dict(kind="train", seq=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq=524288, global_batch=1),
}
SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab=512, q_lora=32, kv_lora=16, qk_nope=16, qk_rope=8, v_head=16,
    moe_n_experts=4, moe_top_k=2, moe_d_ff=32, prefix_dense_layers=1,
    param_dtype="float32", compute_dtype="float32",
    q_block=16, kv_block=16, loss_chunk=16,
)
