"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-*]: MoE 128e top-1 + 1
shared expert on alternating layers; chunked attention (8192) with full
attention every 4th layer (iRoPE); ffslice expert layout (128 experts do not
divide the 256/512-chip mesh — see nn.moe)."""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    activation="silu",
    gated=True,
    norm="rms",
    rope_base=500000.0,
    moe_n_experts=128,
    moe_top_k=1,
    moe_d_ff=8192,
    moe_shared=1,
    moe_period=2,
    moe_layout="ffslice",
    chunk_attn=8192,
    full_attn_every=4,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    q_block=2048,
    kv_block=2048,
    loss_chunk=512,
    remat="full",
)

FAMILY = "lm"
USE_ADAM8 = True
SHAPES = {
    "train_4k": dict(kind="train", seq=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq=524288, global_batch=1),
}
SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab=512, moe_n_experts=4, moe_d_ff=64, chunk_attn=16,
    param_dtype="float32", compute_dtype="float32",
    q_block=16, kv_block=16, loss_chunk=16,
)
