"""xdeepfm [arXiv:1803.05170]: CIN 200-200-200 + deep 400-400 over the same
Criteo-scale 39-field table as deepfm."""
from repro.configs.deepfm import VOCABS
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="xdeepfm",
    model="xdeepfm",
    vocab_sizes=VOCABS,
    embed_dim=10,
    mlp_dims=(400, 400),
    cin_dims=(200, 200, 200),
)

FAMILY = "recsys"
SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", n_candidates=1_000_000),
}
SMOKE = CONFIG.replace(vocab_sizes=(100,) * 8, embed_dim=8, mlp_dims=(32, 32),
                       cin_dims=(16, 16))
