"""Architecture registry: ``--arch <id>`` -> config module + cell builders."""
from __future__ import annotations

import importlib
from typing import Any

ARCHS: dict[str, str] = {
    # LM family
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "granite-20b": "repro.configs.granite_20b",
    "gemma-7b": "repro.configs.gemma_7b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    # GNN
    "meshgraphnet": "repro.configs.meshgraphnet",
    # RecSys
    "deepfm": "repro.configs.deepfm",
    "bst": "repro.configs.bst",
    "two-tower-retrieval": "repro.configs.two_tower",
    "xdeepfm": "repro.configs.xdeepfm",
    # the paper's own
    "lemur": "repro.configs.lemur_paper",
}


def list_archs() -> list[str]:
    return list(ARCHS)


def get_arch(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[arch])


def build_cell(arch: str, shape: str, mesh) -> Any:
    """Instantiate the dry-run Cell for one (arch × shape) pair."""
    from repro.launch import cells

    mod = get_arch(arch)
    if shape not in mod.SHAPES:
        raise KeyError(f"{arch} has no shape {shape!r}; known: {sorted(mod.SHAPES)}")
    spec = dict(mod.SHAPES[shape])
    kind = spec.pop("kind")
    family = mod.FAMILY

    if family == "lm":
        cfg = mod.CONFIG
        if kind == "train":
            return cells.lm_train_cell(
                arch, cfg, seq=spec["seq"], global_batch=spec["global_batch"],
                mesh=mesh, use_adam8=getattr(mod, "USE_ADAM8", False),
            )
        if kind == "prefill":
            return cells.lm_prefill_cell(
                arch, cfg, seq=spec["seq"], global_batch=spec["global_batch"], mesh=mesh
            )
        if kind == "decode":
            return cells.lm_decode_cell(
                arch, cfg, seq=spec["seq"], global_batch=spec["global_batch"], mesh=mesh
            )
    elif family == "gnn":
        cfg = spec.pop("cfg", mod.CONFIG)
        if kind in ("full", "batched"):
            return cells.gnn_full_cell(
                arch, cfg, n_nodes=spec["n_nodes"], n_edges=spec["n_edges"],
                mesh=mesh, n_graphs=spec.get("n_graphs", 0),
            )
        if kind == "sampled":
            return cells.gnn_sampled_cell(
                arch, cfg, n_nodes=spec["n_nodes"], n_edges=spec["n_edges"],
                batch_nodes=spec["batch_nodes"], d_feat=spec["d_feat"], mesh=mesh,
            )
    elif family == "recsys":
        cfg = mod.CONFIG
        if kind in ("train", "serve"):
            return cells.recsys_cell(arch, cfg, batch=spec["batch"], mesh=mesh, kind=kind)
        if kind == "retrieval":
            return cells.recsys_retrieval_cell(
                arch, cfg, n_candidates=spec["n_candidates"], mesh=mesh
            )
    elif family == "lemur":
        cfg = mod.CONFIG
        if kind == "lemur_serve":
            return cells.lemur_serve_cell(
                arch, cfg, m=spec["m"], doc_tokens=spec["doc_tokens"],
                q_tokens=spec["q_tokens"], batch=spec["batch"], mesh=mesh,
            )
        if kind == "lemur_index":
            return cells.lemur_index_cell(
                arch, cfg, m=spec["m"], doc_tokens=spec["doc_tokens"], mesh=mesh
            )
    raise ValueError(f"no builder for family={family} kind={kind}")


def all_cells() -> list[tuple[str, str]]:
    """The full (arch × shape) matrix (assigned 40 cells + the paper's own)."""
    out = []
    for arch in ARCHS:
        mod = get_arch(arch)
        for shape in mod.SHAPES:
            out.append((arch, shape))
    return out
