"""The paper's own configuration (App. A): d'=2048, m'=8192, n=100k,
n'=16384, Adam(3e-3) 100 epochs, IVF+SQ8 ANNS, k=100, k'=1024.

Extra (beyond the 40 assigned cells): LEMUR serving / indexing dry-run cells
over the production mesh — the corpus dimensioned like MS MARCO (Table 1:
8.84M docs, ~67.5 tokens/doc, d=128 ColBERTv2)."""
from repro.anns.params import IVFBackendConfig
from repro.core.config import LemurConfig

CONFIG = LemurConfig(
    d=128,
    d_prime=2048,
    m_pretrain=8192,
    n_train=100_000,
    n_ols=16_384,
    lr=3e-3,
    epochs=100,
    batch_size=512,
    grad_clip=0.5,
    k=100,
    k_prime=1024,
    anns="ivf",
    ivf=IVFBackendConfig(nprobe=32, sq8=True),
)

FAMILY = "lemur"
# MS MARCO-scale serving corpus (Table 1)
SHAPES = {
    "serve_msmarco": dict(kind="lemur_serve", m=8_841_823, doc_tokens=80,
                          q_tokens=32, batch=256),
    "index_msmarco": dict(kind="lemur_index", m=8_841_823, doc_tokens=80),
}
SMOKE = CONFIG.replace(d=32, d_prime=128, m_pretrain=256, n_train=2048,
                       n_ols=512, epochs=3, k=10, k_prime=64)
