"""gemma-7b [arXiv:2403.08295]: GeGLU, head_dim=256, MHA (kv=16), tied+scaled
embeddings."""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="gemma-7b",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab=256000,
    activation="gelu",
    gated=True,
    norm="rms",
    rope_base=10000.0,
    tie_embeddings=True,
    embed_scale=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    q_block=2048,
    kv_block=2048,
    loss_chunk=256,
    remat="full",
)

FAMILY = "lm"
SHAPES = {
    "train_4k": dict(kind="train", seq=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq=524288, global_batch=1),
}
SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32, d_ff=128,
    vocab=512, param_dtype="float32", compute_dtype="float32",
    q_block=16, kv_block=16, loss_chunk=16,
)
