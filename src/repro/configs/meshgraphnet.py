"""meshgraphnet [arXiv:2010.03409]: 15 layers, 128 hidden, sum aggregator,
2-layer MLPs.  Per-cell input dims follow the assigned datasets (Cora-like /
Reddit-like / ogbn-products-like / batched molecules)."""
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="meshgraphnet",
    n_layers=15,
    d_hidden=128,
    mlp_layers=2,
    aggregator="sum",
)

FAMILY = "gnn"
SHAPES = {
    "full_graph_sm": dict(
        kind="full", n_nodes=2708, n_edges=10556,
        cfg=CONFIG.replace(d_node_in=1433, d_edge_in=4, d_out=7, task="classification"),
    ),
    "minibatch_lg": dict(
        kind="sampled", n_nodes=232965, n_edges=114615892, batch_nodes=1024,
        d_feat=602,
        cfg=CONFIG.replace(d_node_in=602, d_edge_in=4, d_out=41, task="classification",
                           fanout=(15, 10)),
    ),
    "ogb_products": dict(
        kind="full", n_nodes=2449029, n_edges=61859140,
        cfg=CONFIG.replace(d_node_in=100, d_edge_in=4, d_out=47, task="classification"),
    ),
    "molecule": dict(
        kind="batched", n_nodes=30 * 128, n_edges=64 * 128, n_graphs=128,
        cfg=CONFIG.replace(d_node_in=16, d_edge_in=4, d_out=1, task="regression",
                           graph_readout=True),
    ),
}
SMOKE = CONFIG.replace(n_layers=3, d_hidden=32, d_node_in=8, d_edge_in=4, d_out=2)
