"""granite-20b [arXiv:2405.04324]: MQA (kv=1), non-gated GELU FFN, LayerNorm
(GPT-BigCode lineage code model)."""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="granite-20b",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    activation="gelu",
    gated=False,
    mlp_bias=True,
    qkv_bias=True,
    norm="ln",
    rope_base=10000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    q_block=2048,
    kv_block=2048,
    loss_chunk=512,
    remat="full",
)

FAMILY = "lm"
SHAPES = {
    "train_4k": dict(kind="train", seq=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq=524288, global_batch=1),
}
SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128,
    vocab=512, param_dtype="float32", compute_dtype="float32",
    q_block=16, kv_block=16, loss_chunk=16,
)
