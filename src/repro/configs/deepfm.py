"""deepfm [arXiv:1703.04247]: 39 sparse fields, embed 10, FM + deep 400³.

Criteo-scale per-field vocabularies (5 huge head fields + long tail),
~16.3M total rows — the table is the hot sharded object."""
from repro.models.recsys import RecsysConfig

VOCABS = (
    (10_000_000, 4_000_000, 1_000_000, 500_000, 250_000)
    + (100_000,) * 4
    + (10_000,) * 10
    + (1_000,) * 10
    + (100,) * 9
    + (1_244,)  # pad field: total 16 262 144 = 31 762 × 512 (shardable anywhere)
)
assert len(VOCABS) == 39
assert sum(VOCABS) % 512 == 0

CONFIG = RecsysConfig(
    name="deepfm",
    model="deepfm",
    vocab_sizes=VOCABS,
    embed_dim=10,
    mlp_dims=(400, 400, 400),
)

FAMILY = "recsys"
SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", n_candidates=1_000_000),
}
SMOKE = CONFIG.replace(vocab_sizes=(100,) * 8, embed_dim=8, mlp_dims=(32, 32))
