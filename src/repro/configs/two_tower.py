"""two-tower-retrieval [Yi et al., RecSys'19]: 256-d towers (1024-512-256),
dot-product interaction, in-batch sampled softmax with logQ correction.
``retrieval_cand`` scores 1 query against 1M candidates via the mesh-sharded
MIPS path (the same machinery LEMUR's latent stage uses)."""
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="two-tower-retrieval",
    model="two_tower",
    vocab_sizes=(1_000_000, 500_000, 100_000, 100_000, 10_000, 10_000, 1_000, 1_000),
    embed_dim=256,
    tower_dims=(1024, 512, 256),
    out_dim=256,
    n_items=10_000_000,
)

FAMILY = "recsys"
SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", n_candidates=1_000_000),
}
SMOKE = CONFIG.replace(vocab_sizes=(100,) * 4, embed_dim=16, tower_dims=(32, 16),
                       out_dim=16, n_items=1000)
