"""qwen2.5-32b [hf:Qwen/Qwen2.5-32B]: dense, GQA kv=8, QKV bias, untied."""
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="qwen2.5-32b",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab=152064,
    activation="silu",
    gated=True,
    qkv_bias=True,
    norm="rms",
    rope_base=1_000_000.0,
    tie_embeddings=False,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    q_block=2048,
    kv_block=2048,
    loss_chunk=512,
    remat="full",
)

FAMILY = "lm"
SHAPES = {
    "train_4k": dict(kind="train", seq=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq=524288, global_batch=1),
}
SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab=512, param_dtype="float32", compute_dtype="float32",
    q_block=16, kv_block=16, loss_chunk=16,
)
