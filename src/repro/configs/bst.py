"""bst [arXiv:1905.06874] Behavior Sequence Transformer (Alibaba): embed 32,
seq 20 history + target, 1 transformer block (8 heads), MLP 1024-512-256."""
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="bst",
    model="bst",
    vocab_sizes=(),
    embed_dim=32,
    seq_len=20,
    n_heads=8,
    n_blocks=1,
    n_items=2_000_000,
    mlp_dims=(1024, 512, 256),
)

FAMILY = "recsys"
SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", n_candidates=1_000_000),
}
SMOKE = CONFIG.replace(n_items=1000, embed_dim=16, seq_len=8, n_heads=4,
                       mlp_dims=(64, 32))
