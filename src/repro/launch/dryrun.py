import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry run: lower + compile every (arch × shape) cell on the
production mesh and record memory / cost / collective statistics.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

Results are appended to results/dryrun_<mesh>.json, which §Roofline reads.
The VERY FIRST lines above force 512 host platform devices BEFORE any jax
import — jax locks the device count at first init.
"""
import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax
import numpy as np


COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*?=?\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\])", re.IGNORECASE
)

SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64|c64)\[([0-9,]*)\]")

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "c64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the (post-SPMD) HLO.

    Operand sizes ≈ output sizes for all-reduce/permute; all-gather outputs
    (the larger side) upper-bound the wire bytes; reduce-scatter outputs
    lower-bound them — adequate for a roofline term.  Only the op's result
    shapes (LHS of `=` ... before the op mnemonic) are counted; async
    -start/-done pairs are counted once (at -start)."""
    out: dict[str, int] = {}
    count: dict[str, int] = {}
    op_re = re.compile(
        r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(-start|-done)?\("
    )
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        m = op_re.search(s)
        if not m or m.group(2) == "-done":
            continue
        op = m.group(1)
        rhs = s.split(" = ", 1)[1]
        result_part = rhs[: m.start() - len(s.split(" = ", 1)[0]) - 3]
        shapes = SHAPE_RE.findall(result_part)
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[op] = out.get(op, 0) + nbytes
        count[op] = count.get(op, 0) + 1
    return {"bytes": out, "count": count, "total_bytes": sum(out.values())}


def run_cell(arch: str, shape: str, mesh, *, hlo_dir: pathlib.Path | None = None):
    from repro.configs.registry import build_cell

    t0 = time.time()
    cell = build_cell(arch, shape, mesh)
    jitted = jax.jit(
        cell.fn,
        in_shardings=cell.in_shardings,
        out_shardings=cell.out_shardings,
        donate_argnums=cell.donate_argnums,
    )
    from repro.common.compat import set_mesh

    with set_mesh(mesh):
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    # loop-corrected static analysis (XLA cost_analysis counts while bodies
    # once; see launch/hlo_analysis.py)
    from repro.launch.hlo_analysis import analyze as hlo_analyze

    corrected = hlo_analyze(hlo)
    if hlo_dir is not None:
        hlo_dir.mkdir(parents=True, exist_ok=True)
        (hlo_dir / f"{arch}__{shape}.hlo.txt").write_text(hlo)

    rec = {
        "arch": arch,
        "shape": shape,
        "kind": cell.kind,
        "mesh": dict(mesh.shape),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "flops_loop_corrected": corrected["flops"],
        "bytes_loop_corrected": corrected["bytes"],
        "collectives_loop_corrected": {
            "bytes": corrected["collective_bytes"],
            "count": corrected["collective_count"],
            "total_bytes": corrected["total_collective_bytes"],
        },
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        },
        "collectives": coll,
    }
    return rec


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--mesh", choices=["single", "multi"], default="single")
    p.add_argument("--out", default="results")
    p.add_argument("--save-hlo", action="store_true")
    p.add_argument("--continue-on-error", action="store_true")
    args = p.parse_args(argv)

    from repro.configs.registry import all_cells
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    outfile = outdir / f"dryrun_{args.mesh}.json"
    existing = {}
    if outfile.exists():
        for r in json.loads(outfile.read_text()):
            existing[(r["arch"], r["shape"])] = r

    if args.all:
        todo = all_cells()
    else:
        if not args.arch or not args.shape:
            p.error("--arch and --shape required unless --all")
        todo = [(args.arch, args.shape)]

    hlo_dir = outdir / "hlo" if args.save_hlo else None
    failures = []
    for arch, shape in todo:
        key = f"{arch} × {shape} [{args.mesh}]"
        try:
            rec = run_cell(arch, shape, mesh, hlo_dir=hlo_dir)
            existing[(arch, shape)] = rec
            mem_gb = rec["memory"]["argument_bytes"] / 2**30
            tmp_gb = rec["memory"]["temp_bytes"] / 2**30
            print(
                f"[ok] {key}: compile {rec['compile_s']:.1f}s  "
                f"flops/dev {rec['flops_loop_corrected']:.3e}  args {mem_gb:.2f}GiB  "
                f"temp {tmp_gb:.2f}GiB  "
                f"coll {rec['collectives_loop_corrected']['total_bytes']/2**30:.3f}GiB"
            )
        except Exception as e:  # noqa: BLE001
            failures.append((key, repr(e)))
            print(f"[FAIL] {key}: {e}", file=sys.stderr)
            traceback.print_exc()
            if not args.continue_on_error:
                raise
        finally:
            # re-merge against the file (other cells may have landed since we
            # loaded it) and write atomically
            merged = {}
            if outfile.exists():
                try:
                    for r in json.loads(outfile.read_text()):
                        merged[(r["arch"], r["shape"])] = r
                except Exception:
                    pass
            merged.update(existing)
            tmp = outfile.with_suffix(".tmp")
            tmp.write_text(json.dumps(list(merged.values()), indent=1))
            tmp.rename(outfile)

    print(f"\n{len(existing)} cells recorded -> {outfile}")
    if failures:
        print(f"{len(failures)} FAILURES:")
        for k, e in failures:
            print(" ", k, e)
        sys.exit(1)


if __name__ == "__main__":
    main()
