"""Training launcher: ``python -m repro.launch.train --arch <id> [--steps N]``.

On this CPU container, LM/GNN/RecSys archs run their SMOKE config with
synthetic data through the fault-tolerant TrainLoop (checkpoint/restart,
retry, straggler accounting).  ``--arch lemur`` runs the paper's pipeline:
ψ pre-training + OLS indexing + a recall report.  On a real pod the same
entry point takes ``--mesh single|multi`` and the full config
(``--full``) — exactly the graphs the dry-run compiles.
"""
from __future__ import annotations

import argparse

import numpy as np


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--checkpoint-dir", default="/tmp/repro_train")
    p.add_argument("--checkpoint-every", type=int, default=50)
    p.add_argument("--full", action="store_true",
                   help="use the FULL config (pod hardware) instead of SMOKE")
    p.add_argument("--backend", default=None,
                   help="lemur only: first-stage anns backend "
                        "(repro.anns.registry name)")
    args = p.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_arch
    from repro.data import synthetic
    from repro.optim import adam_init
    from repro.train import TrainerConfig, TrainLoop

    mod = get_arch(args.arch)
    tc = TrainerConfig(total_steps=args.steps, checkpoint_every=args.checkpoint_every,
                       checkpoint_dir=args.checkpoint_dir, log_every=10)

    if mod.FAMILY == "lemur":
        from repro.core import maxsim, recall_at
        from repro.retriever import LemurRetriever, SearchParams

        cfg = mod.CONFIG if args.full else mod.SMOKE
        if args.backend:
            cfg = cfg.replace(anns=args.backend)
        corpus = synthetic.make_corpus(m=4000, d=cfg.d, avg_tokens=12, max_tokens=16,
                                       seed=0)
        r = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0),
                                 verbose=True)
        q = jnp.asarray(synthetic.queries_from_corpus_query(corpus, 64, 8, seed=7))
        qm = jnp.ones(q.shape[:2], bool)
        _, truth = maxsim.true_topk(q, qm, r.index.doc_tokens, r.index.doc_mask,
                                    cfg.k)
        _, ids = r.search(q, qm, SearchParams())
        print(f"[lemur] backend={r.backend} "
              f"recall@{cfg.k} = {float(recall_at(ids, truth).mean()):.3f}")
        return

    cfg = mod.CONFIG if args.full else mod.SMOKE
    if mod.FAMILY == "lm":
        from repro.models import lm

        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        step = jax.jit(lm.make_train_step(cfg))
        opt = adam_init(params)
        batches = (
            {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
            for t, l in synthetic.lm_token_batches(cfg.vocab, args.batch, args.seq,
                                                   args.steps)
        )
    elif mod.FAMILY == "gnn":
        from repro.models import gnn

        g = synthetic.make_mesh_graph(500, d_feat=cfg.d_node_in, d_edge=cfg.d_edge_in,
                                      d_out=cfg.d_out)
        params = gnn.init_gnn(jax.random.PRNGKey(0), cfg)
        step = jax.jit(gnn.make_train_step(cfg))
        opt = adam_init(params)
        b = {"node_feat": jnp.asarray(g.node_feat), "edge_feat": jnp.asarray(g.edge_feat),
             "senders": jnp.asarray(g.senders), "receivers": jnp.asarray(g.receivers),
             "labels": jnp.asarray(g.labels)}
        batches = (b for _ in range(args.steps))
    else:  # recsys
        from repro.models import recsys

        params = recsys.init_recsys(jax.random.PRNGKey(0), cfg)
        step = jax.jit(recsys.make_train_step(cfg))
        opt = adam_init(params)

        def gen():
            for i in range(args.steps):
                d = synthetic.make_clicks(64, max(cfg.n_fields, 1),
                                          np.array(cfg.vocab_sizes or [10]),
                                          seed=i, hist_len=cfg.seq_len,
                                          n_items=cfg.n_items)
                if cfg.model == "bst":
                    yield {"history": jnp.asarray(d["history"]),
                           "target_item": jnp.asarray(d["target_item"]),
                           "labels": jnp.asarray(d["labels"])}
                elif cfg.model == "two_tower":
                    yield {"ids": jnp.asarray(d["ids"][:, :cfg.n_fields]),
                           "item": jnp.asarray(d["target_item"]),
                           "labels": jnp.asarray(d["labels"])}
                else:
                    yield {"ids": jnp.asarray(d["ids"][:, :cfg.n_fields]),
                           "labels": jnp.asarray(d["labels"])}

        batches = gen()

    loop = TrainLoop(tc, step, params, opt)
    loop.try_restore()
    out = loop.run(batches)
    print(f"[train] done: step {out['final_step']}, "
          f"loss {out['history'][-1]['loss'] if out['history'] else float('nan'):.4f}, "
          f"retries={out['retries']} nan_skips={out['nan_skips']} "
          f"stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
