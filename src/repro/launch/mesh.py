"""Production mesh construction.

Axis semantics (DESIGN.md §4):
  pod   — cross-pod data parallelism (DCN; gradient all-reduce / top-k merge)
  data  — in-pod batch + ZeRO/fsdp sharding (ICI)
  model — tensor/expert/sequence/corpus parallelism (ICI)

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax

from repro.common.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Degenerate 1-chip mesh with the full axis set (CPU tests / examples)."""
    n = len(jax.devices())
    if n >= 4:
        # spread over whatever local devices exist (e.g. XLA host-device tests)
        model = 2
        data = n // 2
        return make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    return make_mesh((1, 1), ("data", "model"), axis_types=(AxisType.Auto,) * 2)


def make_serving_mesh(spec: str):
    """Corpus-serving mesh from a ``--mesh`` CLI spec like ``"1x8"``.

    The rightmost axes of (pod, data, model) are used: ``"8"`` -> 8-way
    ``model``, ``"1x8"`` -> (data=1, model=8), ``"2x2x2"`` -> all three.
    LEMUR's corpus sharding spans every axis (``dist.serve.corpus_axes``),
    so the split across names only matters when serving shares the mesh
    with batch-parallel work."""
    shape = parse_mesh_spec(spec)
    axes = ("pod", "data", "model")[3 - len(shape):]
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


def parse_mesh_spec(spec: str) -> tuple[int, ...]:
    """``"1x8"`` -> (1, 8).  1-3 ``x``-separated positive ints."""
    try:
        shape = tuple(int(p) for p in str(spec).lower().split("x"))
    except ValueError:
        raise ValueError(f"bad --mesh spec {spec!r}; want e.g. '8' or '1x8'")
    if not 1 <= len(shape) <= 3 or any(s < 1 for s in shape):
        raise ValueError(f"bad --mesh spec {spec!r}; want 1-3 positive ints")
    return shape


def ensure_devices(n: int) -> None:
    """Make sure ``n`` devices exist for a ``--mesh`` request, forcing XLA
    host devices when the process has not touched a jax backend yet (the
    flag is read at backend initialization, so this works as long as it
    runs before the first device query).  Raises with the manual fix when
    the backend is already pinned to fewer devices."""
    import os

    if n > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={n}".strip())
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"--mesh needs {n} devices but only {len(jax.devices())} are "
            f"visible; launch with XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n} (or run on a {n}-device accelerator)")


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def n_devices(mesh) -> int:
    import numpy as np

    return int(np.prod(list(mesh.shape.values())))
