"""Production mesh construction.

Axis semantics (DESIGN.md §4):
  pod   — cross-pod data parallelism (DCN; gradient all-reduce / top-k merge)
  data  — in-pod batch + ZeRO/fsdp sharding (ICI)
  model — tensor/expert/sequence/corpus parallelism (ICI)

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Degenerate 1-chip mesh with the full axis set (CPU tests / examples)."""
    n = len(jax.devices())
    if n >= 4:
        # spread over whatever local devices exist (e.g. XLA host-device tests)
        model = 2
        data = n // 2
        return jax.make_mesh((data, model), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
    return jax.make_mesh((1, 1), ("data", "model"), axis_types=(AxisType.Auto,) * 2)


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def n_devices(mesh) -> int:
    import numpy as np

    return int(np.prod(list(mesh.shape.values())))
