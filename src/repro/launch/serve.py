"""Serving launcher: build a LEMUR index over a synthetic corpus and serve
batched retrieval requests, reporting QPS + recall.

  PYTHONPATH=src python -m repro.launch.serve --m 8000 --batch 64
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--m", type=int, default=8000)
    p.add_argument("--d", type=int, default=48)
    p.add_argument("--d-prime", type=int, default=128)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--n-batches", type=int, default=5)
    p.add_argument("--k", type=int, default=10)
    args = p.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.core import LemurConfig, build_index, maxsim, recall_at
    from repro.core.index import query
    from repro.data import synthetic

    corpus = synthetic.make_corpus(m=args.m, d=args.d, avg_tokens=16, max_tokens=24,
                                   seed=0)
    cfg = LemurConfig(d=args.d, d_prime=args.d_prime, m_pretrain=1024, n_train=16384,
                      n_ols=4096, epochs=25, k=args.k, k_prime=256,
                      anns="ivf", ivf_nprobe=32, sq8=True)
    t0 = time.time()
    idx = build_index(jax.random.PRNGKey(0), corpus, cfg, verbose=True)
    print(f"[serve] index built in {time.time()-t0:.1f}s "
          f"({args.m/(time.time()-t0):.0f} docs/s)")

    serve = jax.jit(lambda q, qm: query(idx, q, qm))
    total_q, total_t, recs = 0, 0.0, []
    for b in range(args.n_batches):
        q = jnp.asarray(synthetic.queries_from_corpus_query(corpus, args.batch, 8,
                                                            seed=100 + b))
        qm = jnp.ones(q.shape[:2], bool)
        t0 = time.time()
        s, ids = serve(q, qm)
        jax.block_until_ready(ids)
        dt = time.time() - t0
        if b > 0:  # skip compile batch
            total_q += args.batch
            total_t += dt
        _, truth = maxsim.true_topk(q, qm, idx.doc_tokens, idx.doc_mask, args.k)
        recs.append(float(recall_at(ids, truth).mean()))
    print(f"[serve] QPS={total_q/max(total_t,1e-9):.0f}  "
          f"recall@{args.k}={sum(recs)/len(recs):.3f}")


if __name__ == "__main__":
    main()
