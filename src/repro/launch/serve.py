"""Serving launcher: build a LEMUR retriever over a synthetic corpus and
serve batched retrieval requests, reporting QPS + recall for any registered
first-stage backend.

  PYTHONPATH=src python -m repro.launch.serve --m 8000 --batch 64
  PYTHONPATH=src python -m repro.launch.serve --backend muvera --m 4000
  PYTHONPATH=src python -m repro.launch.serve --backend all --m 4000

``--backend`` takes any name from ``repro.anns.registry`` (or ``all`` to
sweep every backend over the SAME trained reduction via
``LemurRetriever.with_backend``).  The facade compiles exactly one query fn
per (backend, SearchParams, batch shape) — the launcher reports its trace
count.  The first (compile) batch is excluded from BOTH the QPS and the
recall aggregates, so the reported operating point is steady-state.

``--mesh 1x8`` additionally serves through ``LemurRetriever.shard(mesh)``
(the corpus block-sharded over the flattened mesh, per-shard latent scan +
rerank, hierarchical top-k merge) and reports sharded QPS next to the
single-device numbers.  On a CPU host the requested XLA host-device count
is forced automatically:

  PYTHONPATH=src python -m repro.launch.serve --m 8000 --mesh 1x8

``--online`` switches from offline fixed-shape batches to the online
runtime (``repro.serving``): ragged single queries replayed from a seeded
Poisson trace through ``RetrieverServer`` (shape-bucketed micro-batching,
``--online-rate`` offered QPS for ``--online-duration`` seconds), reporting
p50/p95/p99 latency, achieved QPS, micro-batch occupancy, and the
compiled-fn count against the bucket-ladder bound:

  PYTHONPATH=src python -m repro.launch.serve --m 8000 --online \\
      --online-rate 200 --online-duration 10

``--fleet N`` serves the same Poisson replay through ``repro.fleet.Router``
fronting N replicas (least-outstanding dispatch, per-request deadlines via
``--fleet-deadline-ms``, admission control via ``--fleet-queue-depth``, and
— with ``--fleet-slo-ms`` — the SLO controller walking the rung ladder
under load).  Reports the fleet percentiles, achieved-vs-offered QPS,
reject rate, and any rung transitions:

  PYTHONPATH=src python -m repro.launch.serve --m 8000 --fleet 2 \\
      --online-rate 400 --fleet-slo-ms 50
"""
from __future__ import annotations

import argparse
import time


def _serve_loop(search, batches, args):
    """(qps, recall) over ``batches``, excluding the first (compile) batch
    from both aggregates so the operating point is steady-state."""
    import jax

    from repro.core import recall_at

    total_q, total_t, recs = 0, 0.0, []
    for b, (q, qm, truth) in enumerate(batches):
        t0 = time.time()
        s, ids = search(q, qm)
        jax.block_until_ready(ids)
        dt = time.time() - t0
        if b > 0:  # skip the compile batch in QPS *and* recall
            total_q += args.batch
            total_t += dt
            recs.append(float(recall_at(ids, truth).mean()))
        elif len(batches) == 1:  # recall is timing-free: better one sample
            recs.append(float(recall_at(ids, truth).mean()))  # than a fake 0
    return total_q / max(total_t, 1e-9), sum(recs) / max(len(recs), 1)


def serve_backend(retriever, backend, batches, args, *, key=None):
    """Serve ``batches`` through ``retriever`` re-pointed at ``backend``;
    returns a metrics dict.  ``batches`` is a list of (q, qm, truth) —
    ground truth is precomputed once in main() since the query stream is
    identical across backends."""
    from repro.anns import registry
    from repro.retriever import SearchParams

    # serve the retriever's own state when it already runs this backend
    # (so --save-dir round-trips actually serve the LOADED first-stage
    # state); rebuild only when sweeping onto a different backend
    if retriever.backend == registry.canonical(backend):
        r = retriever
    else:
        r = retriever.with_backend(backend, key=key)
    params = SearchParams(k=args.k)
    qps, rec = _serve_loop(lambda q, qm: r.search(q, qm, params), batches, args)
    traces = r.trace_count()
    print(f"[serve] backend={backend:13s} QPS={qps:.0f}  "
          f"recall@{args.k}={rec:.3f}  jit_traces={traces}")
    return {"backend": backend, "qps": qps, f"recall@{args.k}": rec,
            "jit_traces": traces}


def serve_sharded(retriever, mesh_spec, batches, args):
    """Serve ``batches`` through ``retriever.shard(mesh)`` and report the
    sharded operating point next to the single-device rows."""
    from repro.launch.mesh import make_serving_mesh
    from repro.retriever import SearchParams

    mesh = make_serving_mesh(mesh_spec)
    sr = retriever.shard(mesh)
    rows = []
    # flip the one-launch scan both ways: the smoke covers the fused
    # per-shard first stage AND the legacy 3-launch path (distinct compile
    # keys; ids must agree — the parity suite asserts bit-identity)
    for one_launch in (False, True):
        params = SearchParams(k=args.k, use_one_launch=one_launch)
        qps, rec = _serve_loop(lambda q, qm: sr.search(q, qm, params),
                               batches, args)
        traces = sr.trace_count()
        print(f"[serve] mesh={mesh_spec:>7s} sharded QPS={qps:.0f}  "
              f"recall@{args.k}={rec:.3f}  jit_traces={traces}  "
              f"sq8={sr.sq8}  one_launch={one_launch}")
        rows.append({"mesh": mesh_spec, "qps": qps, f"recall@{args.k}": rec,
                     "jit_traces": traces, "one_launch": one_launch})
    return rows[-1]


def serve_online(retriever, args):
    """Online operating point: Poisson replay of ragged single queries
    through the micro-batching server; prints the latency/occupancy row."""
    from repro.serving import (
        BucketLadder,
        RetrieverServer,
        poisson_trace,
        ragged_queries,
        replay,
        warm_buckets,
    )

    ladder = BucketLadder(tuple(int(t) for t in args.online_ladder.split(",")),
                          max_batch=args.online_max_batch)
    queries = ragged_queries(256, retriever.cfg.d,
                             tq_range=(2, ladder.tq_ladder[-1]), seed=17)
    arrivals = poisson_trace(args.online_rate, args.online_duration, seed=18)
    offline_traces = retriever.trace_count()   # the offline phase's shapes
    with RetrieverServer(retriever, ladder=ladder,
                         max_wait_us=args.online_max_wait_us) as srv:
        warm_buckets(retriever, ladder, retriever.cfg.d)
        _, report = replay(srv, queries, arrivals)
    bound = ladder.compile_bound(1)
    online_traces = report["trace_count"] - offline_traces
    print(f"[serve] online rate={args.online_rate:g}qps "
          f"p50={report['p50_ms']:.2f}ms p95={report['p95_ms']:.2f}ms "
          f"p99={report['p99_ms']:.2f}ms achieved={report['qps']:.0f}qps "
          f"occupancy={report['mean_occupancy']:.2f} "
          f"jit_traces={online_traces}/{bound}")
    assert online_traces <= bound, "bucket-ladder compile bound blown"
    return report


def serve_fleet(retriever, args):
    """Fleet operating point: the --online Poisson replay through a
    replicated Router — deadlines, admission control, and (optionally) the
    SLO-adaptive rung ladder.  Prints the fleet row + any rung transitions."""
    from repro.fleet import Router, SLOController, build_rungs, \
        clone_replicas, warm_replicas
    from repro.serving import BucketLadder, poisson_trace, ragged_queries, \
        replay

    ladder = BucketLadder(tuple(int(t) for t in args.online_ladder.split(",")),
                          max_batch=args.online_max_batch)
    queries = ragged_queries(256, retriever.cfg.d,
                             tq_range=(2, ladder.tq_ladder[-1]), seed=17)
    arrivals = poisson_trace(args.online_rate, args.online_duration, seed=18)

    reps = clone_replicas(retriever, args.fleet)
    slo = None
    params_list = (None,)
    if args.fleet_slo_ms is not None:
        rungs = build_rungs(retriever)
        slo = SLOController(rungs, target_p99_ms=args.fleet_slo_ms)
        params_list = rungs
    warmed = warm_replicas(reps, ladder, retriever.cfg.d,
                           params_list=params_list)
    deadline_s = (args.fleet_deadline_ms / 1e3
                  if args.fleet_deadline_ms is not None else None)
    with Router(reps, ladder=ladder, max_wait_us=args.online_max_wait_us,
                max_queue_depth=args.fleet_queue_depth,
                default_deadline_s=deadline_s, slo=slo) as router:
        _, report = replay(router, queries, arrivals)
        bound = router.compile_bound(len(params_list))
        traces = router.trace_count()
        print(f"[serve] fleet replicas={args.fleet} "
              f"rate={args.online_rate:g}qps "
              f"p50={report['p50_ms']:.2f}ms p99={report['p99_ms']:.2f}ms "
              f"achieved={report['qps']:.0f}qps "
              f"rejected={report['n_rejected']} expired={report['n_expired']} "
              f"lost={report['n_lost']} healthy={router.n_healthy} "
              f"jit_traces={traces}/{bound} (warmed {warmed})")
        if slo is not None:
            for tr in slo.transitions:
                print(f"[serve]   slo {tr.direction}: rung {tr.from_rung} -> "
                      f"{tr.to_rung} (p99 {tr.p99_ms:.1f}ms, "
                      f"target {tr.target_ms:.1f}ms)")
            print(f"[serve]   slo final rung={slo.rung}/{len(slo.rungs) - 1}")
        assert traces <= bound, "bucket-ladder compile bound blown"
        assert report["n_lost"] == 0, "fleet lost requests without an outcome"
    return report


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--m", type=int, default=8000)
    p.add_argument("--d", type=int, default=48)
    p.add_argument("--d-prime", type=int, default=128)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--n-batches", type=int, default=5)
    p.add_argument("--k", type=int, default=10)
    p.add_argument("--backend", default="ivf",
                   help="registered anns backend name, or 'all'")
    p.add_argument("--save-dir", default=None,
                   help="optional: persist the built retriever here "
                        "(LemurRetriever.save) and reload before serving")
    p.add_argument("--mesh", default=None,
                   help="also serve sharded over this mesh, e.g. '1x8' "
                        "(host devices are forced on CPU)")
    p.add_argument("--online", action="store_true",
                   help="also serve a Poisson replay of ragged single "
                        "queries through the online micro-batching runtime")
    p.add_argument("--online-rate", type=float, default=100.0,
                   help="offered load for --online, queries/second")
    p.add_argument("--online-duration", type=float, default=8.0,
                   help="Poisson replay length for --online, seconds")
    p.add_argument("--online-ladder", default="8,16,32",
                   help="comma Tq bucket ladder for --online")
    p.add_argument("--online-max-batch", type=int, default=8)
    p.add_argument("--online-max-wait-us", type=int, default=2000)
    p.add_argument("--fleet", type=int, default=0, metavar="N",
                   help="also serve the Poisson replay through a Router "
                        "fronting N replicas (reuses the --online-* knobs)")
    p.add_argument("--fleet-queue-depth", type=int, default=128,
                   help="fleet admission bound: outstanding requests beyond "
                        "this are rejected with a typed Overloaded")
    p.add_argument("--fleet-deadline-ms", type=float, default=None,
                   help="per-request deadline for --fleet; expired requests "
                        "resolve with a typed DeadlineExceeded")
    p.add_argument("--fleet-slo-ms", type=float, default=None,
                   help="attach the SLO controller with this p99 target; "
                        "sustained breach walks SearchParams down the "
                        "pre-compiled rung ladder")
    args = p.parse_args(argv)

    if args.mesh:
        # before any jax backend touch: force the host device count
        import numpy as np

        from repro.launch.mesh import ensure_devices, parse_mesh_spec

        ensure_devices(int(np.prod(parse_mesh_spec(args.mesh))))

    import jax
    import jax.numpy as jnp

    from repro.anns import registry
    from repro.core import LemurConfig, maxsim
    from repro.data import synthetic
    from repro.retriever import IVFBackendConfig, LemurRetriever

    names = registry.list_backends() if args.backend == "all" else [args.backend]
    for n in names:
        registry.get_backend(n)  # fail fast on typos, before the build

    corpus = synthetic.make_corpus(m=args.m, d=args.d, avg_tokens=16, max_tokens=24,
                                   seed=0)
    cfg = LemurConfig(d=args.d, d_prime=args.d_prime, m_pretrain=1024, n_train=16384,
                      n_ols=4096, epochs=25, k=args.k, k_prime=256,
                      anns=names[0], ivf=IVFBackendConfig(nprobe=32, sq8=True))
    t0 = time.time()
    retriever = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0),
                                     verbose=True)
    print(f"[serve] index built in {time.time()-t0:.1f}s "
          f"({args.m/(time.time()-t0):.0f} docs/s)")
    if args.save_dir:
        path = retriever.save(args.save_dir)
        retriever = LemurRetriever.load(args.save_dir)
        print(f"[serve] persisted + reloaded retriever from {path}")

    idx = retriever.index
    batches = []
    for b in range(args.n_batches):
        q = jnp.asarray(synthetic.queries_from_corpus_query(corpus, args.batch, 8,
                                                            seed=100 + b))
        qm = jnp.ones(q.shape[:2], bool)
        _, truth = maxsim.true_topk(q, qm, idx.doc_tokens, idx.doc_mask, args.k)
        batches.append((q, qm, truth))

    for name in names:
        serve_backend(retriever, name, batches, args, key=jax.random.PRNGKey(1))

    if args.mesh:
        serve_sharded(retriever, args.mesh, batches, args)

    if args.online:
        serve_online(retriever, args)

    if args.fleet:
        serve_fleet(retriever, args)


if __name__ == "__main__":
    main()
