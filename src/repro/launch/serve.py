"""Serving launcher: build a LEMUR index over a synthetic corpus and serve
batched retrieval requests, reporting QPS + recall for any registered
first-stage backend.

  PYTHONPATH=src python -m repro.launch.serve --m 8000 --batch 64
  PYTHONPATH=src python -m repro.launch.serve --backend muvera --m 4000
  PYTHONPATH=src python -m repro.launch.serve --backend all --m 4000

``--backend`` takes any name from ``repro.anns.registry`` (or ``all`` to
sweep every backend over the SAME trained reduction).  The jitted query fn
must compile exactly once per backend — the launcher counts traces and
reports it.
"""
from __future__ import annotations

import argparse
import time


def serve_backend(idx, backend, batches, args, *, key=None):
    """Attach `backend` to a built index and serve; returns metrics dict.
    ``batches`` is a list of (q, qm, truth) — ground truth is precomputed
    once in main() since the query stream is identical across backends."""
    import jax

    from repro.core import recall_at
    from repro.core.index import attach_backend, query

    bidx = attach_backend(idx, backend, key=key)
    traces = [0]

    def _query(q, qm):
        traces[0] += 1
        return query(bidx, q, qm)

    serve = jax.jit(_query)
    total_q, total_t, recs = 0, 0.0, []
    for b, (q, qm, truth) in enumerate(batches):
        t0 = time.time()
        s, ids = serve(q, qm)
        jax.block_until_ready(ids)
        dt = time.time() - t0
        if b > 0:  # skip compile batch
            total_q += args.batch
            total_t += dt
        recs.append(float(recall_at(ids, truth).mean()))
    qps = total_q / max(total_t, 1e-9)
    rec = sum(recs) / len(recs)
    print(f"[serve] backend={backend:13s} QPS={qps:.0f}  "
          f"recall@{args.k}={rec:.3f}  jit_traces={traces[0]}")
    return {"backend": backend, "qps": qps, f"recall@{args.k}": rec,
            "jit_traces": traces[0]}


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--m", type=int, default=8000)
    p.add_argument("--d", type=int, default=48)
    p.add_argument("--d-prime", type=int, default=128)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--n-batches", type=int, default=5)
    p.add_argument("--k", type=int, default=10)
    p.add_argument("--backend", default="ivf",
                   help="registered anns backend name, or 'all'")
    args = p.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.anns import registry
    from repro.core import LemurConfig, build_index, maxsim
    from repro.data import synthetic

    names = registry.list_backends() if args.backend == "all" else [args.backend]
    for n in names:
        registry.get_backend(n)  # fail fast on typos, before the build

    corpus = synthetic.make_corpus(m=args.m, d=args.d, avg_tokens=16, max_tokens=24,
                                   seed=0)
    cfg = LemurConfig(d=args.d, d_prime=args.d_prime, m_pretrain=1024, n_train=16384,
                      n_ols=4096, epochs=25, k=args.k, k_prime=256,
                      anns=names[0], ivf_nprobe=32, sq8=True)
    t0 = time.time()
    idx = build_index(jax.random.PRNGKey(0), corpus, cfg, verbose=True)
    print(f"[serve] index built in {time.time()-t0:.1f}s "
          f"({args.m/(time.time()-t0):.0f} docs/s)")

    batches = []
    for b in range(args.n_batches):
        q = jnp.asarray(synthetic.queries_from_corpus_query(corpus, args.batch, 8,
                                                            seed=100 + b))
        qm = jnp.ones(q.shape[:2], bool)
        _, truth = maxsim.true_topk(q, qm, idx.doc_tokens, idx.doc_mask, args.k)
        batches.append((q, qm, truth))

    for name in names:
        serve_backend(idx, name, batches, args, key=jax.random.PRNGKey(1))


if __name__ == "__main__":
    main()
