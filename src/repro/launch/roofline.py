"""§Roofline: derive the three roofline terms per (arch × shape) cell from
the compiled dry-run records.

  compute    t_c = HLO_FLOPs_per_device / peak_FLOPs          (197 TF/s bf16)
  memory     t_m = HLO_bytes_per_device / HBM_bw              (819 GB/s)
  collective t_x = collective_bytes_per_device / link_bw      (50 GB/s/link)

All three numerators come from the loop-corrected static HLO analysis
(launch/hlo_analysis.py) of the per-device SPMD module — XLA's own
cost_analysis counts while bodies once and is reported only as a cross-check.
MODEL_FLOPS is the analytic useful work (6·N_active·D for training,
2·N_active·D per generated token for decode, family formulas otherwise); the
ratio MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch/padding waste.

Caveats recorded with every row:
 * bytes is a fusion-boundary proxy from the CPU-compiled HLO — TPU fusion
   differs; bf16 buffers are fp32-legalized on CPU (inflates ~2x).
 * one ICI link per chip assumed (conservative; v5e has 4).

Usage:  PYTHONPATH=src python -m repro.launch.roofline [--mesh single]
Writes results/roofline_<mesh>.json and a markdown table to stdout.
"""
from __future__ import annotations

import argparse
import json
import pathlib

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / link

TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
          "decode_32k": 128, "long_500k": 1}


def model_flops(arch: str, shape: str, n_chips: int) -> float:
    """Analytic useful FLOPs per device per step."""
    from repro.configs.registry import get_arch

    mod = get_arch(arch)
    if mod.FAMILY == "lm":
        from repro.models import lm

        cfg = mod.CONFIG
        n_active = lm.active_param_count(cfg)
        toks = TOKENS[shape]
        # attention score/AV flops (excluded from 6·N·D; dominant for MLA's
        # 128 heads × ~1.1k effective dim)
        if cfg.attn == "mla":
            dqk, dv = cfg.kv_lora + cfg.qk_rope, cfg.kv_lora
        else:
            dqk = dv = cfg.head_dim
        H = cfg.n_heads
        if shape == "train_4k":
            seq = 4096
            attn = 3.0 * 2.0 * 0.5 * seq * H * (dqk + dv) * cfg.n_layers * toks
            total = 6.0 * n_active * toks + attn
        elif shape == "prefill_32k":
            seq = 32768
            attn = 2.0 * 0.5 * seq * H * (dqk + dv) * cfg.n_layers * toks
            total = 2.0 * n_active * toks + attn
        else:  # decode: one new token against an S-token cache
            S = 32768 if shape == "decode_32k" else 524288
            attn = 2.0 * S * H * (dqk + dv) * cfg.n_layers * toks
            total = 2.0 * n_active * toks + attn
        return total / n_chips
    if mod.FAMILY == "gnn":
        cfg = mod.SHAPES[shape].get("cfg", mod.CONFIG)
        spec = mod.SHAPES[shape]
        dh = cfg.d_hidden
        mlp_cost = lambda d_in, d_out: 2 * (d_in * dh + (cfg.mlp_layers - 1) * dh * dh + dh * d_out)
        E = spec.get("n_edges", 0)
        if spec["kind"] == "sampled":  # two-hop sampled forward, not full E
            b = spec.get("batch_nodes", 1024)
            f1, f2 = cfg.fanout[0], cfg.fanout[1]
            n_enc = b * (1 + f1 + f1 * f2)
            total = n_enc * mlp_cost(cfg.d_node_in, dh) + b * (f1 + 1) * mlp_cost(2 * dh, dh) + b * mlp_cost(dh, cfg.d_out)
            return 3.0 * total / n_chips
        N = spec.get("n_nodes", 0)
        per_edge = mlp_cost(3 * dh, dh)
        per_node = mlp_cost(2 * dh, dh)
        enc = N * mlp_cost(cfg.d_node_in, dh) + E * mlp_cost(cfg.d_edge_in, dh)
        proc = cfg.n_layers * (E * per_edge + N * per_node)
        total = enc + proc + N * mlp_cost(dh, cfg.d_out)
        mult = 3.0 if spec["kind"] in ("full", "batched") else 1.0  # fwd+bwd
        return mult * total / n_chips
    if mod.FAMILY == "recsys":
        cfg = mod.CONFIG
        spec = mod.SHAPES[shape]
        B = spec.get("batch", spec.get("n_candidates", 1))
        d = cfg.embed_dim
        f = max(cfg.n_fields, 1)
        mlp_in = f * d if cfg.model in ("deepfm", "xdeepfm") else None
        per_ex = 0.0
        if cfg.model in ("deepfm", "xdeepfm"):
            dims = (f * d, *cfg.mlp_dims, 1)
            per_ex += sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
            if cfg.model == "xdeepfm":
                hk = f
                for h in cfg.cin_dims:
                    per_ex += 2 * h * hk * f * d
                    hk = h
        elif cfg.model == "bst":
            L = cfg.seq_len + 1
            per_ex += 8 * L * d * d + 4 * L * L * d  # 1 block attention+proj
            per_ex += 2 * L * d * 4 * d * 2          # ffn
            dims = (L * d, *cfg.mlp_dims, 1)
            per_ex += sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
        else:  # two_tower
            dims_u = (f * d, *cfg.tower_dims, cfg.out_dim)
            per_ex += sum(2 * a * b for a, b in zip(dims_u[:-1], dims_u[1:]))
            if spec["kind"] == "retrieval":
                return (2.0 * B * cfg.out_dim) / n_chips * 1  # dot per candidate
            dims_i = (d, *cfg.tower_dims, cfg.out_dim)
            per_ex += sum(2 * a * b for a, b in zip(dims_i[:-1], dims_i[1:]))
        mult = 3.0 if spec["kind"] == "train" else 1.0
        return mult * B * per_ex / n_chips
    if mod.FAMILY == "lemur":
        cfg = mod.CONFIG
        spec = mod.SHAPES[shape]
        m, T = spec["m"], spec["doc_tokens"]
        if spec["kind"] == "lemur_serve":
            B, Tq = spec["batch"], spec["q_tokens"]
            latent = 2.0 * B * m * cfg.d_prime                     # MIPS scan
            kpl = max(cfg.k, 4 * cfg.k_prime // n_chips)
            rerank = 2.0 * B * kpl * n_chips * Tq * T * cfg.d      # exact MaxSim
            psi = 2.0 * B * Tq * cfg.d * cfg.d_prime
            return (latent + rerank + psi) / n_chips
        # indexing: target matrix + OLS solves
        g = 2.0 * cfg.n_ols * m * T * cfg.d
        rhs = 2.0 * cfg.n_ols * cfg.d_prime * m
        solve = 2.0 * cfg.d_prime**2 * m
        return (g + rhs + solve) / n_chips
    raise ValueError(arch)


def summarize(rec: dict, n_chips: int) -> dict:
    flops = rec.get("flops_loop_corrected", rec.get("flops", 0.0))
    byts = rec.get("bytes_loop_corrected", rec.get("bytes_accessed", 0.0))
    coll = rec.get("collectives_loop_corrected", rec.get("collectives", {}))
    coll_b = coll.get("total_bytes", 0.0)
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll_b / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"], n_chips)
    step_time = max(terms.values())
    useful_frac = (mf / PEAK_FLOPS) / step_time if step_time > 0 else 0.0
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "t_collective_s": t_x,
        "dominant": dom,
        "hlo_flops": flops,
        "model_flops": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": min(1.0, useful_frac),
        "hbm_bytes": byts,
        "collective_bytes": coll_b,
    }


def kernel_roofline(flops: float, hbm_bytes: float,
                    measured_s: float) -> dict:
    """Roofline terms for ONE measured kernel launch (the benchmarks' per-row
    helper, vs :func:`summarize`'s per-step dry-run records).

    ``roofline_frac`` = ideal step time (max of the compute/memory terms at
    the chip's peaks) / measured wall time — 1.0 means the launch sits ON
    the roofline.  On the CPU container the fraction is tiny and only
    meaningful RELATIVELY (same op/shape/backend across runs), which is
    exactly how the bench-smoke regression gate uses it."""
    t_c = flops / PEAK_FLOPS
    t_m = hbm_bytes / HBM_BW
    ideal = max(t_c, t_m)
    return {
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "dominant": "compute" if t_c >= t_m else "memory",
        "roofline_frac": min(1.0, ideal / max(measured_s, 1e-12)),
    }


RECOMMEND = {
    "compute": "compute-bound: raise MXU utilization (bf16 everywhere, larger "
               "matmul tiles, drop remat where memory allows)",
    "memory": "memory-bound: fuse / shrink activation round-trips, quantize "
              "resident state (SQ8 corpus, int8 moments), raise arithmetic "
              "intensity per HBM pass",
    "collective": "collective-bound: reshard to cut all-gathers (kv-head vs "
                  "seq cache layout, 2D weight sharding), overlap collectives "
                  "with compute, compress cross-pod traffic",
}


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--mesh", choices=["single", "multi"], default="single")
    p.add_argument("--results", default="results")
    args = p.parse_args(argv)

    path = pathlib.Path(args.results) / f"dryrun_{args.mesh}.json"
    recs = json.loads(path.read_text())
    n_chips = 512 if args.mesh == "multi" else 256

    rows = []
    for rec in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        rows.append(summarize(rec, n_chips))

    out = pathlib.Path(args.results) / f"roofline_{args.mesh}.json"
    out.write_text(json.dumps(rows, indent=1))

    print(f"\n## Roofline — {args.mesh} pod ({n_chips} chips), per device per step\n")
    print("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | dominant |"
          " MODEL/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.2f} "
            f"| {r['t_memory_s']*1e3:.2f} | {r['t_collective_s']*1e3:.2f} "
            f"| {r['dominant']} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} |"
        )
    print("\nbottleneck guidance:")
    for k, v in RECOMMEND.items():
        print(f"  - {k}: {v}")
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
