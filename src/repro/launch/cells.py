"""Dry-run cell builders: one (architecture × input-shape) pair = one Cell.

A Cell packages everything ``dryrun.py`` needs to ``jit(...).lower().compile()``
WITHOUT allocating real data: the step function, abstract (ShapeDtypeStruct)
arguments produced by ``jax.eval_shape`` over the real init/input builders,
and in/out shardings resolved from the family's sharding rules.

Families: lm (train/prefill/decode), gnn (full/sampled/batched), recsys
(train/serve/retrieval), lemur (index/serve).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.common.pytree import tree_map_with_name
from repro.dist.sharding import (
    GNN_RULES,
    LM_RULES,
    LM_RULES_FFSLICE,
    RECSYS_RULES,
    ShardingRules,
)
from repro.launch.mesh import batch_axes
from repro.models import gnn as gnn_mod
from repro.models import lm as lm_mod
from repro.models import recsys as recsys_mod


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    fn: Callable            # positional-args step function
    args: tuple             # pytrees of ShapeDtypeStruct
    in_shardings: tuple
    out_shardings: Any      # None => let GSPMD choose
    donate_argnums: tuple = ()


STACK_RE = __import__("re").compile(r"stack_\d+/pos_\d+/")


def _resolve_spec(rules: ShardingRules, name: str, ndim: int):
    """Rule lookup with scan-stack handling: leaves under stack_*/pos_*/ are
    stacked on a leading scan axis — match the per-layer name and prepend
    None for the scan dim."""
    if STACK_RE.search(name):
        base = STACK_RE.sub("", name)
        spec = rules.spec(base, ndim - 1)
        return P(None, *spec)
    return rules.spec(name, ndim)


def _shardings_from_rules(mesh, rules: ShardingRules, tree):
    return tree_map_with_name(
        lambda n, x: NamedSharding(mesh, _resolve_spec(rules, n, len(x.shape))), tree
    )


def _replicated(mesh, tree):
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)


def _lm_rules(cfg: lm_mod.LMConfig) -> ShardingRules:
    return LM_RULES_FFSLICE if cfg.moe_layout == "ffslice" and cfg.moe_n_experts else LM_RULES


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_abstract_state(cfg, use_adam8: bool):
    def build():
        params = lm_mod.init_lm(jax.random.PRNGKey(0), cfg)
        if use_adam8:
            from repro.optim.adam8bit import adam8_init

            opt = adam8_init(params)
        else:
            from repro.optim import adam_init

            opt = adam_init(params, moment_dtype=jnp.float32)
        return params, opt

    return jax.eval_shape(build)


def lm_train_cell(arch, cfg: lm_mod.LMConfig, *, seq: int, global_batch: int,
                  mesh, use_adam8: bool = False) -> Cell:
    ba = batch_axes(mesh)
    rules = _lm_rules(cfg)
    params_s, opt_s = _lm_abstract_state(cfg, use_adam8)
    tokens = jax.ShapeDtypeStruct((global_batch, seq), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}

    if use_adam8:
        from repro.optim.adam8bit import adam8_update

        def loss_fn(params, tokens, labels):
            hidden, aux = lm_mod.forward_train(params, tokens, cfg, mesh)
            return lm_mod.lm_loss(params, hidden, labels, cfg) + cfg.aux_loss_coef * aux

        def step(params, opt, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch["tokens"], batch["labels"])
            params, opt, m = adam8_update(grads, opt, params)
            return params, opt, {"loss": loss, **m}
    else:
        step = lm_mod.make_train_step(cfg, mesh)

    psh = _shardings_from_rules(mesh, rules, params_s)
    # optimizer moments inherit the param shardings (ZeRO); step counter repl.
    osh = _opt_shardings(mesh, rules, opt_s)
    bsh = {"tokens": NamedSharding(mesh, P(ba, None)),
           "labels": NamedSharding(mesh, P(ba, None))}
    return Cell(arch, f"train_{seq}", "train", step, (params_s, opt_s, batch),
                (psh, osh, bsh), None, donate_argnums=(0, 1))


def _opt_shardings(mesh, rules, opt_s):
    """Moments follow their parameter's sharding; scalars replicated.

    Works for both OptState (mu/nu mirror params) and Opt8State (Q8 leaves:
    q mirrors the param; per-row scales take the param spec minus its last
    axis)."""

    def resolve(name, x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        # strip the state prefix ("mu/", "nu/") so rules match param names
        for pre in ("mu/", "nu/"):
            if name.startswith(pre):
                name = name[len(pre):]
        if name.endswith("/q"):
            return NamedSharding(mesh, _resolve_spec(rules, name[:-2], x.ndim))
        if name.endswith("/scale") and "ln" not in name and "norm" not in name:
            spec = _resolve_spec(rules, name[: -len("/scale")], x.ndim + 1)
            return NamedSharding(mesh, P(*spec[: x.ndim]))
        return NamedSharding(mesh, _resolve_spec(rules, name, x.ndim))

    return tree_map_with_name(resolve, opt_s)


def _cache_shardings(cfg, mesh, caches_s, *, batch: int):
    """KV caches: batch over (pod, data) when divisible, seq over model (plus
    data when batch == 1 -> long-context flash-decode layout)."""
    ba = batch_axes(mesh)
    n_batch_shards = int(np.prod([mesh.shape[a] for a in ba]))
    if batch >= n_batch_shards and batch % n_batch_shards == 0:
        bspec, sspec = ba, ("model",)
    else:
        bspec, sspec = None, ("data", "model") if "data" in mesh.axis_names else ("model",)

    def one(x):
        # leading dim = scan blocks; cache leaves are (nb, B, S, ...) rank 4/5
        rest = (None,) * (len(x.shape) - 3)
        return NamedSharding(mesh, P(None, bspec, sspec, *rest))

    return jax.tree_util.tree_map(one, caches_s)


def lm_prefill_cell(arch, cfg: lm_mod.LMConfig, *, seq: int, global_batch: int,
                    mesh) -> Cell:
    ba = batch_axes(mesh)
    rules = _lm_rules(cfg)
    params_s = jax.eval_shape(lambda: lm_mod.init_lm(jax.random.PRNGKey(0), cfg))
    tokens = jax.ShapeDtypeStruct((global_batch, seq), jnp.int32)
    cache_len = seq + 128

    def step(params, tokens):
        return lm_mod.prefill(params, tokens, cfg, cache_len, mesh)

    psh = _shardings_from_rules(mesh, rules, params_s)
    tsh = NamedSharding(mesh, P(ba, None))
    caches_s = jax.eval_shape(lambda: lm_mod.init_cache(cfg, global_batch, cache_len))
    csh = _cache_shardings(cfg, mesh, caches_s, batch=global_batch)
    out_sh = (NamedSharding(mesh, P(ba, None)), csh)
    return Cell(arch, f"prefill_{seq}", "prefill", step, (params_s, tokens),
                (psh, tsh), out_sh)


def lm_decode_cell(arch, cfg: lm_mod.LMConfig, *, seq: int, global_batch: int,
                   mesh) -> Cell:
    ba = batch_axes(mesh)
    rules = _lm_rules(cfg)
    params_s = jax.eval_shape(lambda: lm_mod.init_lm(jax.random.PRNGKey(0), cfg))
    caches_s = jax.eval_shape(lambda: lm_mod.init_cache(cfg, global_batch, seq))
    token = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)

    def step(params, token, caches):
        logits, new_caches = lm_mod.decode(params, token, caches, seq, cfg, mesh)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_caches

    psh = _shardings_from_rules(mesh, rules, params_s)
    csh = _cache_shardings(cfg, mesh, caches_s, batch=global_batch)
    n_batch_shards = int(np.prod([mesh.shape[a] for a in ba]))
    tok_spec = P(ba, None) if global_batch % n_batch_shards == 0 and global_batch >= n_batch_shards else P()
    tsh = NamedSharding(mesh, tok_spec)
    out_sh = (NamedSharding(mesh, P(tok_spec[0]) if len(tok_spec) else P()), csh)
    return Cell(arch, f"decode_{seq}", "decode", step, (params_s, token, caches_s),
                (psh, tsh, csh), out_sh, donate_argnums=(2,))


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def gnn_full_cell(arch, cfg: gnn_mod.GNNConfig, *, n_nodes: int, n_edges: int,
                  mesh, n_graphs: int = 0) -> Cell:
    axes = tuple(mesh.axis_names)
    node_axes = batch_axes(mesh)
    nd = int(np.prod(list(mesh.shape.values())))
    nn_shards = int(np.prod([mesh.shape[a] for a in node_axes]))
    n_edges = -(-n_edges // nd) * nd      # pad edges to the mesh
    n_nodes = -(-n_nodes // nn_shards) * nn_shards  # pad nodes (mask in loss)
    batch = {
        "node_feat": jax.ShapeDtypeStruct((n_nodes, cfg.d_node_in), jnp.float32),
        "edge_feat": jax.ShapeDtypeStruct((n_edges, cfg.d_edge_in), jnp.float32),
        "senders": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
        "receivers": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
        "label_mask": jax.ShapeDtypeStruct((n_nodes,), jnp.float32),
    }
    if cfg.graph_readout:
        batch["graph_ids"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
        batch["graph_labels"] = jax.ShapeDtypeStruct((n_graphs, cfg.d_out), jnp.float32)
        del batch["label_mask"]
    elif cfg.task == "classification":
        batch["labels"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
    else:
        batch["labels"] = jax.ShapeDtypeStruct((n_nodes, cfg.d_out), jnp.float32)

    def build():
        from repro.optim import adam_init

        params = gnn_mod.init_gnn(jax.random.PRNGKey(0), cfg)
        return params, adam_init(params)

    params_s, opt_s = jax.eval_shape(build)
    step = gnn_mod.make_train_step(cfg, mesh)
    edge_sh = NamedSharding(mesh, P(axes))
    node_sh = NamedSharding(mesh, P(node_axes))
    repl = NamedSharding(mesh, P())
    bsh = {k: node_sh for k in batch}
    for k in ("edge_feat", "senders", "receivers"):
        bsh[k] = edge_sh
    if "graph_labels" in batch:
        bsh["graph_labels"] = repl
    return Cell(arch, f"full_{n_nodes}", "train", step,
                (params_s, opt_s, batch),
                (_replicated(mesh, params_s), _replicated(mesh, opt_s), bsh),
                None, donate_argnums=(0, 1))


def gnn_sampled_cell(arch, cfg: gnn_mod.GNNConfig, *, n_nodes: int, n_edges: int,
                     batch_nodes: int, d_feat: int, mesh) -> Cell:
    ba = batch_axes(mesh)
    batch = {
        "row_ptr": jax.ShapeDtypeStruct((n_nodes + 1,), jnp.int32),
        "col_idx": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
        "node_feat": jax.ShapeDtypeStruct((n_nodes, d_feat), jnp.float32),
        "seeds": jax.ShapeDtypeStruct((batch_nodes,), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch_nodes,), jnp.int32),
    }

    def build():
        from repro.optim import adam_init

        params = gnn_mod.init_gnn(jax.random.PRNGKey(0), cfg)
        return params, adam_init(params)

    params_s, opt_s = jax.eval_shape(build)
    base = gnn_mod.make_sampled_train_step(cfg)
    step = lambda p, o, b: base(p, o, jax.random.PRNGKey(7), b)
    repl = NamedSharding(mesh, P())
    bsh = {k: repl for k in batch}
    bsh["seeds"] = NamedSharding(mesh, P(ba))
    bsh["labels"] = NamedSharding(mesh, P(ba))
    return Cell(arch, "sampled", "train", step, (params_s, opt_s, batch),
                (_replicated(mesh, params_s), _replicated(mesh, opt_s), bsh),
                None, donate_argnums=(0, 1))


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_batch_spec(cfg: recsys_mod.RecsysConfig, batch: int):
    if cfg.model == "bst":
        return {
            "history": jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32),
            "target_item": jax.ShapeDtypeStruct((batch,), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch,), jnp.float32),
        }
    if cfg.model == "two_tower":
        return {
            "ids": jax.ShapeDtypeStruct((batch, cfg.n_fields), jnp.int32),
            "item": jax.ShapeDtypeStruct((batch,), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch,), jnp.float32),
        }
    return {
        "ids": jax.ShapeDtypeStruct((batch, cfg.n_fields), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch,), jnp.float32),
    }


def recsys_cell(arch, cfg: recsys_mod.RecsysConfig, *, batch: int, mesh,
                kind: str) -> Cell:
    ba = batch_axes(mesh)
    batch_spec = _recsys_batch_spec(cfg, batch)

    def build():
        from repro.optim import adam_init

        params = recsys_mod.init_recsys(jax.random.PRNGKey(0), cfg)
        return params, adam_init(params)

    params_s, opt_s = jax.eval_shape(build)
    psh = _shardings_from_rules(mesh, RECSYS_RULES, params_s)
    osh = _opt_shardings(mesh, RECSYS_RULES, opt_s)
    bsh = jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, P(ba) if x.ndim == 1 else P(ba, None)), batch_spec
    )
    if kind == "train":
        step = recsys_mod.make_train_step(cfg, mesh)
        return Cell(arch, f"train_{batch}", "train", step,
                    (params_s, opt_s, batch_spec), (psh, osh, bsh), None,
                    donate_argnums=(0, 1))
    chunk = 32768 if batch > 65536 else 0
    serve = recsys_mod.make_serve_step(cfg, mesh, chunk=chunk)
    batch_spec.pop("labels", None)
    bsh = jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, P(ba) if x.ndim == 1 else P(ba, None)), batch_spec
    )
    step = lambda p, b: serve(p, b)
    return Cell(arch, f"serve_{batch}", "serve", step, (params_s, batch_spec),
                (psh, bsh), NamedSharding(mesh, P(ba)))


def recsys_retrieval_cell(arch, cfg: recsys_mod.RecsysConfig, *, n_candidates: int,
                          mesh, k: int = 100) -> Cell:
    axes = tuple(mesh.axis_names)
    params_s = jax.eval_shape(lambda: recsys_mod.init_recsys(jax.random.PRNGKey(0), cfg))
    psh = _shardings_from_rules(mesh, RECSYS_RULES, params_s)

    nd = int(np.prod(list(mesh.shape.values())))
    pad_to = np.lcm(nd, 65536) if cfg.model != "two_tower" else nd
    n_candidates = -(-n_candidates // pad_to) * pad_to  # pad to mesh (and chunk)
    if cfg.model == "two_tower":
        batch_spec = {"ids": jax.ShapeDtypeStruct((1, cfg.n_fields), jnp.int32)}
        cand = jax.ShapeDtypeStruct((n_candidates, cfg.out_dim), jnp.float32)
        step = recsys_mod.make_retrieval_step(cfg, mesh, k=k)
        bsh = {"ids": NamedSharding(mesh, P())}
        csh = NamedSharding(mesh, P(axes, None))
        return Cell(arch, "retrieval", "retrieval", step,
                    (params_s, batch_spec, cand), (psh, bsh, csh),
                    (NamedSharding(mesh, P()), NamedSharding(mesh, P())))

    # CTR models: bulk-score one user against n_candidates items
    serve = recsys_mod.make_serve_step(cfg, mesh, chunk=65536)
    ba = batch_axes(mesh)
    if cfg.model == "bst":
        batch_spec = {
            "history": jax.ShapeDtypeStruct((n_candidates, cfg.seq_len), jnp.int32),
            "target_item": jax.ShapeDtypeStruct((n_candidates,), jnp.int32),
        }
    else:
        batch_spec = {"ids": jax.ShapeDtypeStruct((n_candidates, cfg.n_fields), jnp.int32)}
    bsh = jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, P(ba) if x.ndim == 1 else P(ba, None)), batch_spec
    )

    def step(params, batch):
        scores = serve(params, batch)
        return jax.lax.top_k(scores, k)

    return Cell(arch, "retrieval", "retrieval", step, (params_s, batch_spec),
                (psh, bsh), None)


# ---------------------------------------------------------------------------
# LEMUR cells (the paper's own serving/indexing over the production mesh)
# ---------------------------------------------------------------------------

def lemur_serve_cell(arch, cfg, *, m: int, doc_tokens: int, q_tokens: int,
                     batch: int, mesh) -> Cell:
    from repro.core import distributed as dist
    from repro.core.model import init_psi

    axes = tuple(mesh.axis_names)
    nd = int(np.prod(list(mesh.shape.values())))
    m = -(-m // nd) * nd  # pad corpus to the mesh
    psi_s = jax.eval_shape(lambda: init_psi(jax.random.PRNGKey(0), cfg.d, cfg.d_prime))
    sq8 = cfg.ivf.sq8
    state_s = dist.ShardedRetrievalState(
        psi=psi_s,
        W=jax.ShapeDtypeStruct((m, cfg.d_prime), jnp.int8 if sq8 else jnp.bfloat16),
        doc_tokens=jax.ShapeDtypeStruct((m, doc_tokens, cfg.d),
                                        jnp.int8 if sq8 else jnp.bfloat16),
        doc_mask=jax.ShapeDtypeStruct((m, doc_tokens), jnp.bool_),
        W_scales=jax.ShapeDtypeStruct((m,), jnp.bfloat16) if sq8 else None,
        doc_scales=jax.ShapeDtypeStruct((m, doc_tokens), jnp.bfloat16) if sq8 else None,
    )
    q = jax.ShapeDtypeStruct((batch, q_tokens, cfg.d), jnp.bfloat16)
    qm = jax.ShapeDtypeStruct((batch, q_tokens), jnp.bool_)
    serve = dist.make_serve_step(mesh, cfg)
    corpus = NamedSharding(mesh, P(axes))
    ssh = dist.ShardedRetrievalState(
        psi=_replicated(mesh, psi_s), W=corpus, doc_tokens=corpus, doc_mask=corpus,
        W_scales=corpus if sq8 else None, doc_scales=corpus if sq8 else None,
    )
    repl = NamedSharding(mesh, P())
    return Cell(arch, "serve", "lemur_serve", serve, (state_s, q, qm),
                (ssh, repl, repl), (repl, repl))


def lemur_index_cell(arch, cfg, *, m: int, doc_tokens: int, mesh) -> Cell:
    from repro.core import distributed as dist

    axes = tuple(mesh.axis_names)
    nd = int(np.prod(list(mesh.shape.values())))
    m = -(-m // nd) * nd
    dpr, npts = cfg.d_prime, cfg.n_ols
    args = (
        jax.ShapeDtypeStruct((dpr, dpr), jnp.float32),            # chol factor
        jax.ShapeDtypeStruct((npts, dpr), jnp.float32),           # feats
        jax.ShapeDtypeStruct((npts, cfg.d), jnp.float32),         # x_ols
        jax.ShapeDtypeStruct((m, doc_tokens, cfg.d), jnp.bfloat16),
        jax.ShapeDtypeStruct((m, doc_tokens), jnp.bool_),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    step = dist.make_index_step(mesh, cfg)
    corpus = NamedSharding(mesh, P(axes))
    repl = NamedSharding(mesh, P())
    in_sh = (repl, repl, repl, corpus, corpus, repl, repl)
    return Cell(arch, "index", "lemur_index", step, args, in_sh, corpus)
