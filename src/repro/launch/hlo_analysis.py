"""Loop-corrected HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 64 transformer blocks reports 1/64th of the real FLOPs,
and collectives inside the loop are similarly undercounted.  This module
re-derives per-device FLOPs / collective bytes by statically walking the
post-optimization HLO:

  1. parse computations and their instructions (shapes + operands),
  2. build the call graph (fusion `calls=`, while `body=`/`condition=`,
     call `to_apply=`, conditional branches),
  3. extract while trip counts from the loop condition's
     ``compare(iv, constant(N)), direction=LT`` pattern,
  4. DFS from the entry computation accumulating dot/convolution FLOPs and
     collective result-bytes, multiplying by the product of enclosing trip
     counts.

Validated against a known scan-of-matmuls (tests/test_hlo_analysis.py).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "token": 0, "s4": 1, "u4": 1,
}

SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\](?:\{[^}]*\})?")
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclasses.dataclass
class Instr:
    name: str
    result_shapes: list[tuple[str, tuple[int, ...]]]
    op: str
    operands: list[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: dict[str, Instr]
    order: list[str]


def _parse_shapes(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    params: dict[str, str] = {}
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(s)
            if m and s.endswith("{"):
                cur = Computation(m.group(1), {}, [])
                if s.startswith("ENTRY"):
                    entry = m.group(1)
                continue
        else:
            if s == "}" or s.startswith("}"):
                comps[cur.name] = cur
                cur = None
                continue
            m = _INSTR_RE.match(s)
            if not m:
                continue
            name, result, op, rest = m.groups()
            shapes = _parse_shapes(result)
            # operand names: %foo refs in the argument list (before attrs)
            operands = re.findall(r"%([\w.\-]+)", rest)
            cur.instrs[name] = Instr(name, shapes, op, operands, rest)
            cur.order.append(name)
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _dot_flops(comp: Computation, instr: Instr) -> float:
    """2 * prod(result dims) * contracted size (per result element)."""
    if not instr.result_shapes:
        return 0.0
    _, rshape = instr.result_shapes[0]
    out = 1
    for d in rshape:
        out *= d
    # contracted size: lhs size / (batch+free dims present in result)
    lhs = instr.operands[0] if instr.operands else None
    lhs_shape = None
    if lhs and lhs in comp.instrs and comp.instrs[lhs].result_shapes:
        lhs_shape = comp.instrs[lhs].result_shapes[0][1]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    if lhs_shape is not None and m is not None:
        contracted = 1
        for d in m.group(1).split(","):
            if d:
                contracted *= lhs_shape[int(d)]
        return 2.0 * out * contracted
    # operand shape unknown (computation parameter): fall back via attrs text
    m2 = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    return 2.0 * out  # lower bound


def _param_shapes_from_caller(comp: Computation, caller_instr: Instr,
                              caller_comp: Computation):
    """Map %param_i shapes from the caller's operand list (for fusions)."""
    shapes = {}
    for i, op_name in enumerate(caller_instr.operands):
        src = caller_comp.instrs.get(op_name)
        if src and src.result_shapes:
            shapes[f"param_{i}"] = src.result_shapes
    return shapes


_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"?(\d+)')


def trip_count_from_config(attrs: str) -> int | None:
    m = _TRIP_RE.search(attrs)
    return int(m.group(1)) if m else None


def trip_count(comps: dict[str, Computation], cond_name: str) -> int:
    """Extract N from `compare(iv, constant(N)), direction=LT` in the cond."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = {}
    for name in cond.order:
        ins = cond.instrs[name]
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)", "constant(" + ins.attrs)
            if m:
                consts[name] = int(m.group(1))
    for name in cond.order:
        ins = cond.instrs[name]
        if ins.op == "compare" and "direction=LT" in ins.attrs:
            for opn in ins.operands:
                if opn in consts:
                    return max(1, consts[opn])
    return 1


def analyze(text: str) -> dict:
    comps, entry = parse_hlo(text)
    memo: dict[str, dict] = {}

    _SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "copy-start", "copy-done", "after-all"}

    def _instr_bytes(comp: Computation, ins: Instr) -> float:
        """HBM-traffic proxy: result + locally-known operand bytes.

        Counted at fusion/top-level granularity (fusion internals excluded),
        so it approximates buffer reads/writes between fused kernels —
        CPU-XLA fusion boundaries differ from TPU's; treated as a proxy."""
        if ins.op in _SKIP_BYTES:
            return 0.0
        b = float(_nbytes(ins.result_shapes))
        if ins.op in ("gather", "dynamic-slice"):
            # random-access reads touch ~result bytes, not the whole operand
            return 2.0 * b
        for opn in ins.operands:
            src = comp.instrs.get(opn)
            if src is not None and src.op not in ("tuple",):
                b += _nbytes(src.result_shapes)
        return b

    def walk(name: str, depth=0) -> dict:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        zero = {"flops": 0.0, "bytes": 0.0, "coll_bytes": defaultdict(float),
                "coll_count": defaultdict(float)}
        if comp is None or depth > 64:
            return zero
        total = {"flops": 0.0, "bytes": 0.0, "coll_bytes": defaultdict(float),
                 "coll_count": defaultdict(float)}

        def add(sub, mult=1.0, with_bytes=True):
            total["flops"] += mult * sub["flops"]
            if with_bytes:
                total["bytes"] += mult * sub["bytes"]
            for k, v in sub["coll_bytes"].items():
                total["coll_bytes"][k] += mult * v
            for k, v in sub["coll_count"].items():
                total["coll_count"][k] += mult * v

        for iname in comp.order:
            ins = comp.instrs[iname]
            op = ins.op
            if op in ("dot", "convolution"):
                total["flops"] += _dot_flops(comp, ins)
            total["bytes"] += _instr_bytes(comp, ins)
            base = op.replace("-start", "")
            if base in COLLECTIVES and not op.endswith("-done"):
                total["coll_bytes"][base] += _nbytes(ins.result_shapes)
                total["coll_count"][base] += 1
            if op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                if m:
                    add(walk(m.group(1), depth + 1), with_bytes=False)
            elif op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                trips = trip_count_from_config(ins.attrs)
                if trips is None:
                    trips = trip_count(comps, mc.group(1)) if mc else 1
                if mb:
                    add(walk(mb.group(1), depth + 1), mult=trips)
            elif op in ("call", "map", "reduce", "reduce-window", "scatter", "sort",
                        "select-and-scatter"):
                m = re.search(r"(?:to_apply|called_computations?)=%?([\w.\-]+)", ins.attrs)
                if m:
                    add(walk(m.group(1), depth + 1), with_bytes=False)
            elif op == "conditional":
                for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                     r"(?:true|false)_computation=%?([\w.\-]+))", ins.attrs):
                    names = (m.group(1) or m.group(2) or "").replace("%", "")
                    for b in [x.strip() for x in names.split(",") if x.strip()]:
                        add(walk(b, depth + 1), with_bytes=False)  # upper bound
        memo[name] = total
        return total

    res = walk(entry)
    return {
        "flops": res["flops"],
        "bytes": res["bytes"],
        "collective_bytes": dict(res["coll_bytes"]),
        "collective_count": dict(res["coll_count"]),
        "total_collective_bytes": sum(res["coll_bytes"].values()),
    }
