"""Scalable LEMUR indexing (§4.3): frozen-ψ + per-document OLS.

The Gram matrix (ΨᵀΨ + λI) is factorized ONCE; each document's latent vector
w_j is then an independent solve against its target column
g_j(x_i) = max_{c∈C_j}⟨c, x_i⟩ over the n' OLS training tokens.  Documents
are therefore embarrassingly parallel — on a pod we shard the corpus over
every device and each shard fits its own W rows (see core.distributed).
This is also the *incremental indexing* path: adding documents never
touches ψ or existing rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import maxsim
from repro.core.config import LemurConfig
from repro.core.model import TargetStats, psi_apply
from repro.data import synthetic


def make_training_tokens(corpus, cfg: LemurConfig, seed: int = 0) -> np.ndarray:
    """§4.2 training-set selection.  Returns (n, d) token embeddings."""
    rng = np.random.default_rng(seed)
    if cfg.query_strategy == "corpus-query":
        n_docs = max(1, cfg.n_train // 8)
        q = synthetic.queries_from_corpus_query(corpus, n_docs, q_tokens=8, seed=seed)
        toks = q.reshape(-1, corpus.d)
    elif cfg.query_strategy == "corpus":
        flat = corpus.doc_tokens[corpus.doc_mask]
        idx = rng.integers(0, flat.shape[0], size=cfg.n_train)
        toks = flat[idx]
    elif cfg.query_strategy == "query":
        q = synthetic.queries_held_out(corpus, max(1, cfg.n_train // 8), q_tokens=8, seed=seed)
        toks = q.reshape(-1, corpus.d)
    else:
        raise ValueError(cfg.query_strategy)
    if toks.shape[0] > cfg.n_train:
        toks = toks[rng.permutation(toks.shape[0])[: cfg.n_train]]
    return np.ascontiguousarray(toks, dtype=np.float32)


def gram_factor(psi_params, x_ols: jax.Array, ridge: float):
    """Cholesky factor of (ΨᵀΨ + λ n' I) and the feature matrix Ψ (n', d')."""
    feats = psi_apply(psi_params, x_ols)  # (n', d')
    n = feats.shape[0]
    gram = feats.T @ feats + ridge * n * jnp.eye(feats.shape[1], dtype=feats.dtype)
    chol = jax.scipy.linalg.cho_factor(gram)
    return chol, feats


def fit_output_layer_ols(
    psi_params,
    x_ols: jax.Array,          # (n', d) OLS training tokens
    doc_tokens: jax.Array,     # (m, Td, d)
    doc_mask: jax.Array,       # (m, Td)
    cfg: LemurConfig,
    stats: TargetStats | None = None,
    *,
    doc_block: int = 2048,
    solver_state: dict | None = None,
) -> jax.Array:
    """Solve eq. (7) for every document.  Returns W (m, d') fp32.

    Targets are standardized with the ψ-pretraining stats so W lives in the
    same output scale the MLP was trained in (App. A).  Pass a prebuilt
    ``solver_state`` (:func:`ols_solver_state`) to skip re-factorizing the
    Gram matrix — the retriever facade does this so build() and add() share
    one solver."""
    if solver_state is not None:
        chol, feats = solver_state["chol"], solver_state["feats"]
    else:
        chol, feats = gram_factor(psi_params, x_ols, cfg.ridge)
    m = doc_tokens.shape[0]
    ws = []
    for lo in range(0, m, doc_block):
        hi = min(lo + doc_block, m)
        g = maxsim.token_maxsim(x_ols, doc_tokens[lo:hi], doc_mask[lo:hi])  # (n', mb)
        if stats is not None:
            g = (g - stats.mean) / stats.std
        rhs = feats.T @ g                                  # (d', mb)
        w = jax.scipy.linalg.cho_solve(chol, rhs)          # (d', mb)
        ws.append(w.T)
    return jnp.concatenate(ws, axis=0)


def ols_solver_state(psi_params, x_ols: jax.Array, cfg: LemurConfig):
    """Reusable solver state for incremental/distributed indexing."""
    chol, feats = gram_factor(psi_params, x_ols, cfg.ridge)
    return {"chol": chol, "feats": feats, "x_ols": x_ols}


def fit_docs(solver_state, doc_tokens, doc_mask, stats: TargetStats | None = None):
    """Fit W rows for one document block (used per-shard on the mesh)."""
    g = maxsim.token_maxsim(solver_state["x_ols"], doc_tokens, doc_mask)
    if stats is not None:
        g = (g - stats.mean) / stats.std
    rhs = solver_state["feats"].T @ g
    return jax.scipy.linalg.cho_solve(solver_state["chol"], rhs).T
