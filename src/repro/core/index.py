"""LemurIndex: the Fig. 1 pipeline state + v0 free-function shims.

:class:`LemurIndex` is the immutable pytree holding a built LEMUR index
(cfg, ψ, target stats, OLS W rows, doc tokens, backend name + opaque
backend state).  The lifecycle around it — build, search, incremental add,
backend swap, save/load — lives in :class:`repro.retriever.LemurRetriever`
(Retriever API v1); the free functions below (``build_index`` /
``attach_backend`` / ``add_docs`` / ``query`` / ``candidates``) are thin
back-compat shims over that facade and keep the v0 call sites working.

New code should prefer::

    from repro.retriever import LemurRetriever, SearchParams
    r = LemurRetriever.build(corpus, cfg)
    scores, ids = r.search(q_tokens, q_mask, SearchParams(k=10))
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import LemurConfig
from repro.core.model import TargetStats


class LemurIndex(NamedTuple):
    cfg: LemurConfig
    psi: dict                 # feature-encoder params
    stats: TargetStats        # target standardization (App. A)
    W: jax.Array              # (m, d') latent doc vectors = OLS output layer
    doc_tokens: jax.Array     # (m, Td, d) for exact rerank
    doc_mask: jax.Array       # (m, Td)
    backend: str              # registered first-stage backend name
    ann: Any                  # opaque backend state (jax pytree)

    @property
    def m(self) -> int:
        return self.W.shape[0]


def _legacy_params(index: LemurIndex, *, k=None, k_prime=None, nprobe=None,
                   use_ann=True):
    """Map the v0 loose kwargs onto a resolved SearchParams."""
    from repro.anns import registry
    from repro.retriever.params import SearchParams

    backend = None
    if nprobe is not None and use_ann:
        cls = registry.get_params_cls(index.backend)
        if "nprobe" in cls.__dataclass_fields__:
            backend = cls(nprobe=int(nprobe))
    return SearchParams(k=k, k_prime=k_prime, use_ann=use_ann,
                        backend=backend).resolve(index.cfg, index.backend)


def build_index(key, corpus, cfg: LemurConfig, *, x_train: np.ndarray | None = None,
                verbose: bool = False) -> LemurIndex:
    """v0 shim: ``LemurRetriever.build(...).index``."""
    from repro.retriever import LemurRetriever

    return LemurRetriever.build(corpus, cfg, key=key, x_train=x_train,
                                verbose=verbose).index


def attach_backend(index: LemurIndex, backend: str, key=None,
                   cfg: LemurConfig | None = None) -> LemurIndex:
    """v0 shim: ``LemurRetriever(index).with_backend(...).index`` — re-point
    an existing index at a different first-stage backend without re-training
    ψ/W."""
    from repro.retriever import LemurRetriever

    return LemurRetriever(index).with_backend(backend, key=key, cfg=cfg).index


def add_docs(index: LemurIndex, doc_tokens, doc_mask, solver_state=None, *,
             seed: int = 0) -> LemurIndex:
    """v0 shim: ``LemurRetriever(index).add(...).index`` — incremental
    growth with the frozen-ψ OLS solver.  Pass the build-time
    ``solver_state`` for bit-exact W scales; otherwise the corpus-sampling
    fallback solver is seeded by the explicit ``seed`` (v0 hid a
    ``default_rng(0)`` here)."""
    from repro.retriever import LemurRetriever

    r = LemurRetriever(index, solver_state=solver_state)
    return r.add(doc_tokens, doc_mask, seed=seed).index


def query(index: LemurIndex, q_tokens, q_mask=None, *, k: int | None = None,
          k_prime: int | None = None, nprobe: int | None = None,
          use_ann: bool = True):
    """q_tokens: (B, Tq, d) -> (scores (B, k), doc_ids (B, k)).

    v0 shim over the pure Retriever-API pipeline (jit-able: the kwargs
    become a static, resolved ``SearchParams``).  ``use_ann=False`` forces
    the exact latent scan regardless of backend (the Fig. 3 "exact
    inference" arm)."""
    from repro.retriever.facade import search_pipeline

    params = _legacy_params(index, k=k, k_prime=k_prime, nprobe=nprobe,
                            use_ann=use_ann)
    if q_mask is None:
        q_mask = jnp.ones(q_tokens.shape[:2], bool)
    return search_pipeline(index, q_tokens, q_mask, params)


def candidates(index: LemurIndex, q_tokens, q_mask=None, *, k_prime: int,
               nprobe: int | None = None, use_ann: bool = False):
    """First-stage candidates only (for recall@k' ablations, Fig. 2 left)."""
    from repro.retriever.facade import first_stage

    params = _legacy_params(index, k_prime=k_prime, nprobe=nprobe,
                            use_ann=use_ann)
    if q_mask is None:
        q_mask = jnp.ones(q_tokens.shape[:2], bool)
    return first_stage(index, q_tokens, q_mask, params)
