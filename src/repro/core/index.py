"""LemurIndex: the Fig. 1 pipeline state + v0 free-function shims.

:class:`LemurIndex` is the immutable pytree holding a built LEMUR index
(cfg, ψ, target stats, the paged corpus store, backend name + opaque
backend state).  Corpus storage is a :class:`repro.core.pages.PagedStore`
— fixed-size token pages behind a per-doc page table — so ``add`` /
``delete`` / ``update`` are page allocations instead of O(N) array
reallocation, and doc ids are stable slot indices that survive mutation.
The dense views (``W`` / ``doc_tokens`` / ``doc_mask`` properties) keep
every v0 consumer working; they materialize from pages on access and are
host-side only (never call them under jit — the query pipeline reads
``index.store`` directly).

The lifecycle — build, search, incremental add, delete/update, backend
swap, save/load — lives in :class:`repro.retriever.LemurRetriever`
(Retriever API v1); the free functions below (``build_index`` /
``attach_backend`` / ``add_docs`` / ``query`` / ``candidates``) are thin
back-compat shims over that facade and keep the v0 call sites working.

New code should prefer::

    from repro.retriever import LemurRetriever, SearchParams
    r = LemurRetriever.build(corpus, cfg)
    scores, ids = r.search(q_tokens, q_mask, SearchParams(k=10))
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pages
from repro.core.config import LemurConfig
from repro.core.model import TargetStats
from repro.core.pages import PagedStore


class LemurIndex(NamedTuple):
    cfg: LemurConfig
    psi: dict                 # feature-encoder params
    stats: TargetStats        # target standardization (App. A)
    store: PagedStore         # paged corpus: W rows + token pages + tombstones
    backend: str              # registered first-stage backend name
    ann: Any                  # opaque backend state (jax pytree)

    @classmethod
    def from_dense(cls, cfg, psi, stats, W, doc_tokens, doc_mask, backend,
                   ann, *, codec=None) -> "LemurIndex":
        """Build from the dense padded layout (same positional order the v1
        constructor took, so legacy call sites swap constructor for
        classmethod).  ``codec`` (a trained
        :class:`~repro.anns.quantization.ResidualCodec`) stores the tokens
        in the compressed residual tier instead of fp32 pages."""
        store, _ = pages.from_dense(W, doc_tokens, doc_mask, codec=codec)
        return cls(cfg, psi, stats, store, backend, ann)

    # -- host-side dense views (concrete index only; O(corpus) gathers) ----

    @property
    def m(self) -> int:
        """Slot high-water mark (NOT reduced by delete — ids are stable)."""
        return int(self.store.n_docs[0])

    @property
    def n_alive(self) -> int:
        return int(np.asarray(self.store.alive).sum())

    @property
    def W(self) -> jax.Array:
        return self.store.W[: self.m]

    @property
    def doc_tokens(self) -> jax.Array:
        return self.dense_view()[0]

    @property
    def doc_mask(self) -> jax.Array:
        return self.dense_view()[1]

    def dense_view(self):
        """(doc_tokens (m, Tm, d), doc_mask (m, Tm)) materialized from
        pages — deleted slots come back all-masked.  ``Tm`` is the page-
        rounded token bound (``store.td_max``), not the original ``Td``."""
        return pages.gather_docs(self.store, jnp.arange(self.m))


def _legacy_params(index: LemurIndex, *, k=None, k_prime=None, nprobe=None,
                   use_ann=True):
    """Map the v0 loose kwargs onto a resolved SearchParams."""
    from repro.anns import registry
    from repro.retriever.params import SearchParams

    backend = None
    if nprobe is not None and use_ann:
        cls = registry.get_params_cls(index.backend)
        if "nprobe" in cls.__dataclass_fields__:
            backend = cls(nprobe=int(nprobe))
    return SearchParams(k=k, k_prime=k_prime, use_ann=use_ann,
                        backend=backend).resolve(index.cfg, index.backend)


def build_index(key, corpus, cfg: LemurConfig, *, x_train: np.ndarray | None = None,
                verbose: bool = False) -> LemurIndex:
    """v0 shim: ``LemurRetriever.build(...).index``."""
    from repro.retriever import LemurRetriever

    return LemurRetriever.build(corpus, cfg, key=key, x_train=x_train,
                                verbose=verbose).index


def attach_backend(index: LemurIndex, backend: str, key=None,
                   cfg: LemurConfig | None = None) -> LemurIndex:
    """v0 shim: ``LemurRetriever(index).with_backend(...).index`` — re-point
    an existing index at a different first-stage backend without re-training
    ψ/W."""
    from repro.retriever import LemurRetriever

    return LemurRetriever(index).with_backend(backend, key=key, cfg=cfg).index


def add_docs(index: LemurIndex, doc_tokens, doc_mask, solver_state=None, *,
             seed: int = 0) -> LemurIndex:
    """v0 shim: ``LemurRetriever(index).add(...).index`` — incremental
    growth with the frozen-ψ OLS solver.  Pass the build-time
    ``solver_state`` for bit-exact W scales; otherwise the corpus-sampling
    fallback solver is seeded by the explicit ``seed`` (v0 hid a
    ``default_rng(0)`` here)."""
    from repro.retriever import LemurRetriever

    r = LemurRetriever(index, solver_state=solver_state)
    return r.add(doc_tokens, doc_mask, seed=seed).index


def query(index: LemurIndex, q_tokens, q_mask=None, *, k: int | None = None,
          k_prime: int | None = None, nprobe: int | None = None,
          use_ann: bool = True):
    """q_tokens: (B, Tq, d) -> (scores (B, k), doc_ids (B, k)).

    v0 shim over the pure Retriever-API pipeline (jit-able: the kwargs
    become a static, resolved ``SearchParams``).  ``use_ann=False`` forces
    the exact latent scan regardless of backend (the Fig. 3 "exact
    inference" arm)."""
    from repro.retriever.facade import search_pipeline

    params = _legacy_params(index, k=k, k_prime=k_prime, nprobe=nprobe,
                            use_ann=use_ann)
    if q_mask is None:
        q_mask = jnp.ones(q_tokens.shape[:2], bool)
    return search_pipeline(index, q_tokens, q_mask, params)


def candidates(index: LemurIndex, q_tokens, q_mask=None, *, k_prime: int,
               nprobe: int | None = None, use_ann: bool = False):
    """First-stage candidates only (for recall@k' ablations, Fig. 2 left)."""
    from repro.retriever.facade import first_stage

    params = _legacy_params(index, k_prime=k_prime, nprobe=nprobe,
                            use_ann=use_ann)
    if q_mask is None:
        q_mask = jnp.ones(q_tokens.shape[:2], bool)
    return first_stage(index, q_tokens, q_mask, params)
