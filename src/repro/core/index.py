"""LemurIndex: the Fig. 1 pipeline as one object.

build:  training-token selection (§4.2) -> ψ pre-training against m' sampled
        docs (§4.3) -> OLS output layer over the full corpus (eq. 7)
        -> first-stage index via the pluggable backend registry.
query:  Ψ(X) pooling -> first-stage candidates (any registered backend)
        -> exact MaxSim rerank -> top-k.

The first stage is index-agnostic (§3.2's "existing single-vector search
indexes"): ``cfg.anns`` names a backend in :mod:`repro.anns.registry`
(bruteforce | ivf | muvera | dessert | token_pruning) and ``LemurIndex``
holds its state as an opaque pytree.  Dispatch happens at trace time — the
backend name is a static Python string — so ``jax.jit(query)`` compiles
once per backend and the whole pool -> candidates -> rerank path stays one
XLA graph.
"""
from __future__ import annotations

import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.anns import registry
from repro.anns.base import CorpusView, QueryBatch
from repro.anns.bruteforce import mips_topk
from repro.core import indexer, maxsim
from repro.core.config import LemurConfig
from repro.core.model import TargetStats, pool_queries, train_phi


class LemurIndex(NamedTuple):
    cfg: LemurConfig
    psi: dict                 # feature-encoder params
    stats: TargetStats        # target standardization (App. A)
    W: jax.Array              # (m, d') latent doc vectors = OLS output layer
    doc_tokens: jax.Array     # (m, Td, d) for exact rerank
    doc_mask: jax.Array       # (m, Td)
    backend: str              # registered first-stage backend name
    ann: Any                  # opaque backend state (jax pytree)

    @property
    def m(self) -> int:
        return self.W.shape[0]


def build_index(key, corpus, cfg: LemurConfig, *, x_train: np.ndarray | None = None,
                verbose: bool = False) -> LemurIndex:
    """corpus: data.synthetic.MultiVectorCorpus (or any object with
    doc_tokens/doc_mask numpy arrays)."""
    t0 = time.time()
    keys = jax.random.split(key, 4)
    doc_tokens = jnp.asarray(corpus.doc_tokens)
    doc_mask = jnp.asarray(corpus.doc_mask)
    m = doc_tokens.shape[0]

    # 1. training tokens (§4.2)
    if x_train is None:
        x_train = indexer.make_training_tokens(corpus, cfg, seed=0)
    x_train = jnp.asarray(x_train)

    # 2. ψ pre-training against m' sampled documents (§4.3)
    m_pre = min(cfg.m_pretrain, m)
    pre_idx = jax.random.choice(keys[0], m, (m_pre,), replace=False)
    g_pre = maxsim.token_maxsim(x_train, doc_tokens[pre_idx], doc_mask[pre_idx])
    phi, stats, losses = train_phi(keys[1], x_train, g_pre, cfg)
    if verbose:
        print(f"[build] psi pretrain done ({time.time()-t0:.1f}s, loss {losses[-1]:.4f})")

    # 3. OLS output layer over the full corpus (eq. 7)
    n_ols = min(cfg.n_ols, x_train.shape[0])
    x_ols = x_train[jax.random.choice(keys[2], x_train.shape[0], (n_ols,), replace=False)]
    W = indexer.fit_output_layer_ols(phi["psi"], x_ols, doc_tokens, doc_mask, cfg, stats)
    if verbose:
        print(f"[build] OLS W ({m} docs) done ({time.time()-t0:.1f}s)")

    # 4. first-stage index via the backend registry
    backend = registry.canonical(cfg.anns)
    be = registry.get_backend(backend)
    ann = be.build(keys[3], CorpusView(W, doc_tokens, doc_mask), cfg)
    if verbose:
        print(f"[build] {backend} index complete ({time.time()-t0:.1f}s)")
    return LemurIndex(cfg, phi["psi"], stats, W, doc_tokens, doc_mask, backend, ann)


def attach_backend(index: LemurIndex, backend: str, key=None,
                   cfg: LemurConfig | None = None) -> LemurIndex:
    """Re-point an existing index at a different first-stage backend without
    re-training ψ/W (backends index W and/or the raw token matrices, both of
    which the index already holds).  Used by benchmarks to sweep backends
    over one trained reduction."""
    cfg = cfg or index.cfg
    backend = registry.canonical(backend)
    be = registry.get_backend(backend)
    if key is None:
        key = jax.random.PRNGKey(0)
    view = CorpusView(index.W, index.doc_tokens, index.doc_mask)
    return index._replace(cfg=cfg.replace(anns=backend), backend=backend,
                          ann=be.build(key, view, cfg))


def add_docs(index: LemurIndex, doc_tokens, doc_mask, solver_state=None) -> LemurIndex:
    """Incremental growth: fit new W rows with the frozen-ψ OLS solver
    (``indexer.ols_solver_state``) and push them into the first-stage backend
    via its ``add`` hook — ψ and existing rows are never touched (§4.3)."""
    doc_tokens = jnp.asarray(doc_tokens)
    doc_mask = jnp.asarray(doc_mask)
    if solver_state is None:
        # rebuild a solver from stored corpus tokens ("corpus" strategy);
        # pass the build-time solver_state for bit-exact W scales
        flat = np.asarray(index.doc_tokens)[np.asarray(index.doc_mask)]
        pick = np.random.default_rng(0).integers(
            0, flat.shape[0], size=min(index.cfg.n_ols, flat.shape[0]))
        solver_state = indexer.ols_solver_state(
            index.psi, jnp.asarray(flat[pick]), index.cfg)
    w_new = indexer.fit_docs(solver_state, doc_tokens, doc_mask, index.stats)
    be = registry.get_backend(index.backend)
    ann = be.add(index.ann, CorpusView(w_new, doc_tokens, doc_mask))
    return index._replace(
        W=jnp.concatenate([index.W, w_new], axis=0),
        doc_tokens=jnp.concatenate([index.doc_tokens, doc_tokens], axis=0),
        doc_mask=jnp.concatenate([index.doc_mask, doc_mask], axis=0),
        ann=ann,
    )


def _first_stage(index: LemurIndex, q_tokens, q_mask, k_prime: int,
                 nprobe: int | None, use_ann: bool):
    """Pool queries and run the selected backend (or the exact latent scan)."""
    psi_q = pool_queries(index.psi, q_tokens, q_mask)  # (B, d')
    if not use_ann:
        _, cand = mips_topk(psi_q, index.W, k_prime)
        return cand
    be = registry.get_backend(index.backend)
    over = be.defaults(index.cfg)
    if nprobe is not None:
        over["nprobe"] = nprobe
    over = {k: v for k, v in over.items() if v is not None}
    _, cand = be.search(index.ann, QueryBatch(psi_q, q_tokens, q_mask),
                        k_prime, **over)
    return cand


def query(index: LemurIndex, q_tokens, q_mask=None, *, k: int | None = None,
          k_prime: int | None = None, nprobe: int | None = None,
          use_ann: bool = True):
    """q_tokens: (B, Tq, d) -> (scores (B, k), doc_ids (B, k)).

    ``use_ann=False`` forces the exact latent scan regardless of backend
    (the Fig. 3 "exact inference" arm).  ``-1``-padded first-stage rows are
    masked inside ``maxsim.rerank`` — pads can never surface as results."""
    cfg = index.cfg
    k = k or cfg.k
    k_prime = k_prime or cfg.k_prime
    if q_mask is None:
        q_mask = jnp.ones(q_tokens.shape[:2], bool)
    cand = _first_stage(index, q_tokens, q_mask, k_prime, nprobe, use_ann)
    return maxsim.rerank(q_tokens, q_mask, cand, index.doc_tokens, index.doc_mask, k)


def candidates(index: LemurIndex, q_tokens, q_mask=None, *, k_prime: int,
               nprobe: int | None = None, use_ann: bool = False):
    """First-stage candidates only (for recall@k' ablations, Fig. 2 left)."""
    if q_mask is None:
        q_mask = jnp.ones(q_tokens.shape[:2], bool)
    return _first_stage(index, q_tokens, q_mask, k_prime, nprobe, use_ann)
