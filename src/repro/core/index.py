"""LemurIndex: the Fig. 1 pipeline as one object.

build:  training-token selection (§4.2) -> ψ pre-training against m' sampled
        docs (§4.3) -> OLS output layer over the full corpus (eq. 7)
        -> single-vector ANNS index over the rows of W.
query:  Ψ(X) pooling -> latent MIPS for k' candidates -> exact MaxSim rerank
        -> top-k.
"""
from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.anns import bruteforce, ivf
from repro.core import indexer, maxsim
from repro.core.config import LemurConfig
from repro.core.model import TargetStats, pool_queries, psi_apply, train_phi


class LemurIndex(NamedTuple):
    cfg: LemurConfig
    psi: dict                 # feature-encoder params
    stats: TargetStats        # target standardization (App. A)
    W: jax.Array              # (m, d') latent doc vectors = OLS output layer
    doc_tokens: jax.Array     # (m, Td, d) for exact rerank
    doc_mask: jax.Array       # (m, Td)
    ann: ivf.IVFIndex | None  # None => exact latent MIPS

    @property
    def m(self) -> int:
        return self.W.shape[0]


def build_index(key, corpus, cfg: LemurConfig, *, x_train: np.ndarray | None = None,
                verbose: bool = False) -> LemurIndex:
    """corpus: data.synthetic.MultiVectorCorpus (or any object with
    doc_tokens/doc_mask numpy arrays)."""
    t0 = time.time()
    keys = jax.random.split(key, 4)
    doc_tokens = jnp.asarray(corpus.doc_tokens)
    doc_mask = jnp.asarray(corpus.doc_mask)
    m = doc_tokens.shape[0]

    # 1. training tokens (§4.2)
    if x_train is None:
        x_train = indexer.make_training_tokens(corpus, cfg, seed=0)
    x_train = jnp.asarray(x_train)

    # 2. ψ pre-training against m' sampled documents (§4.3)
    m_pre = min(cfg.m_pretrain, m)
    pre_idx = jax.random.choice(keys[0], m, (m_pre,), replace=False)
    g_pre = maxsim.token_maxsim(x_train, doc_tokens[pre_idx], doc_mask[pre_idx])
    phi, stats, losses = train_phi(keys[1], x_train, g_pre, cfg)
    if verbose:
        print(f"[build] psi pretrain done ({time.time()-t0:.1f}s, loss {losses[-1]:.4f})")

    # 3. OLS output layer over the full corpus (eq. 7)
    n_ols = min(cfg.n_ols, x_train.shape[0])
    x_ols = x_train[jax.random.choice(keys[2], x_train.shape[0], (n_ols,), replace=False)]
    W = indexer.fit_output_layer_ols(phi["psi"], x_ols, doc_tokens, doc_mask, cfg, stats)
    if verbose:
        print(f"[build] OLS W ({m} docs) done ({time.time()-t0:.1f}s)")

    # 4. ANNS index over W
    ann = None
    if cfg.anns == "ivf":
        ann = ivf.build_ivf(keys[3], W, cfg.ivf_nlist, sq8=cfg.sq8)
    if verbose:
        print(f"[build] index complete ({time.time()-t0:.1f}s)")
    return LemurIndex(cfg, phi["psi"], stats, W, doc_tokens, doc_mask, ann)


def query(index: LemurIndex, q_tokens, q_mask=None, *, k: int | None = None,
          k_prime: int | None = None, nprobe: int | None = None,
          use_ann: bool = True):
    """q_tokens: (B, Tq, d) -> (scores (B, k), doc_ids (B, k))."""
    cfg = index.cfg
    k = k or cfg.k
    k_prime = k_prime or cfg.k_prime
    if q_mask is None:
        q_mask = jnp.ones(q_tokens.shape[:2], bool)

    psi_q = pool_queries(index.psi, q_tokens, q_mask)  # (B, d')
    if use_ann and index.ann is not None:
        _, cand = ivf.search_ivf(index.ann, psi_q, nprobe or cfg.ivf_nprobe, k_prime)
        cand = jnp.maximum(cand, 0)  # -1 pads -> doc 0 (dup-safe: rerank dedups by score)
    else:
        _, cand = bruteforce.mips_topk(psi_q, index.W, k_prime)
    return maxsim.rerank(q_tokens, q_mask, cand, index.doc_tokens, index.doc_mask, k)


def candidates(index: LemurIndex, q_tokens, q_mask=None, *, k_prime: int,
               nprobe: int | None = None, use_ann: bool = False):
    """First-stage candidates only (for recall@k' ablations, Fig. 2 left)."""
    if q_mask is None:
        q_mask = jnp.ones(q_tokens.shape[:2], bool)
    psi_q = pool_queries(index.psi, q_tokens, q_mask)
    if use_ann and index.ann is not None:
        _, cand = ivf.search_ivf(index.ann, psi_q, nprobe or index.cfg.ivf_nprobe, k_prime)
        return cand
    _, cand = bruteforce.mips_topk(psi_q, index.W, k_prime)
    return cand
