"""The paper's primary contribution: LEMUR — learned multi-vector retrieval.

Two problem reductions (DESIGN.md §1):
  1. multi-vector search -> supervised multi-output regression (model.py)
  2. inference under that model -> single-vector MIPS in latent space
     (indexer.py learns W rows = latent doc vectors; index.py serves).
"""
from repro.core.config import LemurConfig
from repro.core.index import LemurIndex, build_index
from repro.core.maxsim import (
    maxsim_pair,
    maxsim_scores,
    recall_at,
    rerank,
    token_maxsim,
    true_topk,
)
from repro.core.model import init_phi, init_psi, pool_queries, psi_apply, train_phi
from repro.core.indexer import fit_output_layer_ols, make_training_tokens

__all__ = [
    "LemurConfig",
    "LemurIndex",
    "build_index",
    "maxsim_pair",
    "maxsim_scores",
    "recall_at",
    "rerank",
    "token_maxsim",
    "true_topk",
    "init_psi",
    "init_phi",
    "pool_queries",
    "psi_apply",
    "train_phi",
    "fit_output_layer_ols",
    "make_training_tokens",
]
