"""Paged corpus memory: fixed-size token pages + per-doc indirection.

The dense ``(N, Td, d)`` corpus layout made streaming ``add()`` an O(N)
``jnp.concatenate`` and made ``delete``/``update`` impossible — every growth
changed the corpus array shapes, so every compiled query fn died with them.
This module rebuilds corpus storage on the paged-KV serving idiom
(vLLM/flashinfer ``NUM_TOKENS_IN_BLOCK``: a pool of fixed-size token pages,
per-sequence page tables fed to kernels):

* ``tok_pages (P, page, d)`` — the page pool.  Each page holds
  ``TOKENS_PER_PAGE`` compacted (mask-stripped) token embeddings; a doc's
  tokens span ``ceil(n_tokens / page)`` pages, the last one zero-padded.
* ``page_table (C, pmax)`` + ``n_tokens (C,)`` — per-doc-slot indirection:
  which pages, how many real tokens.  ``-1`` pads unused table entries.
* ``W (C, d')`` — the OLS latent rows, slot-aligned (dead slots zeroed).
* ``alive (C,)`` — tombstone mask.  ``delete()`` returns pages to the free
  list and flips this bit; the first-stage backends are never rebuilt, so
  stale candidates are filtered by :func:`mask_dead` after every first stage.
* ``n_docs (1,)`` — the slot high-water mark, kept as an int32 ARRAY leaf
  (not static aux) so growing the corpus does not retrace compiled fns.

Doc ids are **stable**: the external id IS the slot index, slots are
allocated monotonically and never reused, and only PAGES return to the free
list.  (Backends number docs by arrival order, so slot numbering and
backend numbering coincide by construction — the invariant that lets
tombstone masking work without ever rebuilding a backend.)

All shapes — pool size ``P``, slot capacity ``C``, pages-per-doc ``pmax``,
and the page size itself — are jit-static and grow in power-of-two buckets
with amortized doubling, so an ``add()`` that fits the pre-grown pool
changes NO shapes and compiled query fns survive it (the compile key gains
only the capacity bucket).  Compacting tokens into pages is *exact* for
MaxSim: per-token dot products are unchanged and the per-query-token max
over a doc's tokens is order-independent, so paged scores are bit-identical
to the dense layout's.

Mutation entry points (:func:`from_dense`, :func:`add_docs`,
:func:`delete_docs`) are host-side (concrete arrays) and return the bytes
they logically moved — the accounting ``benchmarks/serving_online.py`` gates
on (paged bytes-per-add must be O(doc), not O(corpus)).  The traced helpers
(:func:`gather_docs`, :func:`mask_dead`) are jit-safe and feed the query
pipeline.

**Compressed tier** (``codec=...``): the same page/slot machinery can store
tokens as a ColBERTv2-style residual code instead of fp32 — a per-token
centroid id (``cent_pages``) plus a 2/4-bit packed residual
(``code_pages``), with the trained :class:`~repro.anns.quantization.
ResidualCodec` riding along as pytree leaves.  Slot ids, tombstones,
page accounting, and the in-capacity zero-retrace mutation contract are
IDENTICAL to the fp32 tier; only the page payload changes.  Index-time
constant-space pooling (:func:`pool_tokens`) caps every doc at a fixed
token budget before pagination, so corpus memory is bounded per doc.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.anns.quantization import (
    ResidualCodec,
    residual_decode,
    residual_encode,
)

TOKENS_PER_PAGE = 16   # power of two — the paged-KV NUM_TOKENS_IN_BLOCK
MIN_CAPACITY = 8       # smallest doc-slot bucket
_ITEM = 4              # fp32 / int32 bytes, the accounting unit


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1)."""
    return 1 if n <= 1 else 1 << int(n - 1).bit_length()


class PagedStore(NamedTuple):
    """The paged corpus: a pure jax pytree, safe to pass as a jit ARGUMENT
    (which is how compiled query fns survive mutation — see facade)."""

    tok_pages: jax.Array   # (P, page, d)  fp32 compacted token embeddings
    page_table: jax.Array  # (C, pmax)     int32 page ids, -1 padded
    n_tokens: jax.Array    # (C,)          int32 real tokens per slot
    W: jax.Array           # (C, d')       latent rows (dead slots zeroed)
    alive: jax.Array       # (C,)          bool tombstone mask
    n_docs: jax.Array      # (1,)          int32 slot high-water mark
    # compressed tier (None on the fp32 tier; tok_pages is then (P, page, 0))
    cent_pages: jax.Array | None = None   # (P, page)      int32 centroid ids
    code_pages: jax.Array | None = None   # (P, page, db)  uint8 packed residuals
    codec: ResidualCodec | None = None    # trained codec tables (pytree leaves)

    # shape-derived introspection (trace-safe: static under jit)
    @property
    def n_pages(self) -> int:
        return self.tok_pages.shape[0]

    @property
    def page(self) -> int:
        return self.tok_pages.shape[1]

    @property
    def d(self) -> int:
        if self.codec is not None:
            return self.codec.d
        return self.tok_pages.shape[2]

    @property
    def residual(self) -> bool:
        """True when tokens live in the compressed (codec) tier."""
        return self.codec is not None

    @property
    def capacity(self) -> int:
        return self.page_table.shape[0]

    @property
    def pages_per_doc(self) -> int:
        return self.page_table.shape[1]

    @property
    def td_max(self) -> int:
        return self.page_table.shape[1] * self.tok_pages.shape[1]

    @property
    def d_prime(self) -> int:
        return self.W.shape[1]


# --------------------------------------------------------------------------
# host-side mutation (concrete arrays; returns logical bytes moved)
# --------------------------------------------------------------------------

def _paginate(doc_mask, page: int, pmax: int, flats: list):
    """Compact n docs' per-token payloads into page-sized chunks (host).

    ``flats``: arrays ``(k, ...)`` over the k VALID tokens in doc-major
    order (one per payload stream — fp32 tokens, or centroid ids + packed
    residual codes).  Returns ``(chunks [one (need, page, ...) array per
    payload], local_table (n, pmax) int32 of LOCAL chunk indices or -1,
    counts (n,) int32)`` — callers map local chunk indices through their
    page allocation."""
    dm = np.asarray(doc_mask, bool)
    counts = dm.sum(axis=1).astype(np.int64)
    ppd = -(-counts // page)                       # pages per doc (0 if empty)
    if int(ppd.max(initial=0)) > pmax:
        raise ValueError(
            f"doc needs {int(ppd.max())} pages > pmax={pmax} (caller grows)")
    starts = np.concatenate([[0], np.cumsum(ppd)[:-1]]).astype(np.int64)
    need = int(ppd.sum())
    j = np.arange(pmax, dtype=np.int64)[None, :]
    local = np.where(j < ppd[:, None], starts[:, None] + j, -1).astype(np.int32)
    if need:
        tok_start = np.concatenate([[0], np.cumsum(counts)[:-1]])
        t = np.arange(int(counts.sum())) - np.repeat(tok_start, counts)
        rows = np.repeat(starts, counts) + t // page
        cols = t % page
    chunks = []
    for f in flats:
        f = np.asarray(f)
        out = np.zeros((need, page) + f.shape[1:], f.dtype)
        if need:
            out[rows, cols] = f
        chunks.append(out)
    return chunks, local, counts.astype(np.int32)


def _encode_flat(codec: ResidualCodec, flat: np.ndarray):
    """fp32 valid tokens (k, d) -> [cent (k,) int32, packed (k, db) uint8]."""
    cid, packed = residual_encode(codec, jnp.asarray(flat, jnp.float32))
    return [np.asarray(cid, np.int32), np.asarray(packed, np.uint8)]


def from_dense(W, doc_tokens, doc_mask, *, page: int = TOKENS_PER_PAGE,
               min_capacity: int = MIN_CAPACITY,
               codec: ResidualCodec | None = None):
    """Build a :class:`PagedStore` from the dense padded layout.

    With ``codec`` the tokens are residual-encoded into the compressed tier
    (``cent_pages``/``code_pages``; ``tok_pages`` keeps a zero-width fp32
    pool so every shape property still derives from it).  Returns
    ``(store, bytes_moved)`` — the one-time O(corpus) build cost.  The free
    list is derivable (:func:`free_list`), so it is not threaded through
    immutable index snapshots."""
    W = np.asarray(W)
    m = W.shape[0]
    dt = np.asarray(doc_tokens, np.float32)
    dm = np.asarray(doc_mask, bool)
    d = dt.shape[2]
    counts = dm.sum(axis=1)
    pmax = max(1, int((-(-counts // page)).max(initial=1)))
    flat = dt[dm]
    flats = [flat] if codec is None else _encode_flat(codec, flat)
    chunks, local, counts = _paginate(dm, page, pmax, flats)
    need = chunks[0].shape[0]
    C = max(min_capacity, next_pow2(m))
    P = next_pow2(max(1, need))
    table = np.full((C, pmax), -1, np.int32)
    table[:m] = local                               # local idx == page id here
    ntok = np.zeros((C,), np.int32)
    ntok[:m] = counts
    Wc = np.zeros((C, W.shape[1]), W.dtype)
    Wc[:m] = W
    alive = np.zeros((C,), bool)
    alive[:m] = True
    extra = {}
    if codec is None:
        pool = np.zeros((P, page, d), np.float32)
        pool[:need] = chunks[0]
    else:
        pool = np.zeros((P, page, 0), np.float32)
        cent_pool = np.zeros((P, page), np.int32)
        cent_pool[:need] = chunks[0]
        code_pool = np.zeros((P, page, chunks[1].shape[-1]), np.uint8)
        code_pool[:need] = chunks[1]
        extra = dict(cent_pages=jnp.asarray(cent_pool),
                     code_pages=jnp.asarray(code_pool), codec=codec)
    store = PagedStore(jnp.asarray(pool), jnp.asarray(table),
                       jnp.asarray(ntok), jnp.asarray(Wc),
                       jnp.asarray(alive),
                       jnp.asarray([m], dtype=jnp.int32), **extra)
    moved = (sum(c.nbytes for c in chunks) + table.nbytes + ntok.nbytes
             + Wc.nbytes + alive.nbytes)
    return store, moved


def pool_tokens(doc_tokens, doc_mask, budget: int):
    """Index-time constant-space token pooling (PAPERS.md: Efficient
    Constant-Space Multi-Vector Retrieval): hierarchically cluster-pool each
    doc's token embeddings down to a fixed per-doc ``budget``.

    Deterministic (greedy closest-pair agglomeration, count-weighted means,
    first-index tie-break) and host-side — pooling happens once at
    index/add time, never on the query path.  Returns
    ``(pooled (n, min(T, budget), d) fp32, mask)``; ``budget <= 0`` is a
    no-op passthrough."""
    dt = np.asarray(doc_tokens, np.float32)
    dm = np.asarray(doc_mask, bool)
    if budget <= 0 or dt.shape[1] <= budget:
        return dt, dm
    n, T, d = dt.shape
    tp = min(T, budget)
    out = np.zeros((n, tp, d), np.float32)
    om = np.zeros((n, tp), bool)
    for i in range(n):
        toks = dt[i][dm[i]]
        if toks.shape[0] > budget:
            toks = _pool_one(toks, budget)
        t = toks.shape[0]
        out[i, :t] = toks
        om[i, :t] = True
    return out, om


def _pool_one(toks: np.ndarray, budget: int) -> np.ndarray:
    """Agglomerate one doc's (t, d) tokens to ``budget`` count-weighted
    means by repeatedly merging the closest pair (squared Euclidean)."""
    reps = toks.astype(np.float64)
    w = np.ones(len(reps))
    alive = np.ones(len(reps), bool)
    while int(alive.sum()) > budget:
        idx = np.flatnonzero(alive)
        sub = reps[idx]
        sq = np.sum(np.square(sub), axis=1)
        d2 = sq[:, None] + sq[None, :] - 2.0 * (sub @ sub.T)
        iu = np.triu_indices(len(idx), k=1)
        flatpos = np.argmin(d2[iu])
        a, b = iu[0][flatpos], iu[1][flatpos]
        i, j = int(idx[a]), int(idx[b])
        reps[i] = (w[i] * reps[i] + w[j] * reps[j]) / (w[i] + w[j])
        w[i] += w[j]
        alive[j] = False
    return reps[alive].astype(np.float32)


def token_bytes(store: PagedStore) -> int:
    """Resident bytes of the token payload: the fp32 page pool, or the
    compressed tier's id/code pools plus the (corpus-amortized) codec
    tables.  The recall bench's bytes-per-doc column divides this by the
    live doc count."""
    if store.codec is not None:
        tables = sum(int(np.asarray(x).nbytes) for x in store.codec)
        return int(store.cent_pages.nbytes + store.code_pages.nbytes) + tables
    return int(store.tok_pages.nbytes)


# --------------------------------------------------------------------------
# mutation taps (observability seam for the index lifecycle)
# --------------------------------------------------------------------------

_MUTATION_TAPS: list = []


def register_mutation_tap(fn) -> None:
    """Subscribe ``fn(kind, ids, **payload)`` to every store mutation.

    ``kind`` is ``"add"`` (payload: ``doc_tokens``, ``doc_mask``, ``w`` —
    host numpy views of the NEW docs only) or ``"delete"`` (ids only).
    Taps run synchronously on the mutating thread AFTER the store is
    updated; they must be cheap and must never raise (exceptions are
    swallowed so a broken observer cannot corrupt a mutation barrier).
    This is the reservoir feed for ``lifecycle.DriftMonitor``."""
    if fn not in _MUTATION_TAPS:
        _MUTATION_TAPS.append(fn)


def unregister_mutation_tap(fn) -> None:
    try:
        _MUTATION_TAPS.remove(fn)
    except ValueError:
        pass


def _notify_taps(kind: str, ids, **payload) -> None:
    for fn in list(_MUTATION_TAPS):
        try:
            fn(kind, ids, **payload)
        except Exception:
            pass


def free_list(store: PagedStore) -> list[int]:
    """Ascending free page ids: the complement of the referenced pages.
    Deterministic, so snapshots/checkpoints never persist the allocator."""
    used = np.asarray(store.page_table).ravel()
    mask = np.ones(store.n_pages, bool)
    mask[used[used >= 0]] = False
    return np.flatnonzero(mask).tolist()


def add_docs(store: PagedStore, free_pages: list[int], w_new, doc_tokens,
             doc_mask):
    """Allocate pages for n new docs into slots ``[m, m+n)``.

    Returns ``(store, free_pages, new_ids (n,) int32, bytes_moved)``.
    When the new docs fit the pre-grown pool/capacity, no array changes
    shape — compiled query fns taking the store as an argument survive.
    Growth (capacity, pool, or pages-per-doc) pads in power-of-two buckets
    with amortized doubling and bills the copy it forces."""
    dt = np.asarray(doc_tokens, np.float32)
    dm = np.asarray(doc_mask, bool)
    n = dt.shape[0]
    if n == 0:
        return store, list(free_pages), np.empty((0,), np.int32), 0
    m = int(store.n_docs[0])
    page = store.page
    moved = 0

    # 1. pages-per-doc bucket (only a doc LONGER than any before grows it)
    pmax = store.pages_per_doc
    need_pmax = int((-(-dm.sum(axis=1) // page)).max(initial=1))
    if need_pmax > pmax:
        new_pmax = next_pow2(need_pmax)
        moved += store.page_table.size * _ITEM
        store = store._replace(page_table=jnp.pad(
            store.page_table, ((0, 0), (0, new_pmax - pmax)),
            constant_values=-1))
        pmax = new_pmax

    # 2. doc-slot capacity bucket
    C = store.capacity
    if m + n > C:
        newC = max(next_pow2(m + n), 2 * C)
        moved += (store.page_table.nbytes + store.n_tokens.nbytes
                  + store.W.nbytes + store.alive.nbytes)
        store = store._replace(
            page_table=jnp.pad(store.page_table, ((0, newC - C), (0, 0)),
                               constant_values=-1),
            n_tokens=jnp.pad(store.n_tokens, (0, newC - C)),
            W=jnp.pad(store.W, ((0, newC - C), (0, 0))),
            alive=jnp.pad(store.alive, (0, newC - C)),
        )

    # 3. page-pool bucket (amortized doubling)
    flat = dt[dm]
    flats = [flat] if store.codec is None else _encode_flat(store.codec, flat)
    chunks, local, counts = _paginate(dm, page, pmax, flats)
    need = chunks[0].shape[0]
    free_pages = list(free_pages)
    if need > len(free_pages):
        P = store.n_pages
        newP = max(next_pow2(P - len(free_pages) + need), 2 * P)
        moved += store.tok_pages.nbytes
        grown = dict(tok_pages=jnp.pad(
            store.tok_pages, ((0, newP - P), (0, 0), (0, 0))))
        if store.codec is not None:
            moved += store.cent_pages.nbytes + store.code_pages.nbytes
            grown.update(
                cent_pages=jnp.pad(store.cent_pages, ((0, newP - P), (0, 0))),
                code_pages=jnp.pad(store.code_pages,
                                   ((0, newP - P), (0, 0), (0, 0))))
        store = store._replace(**grown)
        free_pages.extend(range(P, newP))

    # 4. allocate (lowest page ids first — deterministic) and scatter
    alloc = np.asarray(free_pages[:need], np.int32)
    free_pages = free_pages[need:]
    table_rows = np.where(local >= 0, alloc[np.maximum(local, 0)],
                          -1).astype(np.int32)
    ids = np.arange(m, m + n, dtype=np.int32)
    pools = {}
    if need:
        ja = jnp.asarray(alloc)
        if store.codec is None:
            pools["tok_pages"] = store.tok_pages.at[ja].set(
                jnp.asarray(chunks[0]))
        else:
            pools["cent_pages"] = store.cent_pages.at[ja].set(
                jnp.asarray(chunks[0]))
            pools["code_pages"] = store.code_pages.at[ja].set(
                jnp.asarray(chunks[1]))
    store = store._replace(
        page_table=store.page_table.at[m:m + n].set(jnp.asarray(table_rows)),
        n_tokens=store.n_tokens.at[m:m + n].set(jnp.asarray(counts)),
        W=store.W.at[m:m + n].set(jnp.asarray(w_new, store.W.dtype)),
        alive=store.alive.at[m:m + n].set(True),
        n_docs=jnp.asarray([m + n], dtype=jnp.int32),
        **pools,
    )
    # logical write set: the new pages + the touched table/W/count rows.
    # O(doc), never O(corpus) — the property the serving bench gates on.
    moved += (sum(c.nbytes for c in chunks) + table_rows.nbytes + counts.nbytes
              + n * store.d_prime * _ITEM + n + _ITEM)
    if _MUTATION_TAPS:
        _notify_taps("add", ids, doc_tokens=dt, doc_mask=dm,
                     w=np.asarray(w_new, np.float32))
    return store, free_pages, ids, moved


def delete_docs(store: PagedStore, free_pages: list[int], doc_ids):
    """Tombstone slots and return their pages to the free list.

    Slots are never reused (ids stay stable); ``W`` rows are zeroed so a
    dead slot can never win a latent scan even unmasked.  Raises
    ``ValueError`` on unknown, already-deleted, or duplicate ids.
    Returns ``(store, free_pages, bytes_moved)``."""
    ids = np.asarray(doc_ids, np.int64).ravel()
    if ids.size == 0:
        return store, list(free_pages), 0
    m = int(store.n_docs[0])
    alive = np.asarray(store.alive)
    if np.unique(ids).size != ids.size:
        raise ValueError(f"duplicate doc ids in delete: {ids.tolist()}")
    bad = ids[(ids < 0) | (ids >= m)]
    if bad.size:
        raise ValueError(f"unknown doc ids {bad.tolist()} (n_docs={m})")
    dead = ids[~alive[ids]]
    if dead.size:
        raise ValueError(f"doc ids already deleted: {dead.tolist()}")
    rows = np.asarray(store.page_table)[ids]
    freed = rows[rows >= 0].tolist()
    free_pages = sorted(list(free_pages) + freed)
    jids = jnp.asarray(ids, jnp.int32)
    store = store._replace(
        page_table=store.page_table.at[jids].set(-1),
        n_tokens=store.n_tokens.at[jids].set(0),
        W=store.W.at[jids].set(0),
        alive=store.alive.at[jids].set(False),
    )
    moved = int(ids.size) * (store.pages_per_doc * _ITEM + _ITEM
                             + store.d_prime * _ITEM + 1)
    if _MUTATION_TAPS:
        _notify_taps("delete", ids.astype(np.int32))
    return store, free_pages, moved


def dense_add_bytes(m_total: int, td: int, d: int, d_prime: int) -> int:
    """What ONE flat-layout add used to write: the full concatenated corpus
    (`jnp.concatenate` materializes all three outputs) — the O(corpus)
    baseline the amortization bench compares against."""
    return m_total * td * d * _ITEM + m_total * td + m_total * d_prime * _ITEM


# --------------------------------------------------------------------------
# traced helpers (jit-safe; feed the query pipeline)
# --------------------------------------------------------------------------

def mask_dead(store: PagedStore, cand_ids):
    """Tombstone filter: candidate ids of deleted slots -> ``-1``.

    Applied after EVERY first stage — backends are never rebuilt on delete,
    so they keep emitting stale ids; this is the single choke point that
    guarantees a deleted doc never surfaces (fused and legacy paths both
    treat ``-1`` as NEG-scored pad)."""
    safe = jnp.maximum(cand_ids, 0)
    ok = (cand_ids >= 0) & jnp.take(store.alive, safe, axis=0)
    return jnp.where(ok, cand_ids, -1)


def gather_docs(store: PagedStore, doc_ids):
    """Materialize docs from pages: ``(...,) int32`` slot ids ->
    ``(tokens (..., pmax*page, d), mask (..., pmax*page) bool)``.

    ``-1`` (or dead) ids yield an all-False mask and zeroed tokens.  This
    is the legacy-gather twin of the paged rerank kernel — identical token
    values in identical positions, so scores agree bit for bit.

    On the compressed tier the tokens are residual-DECODED on the fly
    (pure jnp, jit-safe): callers always see fp32 ``(…, td_max, d)``
    tokens, whichever tier backs them."""
    doc_ids = jnp.asarray(doc_ids)
    safe = jnp.maximum(doc_ids, 0)
    table = jnp.take(store.page_table, safe, axis=0)       # (..., pmax)
    nt = jnp.take(store.n_tokens, safe, axis=0)            # (...,)
    nt = jnp.where(doc_ids >= 0, nt, 0)
    safe_pg = jnp.maximum(table, 0)
    if store.codec is not None:
        cent = jnp.take(store.cent_pages, safe_pg, axis=0)   # (..., pmax, page)
        codes = jnp.take(store.code_pages, safe_pg, axis=0)  # (..., pmax, pg, db)
        cent = cent.reshape(doc_ids.shape + (store.td_max,))
        codes = codes.reshape(doc_ids.shape + (store.td_max,
                                               codes.shape[-1]))
        toks = residual_decode(store.codec, cent, codes)
    else:
        toks = jnp.take(store.tok_pages, safe_pg, axis=0)
        toks = toks.reshape(doc_ids.shape + (store.td_max, store.d))
    pos = jnp.arange(store.td_max, dtype=jnp.int32)
    mask = pos < nt[..., None]
    return toks * mask[..., None], mask
