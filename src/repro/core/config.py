"""LEMUR configuration (paper App. A defaults)."""
from __future__ import annotations

import dataclasses

from repro.common.config import ConfigBase


@dataclasses.dataclass(frozen=True)
class LemurConfig(ConfigBase):
    d: int = 128                 # token embedding dim (ColBERTv2: 128)
    d_prime: int = 2048          # latent dim d' (ablated 1024/2048/4096, §6.2)
    m_pretrain: int = 8192       # m': sampled docs as pretraining targets
    n_train: int = 100_000       # n: token embeddings in the MLP training set
    n_ols: int = 16_384          # n': tokens for the OLS solutions
    lr: float = 3e-3
    epochs: int = 100
    batch_size: int = 512
    grad_clip: float = 0.5
    ridge: float = 1e-4          # OLS regularizer (numerical; paper uses plain OLS)
    query_strategy: str = "corpus-query"  # corpus-query | corpus | query (§4.2)
    k: int = 100                 # final top-k
    k_prime: int = 1024          # candidates to rerank
    anns: str = "ivf"            # first-stage backend name (anns/registry.py):
                                 # bruteforce|ivf|muvera|dessert|token_pruning
                                 # ("exact" = legacy alias for bruteforce)
    ivf_nlist: int = 0           # 0 => 16*sqrt(m) rounded down to pow2 (paper's rule)
    ivf_nprobe: int = 32
    sq8: bool = True             # scalar-quantize the latent corpus (Glass-style)
    # baseline-backend knobs (used only when `anns` selects that backend)
    dessert_tables: int = 32     # DESSERT L
    dessert_bits: int = 5        # DESSERT C -> 2^C buckets
    muvera_r_reps: int = 20      # MUVERA R
    muvera_k_sim: int = 5        # MUVERA k_sim
    muvera_final_dim: int = 1280
    tp_nlist: int = 0            # token pruning: 0 => PLAID 16*sqrt(n) rule
    tp_nprobe: int = 8
    rerank_block: int = 1024     # docs per MaxSim rerank tile
    score_dtype: str = "float32"

    def __post_init__(self):
        from repro.anns import registry  # late: keeps config import-light

        known = set(registry.list_backends()) | {"exact"}
        if self.anns not in known:
            raise ValueError(
                f"anns={self.anns!r} is not a registered backend; "
                f"known: {sorted(known)}"
            )
