"""LEMUR configuration (paper App. A defaults)."""
from __future__ import annotations

import dataclasses

from repro.common.config import ConfigBase


@dataclasses.dataclass(frozen=True)
class LemurConfig(ConfigBase):
    d: int = 128                 # token embedding dim (ColBERTv2: 128)
    d_prime: int = 2048          # latent dim d' (ablated 1024/2048/4096, §6.2)
    m_pretrain: int = 8192       # m': sampled docs as pretraining targets
    n_train: int = 100_000       # n: token embeddings in the MLP training set
    n_ols: int = 16_384          # n': tokens for the OLS solutions
    lr: float = 3e-3
    epochs: int = 100
    batch_size: int = 512
    grad_clip: float = 0.5
    ridge: float = 1e-4          # OLS regularizer (numerical; paper uses plain OLS)
    query_strategy: str = "corpus-query"  # corpus-query | corpus | query (§4.2)
    k: int = 100                 # final top-k
    k_prime: int = 1024          # candidates to rerank
    anns: str = "ivf"            # ivf | exact  (HNSW/Glass -> IVF on TPU, DESIGN §3)
    ivf_nlist: int = 0           # 0 => 16*sqrt(m) rounded down to pow2 (paper's rule)
    ivf_nprobe: int = 32
    sq8: bool = True             # scalar-quantize the latent corpus (Glass-style)
    rerank_block: int = 1024     # docs per MaxSim rerank tile
    score_dtype: str = "float32"
