"""LEMUR configuration (paper App. A defaults).

Retriever API v1 splits the config into a build-time core (the reduction:
d', training sizes, OLS) plus one **per-backend namespace** per registered
first-stage backend (``cfg.ivf``, ``cfg.muvera``, ``cfg.dessert``,
``cfg.token_pruning``, ``cfg.bruteforce`` — the ``config_cls`` types
registered alongside each backend in :mod:`repro.anns.registry`):

    cfg = LemurConfig(anns="ivf", ivf=IVFBackendConfig(nprobe=64, sq8=True))
    cfg.backend_config()          # -> the active backend's namespace
    cfg.override({"ivf.nprobe": 16})   # dotted CLI overrides reach inside

The v0 flat knobs (``ivf_nprobe``, ``sq8``, ``dessert_tables``, …) keep
working as **deprecated aliases**: constructor kwargs and ``replace()`` keys
are folded into the matching namespace, and attribute reads forward to it —
both emit a ``DeprecationWarning`` naming the replacement.
"""
from __future__ import annotations

import dataclasses
import warnings

from repro.anns.params import (
    BruteforceBackendConfig,
    DessertBackendConfig,
    IVFBackendConfig,
    MuveraBackendConfig,
    ResidualConfig,
    TokenPruningBackendConfig,
)
from repro.common.config import ConfigBase

# v0 flat knob -> (namespace field, field inside it)
_LEGACY_KNOBS = {
    "ivf_nlist": ("ivf", "nlist"),
    "ivf_nprobe": ("ivf", "nprobe"),
    "sq8": ("ivf", "sq8"),
    "dessert_tables": ("dessert", "tables"),
    "dessert_bits": ("dessert", "bits"),
    "muvera_r_reps": ("muvera", "r_reps"),
    "muvera_k_sim": ("muvera", "k_sim"),
    "muvera_final_dim": ("muvera", "final_dim"),
    "tp_nlist": ("token_pruning", "nlist"),
    "tp_nprobe": ("token_pruning", "nprobe"),
}


@dataclasses.dataclass(frozen=True)
class LemurConfig(ConfigBase):
    d: int = 128                 # token embedding dim (ColBERTv2: 128)
    d_prime: int = 2048          # latent dim d' (ablated 1024/2048/4096, §6.2)
    m_pretrain: int = 8192       # m': sampled docs as pretraining targets
    n_train: int = 100_000       # n: token embeddings in the MLP training set
    n_ols: int = 16_384          # n': tokens for the OLS solutions
    lr: float = 3e-3
    epochs: int = 100
    batch_size: int = 512
    grad_clip: float = 0.5
    ridge: float = 1e-4          # OLS regularizer (numerical; paper uses plain OLS)
    query_strategy: str = "corpus-query"  # corpus-query | corpus | query (§4.2)
    k: int = 100                 # final top-k
    k_prime: int = 1024          # candidates to rerank
    anns: str = "ivf"            # first-stage backend name (anns/registry.py):
                                 # bruteforce|ivf|muvera|dessert|token_pruning
                                 # ("exact" = legacy alias for bruteforce)
    # per-backend namespaces (used only when `anns` selects that backend)
    bruteforce: BruteforceBackendConfig = BruteforceBackendConfig()
    ivf: IVFBackendConfig = IVFBackendConfig()
    muvera: MuveraBackendConfig = MuveraBackendConfig()
    dessert: DessertBackendConfig = DessertBackendConfig()
    token_pruning: TokenPruningBackendConfig = TokenPruningBackendConfig()
    # compressed token-corpus tier (codec + constant-space pooling): OFF by
    # default — fp32 paged store; enabling is a BUILD-time decision (the
    # corpus must be encoded), use_residual on SearchParams only selects
    # which store a compiled query fn reads
    residual: ResidualConfig = ResidualConfig()
    rerank_block: int = 1024     # docs per MaxSim rerank tile
    use_fused_gather: bool = True  # candidate-gather rerank through the
                                   # gather-at-source kernel path (kernels.
                                   # gather_scan); False = legacy HBM gather.
                                   # The IVF probe-scan twin lives in
                                   # cfg.ivf.use_fused_gather.
    use_one_launch: bool = False   # fuse the pre-rerank first stage (ψ-pool +
                                   # scan + top-k') into ONE kernel launch
                                   # (kernels.query_fused) for the exact scan
                                   # and the sharded serve step.  The IVF twin
                                   # lives in cfg.ivf.use_one_launch.
    score_dtype: str = "float32"

    def __post_init__(self):
        from repro.anns import registry  # late: keeps config import-light

        known = set(registry.list_backends()) | {"exact"}
        if self.anns not in known:
            raise ValueError(
                f"anns={self.anns!r} is not a registered backend; "
                f"known: {sorted(known)}"
            )

    def backend_config(self, name: str | None = None):
        """The config namespace for ``name`` (default: the active backend)."""
        from repro.anns import registry

        return getattr(self, registry.canonical(name or self.anns))

    @classmethod
    def from_dict(cls, d: dict) -> "LemurConfig":
        # v0 dicts/JSON carry the flat knobs as top-level keys; fold them
        # through the same deprecation path as constructor kwargs instead of
        # silently dropping them (ConfigBase.from_dict skips unknown keys)
        d = dict(d)
        legacy = {k: d.pop(k) for k in list(d) if k in _LEGACY_KNOBS}
        cfg = super().from_dict(d)
        return cfg.replace(**legacy) if legacy else cfg

    def __getattr__(self, name: str):
        # read-compat for the v0 flat knobs: cfg.ivf_nprobe -> cfg.ivf.nprobe
        if name in _LEGACY_KNOBS:
            sub, field = _LEGACY_KNOBS[name]
            warnings.warn(
                f"LemurConfig.{name} is deprecated; read cfg.{sub}.{field}",
                DeprecationWarning, stacklevel=2)
            return getattr(getattr(self, sub), field)
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {name!r}")


def _fold_legacy_kwargs(kwargs: dict) -> dict:
    """Fold v0 flat knobs into their namespace (deprecation path)."""
    legacy = {k: kwargs.pop(k) for k in list(kwargs) if k in _LEGACY_KNOBS}
    if not legacy:
        return kwargs
    warnings.warn(
        "flat LemurConfig knobs are deprecated: "
        + ", ".join(f"{k} -> {_LEGACY_KNOBS[k][0]}.{_LEGACY_KNOBS[k][1]}"
                    for k in legacy),
        DeprecationWarning, stacklevel=3)
    for k, v in legacy.items():
        sub, field = _LEGACY_KNOBS[k]
        base = kwargs.get(sub, LemurConfig.__dataclass_fields__[sub].default)
        kwargs[sub] = base.replace(**{field: v})
    return kwargs


_dataclass_init = LemurConfig.__init__


def _compat_init(self, *args, **kwargs):
    _dataclass_init(self, *args, **_fold_legacy_kwargs(kwargs))


LemurConfig.__init__ = _compat_init
