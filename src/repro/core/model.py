"""LEMUR model (§3.1, §4.1): φ(x) = W·ψ(x),  ψ(x) = LN(GELU(W'x + b)).

``train_phi`` is the paper's App. A trainer: Adam(3e-3), MSE on
*standardized* targets, 100 epochs, batch 512, grad-clip 0.5.  The same
routine pre-trains ψ against the m' sampled-document targets (§4.3) — the
output layer learned here is discarded and re-fit by OLS over the full
corpus in ``indexer.py``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.common.prng import PRNGSeq
from repro.core.config import LemurConfig
from repro.nn import layers
from repro.optim import adam_init, adam_update


def init_psi(key, d: int, d_prime: int):
    k1, _ = jax.random.split(key)
    return {
        "dense": layers.init_dense(k1, d, d_prime, use_bias=True),
        "ln": layers.init_layernorm(d_prime),
    }


def psi_apply(params, x):
    """ψ: (..., d) -> (..., d')."""
    h = layers.dense(params["dense"], x)
    h = layers.gelu(h)
    return layers.layernorm(params["ln"], h)


def init_phi(key, d: int, d_prime: int, m_out: int):
    k1, k2 = jax.random.split(key)
    return {
        "psi": init_psi(k1, d, d_prime),
        "out": layers.variance_scaling(k2, (d_prime, m_out)),  # W^T (no bias, §3.1)
    }


def phi_apply(params, x):
    return psi_apply(params["psi"], x) @ params["out"]


def pool_queries(psi_params, q_tokens, q_mask=None):
    """Ψ(X) = Σ_x ψ(x) (eq. 5).  q_tokens: (B, Tq, d) -> (B, d')."""
    feats = psi_apply(psi_params, q_tokens)
    if q_mask is not None:
        feats = feats * q_mask[..., None]
    return jnp.sum(feats, axis=-2)


class TargetStats(NamedTuple):
    mean: jax.Array
    std: jax.Array


def standardize_targets(g: jax.Array) -> tuple[jax.Array, TargetStats]:
    """Global (scalar) standardization, per App. A."""
    mean = jnp.mean(g)
    std = jnp.maximum(jnp.std(g), 1e-6)
    return (g - mean) / std, TargetStats(mean, std)


@functools.partial(jax.jit, static_argnames=("lr", "grad_clip"))
def _train_step(params, opt_state, xb, gb, lr, grad_clip):
    def loss_fn(p):
        pred = phi_apply(p, xb)
        return jnp.mean(jnp.square(pred - gb))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt_state, metrics = adam_update(
        grads, opt_state, params, lr=lr, grad_clip=grad_clip
    )
    return params, opt_state, loss


def train_phi(
    key,
    x_train: jax.Array,   # (n, d) token embeddings (§4.2 training set)
    g_train: jax.Array,   # (n, m_out) MaxSim targets (standardized inside)
    cfg: LemurConfig,
    *,
    log_every: int = 0,
):
    """Returns (params, target_stats, losses)."""
    n, d = x_train.shape
    m_out = g_train.shape[1]
    keys = PRNGSeq(key)
    params = init_phi(next(keys), d, cfg.d_prime, m_out)
    opt_state = adam_init(params)

    g_std, stats = standardize_targets(g_train)
    steps_per_epoch = max(1, n // cfg.batch_size)
    losses = []
    for epoch in range(cfg.epochs):
        perm = jax.random.permutation(next(keys), n)
        epoch_loss = 0.0
        for s in range(steps_per_epoch):
            idx = jax.lax.dynamic_slice_in_dim(perm, s * cfg.batch_size, cfg.batch_size)
            xb = jnp.take(x_train, idx, axis=0)
            gb = jnp.take(g_std, idx, axis=0)
            params, opt_state, loss = _train_step(
                params, opt_state, xb, gb, cfg.lr, cfg.grad_clip
            )
            epoch_loss += float(loss)
        losses.append(epoch_loss / steps_per_epoch)
        if log_every and (epoch + 1) % log_every == 0:
            print(f"[train_phi] epoch {epoch + 1}/{cfg.epochs} loss {losses[-1]:.5f}")
    return params, stats, losses
