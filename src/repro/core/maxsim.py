"""MaxSim similarity (eq. 1) — reference ops used across the framework.

All functions are pure jnp and memory-bounded: the corpus axis is processed
in blocks with ``lax.map`` so the (B, m, Tq, Td) score tensor never
materializes beyond one block.  ``repro.kernels.maxsim`` provides the Pallas
TPU kernel for the same contraction; these ops are its oracle and the
portable fallback inside jitted system graphs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG = -1e30


def maxsim_pair(q, q_mask, c, c_mask):
    """MaxSim(X, C) for one pair.  q: (Tq, d); c: (Td, d)."""
    s = q @ c.T  # (Tq, Td)
    s = jnp.where(c_mask[None, :], s, NEG)
    best = jnp.max(s, axis=-1)
    best = jnp.where(q_mask, best, 0.0)
    return jnp.sum(best)


def _score_block(q, q_mask, docs, docs_mask):
    """q: (B, Tq, d); docs: (Mb, Td, d) -> (B, Mb)."""
    s = jnp.einsum("bqd,mtd->bmqt", q, docs, preferred_element_type=jnp.float32)
    s = jnp.where(docs_mask[None, :, None, :], s, NEG)
    best = jnp.max(s, axis=-1)  # (B, Mb, Tq)
    best = jnp.where(q_mask[:, None, :], best, 0.0)
    return jnp.sum(best, axis=-1)


def maxsim_scores(q, q_mask, docs, docs_mask, *, block: int = 1024):
    """MaxSim of each query against every doc.  q: (B, Tq, d);
    docs: (m, Td, d) -> (B, m) fp32."""
    m = docs.shape[0]
    if m <= block:
        return _score_block(q, q_mask, docs, docs_mask)
    nb = -(-m // block)
    pad = nb * block - m
    docs_p = jnp.pad(docs, ((0, pad), (0, 0), (0, 0)))
    mask_p = jnp.pad(docs_mask, ((0, pad), (0, 0)))
    db = docs_p.reshape(nb, block, *docs.shape[1:])
    mb = mask_p.reshape(nb, block, docs.shape[1])
    out = jax.lax.map(lambda xs: _score_block(q, q_mask, xs[0], xs[1]), (db, mb))
    return jnp.moveaxis(out, 0, 1).reshape(q.shape[0], nb * block)[:, :m]


def token_maxsim(x, docs, docs_mask, *, block: int = 1024):
    """g(x)_l = max_{c in C_l} <c, x>  (§3.1).  x: (n, d) -> (n, m) fp32.

    This is both the OLS/MLP training target generator and the per-token
    inner loop of reranking."""
    m = docs.shape[0]

    def blk(d, dm):
        s = jnp.einsum("nd,mtd->nmt", x, d, preferred_element_type=jnp.float32)
        s = jnp.where(dm[None, :, :], s, NEG)
        return jnp.max(s, axis=-1)

    if m <= block:
        return blk(docs, docs_mask)
    nb = -(-m // block)
    pad = nb * block - m
    docs_p = jnp.pad(docs, ((0, pad), (0, 0), (0, 0)))
    mask_p = jnp.pad(docs_mask, ((0, pad), (0, 0)))
    db = docs_p.reshape(nb, block, *docs.shape[1:])
    mb = mask_p.reshape(nb, block, docs.shape[1])
    out = jax.lax.map(lambda xs: blk(xs[0], xs[1]), (db, mb))
    return jnp.moveaxis(out, 0, 1).reshape(x.shape[0], nb * block)[:, :m]


def rerank(q, q_mask, cand_ids, docs, docs_mask, k: int):
    """Exact MaxSim rerank of candidates (the second stage of Fig. 1).

    q: (B, Tq, d); cand_ids: (B, k') -> (topk_scores (B, k), topk_ids (B, k)).

    ``-1``-padded candidate rows (first-stage backends pad short results)
    score ``NEG`` so a pad can only surface — still carrying id ``-1`` — when
    a row has fewer than ``k`` real candidates.  Clamping pads to doc 0
    instead would duplicate doc 0 and inflate recall.
    """
    valid = cand_ids >= 0                       # (B, k')
    safe = jnp.maximum(cand_ids, 0)
    cd = jnp.take(docs, safe, axis=0)           # (B, k', Td, d)
    cm = jnp.take(docs_mask, safe, axis=0)      # (B, k', Td)
    s = jnp.einsum("bqd,bmtd->bmqt", q, cd, preferred_element_type=jnp.float32)
    s = jnp.where(cm[:, :, None, :], s, NEG)
    best = jnp.max(s, axis=-1)
    best = jnp.where(q_mask[:, None, :], best, 0.0)
    scores = jnp.sum(best, axis=-1)             # (B, k')
    scores = jnp.where(valid, scores, NEG)
    top, idx = jax.lax.top_k(scores, k)
    return top, jnp.take_along_axis(cand_ids, idx, axis=1)


def rerank_gathered(q, q_mask, cand_ids, cand_docs, cand_mask, k: int):
    """:func:`rerank` over PRE-GATHERED candidate docs — the legacy-path
    twin for the paged store, where candidates are materialized from token
    pages (``pages.gather_docs``) instead of ``jnp.take`` on a dense corpus.

    q: (B, Tq, d); cand_docs: (B, k', Tm, d); cand_mask: (B, k', Tm) ->
    (topk_scores (B, k), topk_ids (B, k)).  Same NEG/pad semantics as
    :func:`rerank`; per-token dots and the order-independent max make the
    scores bit-identical to the dense layout's."""
    valid = cand_ids >= 0
    s = jnp.einsum("bqd,bmtd->bmqt", q, cand_docs,
                   preferred_element_type=jnp.float32)
    s = jnp.where(cand_mask[:, :, None, :], s, NEG)
    best = jnp.max(s, axis=-1)
    best = jnp.where(q_mask[:, None, :], best, 0.0)
    scores = jnp.sum(best, axis=-1)
    scores = jnp.where(valid, scores, NEG)
    top, idx = jax.lax.top_k(scores, k)
    return top, jnp.take_along_axis(cand_ids, idx, axis=1)


def true_topk(q, q_mask, docs, docs_mask, k: int, *, block: int = 1024):
    """Exact MaxSim k-nn (ground truth for recall eval)."""
    scores = maxsim_scores(q, q_mask, docs, docs_mask, block=block)
    return jax.lax.top_k(scores, k)


def recall_at(retrieved, truth) -> jnp.ndarray:
    """Recall (eq. 3): |retrieved ∩ truth| / |truth| per row."""
    hits = (retrieved[:, :, None] == truth[:, None, :]).any(axis=1)
    return hits.mean(axis=-1)
