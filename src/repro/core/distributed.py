"""Back-compat shim: the distributed LEMUR serving/indexing steps moved to
:mod:`repro.dist.serve` (alongside the sharding rule tables in
:mod:`repro.dist.sharding`).  v0 call sites keep importing from here; new
code should use :meth:`repro.retriever.LemurRetriever.shard` or
``repro.dist`` directly."""
from repro.dist.serve import (  # noqa: F401
    ShardedRetrievalState,
    corpus_axes,
    default_k_prime_local,
    make_index_step,
    make_serve_step,
    n_corpus_shards,
    state_shardings,
)

__all__ = [
    "ShardedRetrievalState",
    "corpus_axes",
    "default_k_prime_local",
    "make_index_step",
    "make_serve_step",
    "n_corpus_shards",
    "state_shardings",
]
