"""Serving launcher: single replica or fleet, with the learned-index
lifecycle optionally closed behind ``--refresh``.

Builds a LEMUR retriever over a synthetic corpus, fronts it with the online
runtime (one ``RetrieverServer``, or ``--replicas N`` behind the fleet
``Router``), and replays Poisson traffic.  With ``--refresh`` a
``LifecycleManager`` polls the ``DriftMonitor`` in the background: when the
first-stage coverage of recently-mutated docs decays past the trigger, it
re-fits the latent map and re-clusters the first stage off-thread, then
warm-swaps the rebuilt index through the server/fleet FIFO barrier —
in-flight searches keep answering from the snapshot they were stamped with
and zero requests are dropped.  ``--drift-burst`` injects a topic-shifted
document burst mid-traffic so the whole loop can be watched end to end:

  PYTHONPATH=src python launch/serve.py --m 2000 --duration 6
  PYTHONPATH=src python launch/serve.py --refresh --drift-burst 256
  PYTHONPATH=src python launch/serve.py --replicas 3 --refresh \\
      --drift-burst 256 --refresh-min-reservoir 64
"""
import argparse
import time

import jax
import numpy as np

from repro.core import LemurConfig
from repro.data import synthetic
from repro.fleet import Router, clone_replicas
from repro.lifecycle import DriftMonitor, LifecycleManager
from repro.retriever import IVFBackendConfig, LemurRetriever
from repro.serving import (
    BucketLadder,
    RetrieverServer,
    poisson_trace,
    ragged_queries,
    replay,
    warm_buckets,
)


def _version(target) -> int:
    v = getattr(target, "version", None)   # Router property; servers expose
    return int(v if v is not None else target.retriever.version)


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--m", type=int, default=2000)
    p.add_argument("--d", type=int, default=32)
    p.add_argument("--rate", type=float, default=100.0,
                   help="offered load, queries/second (Poisson)")
    p.add_argument("--duration", type=float, default=6.0,
                   help="seconds per replay slice")
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--max-wait-us", type=int, default=2000)
    p.add_argument("--backend", default="ivf")
    p.add_argument("--epochs", type=int, default=6)
    p.add_argument("--replicas", type=int, default=1,
                   help=">1 serves through the fleet Router")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--refresh", action="store_true",
                   help="run the lifecycle loop: drift detection, "
                        "background refresh, zero-downtime warm swap")
    p.add_argument("--refresh-interval", type=float, default=0.25,
                   help="drift poll interval, seconds")
    p.add_argument("--refresh-cooldown", type=float, default=2.0,
                   help="min seconds between refresh attempts")
    p.add_argument("--refresh-min-reservoir", type=int, default=64,
                   help="mutated docs required before a drift report")
    p.add_argument("--refresh-threshold", type=float, default=0.25,
                   help="coverage-ratio trigger: refresh when first-stage "
                        "coverage of recent mutations falls below this "
                        "fraction of the post-build baseline")
    p.add_argument("--refresh-seed", type=int, default=1,
                   help="seed for the background rebuild (determinism)")
    p.add_argument("--drift-burst", type=int, default=0,
                   help="inject N topic-shifted docs mid-traffic (plus "
                        "N//2 deletes) to exercise the refresh")
    args = p.parse_args()

    corpus = synthetic.make_corpus(m=args.m, d=args.d, avg_tokens=12,
                                   max_tokens=16, seed=args.seed)
    cfg = LemurConfig(d=args.d, d_prime=64, m_pretrain=min(512, args.m),
                      n_train=8192, n_ols=2048, epochs=args.epochs, k=10,
                      k_prime=min(128, args.m), anns=args.backend,
                      ivf=IVFBackendConfig(nprobe=16))
    retriever = LemurRetriever.build(corpus, cfg,
                                     key=jax.random.PRNGKey(args.seed),
                                     verbose=True)
    ladder = BucketLadder((8, 16, 32), max_batch=args.max_batch)
    queries = ragged_queries(256, args.d, tq_range=(2, 24), seed=args.seed + 1)

    if args.replicas > 1:
        replicas = clone_replicas(retriever, args.replicas)
        target = Router(replicas, ladder=ladder,
                        max_wait_us=args.max_wait_us)
        served = replicas[0]
    else:
        target = RetrieverServer(retriever, ladder=ladder,
                                 max_wait_us=args.max_wait_us)
        served = retriever
    mgr = None
    with target:
        if args.replicas > 1:
            for rep in replicas:
                warm_buckets(rep, ladder, args.d)
        else:
            warm_buckets(retriever, ladder, args.d)
        if args.refresh:
            # monitor the SERVED index (replica 0 for a fleet — replicas are
            # bit-identical between barriers), not the unserved build
            mon = DriftMonitor(
                served, seed=args.seed,
                coverage_ratio_threshold=args.refresh_threshold)
            mgr = LifecycleManager(
                target, monitor=mon, seed=args.refresh_seed,
                poll_interval_s=args.refresh_interval,
                cooldown_s=args.refresh_cooldown,
                min_reservoir=args.refresh_min_reservoir)
            mgr.start()
            print(f"lifecycle: polling every {args.refresh_interval}s, "
                  f"trigger at coverage < {args.refresh_threshold} * "
                  f"baseline, min reservoir "
                  f"{args.refresh_min_reservoir}")

        _, rep = replay(target, queries,
                        poisson_trace(args.rate, args.duration,
                                      seed=args.seed + 2))
        print(f"steady:   p50={rep['p50_ms']:.2f}ms p99={rep['p99_ms']:.2f}ms "
              f"qps={rep['qps']:.0f} lost={rep['n_lost']} "
              f"version={_version(target)}")

        if args.drift_burst:
            burst = synthetic.make_corpus(
                m=args.drift_burst, d=args.d, avg_tokens=12, max_tokens=16,
                n_centers=6, topic_strength=4.0, seed=777)
            fa = target.add(burst.doc_tokens, burst.doc_mask)
            fd = target.delete(np.arange(args.drift_burst // 2))
            _, rep = replay(target, queries,
                            poisson_trace(args.rate, args.duration,
                                          seed=args.seed + 3))
            fa.result(timeout=300)
            fd.result(timeout=300)
            print(f"drift:    +{args.drift_burst}/-{args.drift_burst // 2} "
                  f"docs mid-traffic; p99={rep['p99_ms']:.2f}ms "
                  f"lost={rep['n_lost']} version={_version(target)}")
            if mgr is not None:
                # keep serving while the background loop detects + swaps
                deadline = time.perf_counter() + 120.0
                while mgr.n_swaps == 0 and time.perf_counter() < deadline:
                    _, rep = replay(target, queries,
                                    poisson_trace(args.rate, 1.0,
                                                  seed=args.seed + 4))
                    if rep["n_lost"]:
                        raise SystemExit(f"lost {rep['n_lost']} requests")
                print(f"swap:     n_swaps={mgr.n_swaps} "
                      f"version={_version(target)} p99={rep['p99_ms']:.2f}ms")

        if mgr is not None:
            mgr.stop()
            for ev in mgr.events():
                print(f"  event: {ev.kind} {ev}")
    print("done")


if __name__ == "__main__":
    main()
