import os
import sys

# Tests run on the single real CPU device (the 512-device farm is ONLY for
# launch/dryrun.py).  Some distributed tests spawn subprocesses with their
# own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def tiny_corpus():
    from repro.data import synthetic

    return synthetic.make_corpus(m=300, d=16, avg_tokens=8, max_tokens=12,
                                 n_centers=24, seed=0)
