import os
import subprocess
import sys
import textwrap

# Tests run on the single real CPU device (the 512-device farm is ONLY for
# launch/dryrun.py).  Multi-device suites go through the `run_forced8`
# fixture below, which isolates the forced device count in a subprocess.
SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def tiny_corpus():
    from repro.data import synthetic

    return synthetic.make_corpus(m=300, d=16, avg_tokens=8, max_tokens=12,
                                 n_centers=24, seed=0)


@pytest.fixture(scope="session")
def run_forced8():
    """Run a python snippet in a subprocess with 8 forced XLA host devices.

    The forced device count lives ONLY in the subprocess environment
    (``XLA_FLAGS``), never in this process — the main test process keeps the
    default single device no matter how pytest orders the suites, and the
    multi-device suites (test_distributed / test_dist_serve) all share this
    one helper instead of each mutating env on their own."""

    def _run(code: str, *, n_devices: int = 8, timeout: int = 560) -> str:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n_devices}")
        env["PYTHONPATH"] = SRC
        r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                           capture_output=True, text=True, env=env,
                           timeout=timeout)
        assert r.returncode == 0, (
            f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}")
        return r.stdout

    return _run
