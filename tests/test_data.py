"""Synthetic data generators + sharded loader."""
import numpy as np
import pytest

from repro.data import loader, synthetic


def test_corpus_statistics():
    c = synthetic.make_corpus(m=500, d=32, avg_tokens=20, max_tokens=32, seed=0)
    assert c.doc_tokens.shape == (500, 32, 32)
    counts = c.doc_mask.sum(1)
    assert counts.min() >= 4 and counts.max() <= 32
    assert abs(counts.mean() - 20) < 3
    # unit-norm valid tokens, zero padding
    norms = np.linalg.norm(c.doc_tokens, axis=-1)
    assert np.allclose(norms[c.doc_mask], 1.0, atol=1e-5)
    assert np.allclose(norms[~c.doc_mask], 0.0)


def test_query_strategies_shapes(tiny_corpus):
    for fn in (synthetic.queries_from_corpus_query, synthetic.queries_from_corpus,
               synthetic.queries_held_out):
        q = fn(tiny_corpus, 10, q_tokens=6)
        assert q.shape == (10, 6, tiny_corpus.d)
        assert np.isfinite(q).all()


def test_corpus_query_tokens_near_source_docs(tiny_corpus):
    """corpus-query queries must be recognizably derived from corpus docs."""
    q = synthetic.queries_from_corpus_query(tiny_corpus, 5, q_tokens=4,
                                            encoder_noise=0.0, seed=3)
    flat = tiny_corpus.doc_tokens[tiny_corpus.doc_mask]
    sims = q.reshape(-1, tiny_corpus.d) @ flat.T
    assert (sims.max(axis=1) > 0.99).all()


def test_mesh_graph_csr_consistent():
    g = synthetic.make_mesh_graph(100, seed=0)
    assert g.row_ptr[-1] == len(g.col_idx)
    # receivers sorted (CSR by receiver)
    assert (np.diff(g.receivers) >= 0).all()
    deg = np.diff(g.row_ptr)
    assert (deg >= 0).all() and deg.sum() == len(g.senders)


def test_clicks_labels_and_vocab_bounds():
    vs = np.array([50, 100, 10])
    d = synthetic.make_clicks(200, 3, vs, hist_len=5, n_items=77)
    assert d["ids"].shape == (200, 3)
    for f in range(3):
        assert d["ids"][:, f].max() < vs[f]
    assert set(np.unique(d["labels"])) <= {0.0, 1.0}
    assert d["history"].max() < 77


def test_lm_token_batches():
    it = synthetic.lm_token_batches(100, 4, 16, 3)
    batches = list(it)
    assert len(batches) == 3
    toks, labels = batches[0]
    assert toks.shape == (4, 16) and labels.shape == (4, 16)
    assert (labels[:, :-1] == toks[:, 1:]).all()


def test_sharded_loader_prefetch():
    batches = [np.full((4,), i, np.float32) for i in range(5)]
    out = list(loader.ShardedLoader(iter(batches), prefetch=2))
    assert len(out) == 5
    for i, b in enumerate(out):
        assert float(b[0]) == i


def test_local_batch_slicer():
    g = np.arange(12)
    assert (loader.local_batch_slicer(g, 1, 3) == np.array([4, 5, 6, 7])).all()
