"""Sharded-vs-local parity for the facade's multi-device serving path.

``LemurRetriever.shard(mesh)`` must be a pure distribution transform: the
same top-k ids AND scores as the single-device facade, bit for bit, on any
mesh — each test runs via the shared ``run_forced8`` conftest fixture (a
subprocess with 8 forced XLA host devices; the main process keeps its
single device under any pytest ordering) and compares a 1-device and an
8-device mesh against the local reference.

The corpora deliberately do NOT divide the device count (m=90, 8 devices)
so the pad-row masking path is always exercised.
"""
import textwrap


# shared preamble: tiny retriever whose k' covers the whole corpus, so the
# two-stage pipeline degenerates to exact MaxSim and parity must be EXACT
_BUILD = """
import jax, jax.numpy as jnp, numpy as np
from repro.common import compat
from repro.core import LemurConfig
from repro.data import synthetic
from repro.retriever import LemurRetriever, SearchParams, ShardedLemurRetriever

def build(m=90, k=5):
    corpus = synthetic.make_corpus(m=m, d=16, avg_tokens=8, max_tokens=8,
                                   n_centers=16, seed=0)
    cfg = LemurConfig(d=16, d_prime=32, m_pretrain=64, n_train=512, n_ols=256,
                      epochs=3, k=k, k_prime=m, anns="bruteforce")
    r = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0))
    q = jnp.asarray(synthetic.queries_from_corpus_query(corpus, 4, 4, seed=5))
    qm = jnp.ones(q.shape[:2], bool)
    return r, q, qm

MESH1 = compat.make_mesh((1,), ("model",))
MESH8 = compat.make_mesh((2, 4), ("data", "model"))
"""


def test_sharded_search_matches_facade_fp32(run_forced8):
    """fp32 sharded search == single-device facade, bit-identical, on 1 and
    8 host devices; exactly one jit trace per (params, batch shape)."""
    out = run_forced8(_BUILD + textwrap.dedent("""
    r, q, qm = build()
    params = SearchParams(use_ann=False)
    want_s, want_i = r.search(q, qm, params)
    for mesh in (MESH1, MESH8):
        sr = r.shard(mesh, sq8=False)
        got_s, got_i = sr.search(q, qm, params)
        assert np.array_equal(np.asarray(got_i), np.asarray(want_i)), mesh
        assert np.array_equal(np.asarray(got_s), np.asarray(want_s)), mesh
        sr.search(q, qm, params)          # same params + shape: no retrace
        assert sr.trace_count() == 1
        assert sr.trace_count(params) == 1
    print("OK")
    """))
    assert "OK" in out


def test_sharded_search_sq8_matches_single_device(run_forced8):
    """SQ8 state: scores are exact w.r.t. the quantized representation, so
    8-device serving must still be bit-identical to the 1-device mesh."""
    out = run_forced8(_BUILD + textwrap.dedent("""
    r, q, qm = build()
    params = SearchParams(use_ann=False)
    s1, i1 = r.shard(MESH1, sq8=True).search(q, qm, params)
    s8, i8 = r.shard(MESH8, sq8=True).search(q, qm, params)
    assert np.array_equal(np.asarray(i1), np.asarray(i8))
    assert np.array_equal(np.asarray(s1), np.asarray(s8))
    ids = np.asarray(i8)
    assert ids.min() >= 0 and ids.max() < r.m      # pads never surface
    # quantized top-k stays close to the fp32 ranking on this easy corpus
    _, fp_i = r.search(q, qm, params)
    overlap = np.mean([len(set(a) & set(b)) / len(a)
                       for a, b in zip(ids, np.asarray(fp_i))])
    assert overlap >= 0.8, overlap
    print("OK")
    """))
    assert "OK" in out


def test_sharded_fused_gather_matches_legacy(run_forced8):
    """The fused (gather-at-source) per-shard rerank — the default — and the
    legacy gather-then-contract path return identical results on 8 devices,
    for both the fp32 and SQ8 states; the toggle gets its own jit trace."""
    out = run_forced8(_BUILD + textwrap.dedent("""
    r, q, qm = build()
    fused = SearchParams(use_ann=False)                    # resolved default: fused
    legacy = SearchParams(use_ann=False, use_fused_gather=False)
    for sq8 in (False, True):
        sr = r.shard(MESH8, sq8=sq8)
        fs, fi = sr.search(q, qm, fused)
        ls, li = sr.search(q, qm, legacy)
        assert np.array_equal(np.asarray(fi), np.asarray(li)), sq8
        assert np.array_equal(np.asarray(fs), np.asarray(ls)), sq8
        assert sr.trace_count(fused) == 1 and sr.trace_count(legacy) == 1
    # fp32 fused sharded == local facade, bit for bit
    sr = r.shard(MESH8, sq8=False)
    want_s, want_i = r.search(q, qm, fused)
    got_s, got_i = sr.search(q, qm, fused)
    assert np.array_equal(np.asarray(got_i), np.asarray(want_i))
    assert np.array_equal(np.asarray(got_s), np.asarray(want_s))
    print("OK")
    """))
    assert "OK" in out


def test_sharded_one_launch_matches_legacy(run_forced8):
    """The one-launch per-shard first stage (fused dense scan + in-kernel
    top-k dispatch) returns the same candidate ids as the legacy
    scan → mask → top_k composition on 8 devices — including the pad-row
    masking path (m=90 does not divide 8) — with its own jit trace."""
    out = run_forced8(_BUILD + textwrap.dedent("""
    r, q, qm = build()
    legacy = SearchParams(use_ann=False)
    one = SearchParams(use_ann=False, use_one_launch=True)
    for sq8 in (False, True):
        sr = r.shard(MESH8, sq8=sq8)
        ls, li = sr.search(q, qm, legacy)
        os_, oi = sr.search(q, qm, one)
        assert np.array_equal(np.asarray(oi), np.asarray(li)), sq8
        assert np.array_equal(np.asarray(os_), np.asarray(ls)), sq8
        assert sr.trace_count(legacy) == 1 and sr.trace_count(one) == 1
    # fp32 one-launch sharded == local facade legacy path, bit for bit
    sr = r.shard(MESH8, sq8=False)
    want_s, want_i = r.search(q, qm, legacy)
    got_s, got_i = sr.search(q, qm, one)
    assert np.array_equal(np.asarray(got_i), np.asarray(want_i))
    assert np.array_equal(np.asarray(got_s), np.asarray(want_s))
    print("OK")
    """))
    assert "OK" in out


def test_sharded_add_matches_facade(run_forced8):
    """Shard-balanced growth: after add(), sharded search still matches the
    (identically grown) facade bit for bit, and every shard holds the same
    row count."""
    out = run_forced8(_BUILD + textwrap.dedent("""
    import repro.dist as dist
    r, q, qm = build()
    sr = r.shard(MESH8, sq8=False)
    extra = synthetic.make_corpus(m=21, d=16, avg_tokens=8, max_tokens=8,
                                  n_centers=16, seed=9)
    sr.add(extra.doc_tokens, extra.doc_mask)      # grows the shared base too
    assert sr.m == r.m == 111
    assert sr.state.W.shape[0] % dist.n_corpus_shards(MESH8) == 0
    params = SearchParams(k_prime=r.m, use_ann=False)  # full coverage again
    want_s, want_i = r.search(q, qm, params)
    got_s, got_i = sr.search(q, qm, params)
    assert np.array_equal(np.asarray(got_i), np.asarray(want_i))
    assert np.array_equal(np.asarray(got_s), np.asarray(want_s))
    print("OK")
    """))
    assert "OK" in out


def test_sharded_mutation_matches_facade(run_forced8):
    """Interleaved add/delete/update on the slot-pool sharded facade: an
    in-capacity mutation is an in-place row write (ZERO new traces for the
    already-compiled serve step), tombstoned ids never surface, and the
    mutated 8-device search stays bit-identical to an identically mutated
    single-device facade."""
    out = run_forced8(_BUILD + textwrap.dedent("""
    r, q, qm = build()
    rl = r.clone()                    # independent local twin (shared solver
    sr = r.shard(MESH8, sq8=False)    # => bit-identical fitted W rows)
    params = SearchParams(use_ann=False)
    sr.search(q, qm, params)
    assert sr.trace_count() == 1
    extra = synthetic.make_corpus(m=12, d=16, avg_tokens=8, max_tokens=8,
                                  n_centers=16, seed=9)
    for t in (sr, rl):
        t.add(extra.doc_tokens, extra.doc_mask)
        t.delete(t.last_added_ids[:6])
        t.update([3, 7], extra.doc_tokens[6:8], extra.doc_mask[6:8])
    assert sr.m == rl.m == 104 and sr.n_alive == rl.n_alive == 96
    assert sr.version == rl.version == 3     # update bumps ONCE
    # pool had free rows + token width fits => in-place writes, no retrace
    _, ids = sr.search(q, qm, params)
    assert sr.trace_count() == 1, "in-capacity mutation retraced the serve step"
    gone = set(range(90, 96)) | {3, 7}
    assert not (set(np.asarray(ids).ravel().tolist()) & gone)
    # full-coverage exact parity vs the identically mutated local facade
    full = SearchParams(use_ann=False, k_prime=sr.m)
    want_s, want_i = rl.search(q, qm, full)
    got_s, got_i = sr.search(q, qm, full)
    assert np.array_equal(np.asarray(got_i), np.asarray(want_i))
    assert np.array_equal(np.asarray(got_s), np.asarray(want_s))
    print("OK")
    """))
    assert "OK" in out


def test_sharded_mutation_sq8_single_vs_8dev(run_forced8):
    """The same churn under SQ8: both meshes quantize the in-place row
    writes identically, so 1-device and 8-device search stay bit-identical
    and deleted ids never surface from the quantized scan either."""
    out = run_forced8(_BUILD + textwrap.dedent("""
    r, q, qm = build()
    extra = synthetic.make_corpus(m=12, d=16, avg_tokens=8, max_tokens=8,
                                  n_centers=16, seed=9)
    res = []
    for mesh in (MESH1, MESH8):
        sr = r.clone().shard(mesh, sq8=True)
        sr.add(extra.doc_tokens, extra.doc_mask)
        sr.delete(sr.last_added_ids[:6])
        sr.update([3, 7], extra.doc_tokens[6:8], extra.doc_mask[6:8])
        res.append(sr.search(q, qm, SearchParams(use_ann=False,
                                                 k_prime=sr.m)))
    (s1, i1), (s8, i8) = res
    assert np.array_equal(np.asarray(i1), np.asarray(i8))
    assert np.array_equal(np.asarray(s1), np.asarray(s8))
    gone = set(range(90, 96)) | {3, 7}
    assert not (set(np.asarray(i8).ravel().tolist()) & gone)
    print("OK")
    """))
    assert "OK" in out


def test_sharded_k_exceeds_corpus_pads_to_k(run_forced8):
    """k > m on a corpus smaller than the device count: search must keep
    the facade's (B, k) shape, padding with (NEG, -1) — not return the
    merge's narrower width."""
    out = run_forced8(_BUILD + textwrap.dedent("""
    corpus = synthetic.make_corpus(m=6, d=16, avg_tokens=6, max_tokens=6,
                                   n_centers=4, seed=0)
    cfg = LemurConfig(d=16, d_prime=16, m_pretrain=6, n_train=128, n_ols=64,
                      epochs=2, batch_size=64, k=10, k_prime=6,
                      anns="bruteforce")
    r = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0))
    q = jnp.asarray(synthetic.queries_from_corpus_query(corpus, 2, 3, seed=1))
    qm = jnp.ones(q.shape[:2], bool)
    sr = r.shard(MESH8, sq8=False)
    s, i = sr.search(q, qm, SearchParams(k=10))
    ids = np.asarray(i)
    assert s.shape == (2, 10) and i.shape == (2, 10)
    assert (ids[:, 6:] == -1).all()
    assert (np.sort(ids[:, :6], axis=1) == np.arange(6)).all()
    print("OK")
    """))
    assert "OK" in out


def test_sharded_save_load_roundtrip(run_forced8):
    """save() persists the mesh-free index; load(directory, mesh) reproduces
    sharded search ids/scores bit-identically."""
    out = run_forced8(_BUILD + textwrap.dedent("""
    import tempfile
    r, q, qm = build()
    params = SearchParams(use_ann=False)
    want_s, want_i = r.shard(MESH8, sq8=False).search(q, qm, params)
    with tempfile.TemporaryDirectory() as d:
        r.shard(MESH8).save(d)
        sr = ShardedLemurRetriever.load(d, MESH8, sq8=False)
        got_s, got_i = sr.search(q, qm, params)
    assert np.array_equal(np.asarray(got_i), np.asarray(want_i))
    assert np.array_equal(np.asarray(got_s), np.asarray(want_s))
    print("OK")
    """))
    assert "OK" in out


def test_sharded_index_step_matches_local_ols(run_forced8):
    """The zero-comms distributed OLS index step reproduces the local
    solve over an 8-way sharded corpus."""
    out = run_forced8("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.common import compat
    from repro.core import LemurConfig, indexer
    from repro.core.model import init_psi
    from repro.data import synthetic
    from repro.dist import make_index_step

    corpus = synthetic.make_corpus(m=96, d=16, avg_tokens=8, max_tokens=8, seed=0)
    cfg = LemurConfig(d=16, d_prime=32, ridge=1e-4, n_ols=128)
    psi = init_psi(jax.random.PRNGKey(0), 16, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 16))
    docs = jnp.asarray(corpus.doc_tokens); mask = jnp.asarray(corpus.doc_mask)
    W_ref = indexer.fit_output_layer_ols(psi, x, docs, mask, cfg)

    chol, feats = indexer.gram_factor(psi, x, cfg.ridge)
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    step = make_index_step(mesh, cfg, doc_block=12)
    W = jax.jit(step)(chol[0], feats, x, docs, mask, jnp.zeros(()), jnp.ones(()))
    err = float(jnp.max(jnp.abs(W - W_ref)))
    assert err < 1e-3, err
    print("OK")
    """)
    assert "OK" in out


def test_online_server_sharded_parity(run_forced8):
    """The online serving runtime over an 8-device ShardedLemurRetriever:
    ragged bucketed micro-batches return the same top-k ids as direct
    sharded search (scores to reduction tolerance), streaming add() lands
    between micro-batches and post-add queries see the new docs, and the
    compiled-step count stays within the bucket-ladder bound."""
    out = run_forced8(_BUILD + textwrap.dedent("""
    from repro.serving import BucketLadder, RetrieverServer

    r, q, qm = build()
    sr = r.shard(MESH8, sq8=False)
    params = SearchParams(use_ann=False)
    ladder = BucketLadder((4, 8), max_batch=4)
    rng = np.random.default_rng(3)
    with RetrieverServer(sr, ladder=ladder, max_wait_us=500,
                         default_params=params) as srv:
        futs = []
        for i in range(12):
            tq = int(rng.integers(1, 9))
            qi = np.asarray(q[i % q.shape[0], :tq])
            futs.append((qi, srv.submit(qi)))
        for qi, fut in futs:
            s, ids = fut.result(timeout=120)
            want_s, want_i = sr.search(qi[None],
                                       np.ones((1, len(qi)), bool), params)
            assert np.array_equal(ids, np.asarray(want_i)[0])
            np.testing.assert_allclose(s, np.asarray(want_s)[0],
                                       rtol=1e-5, atol=1e-6)
        assert srv.trace_count() <= ladder.compile_bound(1)
        # streaming add: applied between micro-batches, later queries see it
        extra = synthetic.make_corpus(m=7, d=16, avg_tokens=8, max_tokens=8,
                                      n_centers=16, seed=11)
        assert srv.add(extra.doc_tokens, extra.doc_mask).result(timeout=300) == 97
        grown = SearchParams(use_ann=False, k_prime=97)
        target = extra.doc_tokens[2][extra.doc_mask[2]]
        s, ids = srv.search(np.asarray(target), params=grown, timeout=300)
        assert ids[0] == 92, ids     # new doc id = 90 + 2, visible post-add
    print("OK")
    """))
    assert "OK" in out


def test_sharded_warm_swap_parity_and_barrier(run_forced8):
    """Lifecycle warm swap on 8 devices: ``build_refresh`` from the sharded
    snapshot is bit-identical to an identically mutated local twin's, the
    install lands through the RetrieverServer FIFO barrier with searches in
    flight (earlier futures stamped with the pre-swap version and answered
    by the old snapshot, later ones by the refit index), and the post-swap
    8-device search matches the locally refreshed facade bit for bit."""
    out = run_forced8(_BUILD + textwrap.dedent("""
    from repro.lifecycle import build_refresh
    from repro.serving import BucketLadder, RetrieverServer

    r, q, qm = build()
    rl = r.clone()                    # independent local twin
    sr = r.shard(MESH8, sq8=False)
    extra = synthetic.make_corpus(m=14, d=16, avg_tokens=8, max_tokens=8,
                                  n_centers=16, seed=9)
    for t in (sr, rl):
        t.add(extra.doc_tokens, extra.doc_mask)
        t.delete([1, 5, 90])
    # same snapshot + same seed => bit-identical refresh artifacts
    res_s = build_refresh(sr, seed=7)
    res_l = build_refresh(rl, seed=7)
    assert res_s.m0 == res_l.m0 == 104
    assert np.array_equal(np.asarray(res_s.W), np.asarray(res_l.W))
    params = SearchParams(use_ann=False, k_prime=sr.m)
    qs = [np.asarray(q[i, :4]) for i in range(3)]
    ones = np.ones((1, 4), bool)
    pre = [sr.search(qi[None], ones, params) for qi in qs]
    rl.install_refresh(res_l)
    post = [rl.search(qi[None], ones, params) for qi in qs]
    v0 = sr.version
    with RetrieverServer(sr, ladder=BucketLadder((4,), max_batch=2),
                         max_wait_us=200, default_params=params) as srv:
        srv.pause()                   # freeze the worker: strict FIFO order
        bef = [srv.submit(qi) for qi in qs]
        swap = srv.apply(lambda t, res=res_s: t.install_refresh(res))
        aft = [srv.submit(qi) for qi in qs]
        srv.resume()
        for fut, (ws, wi) in zip(bef, pre):
            s, ids = fut.result(timeout=300)
            assert fut.snapshot_version == v0
            assert np.array_equal(ids, np.asarray(wi)[0])
            np.testing.assert_allclose(s, np.asarray(ws)[0],
                                       rtol=1e-5, atol=1e-6)
        swap.result(timeout=300)
        assert swap.snapshot_version == v0 + 1
        for fut, (ws, wi) in zip(aft, post):
            s, ids = fut.result(timeout=300)
            assert fut.snapshot_version == v0 + 1
            assert np.array_equal(ids, np.asarray(wi)[0])
            np.testing.assert_allclose(s, np.asarray(ws)[0],
                                       rtol=1e-5, atol=1e-6)
    assert sr.version == rl.version == v0 + 1
    # full-coverage exact parity vs the locally refreshed facade
    want_s, want_i = rl.search(q, qm, params)
    got_s, got_i = sr.search(q, qm, params)
    assert np.array_equal(np.asarray(got_i), np.asarray(want_i))
    assert np.array_equal(np.asarray(got_s), np.asarray(want_s))
    print("OK")
    """))
    assert "OK" in out
