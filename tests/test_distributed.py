"""Multi-device distribution semantics, run in a subprocess with 8 forced
host devices via the shared ``run_forced8`` conftest fixture (the main test
process keeps the default single device under ANY pytest ordering).

All mesh/shard_map plumbing goes through ``repro.common.compat`` so the
suite runs on every supported jax (the installed 0.4.37 has no
``jax.set_mesh`` / ``jax.sharding.AxisType`` / top-level ``shard_map``)."""


def test_moe_ep_multi_device_matches_dense(run_forced8):
    out = run_forced8("""
    import jax, jax.numpy as jnp
    from repro.common import compat
    from repro.nn import moe
    mesh = compat.make_mesh((2,2,2), ("pod","data","model"),
                            axis_types=(compat.AxisType.Auto,)*3)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 32))
    p = moe.init_moe(jax.random.PRNGKey(1), 8, 32, 64, gated=True, n_shared=1)
    want, aux_w = moe.moe_apply_dense(p, x, n_experts=8, top_k=2)
    with compat.set_mesh(mesh):
        for layout in ("ep", "ffslice"):
            got, aux = jax.jit(lambda p, x: moe.moe_apply(
                p, x, layout=layout, n_experts=8, top_k=2, mesh=mesh,
                capacity_factor=8.0))(p, x)
            err = float(jnp.max(jnp.abs(got - want)))
            assert err < 1e-4, (layout, err)
    print("OK")
    """)
    assert "OK" in out


def test_sharded_embedding_lookup_multi_device(run_forced8):
    out = run_forced8("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.common import compat
    from repro.models.recsys import sharded_embedding_lookup
    mesh = compat.make_mesh((2,4), ("data","model"),
                            axis_types=(compat.AxisType.Auto,)*2)
    table = jax.random.normal(jax.random.PRNGKey(0), (40, 8))
    ids = jax.random.randint(jax.random.PRNGKey(1), (6, 3), 0, 40)
    with compat.set_mesh(mesh):
        got = jax.jit(lambda t, i: sharded_embedding_lookup(t, i, mesh))(table, ids)
    want = jnp.take(table, ids, axis=0)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-6
    print("OK")
    """)
    assert "OK" in out


def test_gnn_sharded_forward_matches_unsharded(run_forced8):
    out = run_forced8("""
    import jax, jax.numpy as jnp
    from repro.common import compat
    from repro.data import synthetic
    from repro.models import gnn
    g = synthetic.make_mesh_graph(64, d_feat=8, d_edge=4, d_out=2, seed=0)
    cfg = gnn.GNNConfig(n_layers=2, d_hidden=16, d_node_in=8, d_edge_in=4, d_out=2)
    p = gnn.init_gnn(jax.random.PRNGKey(0), cfg)
    nf, ef = jnp.asarray(g.node_feat), jnp.asarray(g.edge_feat)
    s, r = jnp.asarray(g.senders), jnp.asarray(g.receivers)
    # pad edges to 8 devices
    E = s.shape[0]; pad = (-E) % 8
    ef = jnp.pad(ef, ((0,pad),(0,0))); s = jnp.pad(s, (0,pad)); r = jnp.pad(r, (0,pad))
    # padded edges: self-loops on node 0 with zero features contribute MLP(0) bias...
    # instead point them at a real node with zeroed msg — acceptable tolerance check:
    # use exact edge count divisible instead
    s = s[:E - E % 8]; r = r[:E - E % 8]; ef = ef[:E - E % 8]
    want = gnn.forward(p, nf, ef, s, r, cfg)
    mesh = compat.make_mesh((2,4), ("data","model"),
                            axis_types=(compat.AxisType.Auto,)*2)
    with compat.set_mesh(mesh):
        got = jax.jit(lambda *a: gnn.forward(*a, cfg, mesh))(p, nf, ef, s, r)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 1e-3, err
    print("OK")
    """)
    assert "OK" in out


def test_lemur_distributed_serve_matches_local(run_forced8):
    out = run_forced8("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.common import compat
    from repro.core import LemurConfig, maxsim
    from repro.dist import ShardedRetrievalState, make_serve_step
    from repro.core.model import init_psi, pool_queries
    from repro.data import synthetic

    corpus = synthetic.make_corpus(m=160, d=16, avg_tokens=8, max_tokens=8,
                                   n_centers=16, seed=0)
    cfg = LemurConfig(d=16, d_prime=32, k=5, k_prime=160)
    psi = init_psi(jax.random.PRNGKey(0), 16, 32)
    W = jax.random.normal(jax.random.PRNGKey(1), (160, 32))
    docs = jnp.asarray(corpus.doc_tokens); mask = jnp.asarray(corpus.doc_mask)
    q = jnp.asarray(synthetic.queries_from_corpus_query(corpus, 4, 4))
    qm = jnp.ones(q.shape[:2], bool)

    # local reference: full latent scan + rerank of ALL docs
    pq = pool_queries(psi, q, qm)
    cand = jax.lax.top_k(pq @ W.T, 160)[1]
    want_s, want_i = maxsim.rerank(q, qm, cand, docs, mask, 5)

    mesh = compat.make_mesh((2,2,2), ("pod","data","model"),
                            axis_types=(compat.AxisType.Auto,)*3)
    state = ShardedRetrievalState(psi=psi, W=W, doc_tokens=docs, doc_mask=mask)
    serve = make_serve_step(mesh, cfg, k_prime_local=20)  # 20/shard = all local docs
    with compat.set_mesh(mesh):
        got_s, got_i = jax.jit(serve)(state, q, qm)
    assert (np.sort(np.asarray(got_i)) == np.sort(np.asarray(want_i))).all()
    np.testing.assert_allclose(np.sort(np.asarray(got_s)), np.sort(np.asarray(want_s)), rtol=1e-4)
    print("OK")
    """)
    assert "OK" in out


def test_lemur_distributed_index_matches_local(run_forced8):
    out = run_forced8("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.common import compat
    from repro.core import LemurConfig, indexer
    from repro.dist import make_index_step
    from repro.core.model import init_psi, psi_apply
    from repro.data import synthetic

    corpus = synthetic.make_corpus(m=64, d=16, avg_tokens=8, max_tokens=8, seed=0)
    cfg = LemurConfig(d=16, d_prime=32, ridge=1e-4, n_ols=128)
    psi = init_psi(jax.random.PRNGKey(0), 16, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 16))
    docs = jnp.asarray(corpus.doc_tokens); mask = jnp.asarray(corpus.doc_mask)
    W_ref = indexer.fit_output_layer_ols(psi, x, docs, mask, cfg)

    chol, feats = indexer.gram_factor(psi, x, cfg.ridge)
    mesh = compat.make_mesh((2,2,2), ("pod","data","model"),
                            axis_types=(compat.AxisType.Auto,)*3)
    step = make_index_step(mesh, cfg, doc_block=8)
    with compat.set_mesh(mesh):
        W = jax.jit(step)(chol[0], feats, x, docs, mask,
                          jnp.zeros(()), jnp.ones(()))
    err = float(jnp.max(jnp.abs(W - W_ref)))
    assert err < 1e-3, err
    print("OK")
    """)
    assert "OK" in out


def test_grad_compression_cross_pod(run_forced8):
    out = run_forced8("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.common import compat
    from repro.common.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.optim.compress import ef_int8_allreduce
    mesh = compat.make_mesh((4,2), ("pod","data"),
                            axis_types=(compat.AxisType.Auto,)*2)
    g = jax.random.normal(jax.random.PRNGKey(0), (4, 64))  # 4 pod-shards
    err0 = jnp.zeros((4, 64))
    def body(g, e):
        r, ne = ef_int8_allreduce({"g": g[0]}, {"g": e[0]}, "pod")
        return r["g"][None], ne["g"][None]
    with compat.set_mesh(mesh):
        red, new_err = jax.jit(lambda g, e: shard_map(
            body, mesh=mesh, in_specs=(P("pod"), P("pod")), out_specs=(P("pod"), P("pod")),
            check_vma=False)(g, e))(g, err0)
    # each pod-shard sees ~the mean of the 4 int8-quantized rows
    want = jnp.mean(g, axis=0)
    got = red[0]
    assert float(jnp.max(jnp.abs(got - want))) < 0.1
    print("OK")
    """)
    assert "OK" in out
