"""Optional-hypothesis shim: property tests skip cleanly when the package is
absent (this container has no network, so `pytest.importorskip` at module
scope would throw away every NON-property test in the module too).

    from _hypothesis_compat import given, settings, st

With hypothesis installed this re-exports the real API; without it, `@given`
marks just that test as skipped and `settings`/`st` become inert stand-ins.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Settings:
        def __init__(self, *a, **k):
            pass

        def __call__(self, fn):  # used as decorator
            return fn

        @staticmethod
        def register_profile(*a, **k):
            pass

        @staticmethod
        def load_profile(*a, **k):
            pass

    settings = _Settings  # type: ignore[assignment]

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()  # type: ignore[assignment]

    def given(*a, **k):  # type: ignore[misc]
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed; property test skipped")(fn)
