"""Checkpoint manager: atomicity, async, retention, elastic restore."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore, save
from repro.checkpoint.manager import latest_step


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layer": {"w": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
                  "b": jnp.asarray(rng.standard_normal(8), jnp.bfloat16)},
        "step_count": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip_identity(tmp_path):
    tree = _tree()
    save(tmp_path, 10, tree)
    restored, step = restore(tmp_path, jax.tree_util.tree_map(jnp.zeros_like, tree))
    assert step == 10
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoints_ignored(tmp_path):
    tree = _tree()
    save(tmp_path, 5, tree)
    # forge a newer, uncommitted step
    d = tmp_path / "step_00000009"
    d.mkdir()
    (d / "manifest.json").write_text("{}")
    assert latest_step(tmp_path) == 5


def test_restore_validates_shapes(tmp_path):
    save(tmp_path, 1, _tree())
    bad = {"layer": {"w": jnp.zeros((3, 3)), "b": jnp.zeros(8, jnp.bfloat16)},
           "step_count": jnp.zeros((), jnp.int32)}
    with pytest.raises(ValueError):
        restore(tmp_path, bad)


def test_async_save_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, tree)
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps == [3, 4]
    restored, step = mgr.restore_latest(jax.tree_util.tree_map(jnp.zeros_like, tree))
    assert step == 4


def _simulate_crash_mid_save(directory, step):
    """Forge the on-disk state of a save that died partway: shard partially
    written, manifest missing, no _COMMITTED — both in .tmp staging form and
    as a bare step dir (the pre-rename and post-partial-write crash points)."""
    directory = pathlib.Path(directory)
    staged = directory / f"step_{step:08d}.tmp"
    staged.mkdir(parents=True)
    (staged / "shard_00000.npz").write_bytes(b"PK\x03\x04 truncated")
    bare = directory / f"step_{step + 1:08d}"
    bare.mkdir(parents=True)
    (bare / "shard_00000.npz").write_bytes(b"PK\x03\x04 truncated")
    (bare / "manifest.json").write_text("{")


def test_crash_mid_save_restores_last_complete(tmp_path):
    tree = _tree()
    save(tmp_path, 5, tree)
    _simulate_crash_mid_save(tmp_path, 6)
    assert latest_step(tmp_path) == 5
    restored, step = restore(tmp_path,
                             jax.tree_util.tree_map(jnp.zeros_like, tree))
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_crash_mid_save_then_resave_recovers(tmp_path):
    """A later save over the wreckage clears the stale .tmp staging dir and
    commits cleanly."""
    tree = _tree()
    save(tmp_path, 5, tree)
    _simulate_crash_mid_save(tmp_path, 5)  # stale step_00000005.tmp + junk 6
    d = save(tmp_path, 5, _tree(seed=1))
    assert d.name == "step_00000005"
    assert latest_step(tmp_path) == 5
    restored, _ = restore(tmp_path,
                          jax.tree_util.tree_map(jnp.zeros_like, tree), step=5)
    exp = jax.tree_util.tree_leaves(_tree(seed=1))
    for a, b in zip(exp, jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retriever_load_survives_crash_mid_save(tmp_path):
    """Facade-level regression: a crash mid-``save()`` (partial shard, no
    committed manifest) must leave ``LemurRetriever.load()`` restoring the
    last complete checkpoint bit-identically."""
    from repro.core.config import LemurConfig
    from repro.data import synthetic
    from repro.retriever import LemurRetriever, SearchParams

    corpus = synthetic.make_corpus(m=48, d=8, avg_tokens=6, max_tokens=8,
                                   n_centers=6, seed=0)
    cfg = LemurConfig(d=8, d_prime=16, m_pretrain=32, n_train=512, n_ols=128,
                      epochs=1, k=5, k_prime=24, anns="bruteforce")
    r = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0))
    r.save(tmp_path)
    _simulate_crash_mid_save(tmp_path, 0)   # wreck a would-be re-save
    r2 = LemurRetriever.load(tmp_path)
    q = np.asarray(corpus.doc_tokens[:4])
    qm = np.asarray(corpus.doc_mask[:4])
    p = SearchParams(k=5, k_prime=24)
    s1, i1 = r.search(q, qm, p)
    s2, i2 = r2.search(q, qm, p)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_elastic_restore_with_shardings(tmp_path):
    """Restore with explicit shardings places leaves on the (1-device) mesh —
    the same codepath a resized job uses."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    if hasattr(jax.sharding, "AxisType"):  # newer jax
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    else:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = _tree()
    save(tmp_path, 3, tree)
    sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)
    restored, _ = restore(tmp_path, jax.tree_util.tree_map(jnp.zeros_like, tree),
                          shardings=sh)
    w = restored["layer"]["w"]
    assert w.sharding == NamedSharding(mesh, P())
