"""Checkpoint manager: atomicity, async, retention, elastic restore."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore, save
from repro.checkpoint.manager import latest_step


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layer": {"w": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
                  "b": jnp.asarray(rng.standard_normal(8), jnp.bfloat16)},
        "step_count": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip_identity(tmp_path):
    tree = _tree()
    save(tmp_path, 10, tree)
    restored, step = restore(tmp_path, jax.tree_util.tree_map(jnp.zeros_like, tree))
    assert step == 10
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoints_ignored(tmp_path):
    tree = _tree()
    save(tmp_path, 5, tree)
    # forge a newer, uncommitted step
    d = tmp_path / "step_00000009"
    d.mkdir()
    (d / "manifest.json").write_text("{}")
    assert latest_step(tmp_path) == 5


def test_restore_validates_shapes(tmp_path):
    save(tmp_path, 1, _tree())
    bad = {"layer": {"w": jnp.zeros((3, 3)), "b": jnp.zeros(8, jnp.bfloat16)},
           "step_count": jnp.zeros((), jnp.int32)}
    with pytest.raises(ValueError):
        restore(tmp_path, bad)


def test_async_save_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, tree)
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps == [3, 4]
    restored, step = mgr.restore_latest(jax.tree_util.tree_map(jnp.zeros_like, tree))
    assert step == 4


def test_elastic_restore_with_shardings(tmp_path):
    """Restore with explicit shardings places leaves on the (1-device) mesh —
    the same codepath a resized job uses."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    if hasattr(jax.sharding, "AxisType"):  # newer jax
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    else:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = _tree()
    save(tmp_path, 3, tree)
    sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)
    restored, _ = restore(tmp_path, jax.tree_util.tree_map(jnp.zeros_like, tree),
                          shardings=sh)
    w = restored["layer"]["w"]
    assert w.sharding == NamedSharding(mesh, P())
