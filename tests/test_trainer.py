"""Fault-tolerant training loop: retry, NaN skip, restore-resume, straggler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adam_init, adam_update
from repro.train import TrainerConfig, TrainLoop


def _setup(tmp_path, **kw):
    params = {"w": jnp.asarray([1.0, -1.0])}

    def step_fn(params, opt, batch):
        def loss_fn(p):
            return jnp.mean(jnp.square(p["w"] - batch))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, m = adam_update(grads, opt, params, lr=0.05, grad_clip=None)
        return params, opt, {"loss": loss, **m}

    cfg = TrainerConfig(checkpoint_dir=str(tmp_path), log_every=0, **kw)
    return cfg, step_fn, params, adam_init(params)


def _batches(n):
    return [jnp.asarray([0.5, 0.5])] * n


def test_loop_trains(tmp_path):
    cfg, step_fn, p, o = _setup(tmp_path, total_steps=20, checkpoint_every=10)
    loop = TrainLoop(cfg, jax.jit(step_fn), p, o, logger=lambda s: None)
    out = loop.run(_batches(20))
    assert out["final_step"] == 20
    assert out["history"][-1]["loss"] < out["history"][0]["loss"]


def test_retry_on_transient_failure(tmp_path):
    cfg, step_fn, p, o = _setup(tmp_path, total_steps=10, checkpoint_every=5,
                                max_retries=2)
    fails = {"count": 0}

    def fault_hook(step):
        if step == 3 and fails["count"] < 2:
            fails["count"] += 1
            raise RuntimeError("simulated interconnect fault")

    loop = TrainLoop(cfg, jax.jit(step_fn), p, o, fault_hook=fault_hook,
                     logger=lambda s: None)
    out = loop.run(_batches(10))
    assert out["final_step"] == 10
    assert out["retries"] == 2


def test_nan_guard_skips_update(tmp_path):
    params = {"w": jnp.asarray([1.0])}

    calls = {"n": 0}

    def step_fn(params, opt, batch):
        calls["n"] += 1
        loss = jnp.asarray(float("nan")) if calls["n"] == 2 else jnp.asarray(0.5)
        return jax.tree_util.tree_map(lambda x: x - 0.1, params), opt, {"loss": loss}

    cfg = TrainerConfig(checkpoint_dir=str(tmp_path), total_steps=3,
                        checkpoint_every=0, log_every=0)
    loop = TrainLoop(cfg, step_fn, params, adam_init(params), logger=lambda s: None)
    out = loop.run(_batches(3))
    assert out["nan_skips"] == 1
    # two real updates applied (step 2 skipped)
    np.testing.assert_allclose(float(loop.params["w"][0]), 1.0 - 0.2, rtol=1e-5)


def test_restart_resumes_from_checkpoint(tmp_path):
    cfg, step_fn, p, o = _setup(tmp_path, total_steps=10, checkpoint_every=5)
    loop = TrainLoop(cfg, jax.jit(step_fn), p, o, logger=lambda s: None)
    loop.run(_batches(7))  # stops at 7 via exhausted iterator; ckpt at 5 + final 7

    cfg2, step_fn2, p2, o2 = _setup(tmp_path, total_steps=10, checkpoint_every=5)
    loop2 = TrainLoop(cfg2, jax.jit(step_fn2), p2, o2, logger=lambda s: None)
    assert loop2.try_restore()
    assert loop2.step == 7
    out = loop2.run(_batches(3))
    assert out["final_step"] == 10
