"""ANNS layer: brute force, IVF, SQ8, MUVERA, token pruning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.anns import (
    MuveraConfig,
    build_ivf,
    build_token_pruning,
    doc_fde,
    mips_topk,
    query_fde,
    search_ivf,
    search_token_pruning,
    sq8_dequant,
    sq8_quant,
)

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def test_mips_topk_exact(rng):
    q = jnp.asarray(rng.standard_normal((5, 16)), jnp.float32)
    corpus = jnp.asarray(rng.standard_normal((200, 16)), jnp.float32)
    s, ids = mips_topk(q, corpus, 7, block=64)
    full = np.asarray(q @ corpus.T)
    want = np.argsort(-full, axis=1)[:, :7]
    assert (np.asarray(ids) == want).all()
    np.testing.assert_allclose(np.asarray(s), np.take_along_axis(full, want, 1), rtol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
def test_sq8_roundtrip_error_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((10, 32)) * rng.random() * 5, jnp.float32)
    q, s = sq8_quant(x)
    err = jnp.abs(sq8_dequant(q, s) - x)
    # symmetric scalar quantization: |err| <= scale/2 per element
    assert float(jnp.max(err - s[:, None] / 2)) <= 1e-6


def test_ivf_full_probe_matches_bruteforce(rng):
    corpus = jnp.asarray(rng.standard_normal((500, 16)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    idx = build_ivf(jax.random.PRNGKey(0), corpus, nlist=16, sq8=False)
    s, ids = search_ivf(idx, q, nprobe=16, k=10)
    _, want = mips_topk(q, corpus, 10)
    # same set (scores may tie-break differently)
    got = np.sort(np.asarray(ids), axis=1)
    exp = np.sort(np.asarray(want), axis=1)
    assert (got == exp).mean() > 0.98


def test_ivf_sq8_close_to_exact(rng):
    corpus = jnp.asarray(rng.standard_normal((400, 24)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((6, 24)), jnp.float32)
    idx = build_ivf(jax.random.PRNGKey(0), corpus, nlist=16, sq8=True)
    s, ids = search_ivf(idx, q, nprobe=16, k=10)
    _, want = mips_topk(q, corpus, 10)
    hits = (np.asarray(ids)[:, :, None] == np.asarray(want)[:, None, :]).any(1).mean()
    assert hits > 0.9  # int8 quantization may flip near-ties only


def test_ivf_all_ids_valid(rng):
    corpus = jnp.asarray(rng.standard_normal((100, 8)), jnp.float32)
    idx = build_ivf(jax.random.PRNGKey(0), corpus, nlist=8)
    q = jnp.asarray(rng.standard_normal((3, 8)), jnp.float32)
    _, ids = search_ivf(idx, q, nprobe=8, k=20)
    assert int(ids.min()) >= 0 and int(ids.max()) < 100
    # each row: no duplicate ids among valid entries
    for row in np.asarray(ids):
        assert len(set(row.tolist())) == len(row)


def test_muvera_fde_better_than_random(tiny_corpus):
    """FDE inner products correlate with MaxSim (Jayaram et al. Thm 2.1)."""
    from repro.core import maxsim
    from repro.data import synthetic

    cfg = MuveraConfig(r_reps=8, k_sim=3, final_dim=512)
    docs = jnp.asarray(tiny_corpus.doc_tokens[:100])
    mask = jnp.asarray(tiny_corpus.doc_mask[:100])
    q = jnp.asarray(synthetic.queries_from_corpus_query(tiny_corpus, 8, 4))
    qm = jnp.ones(q.shape[:2], bool)
    dfde = doc_fde(docs, mask, cfg)
    qfde = query_fde(q, qm, cfg)
    approx = qfde @ dfde.T
    _, truth = maxsim.true_topk(q, qm, docs, mask, 10)
    _, got = jax.lax.top_k(approx, 30)
    rec = (np.asarray(got)[:, :, None] == np.asarray(truth)[:, None, :]).any(1).mean()
    assert rec > 0.35  # far better than 30/100 random... at least signal


def test_token_pruning_candidates(tiny_corpus):
    from repro.core import maxsim
    from repro.data import synthetic

    docs = jnp.asarray(tiny_corpus.doc_tokens[:150])
    mask = jnp.asarray(tiny_corpus.doc_mask[:150])
    idx = build_token_pruning(jax.random.PRNGKey(0), docs, mask, nlist=32)
    q = jnp.asarray(synthetic.queries_from_corpus_query(tiny_corpus, 4, 4))
    qm = jnp.ones(q.shape[:2], bool)
    s, cand = search_token_pruning(idx, q, qm, nprobe=8, k_prime=50, m=150)
    _, truth = maxsim.true_topk(q, qm, docs, mask, 10)
    rec = (np.asarray(cand)[:, :, None] == np.asarray(truth)[:, None, :]).any(1).mean()
    assert rec > 0.3


def test_kmeans_decreases_quantization_error(rng):
    from repro.anns.kmeans import kmeans

    x = jnp.asarray(rng.standard_normal((400, 8)), jnp.float32)
    c1, a1 = kmeans(jax.random.PRNGKey(0), x, 16, iters=1)
    c10, a10 = kmeans(jax.random.PRNGKey(0), x, 16, iters=10)
    e1 = float(jnp.mean(jnp.sum(jnp.square(x - c1[a1]), -1)))
    e10 = float(jnp.mean(jnp.sum(jnp.square(x - c10[a10]), -1)))
    assert e10 <= e1 + 1e-5


def test_dessert_lsh_baseline(tiny_corpus):
    """DESSERT-style LSH set-sketch retrieves real candidates (§5.1 family)."""
    import jax.numpy as jnp

    from repro.anns.dessert import DessertConfig, build_dessert, search_dessert
    from repro.core import maxsim
    from repro.data import synthetic

    docs = jnp.asarray(tiny_corpus.doc_tokens[:200])
    mask = jnp.asarray(tiny_corpus.doc_mask[:200])
    q = jnp.asarray(synthetic.queries_from_corpus_query(tiny_corpus, 8, 4, seed=3))
    qm = jnp.ones(q.shape[:2], bool)
    _, truth = maxsim.true_topk(q, qm, docs, mask, 10)
    idx = build_dessert(docs, mask, DessertConfig(n_tables=32, n_bits=5))
    _, cand = search_dessert(idx, q, qm, k_prime=60)
    import numpy as np

    rec = (np.asarray(cand)[:, :, None] == np.asarray(truth)[:, None, :]).any(1).mean()
    assert rec > 0.3
