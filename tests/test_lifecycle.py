"""Learned-index lifecycle: drift detection, refresh, zero-downtime swap.

Contracts hardened here:

* **Mutation tap.**  ``core.pages`` notifies registered taps on add/delete
  with host payloads; a broken tap can never corrupt a mutation.
* **Drift signal.**  In-distribution adds keep the monitor quiet; a
  distribution shift (new topic centers) trips the typed ``DriftReport``.
  Fleet dedupe: two replicas applying the same logical add are counted once.
* **Refresh determinism + efficacy.**  ``build_refresh`` is bit-reproducible
  given (snapshot, seed); installing it recovers the exact-scan recall a
  drifted corpus lost, to within 2% of a from-scratch rebuild.
* **Install validation.**  Corrupt rebuilds (backend mismatch, bad shape,
  NaNs, truncated ann) raise ``CorruptIndexError`` with the served snapshot
  provably untouched.
* **Swap/search interleaving bit-identity.**  Random interleavings of
  ``submit``/``add``/``delete``/warm swap through the server (and fleet
  router) resolve every future bit-identical to a direct search against a
  REPLAY of the exact snapshot version stamped on it — fp32 and SQ8.
  (The 8-forced-host-device sharded twin lives in test_dist_serve.py.)

Every wait carries a timeout so a wedged barrier fails, not hangs.
"""
import threading
import time

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import LemurConfig
from repro.core import pages
from repro.data import synthetic
from repro.lifecycle import (ChaosInjector, DriftMonitor, LifecycleManager,
                             RefreshCompleted, SwapCompleted, build_refresh)
from repro.lifecycle.events import EventLog, LifecycleEvent
from repro.retriever import (CorruptIndexError, IVFBackendConfig,
                             LemurRetriever, SearchParams)
from repro.serving import BucketLadder, RetrieverServer

TIMEOUT = 120.0
PARAMS = SearchParams(k=5, k_prime=60)


@pytest.fixture(scope="module")
def base(tiny_corpus):
    cfg = LemurConfig(d=16, d_prime=32, m_pretrain=128, n_train=1024,
                      n_ols=512, epochs=4, k=5, k_prime=60, anns="ivf",
                      ivf=IVFBackendConfig(nprobe=16))
    return LemurRetriever.build(tiny_corpus, cfg, key=jax.random.PRNGKey(0))


def _in_dist(n, seed=0, skip=300):
    """Docs from the SAME topic centers as tiny_corpus (seed 0)."""
    big = synthetic.make_corpus(m=skip + n, d=16, avg_tokens=8, max_tokens=12,
                                n_centers=24, seed=seed)
    return big.doc_tokens[skip:], big.doc_mask[skip:]


def _shifted(n, seed=777, strength=4.0):
    """Docs from DIFFERENT, strongly-expressed topic centers — a topic
    burst the frozen quantizer has never seen (the drift scenario)."""
    c = synthetic.make_corpus(m=n, d=16, avg_tokens=8, max_tokens=12,
                              n_centers=6, topic_strength=strength, seed=seed)
    return c.doc_tokens, c.doc_mask


def _query(tq, seed):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((tq, 16)).astype(np.float32)
    return q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-9)


# --------------------------------------------------------------------------
# mutation tap
# --------------------------------------------------------------------------

def test_mutation_tap_payloads_and_isolation(base):
    r = base.clone()
    seen = []

    def tap(kind, ids, **payload):
        seen.append((kind, np.asarray(ids).copy(), set(payload)))

    def broken(kind, ids, **payload):
        raise RuntimeError("observer bug")

    pages.register_mutation_tap(tap)
    pages.register_mutation_tap(broken)
    try:
        toks, mask = _in_dist(4)
        r.add(toks, mask)               # broken tap must not break the add
        r.delete(r.last_added_ids[:2])
    finally:
        pages.unregister_mutation_tap(tap)
        pages.unregister_mutation_tap(broken)
    kinds = [s[0] for s in seen]
    assert kinds == ["add", "delete"]
    assert seen[0][2] == {"doc_tokens", "doc_mask", "w"}
    np.testing.assert_array_equal(seen[1][1], r.last_added_ids[:2])
    # unregistered: further mutations are silent
    n = len(seen)
    r.add(toks, mask)
    assert len(seen) == n


# --------------------------------------------------------------------------
# drift monitor
# --------------------------------------------------------------------------

def test_drift_monitor_quiet_in_distribution(base):
    r = base.clone()
    with DriftMonitor(r, reservoir=128, probes=64, seed=1) as mon:
        toks, mask = _in_dist(96)
        r.add(toks, mask)
        rep = mon.report()
    assert rep.n_reservoir == 96
    assert not rep.triggered, rep
    assert rep.fidelity_drop < 0.10
    assert rep.skew <= 0.25
    assert rep.coverage_ratio >= 0.35   # well clear of the 0.25 trigger


def test_drift_monitor_triggers_on_shift(base):
    r = base.clone()
    with DriftMonitor(r, reservoir=128, probes=64, seed=1) as mon:
        assert mon.maybe_report(min_reservoir=8) is None  # empty reservoir
        toks, mask = _shifted(96)
        r.add(toks, mask)
        r.delete(np.arange(0, 60))      # and the fit loses its support
        rep = mon.maybe_report(min_reservoir=8)
    assert rep is not None and rep.triggered, rep
    assert "coverage" in rep.reason
    assert rep.n_reservoir == 96


def test_drift_monitor_dedupes_fleet_replicas(base):
    r1, r2 = base.clone(), base.clone()
    with DriftMonitor(r1, reservoir=64, seed=1) as mon:
        toks, mask = _in_dist(8)
        r1.add(toks, mask)              # same logical mutation, two replicas
        r2.add(toks, mask)
        assert mon.n_mutations == 1
        assert mon.n_reservoir == 8
        r1.delete(r1.last_added_ids[:3])
        r2.delete(r2.last_added_ids[:3])
        assert mon.n_mutations == 2
        assert mon.n_reservoir == 5


# --------------------------------------------------------------------------
# refresh + install
# --------------------------------------------------------------------------

def _drift(r, *, n_add=96, n_del=60, seed=777):
    toks, mask = _shifted(n_add, seed=seed)
    r.add(toks, mask)
    if n_del:
        r.delete(np.arange(n_del))
    return r


def test_build_refresh_deterministic(base):
    r = _drift(base.clone())
    a = build_refresh(r, seed=3)
    b = build_refresh(r, seed=3)
    assert a.m0 == b.m0 and a.version == b.version
    np.testing.assert_array_equal(np.asarray(a.W), np.asarray(b.W))
    np.testing.assert_array_equal(np.asarray(a.ann.centroids),
                                  np.asarray(b.ann.centroids))
    np.testing.assert_array_equal(np.asarray(a.ann.ids), np.asarray(b.ann.ids))


def _exact_recall(r, q, qm, truth, k=5):
    from repro.core import maxsim as mx
    p = SearchParams(k=k, k_prime=64, use_ann=False)
    _, ids = r.search(q, qm, p)
    return float(np.mean(np.asarray(mx.recall_at(np.asarray(ids), truth))))


def test_install_refresh_recovers_recall(base, tiny_corpus):
    """The acceptance gate in miniature: post-swap exact-scan recall within
    2% of a from-scratch rebuild on the same final corpus."""
    from repro.core import maxsim as mx
    from repro.core.pages import gather_docs

    r = _drift(base.clone())
    res = build_refresh(r, seed=3)
    toks_extra, mask_extra = _shifted(16, seed=888)
    r.add(toks_extra, mask_extra)       # post-snapshot adds -> catch-up path
    v0 = r.version
    r.install_refresh(res)
    assert r.version == v0 + 1
    assert r._last_refresh_caught_up == 16

    # truth on the final live corpus
    alive = np.flatnonzero(np.asarray(r.index.store.alive)[:r.m])
    dt, dm = gather_docs(r.index.store, alive)
    q = synthetic.queries_held_out(
        synthetic.make_corpus(m=8, d=16, avg_tokens=8, max_tokens=12,
                              n_centers=6, topic_strength=4.0, seed=777),
        32, q_tokens=4, topic_strength=4.0, seed=9)
    qm = np.ones(q.shape[:2], bool)
    _, t_ids = mx.true_topk(q, qm, np.asarray(dt), np.asarray(dm), 5)
    truth = alive[np.asarray(t_ids)]

    swapped = _exact_recall(r, q, qm, truth)
    # from-scratch rebuild on the final live corpus
    live = synthetic.MultiVectorCorpus(np.asarray(dt), np.asarray(dm),
                                       np.zeros((len(alive), 1), np.int32),
                                       np.zeros((1, 16), np.float32))
    fresh = LemurRetriever.build(live, base.cfg, key=jax.random.PRNGKey(0))
    f_ids = fresh.search(q, qm, SearchParams(k=5, k_prime=64,
                                             use_ann=False))[1]
    f_truth = mx.true_topk(q, qm, np.asarray(dt), np.asarray(dm), 5)[1]
    rebuild = float(np.mean(np.asarray(
        mx.recall_at(np.asarray(f_ids), np.asarray(f_truth)))))
    assert swapped >= rebuild - 0.02, (swapped, rebuild)


def test_install_refresh_rejects_corrupt(base):
    r = _drift(base.clone())
    res = build_refresh(r, seed=3)
    snap, ver, solver = r.snapshot(), r.version, r._solver
    for broken in [
        res._replace(backend="muvera"),
        res._replace(m0=r.m + 7),
        res._replace(W=res.W[:-1]),
        res._replace(W=res.W.at[0, 0].set(np.nan)),
        res._replace(solver={"chol": res.solver["chol"]}),
        res._replace(ann=res.ann._replace(
            ids=res.ann.ids.at[:].set(10 ** 6))),   # out-of-range candidates
    ]:
        with pytest.raises(CorruptIndexError):
            r.install_refresh(broken)
        assert r.snapshot() is snap          # provably untouched
        assert r.version == ver and r._solver is solver
    r.install_refresh(res)                   # the pristine result still lands
    assert r.version == ver + 1


def test_event_log_bounded():
    log = EventLog(maxlen=4)
    for i in range(7):
        log.append(LifecycleEvent(t=float(i)))
    assert len(log) == 4
    assert log.dropped == 3
    assert [e.t for e in log.events()] == [3.0, 4.0, 5.0, 6.0]


def test_chaos_injector_arms_once():
    ch = ChaosInjector()
    ch.fail_at("p", times=2)
    for _ in range(2):
        with pytest.raises(Exception):
            ch.check("p")
    ch.check("p")                            # disarmed after `times` fires
    assert ch.fired("p") == 2


# --------------------------------------------------------------------------
# warm swap through the server: FIFO barrier + replay bit-identity
# --------------------------------------------------------------------------

def _replay(base, log, upto):
    """Rebuild the exact snapshot after the first ``upto`` mutations."""
    r = base.clone()
    for op in log[:upto]:
        if op[0] == "add":
            r.add(op[1], op[2], seed=op[3])
        elif op[0] == "delete":
            r.delete(op[1])
        else:
            r.install_refresh(op[1])
    return r


def _check_interleaving(base, seed, n_ops=18):
    rng = np.random.default_rng(seed)
    serve_r = base.clone()
    v0 = serve_r.version
    mlog = []           # ordered mutation log, exact payloads
    searches = []       # (future, q, qm)
    ladder = BucketLadder((32,), max_batch=4)
    with RetrieverServer(serve_r, ladder=ladder, max_wait_us=200,
                         default_params=PARAMS) as srv:
        mut_futs = []
        for k in range(n_ops):
            roll = rng.random()
            if roll < 0.45:
                q = _query(int(rng.integers(2, 10)), seed=1000 * seed + k)
                qm = np.ones(q.shape[0], bool)
                searches.append((srv.submit(q, qm), q, qm))
            elif roll < 0.65:
                toks, mask = _in_dist(int(rng.integers(2, 6)),
                                      seed=int(rng.integers(1, 10)))
                s = int(rng.integers(0, 100))
                mlog.append(("add", toks, mask, s))
                mut_futs.append(srv.add(toks, mask, seed=s))
            elif roll < 0.8 and mlog:
                # delete something known-alive: replay the log so far
                ref = _replay(base, mlog, len(mlog))
                alive = np.flatnonzero(np.asarray(ref.index.store.alive))
                pick = rng.choice(alive, size=min(2, alive.size),
                                  replace=False).astype(np.int32)
                mlog.append(("delete", pick))
                mut_futs.append(srv.delete(pick))
            else:
                for f in mut_futs:
                    f.result(timeout=TIMEOUT)   # settle, then snapshot
                res = build_refresh(serve_r, seed=int(rng.integers(100)))
                mlog.append(("swap", res))
                mut_futs.append(srv.apply(
                    lambda r, res=res: r.install_refresh(res)))
        for f in mut_futs:
            f.result(timeout=TIMEOUT)
    # every resolved search: bit-identical to a replay of its stamped version
    assert len(mlog) == serve_r.version - v0
    for fut, q, qm in searches:
        s, ids = fut.result(timeout=TIMEOUT)
        v = fut.snapshot_version
        assert v is not None
        rep = _replay(base, mlog, v - v0)
        assert rep.version == v
        ws, wi = rep.search(q[None], qm[None], PARAMS)
        np.testing.assert_array_equal(np.asarray(ids), np.asarray(wi)[0])
        np.testing.assert_allclose(np.asarray(s), np.asarray(ws)[0],
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("seed", [0, 1])
def test_swap_interleaving_bit_identity_fp32(base, seed):
    _check_interleaving(base, seed)


def test_swap_interleaving_bit_identity_sq8(base):
    cfg = base.cfg.replace(anns="ivf", ivf=IVFBackendConfig(sq8=True,
                                                            nprobe=16))
    sq8 = base.with_backend("ivf", key=jax.random.PRNGKey(1), cfg=cfg)
    _check_interleaving(sq8, 2)


@settings(deadline=None, max_examples=4)
@given(seed=st.integers(10, 10_000))
def test_swap_interleaving_bit_identity_random(base, seed):
    _check_interleaving(base, seed, n_ops=12)


def test_server_swap_is_fifo_barrier(base):
    """Searches enqueued before the swap resolve at the old version, after
    at the new — regardless of when results are awaited."""
    serve_r = base.clone()
    res = build_refresh(serve_r, seed=5)
    q = _query(4, seed=0)
    qm = np.ones(4, bool)
    with RetrieverServer(serve_r, ladder=BucketLadder((32,), max_batch=2),
                         max_wait_us=100, default_params=PARAMS) as srv:
        srv.pause()
        before = [srv.submit(q, qm) for _ in range(3)]
        swap = srv.apply(lambda r: r.install_refresh(res))
        after = [srv.submit(q, qm) for _ in range(3)]
        srv.resume()
        swap.result(timeout=TIMEOUT)
        v1 = serve_r.version
        for f in after:
            f.result(timeout=TIMEOUT)
            assert f.snapshot_version == v1
        for f in before:
            f.result(timeout=TIMEOUT)
            assert f.snapshot_version == v1 - 1


def test_lifecycle_manager_closes_the_loop(base):
    """Server + manager, manual drive: drift -> refresh -> swap with typed
    events and a version bump; monitor recalibrated afterwards."""
    serve_r = base.clone()
    with RetrieverServer(serve_r, ladder=BucketLadder((32,), max_batch=4),
                         max_wait_us=200, default_params=PARAMS) as srv:
        mon = DriftMonitor(serve_r, reservoir=128, probes=64, seed=1)
        mgr = LifecycleManager(srv, monitor=mon, seed=3, cooldown_s=0.0,
                               min_reservoir=8)
        mgr.start(auto=False)
        try:
            toks, mask = _shifted(96)
            srv.add(toks, mask).result(timeout=TIMEOUT)
            srv.delete(np.arange(60)).result(timeout=TIMEOUT)
            v0 = serve_r.version
            assert mgr.poll_once()          # triggered -> refresh -> swap
            assert serve_r.version == v0 + 1
            assert mgr.n_swaps == 1
            assert mgr.events(RefreshCompleted)
            done = mgr.events(SwapCompleted)
            assert done and done[-1].version == serve_r.version
            assert mon.n_reservoir == 0     # reset after swap
            # post-swap: a search still answers
            srv.search(_query(4, 1), np.ones(4, bool), timeout=TIMEOUT)
        finally:
            mgr.stop()
