"""Parity suite for the gather-at-source serving kernels (PR: fused serving).

Three contracts, each across a shape grid that includes ``-1``-padded
candidate rows, ``k >`` #valid-candidates, non-128-multiple ``d``, tiny
cluster capacity, and ``B=1``:

* fused IVF probe scan (``search_ivf(use_fused_gather=True)``) returns
  bit-identical ids to the legacy gather-then-score path on fp32, and
  ≤2^-16-relative scores on SQ8 (the in-kernel hi/lo-bf16 dequant);
* fused candidate-gather rerank (``ops.fused_rerank``) is bit-identical to
  the ``maxsim.rerank`` oracle on fp32 (ids AND scores);
* the interpret-mode Pallas kernels themselves (``use_kernel=True``) match
  the pure-jnp refs.

Plus the compilation contract: the fused path still compiles exactly once
per (backend, resolved params, batch shape), and the fused/legacy toggle is
part of the compiled-fn key (flipping it may not silently reuse a trace).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.anns import ivf
from repro.anns.quantization import sq8_quant
from repro.kernels import gather_scan, ops, ref

SQ8_RTOL = 2 ** -16 * 4  # hi/lo bf16 split: ~2^-16 relative, small slack


def _mk_ivf(rng, m, d, nlist, *, sq8):
    vecs = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
    return ivf.build_ivf(jax.random.PRNGKey(0), vecs, nlist, sq8=sq8,
                         kmeans_iters=2)


# --------------------------------------------------------------------------
# fused IVF scan vs the legacy search_ivf path
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,m,d,nlist,nprobe,k", [
    (8, 200, 16, 16, 4, 10),
    (1, 120, 24, 16, 3, 5),       # B=1, non-128-multiple d
    (5, 60, 20, 16, 16, 100),     # k > #valid candidates in the probed lists
    (4, 40, 8, 32, 8, 6),         # tiny clusters (cap < any realistic block)
])
@pytest.mark.parametrize("sq8", [False, True])
def test_fused_ivf_scan_matches_legacy(B, m, d, nlist, nprobe, k, sq8):
    rng = np.random.default_rng(B * m + d)
    index = _mk_ivf(rng, m, d, nlist, sq8=sq8)
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    ws, wi = ivf.search_ivf(index, q, nprobe, k, use_fused_gather=False)
    gs, gi = ivf.search_ivf(index, q, nprobe, k, use_fused_gather=True)
    if not sq8:
        # fp32: bit-exact — identical contraction, identical top-k
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
    else:
        fin = np.isfinite(np.asarray(ws))
        np.testing.assert_array_equal(np.isfinite(np.asarray(gs)), fin)
        np.testing.assert_allclose(np.asarray(gs)[fin], np.asarray(ws)[fin],
                                   rtol=SQ8_RTOL, atol=1e-5)
    # the (B, k) pad contract survives either path: same -1 columns
    np.testing.assert_array_equal(np.asarray(gi) < 0, np.asarray(wi) < 0)


def test_fused_ivf_scan_strip_masks_pads():
    """The kernel-facing scan masks every padded cluster slot to -inf."""
    rng = np.random.default_rng(0)
    index = _mk_ivf(rng, 50, 12, 16, sq8=False)   # ragged lists => many pads
    q = jnp.asarray(rng.standard_normal((3, 12)), jnp.float32)
    probe = jnp.asarray(rng.integers(0, index.nlist, (3, 5)), jnp.int32)
    s = ops.fused_ivf_scan(q, probe, index.ids, index.vecs, index.scales)
    pads = np.asarray(jnp.take(index.ids, probe, axis=0)) < 0
    assert np.all(np.isneginf(np.asarray(s)[pads]))
    assert np.all(np.isfinite(np.asarray(s)[~pads]))


# --------------------------------------------------------------------------
# fused rerank vs the maxsim.rerank oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,m,Tq,Td,d,kp,k", [
    (6, 40, 5, 7, 16, 8, 4),
    (1, 30, 3, 4, 20, 6, 3),      # B=1, non-128-multiple d
    (4, 25, 4, 6, 16, 10, 10),    # k == k', rows with < k valid candidates
])
def test_fused_rerank_matches_oracle(B, m, Tq, Td, d, kp, k):
    from repro.core import maxsim

    rng = np.random.default_rng(B + m + kp)
    q = jnp.asarray(rng.standard_normal((B, Tq, d)), jnp.float32)
    qm = jnp.asarray(rng.random((B, Tq)) > 0.3).at[:, 0].set(True)
    docs = jnp.asarray(rng.standard_normal((m, Td, d)), jnp.float32)
    dm = jnp.asarray(rng.random((m, Td)) > 0.3).at[:, 0].set(True)
    cand = jnp.asarray(rng.integers(-1, m, (B, kp)), jnp.int32)  # -1 pads mixed in
    ws, wi = maxsim.rerank(q, qm, cand, docs, dm, k)
    gs, gi = ops.fused_rerank(q, qm, cand, docs, dm, k)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))


def test_fused_rerank_pads_beyond_kprime():
    """k > k': the fused path pads out to (B, k) with (NEG, -1) instead of
    crashing — strictly wider than the oracle's contract."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((2, 3, 8)), jnp.float32)
    qm = jnp.ones((2, 3), bool)
    docs = jnp.asarray(rng.standard_normal((10, 4, 8)), jnp.float32)
    dm = jnp.ones((10, 4), bool)
    cand = jnp.asarray([[1, 2, -1], [3, -1, -1]], jnp.int32)
    s, i = ops.fused_rerank(q, qm, cand, docs, dm, 5)
    assert s.shape == (2, 5) and i.shape == (2, 5)
    assert np.all(np.asarray(i)[:, 3:] == -1)
    assert np.all(np.asarray(i)[0, :2] >= 0) and np.asarray(i)[1, 0] >= 0


def test_fused_rerank_sq8_matches_sharded_math():
    """SQ8 rerank (per-token scales folded into score rows) == the exact
    gather-then-contract reference, and ≤2^-16-relative via the kernel."""
    rng = np.random.default_rng(2)
    B, m, Tq, Td, d, kp = 3, 20, 4, 5, 16, 6
    q = jnp.asarray(rng.standard_normal((B, Tq, d)), jnp.float32)
    qm = jnp.ones((B, Tq), bool)
    docs = jnp.asarray(rng.standard_normal((m, Td, d)), jnp.float32)
    dm = jnp.asarray(rng.random((m, Td)) > 0.2).at[:, 0].set(True)
    codes, scales = sq8_quant(docs)
    cand = jnp.asarray(rng.integers(0, m, (B, kp)), jnp.int32)
    want = ref.rerank_scores_ref(q, qm, cand, codes, dm, scales)
    got = gather_scan.rerank_gather_scores(q, qm, cand, codes, dm, scales,
                                           interpret=True)
    denom = max(float(jnp.max(jnp.abs(want))), 1.0)
    assert float(jnp.max(jnp.abs(got - want))) / denom < SQ8_RTOL


# --------------------------------------------------------------------------
# the Pallas kernels themselves (interpret mode) vs the jnp refs
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,nlist,cap,d,nprobe", [
    (4, 8, 5, 12, 3),     # tiny cap, non-128 d
    (1, 16, 9, 32, 8),    # B=1
])
def test_ivf_scan_kernel_interpret_vs_ref(B, nlist, cap, d, nprobe):
    rng = np.random.default_rng(B * nlist)
    ids = jnp.asarray(rng.integers(-1, 99, (nlist, cap)), jnp.int32)
    vecs = jnp.asarray(rng.standard_normal((nlist, cap, d)),
                       jnp.float32) * (ids >= 0)[..., None]
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    probe = jnp.asarray(rng.integers(0, nlist, (B, nprobe)), jnp.int32)
    out = gather_scan.ivf_probe_scan(q, probe, ids, vecs, interpret=True)
    want = ref.ivf_scan_ref(q, probe, ids, vecs)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    # SQ8 variant: in-kernel dequant within the hi/lo-bf16 tolerance
    codes, scales = sq8_quant(vecs)
    out = gather_scan.ivf_probe_scan(q, probe, ids, codes, scales,
                                     interpret=True)
    want = ref.ivf_scan_ref(q, probe, ids, codes, scales)
    fin = np.isfinite(np.asarray(want))
    np.testing.assert_array_equal(np.isfinite(np.asarray(out)), fin)
    denom = max(float(np.max(np.abs(np.asarray(want)[fin]))), 1.0)
    assert np.max(np.abs(np.asarray(out)[fin] - np.asarray(want)[fin])) / denom \
        < SQ8_RTOL


def test_rerank_kernel_interpret_vs_ref():
    rng = np.random.default_rng(5)
    B, m, Tq, Td, d, kp = 3, 15, 4, 6, 20, 5
    q = jnp.asarray(rng.standard_normal((B, Tq, d)), jnp.float32)
    qm = jnp.asarray(rng.random((B, Tq)) > 0.4).at[:, 0].set(True)
    docs = jnp.asarray(rng.standard_normal((m, Td, d)), jnp.float32)
    dm = jnp.asarray(rng.random((m, Td)) > 0.4).at[:, 0].set(True)
    cand = jnp.asarray(rng.integers(-1, m, (B, kp)), jnp.int32)
    out = gather_scan.rerank_gather_scores(q, qm, cand, docs, dm,
                                           interpret=True)
    want = ref.rerank_scores_ref(q, qm, cand, docs, dm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("B,nlist,cap,d,nprobe,bits", [
    (4, 8, 5, 16, 3, 4),      # tiny cap
    (1, 16, 9, 8, 8, 2),      # B=1, 2-bit codes
])
def test_ivf_res_scan_kernel_interpret_vs_ref(B, nlist, cap, d, nprobe, bits):
    """Residual-tier probe scan (in-kernel decode-at-source) is BIT-identical
    to the host decode-then-score oracle — the one-hot decode sums exactly
    one fp32 term per element, so no tolerance is needed."""
    rng = np.random.default_rng(B * nlist + bits)
    ids = jnp.asarray(rng.integers(-1, 99, (nlist, cap)), jnp.int32)
    codes = jnp.asarray(rng.integers(0, 256, (nlist, cap, d * bits // 8)),
                        jnp.uint8)
    centroids = jnp.asarray(rng.standard_normal((nlist, d)), jnp.float32)
    values = jnp.asarray(np.sort(rng.standard_normal((d, 1 << bits)), axis=1),
                         jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    probe = jnp.asarray(rng.integers(0, nlist, (B, nprobe)), jnp.int32)
    out = gather_scan.ivf_probe_res_scan(q, probe, ids, codes, centroids,
                                         values, interpret=True)
    want = ref.ivf_scan_res_ref(q, probe, ids, codes, centroids, values)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("B,C,Tq,d,kp,bits", [
    (3, 12, 4, 16, 5, 4),
    (1, 8, 3, 8, 6, 2),       # B=1, 2-bit, k' > #docs
])
def test_rerank_paged_res_kernel_interpret_vs_ref(B, C, Tq, d, kp, bits):
    """Residual-tier paged rerank (compressed pages decoded in VMEM) is
    bit-identical to decoding the whole pool host-side and running the fp32
    paged oracle, -1 pads and short docs included."""
    rng = np.random.default_rng(B * C + bits)
    page, pmax = 4, 2
    P = C * pmax
    cent_pages = jnp.asarray(rng.integers(0, 10, (P, page)), jnp.int32)
    code_pages = jnp.asarray(rng.integers(0, 256, (P, page, d * bits // 8)),
                             jnp.uint8)
    centroids = jnp.asarray(rng.standard_normal((10, d)), jnp.float32)
    values = jnp.asarray(np.sort(rng.standard_normal((d, 1 << bits)), axis=1),
                         jnp.float32)
    table = jnp.asarray(
        rng.permutation(P).reshape(C, pmax), jnp.int32)
    n_tokens = jnp.asarray(rng.integers(1, pmax * page + 1, (C,)), jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, Tq, d)), jnp.float32)
    qm = jnp.asarray(rng.random((B, Tq)) > 0.3).at[:, 0].set(True)
    cand = jnp.asarray(rng.integers(-1, C, (B, kp)), jnp.int32)
    out = gather_scan.rerank_paged_res_scores(
        q, qm, cand, cent_pages, code_pages, table, n_tokens, centroids,
        values, interpret=True)
    want = ref.rerank_scores_paged_res_ref(
        q, qm, cand, cent_pages, code_pages, table, n_tokens, centroids,
        values)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_ops_fused_dispatch_kernel_vs_ref():
    """ops wrappers: forced-kernel (interpret) results == forced-ref results
    (fp32 exact), i.e. platform dispatch cannot change answers."""
    rng = np.random.default_rng(9)
    index = _mk_ivf(rng, 80, 16, 16, sq8=False)
    q = jnp.asarray(rng.standard_normal((2, 16)), jnp.float32)
    probe = jnp.asarray(rng.integers(0, index.nlist, (2, 4)), jnp.int32)
    a = ops.fused_ivf_scan(q, probe, index.ids, index.vecs, use_kernel=True)
    b = ops.fused_ivf_scan(q, probe, index.ids, index.vecs, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mips_sq8_batched_single_call_equivalence():
    """The batched SQ8 fallback (ONE contraction / ONE flattened kernel
    launch) == B independent per-row scans."""
    rng = np.random.default_rng(11)
    B, n, d = 5, 12, 16
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    codes = jnp.asarray(rng.integers(-127, 128, (B, n, d)), jnp.int8)
    scales = jnp.asarray(rng.random((B, n)) + 0.1, jnp.float32)
    want = jnp.stack([ref.mips_sq8_ref(q[b:b + 1], codes[b], scales[b])[0]
                      for b in range(B)])
    got_ref = ops.mips_sq8_batched(q, codes, scales, use_kernel=False)
    # fp32 associativity: batched einsum vs per-row matmul reduction order
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    got_kern = ops.mips_sq8_batched(q, codes, scales, use_kernel=True,
                                    block_q=8, block_m=32)
    denom = max(float(jnp.max(jnp.abs(want))), 1.0)
    assert float(jnp.max(jnp.abs(got_kern - want))) / denom < SQ8_RTOL


# --------------------------------------------------------------------------
# compilation contract
# --------------------------------------------------------------------------

def test_fused_path_trace_count(tiny_corpus):
    """One jit trace per (backend, resolved params, batch shape) with the
    fused path on (the default), and the fused/legacy toggle is a distinct
    cache entry — equivalent spellings of the default still share one."""
    from repro.core import LemurConfig
    from repro.retriever import IVFSearchParams, LemurRetriever, SearchParams

    cfg = LemurConfig(d=16, d_prime=24, m_pretrain=64, n_train=512, n_ols=256,
                      epochs=2, k=5, k_prime=32, anns="ivf")
    r = LemurRetriever.build(tiny_corpus, cfg, key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((4, 6, 16)), jnp.float32)
    qm = jnp.ones((4, 6), bool)

    fused = SearchParams()
    r.search(q, qm, fused)
    r.search(q, qm, fused)
    # explicit spelling of the resolved default => same compiled fn
    r.search(q, qm, SearchParams(
        use_fused_gather=True, backend=IVFSearchParams(use_fused_gather=True)))
    assert r.trace_count(fused) == 1
    assert r.trace_count() == 1

    legacy = SearchParams(use_fused_gather=False,
                          backend=IVFSearchParams(use_fused_gather=False))
    r.search(q, qm, legacy)
    assert r.trace_count(legacy) == 1
    assert r.trace_count() == 2

    # new batch shape => exactly one more trace for the fused entry
    r.search(q[:2], qm[:2], fused)
    assert r.trace_count(fused) == 2
