"""Property tests for the MaxSim core (hypothesis) + consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import maxsim

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@given(seed=st.integers(0, 2**31 - 1), tq=st.integers(1, 6), td=st.integers(1, 7),
       m=st.integers(1, 9))
def test_scores_match_pairwise(seed, tq, td, m):
    rng = np.random.default_rng(seed)
    q = _rand(rng, 2, tq, 8)
    qm = jnp.asarray(rng.random((2, tq)) > 0.2)
    docs = _rand(rng, m, td, 8)
    dm = jnp.asarray(rng.random((m, td)) > 0.2)
    dm = dm.at[:, 0].set(True)  # no empty docs
    s = maxsim.maxsim_scores(q, qm, docs, dm, block=4)
    for b in range(2):
        for j in range(m):
            ref = maxsim.maxsim_pair(q[b], qm[b], docs[j], dm[j])
            assert abs(float(s[b, j]) - float(ref)) < 1e-4


@given(seed=st.integers(0, 2**31 - 1))
def test_doc_token_permutation_invariance(seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, 1, 4, 8)
    qm = jnp.ones((1, 4), bool)
    docs = _rand(rng, 3, 6, 8)
    dm = jnp.ones((3, 6), bool)
    perm = rng.permutation(6)
    s1 = maxsim.maxsim_scores(q, qm, docs, dm)
    s2 = maxsim.maxsim_scores(q, qm, docs[:, perm], dm[:, perm])
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
def test_query_token_permutation_invariance(seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, 1, 5, 8)
    qm = jnp.ones((1, 5), bool)
    docs = _rand(rng, 3, 6, 8)
    dm = jnp.ones((3, 6), bool)
    perm = rng.permutation(5)
    s1 = maxsim.maxsim_scores(q, qm, docs, dm)
    s2 = maxsim.maxsim_scores(q[:, perm], qm, docs, dm)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
def test_duplicate_doc_token_is_noop(seed):
    """max over tokens is idempotent under duplication."""
    rng = np.random.default_rng(seed)
    q = _rand(rng, 1, 4, 8)
    qm = jnp.ones((1, 4), bool)
    docs = _rand(rng, 2, 5, 8)
    dm = jnp.ones((2, 5), bool)
    dup = jnp.concatenate([docs, docs[:, :1]], axis=1)
    dmm = jnp.ones((2, 6), bool)
    s1 = maxsim.maxsim_scores(q, qm, docs, dm)
    s2 = maxsim.maxsim_scores(q, qm, dup, dmm)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5)


@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 5.0))
def test_query_scale_equivariance(seed, scale):
    rng = np.random.default_rng(seed)
    q = _rand(rng, 1, 3, 8)
    qm = jnp.ones((1, 3), bool)
    docs = _rand(rng, 4, 5, 8)
    dm = jnp.ones((4, 5), bool)
    s1 = maxsim.maxsim_scores(q, qm, docs, dm)
    s2 = maxsim.maxsim_scores(q * scale, qm, docs, dm)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s1) * scale, rtol=1e-4)


def test_token_maxsim_matches_scores(rng):
    q = _rand(rng, 2, 4, 8)
    qm = jnp.ones((2, 4), bool)
    docs = _rand(rng, 10, 6, 8)
    dm = jnp.asarray(rng.random((10, 6)) > 0.3)
    dm = dm.at[:, 0].set(True)
    g = maxsim.token_maxsim(q.reshape(8, 8), docs, dm, block=3)
    s = g.reshape(2, 4, 10).sum(axis=1)
    ref = maxsim.maxsim_scores(q, qm, docs, dm)
    np.testing.assert_allclose(np.asarray(s), np.asarray(ref), rtol=1e-4)


def test_rerank_full_equals_true_topk(rng):
    q = _rand(rng, 3, 4, 8)
    qm = jnp.ones((3, 4), bool)
    docs = _rand(rng, 20, 6, 8)
    dm = jnp.ones((20, 6), bool)
    ts, ti = maxsim.true_topk(q, qm, docs, dm, 5)
    all_cands = jnp.broadcast_to(jnp.arange(20)[None], (3, 20))
    rs, ri = maxsim.rerank(q, qm, all_cands, docs, dm, 5)
    np.testing.assert_allclose(np.asarray(rs), np.asarray(ts), rtol=1e-5)
    assert (np.asarray(ri) == np.asarray(ti)).all()


def test_recall_at():
    got = jnp.asarray([[1, 2, 3], [4, 5, 6]])
    truth = jnp.asarray([[1, 9, 3], [6, 5, 4]])
    r = maxsim.recall_at(got, truth)
    np.testing.assert_allclose(np.asarray(r), [2 / 3, 1.0])
