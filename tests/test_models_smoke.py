"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + finiteness.  Exercises every family path the dry-run
compiles at full scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_arch
from repro.optim import adam_init

LM_ARCHS = ["qwen2.5-32b", "granite-20b", "gemma-7b",
            "llama4-maverick-400b-a17b", "deepseek-v3-671b"]
RECSYS_ARCHS = ["deepfm", "xdeepfm", "bst", "two-tower-retrieval"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    from repro.models import lm

    cfg = get_arch(arch).SMOKE
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    step = lm.make_train_step(cfg)
    opt = adam_init(params)
    p2, o2, m = jax.jit(step)(params, opt, {"tokens": toks, "labels": toks})
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_prefill_decode(arch):
    from repro.models import lm

    cfg = get_arch(arch).SMOKE
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, caches = lm.prefill(params, toks, cfg, 24)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    step = lm.make_decode_step(cfg)
    nxt, lg, caches2 = step(params, toks[:, -1:], caches, 17)
    assert nxt.shape == (2, 1)
    assert bool(jnp.all(jnp.isfinite(lg)))
    # cache structure preserved
    assert jax.tree_util.tree_structure(caches) == jax.tree_util.tree_structure(caches2)


def test_lm_decode_matches_train_dense():
    """Decode path == train forward logits at the same position (gemma smoke:
    tied embeddings, GeGLU, embed-scale — the richest dense path)."""
    from repro.models import lm
    from repro.nn import layers as L

    cfg = get_arch("gemma-7b").SMOKE
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    h, _ = lm.forward_train(params, toks, cfg)
    ref = L.embed_logits(params["embed"], h[:, -1])
    _, caches = lm.prefill(params, toks[:, :-1], cfg, 32)
    _, lg, _ = lm.make_decode_step(cfg)(params, toks[:, -1:], caches, 24)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_llama4_layer_pattern():
    from repro.models import lm

    cfg = get_arch("llama4-maverick-400b-a17b").CONFIG
    stacks = lm.layer_stacks(cfg)
    assert len(stacks) == 1
    n_blocks, block = stacks[0]
    assert n_blocks * len(block) == 48
    assert [s.is_moe for s in block] == [False, True, False, True]
    assert block[3].chunk == 0 and block[0].chunk == 8192  # full attn every 4th


def test_deepseek_layer_pattern():
    from repro.models import lm

    cfg = get_arch("deepseek-v3-671b").CONFIG
    stacks = lm.layer_stacks(cfg)
    assert stacks[0][0] == 3 and not stacks[0][1][0].is_moe      # dense prefix
    assert stacks[1][0] == 58 and stacks[1][1][0].is_moe


def test_param_counts_match_public_sizes():
    """Sanity: derived parameter counts within 15% of the published sizes."""
    from repro.models import lm

    expect = {
        "qwen2.5-32b": 32.8e9,
        "granite-20b": 20e9,
        "gemma-7b": 8.5e9,   # gemma-7b is 8.5B with its 256k embed
        "llama4-maverick-400b-a17b": 400e9,
        "deepseek-v3-671b": 671e9,
    }
    for arch, want in expect.items():
        got = lm.param_count(get_arch(arch).CONFIG)
        assert abs(got - want) / want < 0.18, (arch, got, want)
    # active params
    a = lm.active_param_count(get_arch("llama4-maverick-400b-a17b").CONFIG)
    assert abs(a - 17e9) / 17e9 < 0.35, a
    a = lm.active_param_count(get_arch("deepseek-v3-671b").CONFIG)
    assert abs(a - 37e9) / 37e9 < 0.25, a


def test_gnn_smoke_full_and_sampled():
    from repro.data import synthetic
    from repro.models import gnn

    cfg = get_arch("meshgraphnet").SMOKE
    g = synthetic.make_mesh_graph(120, d_feat=cfg.d_node_in, d_edge=cfg.d_edge_in,
                                  d_out=cfg.d_out)
    params = gnn.init_gnn(jax.random.PRNGKey(0), cfg)
    batch = {"node_feat": jnp.asarray(g.node_feat), "edge_feat": jnp.asarray(g.edge_feat),
             "senders": jnp.asarray(g.senders), "receivers": jnp.asarray(g.receivers),
             "labels": jnp.asarray(g.labels)}
    step = gnn.make_train_step(cfg)
    opt = adam_init(params)
    p, o, m = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(m["loss"]))

    scfg = cfg.replace(task="classification", d_out=3)
    sp = gnn.init_gnn(jax.random.PRNGKey(0), scfg)
    sb = {"row_ptr": jnp.asarray(g.row_ptr), "col_idx": jnp.asarray(g.col_idx),
          "node_feat": jnp.asarray(g.node_feat), "seeds": jnp.arange(8),
          "labels": jnp.zeros(8, jnp.int32)}
    sstep = gnn.make_sampled_train_step(scfg)
    so = adam_init(sp)
    sp, so, sm = jax.jit(sstep)(sp, so, jax.random.PRNGKey(2), sb)
    assert np.isfinite(float(sm["loss"]))


def test_gnn_sampler_respects_graph():
    from repro.data import synthetic
    from repro.models.gnn import sample_neighbors

    g = synthetic.make_mesh_graph(80, seed=1)
    nodes = jnp.arange(20)
    nbrs = sample_neighbors(jax.random.PRNGKey(0), jnp.asarray(g.row_ptr),
                            jnp.asarray(g.col_idx), nodes, 5)
    assert nbrs.shape == (20, 5)
    rp, ci = np.asarray(g.row_ptr), np.asarray(g.col_idx)
    for i, v in enumerate(np.asarray(nodes)):
        allowed = set(ci[rp[v]:rp[v + 1]].tolist()) | {int(v)}
        assert set(np.asarray(nbrs[i]).tolist()) <= allowed


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_train_step(arch):
    from repro.data import synthetic
    from repro.models import recsys

    cfg = get_arch(arch).SMOKE
    params = recsys.init_recsys(jax.random.PRNGKey(0), cfg)
    data = synthetic.make_clicks(32, max(cfg.n_fields, 1), np.array(cfg.vocab_sizes or [10]),
                                 hist_len=cfg.seq_len, n_items=cfg.n_items)
    if cfg.model == "bst":
        batch = {"history": jnp.asarray(data["history"]),
                 "target_item": jnp.asarray(data["target_item"]),
                 "labels": jnp.asarray(data["labels"])}
    elif cfg.model == "two_tower":
        batch = {"ids": jnp.asarray(data["ids"][:, :cfg.n_fields]),
                 "item": jnp.asarray(data["target_item"]),
                 "labels": jnp.asarray(data["labels"])}
    else:
        batch = {"ids": jnp.asarray(data["ids"][:, :cfg.n_fields]),
                 "labels": jnp.asarray(data["labels"])}
    step = recsys.make_train_step(cfg)
    opt = adam_init(params)
    p, o, m = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(m["loss"])), arch
    # loss should move after a few steps
    for _ in range(4):
        p, o, m = jax.jit(step)(p, o, batch)
    assert np.isfinite(float(m["loss"]))


def test_embedding_bag_matches_manual():
    from repro.models.recsys import embedding_bag

    table = jnp.asarray(np.random.default_rng(0).standard_normal((50, 8)), jnp.float32)
    ids = jnp.asarray([[1, 2, 0, 0], [3, 0, 0, 0]])  # 0 = pad
    out = embedding_bag(table, ids, combiner="mean")
    want0 = (table[1] + table[2]) / 2
    want1 = table[3]
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want0), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(want1), rtol=1e-5)


def test_all_archs_registered():
    assert len(ARCHS) == 11  # 10 assigned + lemur
    for arch in ARCHS:
        mod = get_arch(arch)
        assert hasattr(mod, "CONFIG") and hasattr(mod, "SHAPES") and hasattr(mod, "SMOKE")
        assert len(mod.SHAPES) >= 2
