"""Online serving runtime conformance (repro.serving).

Three contracts, hardened across every registered first-stage backend:

* **Ragged-shape conformance.**  For query lengths straddling every bucket
  boundary of the default ladder (Tq = 1, 31, 32, 33, 255, 256), the
  server's bucketed/micro-batched answer must carry bit-identical top-k
  ids to a direct ``retriever.search()`` of the raw ragged query (scores
  to float-reduction tolerance), and the ladder padding itself must be a
  no-op: searching the zero-padded/False-masked query directly returns the
  same ids as the unpadded one.
* **Queue semantics.**  Random interleavings of ``submit``/``add`` never
  drop, duplicate, or cross-wire a request id, and queries submitted after
  an ``add`` see the new docs (FIFO barrier).  Runs as a deterministic
  grid everywhere plus a hypothesis sweep when installed
  (tests/_hypothesis_compat.py).
* **Compile bound.**  100 random request shapes churn through the server
  without the compiled-fn cache ever exceeding the bucket-ladder bound
  (``trace_count()`` / ``trace_shapes()``).

Every blocking wait carries an explicit timeout so a deadlocked
micro-batcher fails the test instead of hanging the suite.
"""
import concurrent.futures as cf
import threading
import time

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.anns import registry
from repro.core import LemurConfig
from repro.retriever import IVFBackendConfig, LemurRetriever, SearchParams
from repro.serving import BucketLadder, RetrieverServer, pad_single

BACKENDS = registry.list_backends()
BOUNDARY_TQ = (1, 31, 32, 33, 255, 256)   # straddles every default rung
TIMEOUT = 120.0                            # deadlock guard on every wait


@pytest.fixture(scope="module")
def base(tiny_corpus):
    cfg = LemurConfig(d=16, d_prime=32, m_pretrain=128, n_train=1024,
                      n_ols=512, epochs=4, k=5, k_prime=60, anns="bruteforce")
    return LemurRetriever.build(tiny_corpus, cfg, key=jax.random.PRNGKey(0))


def _ragged_query(tq: int, d: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((tq, d)).astype(np.float32)
    return q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-9)


def _direct(r, q: np.ndarray, params):
    s, ids = r.search(q[None], np.ones((1, q.shape[0]), bool), params)
    return np.asarray(s)[0], np.asarray(ids)[0]


# --------------------------------------------------------------------------
# ragged-shape conformance grid: backend x quantization x bucket boundaries
# --------------------------------------------------------------------------

def _conformance(r, params=None):
    """Server answers == direct facade answers at every bucket boundary,
    and the bucket padding itself is id-preserving."""
    ladder = BucketLadder()  # the default 32/64/128/256 ladder
    serve_r = LemurRetriever(r.index)     # fresh compile cache for the bound
    with RetrieverServer(serve_r, ladder=ladder, max_wait_us=200,
                         default_params=params) as srv:
        for tq in BOUNDARY_TQ:
            q = _ragged_query(tq, r.cfg.d, seed=tq)
            want_s, want_i = _direct(r, q, params)
            got_s, got_i = srv.search(q, timeout=TIMEOUT)
            assert np.array_equal(got_i, want_i), f"Tq={tq}: ids diverged"
            np.testing.assert_allclose(got_s, want_s, rtol=1e-5, atol=1e-6,
                                       err_msg=f"Tq={tq}")
            # pad-mask correctness, independent of the server: the padded
            # rows (zero vectors, False mask) must be exact no-ops
            qp, mp = pad_single(q, np.ones(tq, bool), ladder.tq_bucket(tq))
            s_pad, i_pad = r.search(qp[None], mp[None], params)
            assert np.array_equal(np.asarray(i_pad)[0], want_i), \
                f"Tq={tq}: padded rows leaked into the result"
        # 6 boundary lengths fold into 3 ladder rungs -> <= bound compiles
        assert srv.trace_count() <= ladder.compile_bound(1)
        assert len(srv.trace_shapes()) <= ladder.compile_bound(1)


@pytest.mark.parametrize("name", BACKENDS)
def test_server_matches_direct_search_fp32(name, base):
    _conformance(base.with_backend(name, key=jax.random.PRNGKey(1)))


def test_server_matches_direct_search_sq8(base):
    """SQ8 first-stage state (cfg.ivf.sq8): same conformance contract."""
    cfg = base.cfg.replace(anns="ivf", ivf=IVFBackendConfig(sq8=True))
    _conformance(base.with_backend("ivf", key=jax.random.PRNGKey(1), cfg=cfg))


def test_server_matches_sharded_direct_search(base):
    """The server over a 1-device ShardedLemurRetriever (fp32 AND SQ8
    resident corpus): bucketed answers == direct sharded search.  The
    8-device twin runs in test_dist_serve.py::test_online_server_sharded_
    parity."""
    from repro.common import compat

    mesh = compat.make_mesh((1,), ("model",))
    params = SearchParams(use_ann=False)
    for sq8 in (False, True):
        sr = base.shard(mesh, sq8=sq8)        # served instance
        sr_ref = base.shard(mesh, sq8=sq8)    # direct reference (own cache)
        ladder = BucketLadder((32, 64), max_batch=2)
        with RetrieverServer(sr, ladder=ladder, max_wait_us=200,
                             default_params=params) as srv:
            for tq in (1, 31, 33):
                q = _ragged_query(tq, base.cfg.d, seed=tq)
                want_s, want_i = _direct(sr_ref, q, params)
                got_s, got_i = srv.search(q, timeout=TIMEOUT)
                assert np.array_equal(got_i, want_i), (sq8, tq)
                np.testing.assert_allclose(got_s, want_s, rtol=1e-5,
                                           atol=1e-6)
            assert srv.trace_count() <= ladder.compile_bound(1)


def test_micro_batcher_coalesces_inflight_requests(base):
    """Requests sharing a bucket coalesce into one micro-batch (occupancy
    > 1) and every future still gets its own row."""
    r = LemurRetriever(base.index)
    ladder = BucketLadder((16,), max_batch=8)
    with RetrieverServer(r, ladder=ladder, max_wait_us=300_000) as srv:
        qs = [_ragged_query(5 + i, base.cfg.d, seed=i) for i in range(8)]
        futs = [srv.submit(q) for q in qs]
        outs = [f.result(timeout=TIMEOUT) for f in futs]
    summary = srv.stats.summary()
    assert summary["n_requests"] == 8
    assert summary["n_batches"] < 8, "micro-batcher never coalesced"
    assert max(summary["occupancy_hist"]) > 1
    for q, (s, ids) in zip(qs, outs):
        assert np.array_equal(ids, _direct(base, q, None)[1])


# --------------------------------------------------------------------------
# queue semantics: submit/add interleavings (deterministic + hypothesis)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small(tiny_corpus):
    """A tiny, fast-to-grow retriever for the interleaving property."""
    import dataclasses as dc

    sub = dc.replace(tiny_corpus,
                     doc_tokens=tiny_corpus.doc_tokens[:60],
                     doc_mask=tiny_corpus.doc_mask[:60],
                     topics=tiny_corpus.topics[:60])
    cfg = LemurConfig(d=16, d_prime=32, m_pretrain=48, n_train=512, n_ols=256,
                      epochs=3, k=3, k_prime=512, anns="bruteforce")
    return LemurRetriever.build(sub, cfg, key=jax.random.PRNGKey(0)), sub


def check_interleaving(small, seed: int, n_ops: int = 24,
                       p_add: float = 0.25):
    """Random submit/add interleaving invariants: every request id resolves
    exactly once, to ITS OWN query's answer (each query is the exact token
    set of a distinct known doc, so MaxSim top-1 must be that doc), and
    queries targeting docs added earlier in the stream always find them
    (FIFO barrier visibility)."""
    from repro.data import synthetic

    built, sub = small
    r = LemurRetriever(built.index)       # fresh wrapper: adds stay local
    # adds draw from a DISJOINT pool, so every query target is unambiguous
    addpool = synthetic.make_corpus(m=16, d=16, avg_tokens=8, max_tokens=12,
                                    n_centers=24, seed=900 + seed)
    rng = np.random.default_rng(seed)
    # k' (512) clamps to the (grown) corpus per the backend contract
    params = SearchParams(k_prime=512)
    expected: list[tuple[object, int]] = []   # (future, expected top-1 id)
    adds = []
    n_added = 0
    ladder = BucketLadder((8, 16), max_batch=4)
    with RetrieverServer(r, ladder=ladder, max_wait_us=300,
                         default_params=params) as srv:
        for _ in range(n_ops):
            roll = rng.random()
            if roll < p_add and n_added < addpool.m:
                # grow by one pool doc: its id becomes 60 + n_added
                adds.append(srv.add(addpool.doc_tokens[n_added:n_added + 1],
                                    addpool.doc_mask[n_added:n_added + 1]))
                n_added += 1
            elif roll < 0.6 or n_added == 0:
                j = int(rng.integers(0, 60))
                q = sub.doc_tokens[j][sub.doc_mask[j]]
                expected.append((srv.submit(np.asarray(q)), j))
            else:
                # target a doc whose add is already enqueued: the FIFO
                # barrier guarantees this query sees it
                a = int(rng.integers(0, n_added))
                q = addpool.doc_tokens[a][addpool.doc_mask[a]]
                expected.append((srv.submit(np.asarray(q)), 60 + a))
        for fut in adds:   # every enqueued add must land
            assert fut.result(timeout=TIMEOUT) <= 60 + n_added
        # snapshot hook: a query after the last add is answered by the
        # fully-grown snapshot (facade.version bumps once per add)
        tail = srv.submit(np.asarray(sub.doc_tokens[0][sub.doc_mask[0]]))
        tail.result(timeout=TIMEOUT)
        assert tail.snapshot_version == n_added
    assert r.m == 60 + n_added
    rids = [f.request_id for f, _ in expected]
    assert len(set(rids)) == len(rids), "duplicate request ids"
    for fut, j in expected:
        assert fut.done(), f"request {fut.request_id} dropped"
        s, ids = fut.result(timeout=0)
        assert ids[0] == j, (
            f"request {fut.request_id} cross-wired: top-1 {ids[0]} != {j}")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_submit_add_interleaving_grid(small, seed):
    check_interleaving(small, seed)


@settings(deadline=None, max_examples=5)
@given(seed=st.integers(10, 200))
def test_submit_add_interleaving_random(small, seed):
    check_interleaving(small, seed, n_ops=16)


# --------------------------------------------------------------------------
# compile-bound regression: 100 random shapes never exceed the ladder bound
# --------------------------------------------------------------------------

def _shape_churn(r, ladder: BucketLadder, tqs, expect_param_sets: int = 1):
    with RetrieverServer(r, ladder=ladder, max_wait_us=100) as srv:
        futs = [srv.submit(_ragged_query(tq, r.cfg.d, seed=i))
                for i, tq in enumerate(tqs)]
        for f in futs:
            f.result(timeout=TIMEOUT)
        bound = ladder.compile_bound(expect_param_sets)
        assert srv.trace_count() <= bound, (
            f"{srv.trace_count()} traces > ladder bound {bound}: "
            f"{srv.trace_shapes()}")
        assert len(srv.trace_shapes()) <= bound
        for shape, n in srv.trace_shapes().items():
            assert n == 1, f"shape {shape} retraced {n}x"
            assert shape[1] in ladder.tq_ladder, f"off-ladder Tq in {shape}"
            assert shape[0] in ladder.batch_sizes(), f"off-ladder B in {shape}"


def test_trace_count_bounded_under_shape_churn(base):
    """100 random request shapes; the compiled-fn cache must stay within
    the bucket-ladder bound (the tentpole's compile-bound contract)."""
    rng = np.random.default_rng(42)
    tqs = [int(t) for t in rng.integers(1, 33, size=100)]
    _shape_churn(LemurRetriever(base.index), BucketLadder((8, 16, 32), 4), tqs)


@settings(deadline=None, max_examples=3)
@given(seed=st.integers(0, 100))
def test_trace_count_bounded_random(base, seed):
    rng = np.random.default_rng(seed)
    tqs = [int(t) for t in rng.integers(1, 33, size=40)]
    _shape_churn(LemurRetriever(base.index), BucketLadder((8, 16, 32), 4), tqs)


# --------------------------------------------------------------------------
# ladder unit behaviour
# --------------------------------------------------------------------------

def test_bucket_ladder_policy():
    ladder = BucketLadder((8, 16, 32), max_batch=6)   # rounds up to 8
    assert ladder.max_batch == 8
    assert ladder.batch_sizes() == (1, 2, 4, 8)
    assert [ladder.tq_bucket(t) for t in (1, 8, 9, 16, 17, 32)] == \
        [8, 8, 16, 16, 32, 32]
    assert ladder.tq_bucket(33) == 64                 # overflow: next pow2
    assert [ladder.batch_bucket(n) for n in (1, 2, 3, 5, 9)] == [1, 2, 4, 8, 8]
    assert ladder.compile_bound() == 12
    assert ladder.compile_bound(3) == 36
    with pytest.raises(ValueError):
        BucketLadder((16, 8))
    with pytest.raises(ValueError):
        BucketLadder(())
    q, qm, n_real = ladder.pad_batch(
        [np.ones((3, 4), np.float32), np.ones((10, 4), np.float32)],
        [np.ones(3, bool), np.ones(10, bool)])
    assert q.shape == (2, 16, 4) and qm.shape == (2, 16) and n_real == 2
    assert not qm[0, 3:].any() and not qm[1, 10:].any()
    assert (q[0, 3:] == 0).all()


def test_stop_drain_flushes_pending_add_before_queued_searches(base):
    """The drain ordering guarantee: pending ``add()`` barriers are flushed
    BEFORE the remaining queued searches are served, so drained results
    reflect the final snapshot version — a fleet replica being drained must
    not answer from a stale corpus it already accepted growth for."""
    from repro.data import synthetic

    r = LemurRetriever(base.index)
    grow = synthetic.make_corpus(m=4, d=16, avg_tokens=8, max_tokens=12,
                                 n_centers=24, seed=321)
    srv = RetrieverServer(r, ladder=BucketLadder((8, 16), 2),
                          max_wait_us=200).start()
    srv.search(_ragged_query(6, base.cfg.d, seed=0), timeout=TIMEOUT)  # warm
    # wedge the worker, then queue a search BEFORE the add: FIFO alone would
    # serve it against the old snapshot, the drain guarantee must not
    srv.pause()
    q = np.asarray(grow.doc_tokens[0][grow.doc_mask[0]])
    params = SearchParams(use_ann=False, k_prime=base.m + 4)
    sf = srv.submit(q, params=params)
    af = srv.add(grow.doc_tokens, grow.doc_mask)
    assert not srv.stop(drain=True, timeout=0.2), "drained through the pause"
    srv.resume()
    assert srv.stop(drain=True, timeout=TIMEOUT)
    assert af.result(timeout=0) == base.m + 4
    assert af.snapshot_version == 1
    s, ids = sf.result(timeout=0)
    assert sf.snapshot_version == 1, (
        "drained search answered from the pre-add snapshot")
    assert ids[0] == base.m, "drained search cannot see the flushed add"


class _StallingSubmit:
    """Replay proxy inducing a submit-side stall: open-loop arrivals back up
    behind a slow submitter, the classic coordinated-omission trap."""

    def __init__(self, server, stall_s: float):
        self._server = server
        self._stall_s = stall_s

    def submit(self, *a, **kw):
        import time

        time.sleep(self._stall_s)
        return self._server.submit(*a, **kw)

    def __getattr__(self, name):
        return getattr(self._server, name)


def test_replay_latency_measured_from_scheduled_arrival(base):
    """Coordinated-omission regression: under an induced submit stall the
    arrival-relative percentiles (honest) must diverge far above the
    submit-relative twins (optimistic), and nothing may be lost."""
    from repro.serving import replay

    r = LemurRetriever(base.index)
    ladder = BucketLadder((8,), 2)
    with RetrieverServer(r, ladder=ladder, max_wait_us=200) as srv:
        srv.search(_ragged_query(6, base.cfg.d, seed=0), timeout=TIMEOUT)
        queries = [_ragged_query(6, base.cfg.d, seed=i) for i in range(8)]
        arrivals = np.arange(40) * 0.005       # offered: one per 5ms
        stalled = _StallingSubmit(srv, stall_s=0.015)  # drains 10ms/req late
        _, rep = replay(stalled, queries, arrivals, timeout=TIMEOUT)
    assert rep["n_requests"] == 40 and rep["n_lost"] == 0
    # the schedule fell ~10ms further behind per request (~400ms by the
    # tail); submit-relative latency never sees that backlog
    assert rep["p99_ms"] > rep["submit_p99_ms"] + 100, rep
    assert rep["p99_ms"] > 3 * rep["submit_p99_ms"], rep
    assert rep["p50_ms"] > rep["submit_p50_ms"], rep


def test_server_delete_update_fifo_visibility(base):
    """delete()/update() through the server are FIFO barriers like add():
    a search queued BEFORE a delete answers from the pre-delete snapshot,
    one queued after can never surface the tombstoned doc, and an update's
    replacement is immediately retrievable under its NEW id."""
    from repro.data import synthetic

    r = base.clone()
    grow = synthetic.make_corpus(m=4, d=16, avg_tokens=8, max_tokens=12,
                                 n_centers=24, seed=77)
    repl = synthetic.make_corpus(m=1, d=16, avg_tokens=8, max_tokens=12,
                                 n_centers=24, seed=78)
    m0 = base.m
    with RetrieverServer(r, ladder=BucketLadder((8, 16), 2),
                         max_wait_us=200) as srv:
        af = srv.add(grow.doc_tokens, grow.doc_mask)
        assert af.result(timeout=TIMEOUT) == m0 + 4
        ids = np.asarray(af.added_ids)
        full = SearchParams(use_ann=False, k_prime=r.m)
        q0 = np.asarray(grow.doc_tokens[0][grow.doc_mask[0]])
        _, got = srv.search(q0, params=full, timeout=TIMEOUT)
        assert got[0] == ids[0]
        # wedge the worker so the queue orders deterministically:
        # search -> delete -> search, then drain
        srv.pause()
        before = srv.submit(q0, params=full)
        df = srv.delete(ids[:2])
        after = srv.submit(q0, params=full)
        srv.resume()
        assert df.result(timeout=TIMEOUT) == m0 + 2      # n_alive
        assert df.snapshot_version == 2
        _, got = before.result(timeout=TIMEOUT)
        assert got[0] == ids[0] and before.snapshot_version == 1
        _, got = after.result(timeout=TIMEOUT)
        assert ids[0] not in got and after.snapshot_version == 2
        # update: replacement lands under a FRESH slot id, old id is gone
        uf = srv.update([int(ids[2])], repl.doc_tokens, repl.doc_mask)
        new = np.asarray(uf.result(timeout=TIMEOUT))
        assert new.tolist() == [m0 + 4] and uf.snapshot_version == 3
        full2 = SearchParams(use_ann=False, k_prime=r.m)
        q3 = np.asarray(repl.doc_tokens[0][repl.doc_mask[0]])
        _, got = srv.search(q3, params=full2, timeout=TIMEOUT)
        assert got[0] == new[0] and int(ids[2]) not in got
    assert r.m == m0 + 5 and r.n_alive == m0 + 2


def test_residual_store_churn_zero_traces_and_rebuild_parity(tiny_corpus):
    """Mutation churn on the COMPRESSED (residual-codec) tier through the
    live server: once the pool is warm and adds stay in capacity the churn
    issues ZERO new traces (codec leaves ride jit as arguments), every
    mutation bumps the snapshot version by exactly one, and the post-churn
    ids are BIT-identical to a from-scratch compressed rebuild over the
    survivors' pooled tokens with the same codec."""
    import jax.numpy as jnp

    from repro.anns.params import ResidualConfig
    from repro.core import pages
    from repro.data import synthetic

    budget = 6
    cfg = LemurConfig(d=16, d_prime=32, m_pretrain=128, n_train=1024,
                      n_ols=512, epochs=3, k=5, k_prime=64, anns="bruteforce",
                      residual=ResidualConfig(enabled=True, bits=4, ncent=64,
                                              kmeans_iters=4,
                                              token_budget=budget))
    r = LemurRetriever.build(tiny_corpus, cfg, key=jax.random.PRNGKey(0))
    assert r.index.store.residual
    # raw[slot] = the POOLED tokens that slot was encoded from; the rebuild
    # oracle below re-encodes exactly these with the same codec
    ptoks, pmask = pages.pool_tokens(np.asarray(tiny_corpus.doc_tokens),
                                     np.asarray(tiny_corpus.doc_mask), budget)
    raw = {i: (ptoks[i], pmask[i]) for i in range(r.m)}

    def batch(s):
        c = synthetic.make_corpus(m=3, d=16, avg_tokens=8, max_tokens=12,
                                  n_centers=24, seed=800 + s)
        return np.asarray(c.doc_tokens), np.asarray(c.doc_mask)

    def record(ids, toks, mask):
        pt, pm = pages.pool_tokens(toks, mask, budget)
        for j, i in enumerate(np.asarray(ids).tolist()):
            raw[int(i)] = (pt[j], pm[j])

    params = SearchParams(use_ann=False, k=5, k_prime=64)
    q = _ragged_query(7, 16, seed=0)
    with RetrieverServer(r, ladder=BucketLadder((8, 16), 2),
                         max_wait_us=200) as srv:
        # warm-up round: absorbs any one-time pow2 pool growth + compiles
        # the (params, shape) the loop re-issues
        toks, mask = batch(0)
        f = srv.add(toks, mask)
        f.result(timeout=TIMEOUT)
        record(f.added_ids, toks, mask)
        warm = np.asarray(f.added_ids)
        for i in warm.tolist():
            raw.pop(i)
        srv.delete(warm).result(timeout=TIMEOUT)
        srv.search(q, params=params, timeout=TIMEOUT)

        v0, t0 = r.version, srv.trace_count()
        futs, live = [], []
        for step in range(3):
            toks, mask = batch(1 + step)
            fa = srv.add(toks, mask)
            futs.append(fa)
            fa.result(timeout=TIMEOUT)
            ids = np.asarray(fa.added_ids)
            record(ids, toks, mask)
            srv.search(q, params=params, timeout=TIMEOUT)
            raw.pop(int(ids[0]))
            futs.append(srv.delete(ids[:1]))
            if live:
                raw.pop(live[-1])
                fu = srv.update([live.pop()], toks[:1], mask[:1])
                futs.append(fu)
                record(fu.result(timeout=TIMEOUT), toks[:1], mask[:1])
                live.extend(np.asarray(fu.result(timeout=0)).tolist())
            live.extend(ids[1:].tolist())
        for f in futs:
            f.result(timeout=TIMEOUT)
        versions = [f.snapshot_version for f in futs]
        assert versions == list(range(v0 + 1, v0 + len(futs) + 1)), versions
        srv.search(q, params=params, timeout=TIMEOUT)
        assert srv.trace_count() - t0 == 0, (
            f"warm residual-tier churn issued {srv.trace_count() - t0} traces")

    # from-scratch compressed rebuild over the survivors: same pooled
    # tokens, same codec, one-shot from_dense — ids must map bit-identically
    st = r.index.store
    surv = sorted(raw)
    assert len(surv) == r.n_alive
    rt = np.zeros((len(surv), budget, 16), np.float32)
    rm = np.zeros((len(surv), budget), bool)
    for j, i in enumerate(surv):
        t, mk = raw[i]
        rt[j, : mk.sum()] = t[mk]
        rm[j, : mk.sum()] = True
    store2, _ = pages.from_dense(np.asarray(st.W)[surv], rt, rm,
                                 codec=st.codec)
    r2 = LemurRetriever(r.index._replace(store=store2))
    qb = jnp.asarray(q[None])
    qm = np.ones((1, len(q)), bool)
    _, ids_a = r.search(qb, qm, params)
    _, ids_b = r2.search(qb, qm, params)
    np.testing.assert_array_equal(
        np.asarray(ids_a),
        np.asarray(surv, np.int64)[np.asarray(ids_b)])


def test_server_stop_without_drain_cancels(base):
    r = LemurRetriever(base.index)
    srv = RetrieverServer(r, ladder=BucketLadder((8,), 2),
                          max_wait_us=500_000).start()
    futs = [srv.submit(_ragged_query(4, base.cfg.d, seed=i))
            for i in range(6)]
    srv.stop(drain=False, timeout=TIMEOUT)
    states = [("done" if f.done() and not f.cancelled() else
               "cancelled" if f.cancelled() else "lost") for f in futs]
    assert "lost" not in states, states
    with pytest.raises(RuntimeError):
        srv.submit(_ragged_query(4, base.cfg.d, seed=0))


def test_stop_without_drain_resolves_blocked_mutation_barrier(base,
                                                              tiny_corpus):
    """The no-leak bugfix (ISSUE 8): a caller already BLOCKED on
    ``add().result(timeout=...)`` when the server is stopped without drain
    observes a typed ``CancelledError`` promptly — every pending mutation
    barrier future (add, delete, update) is cancelled, never leaked — and
    the abandoned mutations were never applied to the retriever."""
    r = LemurRetriever(base.index)
    srv = RetrieverServer(r, ladder=BucketLadder((8,), 2),
                          max_wait_us=500_000).start()
    srv.pause()                    # wedge the worker: the barriers queue up
    m0, v0 = r.m, r.version
    fa = srv.add(tiny_corpus.doc_tokens[:3], tiny_corpus.doc_mask[:3])
    fd = srv.delete([0])
    fu = srv.update([1], tiny_corpus.doc_tokens[:1],
                    tiny_corpus.doc_mask[:1])
    outcome: dict = {}

    def blocked_caller():
        try:
            outcome["kind"] = ("result", fa.result(timeout=TIMEOUT))
        except cf.CancelledError:
            outcome["kind"] = "cancelled"
        except Exception as e:  # noqa: BLE001 — the test asserts the type
            outcome["kind"] = repr(e)

    th = threading.Thread(target=blocked_caller, daemon=True)
    th.start()
    time.sleep(0.05)               # let the caller actually block
    assert srv.stop(drain=False, timeout=TIMEOUT)
    th.join(timeout=5.0)
    assert not th.is_alive(), "caller blocked on add().result() hung"
    assert outcome["kind"] == "cancelled"
    for f in (fa, fd, fu):
        assert f.done() and f.cancelled(), "mutation barrier future leaked"
    assert r.m == m0 and r.version == v0, "cancelled mutation was applied"
