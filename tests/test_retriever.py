"""Retriever API v1: facade lifecycle, typed SearchParams, per-backend
config namespaces, save/load persistence, and the one-trace-per-params
compilation contract."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.anns import registry
from repro.core import LemurConfig
from repro.retriever import (
    IVFBackendConfig,
    IVFSearchParams,
    LemurRetriever,
    NoSearchParams,
    SearchParams,
    TokenPruningSearchParams,
)

BACKENDS = registry.list_backends()


@pytest.fixture(scope="module")
def retriever(tiny_corpus):
    cfg = LemurConfig(d=16, d_prime=64, m_pretrain=128, n_train=1024, n_ols=512,
                      epochs=5, k=10, k_prime=60, anns="bruteforce")
    return LemurRetriever.build(tiny_corpus, cfg, key=jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def queries(tiny_corpus):
    from repro.data import synthetic

    q = jnp.asarray(synthetic.queries_from_corpus_query(tiny_corpus, 8, 4, seed=3))
    return q, jnp.ones(q.shape[:2], bool)


# --------------------------------------------------------------------------
# persistence: build -> save -> load -> search must be bit-identical
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", BACKENDS)
def test_save_load_roundtrip_bit_identical(name, retriever, queries, tmp_path):
    q, qm = queries
    r = retriever.with_backend(name, key=jax.random.PRNGKey(1))
    params = SearchParams(k=10)
    s, ids = r.search(q, qm, params)
    r.save(tmp_path / name)
    r2 = LemurRetriever.load(tmp_path / name)
    assert r2.backend == name and r2.cfg == r.cfg and r2.m == r.m
    s2, ids2 = r2.search(q, qm, params)
    np.testing.assert_array_equal(np.asarray(ids2), np.asarray(ids))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(s))


def test_load_rejects_foreign_checkpoints(tmp_path):
    from repro.checkpoint import save as ckpt_save

    ckpt_save(tmp_path, 0, {"w": jnp.zeros(3)}, extra={"format": "other"})
    with pytest.raises(ValueError, match="lemur-retriever-v1"):
        LemurRetriever.load(tmp_path)
    with pytest.raises(FileNotFoundError):
        LemurRetriever.load(tmp_path / "empty")


def test_saved_retriever_add_is_deterministic(retriever, tiny_corpus, tmp_path,
                                              queries):
    """add() after load reuses the persisted OLS tokens — two loads grow to
    bit-identical W; and an explicit seed governs the no-solver fallback."""
    q, qm = queries
    retriever.save(tmp_path / "det")
    extra_t = tiny_corpus.doc_tokens[:25]
    extra_m = tiny_corpus.doc_mask[:25]
    r1 = LemurRetriever.load(tmp_path / "det").add(extra_t, extra_m)
    r2 = LemurRetriever.load(tmp_path / "det").add(extra_t, extra_m)
    np.testing.assert_array_equal(np.asarray(r1.index.W), np.asarray(r2.index.W))
    # build-time solver state is reused: growing the ORIGINAL retriever gives
    # the same rows as growing its save/load clone
    r0 = retriever.with_backend("bruteforce")
    r0.add(extra_t, extra_m)
    np.testing.assert_allclose(np.asarray(r0.index.W), np.asarray(r1.index.W),
                               rtol=1e-5, atol=1e-6)
    _, ids = r1.search(q, qm, SearchParams(k=10))
    assert int(jnp.max(ids)) < r1.m


def test_add_fallback_seed_is_explicit(retriever, tiny_corpus):
    """Wrapping a bare index (no solver, no persisted tokens) falls back to
    corpus sampling, which must be driven by the explicit seed."""
    idx = retriever.with_backend("bruteforce").index
    extra_t, extra_m = tiny_corpus.doc_tokens[:10], tiny_corpus.doc_mask[:10]
    g1 = LemurRetriever(idx).add(extra_t, extra_m, seed=7).index.W
    g2 = LemurRetriever(idx).add(extra_t, extra_m, seed=7).index.W
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


# --------------------------------------------------------------------------
# compilation contract: one jit trace per (backend, SearchParams, shape)
# --------------------------------------------------------------------------

def test_one_trace_per_search_params(retriever, queries):
    q, qm = queries
    r = retriever.with_backend("ivf", key=jax.random.PRNGKey(1))
    params = SearchParams(k=5)
    for _ in range(4):
        r.search(q, qm, params)
    assert r.trace_count(params) == 1, "repeated search() retraced"
    # equivalent spellings of the same resolved params share the compile
    r.search(q, qm, SearchParams(k=5, k_prime=r.cfg.k_prime))
    assert r.trace_count(params) == 1
    # a different SearchParams compiles exactly one more fn
    p2 = SearchParams(k=5, backend=IVFSearchParams(nprobe=4))
    r.search(q, qm, p2)
    r.search(q, qm, p2)
    assert r.trace_count(p2) == 1 and r.trace_count() == 2
    # a new batch shape retraces the same params once
    r.search(q[:3], qm[:3], params)
    assert r.trace_count(params) == 2


def test_add_preserves_compiled_fns(retriever, queries, tiny_corpus):
    """The streaming-add bugfix contract: compiled query fns take the
    mutable state (paged store + backend state) as jit ARGUMENTS, so an add
    that fits the pre-grown pool changes no shapes and issues ZERO new
    traces — the compile cache SURVIVES the mutation."""
    q, qm = queries
    exact = SearchParams(k=5, use_ann=False)
    for name in ("bruteforce", "ivf"):
        r = retriever.with_backend(name, key=jax.random.PRNGKey(1))
        r.search(q, qm, exact)
        m0 = r.m
        assert r.trace_count(exact) == 1
        r.add(tiny_corpus.doc_tokens[:15], tiny_corpus.doc_mask[:15])
        assert r.m == m0 + 15
        _, ids = r.search(q, qm, exact)  # grown corpus, SAME compiled fn
        assert r.trace_count(exact) == 1, "in-capacity add retraced"
        assert int(jnp.max(ids)) < r.m
    # the ANN path survives too: IVF cluster-list capacity is pow2-bucketed
    # with a never-shrink floor, so an in-capacity add keeps list shapes
    r = retriever.with_backend("ivf", key=jax.random.PRNGKey(1))
    ann = SearchParams(k=5)
    r.search(q, qm, ann)
    r.add(tiny_corpus.doc_tokens[:15], tiny_corpus.doc_mask[:15])
    _, ids = r.search(q, qm, ann)
    assert r.trace_count(ann) == 1, "in-capacity add retraced the IVF path"
    assert int(jnp.max(ids)) < r.m


def test_delete_update_lifecycle(retriever, queries, tiny_corpus):
    """delete() tombstones (stable surviving ids, deleted ids never
    surface), update() replaces under ONE version bump with NEW ids."""
    q, qm = queries
    r = retriever.with_backend("bruteforce")
    params = SearchParams(k=10, use_ann=False)
    m0, v0 = r.m, r.version
    r.add(tiny_corpus.doc_tokens[:8], tiny_corpus.doc_mask[:8])
    added = r.last_added_ids
    np.testing.assert_array_equal(added, np.arange(m0, m0 + 8))
    r.delete(added)
    assert r.m == m0 + 8 and r.n_alive == m0  # slots never reused
    assert r.version == v0 + 2
    _, ids = r.search(q, qm, params)
    assert not np.isin(np.asarray(ids), np.asarray(added)).any()
    # unknown / double deletes are typed errors
    with pytest.raises(ValueError):
        r.delete(added[:1])
    with pytest.raises(ValueError):
        r.delete([r.m + 5])
    new_ids = r.update([0, 1], tiny_corpus.doc_tokens[:2],
                       tiny_corpus.doc_mask[:2])
    assert r.version == v0 + 3  # ONE bump for delete+add
    np.testing.assert_array_equal(new_ids, np.arange(m0 + 8, m0 + 10))
    _, ids = r.search(q, qm, params)
    assert not np.isin(np.asarray(ids), [0, 1]).any()


# --------------------------------------------------------------------------
# paged corpus: doc-id stability, tombstone masking, rebuild parity
# --------------------------------------------------------------------------

def _churn(r, corpus):
    """One interleaved add/delete/update round; returns the set of ids that
    must never surface again."""
    m0 = r.m
    r.add(corpus.doc_tokens[:12], corpus.doc_mask[:12])
    added = r.last_added_ids
    np.testing.assert_array_equal(added, np.arange(m0, m0 + 12))
    r.delete(added[:6])
    upd = [3, 9, int(added[6])]
    r.update(upd, corpus.doc_tokens[20:23], corpus.doc_mask[20:23])
    return set(added[:6].tolist()) | set(upd)


@pytest.mark.parametrize("name", BACKENDS)
def test_tombstones_never_surface_any_backend(name, retriever, queries,
                                              tiny_corpus):
    """Backends are never rebuilt on delete — their stale candidates are
    masked after every first stage — so across all five backends, both
    gather paths (fused page-fed kernel dispatch and legacy materialize-
    from-pages), and both the ANN and exact-scan routes, a deleted or
    replaced doc id can never surface."""
    q, qm = queries
    r = retriever.with_backend(name, key=jax.random.PRNGKey(7))
    dead = _churn(r, tiny_corpus)
    for fused in (True, False):
        for params in (SearchParams(k=10, use_fused_gather=fused),
                       SearchParams(k=10, use_ann=False, k_prime=r.m,
                                    use_fused_gather=fused)):
            _, ids = r.search(q, qm, params)
            ids = np.asarray(ids)
            hit = set(ids.ravel().tolist()) & dead
            assert not hit, f"tombstoned ids surfaced (fused={fused}): {hit}"
            assert ids.max() < r.m


def test_tombstones_never_surface_sq8(tiny_corpus, queries):
    """Same contract under the SQ8 first-stage tier (cfg.ivf.sq8)."""
    q, qm = queries
    cfg = LemurConfig(d=16, d_prime=64, m_pretrain=128, n_train=1024,
                      n_ols=512, epochs=4, k=10, k_prime=60, anns="ivf",
                      ivf=IVFBackendConfig(sq8=True, nprobe=32))
    r = LemurRetriever.build(tiny_corpus, cfg, key=jax.random.PRNGKey(0))
    dead = _churn(r, tiny_corpus)
    for fused in (True, False):
        _, ids = r.search(q, qm, SearchParams(k=10, use_fused_gather=fused))
        hit = set(np.asarray(ids).ravel().tolist()) & dead
        assert not hit, f"tombstoned ids surfaced (sq8, fused={fused}): {hit}"


def test_ids_refer_to_same_documents_across_churn(retriever, tiny_corpus):
    """Stable external ids: a doc keeps answering to the SAME id across
    unrelated add/delete/update churn (slots are never reused)."""
    r = retriever.with_backend("bruteforce")

    def top1(doc_id):
        toks = tiny_corpus.doc_tokens[doc_id][tiny_corpus.doc_mask[doc_id]]
        params = SearchParams(k=1, use_ann=False, k_prime=r.m)
        _, ids = r.search(toks[None], np.ones((1, len(toks)), bool), params)
        return int(np.asarray(ids)[0, 0])

    probes = [5, 17, 40]
    assert [top1(i) for i in probes] == probes
    _churn(r, tiny_corpus)          # touches ids 3/9 + its own adds, not 5/17/40
    assert [top1(i) for i in probes] == probes


def test_surviving_ids_bit_identical_to_rebuild(retriever, queries,
                                                tiny_corpus):
    """The acceptance criterion: after interleaved add/delete/update, the
    exact-scan search over the mutated paged store returns bit-identical
    scores — and ids referring to the same documents — as a from-scratch
    dense rebuild over only the surviving docs (same ψ/stats/W rows, ids
    mapped through the survivor order)."""
    from repro.core import pages
    from repro.core.index import LemurIndex

    q, qm = queries
    r = retriever.with_backend("bruteforce")
    _churn(r, tiny_corpus)
    st = r.index.store
    alive = np.flatnonzero(np.asarray(st.alive)[: r.m])
    toks, mask = pages.gather_docs(st, jnp.asarray(alive))
    idx2 = LemurIndex.from_dense(r.cfg, r.index.psi, r.index.stats,
                                 jnp.take(st.W, jnp.asarray(alive), axis=0),
                                 toks, mask, "bruteforce", None)
    r2 = LemurRetriever(idx2)
    assert r2.m == r.n_alive
    s1, i1 = r.search(q, qm, SearchParams(k=10, use_ann=False, k_prime=r.m))
    s2, i2 = r2.search(q, qm, SearchParams(k=10, use_ann=False,
                                           k_prime=r2.m))
    np.testing.assert_array_equal(alive[np.asarray(i2)], np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(s1))


def test_save_load_preserves_tombstones(retriever, queries, tiny_corpus,
                                        tmp_path):
    """The persisted ``alive`` mask is load-bearing: a reloaded retriever
    keeps its tombstones (deleted docs never resurface as zero-score rows),
    its slot high-water mark, and its stable id numbering for further
    growth."""
    q, qm = queries
    r = retriever.with_backend("bruteforce")
    dead = _churn(r, tiny_corpus)
    r.save(tmp_path / "mutated")
    r2 = LemurRetriever.load(tmp_path / "mutated")
    assert r2.m == r.m and r2.n_alive == r.n_alive
    params = SearchParams(k=10, use_ann=False, k_prime=r.m)
    s1, i1 = r.search(q, qm, params)
    s2, i2 = r2.search(q, qm, params)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(s1))
    assert not (set(np.asarray(i2).ravel().tolist()) & dead)
    # growth after reload continues the stable numbering (slots, not holes)
    r2.add(tiny_corpus.doc_tokens[:2], tiny_corpus.doc_mask[:2])
    np.testing.assert_array_equal(r2.last_added_ids,
                                  np.arange(r.m, r.m + 2))


# --------------------------------------------------------------------------
# typed SearchParams + per-backend config namespaces
# --------------------------------------------------------------------------

def test_search_params_hashable_and_resolved(retriever):
    p = SearchParams(k=5, backend=IVFSearchParams(nprobe=8))
    assert hash(p) == hash(SearchParams(k=5, backend=IVFSearchParams(nprobe=8)))
    r = retriever.with_backend("ivf")
    resolved = r.resolve(SearchParams())
    assert resolved.k == r.cfg.k and resolved.k_prime == r.cfg.k_prime
    assert resolved.backend == IVFSearchParams(
        nprobe=r.cfg.ivf.nprobe,
        use_fused_gather=r.cfg.ivf.use_fused_gather,
        use_one_launch=r.cfg.ivf.use_one_launch)
    assert resolved.use_fused_gather == r.cfg.use_fused_gather
    assert resolved.use_one_launch == r.cfg.use_one_launch
    # exact-scan params carry no backend knobs (cache key collapses)
    assert r.resolve(SearchParams(use_ann=False)).backend is None


def test_partial_backend_params_fill_from_config(retriever):
    """An explicit-but-empty params instance means 'cfg defaults', not
    'hardcoded backend defaults' — and collapses to the same cache key."""
    r = retriever.with_backend("ivf", cfg=retriever.cfg.replace(
        anns="ivf", ivf=IVFBackendConfig(nprobe=48)))
    a = r.resolve(SearchParams(backend=IVFSearchParams()))
    b = r.resolve(SearchParams())
    assert a.backend == IVFSearchParams(nprobe=48, use_fused_gather=True,
                                        use_one_launch=False)
    assert a == b


def test_from_dict_folds_v0_flat_knobs():
    """A v0-era config dict (flat knobs at top level) must not silently
    lose settings on load."""
    d = LemurConfig(d=16).to_dict()
    del d["ivf"], d["token_pruning"]
    d |= {"sq8": False, "ivf_nprobe": 64, "tp_nprobe": 2}
    with pytest.warns(DeprecationWarning):
        cfg = LemurConfig.from_dict(d)
    assert cfg.ivf == IVFBackendConfig(nprobe=64, sq8=False)
    assert cfg.token_pruning.nprobe == 2


def test_search_params_backend_type_mismatch(retriever, queries):
    q, qm = queries
    r = retriever.with_backend("muvera", key=jax.random.PRNGKey(1))
    with pytest.raises(TypeError, match="NoSearchParams"):
        r.search(q, qm, SearchParams(backend=IVFSearchParams(nprobe=4)))


def test_registry_exposes_config_and_params_types():
    assert registry.get_config_cls("ivf") is IVFBackendConfig
    assert registry.get_params_cls("ivf") is IVFSearchParams
    assert registry.get_params_cls("muvera") is NoSearchParams
    assert registry.get_params_cls("token_pruning") is TokenPruningSearchParams
    assert registry.get_config_cls("exact").__name__ == "BruteforceBackendConfig"
    for name in BACKENDS:
        be = registry.get_backend(name)
        assert isinstance(be.default_params(be.config_cls()), be.params_cls)


def test_config_namespaces_and_dotted_overrides():
    cfg = LemurConfig(d=16, anns="ivf", ivf=IVFBackendConfig(nprobe=48, sq8=False))
    assert cfg.backend_config() == cfg.ivf
    assert cfg.backend_config("token_pruning").nprobe == 8
    cfg2 = cfg.override({"ivf.nprobe": 16, "muvera.r_reps": 7})
    assert cfg2.ivf.nprobe == 16 and cfg2.muvera.r_reps == 7
    # dict round-trip preserves the nested namespaces
    assert LemurConfig.from_dict(cfg2.to_dict()) == cfg2
    assert hash(LemurConfig.from_dict(cfg2.to_dict())) == hash(cfg2)


def test_legacy_flat_knobs_deprecated_but_working():
    with pytest.warns(DeprecationWarning, match="ivf_nprobe -> ivf.nprobe"):
        cfg = LemurConfig(d=16, ivf_nprobe=48, sq8=False)
    assert cfg.ivf == IVFBackendConfig(nprobe=48, sq8=False)
    with pytest.warns(DeprecationWarning, match="tp_nprobe"):
        cfg = cfg.replace(tp_nprobe=2)
    assert cfg.token_pruning.nprobe == 2
    assert cfg.ivf.nprobe == 48  # replace() preserved the folded namespace
    with pytest.warns(DeprecationWarning, match="read cfg.ivf.nprobe"):
        assert cfg.ivf_nprobe == 48
    with pytest.raises(AttributeError):
        cfg.no_such_knob


def test_legacy_free_functions_are_facade_shims(retriever, queries):
    """v0 query()/candidates() and the facade produce identical results."""
    from repro.core.index import candidates, query

    q, qm = queries
    r = retriever.with_backend("ivf", key=jax.random.PRNGKey(1))
    s_new, ids_new = r.search(q, qm, SearchParams(k=10,
                                                  backend=IVFSearchParams(nprobe=4)))
    s_old, ids_old = query(r.index, q, qm, k=10, nprobe=4)
    np.testing.assert_array_equal(np.asarray(ids_old), np.asarray(ids_new))
    cand_new = r.candidates(q, qm, SearchParams(k_prime=20, use_ann=False))
    cand_old = candidates(r.index, q, qm, k_prime=20)
    np.testing.assert_array_equal(np.asarray(cand_old), np.asarray(cand_new))


def test_with_backend_shares_reduction(retriever):
    r2 = retriever.with_backend("dessert", key=jax.random.PRNGKey(2))
    assert r2.backend == "dessert" and r2.cfg.anns == "dessert"
    assert r2.index.store is retriever.index.store  # ψ/W never re-trained
    assert retriever.backend == "bruteforce"  # original untouched


def test_backend_params_ride_jit_static(retriever, queries):
    """SearchParams fields must all be hashable (jit-static) types."""
    for p in (SearchParams(), SearchParams(k=3, k_prime=7, use_ann=False),
              SearchParams(backend=TokenPruningSearchParams(nprobe=2))):
        assert isinstance(hash(p), int)
        assert dataclasses.is_dataclass(p) and p.__dataclass_params__.frozen


def test_no_stray_deprecation_warnings_on_new_api(tiny_corpus):
    """The facade itself must never touch the legacy alias path."""
    cfg = LemurConfig(d=16, d_prime=32, m_pretrain=64, n_train=256, n_ols=128,
                      epochs=2, batch_size=64, k=5, k_prime=30, anns="ivf")
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        r = LemurRetriever.build(tiny_corpus, cfg, key=jax.random.PRNGKey(0))
        q = jnp.asarray(tiny_corpus.doc_tokens[:4, :4])
        r.search(q, params=SearchParams(k=5))
