"""One-launch query kernel: oracle grid + interpret-mode kernel parity.

The contract under test (ISSUE 6 acceptance): fp32 one-launch candidate ids
are BIT-IDENTICAL to the legacy 3-launch composition (ψ-pool → probe scan →
flat top-k'), with the legacy flat top-k's stable tie-breaking (earlier flat
position wins) reproduced by the kernel's carried per-step merge — covering
engineered score ties, ``-1`` padded cluster slots, k' > #valid candidates,
cap not a multiple of the scan tile, and B=1.  SQ8 scores match to the
hi/lo-bf16 dequant tolerance.  All kernel runs are interpret mode (CPU).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.query_fused import mips_topk, query_fused


def _psi(rng, d, dp):
    return {
        "dense": {
            "kernel": jnp.asarray(rng.standard_normal((d, dp)) * 0.1,
                                  jnp.float32),
            "bias": jnp.asarray(rng.standard_normal(dp) * 0.01, jnp.float32),
        },
        "ln": {
            "scale": jnp.asarray(1 + 0.1 * rng.standard_normal(dp),
                                 jnp.float32),
            "bias": jnp.asarray(0.1 * rng.standard_normal(dp), jnp.float32),
        },
    }


def _setup(rng, B, Tq, d, dp, nlist, cap, n_pad=0, tie_slots=0):
    psi = _psi(rng, d, dp)
    qt = jnp.asarray(rng.standard_normal((B, Tq, d)), jnp.float32)
    qm = jnp.asarray(rng.random((B, Tq)) > 0.3).at[:, 0].set(True)
    ids = jnp.asarray(rng.integers(0, 10_000, (nlist, cap)), jnp.int32)
    vecs = jnp.asarray(rng.standard_normal((nlist, cap, dp)), jnp.float32)
    if n_pad:
        ids = ids.at[:, cap - n_pad:].set(-1)
    if tie_slots:
        # engineered EXACT score ties across clusters: duplicate vector rows
        # (identical slots dot the same pooled query to the same bits), with
        # distinct ids — the stable flat top-k must pick the earlier flat
        # position, and so must the kernel's carried merge
        for j in range(tie_slots):
            src = (j % nlist, j % max(cap - n_pad, 1))
            dst = ((j + 1) % nlist, (2 * j + 1) % max(cap - n_pad, 1))
            vecs = vecs.at[dst[0], dst[1]].set(vecs[src[0], src[1]])
    cents = jnp.asarray(rng.standard_normal((nlist, dp)), jnp.float32)
    return psi, qt, qm, cents, ids, vecs


def _probe(psi, qt, qm, cents, nprobe):
    p = psi["dense"]
    ln = psi["ln"]
    psi_q = ref.psi_pool_ref(qt, qm, p["kernel"], p["bias"], ln["scale"],
                             ln["bias"])
    _, probe = jax.lax.top_k(psi_q @ cents.T, nprobe)
    return psi_q, probe


# --------------------------------------------------------------------------
# oracle vs flat jax.lax.top_k (the in-kernel partial top-k grid)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,Tq,d,dp,nlist,cap,nprobe,kp,n_pad,ties", [
    (4, 6, 16, 32, 8, 10, 3, 12, 3, 0),    # -1 pad slots in the strip
    (4, 6, 16, 32, 8, 10, 3, 12, 0, 6),    # engineered exact score ties
    (1, 5, 16, 32, 6, 7, 2, 9, 2, 3),      # B=1, cap odd (non-tile multiple)
    (3, 4, 16, 32, 4, 5, 4, 40, 4, 0),     # k' > #valid candidates
    (2, 3, 8, 16, 5, 11, 5, 55, 0, 0),     # k' == whole probed strip
])
def test_kernel_matches_flat_topk(B, Tq, d, dp, nlist, cap, nprobe, kp,
                                  n_pad, ties):
    """Interpret kernel == oracle == legacy flat top-k, ids bit-identical."""
    rng = np.random.default_rng(B * 100 + cap + n_pad + ties)
    psi, qt, qm, cents, ids, vecs = _setup(rng, B, Tq, d, dp, nlist, cap,
                                           n_pad, ties)
    p, ln = psi["dense"], psi["ln"]
    psi_q, probe = _probe(psi, qt, qm, cents, nprobe)

    # ground truth: the legacy composition, flat jax.lax.top_k on the strip
    s = ref.ivf_scan_ref(psi_q, probe, ids, vecs)
    gids = jnp.take(ids, probe, axis=0)
    kk = min(kp, nprobe * cap)
    want_s, pos = jax.lax.top_k(s.reshape(B, -1), kk)
    want_i = jnp.take_along_axis(gids.reshape(B, -1), pos, axis=1)

    ws, wi = ref.query_fused_ref(qt, qm, p["kernel"], p["bias"], ln["scale"],
                                 ln["bias"], probe, ids, vecs, kp=kp)
    assert np.array_equal(np.asarray(wi[:, :kk]), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(ws[:, :kk]), np.asarray(want_s))
    assert (np.asarray(wi[:, kk:]) == -1).all()

    ks, ki = query_fused(qt, qm, p["kernel"], p["bias"], ln["scale"],
                         ln["bias"], probe, ids, vecs, kp=kp, interpret=True)
    assert np.array_equal(np.asarray(ki), np.asarray(wi)), "kernel ids"
    finite = np.isfinite(np.asarray(ws))
    np.testing.assert_allclose(np.asarray(ks)[finite],
                               np.asarray(ws)[finite], rtol=2e-5, atol=2e-5)
    assert (np.asarray(ks)[~finite] == -np.inf).all()


def test_kernel_sq8_interpret_parity():
    """SQ8 variant: ids bit-identical, scores to the hi/lo-bf16 tolerance."""
    rng = np.random.default_rng(7)
    B, Tq, d, dp, nlist, cap, nprobe, kp = 4, 6, 16, 32, 8, 12, 3, 16
    psi, qt, qm, cents, ids, _ = _setup(rng, B, Tq, d, dp, nlist, cap, 2)
    codes = jnp.asarray(rng.integers(-127, 128, (nlist, cap, dp)), jnp.int8)
    scales = jnp.asarray(rng.random((nlist, cap)) + 0.1, jnp.float32)
    p, ln = psi["dense"], psi["ln"]
    _, probe = _probe(psi, qt, qm, cents, nprobe)
    ws, wi = ref.query_fused_ref(qt, qm, p["kernel"], p["bias"], ln["scale"],
                                 ln["bias"], probe, ids, codes, scales, kp=kp)
    ks, ki = query_fused(qt, qm, p["kernel"], p["bias"], ln["scale"],
                         ln["bias"], probe, ids, codes, scales, kp=kp,
                         interpret=True)
    assert np.array_equal(np.asarray(ki), np.asarray(wi))
    finite = np.isfinite(np.asarray(ws))
    np.testing.assert_allclose(np.asarray(ks)[finite], np.asarray(ws)[finite],
                               rtol=2 ** -13, atol=1e-3)


# --------------------------------------------------------------------------
# dense-scan twin (mips_topk)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,m,dp,kp,bm", [
    (4, 37, 16, 9, 16),     # m not a multiple of the tile
    (1, 16, 16, 16, 16),    # B=1, k' == m, exact tile
    (3, 50, 32, 50, 8),     # k' == m over many tiles
])
def test_mips_topk_matches_ref(B, m, dp, kp, bm):
    rng = np.random.default_rng(m + kp)
    q = jnp.asarray(rng.standard_normal((B, dp)), jnp.float32)
    W = jnp.asarray(rng.standard_normal((m, dp)), jnp.float32)
    # duplicate rows -> exact ties; position order must break them
    W = W.at[m // 2].set(W[m // 3])
    valid = jnp.asarray(rng.random(m) > 0.2)
    ts, ti = ref.mips_topk_ref(q, W, None, valid, kp=kp)
    ks, ki = mips_topk(q, W, None, valid, kp=kp, block_m=bm, interpret=True)
    assert np.array_equal(np.asarray(ki), np.asarray(ti))
    # scores: the kernel's per-tile dot_general can reduce in a different
    # order than the ref's one-shot matmul -> ulp-level drift, ids exact
    np.testing.assert_allclose(np.asarray(ks), np.asarray(ts), rtol=2e-5,
                               atol=2e-5)


def test_mips_topk_sq8_interpret_parity():
    rng = np.random.default_rng(11)
    B, m, dp, kp = 3, 41, 16, 12
    q = jnp.asarray(rng.standard_normal((B, dp)), jnp.float32)
    codes = jnp.asarray(rng.integers(-127, 128, (m, dp)), jnp.int8)
    scales = jnp.asarray(rng.random(m) + 0.1, jnp.float32)
    ts, ti = ref.mips_topk_ref(q, codes, scales, None, kp=kp)
    ks, ki = mips_topk(q, codes, scales, None, kp=kp, block_m=16,
                       interpret=True)
    assert np.array_equal(np.asarray(ki), np.asarray(ti))
    np.testing.assert_allclose(np.asarray(ks), np.asarray(ts),
                               rtol=2 ** -13, atol=1e-3)


# --------------------------------------------------------------------------
# system-level wiring: dispatch parity, compile keys, ladder bound, launches
# --------------------------------------------------------------------------

def _build_ivf_retriever(m=240, k_prime=64, sq8=False):
    from repro.core import LemurConfig
    from repro.data import synthetic
    from repro.retriever import LemurRetriever

    corpus = synthetic.make_corpus(m=m, d=16, avg_tokens=8, max_tokens=8,
                                   n_centers=16, seed=0)
    cfg = LemurConfig(d=16, d_prime=32, m_pretrain=64, n_train=512, n_ols=256,
                      epochs=3, k=5, k_prime=k_prime, anns="ivf",
                      ivf=LemurConfig().ivf.replace(sq8=sq8))
    r = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0))
    q = jnp.asarray(synthetic.queries_from_corpus_query(corpus, 6, 4, seed=5))
    qm = jnp.ones(q.shape[:2], bool)
    return r, q, qm


@pytest.mark.parametrize("sq8", [False, True])
def test_facade_one_launch_matches_legacy(sq8):
    """retriever.search with one-launch params == legacy params, ids AND
    scores bit-identical (same candidate set and order into the rerank);
    the two spellings get distinct compile keys."""
    from repro.retriever import SearchParams
    from repro.retriever.params import IVFSearchParams

    r, q, qm = _build_ivf_retriever(sq8=sq8)
    legacy = SearchParams()
    one = SearchParams(backend=IVFSearchParams(use_one_launch=True))
    ls, li = r.search(q, qm, legacy)
    os_, oi = r.search(q, qm, one)
    assert np.array_equal(np.asarray(li), np.asarray(oi))
    assert np.array_equal(np.asarray(ls), np.asarray(os_))
    assert r.trace_count(legacy) == 1 and r.trace_count(one) == 1


def test_facade_exact_scan_one_launch_matches_legacy():
    """use_ann=False one-launch (fused dense scan) == blocked mips_topk,
    including the k' > m pad path."""
    from repro.core import LemurConfig
    from repro.data import synthetic
    from repro.retriever import LemurRetriever, SearchParams

    corpus = synthetic.make_corpus(m=90, d=16, avg_tokens=8, max_tokens=8,
                                   n_centers=16, seed=0)
    cfg = LemurConfig(d=16, d_prime=32, m_pretrain=64, n_train=512, n_ols=256,
                      epochs=3, k=5, k_prime=120, anns="bruteforce")
    r = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0))
    q = jnp.asarray(synthetic.queries_from_corpus_query(corpus, 4, 4, seed=5))
    qm = jnp.ones(q.shape[:2], bool)
    legacy = SearchParams(use_ann=False)
    one = SearchParams(use_ann=False, use_one_launch=True)
    ls, li = r.search(q, qm, legacy)
    os_, oi = r.search(q, qm, one)
    assert np.array_equal(np.asarray(li), np.asarray(oi))
    assert np.array_equal(np.asarray(ls), np.asarray(os_))


def test_one_launch_spellings_collapse():
    """Equivalent spellings (explicit False vs default) resolve to ONE
    compiled fn; the flag itself is part of the compile key."""
    from repro.retriever import SearchParams
    from repro.retriever.params import IVFSearchParams

    r, q, qm = _build_ivf_retriever()
    a = SearchParams()
    b = SearchParams(backend=IVFSearchParams(use_one_launch=False),
                     use_one_launch=False)
    assert r.resolve(a) == r.resolve(b)
    r.search(q, qm, a)
    r.search(q, qm, b)
    assert r.trace_count() == 1
    one = SearchParams(backend=IVFSearchParams(use_one_launch=True))
    assert r.resolve(one) != r.resolve(a)


def test_launches_breakdown():
    """launch_plan accounting: legacy = 3 pre-rerank launches, one-launch =
    exactly 1 (asserted inside launch_plan too)."""
    from repro.retriever import SearchParams
    from repro.retriever.params import IVFSearchParams

    r, _, _ = _build_ivf_retriever()
    legacy = r.launches(SearchParams())
    one = r.launches(SearchParams(backend=IVFSearchParams(use_one_launch=True)))
    assert sum(v for k_, v in legacy.items() if k_ != "rerank") == 3
    assert one == {"one_launch": 1, "rerank": 1}
    exact_one = r.launches(SearchParams(use_ann=False, use_one_launch=True))
    assert sum(v for k_, v in exact_one.items() if k_ != "rerank") == 1


def test_one_launch_within_ladder_compile_bound():
    """RetrieverServer over one-launch params: ragged traffic stays within
    BucketLadder.compile_bound(1) — the fused first stage doesn't leak
    shape-special compile keys."""
    from repro.retriever import SearchParams
    from repro.retriever.params import IVFSearchParams
    from repro.serving import BucketLadder, RetrieverServer

    r, q, qm = _build_ivf_retriever(k_prime=32)
    params = SearchParams(backend=IVFSearchParams(use_one_launch=True))
    ladder = BucketLadder((4, 8), max_batch=4)
    rng = np.random.default_rng(3)
    with RetrieverServer(r, ladder=ladder, max_wait_us=500,
                         default_params=params) as srv:
        futs = []
        for i in range(10):
            tq = int(rng.integers(1, 9))
            qi = np.asarray(q[i % q.shape[0], :tq])
            futs.append((qi, srv.submit(qi)))
        for qi, fut in futs:
            s, ids = fut.result(timeout=120)
            want_s, want_i = r.search(qi[None], np.ones((1, len(qi)), bool),
                                      params)
            assert np.array_equal(ids, np.asarray(want_i)[0])
        assert srv.trace_count() <= ladder.compile_bound(1)


def test_ops_dispatch_cpu_matches_legacy():
    """On CPU the ops.fused_query dispatch IS the legacy math (oracle):
    search_ivf_one_launch returns the same candidate ids bit-for-bit as
    pool_queries + search_ivf, scores equal to jit-fusion ulps."""
    from repro.anns.ivf import build_ivf, search_ivf, search_ivf_one_launch
    from repro.core.model import init_psi, pool_queries

    rng = np.random.default_rng(2)
    m, d, dp = 500, 16, 32
    psi = init_psi(jax.random.PRNGKey(0), d, dp)
    lat = jnp.asarray(rng.standard_normal((m, dp)), jnp.float32)
    qt = jnp.asarray(rng.standard_normal((5, 6, d)), jnp.float32)
    qm = jnp.asarray(rng.random((5, 6)) > 0.3).at[:, 0].set(True)
    for sq8 in (False, True):
        idx = build_ivf(jax.random.PRNGKey(1), lat, 8, sq8=sq8)
        want = search_ivf(idx, pool_queries(psi, qt, qm), 3, 40)
        got = search_ivf_one_launch(idx, psi, qt, qm, 3, 40)
        assert np.array_equal(np.asarray(want[1]), np.asarray(got[1])), sq8
        np.testing.assert_allclose(np.asarray(want[0]), np.asarray(got[0]),
                                   rtol=2e-6, atol=2e-6)
