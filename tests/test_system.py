"""End-to-end behaviour of the LEMUR system (the paper's pipeline, Fig. 1).

Validates the framework's central promises on a small synthetic corpus:
C1-style candidate quality, ANN/exact consistency, rerank correctness, and
query-strategy robustness (App. D)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LemurConfig, build_index, maxsim, recall_at
from repro.core.index import candidates, query
from repro.data import synthetic


@pytest.fixture(scope="module")
def system():
    corpus = synthetic.make_corpus(m=1500, d=32, avg_tokens=12, max_tokens=16,
                                   n_centers=48, seed=0)
    cfg = LemurConfig(d=32, d_prime=256, m_pretrain=512, n_train=8192, n_ols=2048,
                      epochs=25, k=10, k_prime=200, anns="ivf", ivf_nprobe=24,
                      sq8=True)
    idx = build_index(jax.random.PRNGKey(0), corpus, cfg)
    q = jnp.asarray(synthetic.queries_from_corpus_query(corpus, 64, q_tokens=8, seed=99))
    qm = jnp.ones(q.shape[:2], bool)
    _, truth = maxsim.true_topk(q, qm, idx.doc_tokens, idx.doc_mask, 10)
    return corpus, cfg, idx, q, qm, truth


def test_candidate_recall_grows_with_kprime(system):
    corpus, cfg, idx, q, qm, truth = system
    recalls = []
    for kp in (20, 100, 400):
        cand = candidates(idx, q, qm, k_prime=kp)
        recalls.append(float(recall_at(cand, truth).mean()))
    assert recalls[0] <= recalls[1] <= recalls[2] + 1e-6
    assert recalls[-1] > 0.8, recalls


def test_end_to_end_recall(system):
    corpus, cfg, idx, q, qm, truth = system
    s, ids = query(idx, q, qm, k_prime=400, use_ann=False)
    rec = float(recall_at(ids, truth).mean())
    assert rec > 0.8, rec
    # reranked scores must equal exact MaxSim of the returned docs
    exact = maxsim.maxsim_scores(q, qm, idx.doc_tokens, idx.doc_mask)
    got = np.take_along_axis(np.asarray(exact), np.asarray(ids), axis=1)
    np.testing.assert_allclose(np.asarray(s), got, rtol=1e-3, atol=1e-3)


def test_ann_path_tracks_exact_path(system):
    corpus, cfg, idx, q, qm, truth = system
    _, ids_exact = query(idx, q, qm, k_prime=200, use_ann=False)
    _, ids_ann = query(idx, q, qm, k_prime=200, use_ann=True, nprobe=idx.ann.nlist)
    r_exact = float(recall_at(ids_exact, truth).mean())
    r_ann = float(recall_at(ids_ann, truth).mean())
    assert r_ann >= r_exact - 0.05  # full-probe IVF ~= exact scan


def test_lemur_beats_muvera_at_equal_budget(system):
    """Claim C1: learned LEMUR embeddings vs a MUVERA FDE of HIGHER dim."""
    from repro.anns import MuveraConfig, doc_fde, mips_topk, query_fde

    corpus, cfg, idx, q, qm, truth = system
    mcfg = MuveraConfig(r_reps=10, k_sim=4, final_dim=512)  # 2x LEMUR's 256
    dfde = doc_fde(idx.doc_tokens, idx.doc_mask, mcfg)
    qfde = query_fde(q, qm, mcfg)
    _, mu_cand = mips_topk(qfde, dfde, 100)
    le_cand = candidates(idx, q, qm, k_prime=100)
    r_mu = float(recall_at(mu_cand, truth).mean())
    r_le = float(recall_at(le_cand, truth).mean())
    assert r_le > r_mu, (r_le, r_mu)


def test_query_strategy_robustness(system):
    """App. D: corpus-trained LEMUR still works on held-out queries."""
    corpus, cfg, idx, q, qm, truth = system
    q2 = jnp.asarray(synthetic.queries_held_out(corpus, 32, q_tokens=8, seed=5))
    qm2 = jnp.ones(q2.shape[:2], bool)
    _, truth2 = maxsim.true_topk(q2, qm2, idx.doc_tokens, idx.doc_mask, 10)
    cand = candidates(idx, q2, qm2, k_prime=400)
    assert float(recall_at(cand, truth2).mean()) > 0.6
