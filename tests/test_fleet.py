"""Fleet serving conformance (repro.fleet).

The router's contracts, each driven deterministically:

* **Replica cloning** — ``clone()`` shares the built state, answers
  bit-identically, and isolates growth per clone until fanned out.
* **Deadlines + admission, all five backends** — an expired request
  resolves with a typed ``DeadlineExceeded`` (never a silent drop),
  rejected requests raise/resolve a typed ``Overloaded`` and never consume
  a micro-batch slot (server ``n_requests`` counts only served requests).
* **Router parity + exactly-once** — fleet answers are bit-identical to a
  direct ``retriever.search``; the submit/add interleaving property from
  ``test_serving_runtime.py`` extends through a 3-replica router with a
  mid-stream replica kill: no dropped, duplicated, or cross-wired ids.
* **Write barrier** — ``add()`` resolves only when every replica landed on
  the same ``snapshot_version``; a paused replica holds the barrier; a
  quarantined replica is excused.
* **Health** — a replica that stops draining with outstanding work is
  quarantined by the monitor and its requests complete elsewhere.
* **SLO controller** — breach walks one rung down, recovery is hysteretic
  (``hold`` clean evaluations below ``recover_frac * target``), every
  logged transition is consistent with the p99 that triggered it, and the
  rung ladder stays within the pre-compiled bound.

Every wait carries a timeout so a deadlocked router fails, not hangs.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.anns import registry
from repro.core import LemurConfig
from repro.data import synthetic
from repro.retriever import LemurRetriever, SearchParams
from repro.serving import (
    BucketLadder,
    DeadlineExceeded,
    Overloaded,
    RetrieverServer,
)
from repro.fleet import (
    Router,
    SLOController,
    build_rungs,
    clone_replicas,
    warm_replicas,
)

BACKENDS = registry.list_backends()
TIMEOUT = 120.0


@pytest.fixture(scope="module")
def base(tiny_corpus):
    cfg = LemurConfig(d=16, d_prime=32, m_pretrain=128, n_train=1024,
                      n_ols=512, epochs=4, k=5, k_prime=60, anns="bruteforce")
    return LemurRetriever.build(tiny_corpus, cfg, key=jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def small(tiny_corpus):
    """Tiny fast-growing retriever for interleaving/kill properties (same
    shape as test_serving_runtime.small)."""
    import dataclasses as dc

    sub = dc.replace(tiny_corpus,
                     doc_tokens=tiny_corpus.doc_tokens[:60],
                     doc_mask=tiny_corpus.doc_mask[:60],
                     topics=tiny_corpus.topics[:60])
    cfg = LemurConfig(d=16, d_prime=32, m_pretrain=48, n_train=512, n_ols=256,
                      epochs=3, k=3, k_prime=512, anns="bruteforce")
    return LemurRetriever.build(sub, cfg, key=jax.random.PRNGKey(0)), sub


def _ragged_query(tq: int, d: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((tq, d)).astype(np.float32)
    return q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-9)


def _direct(r, q: np.ndarray, params):
    s, ids = r.search(q[None], np.ones((1, q.shape[0]), bool), params)
    return np.asarray(s)[0], np.asarray(ids)[0]


# --------------------------------------------------------------------------
# replica cloning
# --------------------------------------------------------------------------

def test_clone_shares_state_and_answers_identically(base):
    c1, c2 = clone_replicas(base, 2)
    assert c1 is not base and c1 is not c2
    assert c1.index is base.index          # shared immutable snapshot
    assert c1.version == base.version
    q = _ragged_query(7, base.cfg.d, seed=3)
    _, want = _direct(base, q, None)
    for c in (c1, c2):
        assert np.array_equal(_direct(c, q, None)[1], want)


def test_clone_add_is_deterministic_and_isolated(base):
    c1, c2 = clone_replicas(base, 2)
    grow = synthetic.make_corpus(m=3, d=16, avg_tokens=8, max_tokens=12,
                                 n_centers=24, seed=77)
    c1.add(grow.doc_tokens, grow.doc_mask)
    assert (c1.m, c1.version) == (base.m + 3, 1)
    assert (c2.m, c2.version) == (base.m, 0), "add leaked across clones"
    assert base.m == c2.m, "add mutated the source retriever"
    # fan the same add out to the second clone: bit-identical W rows — the
    # invariant the fleet write barrier relies on
    c2.add(grow.doc_tokens, grow.doc_mask)
    np.testing.assert_array_equal(np.asarray(c1.index.W),
                                  np.asarray(c2.index.W))


# --------------------------------------------------------------------------
# deadlines + admission control, every backend (satellite)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", BACKENDS)
def test_deadline_and_admission_typed_outcomes(name, base):
    r = base.with_backend(name, key=jax.random.PRNGKey(1)).clone()
    ladder = BucketLadder((8,), 2)
    q = _ragged_query(6, base.cfg.d, seed=1)
    with RetrieverServer(r, ladder=ladder, max_wait_us=200,
                         max_queue_depth=3) as srv:
        _, want = srv.search(q, timeout=TIMEOUT)     # warm + sanity
        # -- deadline expiry: typed, never silent -------------------------
        srv.pause()
        expired = srv.submit(q, deadline_s=0.05)
        live = srv.submit(q)
        time.sleep(0.15)
        srv.resume()
        with pytest.raises(DeadlineExceeded) as ei:
            expired.result(timeout=TIMEOUT)
        assert ei.value.request_id == expired.request_id
        assert ei.value.waited_s >= 0.05
        assert np.array_equal(live.result(timeout=TIMEOUT)[1], want)
        assert srv.stats.n_expired == 1
        # -- admission control: typed reject, zero slots consumed ---------
        srv.pause()
        accepted = [srv.submit(q) for _ in range(3)]
        with pytest.raises(Overloaded):
            srv.submit(q)
        srv.resume()
        for f in accepted:
            assert np.array_equal(f.result(timeout=TIMEOUT)[1], want)
        assert srv.stats.n_rejected == 1
    summary = srv.stats.summary()
    # served = warm + live + 3 accepted; the expired and rejected requests
    # never occupied a micro-batch slot
    assert summary["n_requests"] == 5
    assert summary["n_expired"] == 1 and summary["n_rejected"] == 1


def test_expired_request_never_joins_a_batch(base):
    """An expired request queued BEHIND live ones is swept typed while the
    live ones coalesce without it."""
    r = base.clone()
    ladder = BucketLadder((8,), 4)
    q = _ragged_query(5, base.cfg.d, seed=2)
    with RetrieverServer(r, ladder=ladder, max_wait_us=200) as srv:
        srv.search(q, timeout=TIMEOUT)
        srv.pause()
        doomed = srv.submit(q, deadline_s=0.05)
        live = [srv.submit(q) for _ in range(3)]
        time.sleep(0.15)
        srv.resume()
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=TIMEOUT)
        for f in live:
            f.result(timeout=TIMEOUT)
    hist = srv.stats.summary()["occupancy_hist"]
    assert 4 not in hist, f"expired request joined a batch: {hist}"


# --------------------------------------------------------------------------
# router: parity, least-outstanding dispatch, exactly-once under kill
# --------------------------------------------------------------------------

def test_router_parity_and_dispatch_balance(base):
    reps = clone_replicas(base, 3)
    ladder = BucketLadder((8, 16), 4)
    warm_replicas(reps, ladder, base.cfg.d)
    with Router(reps, ladder=ladder, max_wait_us=200,
                stall_timeout_s=30.0) as router:
        # pause every replica so outstanding counts accumulate during the
        # submit burst — least-outstanding dispatch then MUST spread the
        # requests across all three (with live replicas a fast worker can
        # legitimately drain each request before the next submit arrives,
        # which makes the balance assertion timing-dependent)
        for srv in router.servers:
            srv.pause()
        futs, wants = [], []
        for i in range(24):
            q = _ragged_query(3 + (i % 10), base.cfg.d, seed=i)
            futs.append(router.submit(q))
            wants.append(_direct(base, q, None)[1])
        for srv in router.servers:
            srv.resume()
        served = set()
        for f, want in zip(futs, wants):
            _, ids = f.result(timeout=TIMEOUT)
            assert np.array_equal(ids, want), "fleet ids diverged from direct"
            served.add(f.replica)
        rids = [f.request_id for f in futs]
        assert len(set(rids)) == len(rids)
        assert served == {0, 1, 2}, (
            f"least-outstanding dispatch starved replicas: {served}")
        assert router.stats.n_completed == 24


def test_router_interleaving_with_mid_stream_kill(small):
    """The submit/add interleaving property through a 3-replica router with
    a replica killed mid-stream: every request id resolves exactly once to
    its own query's answer, adds stay snapshot-consistent fleet-wide."""
    built, sub = small
    reps = clone_replicas(built, 3)
    addpool = synthetic.make_corpus(m=16, d=16, avg_tokens=8, max_tokens=12,
                                    n_centers=24, seed=901)
    rng = np.random.default_rng(5)
    params = SearchParams(k_prime=512)
    ladder = BucketLadder((8, 16), max_batch=4)
    expected: list[tuple[object, int]] = []
    adds = []
    n_added = 0
    with Router(reps, ladder=ladder, max_wait_us=300, default_params=params,
                max_queue_depth=None, stall_timeout_s=30.0) as router:
        for step in range(24):
            if step == 12:
                router.kill_replica(1)
            roll = rng.random()
            if roll < 0.25 and n_added < addpool.m:
                adds.append(router.add(
                    addpool.doc_tokens[n_added:n_added + 1],
                    addpool.doc_mask[n_added:n_added + 1]))
                n_added += 1
            elif roll < 0.6 or n_added == 0:
                j = int(rng.integers(0, 60))
                q = sub.doc_tokens[j][sub.doc_mask[j]]
                expected.append((router.submit(np.asarray(q)), j))
            else:
                a = int(rng.integers(0, n_added))
                q = addpool.doc_tokens[a][addpool.doc_mask[a]]
                expected.append((router.submit(np.asarray(q)), 60 + a))
        for fut in adds:
            assert fut.result(timeout=TIMEOUT) <= 60 + n_added
        assert router.n_healthy == 2
        assert router.quarantined() == [1]
        # every healthy replica landed on the same final snapshot
        versions = {i: reps[i].version for i in (0, 2)}
        assert set(versions.values()) == {n_added}, versions
        tail = router.submit(
            np.asarray(sub.doc_tokens[0][sub.doc_mask[0]]))
        tail.result(timeout=TIMEOUT)
        assert tail.snapshot_version == n_added
    rids = [f.request_id for f, _ in expected]
    assert len(set(rids)) == len(rids), "duplicate fleet request ids"
    for fut, j in expected:
        assert fut.done(), f"request {fut.request_id} dropped"
        s, ids = fut.result(timeout=0)
        assert ids[0] == j, (
            f"request {fut.request_id} cross-wired: top-1 {ids[0]} != {j}")


def test_router_deadline_and_admission(base):
    reps = clone_replicas(base, 2)
    ladder = BucketLadder((8,), 2)
    warm_replicas(reps, ladder, base.cfg.d)
    q = _ragged_query(6, base.cfg.d, seed=4)
    with Router(reps, ladder=ladder, max_wait_us=200, max_queue_depth=4,
                stall_timeout_s=30.0) as router:
        for srv in router.servers:
            srv.pause()
        doomed = router.submit(q, deadline_s=0.05)
        accepted = [router.submit(q) for _ in range(3)]
        rejected = router.submit(q)          # outstanding == 4 == bound
        assert rejected.done()
        with pytest.raises(Overloaded):
            rejected.result(timeout=0)
        time.sleep(0.15)
        for srv in router.servers:
            srv.resume()
        with pytest.raises(DeadlineExceeded) as ei:
            doomed.result(timeout=TIMEOUT)
        assert ei.value.request_id == doomed.request_id
        want = _direct(base, q, None)[1]
        for f in accepted:
            assert np.array_equal(f.result(timeout=TIMEOUT)[1], want)
        assert router.stats.n_rejected == 1
        assert router.stats.n_expired == 1


# --------------------------------------------------------------------------
# write barrier + health
# --------------------------------------------------------------------------

def test_add_barrier_waits_for_every_replica(base):
    reps = clone_replicas(base, 3)
    grow = synthetic.make_corpus(m=2, d=16, avg_tokens=8, max_tokens=12,
                                 n_centers=24, seed=13)
    with Router(reps, ladder=BucketLadder((8,), 2),
                stall_timeout_s=30.0) as router:
        router.servers[2].pause()
        af = router.add(grow.doc_tokens, grow.doc_mask)
        # replicas 0/1 apply (first add compiles, so poll rather than sleep);
        # the paused replica 2 cannot, and the barrier must hold for it
        t_end = time.perf_counter() + TIMEOUT
        while ((reps[0].version < 1 or reps[1].version < 1)
               and time.perf_counter() < t_end):
            time.sleep(0.01)
        assert reps[0].version == 1 and reps[1].version == 1
        assert not af.done(), "barrier resolved before every replica applied"
        assert reps[2].version == 0
        router.servers[2].resume()
        assert af.result(timeout=TIMEOUT) == base.m + 2
        assert af.snapshot_version == 1
        assert {r.version for r in reps} == {1}
        # post-barrier searches observe the new snapshot on EVERY replica
        q = np.asarray(grow.doc_tokens[0][grow.doc_mask[0]])
        for _ in range(6):
            f = router.submit(q, params=SearchParams(use_ann=False,
                                                     k_prime=base.m + 2))
            _, ids = f.result(timeout=TIMEOUT)
            assert ids[0] == base.m and f.snapshot_version == 1


def test_add_barrier_excuses_quarantined_replica(base):
    reps = clone_replicas(base, 3)
    grow = synthetic.make_corpus(m=2, d=16, avg_tokens=8, max_tokens=12,
                                 n_centers=24, seed=14)
    with Router(reps, ladder=BucketLadder((8,), 2),
                stall_timeout_s=30.0) as router:
        router.servers[1].pause()
        af = router.add(grow.doc_tokens, grow.doc_mask)
        time.sleep(0.2)
        assert not af.done()
        router.quarantine(1, reason="test")
        assert af.result(timeout=TIMEOUT) == base.m + 2
        assert af.snapshot_version == 1
        assert reps[0].version == reps[2].version == 1


def test_router_delete_update_barrier_end_to_end(base):
    """The generalized write barrier, happy path: delete() and update()
    fan out to every replica, hold until all apply, land the fleet on one
    snapshot version, and post-barrier searches on EVERY replica see the
    replacement doc under its new id — never the tombstoned ones."""
    reps = clone_replicas(base, 3)
    grow = synthetic.make_corpus(m=4, d=16, avg_tokens=8, max_tokens=12,
                                 n_centers=24, seed=23)
    repl = synthetic.make_corpus(m=1, d=16, avg_tokens=8, max_tokens=12,
                                 n_centers=24, seed=24)
    with Router(reps, ladder=BucketLadder((8, 16), 2),
                stall_timeout_s=30.0) as router:
        af = router.add(grow.doc_tokens, grow.doc_mask)
        assert af.result(timeout=TIMEOUT) == base.m + 4
        # clones share the OLS solver => bit-identical adds => same ids
        ids = np.arange(base.m, base.m + 4)
        df = router.delete(ids[:2].tolist())
        assert df.result(timeout=TIMEOUT) == base.m + 2   # fleet n_alive
        assert df.snapshot_version == 2
        uf = router.update([int(ids[2])], repl.doc_tokens, repl.doc_mask)
        new = np.asarray(uf.result(timeout=TIMEOUT))
        assert new.tolist() == [base.m + 4]               # fresh slot id
        assert uf.snapshot_version == 3                   # ONE bump
        assert {r.version for r in reps} == {3}
        assert {r.n_alive for r in reps} == {base.m + 2}
        q3 = np.asarray(repl.doc_tokens[0][repl.doc_mask[0]])
        full = SearchParams(use_ann=False, k_prime=base.m + 5)
        for _ in range(6):
            f = router.submit(q3, params=full)
            _, got = f.result(timeout=TIMEOUT)
            assert got[0] == base.m + 4 and f.snapshot_version == 3
            assert int(ids[2]) not in got and int(ids[0]) not in got


def test_router_stop_without_drain_resolves_mutation_barriers(base):
    """The no-leak bugfix through the fleet layer: a non-drain router stop
    cancels every replica's queued mutation, and each pending fleet barrier
    (add, delete, update) resolves with a TYPED error — a caller blocked on
    ``result(timeout=...)`` never hangs, and no replica applied anything."""
    reps = clone_replicas(base, 2)
    grow = synthetic.make_corpus(m=2, d=16, avg_tokens=8, max_tokens=12,
                                 n_centers=24, seed=21)
    router = Router(reps, ladder=BucketLadder((8,), 2),
                    stall_timeout_s=30.0).start()
    for srv in router.servers:
        srv.pause()                 # wedge both workers: barriers stay queued
    af = router.add(grow.doc_tokens, grow.doc_mask)
    df = router.delete([0])
    uf = router.update([1], grow.doc_tokens[:1], grow.doc_mask[:1])
    assert not af.done() and not df.done() and not uf.done()
    router.stop(drain=False, timeout=TIMEOUT)
    for f in (af, df, uf):
        with pytest.raises(RuntimeError, match="no replica completed"):
            f.result(timeout=5.0)   # resolves promptly, typed — not a hang
    assert {r.version for r in reps} == {0}, "cancelled mutation applied"


def test_stalled_replica_quarantined_and_requests_rehomed(base):
    reps = clone_replicas(base, 2)
    ladder = BucketLadder((8,), 2)
    warm_replicas(reps, ladder, base.cfg.d)
    q = _ragged_query(6, base.cfg.d, seed=6)
    with Router(reps, ladder=ladder, max_wait_us=200,
                stall_timeout_s=0.3, health_interval_s=0.05) as router:
        for _ in range(4):
            router.search(q, timeout=TIMEOUT)
        router.servers[0].pause()
        futs = [router.submit(q) for _ in range(8)]
        want = _direct(base, q, None)[1]
        for f in futs:   # stalled replica's share re-dispatched to replica 1
            assert np.array_equal(f.result(timeout=TIMEOUT)[1], want)
        deadline = time.monotonic() + 10
        while 0 not in router.quarantined() and time.monotonic() < deadline:
            time.sleep(0.05)
        assert router.quarantined() == [0], router.events()
        ev = [e for e in router.events() if e["replica"] == 0]
        assert ev and "no progress" in ev[0]["reason"]
        assert router.stats.n_redispatched > 0
        assert router.stats.n_completed == 12


# --------------------------------------------------------------------------
# SLO controller
# --------------------------------------------------------------------------

def test_build_rungs_walks_nprobe_and_k_prime(base):
    r = base.with_backend("ivf", key=jax.random.PRNGKey(1))
    rungs = build_rungs(r, n_rungs=3)
    assert len(rungs) == 3
    assert rungs[0] == r.resolve(None)
    for a, b in zip(rungs, rungs[1:]):
        assert b.k_prime == max(a.k_prime // 2, max(a.k, 8))
        assert b.backend.nprobe == max(a.backend.nprobe // 2, 1)
        assert b.k == a.k, "rungs must not change the response contract"
    # the ladder saturates at the floors instead of emitting duplicates
    assert len(build_rungs(r, n_rungs=50)) < 50
    # backends without an nprobe knob still degrade via k_prime
    rungs_bf = build_rungs(base, n_rungs=2)
    assert rungs_bf[1].k_prime == rungs_bf[0].k_prime // 2


def test_slo_controller_downshift_and_hysteretic_recovery():
    rungs = ["full", "half", "quarter"]
    slo = SLOController(rungs, target_p99_ms=10.0, window=8, min_window=4,
                        eval_every=4, recover_frac=0.7, hold=3)
    assert slo.params() == "full"
    # sustained breach: one rung down per evaluation, never past the floor
    for _ in range(4):
        slo.observe(0.050)          # 50ms >> 10ms target
    assert slo.rung == 1
    for _ in range(4):
        slo.observe(0.050)
    assert slo.rung == 2 and slo.params() == "quarter"
    for _ in range(8):
        slo.observe(0.050)
    assert slo.rung == 2, "stepped past the last rung"
    # mid-band latencies (between recover_frac*target and target): hold
    for _ in range(16):
        slo.observe(0.009)          # 9ms: below target, above 7ms recover
    assert slo.rung == 2, "recovered without clearing the hysteresis band"
    # clean latencies: recovery needs `hold` consecutive clean evaluations
    # over an all-clean window
    for _ in range(8):
        slo.observe(0.001)
    assert slo.rung == 2
    for _ in range(8):
        slo.observe(0.001)          # 3rd clean evaluation -> step up
    assert slo.rung == 1
    for tr in slo.transitions:
        if tr.direction == "down":
            assert tr.p99_ms > tr.target_ms
        else:
            assert tr.p99_ms < 0.7 * tr.target_ms
    downs = [t for t in slo.transitions if t.direction == "down"]
    ups = [t for t in slo.transitions if t.direction == "up"]
    assert len(downs) == 2 and len(ups) == 1


def test_slo_window_cleared_on_transition():
    slo = SLOController([0, 1], target_p99_ms=10.0, min_window=4,
                        eval_every=4)
    for _ in range(4):
        slo.observe(0.050)
    assert slo.rung == 1
    assert np.isnan(slo.windowed_p99_ms()), (
        "stale pre-transition samples survived the downshift")


def test_router_slo_downshift_under_breach_and_recovery(base):
    """Fleet integration: a breached target walks dispatch down one rung
    (observable on future.params), a cleared target walks it back up."""
    r = base.with_backend("ivf", key=jax.random.PRNGKey(1))
    reps = clone_replicas(r, 2)
    rungs = build_rungs(reps[0], n_rungs=2)
    ladder = BucketLadder((8,), 2)
    warm_replicas(reps, ladder, base.cfg.d, params_list=rungs)
    slo = SLOController(rungs, target_p99_ms=1e-6, window=32, min_window=4,
                        eval_every=4, hold=2)
    q = _ragged_query(6, base.cfg.d, seed=8)
    with Router(reps, ladder=ladder, max_wait_us=200, slo=slo,
                stall_timeout_s=30.0) as router:
        futs = [router.submit(q) for _ in range(8)]
        for f in futs:
            f.result(timeout=TIMEOUT)
        assert slo.rung == 1, "SLO never downshifted under a breached target"
        assert futs[0].params == rungs[0]
        # dispatch now rides the degraded rung, with parity at that rung
        f = router.submit(q)
        _, ids = f.result(timeout=TIMEOUT)
        assert f.params == rungs[1]
        assert np.array_equal(ids, _direct(r, q, rungs[1])[1])
        # clear the target: hysteretic recovery back to rung 0
        slo.target_p99_ms = 1e9
        for _ in range(16):
            router.search(q, timeout=TIMEOUT)
        assert slo.rung == 0
        assert router.submit(q).params == rungs[0]
        downs = [t for t in slo.transitions if t.direction == "down"]
        assert downs and all(t.p99_ms > t.target_ms for t in downs)


# --------------------------------------------------------------------------
# fleet overload: typed rejects, nothing lost
# --------------------------------------------------------------------------

def test_fleet_overload_every_request_accounted(base):
    reps = clone_replicas(base, 2)
    ladder = BucketLadder((8,), 2)
    warm_replicas(reps, ladder, base.cfg.d)
    q = _ragged_query(6, base.cfg.d, seed=9)
    with Router(reps, ladder=ladder, max_wait_us=200, max_queue_depth=6,
                stall_timeout_s=30.0) as router:
        for srv in router.servers:
            srv.pause()
        futs = [router.submit(q) for _ in range(32)]
        for srv in router.servers:
            srv.resume()
        outcomes = {"ok": 0, "rejected": 0}
        for f in futs:
            try:
                f.result(timeout=TIMEOUT)
                outcomes["ok"] += 1
            except Overloaded:
                outcomes["rejected"] += 1
        assert outcomes["ok"] + outcomes["rejected"] == 32, "requests lost"
        assert outcomes["ok"] == 6 and outcomes["rejected"] == 26
        assert router.stats.n_rejected == 26
        # rejected requests never reached any replica queue
        served = sum(s.stats.summary()["n_requests"] for s in router.servers)
        assert served == 6


def test_router_submit_thread_safety(base):
    """Concurrent submitters: ids stay unique, every future resolves."""
    reps = clone_replicas(base, 2)
    ladder = BucketLadder((8,), 4)
    warm_replicas(reps, ladder, base.cfg.d)
    with Router(reps, ladder=ladder, max_wait_us=500,
                stall_timeout_s=30.0) as router:
        futs: list = []
        lock = threading.Lock()

        def client(seed):
            for i in range(8):
                f = router.submit(_ragged_query(4, base.cfg.d,
                                                seed=seed * 100 + i))
                with lock:
                    futs.append(f)

        threads = [threading.Thread(target=client, args=(s,))
                   for s in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for f in futs:
            f.result(timeout=TIMEOUT)
        rids = [f.request_id for f in futs]
        assert len(set(rids)) == len(rids) == 32


# --------------------------------------------------------------------------
# bounded observability state (a long-lived fleet must not leak)
# --------------------------------------------------------------------------

def test_router_event_log_bounded_with_dropped_counter(base):
    reps = clone_replicas(base, 1)
    with Router(reps, ladder=BucketLadder((8,), 2), stall_timeout_s=30.0,
                event_log_size=4) as router:
        assert router.events_dropped == 0
        with router._lock:
            for i in range(9):
                router._record_event(t=float(i), event="test", seq=i)
        evs = router.events()
        assert len(evs) == 4, "event ring exceeded its bound"
        assert [e["seq"] for e in evs] == [5, 6, 7, 8], "ring kept oldest"
        assert router.events_dropped == 5


def test_fleet_stats_latency_windows_bounded():
    from repro.fleet.router import FleetStats

    st = FleetStats(window=8)
    for i in range(100):
        st.record_completed(0.001 * (i + 1), 0.001 * (i + 1), float(i))
    s = st.summary()
    assert s["n_requests"] == 100           # counters stay exact totals
    # percentile state only ever sees the window tail
    assert st._lat.maxlen == 8 and len(st._lat) == 8
    assert st._submit_lat.maxlen == 8 and len(st._submit_lat) == 8


# --------------------------------------------------------------------------
# SLO floor-rung edge: breach with nothing left to shed
# --------------------------------------------------------------------------

def test_slo_floor_breach_no_spurious_transition_and_recovery():
    """A sustained breach AT the floor rung must not clear the window or
    record same-rung transitions — and once load drops, the normal
    recovery hysteresis must still engage from real samples."""
    slo = SLOController([0, 1], target_p99_ms=10.0, window=8, min_window=4,
                        eval_every=4, recover_frac=0.7, hold=2)
    for _ in range(4):
        slo.observe(0.050)
    assert slo.rung == 1                    # at the floor now
    n_tr = len(slo.transitions)
    for _ in range(40):
        slo.observe(0.050)                  # sustained breach at the floor
    assert slo.rung == 1
    assert len(slo.transitions) == n_tr, (
        "breach at the floor recorded a spurious transition")
    assert slo.n_floor_breaches == 10       # every evaluation counted
    assert not np.isnan(slo.windowed_p99_ms()), (
        "floor breach cleared the latency window")
    # load drops: recovery must work exactly as from any other rung
    for _ in range(16):
        slo.observe(0.001)
    assert slo.rung == 0, "recovery hysteresis broken after floor breaches"


def test_slo_floor_breach_resets_clear_streak():
    """A breach evaluation at the floor interrupts a recovery streak: the
    controller must demand `hold` CONSECUTIVE clean evaluations again."""
    slo = SLOController([0, 1], target_p99_ms=10.0, window=4, min_window=4,
                        eval_every=4, recover_frac=0.7, hold=2)
    for _ in range(4):
        slo.observe(0.050)
    assert slo.rung == 1
    for _ in range(4):
        slo.observe(0.001)                  # clean eval #1 (streak 1/2)
    for _ in range(4):
        slo.observe(0.050)                  # breach at floor: streak reset
    for _ in range(4):
        slo.observe(0.001)                  # clean again: streak 1/2 only
    assert slo.rung == 1, "recovered without `hold` consecutive clean evals"
    for _ in range(4):
        slo.observe(0.001)                  # streak 2/2
    assert slo.rung == 0
