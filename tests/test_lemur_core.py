"""LEMUR model/indexer invariants: pooling linearity, OLS optimality, e2e recall."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import LemurConfig, indexer, maxsim
from repro.core.model import (
    init_phi,
    init_psi,
    phi_apply,
    pool_queries,
    psi_apply,
    standardize_targets,
    train_phi,
)

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def test_pooling_linearity():
    """Ψ(X1 ∪ X2) = Ψ(X1) + Ψ(X2) (eq. 5 — the reduction's linchpin)."""
    rng = np.random.default_rng(0)
    psi = init_psi(jax.random.PRNGKey(0), 16, 32)
    x1 = jnp.asarray(rng.standard_normal((2, 3, 16)), jnp.float32)
    x2 = jnp.asarray(rng.standard_normal((2, 5, 16)), jnp.float32)
    both = jnp.concatenate([x1, x2], axis=1)
    p = pool_queries(psi, both)
    np.testing.assert_allclose(
        np.asarray(p), np.asarray(pool_queries(psi, x1) + pool_queries(psi, x2)),
        rtol=1e-4, atol=1e-5,
    )


def test_phi_factorizes_through_psi():
    """f(X) ≈ W Ψ(X): summing per-token outputs == pooled-then-projected (eq. 5)."""
    rng = np.random.default_rng(1)
    phi = init_phi(jax.random.PRNGKey(1), 16, 32, 50)
    x = jnp.asarray(rng.standard_normal((7, 16)), jnp.float32)
    per_token = phi_apply(phi, x).sum(axis=0)
    pooled = pool_queries(phi["psi"], x[None]) @ phi["out"]
    np.testing.assert_allclose(np.asarray(per_token), np.asarray(pooled[0]),
                               rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1))
def test_ols_residual_orthogonality(seed):
    """The OLS solution's residual is orthogonal to the features (exact-min
    certificate for eq. 7, up to the ridge term)."""
    rng = np.random.default_rng(seed)
    n, dp = 64, 8
    feats = jnp.asarray(rng.standard_normal((n, dp)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    ridge = 1e-6
    gram = feats.T @ feats + ridge * n * jnp.eye(dp)
    w = jnp.linalg.solve(gram, feats.T @ g)
    resid = g - feats @ w
    # Xᵀr = λ n w
    np.testing.assert_allclose(
        np.asarray(feats.T @ resid), np.asarray(ridge * n * w), rtol=1e-2, atol=1e-3
    )


def test_ols_beats_random_beta(tiny_corpus):
    cfg = LemurConfig(d=16, d_prime=32, m_pretrain=64, n_train=512, n_ols=256,
                      epochs=2, ridge=1e-4)
    rng = np.random.default_rng(0)
    psi = init_psi(jax.random.PRNGKey(0), 16, 32)
    x = jnp.asarray(rng.standard_normal((256, 16)), jnp.float32)
    docs = jnp.asarray(tiny_corpus.doc_tokens[:40])
    mask = jnp.asarray(tiny_corpus.doc_mask[:40])
    W = indexer.fit_output_layer_ols(psi, x, docs, mask, cfg)
    feats = psi_apply(psi, x)
    g = maxsim.token_maxsim(x, docs, mask)
    mse_ols = float(jnp.mean(jnp.square(feats @ W.T - g)))
    for seed in range(3):
        W2 = W + 0.05 * jnp.asarray(np.random.default_rng(seed).standard_normal(W.shape),
                                    jnp.float32)
        mse2 = float(jnp.mean(jnp.square(feats @ W2.T - g)))
        assert mse_ols <= mse2 + 1e-6


def test_incremental_indexing_matches_batch(tiny_corpus):
    """fit_docs on shards == fit_output_layer_ols on the whole corpus (the
    embarrassingly-parallel indexing property, §4.3)."""
    cfg = LemurConfig(d=16, d_prime=32, ridge=1e-4)
    rng = np.random.default_rng(0)
    psi = init_psi(jax.random.PRNGKey(0), 16, 32)
    x = jnp.asarray(rng.standard_normal((128, 16)), jnp.float32)
    docs = jnp.asarray(tiny_corpus.doc_tokens[:30])
    mask = jnp.asarray(tiny_corpus.doc_mask[:30])
    W = indexer.fit_output_layer_ols(psi, x, docs, mask, cfg)
    state = indexer.ols_solver_state(psi, x, cfg)
    w_a = indexer.fit_docs(state, docs[:13], mask[:13])
    w_b = indexer.fit_docs(state, docs[13:], mask[13:])
    np.testing.assert_allclose(np.asarray(jnp.concatenate([w_a, w_b])), np.asarray(W),
                               rtol=5e-3, atol=1e-3)  # fp32 GEMM re-association across block splits


def test_train_phi_reduces_loss(tiny_corpus):
    cfg = LemurConfig(d=16, d_prime=64, epochs=6, batch_size=64, n_train=256)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((256, 16)), jnp.float32)
    docs = jnp.asarray(tiny_corpus.doc_tokens[:32])
    mask = jnp.asarray(tiny_corpus.doc_mask[:32])
    g = maxsim.token_maxsim(x, docs, mask)
    params, stats, losses = train_phi(jax.random.PRNGKey(0), x, g, cfg)
    assert losses[-1] < losses[0] * 0.8


def test_standardize_targets_roundtrip():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((50, 7)) * 3 + 2, jnp.float32)
    gs, stats = standardize_targets(g)
    assert abs(float(gs.mean())) < 1e-5
    assert abs(float(gs.std()) - 1) < 1e-4
    np.testing.assert_allclose(np.asarray(gs * stats.std + stats.mean), np.asarray(g),
                               rtol=1e-4, atol=1e-4)


def test_e2e_candidate_recall(tiny_corpus):
    """Exact-latent candidates at k'=m recover ALL true neighbors (recall 1)."""
    from repro.core.index import build_index, candidates

    from repro.data import synthetic

    cfg = LemurConfig(d=16, d_prime=64, m_pretrain=128, n_train=1024, n_ols=512,
                      epochs=5, k=5, k_prime=tiny_corpus.m, anns="exact")
    idx = build_index(jax.random.PRNGKey(0), tiny_corpus, cfg)
    q = jnp.asarray(synthetic.queries_from_corpus_query(tiny_corpus, 8, q_tokens=4))
    qm = jnp.ones(q.shape[:2], bool)
    _, ti = maxsim.true_topk(q, qm, idx.doc_tokens, idx.doc_mask, 5)
    cand = candidates(idx, q, qm, k_prime=tiny_corpus.m)
    rec = float(maxsim.recall_at(cand, ti).mean())
    assert rec == 1.0
