"""Static sharding validation: every param leaf of every arch resolves to a
spec whose axes divide the production mesh — catches config/rule drift
without compiling (the cheap canary for the dry-run)."""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_arch
from repro.common.pytree import tree_map_with_name

MESHES = {"single": {"data": 16, "model": 16},
          "multi": {"pod": 2, "data": 16, "model": 16}}


def _check_divisible(name, shape, spec, mesh_shape):
    for dim, axis in zip(shape, tuple(spec)):
        if axis is None:
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        factor = int(np.prod([mesh_shape[a] for a in axes]))
        assert dim % factor == 0, (
            f"{name}: dim {dim} not divisible by {factor} ({spec})"
        )


@pytest.mark.parametrize("mesh_name", ["single", "multi"])
@pytest.mark.parametrize(
    "arch", ["qwen2.5-32b", "granite-20b", "gemma-7b",
             "llama4-maverick-400b-a17b", "deepseek-v3-671b"]
)
def test_lm_param_shardings_divide(arch, mesh_name):
    from repro.dist.sharding import LM_RULES, LM_RULES_FFSLICE
    from repro.launch.cells import _resolve_spec
    from repro.models import lm

    cfg = get_arch(arch).CONFIG
    rules = LM_RULES_FFSLICE if cfg.moe_layout == "ffslice" and cfg.moe_n_experts else LM_RULES
    params = jax.eval_shape(lambda: lm.init_lm(jax.random.PRNGKey(0), cfg))
    mesh_shape = MESHES[mesh_name]

    def check(name, leaf):
        spec = _resolve_spec(rules, name, len(leaf.shape))
        _check_divisible(f"{arch}:{name}", leaf.shape, spec, mesh_shape)
        return leaf

    tree_map_with_name(check, params)


@pytest.mark.parametrize(
    "arch", ["deepfm", "xdeepfm", "bst", "two-tower-retrieval"]
)
def test_recsys_param_shardings_divide(arch):
    from repro.dist.sharding import RECSYS_RULES
    from repro.launch.cells import _resolve_spec
    from repro.models import recsys

    cfg = get_arch(arch).CONFIG
    params = jax.eval_shape(lambda: recsys.init_recsys(jax.random.PRNGKey(0), cfg))

    def check(name, leaf):
        spec = _resolve_spec(RECSYS_RULES, name, len(leaf.shape))
        _check_divisible(f"{arch}:{name}", leaf.shape, spec, MESHES["single"])
        return leaf

    tree_map_with_name(check, params)


def test_lm_shape_cells_batch_divisible():
    """Train/prefill batch dims divide the data axes on both meshes."""
    for arch in ("qwen2.5-32b", "granite-20b", "gemma-7b",
                 "llama4-maverick-400b-a17b", "deepseek-v3-671b"):
        shapes = get_arch(arch).SHAPES
        for name, spec in shapes.items():
            gb = spec["global_batch"]
            if spec["kind"] in ("train", "prefill"):
                assert gb % 32 == 0 or gb == 32, (arch, name, gb)
            seq = spec["seq"]
            assert seq % 16 == 0  # model-axis seq sharding


def test_cells_resolve_specs_for_lm_and_recsys():
    """Regression: launch/cells.py imports repro.dist.sharding and builds
    full cells — every in_sharding leaf resolves to a NamedSharding on the
    mesh — for one LM and one recsys config (no compilation, eval_shape
    only)."""
    from jax.sharding import NamedSharding

    from repro.common.compat import make_mesh
    from repro.launch import cells
    from repro.models import lm, recsys

    mesh = make_mesh((1, 1), ("data", "model"))

    lm_cfg = get_arch("gemma-7b").CONFIG
    cell = cells.lm_prefill_cell("gemma-7b", lm_cfg, seq=128, global_batch=1,
                                 mesh=mesh)
    rs_cfg = get_arch("two-tower-retrieval").CONFIG
    rcell = cells.recsys_cell("two-tower-retrieval", rs_cfg, batch=32,
                              mesh=mesh, kind="train")
    for c in (cell, rcell):
        leaves = jax.tree_util.tree_leaves(
            c.in_shardings,
            is_leaf=lambda x: isinstance(x, NamedSharding))
        assert leaves and all(isinstance(l, NamedSharding) for l in leaves), c.arch
        assert all(l.mesh == mesh for l in leaves), c.arch


def test_rules_first_match_wins():
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import ShardingRules

    rules = ShardingRules(rules=((r"special/w$", P("model")), (r".*", P())))
    assert rules.spec("special/w", 1) == P("model")
    assert rules.spec("other/w", 2) == P()


def test_rule_rank_overflow_raises():
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import ShardingRules

    rules = ShardingRules(rules=((r".*", P("data", "model")),))
    with pytest.raises(ValueError):
        rules.spec("w", 1)
