"""Optimizers: Adam reference semantics, 8-bit Adam, clip, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adam_init, adam_update, linear_warmup_cosine, quantize_int8, dequantize_int8
from repro.optim.adam import clip_by_global_norm
from repro.optim.adam8bit import Q8, adam8_init, adam8_update, _quantize, _dequantize


def test_adam_first_step_matches_closed_form():
    params = {"w": jnp.asarray([1.0, 2.0])}
    grads = {"w": jnp.asarray([0.1, -0.2])}
    st = adam_init(params)
    new, st2, m = adam_update(grads, st, params, lr=0.01, grad_clip=None)
    # step 1: mhat = g, vhat = g^2 -> delta = g/(|g|+eps) = sign(g)
    np.testing.assert_allclose(np.asarray(new["w"]), [1.0 - 0.01, 2.0 + 0.01], rtol=1e-4)


def test_clip_by_global_norm():
    grads = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    total = jnp.sqrt(clipped["a"][0] ** 2 + clipped["b"][0] ** 2)
    assert abs(float(total) - 1.0) < 1e-5


def test_adam_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    st = adam_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, st, _ = adam_update(grads, st, params, lr=0.1, grad_clip=None)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_adam8_tracks_adam():
    p1 = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((8, 64)), jnp.float32)}
    p2 = jax.tree_util.tree_map(lambda x: x, p1)
    s1, s2 = adam_init(p1), adam8_init(p2)
    rng = np.random.default_rng(1)
    for _ in range(20):
        g = {"w": jnp.asarray(rng.standard_normal((8, 64)) * 0.1, jnp.float32)}
        p1, s1, _ = adam_update(g, s1, p1, lr=0.01, grad_clip=None)
        p2, s2, _ = adam8_update(g, s2, p2, lr=0.01, grad_clip=None)
    diff = float(jnp.max(jnp.abs(p1["w"] - p2["w"])))
    assert diff < 0.15, diff  # int8 moments: bounded drift, not bit-exact


def test_q8_shapes_and_sharding_friendliness():
    """Per-row scales: no flat reshape (the GSPMD-safety property)."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 6, 32)), jnp.float32)
    q = _quantize(x)
    assert q.q.shape == x.shape
    assert q.scale.shape == (4, 6)
    err = jnp.abs(_dequantize(q) - x)
    assert float(jnp.max(err - q.scale[..., None] / 2)) <= 1e-6


def test_schedule_warmup_then_decay():
    lr = linear_warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1.0) < 0.11
    assert float(lr(jnp.asarray(100))) < 0.2


def test_int8_compression_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(100) * 3, jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(jnp.max(err)) <= float(s) / 2 + 1e-6


def test_ef_int8_allreduce_error_feedback():
    """Over many steps the error-feedback compression is unbiased: the sum of
    dequantized transmissions converges to the sum of true gradients."""
    from repro.optim.compress import ef_int8_allreduce
    from repro.common.compat import AxisType, make_mesh, shard_map
    from jax.sharding import PartitionSpec as P

    mesh = make_mesh((1,), ("pod",), axis_types=(AxisType.Auto,))
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.standard_normal(32), jnp.float32) for _ in range(30)]
    err = {"g": jnp.zeros(32)}
    sent_total = jnp.zeros(32)
    for g in g_true:
        def body(g, e):
            return ef_int8_allreduce({"g": g}, e, "pod")

        (red, err) = shard_map(body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                               check_vma=False)(g, err)
        sent_total = sent_total + red["g"]
    true_total = sum(np.asarray(g) for g in g_true)
    np.testing.assert_allclose(np.asarray(sent_total), true_total, atol=0.2)
