"""Backend registry contract: every registered first-stage backend obeys the
same build/search/add protocol and serves the unified query() pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.anns import registry
from repro.anns.base import CorpusView, QueryBatch
from repro.core import LemurConfig, maxsim, recall_at
from repro.core.index import add_docs, attach_backend, build_index, query

BACKENDS = registry.list_backends()

# recall@10 floor per backend relative to the bruteforce first stage; exact
# methods must match it, sketch/pruning baselines get an approximation margin
PARITY = {"bruteforce": 1.0, "ivf": 0.95, "muvera": 0.7, "dessert": 0.7,
          "token_pruning": 0.6}


@pytest.fixture(scope="module")
def protocol_data(tiny_corpus):
    rng = np.random.default_rng(7)
    m, dp = 150, 32
    view = CorpusView(
        jnp.asarray(rng.standard_normal((m, dp)), jnp.float32),
        jnp.asarray(tiny_corpus.doc_tokens[:m]),
        jnp.asarray(tiny_corpus.doc_mask[:m]),
    )
    extra = CorpusView(
        jnp.asarray(rng.standard_normal((40, dp)), jnp.float32),
        jnp.asarray(tiny_corpus.doc_tokens[m:m + 40]),
        jnp.asarray(tiny_corpus.doc_mask[m:m + 40]),
    )
    qb = QueryBatch(
        jnp.asarray(rng.standard_normal((5, dp)), jnp.float32),
        jnp.asarray(tiny_corpus.doc_tokens[:5, :6]),
        jnp.asarray(tiny_corpus.doc_mask[:5, :6]),
    )
    return view, extra, qb


@pytest.fixture(scope="module")
def lemur_system(tiny_corpus):
    from repro.data import synthetic

    cfg = LemurConfig(d=16, d_prime=64, m_pretrain=128, n_train=1024, n_ols=512,
                      epochs=5, k=10, k_prime=60, anns="bruteforce",
                      ivf_nprobe=32)
    idx = build_index(jax.random.PRNGKey(0), tiny_corpus, cfg)
    q = jnp.asarray(synthetic.queries_from_corpus_query(tiny_corpus, 16, 4, seed=3))
    qm = jnp.ones(q.shape[:2], bool)
    _, truth = maxsim.true_topk(q, qm, idx.doc_tokens, idx.doc_mask, 10)
    _, bf_ids = query(idx, q, qm)
    bf_rec = float(recall_at(bf_ids, truth).mean())
    return idx, q, qm, truth, bf_rec


@pytest.mark.parametrize("name", BACKENDS)
def test_build_search_contract(name, protocol_data):
    """search returns (B, k) scores + int32 ids in [-1, m), -1-padded, with
    valid ids unique per row and scores descending."""
    view, _, qb = protocol_data
    be = registry.get_backend(name)
    state = be.build(jax.random.PRNGKey(0), view, None)
    for k in (10, view.m + 20):  # including k > m: must pad, not crash
        scores, ids = be.search(state, qb, k)
        assert scores.shape == (5, k) and ids.shape == (5, k)
        assert ids.dtype == jnp.int32
        ids_np = np.asarray(ids)
        assert ids_np.min() >= -1 and ids_np.max() < view.m
        for row in ids_np:
            valid = row[row >= 0]
            assert len(set(valid.tolist())) == len(valid), "duplicate candidates"
        d = np.diff(np.asarray(scores), axis=1)
        assert (d[~np.isnan(d)] <= 1e-5).all(), "scores not sorted"  # NaN: -inf pads


@pytest.mark.parametrize("name", BACKENDS)
def test_add_contract(name, protocol_data):
    """add() appends docs with ids continuing the numbering, and the grown
    index still returns only valid ids over the larger corpus."""
    view, extra, qb = protocol_data
    be = registry.get_backend(name)
    state = be.build(jax.random.PRNGKey(0), view, None)
    state2 = be.add(state, extra)
    _, ids = be.search(state2, qb, view.m + extra.m)
    ids_np = np.asarray(ids)
    assert ids_np.max() < view.m + extra.m
    # every added doc must be reachable from the grown index
    got = set(ids_np.flatten().tolist())
    new_ids = set(range(view.m, view.m + extra.m))
    assert new_ids & got, "no added doc ever retrieved"


@pytest.mark.parametrize("name", BACKENDS)
def test_search_is_jitable_no_retrace(name, protocol_data):
    view, _, qb = protocol_data
    be = registry.get_backend(name)
    state = be.build(jax.random.PRNGKey(0), view, None)
    traces = []

    @jax.jit
    def go(st, q):
        traces.append(1)
        return be.search(st, q, 10)

    go(state, qb)
    go(state, qb)
    assert len(traces) == 1, f"{name} retraced under jit"


@pytest.mark.parametrize("name", BACKENDS)
def test_query_recall_parity(name, lemur_system):
    """query() through every backend clears its recall floor vs the
    bruteforce first stage on the same trained reduction."""
    idx, q, qm, truth, bf_rec = lemur_system
    bidx = attach_backend(idx, name, key=jax.random.PRNGKey(1))
    # ivf's parity contract is at full probe (its exactness guarantee);
    # partial-probe recall/latency tradeoffs are benchmarked, not asserted
    nprobe = bidx.ann.nlist if name == "ivf" else None
    _, ids = jax.jit(lambda a, b: query(bidx, a, b, nprobe=nprobe))(q, qm)
    rec = float(recall_at(ids, truth).mean())
    assert rec >= PARITY[name] * bf_rec - 1e-6, (
        f"{name}: recall {rec:.3f} < {PARITY[name]:.2f} x bruteforce {bf_rec:.3f}")


def test_registry_aliases_and_errors():
    assert registry.get_backend("exact") is registry.get_backend("bruteforce")
    with pytest.raises(KeyError, match="unknown anns backend"):
        registry.get_backend("hnswlib")
    with pytest.raises(ValueError, match="not a registered backend"):
        LemurConfig(anns="faiss")


def test_rerank_masks_padded_candidates(tiny_corpus):
    """-1 pads must score NEG, not alias doc 0 (the old clamp inflated
    recall with duplicate doc-0 candidates)."""
    docs = jnp.asarray(tiny_corpus.doc_tokens[:50])
    mask = jnp.asarray(tiny_corpus.doc_mask[:50])
    q = jnp.asarray(tiny_corpus.doc_tokens[:2, :4])
    qm = jnp.ones((2, 4), bool)
    cand = jnp.asarray([[3, 7, -1, -1], [0, -1, -1, -1]], jnp.int32)
    scores, ids = maxsim.rerank(q, qm, cand, docs, mask, 3)
    ids_np = np.asarray(ids)
    # row 0: two real candidates then a -1 pad; doc 0 must NOT appear
    assert set(ids_np[0, :2].tolist()) == {3, 7}
    assert ids_np[0, 2] == -1
    # row 1: only doc 0 is real
    assert ids_np[1, 0] == 0 and (ids_np[1, 1:] == -1).all()
    assert float(np.asarray(scores)[0, 2]) <= maxsim.NEG / 2


# --------------------------------------------------------------------------
# cross-tier conformance: backend x storage tier x gather path
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tier_system():
    """fp32-store and residual-store twins over the SAME reduction on a
    well-separated corpus (one topic per doc, strongly expressed), so every
    tier x backend x gather path must retrieve a doc's own token set top-1.
    The codec key is folded off the build key, so ψ/W are bit-identical
    between the twins; k' covers the whole corpus so approximate first
    stages cannot blur the contract."""
    from repro.anns.params import ResidualConfig
    from repro.data import synthetic
    from repro.retriever import LemurRetriever

    corpus = synthetic.make_corpus(m=64, d=16, avg_tokens=8, max_tokens=12,
                                   n_centers=64, topic_strength=4.0, seed=5)
    cfg = LemurConfig(d=16, d_prime=32, m_pretrain=48, n_train=512, n_ols=256,
                      epochs=3, k=5, k_prime=64, anns="bruteforce")
    rcfg = cfg.replace(residual=ResidualConfig(enabled=True, bits=4, ncent=32,
                                               kmeans_iters=4,
                                               token_budget=6))
    r_fp = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0))
    r_res = LemurRetriever.build(corpus, rcfg, key=jax.random.PRNGKey(0))
    picks = [3, 17, 31, 50]
    q = jnp.asarray(corpus.doc_tokens[picks])
    qm = jnp.asarray(corpus.doc_mask[picks])
    return r_fp, r_res, q, qm, picks


@pytest.mark.parametrize("name", BACKENDS)
def test_cross_tier_grid_identical_ids(name, tier_system):
    """Within a tier, every gather path (fused kernel / legacy HBM gather /
    residual-decoded view) returns IDENTICAL ids; across tiers, the top-1
    self-retrieval agrees — for every registered backend."""
    from repro.retriever import LemurRetriever, SearchParams

    r_fp, r_res, q, qm, picks = tier_system
    key = jax.random.PRNGKey(1)
    for base in (r_fp, r_res):
        r = base.with_backend(name, key=key)
        spellings = [SearchParams(), SearchParams(use_fused_gather=False)]
        if r.index.store.residual:
            # use_residual=False on a residual store reads the decoded
            # fp32 view through the legacy gather — same answers required
            spellings.append(SearchParams(use_residual=False))
        ids = [np.asarray(r.search(q, qm, p)[1]) for p in spellings]
        for other in ids[1:]:
            np.testing.assert_array_equal(other, ids[0])
        assert ids[0][:, 0].tolist() == picks, (
            f"{name}/{'res' if r.index.store.residual else 'fp32'}: "
            f"top-1 {ids[0][:, 0].tolist()} != {picks}")


def test_residual_tier_tombstones_never_surface(tier_system):
    """Deleted docs on a residual-tier store can never surface, even under
    the exact full-capacity scan (the widest candidate set)."""
    from repro.retriever import SearchParams

    _, r_res, q, qm, picks = tier_system
    r = r_res.clone()
    dead = [int(picks[0]), int(picks[1])]
    r.delete(dead)
    _, ids = r.search(q, qm, SearchParams(use_ann=False, k=10, k_prime=r.m))
    got = set(np.asarray(ids).ravel().tolist())
    assert not (got & set(dead)), f"tombstoned docs surfaced: {got & set(dead)}"


def test_residual_tier_adds_exactly_one_compile_key(tier_system):
    """``use_residual`` is ONE compile key: flipping it on a residual store
    compiles exactly one more fn; every equivalent spelling shares a trace;
    on an fp32 store the resolved default adds nothing."""
    from repro.retriever import LemurRetriever, SearchParams

    r_fp, r_res, q, qm, _ = tier_system
    r = LemurRetriever(r_res.index)       # fresh compile cache
    r.search(q, qm, SearchParams())
    r.search(q, qm, SearchParams(use_residual=True))   # the resolved default
    assert r.trace_count() == 1
    r.search(q, qm, SearchParams(use_residual=False))  # the decoded view
    assert r.trace_count() == 2
    r.search(q, qm, SearchParams())
    assert r.trace_count() == 2

    rf = LemurRetriever(r_fp.index)
    rf.search(q, qm, SearchParams())
    rf.search(q, qm, SearchParams(use_residual=False))
    assert rf.trace_count() == 1


def test_add_docs_grows_index_and_stays_searchable(lemur_system):
    from repro.data import synthetic

    idx, q, qm, _, _ = lemur_system
    bidx = attach_backend(idx, "ivf", key=jax.random.PRNGKey(1))
    m0 = bidx.m
    extra = synthetic.make_corpus(m=20, d=16, avg_tokens=8,
                                  max_tokens=bidx.doc_tokens.shape[1],
                                  n_centers=24, seed=9)
    grown = add_docs(bidx, extra.doc_tokens, extra.doc_mask)
    assert grown.m == m0 + 20
    _, ids = query(grown, q, qm)
    assert int(jnp.max(ids)) < m0 + 20
    # recall against ground truth over the GROWN corpus stays healthy
    _, truth2 = maxsim.true_topk(q, qm, grown.doc_tokens, grown.doc_mask, 10)
    rec = float(recall_at(ids, truth2).mean())
    _, ids0 = query(bidx, q, qm)
    _, truth0 = maxsim.true_topk(q, qm, bidx.doc_tokens, bidx.doc_mask, 10)
    rec0 = float(recall_at(ids0, truth0).mean())
    assert rec >= rec0 - 0.15, (rec, rec0)
