"""The loop-corrected HLO analyzer (the roofline's measurement tool)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze, parse_hlo


def test_scan_trip_count_correction():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    comp = jax.jit(f).lower(spec, spec).compile()
    r = analyze(comp.as_text())
    want = 10 * 2 * 64**3
    assert abs(r["flops"] - want) / want < 0.01


def test_collectives_inside_scan_multiplied():
    from repro.common.compat import AxisType, make_mesh, set_mesh, shard_map
    from jax.sharding import PartitionSpec as P

    mesh = make_mesh((1, 1), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)

    def g(x):
        def body(c, _):
            def inner(v):
                return jax.lax.psum(v @ v, "model")
            return shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P(),
                             check_vma=False)(c), None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    spec = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    with set_mesh(mesh):
        comp = jax.jit(g).lower(spec).compile()
    r = analyze(comp.as_text())
    assert r["collective_count"].get("all-reduce", 0) == 5
    assert r["total_collective_bytes"] == 5 * 32 * 32 * 4
    want = 5 * 2 * 32**3
    assert abs(r["flops"] - want) / want < 0.01


def test_plain_matmul_flops():
    f = lambda a, b: a @ b
    spec = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    spec2 = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    comp = jax.jit(f).lower(spec, spec2).compile()
    r = analyze(comp.as_text())
    want = 2 * 128 * 256 * 64
    assert abs(r["flops"] - want) / want < 0.01


def test_parser_handles_tuple_computations():
    def f(x):
        def body(c, _):
            return (c[0] + 1, c[1] @ c[1]), None
        out, _ = jax.lax.scan(body, (jnp.float32(0), x), None, length=3)
        return out[1]

    spec = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    comp = jax.jit(f).lower(spec).compile()
    comps, entry = parse_hlo(comp.as_text())
    assert entry is not None and len(comps) > 1
    r = analyze(comp.as_text())
    want = 3 * 2 * 16**3
    assert abs(r["flops"] - want) / want < 0.01
