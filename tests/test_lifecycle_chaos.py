"""Fault injection for the index lifecycle (lifecycle/chaos.py harness).

Every scenario asserts the same three-part contract: the failure surfaces
as a TYPED event (never a hang, never an unhandled crash on the serving
path), serving continues bit-identically on the last-good snapshot, and a
subsequent clean attempt succeeds (faults are transient, the lifecycle is
not wedged):

* refresh killed in each rebuild phase    -> ``RefreshFailed(phase=...)``
* corrupted rebuild handed to the swap    -> ``SwapAborted``, last-good kept
* replica killed mid-swap                 -> barrier excuses it, swap lands
  on the healthy replicas
* corrupt swap fanned fleet-wide          -> typed ``CorruptIndexError`` on
  the aggregate future, NO quarantine (rejection is not replica failure)

Every wait carries a timeout so a wedged barrier fails the test instead of
hanging the suite.
"""
import jax
import numpy as np
import pytest

from repro.core import LemurConfig
from repro.data import synthetic
from repro.fleet import Router, clone_replicas
from repro.lifecycle import (ChaosError, ChaosInjector, LifecycleManager,
                             RefreshCompleted, RefreshFailed, RefreshStarted,
                             SwapAborted, SwapCompleted, build_refresh)
from repro.retriever import (CorruptIndexError, IVFBackendConfig,
                             LemurRetriever, SearchParams)
from repro.serving import BucketLadder, RetrieverServer

TIMEOUT = 120.0
PARAMS = SearchParams(k=5, k_prime=60)
CHAOS_POINTS = ("refresh:solver", "refresh:refit", "refresh:recluster")


@pytest.fixture(scope="module")
def base(tiny_corpus):
    cfg = LemurConfig(d=16, d_prime=32, m_pretrain=128, n_train=1024,
                      n_ols=512, epochs=4, k=5, k_prime=60, anns="ivf",
                      ivf=IVFBackendConfig(nprobe=16))
    return LemurRetriever.build(tiny_corpus, cfg, key=jax.random.PRNGKey(0))


def _query(tq, seed):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((tq, 16)).astype(np.float32)
    return q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-9)


def _ladder():
    return BucketLadder((32,), max_batch=4)


# --------------------------------------------------------------------------
# refresh killed mid-train
# --------------------------------------------------------------------------

@pytest.mark.parametrize("point", CHAOS_POINTS)
def test_refresh_crash_leaves_serving_untouched(base, point):
    serve_r = base.clone()
    chaos = ChaosInjector()
    chaos.fail_at(point)
    q, qm = _query(4, seed=1), np.ones(4, bool)
    with RetrieverServer(serve_r, ladder=_ladder(), max_wait_us=200,
                         default_params=PARAMS) as srv:
        s0, i0 = srv.search(q, qm, timeout=TIMEOUT)
        snap, ver = serve_r.snapshot(), serve_r.version
        mgr = LifecycleManager(srv, seed=3, chaos=chaos, cooldown_s=0.0)
        assert not mgr.refresh_now(reason="chaos")
        fails = mgr.events(RefreshFailed)
        assert len(fails) == 1
        assert fails[0].phase == point.split(":")[1]
        assert "ChaosError" in fails[0].error
        assert chaos.fired(point) == 1
        # serving was never touched: same snapshot, same version, bit-equal
        assert serve_r.snapshot() is snap and serve_r.version == ver
        s1, i1 = srv.search(q, qm, timeout=TIMEOUT)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
        assert mgr.n_swaps == 0 and not mgr.events(SwapCompleted)
        # the fault was transient: the next attempt completes the swap
        assert mgr.refresh_now(reason="retry")
        assert serve_r.version == ver + 1
        assert mgr.events(SwapCompleted)[-1].version == serve_r.version


def test_refresh_crash_events_are_ordered(base):
    """A failed attempt leaves Started -> Failed; the retry appends
    Started -> Completed -> SwapCompleted — the runbook sequence."""
    serve_r = base.clone()
    chaos = ChaosInjector()
    chaos.fail_at("refresh:refit")
    with RetrieverServer(serve_r, ladder=_ladder(), max_wait_us=200,
                         default_params=PARAMS) as srv:
        mgr = LifecycleManager(srv, seed=3, chaos=chaos, cooldown_s=0.0)
        mgr.refresh_now(reason="a")
        mgr.refresh_now(reason="b")
        kinds = [e.kind for e in mgr.events()]
    assert kinds == ["RefreshStarted", "RefreshFailed", "RefreshStarted",
                     "RefreshCompleted", "SwapCompleted"]


# --------------------------------------------------------------------------
# corrupted rebuild handed to the swap
# --------------------------------------------------------------------------

def _poison(res):
    return res._replace(W=res.W.at[:, 0].set(np.nan))


def test_corrupt_refresh_aborts_swap_keeps_last_good(base):
    serve_r = base.clone()
    chaos = ChaosInjector()
    chaos.corrupt_results(_poison)
    q, qm = _query(6, seed=2), np.ones(6, bool)
    with RetrieverServer(serve_r, ladder=_ladder(), max_wait_us=200,
                         default_params=PARAMS) as srv:
        s0, i0 = srv.search(q, qm, timeout=TIMEOUT)
        snap, ver = serve_r.snapshot(), serve_r.version
        mgr = LifecycleManager(srv, seed=3, chaos=chaos, cooldown_s=0.0,
                               swap_timeout_s=TIMEOUT)
        assert not mgr.refresh_now(reason="chaos")
        aborts = mgr.events(SwapAborted)
        assert len(aborts) == 1 and "CorruptIndexError" in aborts[0].error
        # the rebuild itself completed; only the install was rejected
        assert mgr.events(RefreshCompleted) and mgr.n_refreshes == 1
        assert serve_r.snapshot() is snap and serve_r.version == ver
        s1, i1 = srv.search(q, qm, timeout=TIMEOUT)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        # clearing the corruption lets the identical rebuild install
        chaos.corrupt_results(None)
        assert mgr.refresh_now(reason="clean")
        assert serve_r.version == ver + 1


# --------------------------------------------------------------------------
# fleet: replica killed mid-swap
# --------------------------------------------------------------------------

def test_fleet_swap_completes_when_replica_killed_mid_swap(base):
    reps = clone_replicas(base.clone(), 3)
    res = build_refresh(reps[0], seed=3)
    with Router(reps, ladder=_ladder(), max_wait_us=200,
                default_params=PARAMS, stall_timeout_s=30.0) as router:
        v0 = router.version
        router.servers[1].pause()       # replica 1 cannot drain its arm
        fut = router.apply(lambda r: r.install_refresh(res))
        assert router.kill_replica(1) >= 0
        fut.result(timeout=TIMEOUT)     # barrier excuses the dead replica
        assert fut.snapshot_version == v0 + 1
        assert router.n_healthy == 2 and router.quarantined() == [1]
        for i in (0, 2):
            assert router.servers[i].retriever.version == v0 + 1
        assert reps[1].version == v0    # the corpse kept its old snapshot
        # the surviving fleet serves the refit index bit-identically
        q, qm = _query(5, seed=3), np.ones(5, bool)
        s, ids = router.search(q, qm, timeout=TIMEOUT)
        ws, wi = reps[0].search(q[None], qm[None], PARAMS)
        np.testing.assert_array_equal(np.asarray(ids), np.asarray(wi)[0])
        kinds = [e["event"] for e in router.events()]
        assert "quarantine" in kinds


# --------------------------------------------------------------------------
# fleet: corrupt swap rejected everywhere, nobody quarantined
# --------------------------------------------------------------------------

def test_fleet_corrupt_swap_typed_rejection_no_quarantine(base):
    reps = clone_replicas(base.clone(), 3)
    bad = _poison(build_refresh(reps[0], seed=3))
    with Router(reps, ladder=_ladder(), max_wait_us=200,
                default_params=PARAMS, stall_timeout_s=30.0) as router:
        v0 = router.version
        fut = router.apply(lambda r: r.install_refresh(bad))
        with pytest.raises(CorruptIndexError):
            fut.result(timeout=TIMEOUT)
        # a deterministic rejection is NOT a replica failure: the whole
        # fleet stays healthy on its last-good snapshot
        assert router.n_healthy == 3 and router.quarantined() == []
        for srv in router.servers:
            assert srv.retriever.version == v0
        q, qm = _query(4, seed=4), np.ones(4, bool)
        router.search(q, qm, timeout=TIMEOUT)   # still serving
        # and a clean result still lands fleet-wide afterwards
        good = build_refresh(router.servers[0].retriever, seed=3)
        fut = router.apply(lambda r: r.install_refresh(good))
        fut.result(timeout=TIMEOUT)
        assert fut.snapshot_version == v0 + 1
        assert all(s.retriever.version == v0 + 1 for s in router.servers)


# --------------------------------------------------------------------------
# manager over a fleet, faults injected end to end
# --------------------------------------------------------------------------

def test_manager_drives_fleet_through_transient_fault(base):
    """Drift detected on fleet-fanned mutations -> first refresh killed by
    chaos (typed RefreshFailed, fleet untouched) -> retry completes the
    fleet-wide warm swap; every replica converges on the same version."""
    reps = clone_replicas(base.clone(), 2)
    chaos = ChaosInjector()
    chaos.fail_at("refresh:recluster")
    with Router(reps, ladder=_ladder(), max_wait_us=200,
                default_params=PARAMS, stall_timeout_s=30.0) as router:
        mgr = LifecycleManager(router, seed=3, chaos=chaos, cooldown_s=0.0,
                               min_reservoir=8, swap_timeout_s=TIMEOUT)
        mgr.start(auto=False)
        try:
            sh = synthetic.make_corpus(m=96, d=16, avg_tokens=8,
                                       max_tokens=12, n_centers=6,
                                       topic_strength=4.0, seed=777)
            router.add(sh.doc_tokens, sh.doc_mask).result(timeout=TIMEOUT)
            router.delete(np.arange(60)).result(timeout=TIMEOUT)
            v0 = router.version
            assert not mgr.poll_once()          # chaos kills the rebuild
            assert mgr.events(RefreshFailed)
            assert router.version == v0
            assert mgr.poll_once()              # retry swaps fleet-wide
            assert router.version == v0 + 1
            assert all(s.retriever.version == v0 + 1
                       for s in router.servers)
            assert mgr.events(SwapCompleted)[-1].version == v0 + 1
            assert router.n_healthy == 2
        finally:
            mgr.stop()
