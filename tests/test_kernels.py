"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.fused_psi import fused_psi
from repro.kernels.maxsim import token_maxsim
from repro.kernels.mips_sq8 import mips_sq8


@pytest.mark.parametrize("n,m,T,d,bn,bm", [
    (16, 16, 4, 16, 8, 8),
    (33, 17, 7, 24, 16, 8),     # non-divisible everything (padding path)
    (64, 96, 12, 128, 32, 16),  # d already MXU-aligned
])
def test_token_maxsim_shapes(n, m, T, d, bn, bm):
    rng = np.random.default_rng(n * m)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    docs = jnp.asarray(rng.standard_normal((m, T, d)), jnp.float32)
    mask = jnp.asarray(rng.random((m, T)) > 0.3)
    mask = mask.at[:, 0].set(True)
    out = token_maxsim(x, docs * mask[..., None], mask, block_n=bn, block_m=bm,
                       interpret=True)
    want = ref.token_maxsim_ref(x, docs * mask[..., None], mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_token_maxsim_dtypes(dtype):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((24, 32)), dtype)
    docs = jnp.asarray(rng.standard_normal((20, 5, 32)), dtype)
    mask = jnp.ones((20, 5), bool)
    out = token_maxsim(x, docs, mask, block_n=8, block_m=4, interpret=True)
    want = ref.token_maxsim_ref(x.astype(jnp.float32), docs.astype(jnp.float32), mask)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("n,d,dp,bn", [
    (16, 16, 32, 8),
    (33, 24, 64, 16),
    (64, 128, 256, 32),
])
def test_fused_psi_shapes(n, d, dp, bn):
    rng = np.random.default_rng(n + dp)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((d, dp)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal(dp) * 0.01, jnp.float32)
    g = jnp.asarray(1 + 0.1 * rng.standard_normal(dp), jnp.float32)
    beta = jnp.asarray(0.1 * rng.standard_normal(dp), jnp.float32)
    out = fused_psi(x, k, b, g, beta, block_n=bn, interpret=True)
    want = ref.fused_psi_ref(x, k, b, g, beta)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_fused_psi_matches_model_psi():
    """Kernel == repro.core.model.psi_apply (the system-level contract)."""
    from repro.core.model import init_psi, psi_apply

    rng = np.random.default_rng(0)
    params = init_psi(jax.random.PRNGKey(0), 24, 64)
    x = jnp.asarray(rng.standard_normal((40, 24)), jnp.float32)
    out = fused_psi(
        x, params["dense"]["kernel"], params["dense"]["bias"],
        params["ln"]["scale"], params["ln"]["bias"], block_n=16, interpret=True,
    )
    want = psi_apply(params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,m,d,bq,bm", [
    (8, 32, 16, 8, 16),
    (17, 41, 24, 8, 16),
    (32, 128, 64, 16, 64),
])
def test_mips_sq8_shapes(B, m, d, bq, bm):
    rng = np.random.default_rng(B * m)
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    codes = jnp.asarray(rng.integers(-127, 128, (m, d)), jnp.int8)
    scales = jnp.asarray(rng.random(m) + 0.1, jnp.float32)
    out = mips_sq8(q, codes, scales, block_q=bq, block_m=bm, interpret=True)
    want = ref.mips_sq8_ref(q, codes, scales)
    denom = max(float(jnp.max(jnp.abs(want))), 1.0)
    assert float(jnp.max(jnp.abs(out - want))) / denom < 1e-4


def test_ops_dispatch_cpu_uses_ref():
    """On CPU the ops wrappers default to the reference implementation."""
    from repro.kernels import ops

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    docs = jnp.asarray(rng.standard_normal((6, 4, 16)), jnp.float32)
    mask = jnp.ones((6, 4), bool)
    out = ops.token_maxsim(x, docs, mask)
    want = ref.token_maxsim_ref(x, docs, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want))


def test_ops_maxsim_scores_consistency():
    from repro.core import maxsim
    from repro.kernels import ops

    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((3, 5, 16)), jnp.float32)
    qm = jnp.asarray(rng.random((3, 5)) > 0.3)
    docs = jnp.asarray(rng.standard_normal((9, 4, 16)), jnp.float32)
    dm = jnp.ones((9, 4), bool)
    out = ops.maxsim_scores(q, qm, docs, dm, use_kernel=True)
    want = maxsim.maxsim_scores(q, qm, docs, dm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)
