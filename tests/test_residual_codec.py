"""Property suite for the compressed corpus tier (residual codec).

Four contracts:

* **Round-trip error bound.**  For 2- and 4-bit codecs trained on the
  encoded data, per-dimension reconstruction error never exceeds the
  quantization step (the widest residual bucket of that dimension) — on
  random tokens AND the adversarial shapes (all-zero rows, max-norm rows,
  duplicated tokens) that break naive per-dim quantizers.
* **Packed layout.**  ``pack_codes``/``unpack_codes`` round-trip every
  bucket index, and the host decoder (``quantization.residual_decode``) is
  BIT-identical to the gather-free one-hot decoder the Pallas kernels use
  (``gather_scan.residual_decode_onehot``) — the layout contract the
  in-kernel dequant depends on.
* **Checkpoint round-trip.**  A retriever built with the residual tier
  saves/loads with bit-identical compressed pages, codec tables, and
  search ids (2-bit path end-to-end).
* **SQ8 zero-row regression.**  An all-zero row (a fully-masked pad doc's
  latent) must quantize to finite codes/scales and dequantize to exact 0 —
  the unclamped scale divided by zero and poisoned every score with NaN.

Deterministic grids run everywhere; the ``@given`` twins widen the sweep
when hypothesis is installed (tests/_hypothesis_compat.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.anns import ivf
from repro.anns import quantization as quant
from repro.kernels import gather_scan

BITS = (2, 4)


def _adversarial(rng, n, d):
    """Random tokens + the shapes that break naive per-dim quantizers."""
    x = rng.standard_normal((n, d)).astype(np.float32)
    x[0] = 0.0                                    # all-zero row
    x[1] = 12.0 * np.sign(rng.standard_normal(d))  # max-norm row
    x[2] = x[3]                                   # duplicate tokens
    return x


def _roundtrip_bound(x, codec):
    """|decode(encode(x)) - x| per dim vs the widest bucket of that dim.

    Every residual lands in a bucket whose reconstruction value (a quantile
    INSIDE the bucket) shares its interval, so the error is bounded by the
    bucket width; the extreme buckets extend to the actual residual
    min/max."""
    cid, packed = quant.residual_encode(codec, jnp.asarray(x))
    dec = np.asarray(quant.residual_decode(codec, cid, packed))
    r = x - np.asarray(codec.centroids)[np.asarray(cid)]
    cuts = np.asarray(codec.cuts)                 # (d, L-1)
    vals = np.asarray(codec.values)               # (d, L)
    lo = np.minimum(r.min(axis=0), vals[:, 0])
    hi = np.maximum(r.max(axis=0), vals[:, -1])
    edges = np.concatenate([lo[:, None], cuts, hi[:, None]], axis=1)
    step = np.diff(edges, axis=1).max(axis=1)     # (d,) widest bucket
    err = np.abs(dec - x)
    assert np.all(err <= step[None, :] + 1e-5), (
        f"max err {err.max():.4f} > widest bucket {step.max():.4f}")


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("seed", [0, 1])
def test_roundtrip_error_bounded_by_quantization_step(bits, seed):
    rng = np.random.default_rng(seed)
    x = _adversarial(rng, 400, 16)
    codec = quant.train_residual_codec(jax.random.PRNGKey(seed),
                                       jnp.asarray(x), bits=bits, ncent=16,
                                       iters=4)
    _roundtrip_bound(x, codec)


@settings(deadline=None, max_examples=8)
@given(bits=st.sampled_from(BITS), seed=st.integers(0, 1000))
def test_roundtrip_error_bounded_random(bits, seed):
    rng = np.random.default_rng(seed)
    x = _adversarial(rng, 200, 8)
    codec = quant.train_residual_codec(jax.random.PRNGKey(seed),
                                       jnp.asarray(x), bits=bits, ncent=8,
                                       iters=3)
    _roundtrip_bound(x, codec)


# --------------------------------------------------------------------------
# packed-nibble layout: pack/unpack + host decode == one-hot kernel decode
# --------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("d", [8, 16, 20])
def test_pack_unpack_roundtrip(bits, d):
    rng = np.random.default_rng(bits * d)
    idx = jnp.asarray(rng.integers(0, 1 << bits, (50, d)), jnp.int32)
    packed = quant.pack_codes(idx, bits)
    assert packed.dtype == jnp.uint8
    assert packed.shape == (50, d * bits // 8)
    np.testing.assert_array_equal(np.asarray(quant.unpack_codes(packed, bits)),
                                  np.asarray(idx))


def test_pack_rejects_bad_bits_and_widths():
    idx = jnp.zeros((3, 8), jnp.int32)
    with pytest.raises(ValueError, match="2 or 4 bits"):
        quant.pack_codes(idx, 3)
    with pytest.raises(ValueError, match="not divisible"):
        quant.pack_codes(jnp.zeros((3, 5), jnp.int32), 4)


@pytest.mark.parametrize("bits", BITS)
def test_host_decode_bit_identical_to_onehot_kernel_decode(bits):
    """The layout contract: ``residual_decode`` (take/take_along_axis) and
    ``residual_decode_onehot`` (shift/AND unpack + select-sum + one-hot
    matmul — what runs inside the Pallas kernels) agree BIT-exactly on
    arbitrary codec tables and codes."""
    rng = np.random.default_rng(bits)
    n, d, ncent, L = 64, 16, 12, 1 << bits
    codec = quant.ResidualCodec(
        centroids=jnp.asarray(rng.standard_normal((ncent, d)), jnp.float32),
        cuts=None,  # decode never reads cuts
        values=jnp.asarray(np.sort(rng.standard_normal((d, L)), axis=1),
                           jnp.float32))
    cent = jnp.asarray(rng.integers(0, ncent, (n,)), jnp.int32)
    packed = jnp.asarray(rng.integers(0, 256, (n, d * bits // 8)), jnp.uint8)
    host = quant.residual_decode(codec, cent, packed)
    kern = gather_scan.residual_decode_onehot(cent, packed, codec.centroids,
                                              codec.values, bits=bits)
    np.testing.assert_array_equal(np.asarray(host), np.asarray(kern))


@settings(deadline=None, max_examples=10)
@given(bits=st.sampled_from(BITS), seed=st.integers(0, 1000))
def test_host_decode_matches_onehot_random(bits, seed):
    rng = np.random.default_rng(seed)
    n, d, ncent = 16, 8, 5
    codec = quant.ResidualCodec(
        centroids=jnp.asarray(rng.standard_normal((ncent, d)), jnp.float32),
        cuts=None,
        values=jnp.asarray(rng.standard_normal((d, 1 << bits)), jnp.float32))
    cent = jnp.asarray(rng.integers(0, ncent, (n,)), jnp.int32)
    packed = jnp.asarray(rng.integers(0, 256, (n, d * bits // 8)), jnp.uint8)
    np.testing.assert_array_equal(
        np.asarray(quant.residual_decode(codec, cent, packed)),
        np.asarray(gather_scan.residual_decode_onehot(
            cent, packed, codec.centroids, codec.values, bits=bits)))


def test_encode_is_decode_stable_on_fixed_assignment():
    """Re-encoding a decoded vector AGAINST ITS OWN centroid reproduces the
    codes exactly (reconstruction values live strictly inside their
    buckets) — the property the paged store's gather/re-add path relies
    on."""
    rng = np.random.default_rng(3)
    x = rng.standard_normal((300, 16)).astype(np.float32)
    codec = quant.train_residual_codec(jax.random.PRNGKey(0), jnp.asarray(x),
                                       bits=4, ncent=16, iters=4)
    cid, packed = quant.residual_encode(codec, jnp.asarray(x))
    dec = quant.residual_decode(codec, cid, packed)
    cid2, packed2 = quant.residual_encode(codec, dec, cent_ids=cid)
    np.testing.assert_array_equal(np.asarray(packed2), np.asarray(packed))


# --------------------------------------------------------------------------
# checkpoint round-trip (2-bit end-to-end)
# --------------------------------------------------------------------------

def test_residual_store_save_load_bit_identical(tmp_path):
    from repro.anns.params import ResidualConfig
    from repro.core import LemurConfig
    from repro.data import synthetic
    from repro.retriever import LemurRetriever, SearchParams

    corpus = synthetic.make_corpus(m=80, d=16, avg_tokens=8, max_tokens=12,
                                   n_centers=16, seed=0)
    cfg = LemurConfig(d=16, d_prime=24, m_pretrain=48, n_train=512, n_ols=256,
                      epochs=2, k=5, k_prime=40, anns="ivf",
                      residual=ResidualConfig(enabled=True, bits=2, ncent=32,
                                              kmeans_iters=3, token_budget=6))
    r = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0))
    assert r.index.store.residual and r.index.store.codec.bits == 2
    r.save(tmp_path)
    r2 = LemurRetriever.load(tmp_path)
    st, st2 = r.index.store, r2.index.store
    np.testing.assert_array_equal(np.asarray(st.cent_pages),
                                  np.asarray(st2.cent_pages))
    np.testing.assert_array_equal(np.asarray(st.code_pages),
                                  np.asarray(st2.code_pages))
    for a, b in zip(st.codec, st2.codec):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    q = jnp.asarray(corpus.doc_tokens[:4])
    qm = jnp.asarray(corpus.doc_mask[:4])
    for params in (SearchParams(), SearchParams(use_ann=False)):
        _, ids = r.search(q, qm, params)
        _, ids2 = r2.search(q, qm, params)
        np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids2))


# --------------------------------------------------------------------------
# SQ8 zero-row regression (the fully-masked pad doc)
# --------------------------------------------------------------------------

def test_sq8_all_zero_row_quantizes_finite():
    x = jnp.asarray(np.r_[np.zeros((1, 8)),
                          np.random.default_rng(0).standard_normal((5, 8))],
                    jnp.float32)
    codes, scales = quant.sq8_quant(x)
    assert np.all(np.isfinite(np.asarray(scales))) and np.asarray(scales)[0] > 0
    dec = np.asarray(quant.sq8_dequant(codes, scales))
    assert np.all(np.isfinite(dec))
    np.testing.assert_array_equal(dec[0], np.zeros(8))


def test_sq8_ivf_with_pad_doc_scores_finite():
    """An SQ8 first-stage index over a corpus containing a fully-masked pad
    doc (all-zero latent row) must return finite scores for every real
    candidate — the unclamped per-row scale made them all NaN."""
    rng = np.random.default_rng(1)
    vecs = rng.standard_normal((40, 16)).astype(np.float32)
    vecs[7] = 0.0                                 # the pad doc's latent row
    index = ivf.build_ivf(jax.random.PRNGKey(0), jnp.asarray(vecs), 8,
                          sq8=True, kmeans_iters=2)
    q = jnp.asarray(rng.standard_normal((3, 16)), jnp.float32)
    scores, ids = ivf.search_ivf(index, q, 8, 10)
    s = np.asarray(scores)
    assert np.all(np.isfinite(s[np.asarray(ids) >= 0]))
    assert not np.any(np.isnan(s))
