"""Property-based backend contract tests (hypothesis, optional).

Randomized (m, Td, d, k, k') shapes through every registered first-stage
backend: search must return valid in-range ids, the exact rerank must never
leak ``-1`` pads while real candidates remain, and ``k > m`` must clamp
(pad) instead of crashing.  With hypothesis absent (`tests/_hypothesis_compat`)
the ``@given`` tests skip, but the same invariant checker still runs over a
small deterministic grid so the contract is exercised everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.anns import registry
from repro.anns.base import CorpusView, QueryBatch
from repro.core import maxsim

BACKENDS = registry.list_backends()
DP = 16   # latent dim (fixed: backends either use it or ignore it)
B = 3     # query batch


def _make_data(m: int, td: int, d: int, seed: int):
    rng = np.random.default_rng(seed)
    mask = rng.random((m, td)) < 0.8
    mask[:, 0] = True                      # every doc keeps >= 1 token
    view = CorpusView(
        jnp.asarray(rng.standard_normal((m, DP)), jnp.float32),
        jnp.asarray(rng.standard_normal((m, td, d)), jnp.float32),
        jnp.asarray(mask),
    )
    qb = QueryBatch(
        jnp.asarray(rng.standard_normal((B, DP)), jnp.float32),
        jnp.asarray(rng.standard_normal((B, 3, d)), jnp.float32),
        jnp.ones((B, 3), bool),
    )
    return view, qb


def check_backend_contract(name: str, m: int, td: int, d: int, k: int,
                           k_prime: int, seed: int = 0):
    """The invariants every registered backend must uphold for ANY shape."""
    view, qb = _make_data(m, td, d, seed)
    be = registry.get_backend(name)
    state = be.build(jax.random.PRNGKey(seed), view, None)

    # -- first stage: (B, k') int32 ids in [-1, m), valid ids unique per row
    scores, ids = be.search(state, qb, k_prime)
    assert scores.shape == (B, k_prime) and ids.shape == (B, k_prime)
    assert ids.dtype == jnp.int32
    ids_np = np.asarray(ids)
    assert ids_np.min() >= -1 and ids_np.max() < m, name
    for row in ids_np:
        valid = row[row >= 0]
        assert len(set(valid.tolist())) == len(valid), f"{name}: dup candidates"

    # -- rerank: pads may only surface when a row ran out of real candidates
    kk = min(k, k_prime)
    r_scores, r_ids = maxsim.rerank(qb.tokens, qb.mask, ids,
                                    view.doc_tokens, view.doc_mask, kk)
    assert r_ids.shape == (B, kk)
    r_np = np.asarray(r_ids)
    assert r_np.min() >= -1 and r_np.max() < m, name
    for first, row in zip(ids_np, r_np):
        n_valid = int((first >= 0).sum())
        lead = row[: min(kk, n_valid)]
        assert (lead >= 0).all(), f"{name}: -1 leaked past {n_valid} candidates"
        valid = row[row >= 0]
        assert len(set(valid.tolist())) == len(valid), f"{name}: dup after rerank"

    # -- k' > m must clamp (pad with -1), not crash or invent ids
    s2, i2 = be.search(state, qb, m + 7)
    assert i2.shape == (B, m + 7)
    i2_np = np.asarray(i2)
    assert i2_np.min() >= -1 and i2_np.max() < m, name


# deterministic floor: runs with or without hypothesis
@pytest.mark.parametrize("name", BACKENDS)
@pytest.mark.parametrize("m,td,d,k,k_prime", [
    (24, 2, 4, 5, 10),
    (64, 5, 12, 10, 96),    # k' > m: clamped
    (40, 3, 8, 50, 30),     # k > k': rerank clamps to k'
])
def test_backend_contract_grid(name, m, td, d, k, k_prime):
    check_backend_contract(name, m, td, d, k, k_prime, seed=1)


# randomized sweep: only with hypothesis installed
@pytest.mark.parametrize("name", BACKENDS)
@settings(deadline=None, max_examples=15)
@given(m=st.integers(24, 96), td=st.integers(2, 6), d=st.integers(4, 16),
       k=st.integers(1, 30), k_prime=st.integers(1, 120),
       seed=st.integers(0, 3))
def test_backend_contract_random(name, m, td, d, k, k_prime, seed):
    check_backend_contract(name, m, td, d, k, k_prime, seed)
