"""App. D: training-set selection ablation — query / corpus-query / corpus
strategies (claim C4: robust to the training distribution, actual queries
slightly best)."""
from __future__ import annotations

from benchmarks import common
from repro.core import recall_at
from repro.retriever import SearchParams


def run():
    q, qm = common.queries()
    truth = common.ground_truth()
    out = {}
    for strategy in ("corpus-query", "corpus", "query"):
        r = common.lemur_retriever(128, query_strategy=strategy)
        cand = r.candidates(q, qm, SearchParams(k_prime=200, use_ann=False))
        rec = float(recall_at(cand, truth).mean())
        out[strategy] = rec
        common.emit(f"appendix_d_{strategy}", 0.0, f"recall200={rec:.3f}")
    common.save_json("appendix_d_training", out)
    return out


if __name__ == "__main__":
    run()
