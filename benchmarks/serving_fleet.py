"""Fleet serving benchmark: replica scaling + SLO-adaptive overload.

Drives the replicated router (``repro.fleet``) with the same open-loop
Poisson replay as ``serving_online`` and extends the repo-root
``BENCH_serving.json`` with two new sections it owns:

* ``"replicated"`` — one row per fleet size (default 1/2/4 replicas), all
  at the SAME offered load (``overload_factor`` × the canonical 100 QPS
  trace), so achieved-vs-offered QPS isolates what replication buys:

      {"op": "fleet_replicated", "replicas": n, "p50_ms": ..., "p95_ms": ...,
       "p99_ms": ..., "qps": ..., "offered_qps": ..., "reject_rate": ...,
       "n_lost": 0, "parity": true}

* ``"overload"`` — one row for the SLO-adaptive run: capacity is measured
  (closed-loop saturation burst), the latency target is set from a light
  calibration phase (``3 × p99_light``), then a 10×-capacity replay must
  keep the windowed p99 bounded by *observably* walking the rung ladder
  down (every transition is recorded in the row) while admission control
  absorbs the excess as typed rejects:

      {"op": "fleet_overload", ..., "capacity_qps": ..., "offered_qps": ...,
       "target_p99_ms": ..., "final_rung": ..., "transitions": [...],
       "reject_rate": ..., "n_lost": 0}

Contract gates (SystemExit → CI bench-smoke fails):

* parity — sampled fleet answers bit-identical to a direct
  ``retriever.search`` of the same ragged query;
* zero lost requests — every submit resolves with a result or a typed
  outcome (``Overloaded`` / ``DeadlineExceeded``), never silence;
* achieved QPS does not degrade as replicas are added, and the largest
  fleet beats one replica;
* the overload run downshifts at least once, every down-transition fired
  on a genuine breach (windowed p99 > target), and the replay-wide p99
  stays under the queue-depth bound implied by measured capacity;
* trace counts stay within the bucket-ladder compile bound.

  PYTHONPATH=src python -m benchmarks.serving_fleet                 # default
  PYTHONPATH=src python -m benchmarks.serving_fleet --m 600 --epochs 4 \\
      --replicas 1,2 --duration 10                                  # CI smoke
"""
from __future__ import annotations

import argparse
import json
import math
import time

import numpy as np

from benchmarks import common

LADDER = (8, 16, 32)
MAX_TRACE = 4000  # open-loop arrival cap per phase (overload traces explode)


def _capped_trace(rate: float, duration: float, seed: int):
    from repro.serving import poisson_trace

    at = poisson_trace(rate, duration, seed=seed)
    if len(at) > MAX_TRACE:
        print(f"# capping trace at {MAX_TRACE} of {len(at)} arrivals "
              f"({rate:.0f} qps x {duration:.0f}s)")
        at = at[:MAX_TRACE]
    return at


def _parity_sample(results, queries, retriever, seed, n=12):
    """Sampled fleet answers vs direct facade search (typed outcomes and
    losses are skipped — they have no ids to compare)."""
    ok_idx = [i for i, r in enumerate(results) if isinstance(r, tuple)]
    if not ok_idx:
        return False
    rng = np.random.default_rng(seed)
    sample = rng.choice(ok_idx, min(n, len(ok_idx)), replace=False)
    parity = True
    for i in sample:
        q = queries[i % len(queries)]
        _, want = retriever.search(q[None], np.ones((1, len(q)), bool))
        parity &= bool(np.array_equal(results[i][1], np.asarray(want)[0]))
    return parity


def _measure_capacity(router, queries, burst: int = 64,
                      timeout: float = 300.0) -> float:
    """Closed-loop saturation burst: submit ``burst`` requests back-to-back
    and wait for all — achieved rate approximates the fleet's micro-batched
    service capacity (what the overload factor is multiplied against)."""
    futs = [router.submit(queries[i % len(queries)]) for i in range(burst)]
    t0 = time.perf_counter()
    for f in futs:
        f.result(timeout=timeout)
    return burst / max(time.perf_counter() - t0, 1e-9)


def run(m: int = 2000, *, d: int = 32, rate: float = 100.0,
        duration: float = 10.0, replicas=(1, 2, 4), overload_factor: float = 10.0,
        max_batch: int = 8, max_wait_us: int = 2000, max_queue_depth: int = 64,
        backend: str = "ivf", epochs: int = 10, seed: int = 0,
        emit_json: bool = True) -> dict:
    import jax

    from repro.core import LemurConfig
    from repro.data import synthetic
    from repro.fleet import Router, SLOController, build_rungs, clone_replicas, \
        warm_replicas
    from repro.retriever import IVFBackendConfig, LemurRetriever
    from repro.serving import BucketLadder, ragged_queries, replay

    import os

    corpus = synthetic.make_corpus(m=m, d=d, avg_tokens=12, max_tokens=16,
                                   seed=seed)
    cfg = LemurConfig(d=d, d_prime=64, m_pretrain=min(256, m),
                      n_train=4096, n_ols=1024, epochs=epochs, k=10,
                      k_prime=min(128, m), anns=backend,
                      ivf=IVFBackendConfig(nprobe=16))
    retriever = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(seed))
    ladder = BucketLadder(LADDER, max_batch=max_batch)
    queries = ragged_queries(256, d, tq_range=(2, 24), seed=seed + 1)
    n_cores = len(os.sched_getaffinity(0))

    # ---- replicated scaling rows: same offered load, growing fleets -------
    # the offered load must saturate a SINGLE replica for replication to be
    # visible — calibrate against its measured closed-loop capacity, clamped
    # to the 10-100x band around the canonical trace rate
    rep_rows = []
    arrivals = None
    offered = rate * overload_factor
    for n in replicas:
        reps = clone_replicas(retriever, n)
        warm_replicas(reps, ladder, d)
        with Router(reps, ladder=ladder, max_wait_us=max_wait_us,
                    max_queue_depth=max_queue_depth,
                    stall_timeout_s=60.0) as router:
            if arrivals is None:
                cap1 = _measure_capacity(router, queries,
                                         burst=min(64, max_queue_depth))
                offered = min(max(overload_factor * rate, 2.5 * cap1),
                              100.0 * rate)
                print(f"# replica-1 capacity {cap1:.0f} qps -> offered "
                      f"{offered:.0f} qps ({n_cores} cores)")
                arrivals = _capped_trace(offered, duration, seed + 2)
            results, report = replay(router, queries, arrivals)
            parity = _parity_sample(results, queries, retriever, seed + 3)
            rep_rows.append({
                "op": "fleet_replicated",
                "shape": (f"m={m},backend={backend},replicas={n},"
                          f"offered={offered:g}qps,depth={max_queue_depth}"),
                "replicas": n,
                **{k: report[k] for k in (
                    "p50_ms", "p95_ms", "p99_ms", "mean_ms", "qps",
                    "offered_qps", "n_requests", "n_rejected", "n_lost",
                    "reject_rate")},
                "trace_count": router.trace_count(),
                "compile_bound": router.compile_bound(1),
                "parity": parity,
            })
            common.emit(f"serving_fleet_r{n}_p99",
                        rep_rows[-1]["p99_ms"] * 1e3,
                        f"qps={rep_rows[-1]['qps']:.0f}/"
                        f"{offered:.0f},rej={report['reject_rate']:.2f}")

    # ---- SLO-adaptive overload row ----------------------------------------
    n_slo = max(r for r in replicas if r <= 2) if any(r <= 2 for r in replicas) \
        else min(replicas)
    reps = clone_replicas(retriever, n_slo)
    rungs = build_rungs(retriever, n_rungs=3)
    warm_replicas(reps, ladder, d, params_list=rungs)

    # light phase on a plain router calibrates the latency target
    with Router(reps, ladder=ladder, max_wait_us=max_wait_us,
                max_queue_depth=max_queue_depth,
                stall_timeout_s=60.0) as router:
        light = _capped_trace(rate, min(duration, 4.0), seed + 4)
        _, light_rep = replay(router, queries, light)
        p99_light = light_rep["p99_ms"]
        capacity = _measure_capacity(router, queries,
                                     burst=min(64, max_queue_depth))
    target_ms = 3.0 * p99_light

    # queue depth calibrated so a FULL admission queue implies an SLO breach
    # (wait ~ depth/capacity ~ 2x target): without this, admission control
    # alone can bound p99 below the target and the controller never engages
    depth_over = max(int(math.ceil(2.0 * (target_ms / 1e3) * capacity)),
                     4 * max_batch)
    slo = SLOController(rungs, target_p99_ms=target_ms, window=64,
                        min_window=16, eval_every=16)
    over_rate = overload_factor * capacity
    over = _capped_trace(over_rate, duration, seed + 5)
    print(f"# overload: capacity {capacity:.0f} qps, target "
          f"{target_ms:.1f}ms, depth {depth_over}, offered {over_rate:.0f}")
    with Router(reps, ladder=ladder, max_wait_us=max_wait_us,
                max_queue_depth=depth_over, slo=slo,
                stall_timeout_s=60.0) as router:
        results, report = replay(router, queries, over)
        transitions = [{"t": tr.t, "from": tr.from_rung, "to": tr.to_rung,
                        "p99_ms": tr.p99_ms, "direction": tr.direction}
                       for tr in slo.transitions]
        over_row = {
            "op": "fleet_overload",
            "shape": (f"m={m},backend={backend},replicas={n_slo},"
                      f"overload={overload_factor:g}x,depth={max_queue_depth}"),
            "replicas": n_slo,
            **{k: report[k] for k in (
                "p50_ms", "p95_ms", "p99_ms", "qps", "offered_qps",
                "n_requests", "n_rejected", "n_lost", "reject_rate")},
            "capacity_qps": capacity,
            "p99_light_ms": p99_light,
            "target_p99_ms": target_ms,
            "n_rungs": len(rungs),
            "final_rung": slo.rung,
            "transitions": transitions,
            "trace_count": router.trace_count(),
            "compile_bound": router.compile_bound(len(rungs)),
        }
        common.emit("serving_fleet_overload_p99", over_row["p99_ms"] * 1e3,
                    f"rung={slo.rung}/{len(rungs) - 1},"
                    f"rej={report['reject_rate']:.2f},"
                    f"downs={sum(t['direction'] == 'down' for t in transitions)}")

    out = {
        "replicated": {
            "meta": common.bench_meta(
                seed=seed, m=m, d=d, offered_qps=offered, n_cores=n_cores,
                duration_s=duration, ladder=list(LADDER),
                max_batch=max_batch, max_queue_depth=max_queue_depth,
                first_stage=backend,
                note="same Poisson trace replayed against growing fleets; "
                     "achieved-vs-offered QPS is the scaling contract "
                     "(strict scaling gated only on multi-core hosts)"),
            "rows": rep_rows,
        },
        "overload": {
            "meta": common.bench_meta(
                seed=seed, m=m, d=d, overload_factor=overload_factor,
                n_cores=n_cores, duration_s=duration, ladder=list(LADDER),
                max_batch=max_batch, max_queue_depth=depth_over,
                first_stage=backend,
                note="capacity-calibrated overload with SLO-adaptive rung "
                     "ladder; every rung transition is recorded in the row"),
            "rows": [over_row],
        },
    }
    if emit_json:
        doc = common.load_bench_root("serving")
        for sec in ("replicated", "overload"):
            common.merge_section(doc, sec, out[sec]["meta"], out[sec]["rows"])
        common.save_bench_root("serving", doc)

    _gate(rep_rows, over_row, target_ms, capacity, depth_over, max_batch,
          n_cores)
    return out


def _gate(rep_rows, over_row, target_ms, capacity, depth, max_batch,
          n_cores) -> None:
    """The fleet serving contract — SystemExit on any violation."""
    bad = [r["op"] + r["shape"] for r in rep_rows if not r["parity"]]
    if bad:
        raise SystemExit(f"fleet parity regression in: {bad}")
    lost = [r["shape"] for r in rep_rows + [over_row] if r["n_lost"]]
    if lost:
        raise SystemExit(f"lost requests (no typed outcome) in: {lost}")
    for r in rep_rows + [over_row]:
        if not math.isfinite(r["p99_ms"]):
            raise SystemExit(f"non-finite p99 in {r['op']}: {r['p99_ms']}")
        if r["trace_count"] > r["compile_bound"]:
            raise SystemExit(
                f"{r['op']}: trace_count {r['trace_count']} exceeded compile "
                f"bound {r['compile_bound']}")
    qps = [r["qps"] for r in sorted(rep_rows, key=lambda r: r["replicas"])]
    if len(qps) > 1:
        if n_cores >= 2 and qps[-1] <= qps[0]:
            raise SystemExit(
                f"replication did not raise achieved QPS: {qps}")
        # a single-core host cannot serve replicas in parallel — replication
        # is gated on NON-COLLAPSE there (context switching between worker
        # threads costs real throughput); the strict scaling contract only
        # binds where the hardware can express it
        tol = 0.8 if n_cores >= 2 else 0.6
        if any(b < tol * a for a, b in zip(qps, qps[1:])):
            raise SystemExit(f"achieved QPS degraded with replicas: {qps}")
    downs = [t for t in over_row["transitions"] if t["direction"] == "down"]
    if not downs:
        raise SystemExit(
            "overload replay never downshifted — SLO controller inert "
            f"(target {target_ms:.1f}ms, transitions "
            f"{over_row['transitions']})")
    breach = [t for t in downs if t["p99_ms"] <= t.get("target_ms",
                                                       target_ms)]
    if breach:
        raise SystemExit(f"downshift without a p99 breach: {breach}")
    # queue-depth latency bound: a request admitted at full depth waits at
    # most ~(depth + one batch) service intervals at the rate the fleet
    # ACTUALLY sustained under overload (the closed-loop burst capacity is
    # optimistic — batching amortizes better there), plus 4x slack for rung
    # transitions and CPU noise.  An unbounded queue would blow straight
    # through this: its p99 grows with trace length, not with depth.
    svc = over_row["qps"] if (math.isfinite(over_row["qps"])
                              and over_row["qps"] > 0) else capacity
    bound_ms = 4.0 * 1e3 * (depth + max_batch) / max(svc, 1e-9) \
        + 4.0 * target_ms
    if over_row["p99_ms"] > bound_ms:
        raise SystemExit(
            f"overload p99 {over_row['p99_ms']:.1f}ms exceeded the "
            f"queue-depth bound {bound_ms:.1f}ms — admission control or "
            f"SLO downshift failed to bound latency")


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--m", type=int, default=2000)
    p.add_argument("--d", type=int, default=32)
    p.add_argument("--rate", type=float, default=100.0,
                   help="canonical offered load, queries/second")
    p.add_argument("--duration", type=float, default=10.0)
    p.add_argument("--replicas", default="1,2,4",
                   help="comma-separated fleet sizes for the scaling rows")
    p.add_argument("--overload-factor", type=float, default=10.0)
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--max-wait-us", type=int, default=2000)
    p.add_argument("--max-queue-depth", type=int, default=64)
    p.add_argument("--backend", default="ivf")
    p.add_argument("--epochs", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--no-emit-json", action="store_true")
    a = p.parse_args()
    out = run(a.m, d=a.d, rate=a.rate, duration=a.duration,
              replicas=tuple(int(x) for x in a.replicas.split(",")),
              overload_factor=a.overload_factor, max_batch=a.max_batch,
              max_wait_us=a.max_wait_us, max_queue_depth=a.max_queue_depth,
              backend=a.backend, epochs=a.epochs, seed=a.seed,
              emit_json=not a.no_emit_json)
    print(json.dumps({k: v["rows"] for k, v in out.items()}, indent=1))
