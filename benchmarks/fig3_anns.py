"""Fig. 3: first-stage backends vs exact latent inference inside LEMUR.

Claim C3: the ANNS index wins below the very highest recall levels; exact
scan catches up at recall ~1 (and on small corpora).  The IVF arm sweeps
``nprobe`` (the recall/latency knob) as typed ``SearchParams``; with
``backends=[...]`` (wired to ``benchmarks/run.py --backend``) every other
registered backend is measured at its default operating point through the
same unified ``LemurRetriever.search`` pipeline."""
from __future__ import annotations

from benchmarks import common
from repro.core import recall_at
from repro.retriever import IVFSearchParams, SearchParams

NPROBES = (4, 8, 16, 32, 64)


def run(backends=None):
    q, qm = common.queries()
    truth = common.ground_truth()
    r = common.lemur_retriever(128)
    out = {"exact": {}, "ivf": [], "backends": {}}

    exact_params = SearchParams(k_prime=200, use_ann=False)
    t = common.timeit(lambda a, b: r.search(a, b, exact_params), q, qm)
    _, ids = r.search(q, qm, exact_params)
    rec = float(recall_at(ids, truth).mean())
    out["exact"] = {"recall": rec, "qps": q.shape[0] / t}
    common.emit("fig3_exact", t / q.shape[0] * 1e6, f"recall={rec:.3f}")

    for nprobe in NPROBES:
        params = SearchParams(k_prime=200, backend=IVFSearchParams(nprobe=nprobe))
        t = common.timeit(lambda a, b, p=params: r.search(a, b, p), q, qm)
        _, ids = r.search(q, qm, params)
        rec = float(recall_at(ids, truth).mean())
        out["ivf"].append({"nprobe": nprobe, "recall": rec, "qps": q.shape[0] / t})
        common.emit(f"fig3_ivf_nprobe{nprobe}", t / q.shape[0] * 1e6,
                    f"recall={rec:.3f}")

    for name in (backends or []):
        if name == "ivf":
            continue  # swept above
        br = common.lemur_retriever(128, backend=name)
        params = SearchParams(k_prime=200)
        t = common.timeit(lambda a, b, _r=br, p=params: _r.search(a, b, p), q, qm)
        _, ids = br.search(q, qm, params)
        rec = float(recall_at(ids, truth).mean())
        out["backends"][name] = {"recall": rec, "qps": q.shape[0] / t}
        common.emit(f"fig3_{name}", t / q.shape[0] * 1e6, f"recall={rec:.3f}")

    common.save_json("fig3_anns", out)
    return out


if __name__ == "__main__":
    run()
