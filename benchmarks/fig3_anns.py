"""Fig. 3: first-stage backends vs exact latent inference inside LEMUR.

Claim C3: the ANNS index wins below the very highest recall levels; exact
scan catches up at recall ~1 (and on small corpora).  The IVF arm sweeps
``nprobe`` (the recall/latency knob); with ``backends=[...]`` (wired to
``benchmarks/run.py --backend``) every other registered backend is measured
at its default operating point through the same unified ``query()``
pipeline."""
from __future__ import annotations

import jax

from benchmarks import common
from repro.anns import registry
from repro.core import recall_at
from repro.core.index import query

NPROBES = (4, 8, 16, 32, 64)


def run(backends=None):
    q, qm = common.queries()
    truth = common.ground_truth()
    idx = common.lemur_index(128)
    out = {"exact": {}, "ivf": [], "backends": {}}

    def exact(qq, qqm):
        return query(idx, qq, qqm, k_prime=200, use_ann=False)

    t = common.timeit(jax.jit(exact), q, qm)
    _, ids = exact(q, qm)
    rec = float(recall_at(ids, truth).mean())
    out["exact"] = {"recall": rec, "qps": q.shape[0] / t}
    common.emit("fig3_exact", t / q.shape[0] * 1e6, f"recall={rec:.3f}")

    for nprobe in NPROBES:
        def ann(qq, qqm, n=nprobe):
            return query(idx, qq, qqm, k_prime=200, use_ann=True, nprobe=n)

        t = common.timeit(jax.jit(ann), q, qm)
        _, ids = ann(q, qm)
        rec = float(recall_at(ids, truth).mean())
        out["ivf"].append({"nprobe": nprobe, "recall": rec, "qps": q.shape[0] / t})
        common.emit(f"fig3_ivf_nprobe{nprobe}", t / q.shape[0] * 1e6,
                    f"recall={rec:.3f}")

    for name in (backends or []):
        if name == "ivf":
            continue  # swept above
        bidx = common.lemur_index(128, backend=name)
        fn = jax.jit(lambda a, b, _i=bidx: query(_i, a, b, k_prime=200))
        t = common.timeit(fn, q, qm)
        _, ids = fn(q, qm)
        rec = float(recall_at(ids, truth).mean())
        out["backends"][name] = {"recall": rec, "qps": q.shape[0] / t}
        common.emit(f"fig3_{name}", t / q.shape[0] * 1e6, f"recall={rec:.3f}")

    common.save_json("fig3_anns", out)
    return out


if __name__ == "__main__":
    run()
