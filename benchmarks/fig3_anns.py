"""Fig. 3: ANNS (IVF) vs exact latent inference inside LEMUR.

Claim C3: the ANNS index wins below the very highest recall levels; exact
scan catches up at recall ~1 (and on small corpora)."""
from __future__ import annotations

import jax

from benchmarks import common
from repro.core import recall_at
from repro.core.index import query

NPROBES = (4, 8, 16, 32, 64)


def run():
    q, qm = common.queries()
    truth = common.ground_truth()
    idx = common.lemur_index(128)
    out = {"exact": {}, "ivf": []}

    def exact(qq, qqm):
        return query(idx, qq, qqm, k_prime=200, use_ann=False)

    t = common.timeit(jax.jit(exact), q, qm)
    _, ids = exact(q, qm)
    rec = float(recall_at(ids, truth).mean())
    out["exact"] = {"recall": rec, "qps": q.shape[0] / t}
    common.emit("fig3_exact", t / q.shape[0] * 1e6, f"recall={rec:.3f}")

    for nprobe in NPROBES:
        def ann(qq, qqm, n=nprobe):
            return query(idx, qq, qqm, k_prime=200, use_ann=True, nprobe=n)

        t = common.timeit(jax.jit(ann), q, qm)
        _, ids = ann(q, qm)
        rec = float(recall_at(ids, truth).mean())
        out["ivf"].append({"nprobe": nprobe, "recall": rec, "qps": q.shape[0] / t})
        common.emit(f"fig3_ivf_nprobe{nprobe}", t / q.shape[0] * 1e6,
                    f"recall={rec:.3f}")

    common.save_json("fig3_anns", out)
    return out


if __name__ == "__main__":
    run()
