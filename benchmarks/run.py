"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run                 # all
  PYTHONPATH=src python -m benchmarks.run fig2            # one
  PYTHONPATH=src python -m benchmarks.run table2 --backend all
  PYTHONPATH=src python -m benchmarks.run table2 --backend ivf,muvera

Prints ``name,us_per_call,derived`` CSV per the harness contract and writes
results/bench_*.json consumed by EXPERIMENTS.md.  ``--backend`` selects
which registered first-stage backends the backend-aware benches (fig3,
table2) sweep — ``all`` expands to the full registry and emits one
``results/bench_table2_<backend>.json`` per backend so the perf trajectory
tracks backends separately.
"""
from __future__ import annotations

import argparse
import sys
import time

BENCHES = ["fig2", "fig3", "table2", "appendix_d", "kernels",
           "serving_online", "serving_fleet", "recall"]


def _selected(which, bench: str) -> bool:
    """Prefix selection per bench NAME: ``serving`` runs both serving
    benches, ``serving_fleet`` just the fleet one."""
    return any(bench.startswith(w) for w in which)


def _resolve_backends(spec: str | None):
    if not spec:
        return None
    from repro.anns import registry

    if spec == "all":
        return registry.list_backends()
    names = [s for s in spec.split(",") if s]
    for n in names:
        registry.get_backend(n)  # fail fast on unknown names
    return names


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("names", nargs="*", default=[],
                   help=f"benchmarks to run (prefix match); default: {BENCHES}")
    p.add_argument("--backend", default=None,
                   help="first-stage backends for fig3/table2: a registry "
                        "name, comma list, or 'all'")
    p.add_argument("--mesh", default=None,
                   help="table2 also reports sharded QPS over this mesh "
                        "spec, e.g. '1x8' (host devices forced on CPU)")
    p.add_argument("--emit-json", action="store_true",
                   help="also write the repo-root BENCH_*.json perf "
                        "trajectory (BENCH_kernels.json from the kernels "
                        "bench, BENCH_serving.json from table2's fused-vs-"
                        "legacy serving rows)")
    args = p.parse_args(argv)
    which = args.names or BENCHES
    if args.mesh:
        # before ANY bench initializes the jax backend (XLA_FLAGS is
        # read once at backend init — forcing later is a no-op)
        import numpy as np

        from repro.launch.mesh import ensure_devices, parse_mesh_spec

        ensure_devices(int(np.prod(parse_mesh_spec(args.mesh))))
    backends = _resolve_backends(args.backend)

    t0 = time.time()
    if _selected(which, "fig2"):
        from benchmarks import fig2_dprime

        fig2_dprime.run()
    if _selected(which, "fig3"):
        from benchmarks import fig3_anns

        fig3_anns.run(backends=backends)
    if _selected(which, "table2"):
        from benchmarks import table2_qps

        table2_qps.run(backends=backends, mesh=args.mesh,
                       emit_json=args.emit_json)
    if _selected(which, "appendix_d"):
        from benchmarks import appendix_d_training

        appendix_d_training.run()
    if _selected(which, "kernels"):
        from benchmarks import kernels_bench

        kernels_bench.run(emit_json=args.emit_json)
    if _selected(which, "serving_online"):
        from benchmarks import serving_online

        serving_online.run(emit_json=args.emit_json)
    if _selected(which, "serving_fleet"):
        from benchmarks import serving_fleet

        serving_fleet.run(emit_json=args.emit_json)
    if _selected(which, "recall"):
        from benchmarks import recall_bench

        recall_bench.run(emit_json=args.emit_json)
    print(f"# total bench time: {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
