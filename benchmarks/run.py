"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig2       # one

Prints ``name,us_per_call,derived`` CSV per the harness contract and writes
results/bench_*.json consumed by EXPERIMENTS.md.
"""
from __future__ import annotations

import sys
import time

BENCHES = ["fig2", "fig3", "table2", "appendix_d", "kernels"]


def main() -> None:
    which = sys.argv[1:] or BENCHES
    t0 = time.time()
    if any(w.startswith("fig2") for w in which):
        from benchmarks import fig2_dprime

        fig2_dprime.run()
    if any(w.startswith("fig3") for w in which):
        from benchmarks import fig3_anns

        fig3_anns.run()
    if any(w.startswith("table2") for w in which):
        from benchmarks import table2_qps

        table2_qps.run()
    if any(w.startswith("appendix") for w in which):
        from benchmarks import appendix_d_training

        appendix_d_training.run()
    if any(w.startswith("kernel") for w in which):
        from benchmarks import kernels_bench

        kernels_bench.run()
    print(f"# total bench time: {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
