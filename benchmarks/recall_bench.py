"""Recall-regression trail for the compressed corpus tier.

Component-level (no ψ training — this isolates the STORAGE tier): an exact
MaxSim scan over the corpus tokens as each tier stores them, scored against
the unpooled-fp32 exact scan as the oracle.  Tiers:

====================  ====================================================
``fp32``              dense fp32 tokens (the oracle representation)
``sq8``               per-token symmetric int8 (d + 4 scale bytes / token)
``residual-4bit``     codec: centroid id + packed 4-bit/dim residual
``residual-2bit``     codec: centroid id + packed 2-bit/dim residual
====================  ====================================================

each crossed with constant-space token-pooling budgets
(``pages.pool_tokens``; budget 0 = keep every token).  Every row carries
two recall columns against the unpooled-fp32 oracle's top-10:

* ``recall_at_10`` — overlap of the tier's top-10 with the oracle's
  (exact final-ranking agreement — strict, shows the codec's cost);
* ``recall_at_100`` — the FAISS-style 10-in-100: fraction of the oracle
  top-10 surviving in the tier's top-100.  This is the operational metric
  for a storage tier that feeds a k'-budget rerank — what matters is that
  the true winners stay inside the candidate budget, not that tail
  margins at rank ~100 agree.

plus a bytes-per-doc column measured from the ACTUAL encoded arrays
(valid-token payload + the codec tables amortized over the corpus), so
the compression ratios are real, not formula-derived.

``BENCH_recall.json`` (merge-preserve, ``--emit-json``) is the committed
recall trajectory.  Three SystemExit gates make it a regression TRAIL:

* **ratchet** — a re-measured (op, shape, backend) row's recall may not
  drop more than ``REPRO_RECALL_TOL`` (default 0.02) below the committed
  row;
* **codec floor** — residual-4bit recall@100 must stay within 5% of SQ8's
  (relative, unpooled);
* **compression floor** — residual-4bit at the pooled budget must be
  >= 8x smaller per doc than unpooled fp32.

``--self-test-gate`` proves the ratchet actually fires: it fabricates an
impossible committed baseline, asserts the gate trips, and writes nothing.
"""
from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.anns import quantization as quant
from repro.core import maxsim, pages
from repro.data import synthetic

SECTION = "recall_tiers"
BUDGETS = (0, 8)
RECALL_TOL = float(os.environ.get("REPRO_RECALL_TOL", "0.02"))


def _recall(ids: np.ndarray, oracle: np.ndarray) -> float:
    """Fraction of each query's ``oracle`` ids found in its ``ids`` row."""
    hits = [np.intersect1d(a, b[b >= 0]).size / max((b >= 0).sum(), 1)
            for a, b in zip(ids, oracle)]
    return float(np.mean(hits))


def _tier_encode(tier: str, toks, mask, *, seed: int = 0):
    """Encode ``(m, T, d)`` tokens as ``tier`` stores them.

    Returns ``(decoded (m, T, d) fp32, payload_bytes, table_bytes)`` —
    payload counts only the VALID tokens' encoded bytes (page padding is a
    pool-sizing artifact, not a property of the codec), tables are the
    tier's corpus-amortized side arrays (codec centroids/cuts/values)."""
    m = toks.shape[0]
    flat = np.asarray(toks)[np.asarray(mask)]
    if tier == "fp32":
        return np.asarray(toks, np.float32), flat.nbytes, 0
    if tier == "sq8":
        codes, scales = quant.sq8_quant(jnp.asarray(toks))
        dec = np.asarray(quant.sq8_dequant(codes, scales))
        payload = (np.asarray(codes)[np.asarray(mask)].nbytes
                   + np.asarray(scales)[np.asarray(mask)].nbytes)
        return dec, payload, 0
    bits = {"residual-4bit": 4, "residual-2bit": 2}[tier]
    codec = quant.train_residual_codec(
        jax.random.PRNGKey(seed), jnp.asarray(flat), bits=bits, ncent=256)
    cid, packed = quant.residual_encode(codec, jnp.asarray(toks, jnp.float32))
    dec = np.asarray(quant.residual_decode(codec, cid, packed))
    payload = (np.asarray(cid)[np.asarray(mask)].nbytes
               + np.asarray(packed)[np.asarray(mask)].nbytes)
    tables = sum(int(np.asarray(x).nbytes) for x in codec)
    return dec, payload, tables


def measure(m: int, n_queries: int, seed: int = 0) -> list[dict]:
    c = synthetic.make_corpus(m=m, d=common.D, avg_tokens=common.AVG_T,
                              max_tokens=common.MAX_T, n_centers=96,
                              topic_strength=1.6, seed=seed)
    q = jnp.asarray(synthetic.queries_from_corpus_query(
        c, n_queries, common.Q_TOKENS, encoder_noise=0.15, seed=99))
    qm = jnp.ones(q.shape[:2], bool)
    toks0 = np.asarray(c.doc_tokens, np.float32)
    mask0 = np.asarray(c.doc_mask, bool)
    _, oracle10 = maxsim.true_topk(q, qm, jnp.asarray(toks0),
                                   jnp.asarray(mask0), min(10, m))
    oracle10 = np.asarray(oracle10)

    rows = []
    for budget in BUDGETS:
        toks, mask = pages.pool_tokens(toks0, mask0, budget)
        for tier in ("fp32", "sq8", "residual-4bit", "residual-2bit"):
            dec, payload, tables = _tier_encode(tier, toks, mask, seed=seed)
            dm = jnp.asarray(mask)
            row = {"op": "recall", "shape": f"{tier}@pool{budget}",
                   "tier": tier, "budget": int(budget), "m": int(m),
                   "bytes_per_doc": (payload + tables) / m,
                   "backend": jax.default_backend()}
            for k in (10, 100):
                _, ids = maxsim.true_topk(q, qm, jnp.asarray(dec), dm,
                                          min(k, m))
                row[f"recall_at_{k}"] = _recall(np.asarray(ids), oracle10)
            rows.append(row)
            common.emit(f"recall_{tier}_pool{budget}",
                        row["bytes_per_doc"],
                        f"r@10={row['recall_at_10']:.3f},"
                        f"r@100={row['recall_at_100']:.3f},"
                        f"B/doc={row['bytes_per_doc']:.0f}")
    return rows


def _by_shape(rows: list[dict]) -> dict[str, dict]:
    return {r["shape"]: r for r in rows}


def ratchet_violations(fresh: list[dict], committed: dict,
                       tol: float = RECALL_TOL) -> list[str]:
    """Recall drops vs the committed section, keyed (op, shape, backend)."""
    prev = {(r.get("op"), r.get("shape"), r.get("backend")): r
            for r in committed.get(SECTION, {}).get("rows", [])}
    out = []
    for r in fresh:
        old = prev.get((r["op"], r["shape"], r["backend"]))
        if old is None:
            continue
        for col in ("recall_at_10", "recall_at_100"):
            if col in old and r[col] < old[col] - tol:
                out.append(f"{r['shape']}: {col} {r[col]:.3f} < committed "
                           f"{old[col]:.3f} - {tol}")
    return out


def acceptance_violations(fresh: list[dict]) -> list[str]:
    """The codec-floor and compression-floor gates (fresh rows only)."""
    by = _by_shape(fresh)
    out = []
    res4, sq8 = by["residual-4bit@pool0"], by["sq8@pool0"]
    if res4["recall_at_100"] < 0.95 * sq8["recall_at_100"]:
        out.append(f"codec floor: residual-4bit r@100 "
                   f"{res4['recall_at_100']:.3f} < 0.95 * sq8 "
                   f"{sq8['recall_at_100']:.3f}")
    pooled = by[f"residual-4bit@pool{BUDGETS[1]}"]
    ratio = by["fp32@pool0"]["bytes_per_doc"] / pooled["bytes_per_doc"]
    if ratio < 8.0:
        out.append(f"compression floor: fp32/residual-4bit-pooled bytes "
                   f"ratio {ratio:.1f}x < 8x")
    return out


def run(m: int = 2000, n_queries: int = 64, *, emit_json: bool = False,
        self_test_gate: bool = False) -> list[dict]:
    rows = measure(m, n_queries)

    if self_test_gate:
        # fabricate a committed baseline no honest run can reach and prove
        # the ratchet trips on it; nothing is written
        fake = {SECTION: {"rows": [dict(r, recall_at_10=1.5, recall_at_100=1.5)
                                   for r in rows]}}
        if not ratchet_violations(rows, fake):
            raise SystemExit("recall gate self-test FAILED: ratchet did not "
                             "fire on an impossible committed baseline")
        print("# recall gate self-test: ratchet fired as expected",
              file=sys.stderr)
        return rows

    committed = common.load_bench_root("recall")
    violations = (ratchet_violations(rows, committed)
                  + acceptance_violations(rows))
    doc = committed
    common.merge_section(doc, SECTION,
                         common.bench_meta(m=m, n_queries=n_queries,
                                           budgets=list(BUDGETS),
                                           recall_tol=RECALL_TOL), rows)
    common.save_json("recall", doc)
    if emit_json:
        common.save_bench_root("recall", doc)
    if violations:
        raise SystemExit("recall gate violations:\n  "
                         + "\n  ".join(violations))
    return rows


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    p.add_argument("--m", type=int, default=2000)
    p.add_argument("--queries", type=int, default=64)
    p.add_argument("--emit-json", action="store_true",
                   help="write the committed BENCH_recall.json trajectory")
    p.add_argument("--self-test-gate", action="store_true",
                   help="prove the recall ratchet fires (writes nothing)")
    args = p.parse_args(argv)
    run(args.m, args.queries, emit_json=args.emit_json,
        self_test_gate=args.self_test_gate)


if __name__ == "__main__":
    main()
