"""Fig. 2 (left + right): latent-dimension ablation.

Left:  Recall-k@k' of exact-latent LEMUR candidates for d' ∈ {64, 128, 256}
       vs a 10x-wider MUVERA FDE — claim C1: learned beats data-oblivious at
       a fraction of the dimension.
Right: end-to-end (ANNS + rerank) latency/recall per d' — claim C2:
       diminishing returns beyond the middle d'.
(d' values are CPU-scaled from the paper's 1024/2048/4096; the *ratios*
to the FDE dimension match the paper's setup.)
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks import common
from repro.anns import MuveraConfig, doc_fde, mips_topk, query_fde
from repro.core import recall_at
from repro.retriever import SearchParams

D_PRIMES = (64, 128, 256)
FDE_DIM = 1280  # 10x the middle d' — mirrors "10240 vs 1024" in the paper
KPRIMES = (20, 50, 100, 200, 400)


def run():
    q, qm = common.queries()
    truth = common.ground_truth()
    out = {"kprimes": list(KPRIMES), "recall_curves": {}, "e2e": {}}

    # --- left: candidate recall vs k' ---
    for dp in D_PRIMES:
        r = common.lemur_retriever(dp)
        rs = []
        for kp in KPRIMES:
            cand = r.candidates(q, qm, SearchParams(k_prime=kp, use_ann=False))
            rs.append(float(recall_at(cand, truth).mean()))
        out["recall_curves"][f"lemur_d{dp}"] = rs
        common.emit(f"fig2_recall_lemur_d{dp}_k{KPRIMES[-1]}", 0.0, f"recall={rs[-1]:.3f}")

    mcfg = MuveraConfig(r_reps=20, k_sim=5, final_dim=FDE_DIM)
    c = common.corpus()
    dfde = doc_fde(jnp.asarray(c.doc_tokens), jnp.asarray(c.doc_mask), mcfg)
    qfde = query_fde(q, qm, mcfg)
    rs = []
    for kp in KPRIMES:
        _, cand = mips_topk(qfde, dfde, kp)
        rs.append(float(recall_at(cand, truth).mean()))
    out["recall_curves"][f"muvera_fde{FDE_DIM}"] = rs
    common.emit(f"fig2_recall_muvera_fde{FDE_DIM}_k{KPRIMES[-1]}", 0.0, f"recall={rs[-1]:.3f}")

    # --- right: end-to-end latency vs recall per d' ---
    for dp in D_PRIMES:
        r = common.lemur_retriever(dp)
        params = SearchParams(k_prime=200)
        t = common.timeit(lambda qq, qqm, _r=r, p=params: _r.search(qq, qqm, p),
                          q, qm)
        _, ids = r.search(q, qm, params)
        rec = float(recall_at(ids, truth).mean())
        qps = q.shape[0] / t
        out["e2e"][f"d{dp}"] = {"recall": rec, "qps": qps}
        common.emit(f"fig2_e2e_lemur_d{dp}", t / q.shape[0] * 1e6,
                    f"recall={rec:.3f},qps={qps:.0f}")

    common.save_json("fig2_dprime", out)
    return out


if __name__ == "__main__":
    run()
