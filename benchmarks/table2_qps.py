"""Table 2 / Fig. 4: best QPS at ≥80% recall (k=10, CPU-scaled corpus).

Every registered first-stage backend runs through the SAME unified
pool → candidates → rerank pipeline (``LemurRetriever.search``) over the
same trained LEMUR reduction; token-level baselines (muvera, dessert,
token_pruning) simply ignore the latent side of the query batch.  Each
backend gets a hyperparameter grid-search — a list of typed
``SearchParams`` — and we report its fastest configuration clearing the
recall bar (the paper's Pareto protocol), plus the exact-MaxSim latency
ceiling.  The facade compiles one query fn per SearchParams, so ``timeit``
measures steady-state latency by construction.

``run(backends=[...])`` restricts the sweep (wired to
``benchmarks/run.py --backend``); per-backend rows are also written to
``results/bench_table2_<backend>.json`` so the perf trajectory tracks each
backend separately."""
from __future__ import annotations

import dataclasses

import jax

from benchmarks import common
from repro.anns import registry
from repro.core import maxsim, recall_at
from repro.retriever import IVFSearchParams, SearchParams, TokenPruningSearchParams

RECALL_BAR = 0.8

# per-backend query-time grids: typed SearchParams; backends without
# per-call knobs beyond k' (the shared rerank budget) sweep k' only
SWEEPS = {
    "ivf": [SearchParams(k_prime=kp, backend=IVFSearchParams(nprobe=n))
            for n in (8, 16, 32, 64) for kp in (50, 100, 200)],
    "bruteforce": [SearchParams(k_prime=kp) for kp in (50, 100, 200)],
    "muvera": [SearchParams(k_prime=kp) for kp in (50, 100, 200, 400)],
    "dessert": [SearchParams(k_prime=kp) for kp in (50, 100, 200, 400)],
    "token_pruning": [SearchParams(k_prime=kp,
                                   backend=TokenPruningSearchParams(nprobe=n))
                      for n in (2, 4, 8) for kp in (100, 200, 400)],
}


def _row_params(params: SearchParams) -> dict:
    """JSON-able row label for one grid point."""
    row = {"k_prime": params.k_prime}
    if params.backend is not None:
        row |= {k: v for k, v in dataclasses.asdict(params.backend).items()
                if v is not None}
    return row


def _best(rows):
    ok = [r for r in rows if r["recall"] >= RECALL_BAR]
    if not ok:
        return max(rows, key=lambda r: r["recall"]) | {"note": "recall bar missed"}
    return max(ok, key=lambda r: r["qps"])


def sweep_backend(name: str, q, qm, truth):
    """Grid-search one backend's SearchParams through the facade."""
    r = common.lemur_retriever(128, backend=name)
    rows = []
    for params in SWEEPS.get(name, [SearchParams(k_prime=kp)
                                    for kp in (50, 100, 200)]):
        t = common.timeit(lambda a, b, p=params: r.search(a, b, p), q, qm, iters=3)
        _, ids = r.search(q, qm, params)
        rows.append(_row_params(params)
                    | {"recall": float(recall_at(ids, truth).mean()),
                       "qps": q.shape[0] / t})
    return rows


def sweep_sharded(mesh_spec: str, q, qm, truth):
    """The sharded serving row: ``LemurRetriever.shard(mesh)`` (per-shard
    latent scan + rerank + hierarchical merge; the first stage is the exact
    scan, so the only query-time knob is the shared k' budget)."""
    from repro.launch.mesh import make_serving_mesh

    sr = common.lemur_retriever(128).shard(make_serving_mesh(mesh_spec))
    rows = []
    for params in (SearchParams(k_prime=kp) for kp in (50, 100, 200)):
        t = common.timeit(lambda a, b, p=params: sr.search(a, b, p), q, qm, iters=3)
        _, ids = sr.search(q, qm, params)
        rows.append(_row_params(params)
                    | {"recall": float(recall_at(ids, truth).mean()),
                       "qps": q.shape[0] / t})
    return rows


def run(backends=None, mesh=None):
    if mesh:
        # must precede the first jax backend touch below
        import numpy as np

        from repro.launch.mesh import ensure_devices, parse_mesh_spec

        ensure_devices(int(np.prod(parse_mesh_spec(mesh))))
    q, qm = common.queries()
    truth = common.ground_truth()
    c = common.corpus()
    import jax.numpy as jnp

    docs = jnp.asarray(c.doc_tokens)
    mask = jnp.asarray(c.doc_mask)
    out = {}

    for name in backends or registry.list_backends():
        rows = sweep_backend(name, q, qm, truth)
        out[name] = _best(rows)
        common.save_json(f"table2_{name}", {"rows": rows, "best": out[name]})

    # exact MaxSim brute force (the latency ceiling)
    fn = jax.jit(lambda a, b: maxsim.true_topk(a, b, docs, mask, common.K))
    t = common.timeit(fn, q, qm, iters=3)
    out["exact_maxsim"] = {"recall": 1.0, "qps": q.shape[0] / t}

    if mesh:
        rows = sweep_sharded(mesh, q, qm, truth)
        out[f"sharded_{mesh}"] = _best(rows)
        common.save_json(f"table2_sharded_{mesh}", {"rows": rows,
                                                    "best": out[f"sharded_{mesh}"]})

    for name, r in out.items():
        common.emit(f"table2_{name}", 1e6 / max(r["qps"], 1e-9),
                    f"recall={r['recall']:.3f},qps={r['qps']:.0f}")
    common.save_json("table2_qps", out)

    if "ivf" in out:
        baselines = [out[n]["qps"] for n in ("muvera", "token_pruning", "dessert")
                     if n in out]
        if baselines:
            common.emit("table2_speedup_vs_best_baseline", 0.0,
                        f"x{out['ivf']['qps'] / max(max(baselines), 1e-9):.1f}")
    return out


if __name__ == "__main__":
    import argparse

    _p = argparse.ArgumentParser()
    _p.add_argument("--backend", default=None,
                    help="comma list of backends, or 'all'")
    _p.add_argument("--mesh", default=None,
                    help="also report sharded QPS over this mesh, e.g. '1x8'")
    _a = _p.parse_args()
    if _a.backend in (None, "all"):
        _backends = None  # run() defaults to the full registry
    else:
        _backends = [s for s in _a.backend.split(",") if s]
        for _n in _backends:
            registry.get_backend(_n)  # fail fast, before the corpus build
    run(backends=_backends, mesh=_a.mesh)
